//! Differential kernel-fuzz suite for the vectorized kernel floor.
//!
//! Every fast-path kernel is checked byte-for-byte against a scalar
//! reference on randomized inputs:
//! * LSD radix argsort vs. stable comparison argsort over packed
//!   [`SortKeys`] rows — mixed dtypes, mixed directions, `i64::MIN/MAX`,
//!   nulls-first flag bytes, duplicate-heavy and already-sorted inputs,
//!   plus an explicit stability witness.
//! * Bit-parallel [`ValidityMask`] kernels (filter/take/slice/extend/
//!   and/or/popcount) vs. per-bit references, at word-boundary lengths
//!   (63/64/65, 127/128/129) and all-valid / all-null densities.
//! * Dictionary-encoded string wire frames and dictionary-encoded packed
//!   string keys vs. the escaped-bytes path — empty strings, embedded
//!   NULs, and high cardinality forcing code-width promotion.
//!
//! Seeds and case counts come from `HIFRAMES_PROP_SEED` /
//! `HIFRAMES_PROP_CASES` (CI's kernel-fuzz step runs 256 cases); a failure
//! panic prints the one-case re-run command.

use hiframes::column::{
    decode_column, encode_column, encode_column_take, encode_column_with, DictEncoding,
};
use hiframes::datagen::Rng;
use hiframes::ops::keys::{cmp_key_rows, key_rows_nullable};
use hiframes::ops::{group_packed, PackedKeys, SortKeys};
use hiframes::prelude::*;
use hiframes::prop::{forall, forall_cases, scaled_cases};
use std::cmp::Ordering;

const EXTREMES: [i64; 6] = [i64::MIN, i64::MIN + 1, -1, 0, 1, i64::MAX];

fn gen_i64s(rng: &mut Rng, n: usize, lo: i64, hi: i64) -> Vec<i64> {
    (0..n)
        .map(|_| {
            if rng.bool(0.15) {
                *rng.choose(&EXTREMES)
            } else {
                rng.i64_range(lo, hi)
            }
        })
        .collect()
}

fn gen_strs(rng: &mut Rng, n: usize) -> Vec<String> {
    const POOL: [&str; 8] = ["", "a", "ab", "ba", "b\0", "\0", "a\0b", "zzz"];
    (0..n)
        .map(|_| {
            let base = *rng.choose(&POOL);
            if rng.bool(0.3) {
                format!("{base}{}", rng.i64_range(0, 40))
            } else {
                base.to_string()
            }
        })
        .collect()
}

fn gen_orders(rng: &mut Rng, ncols: usize) -> Vec<SortOrder> {
    (0..ncols)
        .map(|_| {
            if rng.bool(0.5) {
                SortOrder::Desc
            } else {
                SortOrder::Asc
            }
        })
        .collect()
}

fn opt_mask(rng: &mut Rng, n: usize, p_some: f64) -> Option<Vec<bool>> {
    if rng.bool(p_some) {
        Some((0..n).map(|_| rng.bool(0.8)).collect())
    } else {
        None
    }
}

/// The stable-argsort reference every radix result must reproduce exactly.
fn stable_argsort(n: usize, mut cmp: impl FnMut(usize, usize) -> Ordering) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| cmp(a, b));
    idx
}

// ---------------------------------------------------------------------------
// Radix argsort vs. comparison argsort
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
struct FixedKeysCase {
    a: Vec<i64>,
    b: Vec<bool>,
    mask_a: Option<Vec<bool>>,
    orders: Vec<SortOrder>,
    with_flags: bool,
}

fn gen_fixed_case(rng: &mut Rng, lo: i64, hi: i64) -> FixedKeysCase {
    let n = rng.usize(300);
    FixedKeysCase {
        a: gen_i64s(rng, n, lo, hi),
        b: (0..n).map(|_| rng.bool(0.5)).collect(),
        mask_a: opt_mask(rng, n, 0.5),
        orders: gen_orders(rng, 2),
        with_flags: rng.bool(0.5),
    }
}

fn check_fixed_case(case: &FixedKeysCase) -> Result<(), String> {
    let a = Column::I64(case.a.clone());
    let b = Column::Bool(case.b.clone());
    let mask = case.mask_a.as_ref().map(|m| ValidityMask::from_bools(m));
    let masks = [mask.as_ref(), None];
    let sk = SortKeys::pack_nullable(&[&a, &b], &masks, &case.orders, case.with_flags)
        .map_err(|e| e.to_string())?
        .expect("Int64/Bool keys pack to fixed width");
    let radix = sk.radix_argsort();
    let reference = sk.comparison_argsort();
    if radix != reference {
        return Err(format!("radix {radix:?} != comparison {reference:?}"));
    }
    if sk.argsort() != reference {
        return Err("argsort dispatch disagrees with comparison sort".into());
    }
    // stability witness: equal packed rows must keep original index order
    for w in radix.windows(2) {
        if sk.row(w[0]) == sk.row(w[1]) && w[0] > w[1] {
            return Err(format!("unstable on equal rows: {} before {}", w[0], w[1]));
        }
    }
    Ok(())
}

#[test]
fn radix_matches_comparison_on_wide_keys() {
    forall(
        "radix-vs-comparison-wide",
        |rng| gen_fixed_case(rng, -5000, 5000),
        check_fixed_case,
    );
}

#[test]
fn radix_matches_comparison_on_duplicate_heavy_keys() {
    forall(
        "radix-vs-comparison-duplicates",
        |rng| gen_fixed_case(rng, -2, 3),
        check_fixed_case,
    );
}

#[test]
fn radix_argsort_range_matches_stable_slice_sort() {
    forall(
        "radix-argsort-range",
        |rng| {
            let case = gen_fixed_case(rng, -40, 40);
            let n = case.a.len();
            let start = if n == 0 { 0 } else { rng.usize(n) };
            let end = start + rng.usize(n - start + 1);
            (case, start, end)
        },
        |(case, start, end)| {
            let a = Column::I64(case.a.clone());
            let b = Column::Bool(case.b.clone());
            let sk = SortKeys::pack(&[&a, &b], &case.orders)
                .map_err(|e| e.to_string())?
                .expect("fixed keys");
            let got = sk.argsort_range(*start, *end);
            let mut want: Vec<usize> = (*start..*end).collect();
            want.sort_by(|&x, &y| sk.row(x).cmp(sk.row(y)));
            if got != want {
                return Err(format!("range [{start}, {end}): {got:?} != {want:?}"));
            }
            Ok(())
        },
    );
}

#[test]
fn radix_on_sorted_input_is_identity() {
    // already-sorted duplicate runs: the stable sort is the identity, and
    // the constant high bytes exercise the skip-pass fast path
    let col = Column::I64((0..1000).map(|i| i / 4).collect());
    let sk = SortKeys::pack(&[&col], &[SortOrder::Asc])
        .unwrap()
        .expect("fixed keys");
    let identity: Vec<usize> = (0..1000).collect();
    assert_eq!(sk.radix_argsort(), identity);
    assert_eq!(sk.argsort(), identity);
}

#[test]
fn null_flag_bytes_order_nulls_first_asc_last_desc() {
    let vals = Column::I64(vec![5, 3, 5, 1]);
    let mask = ValidityMask::from_bools(&[true, false, true, false]);
    let asc = SortKeys::pack_nullable(&[&vals], &[Some(&mask)], &[SortOrder::Asc], true)
        .unwrap()
        .expect("fixed keys");
    assert_eq!(asc.radix_argsort(), vec![1, 3, 0, 2]);
    assert_eq!(asc.radix_argsort(), asc.comparison_argsort());
    let desc = SortKeys::pack_nullable(&[&vals], &[Some(&mask)], &[SortOrder::Desc], true)
        .unwrap()
        .expect("fixed keys");
    assert_eq!(desc.radix_argsort(), vec![0, 2, 1, 3]);
    assert_eq!(desc.radix_argsort(), desc.comparison_argsort());
}

#[test]
fn string_sort_keys_match_cmp_key_rows_oracle() {
    forall(
        "string-sort-keys-vs-cmp-key-rows",
        |rng| {
            let n = rng.usize(150);
            let s = gen_strs(rng, n);
            let v = gen_i64s(rng, n, -10, 10);
            let mask_s = opt_mask(rng, n, 0.5);
            let orders = gen_orders(rng, 2);
            (s, v, mask_s, orders)
        },
        |(s, v, mask_s, orders)| {
            let cs = Column::Str(s.clone());
            let cv = Column::I64(v.clone());
            let mask = mask_s.as_ref().map(|m| ValidityMask::from_bools(m));
            let krows = key_rows_nullable(&[&cs, &cv], &[mask.as_ref(), None])
                .map_err(|e| e.to_string())?;
            let sk = SortKeys::from_key_rows(&krows, orders);
            let got = sk.argsort();
            let want = stable_argsort(krows.len(), |a, b| {
                cmp_key_rows(&krows[a], &krows[b], orders)
            });
            if got != want {
                return Err(format!("dict sort keys {got:?} != key-row oracle {want:?}"));
            }
            if sk.radix_argsort() != want || sk.comparison_argsort() != want {
                return Err("radix/comparison disagree on dict-coded string keys".into());
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Bit-parallel validity-mask kernels vs. per-bit references
// ---------------------------------------------------------------------------

const BOUNDARY_LENS: [usize; 13] = [0, 1, 2, 31, 32, 33, 63, 64, 65, 127, 128, 129, 200];

#[derive(Debug, Clone)]
struct MaskCase {
    bits: Vec<bool>,
    bits2: Vec<bool>,
    keep: Vec<bool>,
    idx: Vec<usize>,
    opt_idx: Vec<Option<usize>>,
    start: usize,
    slice_len: usize,
    grow: usize,
}

fn gen_mask_case(rng: &mut Rng) -> MaskCase {
    let len = if rng.bool(0.25) {
        rng.usize(300)
    } else {
        *rng.choose(&BOUNDARY_LENS)
    };
    // all-valid, all-null, and mixed densities
    let p = *rng.choose(&[0.0, 0.1, 0.5, 0.9, 1.0]);
    let bits: Vec<bool> = (0..len).map(|_| rng.bool(p)).collect();
    let bits2: Vec<bool> = (0..len).map(|_| rng.bool(0.5)).collect();
    let keep: Vec<bool> = (0..len).map(|_| rng.bool(0.5)).collect();
    let n_idx = rng.usize(2 * len + 1);
    let idx: Vec<usize> = (0..n_idx).map(|_| rng.usize(len.max(1))).collect();
    let opt_idx: Vec<Option<usize>> = (0..n_idx)
        .map(|_| {
            if rng.bool(0.3) {
                None
            } else {
                Some(rng.usize(len.max(1)))
            }
        })
        .collect();
    let start = rng.usize(len + 1);
    let slice_len = rng.usize(len - start + 1);
    MaskCase {
        idx: if len == 0 { Vec::new() } else { idx },
        opt_idx: if len == 0 { Vec::new() } else { opt_idx },
        bits,
        bits2,
        keep,
        start,
        slice_len,
        grow: rng.usize(130),
    }
}

fn check_mask_case(case: &MaskCase) -> Result<(), String> {
    let bits = &case.bits;
    let m = ValidityMask::from_bools(bits);
    let eq = |what: &str, got: Vec<bool>, want: Vec<bool>| {
        if got == want {
            Ok(())
        } else {
            Err(format!("{what}: {got:?} != {want:?}"))
        }
    };
    eq("to_bools roundtrip", m.to_bools(), bits.clone())?;
    if (0..bits.len()).any(|i| m.get(i) != bits[i]) {
        return Err("get(i) disagrees with source bits".into());
    }
    if m.count_valid() != bits.iter().filter(|&&b| b).count() {
        return Err("count_valid != per-bit popcount".into());
    }
    if m.all_valid() != bits.iter().all(|&b| b) {
        return Err("all_valid != per-bit all()".into());
    }
    let m2 = ValidityMask::from_bools(&case.bits2);
    let zip_with = |f: fn(bool, bool) -> bool| -> Vec<bool> {
        bits.iter().zip(&case.bits2).map(|(&x, &y)| f(x, y)).collect()
    };
    eq("and", m.and(&m2).to_bools(), zip_with(|x, y| x && y))?;
    eq("or", m.or(&m2).to_bools(), zip_with(|x, y| x || y))?;
    let filtered: Vec<bool> = bits
        .iter()
        .zip(&case.keep)
        .filter(|&(_, &k)| k)
        .map(|(&b, _)| b)
        .collect();
    eq("filter", m.filter(&case.keep).to_bools(), filtered)?;
    let taken: Vec<bool> = case.idx.iter().map(|&i| bits[i]).collect();
    eq("take", m.take(&case.idx).to_bools(), taken)?;
    let opt_taken: Vec<bool> = case
        .opt_idx
        .iter()
        .map(|o| o.map_or(false, |i| bits[i]))
        .collect();
    eq("take_opt", m.take_opt(&case.opt_idx).to_bools(), opt_taken)?;
    let sliced = bits[case.start..case.start + case.slice_len].to_vec();
    eq("slice", m.slice(case.start, case.slice_len).to_bools(), sliced)?;
    let mut grown = m.clone();
    grown.extend(&m2);
    let mut want: Vec<bool> = bits.clone();
    want.extend_from_slice(&case.bits2);
    eq("extend", grown.to_bools(), want.clone())?;
    grown.extend_valid(case.grow);
    want.extend((0..case.grow).map(|_| true));
    eq("extend_valid", grown.to_bools(), want)
}

#[test]
fn mask_kernels_match_per_bit_references() {
    forall("mask-kernels", gen_mask_case, check_mask_case);
}

#[test]
fn column_filter_matches_retain_reference() {
    forall(
        "column-filter",
        |rng| {
            let len = *rng.choose(&BOUNDARY_LENS);
            let v = gen_i64s(rng, len, -100, 100);
            let s = gen_strs(rng, len);
            let p = *rng.choose(&[0.0, 0.5, 1.0]);
            let keep: Vec<bool> = (0..len).map(|_| rng.bool(p)).collect();
            (v, s, keep)
        },
        |(v, s, keep)| {
            let pick = |b: &[bool]| -> Vec<usize> {
                b.iter()
                    .enumerate()
                    .filter(|&(_, &k)| k)
                    .map(|(i, _)| i)
                    .collect()
            };
            let kept = pick(keep);
            let got = Column::I64(v.clone()).filter(keep);
            let want = Column::I64(kept.iter().map(|&i| v[i]).collect());
            if got != want {
                return Err(format!("I64 filter: {got:?} != {want:?}"));
            }
            let got = Column::Str(s.clone()).filter(keep);
            let want = Column::Str(kept.iter().map(|&i| s[i].clone()).collect());
            if got != want {
                return Err(format!("Str filter: {got:?} != {want:?}"));
            }
            if hiframes::column::count_true(keep) != kept.len() {
                return Err("count_true != per-bit count".into());
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Dictionary-encoded string keys and wire frames vs. the escaped-bytes path
// ---------------------------------------------------------------------------

#[test]
fn dict_wire_roundtrips_under_every_mode() {
    forall(
        "dict-wire-roundtrip",
        |rng| {
            let n = rng.usize(200);
            gen_strs(rng, n)
        },
        |v| {
            let col = Column::Str(v.clone());
            let mut sizes = Vec::new();
            for mode in [DictEncoding::Off, DictEncoding::Force, DictEncoding::Auto] {
                let mut buf = Vec::new();
                encode_column_with(&col, mode, &mut buf);
                let mut pos = 0;
                let back = decode_column(&buf, &mut pos).map_err(|e| e.to_string())?;
                if back != col {
                    return Err(format!("{mode:?} roundtrip changed the column"));
                }
                if pos != buf.len() {
                    return Err(format!("{mode:?} decode consumed {pos} of {} bytes", buf.len()));
                }
                sizes.push(buf.len());
            }
            // Auto picks the dictionary frame only when strictly smaller
            if sizes[2] > sizes[0] {
                return Err(format!("auto frame {} > plain frame {}", sizes[2], sizes[0]));
            }
            Ok(())
        },
    );
}

#[test]
fn dict_code_width_promotes_with_cardinality() {
    // distinct counts straddling the u8 and u16 code-width limits; Force
    // keeps the dictionary frame even when plain encoding would be smaller
    for distinct in [200usize, 300, 70_000] {
        let v: Vec<String> = (0..distinct + 50).map(|i| format!("k{}", i % distinct)).collect();
        let col = Column::Str(v);
        let mut buf = Vec::new();
        encode_column_with(&col, DictEncoding::Force, &mut buf);
        assert_eq!(buf[0], 4, "Force must emit the dictionary tag");
        let mut pos = 0;
        let back = decode_column(&buf, &mut pos).unwrap();
        assert_eq!(back, col, "promotion roundtrip at {distinct} distinct codes");
    }
}

#[test]
fn dict_frame_wins_on_duplicates_and_loses_on_unique_strings() {
    let dup: Vec<String> = (0..500).map(|i| format!("long-shared-payload-{}", i % 4)).collect();
    let mut buf = Vec::new();
    encode_column_with(&Column::Str(dup), DictEncoding::Auto, &mut buf);
    assert_eq!(buf[0], 4, "duplicate-heavy strings should dict-encode");
    let unique: Vec<String> = (0..500).map(|i| format!("unique-{i}")).collect();
    buf.clear();
    encode_column_with(&Column::Str(unique), DictEncoding::Auto, &mut buf);
    assert_eq!(buf[0], 3, "unique strings should stay plain");
}

#[test]
fn encode_take_matches_take_then_encode() {
    forall(
        "dict-encode-take",
        |rng| {
            let n = rng.usize(150);
            let v = gen_strs(rng, n);
            let n_idx = rng.usize(2 * v.len() + 1);
            let idx: Vec<usize> = (0..n_idx).map(|_| rng.usize(v.len().max(1))).collect();
            (v.clone(), if v.is_empty() { Vec::new() } else { idx })
        },
        |(v, idx)| {
            let col = Column::Str(v.clone());
            let mut direct = Vec::new();
            encode_column_take(&col, idx, &mut direct);
            let mut staged = Vec::new();
            encode_column(&col.take(idx), &mut staged);
            if direct != staged {
                return Err("encode_column_take != take-then-encode".into());
            }
            Ok(())
        },
    );
}

#[test]
fn packed_dict_keys_agree_with_key_row_oracle() {
    forall_cases(
        "packed-dict-keys",
        scaled_cases(32),
        |rng| {
            let n = rng.usize(40);
            let m = rng.usize(40);
            let any_mask = rng.bool(0.6);
            (
                gen_strs(rng, n),
                gen_strs(rng, m),
                if any_mask { opt_mask(rng, n, 0.7) } else { None },
                if any_mask { opt_mask(rng, m, 0.7) } else { None },
            )
        },
        |(l, r, lmask, rmask)| {
            let (cl, cr) = (Column::Str(l.clone()), Column::Str(r.clone()));
            let ml = lmask.as_ref().map(|m| ValidityMask::from_bools(m));
            let mr = rmask.as_ref().map(|m| ValidityMask::from_bools(m));
            // both join sides must agree on the flag-byte layout
            let flags = ml.is_some() || mr.is_some();
            let pl = PackedKeys::pack_masked(&[&cl], &[ml.as_ref()], flags)
                .map_err(|e| e.to_string())?;
            let pr = PackedKeys::pack_masked(&[&cr], &[mr.as_ref()], flags)
                .map_err(|e| e.to_string())?;
            if !matches!(pl, PackedKeys::Dict { .. }) {
                return Err("single string key column must pack to the Dict layout".into());
            }
            let kl = key_rows_nullable(&[&cl], &[ml.as_ref()]).map_err(|e| e.to_string())?;
            let kr = key_rows_nullable(&[&cr], &[mr.as_ref()]).map_err(|e| e.to_string())?;
            for (i, krow_l) in kl.iter().enumerate() {
                for (j, krow_r) in kr.iter().enumerate() {
                    let want = cmp_key_rows(krow_l, krow_r, &[]);
                    if pl.cmp_rows(i, &pr, j) != want {
                        return Err(format!("cmp_rows({i}, {j}) != key-row oracle {want:?}"));
                    }
                    if pl.eq_rows(i, &pr, j) != (want == Ordering::Equal) {
                        return Err(format!("eq_rows({i}, {j}) != key-row oracle"));
                    }
                    if want == Ordering::Equal && pl.hash_row(i) != pr.hash_row(j) {
                        return Err(format!("equal rows {i}/{j} hash differently"));
                    }
                }
            }
            // dense grouping over dict codes matches distinct key-row count
            let mut distinct = kl.clone();
            distinct.sort_by(|a, b| cmp_key_rows(a, b, &[]));
            distinct.dedup();
            if group_packed(&pl).num_groups() != distinct.len() {
                return Err("group_packed group count != distinct key rows".into());
            }
            Ok(())
        },
    );
}

#[test]
fn dict_layout_is_byte_identical_to_bytes_layout() {
    forall_cases(
        "dict-vs-bytes-layout",
        scaled_cases(32),
        |rng| {
            let n = rng.usize(40);
            (gen_strs(rng, n), opt_mask(rng, n, 0.5))
        },
        |(v, maskbits)| {
            let col = Column::Str(v.clone());
            let mask = maskbits.as_ref().map(|m| ValidityMask::from_bools(m));
            let dict = PackedKeys::pack_nullable(&[&col], &[mask.as_ref()])
                .map_err(|e| e.to_string())?;
            // mirror the Dict rows as an explicit Bytes layout: the dict
            // entries are exact Bytes-layout encodings, so the two must be
            // mutually comparable and hash identically
            let mut offsets = vec![0usize];
            let mut data = Vec::new();
            for i in 0..dict.len() {
                dict.append_row_bytes(i, &mut data);
                offsets.push(data.len());
            }
            let bytes = PackedKeys::Bytes { offsets, data };
            for i in 0..dict.len() {
                if dict.hash_row(i) != bytes.hash_row(i) {
                    return Err(format!("row {i} hashes differently across layouts"));
                }
                let mut enc = Vec::new();
                bytes.append_row_bytes(i, &mut enc);
                if !dict.row_matches(i, &enc) {
                    return Err(format!("row {i}: row_matches rejects its own encoding"));
                }
                if dict.hash_encoded_row(&enc) != dict.hash_row(i) {
                    return Err(format!("row {i}: encoded-row hash disagrees"));
                }
                for j in 0..dict.len() {
                    if dict.cmp_rows(i, &bytes, j) != bytes.cmp_rows(i, &dict, j) {
                        return Err(format!("cmp_rows({i}, {j}) not layout-symmetric"));
                    }
                    let both_valid = maskbits.as_ref().map_or(true, |m| m[i] && m[j]);
                    let both_null = maskbits.as_ref().map_or(false, |m| !m[i] && !m[j]);
                    if dict.eq_rows(i, &bytes, j) != (v[i] == v[j] && both_valid || both_null) {
                        return Err(format!("eq_rows({i}, {j}) != string/null oracle"));
                    }
                }
            }
            Ok(())
        },
    );
}
