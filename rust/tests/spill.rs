//! Out-of-core property tests: join, aggregate and sort under per-rank
//! memory budgets must agree *byte for byte* with the unbudgeted in-memory
//! paths and the serial oracle, at every budget from "fits easily" (100% of
//! the input) down to "spills hard" (5%), while the global spill counters
//! prove the tight runs really went to disk. Budgets are passed explicitly
//! through `ExecOptions.mem_budget` / `SpillCtx` — never the env knob — so
//! parallel test cases cannot race on process state.

use hiframes::comm::{block_range, run_spmd};
use hiframes::datagen::Rng;
use hiframes::exec::{collect, collect_serial, ExecOptions};
use hiframes::ir::{source_mem, Plan};
use hiframes::metrics::spill_stats;
use hiframes::ops::aggregate::{AggSpec, AggStrategy};
use hiframes::ops::{self, KeyNullability, MemoryBudget, SpillCtx};
use hiframes::prelude::*;
use hiframes::types::JoinStrategy;

fn opts(workers: usize, mem_budget: Option<usize>) -> ExecOptions {
    ExecOptions {
        workers,
        mem_budget,
        ..Default::default()
    }
}

/// A fact/dim pair: duplicate-heavy group keys, a float measure, a
/// partially-matching dimension with a nullable payload column.
fn pipeline_tables(rows: usize) -> (Table, Table) {
    let mut rng = Rng::new(7);
    let grp: Vec<i64> = (0..rows).map(|_| rng.i64_range(0, 40)).collect();
    let left = Table::from_pairs(vec![
        ("id", Column::I64((0..rows as i64).collect())),
        ("grp", Column::I64(grp)),
        (
            "val",
            Column::F64((0..rows).map(|i| (i as f64 * 1.7) % 31.0).collect()),
        ),
    ])
    .unwrap();
    // ~2/3 of the ids match; every 7th tag is null
    let rid: Vec<i64> = (0..rows as i64).filter(|i| i % 3 != 0).collect();
    let tag: Vec<i64> = rid.iter().map(|i| i * 5).collect();
    let tag_valid: Vec<bool> = rid.iter().map(|i| i % 7 != 0).collect();
    let right = Table::from_pairs(vec![
        ("rid", Column::I64(rid)),
        ("tag", Column::I64(tag)),
    ])
    .unwrap()
    .with_null_mask("tag", ValidityMask::from_bools(&tag_valid))
    .unwrap();
    (left, right)
}

fn join_then_sort(left: &Table, right: &Table) -> Plan {
    // join + full-width sort: both sides of the budget story on many rows
    Plan::Sort {
        input: Box::new(Plan::Join {
            left: Box::new(source_mem("l", left.clone())),
            right: Box::new(source_mem("r", right.clone())),
            on: vec![("id".into(), "rid".into())],
            how: JoinType::Left,
            strategy: JoinStrategy::Hash,
        }),
        keys: vec![
            ("grp".into(), SortOrder::Asc),
            ("id".into(), SortOrder::Asc),
        ],
    }
}

fn join_then_aggregate(left: &Table, right: &Table) -> Plan {
    // the aggregation input (join output) is what must spill here; the
    // final sort output is 40 groups and stays tiny
    Plan::Sort {
        input: Box::new(Plan::Aggregate {
            input: Box::new(Plan::Join {
                left: Box::new(source_mem("l", left.clone())),
                right: Box::new(source_mem("r", right.clone())),
                on: vec![("id".into(), "rid".into())],
                how: JoinType::Left,
                strategy: JoinStrategy::Hash,
            }),
            keys: vec!["grp".into()],
            aggs: vec![
                AggExpr::new("sv", AggFn::Sum, col("val")),
                AggExpr::new("st", AggFn::Sum, col("tag")),
            ],
        }),
        keys: vec![("grp".into(), SortOrder::Asc)],
    }
}

#[test]
fn budgeted_pipelines_agree_with_serial_and_unbudgeted() {
    let (left, right) = pipeline_tables(3000);
    let input_bytes = left.byte_size() + right.byte_size();
    for plan in [
        join_then_sort(&left, &right),
        join_then_aggregate(&left, &right),
    ] {
        let serial = collect_serial(plan.clone()).unwrap();
        for workers in [2usize, 3] {
            let unbudgeted = collect(plan.clone(), &opts(workers, None)).unwrap();
            assert_eq!(unbudgeted, serial, "workers={workers}");
            for frac in [1.0f64, 0.25, 0.05] {
                let budget = ((input_bytes as f64) * frac) as usize;
                let before = spill_stats().snapshot();
                let got = collect(plan.clone(), &opts(workers, Some(budget))).unwrap();
                let after = spill_stats().snapshot();
                assert_eq!(got, unbudgeted, "workers={workers} frac={frac}");
                if frac <= 0.05 {
                    // counters are process-global and other tests may add to
                    // them concurrently, so assert a monotonic delta only
                    assert!(
                        after.bytes_spilled > before.bytes_spilled,
                        "workers={workers} frac={frac}: nothing spilled"
                    );
                    assert!(after.spill_passes > before.spill_passes);
                    assert!(after.merge_passes > before.merge_passes);
                }
            }
        }
    }
}

#[test]
fn all_join_types_budgeted_match_unbudgeted() {
    let mut rng = Rng::new(99);
    let n = 600usize;
    // half-overlapping key ranges: matched, left-only and right-only rows
    let lk: Vec<i64> = (0..n).map(|_| rng.i64_range(0, 50)).collect();
    let rk: Vec<i64> = (0..n).map(|_| rng.i64_range(25, 75)).collect();
    let lmask: Vec<bool> = (0..n).map(|i| i % 11 != 0).collect();
    let rmask: Vec<bool> = (0..n).map(|i| i % 13 != 0).collect();
    for how in [
        JoinType::Inner,
        JoinType::Left,
        JoinType::Right,
        JoinType::Outer,
        JoinType::Semi,
        JoinType::Anti,
    ] {
        let run = |budget: Option<usize>| {
            run_spmd(2, |c| {
                let (s, l) = block_range(n, 2, c.rank());
                let lkc = Column::I64(lk[s..s + l].to_vec());
                let lvc = Column::I64((s as i64..(s + l) as i64).collect());
                let lm = ValidityMask::from_bools(&lmask[s..s + l]);
                let rkc = Column::I64(rk[s..s + l].to_vec());
                let rvc = Column::I64((s as i64..(s + l) as i64).map(|i| i * 2).collect());
                let rm = ValidityMask::from_bools(&rmask[s..s + l]);
                let spill = SpillCtx::new(MemoryBudget::from_opt(budget), c.rank());
                ops::distributed_join_on_budgeted(
                    &c,
                    &[(&lkc, Some(&lm))],
                    &[(&lvc, None)],
                    &[(&rkc, Some(&rm))],
                    &[(&rvc, None)],
                    how,
                    JoinStrategy::Hash,
                    KeyNullability::Runtime,
                    &spill,
                )
                .unwrap()
            })
        };
        let base = run(None);
        let before = spill_stats().snapshot();
        let tight = run(Some(512)); // per-rank build side ~5KB >> 512B
        let after = spill_stats().snapshot();
        assert_eq!(base, tight, "join type {how} diverged under budget");
        assert!(after.bytes_spilled > before.bytes_spilled, "{how}: no spill");
    }
}

#[test]
fn budgeted_aggregate_is_bit_identical() {
    // f64 sums must be *bit*-equal: the spill path may not change any
    // group's accumulation order
    let mut rng = Rng::new(3);
    let n = 900usize;
    let keys: Vec<i64> = (0..n).map(|_| rng.i64_range(0, 60)).collect();
    let vals: Vec<f64> = (0..n).map(|i| ((i * 37) % 97) as f64 * 0.1).collect();
    let kmask: Vec<bool> = (0..n).map(|i| i % 9 != 0).collect();
    let run = |budget: Option<usize>| {
        run_spmd(3, |c| {
            let (s, l) = block_range(n, 3, c.rank());
            let kc = Column::I64(keys[s..s + l].to_vec());
            let km = ValidityMask::from_bools(&kmask[s..s + l]);
            let vc = Column::F64(vals[s..s + l].to_vec());
            let spill = SpillCtx::new(MemoryBudget::from_opt(budget), c.rank());
            ops::distributed_aggregate_keys_budgeted(
                &c,
                &[(&kc, Some(&km))],
                &[(&vc, None)],
                &[AggSpec {
                    func: AggFn::Sum,
                    input_dtype: DType::F64,
                }],
                AggStrategy::RawShuffle,
                KeyNullability::Runtime,
                &spill,
            )
            .unwrap()
        })
    };
    let base = run(None);
    let before = spill_stats().snapshot();
    let tight = run(Some(400));
    let after = spill_stats().snapshot();
    assert_eq!(base, tight, "budgeted aggregation diverged");
    assert!(after.bytes_spilled > before.bytes_spilled);
}

#[test]
fn env_budget_reaches_exec_options() {
    // ExecOptions::default() is where HIFRAMES_MEM_BUDGET lands; the test
    // keeps its hands off the env (races) and checks explicit parsing only
    assert_eq!(hiframes::config::parse_byte_size("64k"), Some(64 << 10));
    let o = ExecOptions {
        mem_budget: hiframes::config::parse_byte_size("64k"),
        ..Default::default()
    };
    assert_eq!(o.mem_budget, Some(64 << 10));
    assert!(MemoryBudget::from_opt(o.mem_budget).is_limited());
}
