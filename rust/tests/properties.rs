//! Property-based suites over the coordinator invariants (mini-prop
//! harness; the offline image has no proptest — see DESIGN.md §3):
//!
//! * shuffle preserves the global (key, value) multiset and routes every
//!   key to its owner;
//! * distributed join ≡ nested-loop oracle, any worker count;
//! * distributed aggregate ≡ serial fold, both strategies;
//! * cumsum/stencil ≡ serial oracles on arbitrary splits;
//! * rebalance yields 1D_BLOCK chunk sizes and preserves order;
//! * sample-sort produces a globally sorted permutation;
//! * optimizer passes preserve query semantics on randomized plans;
//! * agg-state merge is associative-commutative (pre-agg soundness);
//! * packed composite keys ≡ the KeyVal path on hash routing, equality and
//!   sort order (incl. i64::MIN/MAX, empty strings, embedded NULs, mixed
//!   dtypes — see `prop_packed_keys_*` / `prop_sort_keys_*`).

use hiframes::column::Column;
use hiframes::comm::{block_range, run_spmd};
use hiframes::datagen::Rng;
use hiframes::exec::{collect_optimized, ExecOptions};
use hiframes::expr::{col, lit, AggExpr, AggFn, AggState};
use hiframes::ops;
use hiframes::ops::keys::{cmp_key_rows, key_rows, PackedKeys, SortKeys};
use hiframes::passes::{optimize, PassOptions};
use hiframes::prelude::*;
use hiframes::prop::{forall, gen};
use hiframes::types::{DType, SortOrder};

fn workers_for(seed: &[i64]) -> usize {
    1 + (seed.len() % 4)
}

#[test]
fn prop_shuffle_preserves_multiset_and_ownership() {
    forall(
        "shuffle-multiset",
        |rng| {
            let n = rng.usize(200);
            let keys: Vec<i64> = (0..n).map(|_| rng.i64_range(-30, 30)).collect();
            keys
        },
        |keys| {
            let p = workers_for(keys);
            let out = run_spmd(p, |c| {
                let (s, l) = block_range(keys.len(), c.nranks(), c.rank());
                let local = &keys[s..s + l];
                let vals = Column::I64(local.iter().map(|&k| k * 31).collect());
                let (k, cols) = ops::shuffle_by_key(&c, local, &[vals]).unwrap();
                (c.rank(), k, cols[0].as_i64().to_vec())
            });
            let mut got: Vec<i64> = Vec::new();
            for (rank, ks, vs) in &out {
                for (k, v) in ks.iter().zip(vs) {
                    if ops::shuffle::owner_of(*k, p) != *rank {
                        return Err(format!("key {k} on wrong rank {rank}"));
                    }
                    if *v != k * 31 {
                        return Err(format!("payload detached: {k} -> {v}"));
                    }
                    got.push(*k);
                }
            }
            let mut want = keys.clone();
            want.sort_unstable();
            got.sort_unstable();
            if got != want {
                return Err("multiset changed".to_string());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_distributed_join_matches_nested_loop() {
    forall(
        "join-oracle",
        |rng| {
            let nl = rng.usize(80);
            let nr = rng.usize(80);
            let lk: Vec<i64> = (0..nl).map(|_| rng.i64_range(0, 15)).collect();
            let rk: Vec<i64> = (0..nr).map(|_| rng.i64_range(0, 15)).collect();
            (lk, rk)
        },
        |(lk, rk)| {
            let p = 1 + (lk.len() + rk.len()) % 3;
            let out = run_spmd(p, |c| {
                let (ls, ll) = block_range(lk.len(), c.nranks(), c.rank());
                let (rs, rl) = block_range(rk.len(), c.nranks(), c.rank());
                let (keys, _, _) = ops::distributed_join(
                    &c,
                    &lk[ls..ls + ll],
                    &[],
                    &rk[rs..rs + rl],
                    &[],
                )
                .unwrap();
                keys
            });
            let mut got: Vec<i64> = out.into_iter().flatten().collect();
            got.sort_unstable();
            let mut want = Vec::new();
            for &a in lk {
                for &b in rk {
                    if a == b {
                        want.push(a);
                    }
                }
            }
            want.sort_unstable();
            (got == want)
                .then_some(())
                .ok_or_else(|| format!("join sizes {} vs {}", got.len(), want.len()))
        },
    );
}

#[test]
fn prop_aggregate_strategies_match_serial() {
    use hiframes::ops::aggregate::{AggSpec, AggStrategy};
    forall(
        "aggregate-oracle",
        |rng| {
            let n = rng.usize(150);
            let rows: Vec<(i64, f64)> = (0..n)
                .map(|_| (rng.i64_range(0, 12), rng.normal() * 5.0))
                .collect();
            rows
        },
        |rows| {
            let keys: Vec<i64> = rows.iter().map(|r| r.0).collect();
            let vals: Vec<f64> = rows.iter().map(|r| r.1).collect();
            // serial oracle
            let mut oracle: std::collections::BTreeMap<i64, (f64, i64, f64)> = Default::default();
            for (k, v) in rows {
                let e = oracle.entry(*k).or_insert((0.0, 0, f64::NEG_INFINITY));
                e.0 += v;
                e.1 += 1;
                e.2 = e.2.max(*v);
            }
            let specs = vec![
                AggSpec { func: AggFn::Sum, input_dtype: DType::F64 },
                AggSpec { func: AggFn::Count, input_dtype: DType::F64 },
                AggSpec { func: AggFn::Max, input_dtype: DType::F64 },
            ];
            for strategy in [AggStrategy::RawShuffle, AggStrategy::PreAggregate] {
                let p = 1 + keys.len() % 4;
                let out = run_spmd(p, |c| {
                    let (s, l) = block_range(keys.len(), c.nranks(), c.rank());
                    let vcol = Column::F64(vals[s..s + l].to_vec());
                    ops::distributed_aggregate(
                        &c,
                        &keys[s..s + l],
                        &[vcol.clone(), vcol.clone(), vcol],
                        &specs,
                        strategy,
                    )
                    .unwrap()
                });
                let mut got: Vec<(i64, f64, i64, f64)> = Vec::new();
                for (ks, cols) in &out {
                    for (i, k) in ks.iter().enumerate() {
                        got.push((
                            *k,
                            cols[0].as_f64()[i],
                            cols[1].as_i64()[i],
                            cols[2].as_f64()[i],
                        ));
                    }
                }
                got.sort_by_key(|r| r.0);
                if got.len() != oracle.len() {
                    return Err(format!("{strategy:?}: group count"));
                }
                for ((k, s, n, m), (ok, (os, on, om))) in got.iter().zip(oracle.iter()) {
                    if k != ok || n != on {
                        return Err(format!("{strategy:?}: key/count"));
                    }
                    if (s - os).abs() > 1e-6 || (m - om).abs() > 1e-9 {
                        return Err(format!("{strategy:?}: sum/max"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_cumsum_matches_serial() {
    forall(
        "cumsum-oracle",
        |rng| gen::vec_f64(rng, 300),
        |xs| {
            let p = 1 + xs.len() % 5;
            let got: Vec<f64> = run_spmd(p, |c| {
                let (s, l) = block_range(xs.len(), c.nranks(), c.rank());
                ops::cumsum_f64(&c, &xs[s..s + l])
            })
            .into_iter()
            .flatten()
            .collect();
            let mut acc = 0.0;
            for (i, x) in xs.iter().enumerate() {
                acc += x;
                if (got[i] - acc).abs() > 1e-6 {
                    return Err(format!("at {i}: {} vs {acc}", got[i]));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_stencil_matches_serial() {
    forall(
        "stencil-oracle",
        |rng| {
            let xs = gen::vec_f64(rng, 200);
            let w = match rng.usize(3) {
                0 => vec![1.0],
                1 => vec![0.25, 0.5, 0.25],
                _ => ops::stencil::sma_weights(5),
            };
            (xs, w)
        },
        |(xs, w)| {
            let want = ops::stencil_serial(xs, w);
            let p = 1 + xs.len() % 4;
            let got: Vec<f64> = run_spmd(p, |c| {
                let (s, l) = block_range(xs.len(), c.nranks(), c.rank());
                ops::stencil_1d(&c, &xs[s..s + l], w)
            })
            .into_iter()
            .flatten()
            .collect();
            if got.len() != want.len() {
                return Err("length".into());
            }
            for (i, (g, e)) in got.iter().zip(&want).enumerate() {
                if (g - e).abs() > 1e-6 {
                    return Err(format!("at {i}: {g} vs {e}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_rebalance_blocks_and_order() {
    forall(
        "rebalance-invariants",
        |rng| {
            // random per-rank chunk lengths
            let p = 1 + rng.usize(4);
            let lens: Vec<usize> = (0..p).map(|_| rng.usize(40)).collect();
            lens
        },
        |lens| {
            let p = lens.len();
            let total: usize = lens.iter().sum();
            let out = run_spmd(p, |c| {
                let my_start: usize = lens[..c.rank()].iter().sum();
                let vals: Vec<i64> =
                    (0..lens[c.rank()]).map(|i| (my_start + i) as i64).collect();
                let cols = vec![Column::I64(vals)];
                let out = ops::rebalance_block(&c, &cols).unwrap();
                out[0].as_i64().to_vec()
            });
            // chunk sizes must match block_range and order must be global
            let mut all = Vec::new();
            for (r, chunk) in out.iter().enumerate() {
                let (_, l) = block_range(total, p, r);
                if chunk.len() != l {
                    return Err(format!("rank {r}: {} != {l}", chunk.len()));
                }
                all.extend_from_slice(chunk);
            }
            let want: Vec<i64> = (0..total as i64).collect();
            (all == want).then_some(()).ok_or("order broken".into())
        },
    );
}

#[test]
fn prop_sort_is_sorted_permutation() {
    forall(
        "sample-sort",
        |rng| gen::vec_i64(rng, 250, -1000, 1000),
        |keys| {
            let p = 1 + keys.len() % 4;
            let got: Vec<i64> = run_spmd(p, |c| {
                let (s, l) = block_range(keys.len(), c.nranks(), c.rank());
                let (k, _) =
                    ops::distributed_sort_by_key(&c, &keys[s..s + l], &[]).unwrap();
                k
            })
            .into_iter()
            .flatten()
            .collect();
            let mut want = keys.clone();
            want.sort_unstable();
            (got == want).then_some(()).ok_or("not sorted".into())
        },
    );
}

#[test]
fn prop_optimizer_preserves_semantics() {
    // random filter+withcolumn+aggregate pipelines over random tables:
    // optimized and unoptimized execution must agree
    forall(
        "optimizer-semantics",
        |rng| {
            let n = 20 + rng.usize(100);
            let keys: Vec<i64> = (0..n).map(|_| rng.i64_range(0, 8)).collect();
            let xs: Vec<f64> = (0..n).map(|_| rng.normal() * 2.0).collect();
            let threshold = rng.normal();
            let use_join = rng.bool(0.5);
            (keys, xs, threshold, use_join)
        },
        |(keys, xs, threshold, use_join)| {
            let hf = HiFrames::with_workers(3);
            let t = Table::from_pairs(vec![
                ("id", Column::I64(keys.clone())),
                ("x", Column::F64(xs.clone())),
            ])
            .unwrap();
            let base = hf.table("t", t);
            let dim = hf.table(
                "dim",
                Table::from_pairs(vec![
                    ("did", Column::I64((0..8).collect())),
                    ("w", Column::F64((0..8).map(|i| i as f64).collect())),
                ])
                .unwrap(),
            );
            let q = if *use_join {
                base.join(&dim, "id", "did")
                    .with_column("xw", col("x").mul(col("w")))
                    .filter(col("x").gt(lit(*threshold)))
                    .aggregate(
                        "id",
                        vec![
                            AggExpr::new("n", AggFn::Count, col("xw")),
                            AggExpr::new("s", AggFn::Sum, col("xw")),
                        ],
                    )
                    .sort_by("id")
            } else {
                base.filter(col("x").gt(lit(*threshold)))
                    .aggregate(
                        "id",
                        vec![
                            AggExpr::new("n", AggFn::Count, col("x")),
                            AggExpr::new("s", AggFn::Sum, col("x")),
                        ],
                    )
                    .sort_by("id")
            };
            let plan = q.plan().clone();
            let on = ExecOptions {
                workers: 3,
                passes: PassOptions::default(),
                agg_strategy: hiframes::ops::aggregate::AggStrategy::PreAggregate,
                mem_budget: None,
                profile: false,
            };
            let off = ExecOptions {
                workers: 2,
                passes: PassOptions::none(),
                agg_strategy: hiframes::ops::aggregate::AggStrategy::RawShuffle,
                mem_budget: None,
                profile: false,
            };
            let a = collect_optimized(&optimize(plan.clone(), &on.passes).unwrap(), &on)
                .map_err(|e| e.to_string())?;
            let b = collect_optimized(&optimize(plan, &off.passes).unwrap(), &off)
                .map_err(|e| e.to_string())?;
            if a.num_rows() != b.num_rows() {
                return Err(format!("rows {} vs {}", a.num_rows(), b.num_rows()));
            }
            if a.column("id").unwrap() != b.column("id").unwrap()
                || a.column("n").unwrap() != b.column("n").unwrap()
            {
                return Err("keys/counts differ".into());
            }
            for (x, y) in a
                .column("s")
                .unwrap()
                .as_f64()
                .iter()
                .zip(b.column("s").unwrap().as_f64())
            {
                if (x - y).abs() > 1e-6 {
                    return Err(format!("sum {x} vs {y}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_agg_state_merge_commutative_associative() {
    forall(
        "agg-merge-laws",
        |rng| {
            let funcs = [AggFn::Sum, AggFn::Mean, AggFn::Min, AggFn::Max, AggFn::Var];
            let f = *rng.choose(&funcs);
            let xs = gen::vec_f64(rng, 60);
            (f, xs)
        },
        |(f, xs)| {
            let mk = |slice: &[f64]| {
                let mut s = AggState::new(*f, DType::F64);
                for x in slice {
                    s.update(&Value::F64(*x));
                }
                s
            };
            if xs.len() < 3 {
                return Ok(());
            }
            let third = xs.len() / 3;
            let (a, b, c) = (
                mk(&xs[..third]),
                mk(&xs[third..2 * third]),
                mk(&xs[2 * third..]),
            );
            // (a+b)+c == a+(b+c) and a+b == b+a, by finished value
            let mut ab_c = a.clone();
            ab_c.merge(&b);
            ab_c.merge(&c);
            let mut bc = b.clone();
            bc.merge(&c);
            let mut a_bc = a.clone();
            a_bc.merge(&bc);
            let mut ba = b.clone();
            ba.merge(&a);
            let mut ab = a.clone();
            ab.merge(&b);
            let close = |u: &AggState, v: &AggState| {
                let (x, y) = (
                    u.finish().as_f64().unwrap_or(f64::NAN),
                    v.finish().as_f64().unwrap_or(f64::NAN),
                );
                (x.is_nan() && y.is_nan()) || (x - y).abs() < 1e-6 * (1.0 + x.abs())
            };
            if !close(&ab_c, &a_bc) {
                return Err(format!("{f:?} not associative"));
            }
            if !close(&ab, &ba) {
                return Err(format!("{f:?} not commutative"));
            }
            Ok(())
        },
    );
}

/// One random key column with adversarial values: i64 extremes, empty
/// strings and strings with embedded NUL bytes (`dtype`: 0 = I64, 1 = Bool,
/// 2 = Str).
fn gen_key_col(rng: &mut Rng, dtype: u8, n: usize) -> Column {
    match dtype {
        0 => {
            let pool = [i64::MIN, i64::MAX, i64::MIN + 1, -1, 0, 1];
            Column::I64(
                (0..n)
                    .map(|_| {
                        if rng.bool(0.3) {
                            *rng.choose(&pool)
                        } else {
                            rng.i64_range(-4, 4)
                        }
                    })
                    .collect(),
            )
        }
        1 => Column::Bool((0..n).map(|_| rng.bool(0.5)).collect()),
        _ => {
            let pool = ["", "a", "b", "ab", "aa", "\0", "a\0", "a\0b"];
            Column::Str((0..n).map(|_| rng.choose(&pool).to_string()).collect())
        }
    }
}

#[test]
fn prop_packed_keys_match_keyval_path() {
    forall(
        "packed-keys-agree",
        |rng| {
            let n = rng.usize(50);
            let ncols = 1 + rng.usize(3);
            let dtypes: Vec<u8> = (0..ncols).map(|_| rng.usize(3) as u8).collect();
            let cols: Vec<Column> = dtypes.iter().map(|&d| gen_key_col(rng, d, n)).collect();
            cols
        },
        |cols| {
            let refs: Vec<&Column> = cols.iter().collect();
            let packed = PackedKeys::pack(&refs).map_err(|e| e.to_string())?;
            let rows = key_rows(&refs).map_err(|e| e.to_string())?;
            if packed.len() != rows.len() {
                return Err(format!("len {} vs {}", packed.len(), rows.len()));
            }
            for i in 0..rows.len() {
                for j in 0..rows.len() {
                    let eq = packed.eq_rows(i, &packed, j);
                    if eq != (rows[i] == rows[j]) {
                        return Err(format!("equality mismatch at ({i},{j})"));
                    }
                    if packed.cmp_rows(i, &packed, j) != cmp_key_rows(&rows[i], &rows[j], &[]) {
                        return Err(format!("sort-order mismatch at ({i},{j})"));
                    }
                    // hash routing must be a function of the tuple value
                    if eq && packed.owner(i, 5) != packed.owner(j, 5) {
                        return Err(format!("routing mismatch at ({i},{j})"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_packed_keys_cross_instance_agreement() {
    // the two sides of a join pack independently; equality, order and
    // owner-routing must still agree across the instances
    forall(
        "packed-keys-cross",
        |rng| {
            let ncols = 1 + rng.usize(2);
            let dtypes: Vec<u8> = (0..ncols).map(|_| rng.usize(3) as u8).collect();
            let nl = rng.usize(30);
            let nr = rng.usize(30);
            let lcols: Vec<Column> =
                dtypes.iter().map(|&d| gen_key_col(rng, d, nl)).collect();
            let rcols: Vec<Column> =
                dtypes.iter().map(|&d| gen_key_col(rng, d, nr)).collect();
            (lcols, rcols)
        },
        |(lcols, rcols)| {
            let lrefs: Vec<&Column> = lcols.iter().collect();
            let rrefs: Vec<&Column> = rcols.iter().collect();
            let lp = PackedKeys::pack(&lrefs).map_err(|e| e.to_string())?;
            let rp = PackedKeys::pack(&rrefs).map_err(|e| e.to_string())?;
            let lrows = key_rows(&lrefs).map_err(|e| e.to_string())?;
            let rrows = key_rows(&rrefs).map_err(|e| e.to_string())?;
            for i in 0..lrows.len() {
                for j in 0..rrows.len() {
                    let eq = lp.eq_rows(i, &rp, j);
                    if eq != (lrows[i] == rrows[j]) {
                        return Err(format!("cross equality mismatch at ({i},{j})"));
                    }
                    if eq && lp.owner(i, 7) != rp.owner(j, 7) {
                        return Err(format!("cross routing mismatch at ({i},{j})"));
                    }
                    if lp.cmp_rows(i, &rp, j) != cmp_key_rows(&lrows[i], &rrows[j], &[]) {
                        return Err(format!("cross order mismatch at ({i},{j})"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_sort_keys_match_cmp_key_rows() {
    forall(
        "sort-keys-agree",
        |rng| {
            let n = rng.usize(40);
            let ncols = 1 + rng.usize(3);
            let cols: Vec<Column> = (0..ncols)
                .map(|_| {
                    let d = rng.usize(2) as u8; // I64 | Bool — the packed sort layouts
                    gen_key_col(rng, d, n)
                })
                .collect();
            let orders: Vec<SortOrder> = (0..ncols)
                .map(|_| {
                    if rng.bool(0.5) {
                        SortOrder::Desc
                    } else {
                        SortOrder::Asc
                    }
                })
                .collect();
            (cols, orders)
        },
        |(cols, orders)| {
            let refs: Vec<&Column> = cols.iter().collect();
            let sk = SortKeys::pack(&refs, orders)
                .map_err(|e| e.to_string())?
                .ok_or("Int64/Bool keys must take the packed sort path")?;
            let rows = key_rows(&refs).map_err(|e| e.to_string())?;
            for i in 0..rows.len() {
                for j in 0..rows.len() {
                    if sk.row(i).cmp(sk.row(j)) != cmp_key_rows(&rows[i], &rows[j], orders) {
                        return Err(format!("direction-aware order mismatch at ({i},{j})"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_codec_roundtrip_random_columns() {
    forall(
        "codec-roundtrip",
        |rng| {
            let n = rng.usize(100);
            match rng.usize(4) {
                0 => Column::I64((0..n).map(|_| rng.i64_range(i64::MIN / 2, i64::MAX / 2)).collect()),
                1 => Column::F64((0..n).map(|_| rng.normal() * 1e6).collect()),
                2 => Column::Bool((0..n).map(|_| rng.bool(0.5)).collect()),
                _ => Column::Str(
                    (0..n)
                        .map(|_| "x".repeat(rng.usize(20)))
                        .collect(),
                ),
            }
        },
        |col| {
            let mut buf = Vec::new();
            hiframes::column::encode_column(col, &mut buf);
            if buf.len() != hiframes::column::encoded_size(col) {
                return Err("size prediction wrong".into());
            }
            let mut pos = 0;
            let back =
                hiframes::column::decode_column(&buf, &mut pos).map_err(|e| e.to_string())?;
            (back == *col && pos == buf.len())
                .then_some(())
                .ok_or("roundtrip mismatch".into())
        },
    );
}
