//! Incremental micro-batch execution (DESIGN.md §4.9): after any number of
//! ticks, a standing query's `tick()` output must be **byte-identical** —
//! values and validity masks — to a cold batch `collect()` over the union
//! of all pushed batches. The suite sweeps tick sizes (1 row, a prime, the
//! whole input at once) × worker counts × nullable keys across every
//! stateful operator (group-by with all aggregate functions, inner/left
//! hash join, partitioned window) plus the delta-append row-wise path, the
//! multi-operator standing-query shape, and the tracked full-recompute
//! fallback.

use hiframes::datagen::Rng;
use hiframes::exec::ExecOptions;
use hiframes::frame::DataFrame;
use hiframes::ops::aggregate::AggStrategy;
use hiframes::passes::PassOptions;
use hiframes::prelude::*;
use hiframes::types::JoinType;

/// The session forces tick-replicable knobs (raw-shuffle aggregation, no
/// skew joins, no spilling); the cold-collect oracle context must match so
/// "cold batch collect" means the same physical plan.
fn opts(workers: usize) -> ExecOptions {
    ExecOptions {
        workers,
        agg_strategy: AggStrategy::RawShuffle,
        mem_budget: None,
        profile: false,
        passes: PassOptions {
            skew_join: false,
            ..Default::default()
        },
    }
}

fn ctx(workers: usize) -> HiFrames {
    HiFrames::new(opts(workers))
}

fn assert_identical(a: &Table, b: &Table, what: &str) {
    assert_eq!(a.schema().names(), b.schema().names(), "{what}: schema");
    assert_eq!(a.num_rows(), b.num_rows(), "{what}: row count");
    for i in 0..a.num_cols() {
        assert_eq!(a.column_at(i), b.column_at(i), "{what}: column {i}");
        assert_eq!(a.mask_at(i), b.mask_at(i), "{what}: mask {i}");
    }
}

/// `n` event rows: key `k` in [0, 6) (nullable when asked, ~1/5 null),
/// `v` i64 in [-50, 50), `x` exact-binary f64.
fn events_master(n: usize, nullable_key: bool, seed: u64) -> Table {
    let mut rng = Rng::new(seed);
    let k: Vec<i64> = (0..n).map(|_| rng.i64_range(0, 6)).collect();
    let v: Vec<i64> = (0..n).map(|_| rng.i64_range(-50, 50)).collect();
    let x: Vec<f64> = (0..n)
        .map(|_| rng.i64_range(0, 1000) as f64 / 8.0)
        .collect();
    let valid: Vec<bool> = (0..n).map(|_| rng.i64_range(0, 5) != 0).collect();
    let mut t = Table::from_pairs(vec![
        ("k", Column::I64(k)),
        ("v", Column::I64(v)),
        ("x", Column::F64(x)),
    ])
    .unwrap();
    if nullable_key {
        t = t
            .with_null_mask("k", ValidityMask::from_bools(&valid))
            .unwrap();
    }
    t
}

/// Drive `master` through a fresh session of `pipeline` in `tick_rows`
/// chunks, asserting byte-identity against the session's own batch oracle
/// at checkpoints and against an external cold collect at the end.
fn check_ticked(
    workers: usize,
    tick_rows: usize,
    master: &Table,
    pipeline: &dyn Fn(DataFrame) -> DataFrame,
    expect_incremental: bool,
) {
    let hf = ctx(workers);
    let seed = Table::empty(master.schema().clone());
    let df = pipeline(hf.table("events", seed));
    let mut s = hf.session(&df).unwrap();
    assert_eq!(
        !s.is_fallback(),
        expect_incremental,
        "w={workers} t={tick_rows}: unexpected mode\n{}",
        s.explain_incremental()
    );
    let mut start = 0;
    let mut ticks = 0usize;
    while start < master.num_rows() {
        let len = tick_rows.min(master.num_rows() - start);
        s.push("events", master.slice(start, len)).unwrap();
        start += len;
        ticks += 1;
        let out = s.tick().unwrap();
        if ticks % 5 == 0 || start == master.num_rows() {
            let oracle = s.collect_batch().unwrap();
            assert_identical(
                &out,
                &oracle,
                &format!("w={workers} tick_rows={tick_rows} after {start} rows"),
            );
        }
    }
    // an empty tick must leave the output unchanged
    let stable = s.tick().unwrap();
    let cold = pipeline(hf.table("events", master.clone()))
        .collect()
        .unwrap();
    assert_identical(
        &stable,
        &cold,
        &format!("w={workers} tick_rows={tick_rows} final vs cold collect"),
    );
}

const TICK_SIZES: [usize; 3] = [1, 7, usize::MAX];

#[test]
fn group_by_all_agg_fns_agree_across_tick_sizes() {
    let pipeline = |df: DataFrame| {
        df.group_by(&["k"])
            .agg("s", AggFn::Sum, col("v"))
            .agg("n", AggFn::Count, col("v"))
            .agg("m", AggFn::Mean, col("x"))
            .agg("lo", AggFn::Min, col("v"))
            .agg("hi", AggFn::Max, col("v"))
            .agg("vr", AggFn::Var, col("x"))
            .agg("f", AggFn::First, col("v"))
            .build()
    };
    for workers in [2usize, 3] {
        for nullable in [false, true] {
            let master = events_master(61, nullable, 7 + workers as u64);
            for tick_rows in TICK_SIZES {
                check_ticked(workers, tick_rows, &master, &pipeline, true);
            }
        }
    }
}

#[test]
fn group_by_with_nullable_agg_inputs_agrees() {
    // nulls in the aggregated column exercise the null-skip fold rules
    let mut rng = Rng::new(11);
    let n = 53;
    let valid: Vec<bool> = (0..n).map(|_| rng.i64_range(0, 3) != 0).collect();
    let master = events_master(n, true, 23)
        .with_null_mask("v", ValidityMask::from_bools(&valid))
        .unwrap();
    let pipeline = |df: DataFrame| {
        df.group_by(&["k"])
            .agg("s", AggFn::Sum, col("v"))
            .agg("n", AggFn::Count, col("v"))
            .agg("m", AggFn::Mean, col("v"))
            .build()
    };
    for tick_rows in TICK_SIZES {
        check_ticked(2, tick_rows, &master, &pipeline, true);
    }
}

/// Two-source joins need their own driver: pushes alternate between the
/// probe and build sides so some ticks leave the build side untouched
/// (the append-only probe fast path) and some grow it (full local
/// re-join).
fn check_join(workers: usize, tick_rows: usize, how: JoinType) {
    let hf = ctx(workers);
    let lmaster = events_master(47, true, 31);
    let mut rng = Rng::new(5);
    let m = 19;
    let rk: Vec<i64> = (0..m).map(|_| rng.i64_range(0, 6)).collect();
    let rz: Vec<i64> = (0..m).map(|_| rng.i64_range(0, 100)).collect();
    let rmaster = Table::from_pairs(vec![("rk", Column::I64(rk)), ("z", Column::I64(rz))])
        .unwrap();
    let lseed = Table::empty(lmaster.schema().clone());
    let rseed = Table::empty(rmaster.schema().clone());
    let build = |l: DataFrame, r: &DataFrame| l.join_on(r, &[("k", "rk")], how);
    let left = hf.table("l", lseed);
    let right = hf.table("r", rseed);
    let df = build(left, &right);
    let mut s = hf.session(&df).unwrap();
    assert!(!s.is_fallback(), "{}", s.explain_incremental());
    let (mut ls, mut rs) = (0usize, 0usize);
    let mut ticks = 0usize;
    while ls < lmaster.num_rows() || rs < rmaster.num_rows() {
        // grow the build side only every third tick
        if ticks % 3 == 2 && rs < rmaster.num_rows() {
            let len = tick_rows.min(rmaster.num_rows() - rs);
            s.push("r", rmaster.slice(rs, len)).unwrap();
            rs += len;
        } else if ls < lmaster.num_rows() {
            let len = tick_rows.min(lmaster.num_rows() - ls);
            s.push("l", lmaster.slice(ls, len)).unwrap();
            ls += len;
        } else {
            let len = tick_rows.min(rmaster.num_rows() - rs);
            s.push("r", rmaster.slice(rs, len)).unwrap();
            rs += len;
        }
        ticks += 1;
        let out = s.tick().unwrap();
        if ticks % 4 == 0 || (ls == lmaster.num_rows() && rs == rmaster.num_rows()) {
            let oracle = s.collect_batch().unwrap();
            assert_identical(
                &out,
                &oracle,
                &format!("join {how:?} w={workers} tick_rows={tick_rows} tick {ticks}"),
            );
        }
    }
    let cold = build(
        hf.table("l", lmaster.clone()),
        &hf.table("r", rmaster.clone()),
    )
    .collect()
    .unwrap();
    let last = s.tick().unwrap();
    assert_identical(
        &last,
        &cold,
        &format!("join {how:?} w={workers} tick_rows={tick_rows} vs cold collect"),
    );
}

#[test]
fn inner_join_agrees_across_tick_sizes() {
    for workers in [2usize, 3] {
        for tick_rows in TICK_SIZES {
            check_join(workers, tick_rows, JoinType::Inner);
        }
    }
}

#[test]
fn left_join_agrees_across_tick_sizes() {
    for workers in [2usize, 3] {
        for tick_rows in TICK_SIZES {
            check_join(workers, tick_rows, JoinType::Left);
        }
    }
}

#[test]
fn partitioned_window_agrees_across_tick_sizes() {
    let pipeline = |df: DataFrame| {
        df.window()
            .partition_by(&["k"])
            .order_by(&[("v", SortOrder::Asc), ("x", SortOrder::Desc)])
            .rank("r")
            .agg("cs", WindowFunc::Sum, col("v"))
            .build()
    };
    for workers in [2usize, 3] {
        for nullable in [false, true] {
            let master = events_master(43, nullable, 17 + workers as u64);
            for tick_rows in TICK_SIZES {
                check_ticked(workers, tick_rows, &master, &pipeline, true);
            }
        }
    }
}

#[test]
fn row_wise_delta_append_agrees() {
    // no stateful operator at all: the completion itself is delta-capable,
    // so ticks gather only new output rows and append driver-side
    let pipeline = |df: DataFrame| {
        df.filter(col("v").ge(lit(0i64)))
            .with_column("v2", col("v").add(col("v")))
            .select(&["k", "v2"])
    };
    for workers in [2usize, 3] {
        let master = events_master(37, true, 41);
        for tick_rows in TICK_SIZES {
            check_ticked(workers, tick_rows, &master, &pipeline, true);
        }
    }
}

#[test]
fn standing_query_pipeline_agrees() {
    // the BigBench Q01 shape: multi-column aggregate -> left join against a
    // dimension -> partitioned rank -> top-K filter. The aggregate keeps
    // state; the join and window replay over its (small) output.
    let hf = ctx(3);
    let master = events_master(59, false, 3);
    let dim = Table::from_pairs(vec![
        ("dk", Column::I64(vec![0, 1, 2, 3, 4, 5])),
        ("cat", Column::I64(vec![10, 10, 20, 20, 30, 30])),
    ])
    .unwrap();
    let pipeline = |events: DataFrame, dim: &DataFrame| {
        events
            .group_by(&["k"])
            .agg("n", AggFn::Count, col("v"))
            .agg("rev", AggFn::Sum, col("v"))
            .build()
            .join_on(dim, &[("k", "dk")], JoinType::Left)
            .window()
            .partition_by(&["cat"])
            .order_by(&[("rev", SortOrder::Desc), ("k", SortOrder::Asc)])
            .rank("r")
            .build()
            .filter(col("r").le(lit(2i64)))
    };
    let seed = Table::empty(master.schema().clone());
    let df = pipeline(hf.table("events", seed), &hf.table("dim", dim.clone()));
    let mut s = hf.session(&df).unwrap();
    assert!(!s.is_fallback(), "{}", s.explain_incremental());
    assert!(
        s.explain_incremental().contains("[stateful]"),
        "aggregate must keep state:\n{}",
        s.explain_incremental()
    );
    let mut start = 0;
    while start < master.num_rows() {
        let len = 7.min(master.num_rows() - start);
        s.push("events", master.slice(start, len)).unwrap();
        start += len;
        let out = s.tick().unwrap();
        let oracle = s.collect_batch().unwrap();
        assert_identical(&out, &oracle, &format!("standing query after {start} rows"));
    }
    let cold = pipeline(hf.table("events", master.clone()), &hf.table("dim", dim))
        .collect()
        .unwrap();
    let last = s.tick().unwrap();
    assert_identical(&last, &cold, "standing query vs cold collect");
    let r = s.last_report().unwrap();
    assert!(!r.fallback);
    assert!(
        r.rows_avoided > 0,
        "the aggregate must avoid refolding absorbed rows: {r:?}"
    );
}

#[test]
fn unsupported_plan_falls_back_to_tracked_full_recompute() {
    // a Sort at the root has no incremental handle: the session must agree
    // with the batch oracle anyway, via whole-plan recompute, and say so
    let pipeline = |df: DataFrame| {
        df.group_by(&["k"])
            .agg("s", AggFn::Sum, col("v"))
            .build()
            .sort_by_keys(&[("s", SortOrder::Desc), ("k", SortOrder::Asc)])
    };
    let master = events_master(31, true, 13);
    check_ticked(2, 7, &master, &pipeline, false);

    // and the global counters record the fallbacks
    let before = hiframes::metrics::stream_stats().snapshot();
    let hf = ctx(2);
    let df = pipeline(hf.table("events", Table::empty(master.schema().clone())));
    let mut s = hf.session(&df).unwrap();
    assert!(s.is_fallback());
    s.push("events", master.slice(0, 9)).unwrap();
    s.tick().unwrap();
    let after = hiframes::metrics::stream_stats().snapshot();
    assert!(after.fallbacks > before.fallbacks, "{before:?} -> {after:?}");
}

#[test]
fn later_ticks_avoid_work() {
    // the whole point: per-tick processed rows must track the delta, not
    // the accumulated history
    let hf = ctx(2);
    let master = events_master(60, false, 29);
    let df = hf
        .table("events", Table::empty(master.schema().clone()))
        .group_by(&["k"])
        .agg("s", AggFn::Sum, col("v"))
        .build();
    let mut s = hf.session(&df).unwrap();
    for i in 0..6 {
        s.push("events", master.slice(i * 10, 10)).unwrap();
        s.tick().unwrap();
    }
    let reports = s.reports();
    assert_eq!(reports.len(), 6);
    let last = reports[5];
    assert_eq!(last.rows_processed, 10, "only the delta is folded");
    assert_eq!(last.rows_avoided, 50, "absorbed history is not re-read");
    assert!(reports[0].rows_avoided == 0);
}
