//! Composite-key relational API, end to end: multi-key group-by, join
//! types (Left/Right/Outer/Semi/Anti), and multi-key sort must produce the
//! same relation on the distributed HiFrames engine (≥2 workers) as on the
//! serial baseline engine over the same data.

use hiframes::baseline::serial;
use hiframes::datagen::Rng;
use hiframes::prelude::*;
use hiframes::types::{JoinType, SortOrder};

fn left_table(rng: &mut Rng, n: usize) -> Table {
    Table::from_pairs(vec![
        (
            "k1",
            Column::I64((0..n).map(|_| rng.i64_range(0, 6)).collect()),
        ),
        (
            "k2",
            Column::I64((0..n).map(|_| rng.i64_range(0, 4)).collect()),
        ),
        (
            "x",
            Column::F64((0..n).map(|_| rng.normal() * 2.0).collect()),
        ),
    ])
    .unwrap()
}

/// Compare tables cell-by-cell: values, dtypes, nullability flags and null
/// positions (validity masks) must all agree. Floats compare with a small
/// tolerance (NaN == NaN for genuine float data).
fn assert_tables_equal(a: &Table, b: &Table, label: &str) {
    assert_eq!(a.num_rows(), b.num_rows(), "{label}: row counts");
    assert_eq!(a.schema().names(), b.schema().names(), "{label}: schemas");
    for (name, dt) in a.schema().fields() {
        assert_eq!(
            Some(*dt),
            b.schema().dtype_of(name),
            "{label}: dtype of {name}"
        );
        assert_eq!(
            a.schema().nullable_of(name),
            b.schema().nullable_of(name),
            "{label}: nullability of {name}"
        );
        assert_eq!(a.mask(name), b.mask(name), "{label}: null positions of {name}");
        let (ca, cb) = (a.column(name).unwrap(), b.column(name).unwrap());
        match (ca, cb) {
            (Column::F64(x), Column::F64(y)) => {
                for (i, (u, v)) in x.iter().zip(y).enumerate() {
                    let same = (u.is_nan() && v.is_nan())
                        || (u - v).abs() <= 1e-9 * (1.0 + u.abs());
                    assert!(same, "{label}: {name}[{i}] {u} vs {v}");
                }
            }
            _ => assert_eq!(ca, cb, "{label}: column {name}"),
        }
    }
}

#[test]
fn multi_key_aggregate_matches_serial_across_workers() {
    let mut rng = Rng::new(401);
    let t = left_table(&mut rng, 300);
    let aggs = vec![
        AggExpr::new("n", AggFn::Count, col("x")),
        AggExpr::new("s", AggFn::Sum, col("x")),
        AggExpr::new("hi", AggFn::Max, col("x")),
    ];
    let canon = [("k1", SortOrder::Asc), ("k2", SortOrder::Asc)];
    for workers in [2usize, 3, 5] {
        let hf = HiFrames::with_workers(workers);
        let ours = hf
            .table("t", t.clone())
            .aggregate_by(&["k1", "k2"], aggs.clone())
            .sort_by_keys(&canon)
            .collect()
            .unwrap();
        let oracle = serial::aggregate_by(&t, &["k1", "k2"], &aggs)
            .unwrap()
            .sorted_by_keys(&canon)
            .unwrap();
        assert!(ours.num_rows() > 1, "need real groups");
        assert_tables_equal(&ours, &oracle, &format!("agg workers={workers}"));
    }
}

#[test]
fn join_types_match_serial_across_workers() {
    let mut rng = Rng::new(77);
    // unique composite left keys so row orders canonicalize by key alone
    let n = 60usize;
    let l = Table::from_pairs(vec![
        ("a", Column::I64((0..n as i64).collect())),
        ("b", Column::I64((0..n as i64).map(|i| i % 7).collect())),
        (
            "x",
            Column::F64((0..n).map(|_| rng.f64() * 10.0).collect()),
        ),
    ])
    .unwrap();
    // right side covers a subset of (a, b) tuples plus some misses
    let m = 40usize;
    let r = Table::from_pairs(vec![
        ("ra", Column::I64((0..m as i64).map(|i| i * 2).collect())),
        ("rb", Column::I64((0..m as i64).map(|i| (i * 2) % 7).collect())),
        ("w", Column::I64((0..m as i64).map(|i| 1000 + i).collect())),
    ])
    .unwrap();
    let on = [("a", "ra"), ("b", "rb")];
    let canon = [("a", SortOrder::Asc), ("b", SortOrder::Asc)];
    for how in [
        JoinType::Inner,
        JoinType::Left,
        JoinType::Right,
        JoinType::Outer,
        JoinType::Semi,
        JoinType::Anti,
    ] {
        for workers in [2usize, 4] {
            let hf = HiFrames::with_workers(workers);
            let ours = hf
                .table("l", l.clone())
                .join_on(&hf.table("r", r.clone()), &on, how)
                .sort_by_keys(&canon)
                .collect()
                .unwrap();
            let oracle = serial::join_on(&l, &r, &on, how)
                .unwrap()
                .sorted_by_keys(&canon)
                .unwrap();
            assert_tables_equal(&ours, &oracle, &format!("{how:?} workers={workers}"));
        }
    }
}

#[test]
fn left_join_keeps_every_left_row() {
    // the acceptance shape: a LEFT join for a sparse dimension across ≥2
    // workers, verified against the serial engine
    let l = Table::from_pairs(vec![
        ("id", Column::I64((0..50).collect())),
        ("x", Column::F64((0..50).map(|i| i as f64).collect())),
    ])
    .unwrap();
    let r = Table::from_pairs(vec![
        ("rid", Column::I64((0..50).filter(|i| i % 3 == 0).collect())),
        (
            "w",
            Column::I64((0..50).filter(|i| i % 3 == 0).map(|i| i * 10).collect()),
        ),
    ])
    .unwrap();
    let hf = HiFrames::with_workers(3);
    let ours = hf
        .table("l", l.clone())
        .join_on(&hf.table("r", r.clone()), &[("id", "rid")], JoinType::Left)
        .sort_by("id")
        .collect()
        .unwrap();
    assert_eq!(ours.num_rows(), 50);
    let oracle = serial::join_on(&l, &r, &[("id", "rid")], JoinType::Left)
        .unwrap()
        .sorted_by("id")
        .unwrap();
    assert_tables_equal(&ours, &oracle, "left join");
    // dtype preserved: the sparse dimension column stays Int64 and the
    // holes land on non-multiples of 3 in the validity mask
    assert_eq!(ours.schema().dtype_of("w"), Some(DType::I64));
    let w = ours.column("w").unwrap().as_i64();
    let mask = ours.mask("w").unwrap();
    for i in 0..50usize {
        if i % 3 == 0 {
            assert!(mask.get(i), "row {i} should be valid");
            assert_eq!(w[i], (i * 10) as i64);
        } else {
            assert!(!mask.get(i), "row {i} should be a null hole");
            assert_eq!(w[i], 0, "null lanes hold the dtype default");
        }
    }
}

#[test]
fn multi_key_sort_desc_matches_table_sort() {
    let mut rng = Rng::new(5);
    let t = left_table(&mut rng, 200);
    let keys = [("k1", SortOrder::Desc), ("k2", SortOrder::Asc)];
    let hf = HiFrames::with_workers(4);
    let ours = hf
        .table("t", t.clone())
        .sort_by_keys(&keys)
        .collect()
        .unwrap();
    let expect = t.sorted_by_keys(&keys).unwrap();
    // key columns must match exactly; payload multisets per key tuple must
    // match (stability across the shuffle is not guaranteed)
    assert_eq!(ours.column("k1").unwrap(), expect.column("k1").unwrap());
    assert_eq!(ours.column("k2").unwrap(), expect.column("k2").unwrap());
    let mut a = ours.column("x").unwrap().as_f64().to_vec();
    let mut b = expect.column("x").unwrap().as_f64().to_vec();
    a.sort_by(|p, q| p.partial_cmp(q).unwrap());
    b.sort_by(|p, q| p.partial_cmp(q).unwrap());
    assert_eq!(a, b);
}

#[test]
fn optimizer_preserves_typed_join_semantics() {
    // full pass pipeline over a Left join with a post-join filter that
    // mixes a pushable left conjunct and a null-sensitive right conjunct —
    // optimized and unoptimized execution must agree
    use hiframes::exec::{collect_optimized, ExecOptions};
    use hiframes::passes::{optimize, PassOptions};
    let l = Table::from_pairs(vec![
        ("id", Column::I64((0..40).collect())),
        ("x", Column::F64((0..40).map(|i| (i as f64) * 0.5).collect())),
    ])
    .unwrap();
    let r = Table::from_pairs(vec![
        ("rid", Column::I64((0..40).filter(|i| i % 2 == 0).collect())),
        (
            "w",
            Column::F64(
                (0..40)
                    .filter(|i| i % 2 == 0)
                    .map(|i| i as f64)
                    .collect(),
            ),
        ),
    ])
    .unwrap();
    let hf = HiFrames::with_workers(3);
    let q = hf
        .table("l", l)
        .join_on(&hf.table("r", r), &[("id", "rid")], JoinType::Left)
        .filter(col("x").gt(lit(3.0)).and(col("w").gt(lit(10.0))))
        .sort_by("id");
    let plan = q.plan().clone();
    let on = ExecOptions {
        workers: 3,
        passes: PassOptions::default(),
        ..Default::default()
    };
    let off = ExecOptions {
        workers: 2,
        passes: PassOptions::none(),
        ..Default::default()
    };
    let a = collect_optimized(&optimize(plan.clone(), &on.passes).unwrap(), &on).unwrap();
    let b = collect_optimized(&optimize(plan, &off.passes).unwrap(), &off).unwrap();
    assert_tables_equal(&a, &b, "optimized vs unoptimized left join");
    // the filter dropped every unmatched row (a null w compares as NULL,
    // which the filter treats as false)
    assert!(a.num_rows() > 0);
    assert_eq!(a.null_count("w"), 0);
    assert!(a.column("w").unwrap().as_f64().iter().all(|v| *v > 10.0));
}
