//! Table 1 of the paper, as executable assertions: every API row
//! (projection, filter, join, aggregate, concatenation, cumsum, SMA, WMA)
//! behaves like its Julia/SQL counterpart.

use hiframes::prelude::*;

fn hf() -> HiFrames {
    HiFrames::with_workers(3)
}

fn df1(hf: &HiFrames) -> hiframes::frame::DataFrame {
    hf.table(
        "df1",
        Table::from_pairs(vec![
            ("id", Column::I64(vec![1, 2, 3, 1, 2, 3])),
            ("x", Column::F64(vec![0.5, 1.5, 0.7, 2.5, 0.2, 3.5])),
            ("y", Column::F64(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0])),
        ])
        .unwrap(),
    )
}

#[test]
fn row_projection() {
    // v = df[:id]  ≡  select id from t
    let hf = hf();
    let v = df1(&hf).select(&["id"]).collect().unwrap();
    assert_eq!(v.schema().names(), vec!["id"]);
    assert_eq!(v.column("id").unwrap().as_i64(), &[1, 2, 3, 1, 2, 3]);
}

#[test]
fn row_filter() {
    // df2 = df[:id < 100]  ≡  select * from t where id < 100
    let hf = hf();
    let out = df1(&hf).filter(col("id").lt(lit(3i64))).collect().unwrap();
    assert_eq!(out.num_rows(), 4);
    assert!(out.column("id").unwrap().as_i64().iter().all(|&i| i < 3));
}

#[test]
fn row_join() {
    // df3 = join(df1, df2, :id == :cid) — different key names allowed
    let hf = hf();
    let df2 = hf.table(
        "df2",
        Table::from_pairs(vec![
            ("cid", Column::I64(vec![1, 3])),
            ("z", Column::I64(vec![10, 30])),
        ])
        .unwrap(),
    );
    let out = df1(&hf).join(&df2, "id", "cid").sort_by("id").collect().unwrap();
    assert_eq!(out.num_rows(), 4); // two id=1 rows + two id=3 rows
    assert_eq!(out.schema().names(), vec!["id", "x", "y", "z"]);
    assert_eq!(out.column("z").unwrap().as_i64(), &[10, 10, 30, 30]);
}

#[test]
fn row_aggregate() {
    // df2 = aggregate(df1, :id, :xc = sum(:x < 1.0), :ym = mean(:y))
    let hf = hf();
    let out = df1(&hf)
        .aggregate(
            "id",
            vec![
                AggExpr::new("xc", AggFn::Sum, col("x").lt(lit(1.0))),
                AggExpr::new("ym", AggFn::Mean, col("y")),
            ],
        )
        .sort_by("id")
        .collect()
        .unwrap();
    assert_eq!(out.column("id").unwrap().as_i64(), &[1, 2, 3]);
    assert_eq!(out.column("xc").unwrap().as_i64(), &[1, 1, 1]);
    assert_eq!(out.column("ym").unwrap().as_f64(), &[2.5, 3.5, 4.5]);
}

#[test]
fn row_concatenation() {
    // df3 = [df1; df2]  ≡  union all
    let hf = hf();
    let d = df1(&hf);
    let out = d.concat(&d).collect().unwrap();
    assert_eq!(out.num_rows(), 12);
    // schema mismatch must fail at planning time
    let other = hf.table(
        "o",
        Table::from_pairs(vec![("id", Column::I64(vec![1]))]).unwrap(),
    );
    assert!(d.concat(&other).schema().is_err());
}

#[test]
fn row_cumsum() {
    // cumsum(df[:x]) — needs a scan, not map-reduce
    let hf = hf();
    let out = df1(&hf).cumsum("y", "cs").collect().unwrap();
    assert_eq!(
        out.column("cs").unwrap().as_f64(),
        &[1.0, 3.0, 6.0, 10.0, 15.0, 21.0]
    );
}

#[test]
fn row_sma() {
    // A = stencil(x -> (x[-1]+x[0]+x[1])/3.0, df[:x])
    let hf = hf();
    let out = df1(&hf).sma("y", "sma", 3).collect().unwrap();
    let sma = out.column("sma").unwrap().as_f64();
    for i in 1..5 {
        assert!((sma[i] - (i as f64 + 1.0)).abs() < 1e-9); // mean of consecutive ints
    }
    // edges: truncated window, renormalized
    assert!((sma[0] - 1.5).abs() < 1e-9);
    assert!((sma[5] - 5.5).abs() < 1e-9);
}

#[test]
fn row_wma() {
    // A = stencil(x -> (x[-1]+2*x[0]+x[1])/4.0, df[:x])
    let hf = hf();
    let out = df1(&hf).wma("y", "wma").collect().unwrap();
    let wma = out.column("wma").unwrap().as_f64();
    for i in 1..5 {
        // (v-1 + 2v + v+1)/4 = v for consecutive ints
        assert!((wma[i] - (i as f64 + 1.0)).abs() < 1e-9);
    }
}

#[test]
fn general_array_expressions_in_filter() {
    // the paper: "any array expression that results in a boolean array can
    // be used" — including math functions and UDFs
    let hf = hf();
    let out = df1(&hf)
        .filter(
            col("x")
                .math(MathFn::Exp)
                .gt(lit(2.0))
                .and(col("y").le(lit(5.0))),
        )
        .collect()
        .unwrap();
    assert_eq!(out.num_rows(), 3); // exp(x)>2 ⇔ x>ln2: x∈{1.5,0.7,2.5} with y≤5
}
