//! Integration: rust PJRT runtime ⇄ AOT artifacts produced by
//! `python/compile/aot.py`. These tests exercise the full three-layer
//! stack: Pallas kernel (L1) inside the jax model (L2) loaded and executed
//! from rust (L3) — no Python at runtime.
//!
//! Skipped gracefully when `make artifacts` has not run yet.

use hiframes::prelude::*;
use hiframes::runtime::{artifacts_available, Engine};

fn engine_or_skip() -> Option<Engine> {
    if !artifacts_available() {
        eprintln!("SKIP: artifacts/manifest.txt missing — run `make artifacts`");
        return None;
    }
    Some(Engine::load_default().expect("engine load"))
}

#[test]
fn kmeans_step_matches_rust_oracle() {
    let Some(engine) = engine_or_skip() else { return };
    let e = engine.entry("kmeans_step").unwrap();
    let (n, d, k) = (
        e.param("n").unwrap(),
        e.param("d").unwrap(),
        e.param("k").unwrap(),
    );
    // two real rows per cluster + padding
    let mut rng = hiframes::datagen::Rng::new(9);
    let real = 64usize.min(n);
    let mut points = vec![0.0f32; n * d];
    let mut mask = vec![0.0f32; n];
    for i in 0..real {
        mask[i] = 1.0;
        for f in 0..d {
            let blob = if i % 2 == 0 { 0.0 } else { 5.0 };
            points[i * d + f] = (blob + rng.normal() * 0.1) as f32;
        }
    }
    let mut centroids = vec![0.0f32; k * d];
    for f in 0..d {
        centroids[d + f] = 5.0; // centroid 1 at the far blob
    }
    let (sums, counts, inertia) = engine.kmeans_step(&points, &mask, &centroids).unwrap();
    // oracle: rust-side assignment over the same data
    let mut osums = vec![0.0f64; k * d];
    let mut ocounts = vec![0.0f64; k];
    let mut oinertia = 0.0f64;
    for i in 0..n {
        if mask[i] == 0.0 {
            continue;
        }
        let mut best = 0;
        let mut best_d = f64::INFINITY;
        for j in 0..k {
            let mut dist = 0.0;
            for f in 0..d {
                let diff = points[i * d + f] as f64 - centroids[j * d + f] as f64;
                dist += diff * diff;
            }
            if dist < best_d {
                best_d = dist;
                best = j;
            }
        }
        oinertia += best_d;
        ocounts[best] += 1.0;
        for f in 0..d {
            osums[best * d + f] += points[i * d + f] as f64;
        }
    }
    for j in 0..k {
        assert!(
            (counts[j] as f64 - ocounts[j]).abs() < 1e-3,
            "counts[{j}]: {} vs {}",
            counts[j],
            ocounts[j]
        );
        for f in 0..d {
            assert!(
                (sums[j * d + f] as f64 - osums[j * d + f]).abs() < 1e-2,
                "sums[{j},{f}]"
            );
        }
    }
    assert!((inertia as f64 - oinertia).abs() < 1e-2 * (1.0 + oinertia));
}

#[test]
fn wma_artifact_matches_stencil_serial() {
    let Some(engine) = engine_or_skip() else { return };
    let e = engine.entry("wma").unwrap();
    let n = e.param("n").unwrap();
    let mut rng = hiframes::datagen::Rng::new(4);
    let xs: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
    let weights = [0.25f32, 0.5, 0.25];
    let got = engine.wma(&xs, &weights).unwrap();
    let xs64: Vec<f64> = xs.iter().map(|&x| x as f64).collect();
    let want = hiframes::ops::stencil_serial(&xs64, &[0.25, 0.5, 0.25]);
    assert_eq!(got.len(), want.len());
    for (i, (g, w)) in got.iter().zip(&want).enumerate() {
        assert!(
            (*g as f64 - w).abs() < 1e-3,
            "wma[{i}]: {g} vs {w}"
        );
    }
}

#[test]
fn logreg_step_gradient_direction() {
    let Some(engine) = engine_or_skip() else { return };
    let e = engine.entry("logreg_step").unwrap();
    let (n, d) = (e.param("n").unwrap(), e.param("d").unwrap());
    let mut rng = hiframes::datagen::Rng::new(5);
    let real = 256.min(n);
    let mut xs = vec![0.0f32; n * d];
    let mut ys = vec![0.0f32; n];
    let mut mask = vec![0.0f32; n];
    for i in 0..real {
        mask[i] = 1.0;
        let label = (i % 2) as f32;
        ys[i] = label;
        for f in 0..d {
            xs[i * d + f] = (rng.normal() as f32) + label * 2.0;
        }
    }
    let mut w = vec![0.0f32; d + 1];
    let (_, loss0) = engine.logreg_step(&xs, &ys, &mask, &w).unwrap();
    // a few GD steps must reduce the loss
    for _ in 0..20 {
        let (grad, _) = engine.logreg_step(&xs, &ys, &mask, &w).unwrap();
        for (wi, g) in w.iter_mut().zip(&grad) {
            *wi -= 0.01 * g / real as f32;
        }
    }
    let (_, loss1) = engine.logreg_step(&xs, &ys, &mask, &w).unwrap();
    assert!(
        loss1 < loss0 * 0.9,
        "GD did not reduce loss: {loss0} -> {loss1}"
    );
}

#[test]
fn standardize_artifact() {
    let Some(engine) = engine_or_skip() else { return };
    let e = engine.entry("standardize").unwrap();
    let n = e.param("n").unwrap();
    let mut rng = hiframes::datagen::Rng::new(6);
    let xs: Vec<f32> = (0..n).map(|_| (rng.normal() * 3.0 + 7.0) as f32).collect();
    let got = engine.standardize(&xs).unwrap();
    // mean ≈ 0 after centering
    let mean: f64 = got.iter().map(|&x| x as f64).sum::<f64>() / n as f64;
    assert!(mean.abs() < 1e-3, "mean {mean}");
}

#[test]
fn kmeans_pjrt_through_dataframe_api() {
    if !artifacts_available() {
        eprintln!("SKIP: artifacts missing");
        return;
    }
    // the full pipeline: frame -> matrix assembly -> kmeans(use_pjrt=true).
    // d must match the artifact (Q26 dims: 6 features, k=8)
    let engine = Engine::load_default().unwrap();
    let e = engine.entry("kmeans_step").unwrap();
    let (d, k) = (e.param("d").unwrap(), e.param("k").unwrap());
    drop(engine);

    let n = 128usize;
    let mut rng = hiframes::datagen::Rng::new(8);
    let mut cols: Vec<(String, Column)> = Vec::new();
    for f in 0..d {
        let vals: Vec<f64> = (0..n)
            .map(|i| (i % k) as f64 * 10.0 + rng.normal() * 0.1 + f as f64)
            .collect();
        cols.push((format!("c{f}"), Column::F64(vals)));
    }
    let pairs: Vec<(&str, Column)> = cols
        .iter()
        .map(|(n, c)| (n.as_str(), c.clone()))
        .collect();
    let t = Table::from_pairs(pairs).unwrap();
    let names: Vec<String> = (0..d).map(|f| format!("c{f}")).collect();
    let refs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();

    let hf = HiFrames::with_workers(2);
    let out = hf
        .table("pts", t)
        .matrix_assembly(&refs)
        .kmeans(k, 15, true)
        .collect()
        .unwrap();
    assert_eq!(out.num_rows(), k);
    // centroids must land near the k levels 0,10,…,10(k-1) (+feature offset)
    let f0 = out.column("f0").unwrap().as_f64();
    let mut levels: Vec<f64> = f0.to_vec();
    levels.sort_by(|a, b| a.partial_cmp(b).unwrap());
    for (j, v) in levels.iter().enumerate() {
        assert!(
            (v - (j as f64) * 10.0).abs() < 2.0,
            "centroid {j}: {v}"
        );
    }
}
