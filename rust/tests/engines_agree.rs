//! Three-way engine agreement: for randomized workloads, the HiFrames SPMD
//! executor, the sparklike map-reduce engine and the serial pandas-like
//! engine must produce identical relations. This is the strongest
//! correctness signal in the repo: the engines share no operator code.

use hiframes::baseline::{serial, sparklike::SparkLike};
use hiframes::column::{set_dict_encoding, DictEncoding};
use hiframes::datagen::Rng;
use hiframes::exec::{collect, collect_serial, ExecOptions};
use hiframes::ir::{source_mem, Plan, WindowAgg};
use hiframes::metrics::spill_stats;
use hiframes::prelude::*;
use hiframes::prop::forall_cases;
use hiframes::types::JoinStrategy;

fn random_table(rng: &mut Rng, n: usize, key_range: i64) -> Table {
    Table::from_pairs(vec![
        (
            "id",
            Column::I64((0..n).map(|_| rng.i64_range(0, key_range)).collect()),
        ),
        (
            "x",
            Column::F64((0..n).map(|_| rng.normal() * 3.0).collect()),
        ),
        (
            "y",
            Column::F64((0..n).map(|_| rng.f64() * 100.0).collect()),
        ),
    ])
    .unwrap()
}

fn tables_equal_approx(a: &Table, b: &Table, label: &str) -> Result<(), String> {
    if a.num_rows() != b.num_rows() {
        return Err(format!("{label}: rows {} vs {}", a.num_rows(), b.num_rows()));
    }
    if a.schema().names() != b.schema().names() {
        return Err(format!("{label}: schemas differ"));
    }
    for (name, _) in a.schema().fields() {
        let (ca, cb) = (a.column(name).unwrap(), b.column(name).unwrap());
        match (ca, cb) {
            (Column::F64(x), Column::F64(y)) => {
                for (i, (u, v)) in x.iter().zip(y).enumerate() {
                    if (u - v).abs() > 1e-6 * (1.0 + u.abs()) {
                        return Err(format!("{label}: {name}[{i}] {u} vs {v}"));
                    }
                }
            }
            _ => {
                if ca != cb {
                    return Err(format!("{label}: column {name} differs"));
                }
            }
        }
    }
    Ok(())
}

#[test]
fn filter_three_way() {
    forall_cases(
        "filter-3way",
        16,
        |rng| {
            let n = 50 + rng.usize(300);
            (random_table(rng, n, 40), rng.normal())
        },
        |(t, threshold)| {
            let pred = col("x").lt(lit(*threshold)).or(col("id").eq_(lit(7i64)));
            let hf = HiFrames::with_workers(3);
            let ours = hf
                .table("t", t.clone())
                .filter(pred.clone())
                .collect()
                .map_err(|e| e.to_string())?;
            let srl = serial::filter(t, &pred).map_err(|e| e.to_string())?;
            let eng = SparkLike::new(2, 3);
            let spk = eng
                .collect(&eng.filter(&eng.parallelize(t), &pred).map_err(|e| e.to_string())?)
                .map_err(|e| e.to_string())?;
            tables_equal_approx(&ours, &srl, "hiframes vs serial")?;
            tables_equal_approx(&srl, &spk, "serial vs sparklike")
        },
    );
}

#[test]
fn join_three_way() {
    forall_cases(
        "join-3way",
        12,
        |rng| {
            let nl = 30 + rng.usize(150);
            let nr = 10 + rng.usize(80);
            let l = random_table(rng, nl, 25);
            let mut r = random_table(rng, nr, 25);
            // rename right side to avoid collisions
            r = Table::from_pairs(vec![
                ("rid", r.column("id").unwrap().clone()),
                ("w", r.column("x").unwrap().clone()),
            ])
            .unwrap();
            (l, r)
        },
        |(l, r)| {
            let hf = HiFrames::with_workers(3);
            let ours = hf
                .table("l", l.clone())
                .join(&hf.table("r", r.clone()), "id", "rid")
                .sort_by("id")
                .collect()
                .map_err(|e| e.to_string())?;
            let srl = serial::join(l, r, "id", "rid")
                .map_err(|e| e.to_string())?
                .sorted_by("id")
                .map_err(|e| e.to_string())?;
            let eng = SparkLike::new(2, 4);
            let spk = eng
                .collect(
                    &eng.join(&eng.parallelize(l), &eng.parallelize(r), "id", "rid")
                        .map_err(|e| e.to_string())?,
                )
                .map_err(|e| e.to_string())?
                .sorted_by("id")
                .map_err(|e| e.to_string())?;
            // join output ordering within equal keys differs per engine;
            // compare sorted multisets per key via counts + sums
            for t in [&ours, &srl, &spk] {
                if t.num_rows() != ours.num_rows() {
                    return Err("row counts differ".into());
                }
            }
            let summarize = |t: &Table| {
                let keys = t.column("id").unwrap().as_i64();
                let xs = t.column("x").unwrap().as_f64();
                let ws = t.column("w").unwrap().as_f64();
                let mut m: std::collections::BTreeMap<i64, (usize, f64, f64)> = Default::default();
                for i in 0..keys.len() {
                    let e = m.entry(keys[i]).or_insert((0, 0.0, 0.0));
                    e.0 += 1;
                    e.1 += xs[i];
                    e.2 += ws[i];
                }
                m
            };
            let (a, b, c) = (summarize(&ours), summarize(&srl), summarize(&spk));
            if a.len() != b.len() || b.len() != c.len() {
                return Err("key sets differ".into());
            }
            for ((ka, va), (kb, vb)) in a.iter().zip(b.iter()) {
                if ka != kb || va.0 != vb.0 {
                    return Err("counts differ".into());
                }
                if (va.1 - vb.1).abs() > 1e-6 || (va.2 - vb.2).abs() > 1e-6 {
                    return Err("sums differ".into());
                }
            }
            for ((ka, va), (kc, vc)) in a.iter().zip(c.iter()) {
                if ka != kc || va.0 != vc.0 {
                    return Err("spark counts differ".into());
                }
                if (va.1 - vc.1).abs() > 1e-6 {
                    return Err("spark sums differ".into());
                }
            }
            Ok(())
        },
    );
}

#[test]
fn aggregate_three_way() {
    forall_cases(
        "aggregate-3way",
        12,
        |rng| { let n = 50 + rng.usize(250); random_table(rng, n, 15) },
        |t| {
            let aggs = vec![
                AggExpr::new("n", AggFn::Count, col("x")),
                AggExpr::new("s", AggFn::Sum, col("x")),
                AggExpr::new("m", AggFn::Mean, col("y")),
                AggExpr::new("hi", AggFn::Max, col("y")),
                AggExpr::new("lo", AggFn::Min, col("x")),
                AggExpr::new("v", AggFn::Var, col("x")),
            ];
            let hf = HiFrames::with_workers(4);
            let ours = hf
                .table("t", t.clone())
                .aggregate("id", aggs.clone())
                .sort_by("id")
                .collect()
                .map_err(|e| e.to_string())?;
            let srl = serial::aggregate(t, "id", &aggs)
                .map_err(|e| e.to_string())?
                .sorted_by("id")
                .map_err(|e| e.to_string())?;
            let eng = SparkLike::new(2, 3);
            let spk = eng
                .collect(
                    &eng.aggregate(&eng.parallelize(t), "id", &aggs)
                        .map_err(|e| e.to_string())?,
                )
                .map_err(|e| e.to_string())?
                .sorted_by("id")
                .map_err(|e| e.to_string())?;
            tables_equal_approx(&ours, &srl, "hiframes vs serial")?;
            tables_equal_approx(&srl, &spk, "serial vs sparklike")
        },
    );
}

#[test]
fn analytics_three_way() {
    forall_cases(
        "analytics-3way",
        10,
        |rng| { let n = 20 + rng.usize(200); random_table(rng, n, 10) },
        |t| {
            let hf = HiFrames::with_workers(3);
            // cumsum
            let ours = hf
                .table("t", t.clone())
                .cumsum("x", "cs")
                .collect()
                .map_err(|e| e.to_string())?;
            let srl = serial::cumsum(t, "x", "cs").map_err(|e| e.to_string())?;
            tables_equal_approx(&ours, &srl, "cumsum")?;
            // sma
            let ours = hf
                .table("t", t.clone())
                .sma("x", "s", 3)
                .collect()
                .map_err(|e| e.to_string())?;
            let srl = serial::sma(t, "x", "s", 3).map_err(|e| e.to_string())?;
            tables_equal_approx(&ours, &srl, "sma")?;
            // wma vs sparklike single-executor window
            let weights = hiframes::ops::stencil::wma_weights_124();
            let ours = hf
                .table("t", t.clone())
                .wma("x", "w")
                .collect()
                .map_err(|e| e.to_string())?;
            let eng = SparkLike::new(2, 3);
            let spk_rdd = eng
                .window_one_executor(
                    &eng.parallelize(t),
                    "x",
                    "w",
                    hiframes::baseline::sparklike::WindowKind::Stencil(weights),
                )
                .map_err(|e| e.to_string())?;
            let spk = eng.collect(&spk_rdd).map_err(|e| e.to_string())?;
            tables_equal_approx(&ours, &spk, "wma vs sparklike")
        },
    );
}

#[test]
fn udf_results_identical_across_engines() {
    // Fig. 9/10's semantic premise: UDF and built-in versions compute the
    // same thing everywhere
    forall_cases(
        "udf-equivalence",
        8,
        |rng| { let n = 100 + rng.usize(100); random_table(rng, n, 20) },
        |t| {
            let udf = Udf::new("affine", |a| a[0] * 2.0 + 1.0);
            let udf_expr = Expr::Udf(udf, vec![col("x")]).gt(lit(1.0));
            let builtin_expr = col("x").mul(lit(2.0)).add(lit(1.0)).gt(lit(1.0));
            let hf = HiFrames::with_workers(2);
            let a = hf
                .table("t", t.clone())
                .filter(udf_expr.clone())
                .collect()
                .map_err(|e| e.to_string())?;
            let b = hf
                .table("t", t.clone())
                .filter(builtin_expr.clone())
                .collect()
                .map_err(|e| e.to_string())?;
            tables_equal_approx(&a, &b, "hiframes udf vs builtin")?;
            let eng = SparkLike::new(2, 2);
            let c = eng
                .collect(
                    &eng.filter(&eng.parallelize(t), &udf_expr)
                        .map_err(|e| e.to_string())?,
                )
                .map_err(|e| e.to_string())?;
            tables_equal_approx(&a, &c, "hiframes vs sparklike udf")
        },
    );
}

// ---------------------------------------------------------------------------
// String-keyed sweep: dictionary-encoded keys across all three engines.
// Tables are compared with `==` — byte-identical values AND validity masks.
// The dict toggle is safe to flip process-wide: every assertion requires the
// results to be identical under either wire format.
// ---------------------------------------------------------------------------

/// Duplicate-heavy nullable string keys: empty strings, embedded NULs, and
/// random suffixes that keep cardinality realistic.
fn random_str_keys(rng: &mut Rng, n: usize) -> (Vec<String>, Vec<bool>) {
    const POOL: [&str; 7] = ["", "east", "west", "w\0est", "\0", "north", "s"];
    let keys = (0..n)
        .map(|_| {
            let base = *rng.choose(&POOL);
            if rng.bool(0.3) {
                format!("{base}-{}", rng.i64_range(0, 12))
            } else {
                base.to_string()
            }
        })
        .collect();
    let mask = (0..n).map(|_| rng.bool(0.9)).collect();
    (keys, mask)
}

fn str_table(rng: &mut Rng, n: usize, key: &str, val: &str) -> Table {
    let (keys, mask) = random_str_keys(rng, n);
    Table::from_pairs(vec![
        (key, Column::Str(keys)),
        (val, Column::I64((0..n).map(|_| rng.i64_range(-50, 50)).collect())),
    ])
    .unwrap()
    .with_null_mask(key, ValidityMask::from_bools(&mask))
    .unwrap()
}

fn tables_identical(a: &Table, b: &Table, label: &str) -> Result<(), String> {
    if a == b {
        Ok(())
    } else {
        Err(format!("{label}: tables differ (values or masks)"))
    }
}

#[test]
fn string_keyed_join_three_way_all_types() {
    forall_cases(
        "str-join-3way",
        6,
        |rng| {
            let nl = 30 + rng.usize(120);
            let nr = 10 + rng.usize(60);
            (str_table(rng, nl, "k", "v"), str_table(rng, nr, "rk", "w"))
        },
        |(l, r)| {
            for mode in [DictEncoding::Off, DictEncoding::Auto] {
                set_dict_encoding(mode);
                for how in [
                    JoinType::Inner,
                    JoinType::Left,
                    JoinType::Right,
                    JoinType::Outer,
                    JoinType::Semi,
                    JoinType::Anti,
                ] {
                    // Semi/Anti keep only the left columns
                    let canon: &[(&str, SortOrder)] =
                        if matches!(how, JoinType::Semi | JoinType::Anti) {
                            &[("k", SortOrder::Asc), ("v", SortOrder::Asc)]
                        } else {
                            &[
                                ("k", SortOrder::Asc),
                                ("v", SortOrder::Asc),
                                ("w", SortOrder::Asc),
                            ]
                        };
                    let label = |engines: &str| format!("{how} [{mode:?}]: {engines}");
                    let hf = HiFrames::with_workers(3);
                    let ours = hf
                        .table("l", l.clone())
                        .join_on(&hf.table("r", r.clone()), &[("k", "rk")], how)
                        .sort_by_keys(canon)
                        .collect()
                        .map_err(|e| e.to_string())?;
                    let srl = serial::join_on(l, r, &[("k", "rk")], how)
                        .map_err(|e| e.to_string())?
                        .sorted_by_keys(canon)
                        .map_err(|e| e.to_string())?;
                    let eng = SparkLike::new(2, 3);
                    let spk = eng
                        .join_on(&eng.parallelize(l), &eng.parallelize(r), &[("k", "rk")], how)
                        .and_then(|rdd| eng.collect(&rdd))
                        .map_err(|e| e.to_string())?
                        .sorted_by_keys(canon)
                        .map_err(|e| e.to_string())?;
                    tables_identical(&ours, &srl, &label("hiframes vs serial"))?;
                    tables_identical(&srl, &spk, &label("serial vs sparklike"))?;
                }
            }
            set_dict_encoding(DictEncoding::Auto);
            Ok(())
        },
    );
}

#[test]
fn string_keyed_aggregate_three_way() {
    forall_cases(
        "str-aggregate-3way",
        8,
        |rng| {
            let n = 50 + rng.usize(200);
            str_table(rng, n, "k", "v")
        },
        |t| {
            // order-independent aggregates only: the three engines may fold
            // groups in different orders, and the outputs must still be
            // byte-identical
            let aggs = vec![
                AggExpr::new("n", AggFn::Count, col("v")),
                AggExpr::new("lo", AggFn::Min, col("v")),
                AggExpr::new("hi", AggFn::Max, col("v")),
            ];
            let canon: &[(&str, SortOrder)] = &[("k", SortOrder::Asc)];
            for mode in [DictEncoding::Off, DictEncoding::Auto] {
                set_dict_encoding(mode);
                let hf = HiFrames::with_workers(3);
                let ours = hf
                    .table("t", t.clone())
                    .aggregate_by(&["k"], aggs.clone())
                    .sort_by_keys(canon)
                    .collect()
                    .map_err(|e| e.to_string())?;
                let srl = serial::aggregate_by(t, &["k"], &aggs)
                    .map_err(|e| e.to_string())?
                    .sorted_by_keys(canon)
                    .map_err(|e| e.to_string())?;
                let eng = SparkLike::new(2, 3);
                let spk = eng
                    .aggregate_by(&eng.parallelize(t), &["k"], &aggs)
                    .and_then(|rdd| eng.collect(&rdd))
                    .map_err(|e| e.to_string())?
                    .sorted_by_keys(canon)
                    .map_err(|e| e.to_string())?;
                tables_identical(&ours, &srl, &format!("[{mode:?}] hiframes vs serial"))?;
                tables_identical(&srl, &spk, &format!("[{mode:?}] serial vs sparklike"))?;
            }
            set_dict_encoding(DictEncoding::Auto);
            Ok(())
        },
    );
}

#[test]
fn string_keyed_sort_agrees_with_serial() {
    forall_cases(
        "str-sort",
        8,
        |rng| {
            let n = 50 + rng.usize(250);
            (str_table(rng, n, "k", "v"), rng.bool(0.5))
        },
        |(t, desc)| {
            let dir = if *desc { SortOrder::Desc } else { SortOrder::Asc };
            // v breaks ties, so the row order is fully determined
            let keys: &[(&str, SortOrder)] = &[("k", dir), ("v", SortOrder::Asc)];
            for mode in [DictEncoding::Off, DictEncoding::Auto] {
                set_dict_encoding(mode);
                for workers in [2usize, 3] {
                    let hf = HiFrames::with_workers(workers);
                    let ours = hf
                        .table("t", t.clone())
                        .sort_by_keys(keys)
                        .collect()
                        .map_err(|e| e.to_string())?;
                    let srl = t.sorted_by_keys(keys).map_err(|e| e.to_string())?;
                    tables_identical(
                        &ours,
                        &srl,
                        &format!("[{mode:?}] workers={workers} sort vs serial"),
                    )?;
                }
            }
            set_dict_encoding(DictEncoding::Auto);
            Ok(())
        },
    );
}

#[test]
fn string_partitioned_window_three_way() {
    forall_cases(
        "str-window-3way",
        8,
        |rng| {
            let n = 30 + rng.usize(150);
            let (keys, mask) = random_str_keys(rng, n);
            // a globally-unique order column makes the within-partition
            // order (and so every running sum) fully deterministic
            let mut o: Vec<i64> = (0..n as i64).collect();
            for i in (1..n).rev() {
                o.swap(i, rng.usize(i + 1));
            }
            Table::from_pairs(vec![
                ("k", Column::Str(keys)),
                ("o", Column::I64(o)),
                (
                    "v",
                    Column::I64((0..n).map(|_| rng.i64_range(-50, 50)).collect()),
                ),
            ])
            .unwrap()
            .with_null_mask("k", ValidityMask::from_bools(&mask))
            .unwrap()
        },
        |t| {
            let aggs = vec![WindowAgg::new(
                "cs",
                WindowFunc::Sum,
                WindowFrame::CumulativeToCurrent,
                col("v"),
            )];
            let order: &[(&str, SortOrder)] = &[("o", SortOrder::Asc)];
            let canon: &[(&str, SortOrder)] = &[("k", SortOrder::Asc), ("o", SortOrder::Asc)];
            for mode in [DictEncoding::Off, DictEncoding::Auto] {
                set_dict_encoding(mode);
                let hf = HiFrames::with_workers(3);
                let ours = hf
                    .table("t", t.clone())
                    .window()
                    .partition_by(&["k"])
                    .order_by(order)
                    .agg("cs", WindowFunc::Sum, col("v"))
                    .build()
                    .collect()
                    .map_err(|e| e.to_string())?
                    .sorted_by_keys(canon)
                    .map_err(|e| e.to_string())?;
                let srl = serial::window(t, &["k"], order, &aggs)
                    .map_err(|e| e.to_string())?
                    .sorted_by_keys(canon)
                    .map_err(|e| e.to_string())?;
                let eng = SparkLike::new(2, 3);
                let spk = eng
                    .window_over(&eng.parallelize(t), &["k"], order, &aggs)
                    .and_then(|rdd| eng.collect(&rdd))
                    .map_err(|e| e.to_string())?
                    .sorted_by_keys(canon)
                    .map_err(|e| e.to_string())?;
                // engines may order output columns differently; compare the
                // shared columns byte-for-byte, masks included
                for c in ["k", "o", "v", "cs"] {
                    for (other, engines) in
                        [(&srl, "hiframes vs serial"), (&spk, "hiframes vs sparklike")]
                    {
                        if ours.column(c) != other.column(c) || ours.mask(c) != other.mask(c) {
                            return Err(format!("[{mode:?}] {engines}: column {c} differs"));
                        }
                    }
                }
            }
            set_dict_encoding(DictEncoding::Auto);
            Ok(())
        },
    );
}

#[test]
fn string_keyed_spill_run_ships_dict_frames() {
    // the out-of-core path must agree with the serial oracle while string
    // key columns ride the dictionary wire format through shuffle and spill
    set_dict_encoding(DictEncoding::Auto);
    let mut rng = Rng::new(42);
    let left = str_table(&mut rng, 3000, "k", "v");
    let right = str_table(&mut rng, 1000, "rk", "w");
    let plan = Plan::Sort {
        input: Box::new(Plan::Join {
            left: Box::new(source_mem("l", left.clone())),
            right: Box::new(source_mem("r", right.clone())),
            on: vec![("k".into(), "rk".into())],
            how: JoinType::Left,
            strategy: JoinStrategy::Hash,
        }),
        keys: vec![
            ("k".into(), SortOrder::Asc),
            ("v".into(), SortOrder::Asc),
            ("w".into(), SortOrder::Asc),
        ],
    };
    let serial = collect_serial(plan.clone()).unwrap();
    let input_bytes = left.byte_size() + right.byte_size();
    for frac in [0.25f64, 0.05] {
        let budget = ((input_bytes as f64) * frac) as usize;
        let o = ExecOptions {
            workers: 2,
            mem_budget: Some(budget),
            ..Default::default()
        };
        let before = spill_stats().snapshot();
        let got = collect(plan.clone(), &o).unwrap();
        let after = spill_stats().snapshot();
        assert_eq!(got, serial, "frac={frac}");
        if frac <= 0.05 {
            // counters are process-global; assert a monotonic delta only
            assert!(
                after.bytes_spilled > before.bytes_spilled,
                "frac={frac}: nothing spilled"
            );
        }
    }
}
