//! Window-function property suite: the HiFrames SPMD executor, the serial
//! pandas-like engine and the sparklike map-reduce engine must agree on
//! window values *and* null positions — rolling aggregates, shifts across
//! rank boundaries, partitioned windows with keys split across ranks,
//! nullable inputs, and frames wider than a rank's local chunk.

use hiframes::baseline::{serial, sparklike::SparkLike};
use hiframes::datagen::Rng;
use hiframes::ir::WindowAgg;
use hiframes::ops::stencil::{stencil_serial, wma_weights_124};
use hiframes::prelude::*;
use hiframes::prop::forall_cases;

/// Random frame: group key `g` (sometimes nullable), unique order key `o`,
/// nullable Int64 value `v`, exact-in-f64 value `x`.
fn random_table(rng: &mut Rng, n: usize, null_v: f64, null_g: bool) -> Table {
    let g: Vec<i64> = (0..n).map(|_| rng.i64_range(0, 5)).collect();
    // unique order keys → every engine agrees on a total row order
    let o: Vec<i64> = (0..n as i64).map(|i| (i * 7919) % n as i64).collect();
    let v: Vec<i64> = (0..n).map(|_| rng.i64_range(-50, 50)).collect();
    let x: Vec<f64> = v.iter().map(|&a| a as f64 * 0.5).collect();
    let mut t = Table::from_pairs(vec![
        ("g", Column::I64(g)),
        ("o", Column::I64(o)),
        ("v", Column::I64(v)),
        ("x", Column::F64(x)),
    ])
    .unwrap();
    if null_v > 0.0 {
        let keep: Vec<bool> = (0..n).map(|_| rng.f64() >= null_v).collect();
        t = t
            .with_null_mask("v", ValidityMask::from_bools(&keep))
            .unwrap();
    }
    if null_g {
        let keep: Vec<bool> = (0..n).map(|_| rng.f64() >= 0.1).collect();
        t = t
            .with_null_mask("g", ValidityMask::from_bools(&keep))
            .unwrap();
    }
    t
}

/// Exact table comparison over the named columns (values and masks). All
/// numeric inputs are integers/halves, so even the F64 window outputs are
/// bit-identical across engines.
fn columns_equal(a: &Table, b: &Table, cols: &[&str], label: &str) -> Result<(), String> {
    if a.num_rows() != b.num_rows() {
        return Err(format!("{label}: rows {} vs {}", a.num_rows(), b.num_rows()));
    }
    for c in cols {
        if a.column(c) != b.column(c) {
            return Err(format!("{label}: column {c} differs"));
        }
        if a.mask(c) != b.mask(c) {
            return Err(format!("{label}: mask of {c} differs"));
        }
    }
    Ok(())
}

/// Apply the same aggregate list through the fluent builder.
fn hiframes_window(
    df: &DataFrame,
    partition_by: &[&str],
    order_by: &[(&str, SortOrder)],
    aggs: &[WindowAgg],
) -> DataFrame {
    let mut b = df.window().partition_by(partition_by).order_by(order_by);
    for a in aggs {
        b = b.agg_expr(
            &a.out,
            WindowExpr {
                input: a.input.clone(),
                frame: a.frame.clone(),
                func: a.func.clone(),
            },
        );
    }
    b.build()
}

#[test]
fn stencil_wrapper_byte_identical_to_legacy_kernel() {
    // acceptance: df.stencil through the Window node reproduces the
    // pre-refactor stencil output bit-for-bit
    let xs: Vec<f64> = (0..257).map(|i| ((i * 13) % 17) as f64 - 8.0).collect();
    let t = Table::from_pairs(vec![("x", Column::F64(xs.clone()))]).unwrap();
    let expect = stencil_serial(&xs, &wma_weights_124());
    for workers in [1usize, 2, 4] {
        let hf = HiFrames::with_workers(workers);
        let got = hf
            .table("t", t.clone())
            .stencil("x", "w", wma_weights_124())
            .collect()
            .unwrap();
        assert_eq!(
            got.column("w").unwrap().as_f64(),
            expect.as_slice(),
            "workers={workers}"
        );
        assert_eq!(got.mask("w"), None);
        // the serial baseline engine computes the same thing
        let srl = serial::wma(&t, "x", "w", &wma_weights_124()).unwrap();
        assert_eq!(srl.column("w").unwrap().as_f64(), expect.as_slice());
    }
}

#[test]
fn global_windows_match_serial() {
    forall_cases(
        "window-global",
        10,
        |rng| {
            let n = 20 + rng.usize(180);
            let p = rng.usize(4);
            let f = rng.usize(3);
            (random_table(rng, n, 0.2, false), p, f)
        },
        |(t, p, f)| {
            let aggs = vec![
                WindowAgg::new("rs", WindowFunc::Sum, roll(*p, *f), col("v")),
                WindowAgg::new("rm", WindowFunc::Mean, roll(*p, *f), col("v")),
                WindowAgg::new("rlo", WindowFunc::Min, roll(*p, *f), col("x")),
                WindowAgg::new("prev", WindowFunc::Value, WindowFrame::Shift(1), col("v")),
                WindowAgg::new("nxt2", WindowFunc::Value, WindowFrame::Shift(-2), col("v")),
                WindowAgg::new(
                    "cs",
                    WindowFunc::Sum,
                    WindowFrame::CumulativeToCurrent,
                    col("v"),
                ),
            ];
            let expect = serial::window(t, &[], &[], &aggs).map_err(|e| e.to_string())?;
            let outs = ["rs", "rm", "rlo", "prev", "nxt2", "cs", "v", "g"];
            for workers in [2usize, 4] {
                let hf = HiFrames::with_workers(workers);
                let got = hiframes_window(&hf.table("t", t.clone()), &[], &[], &aggs)
                    .collect()
                    .map_err(|e| e.to_string())?;
                columns_equal(&got, &expect, &outs, &format!("global w={workers}"))?;
            }
            Ok(())
        },
    );
}

fn roll(preceding: usize, following: usize) -> WindowFrame {
    WindowFrame::Rolling {
        preceding,
        following,
    }
}

#[test]
fn partitioned_windows_three_way() {
    forall_cases(
        "window-partitioned",
        10,
        |rng| {
            let n = 30 + rng.usize(170);
            let null_g = rng.usize(2) == 0;
            random_table(rng, n, 0.2, null_g)
        },
        |t| {
            let aggs = vec![
                WindowAgg::new("rs", WindowFunc::Sum, roll(2, 0), col("v")),
                WindowAgg::new("rm", WindowFunc::Mean, roll(1, 1), col("x")),
                WindowAgg::new("prev", WindowFunc::Value, WindowFrame::Shift(1), col("v")),
                WindowAgg::new(
                    "cs",
                    WindowFunc::Sum,
                    WindowFrame::CumulativeToCurrent,
                    col("v"),
                ),
                WindowAgg::new(
                    "r",
                    WindowFunc::Rank,
                    WindowFrame::CumulativeToCurrent,
                    lit(0i64),
                ),
            ];
            let part = ["g"];
            let order = [("o", SortOrder::Asc)];
            let canon = [("g", SortOrder::Asc), ("o", SortOrder::Asc)];
            let outs = ["g", "o", "v", "rs", "rm", "prev", "cs", "r"];
            let expect = serial::window(t, &part, &order, &aggs)
                .map_err(|e| e.to_string())?
                .sorted_by_keys(&canon)
                .map_err(|e| e.to_string())?;
            // hiframes across worker counts (partitions split across ranks)
            for workers in [2usize, 3] {
                let hf = HiFrames::with_workers(workers);
                let got = hiframes_window(&hf.table("t", t.clone()), &part, &order, &aggs)
                    .collect()
                    .map_err(|e| e.to_string())?
                    .sorted_by_keys(&canon)
                    .map_err(|e| e.to_string())?;
                columns_equal(&got, &expect, &outs, &format!("hiframes w={workers}"))?;
            }
            // sparklike row-eval parity
            let eng = SparkLike::new(2, 3);
            let spk = eng
                .window_over(&eng.parallelize(t), &part, &order, &aggs)
                .map_err(|e| e.to_string())?;
            let spk = eng
                .collect(&spk)
                .map_err(|e| e.to_string())?
                .sorted_by_keys(&canon)
                .map_err(|e| e.to_string())?;
            columns_equal(&spk, &expect, &outs, "sparklike")
        },
    );
}

#[test]
fn frames_wider_than_a_local_chunk_fall_back() {
    // 4 workers over 6 rows with a 5-deep frame: every block is smaller
    // than the frame reach, so the gather fallback must kick in and still
    // match the serial oracle
    let t = Table::from_pairs(vec![
        ("v", Column::I64(vec![5, -3, 8, 0, 2, 7])),
        ("x", Column::F64(vec![0.5, 1.0, 1.5, 2.0, 2.5, 3.0])),
    ])
    .unwrap()
    .with_null_mask("v", ValidityMask::from_bools(&[true, false, true, true, true, false]))
    .unwrap();
    let aggs = vec![
        WindowAgg::new("s", WindowFunc::Sum, roll(5, 0), col("v")),
        WindowAgg::new("m", WindowFunc::Min, roll(0, 4), col("v")),
        WindowAgg::new("far", WindowFunc::Value, WindowFrame::Shift(4), col("x")),
    ];
    let expect = serial::window(&t, &[], &[], &aggs).unwrap();
    for workers in [4usize, 6] {
        let hf = HiFrames::with_workers(workers);
        let got = hiframes_window(&hf.table("t", t.clone()), &[], &[], &aggs)
            .collect()
            .unwrap();
        columns_equal(&got, &expect, &["s", "m", "far"], &format!("w={workers}"))
            .unwrap();
    }
}

#[test]
fn shift_crosses_rank_boundaries() {
    // lag/lead pull values across rank edges: only the *global* edges are
    // null, never the internal block boundaries
    let n = 30usize;
    let t = Table::from_pairs(vec![(
        "v",
        Column::I64((0..n as i64).map(|i| i * 3).collect()),
    )])
    .unwrap();
    for workers in [2usize, 3, 5] {
        let hf = HiFrames::with_workers(workers);
        let got = hf
            .table("t", t.clone())
            .window()
            .agg_expr("prev", col("v").lag(1))
            .agg_expr("ahead", col("v").lead(3))
            .row_number("rn")
            .build()
            .collect()
            .unwrap();
        let prev = got.column("prev").unwrap().as_i64();
        let ahead = got.column("ahead").unwrap().as_i64();
        let pm = got.mask("prev").unwrap();
        let am = got.mask("ahead").unwrap();
        for i in 0..n {
            if i == 0 {
                assert!(!pm.get(i), "workers={workers}");
            } else {
                assert!(pm.get(i), "workers={workers} row {i}");
                assert_eq!(prev[i], (i as i64 - 1) * 3, "workers={workers}");
            }
            if i + 3 < n {
                assert!(am.get(i), "workers={workers} row {i}");
                assert_eq!(ahead[i], (i as i64 + 3) * 3, "workers={workers}");
            } else {
                assert!(!am.get(i), "workers={workers}");
            }
        }
        assert_eq!(
            got.column("rn").unwrap().as_i64(),
            (1..=n as i64).collect::<Vec<_>>().as_slice(),
            "workers={workers}"
        );
    }
}

#[test]
fn nullable_windows_type_and_collect_end_to_end() {
    // a left join introduces a nullable column; windows accept it directly
    // (the old Cumsum/Stencil nodes rejected nullable inputs)
    let hf = HiFrames::with_workers(3);
    let left = hf.table(
        "l",
        Table::from_pairs(vec![("id", Column::I64(vec![0, 1, 2, 3, 4, 5]))]).unwrap(),
    );
    let right = hf.table(
        "r",
        Table::from_pairs(vec![
            ("rid", Column::I64(vec![0, 2, 4])),
            ("w", Column::I64(vec![10, 20, 30])),
        ])
        .unwrap(),
    );
    let joined = left.join_on(&right, &[("id", "rid")], JoinType::Left);
    assert_eq!(joined.schema().unwrap().nullable_of("w"), Some(true));
    // global windows run in row order: canonicalize with a sort *first*
    // (the optimizer then inserts the rebalance the rolling frame needs)
    let out = joined
        .sort_by("id")
        .window()
        .agg_expr("cs", col("w").cum_sum())
        .rolling_between(1, 1)
        .agg("rm", WindowFunc::Mean, col("w"))
        .build()
        .collect()
        .unwrap();
    // cum over [10,_,20,_,30,_] — sums skip nulls, never NULL
    assert_eq!(out.schema().nullable_of("cs"), Some(false));
    assert_eq!(out.column("cs").unwrap().as_i64(), &[10, 10, 30, 30, 60, 60]);
    // rolling mean: centered window always sees ≥1 valid here
    let rm = out.column("rm").unwrap().as_f64();
    assert!((rm[0] - 10.0).abs() < 1e-12);
    assert!((rm[1] - 15.0).abs() < 1e-12);
    assert_eq!(out.null_count("rm"), 0);
}
