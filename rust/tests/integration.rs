//! Cross-module integration: optimizer ⇄ executor semantics, HFS sources,
//! multi-operation pipelines, worker-count invariance, comm statistics.

use hiframes::exec::{collect_optimized, ExecOptions};
use hiframes::ops::aggregate::AggStrategy;
use hiframes::passes::{optimize, PassOptions, RebalanceMode};
use hiframes::prelude::*;

fn micro(rows: usize) -> Table {
    hiframes::datagen::micro_table(rows, 50, 99)
}

/// Build the paper's Fig. 6 query: filter over join.
fn fig6_plan(hf: &HiFrames) -> hiframes::frame::DataFrame {
    let customer = hf.table(
        "customer",
        Table::from_pairs(vec![
            ("id", Column::I64((0..200).collect())),
            ("phone", Column::I64((0..200).map(|i| i * 7).collect())),
        ])
        .unwrap(),
    );
    let order = hf.table(
        "order",
        Table::from_pairs(vec![
            ("customerId", Column::I64((0..400).map(|i| i % 200).collect())),
            (
                "amount",
                Column::F64((0..400).map(|i| (i as f64 * 13.7) % 200.0).collect()),
            ),
        ])
        .unwrap(),
    );
    customer
        .join(&order, "id", "customerId")
        .filter(col("amount").gt(lit(100.0)))
}

#[test]
fn optimized_and_unoptimized_agree() {
    let hf = HiFrames::with_workers(3);
    let q = fig6_plan(&hf).sort_by("id");
    let plan = q.plan().clone();

    let opts_on = ExecOptions {
        workers: 3,
        passes: PassOptions::default(),
        agg_strategy: AggStrategy::RawShuffle,
        mem_budget: None,
        profile: false,
    };
    let opts_off = ExecOptions {
        workers: 3,
        passes: PassOptions::none(),
        agg_strategy: AggStrategy::RawShuffle,
        mem_budget: None,
        profile: false,
    };
    let a = collect_optimized(&optimize(plan.clone(), &opts_on.passes).unwrap(), &opts_on).unwrap();
    let b =
        collect_optimized(&optimize(plan, &opts_off.passes).unwrap(), &opts_off).unwrap();
    assert_eq!(a.num_rows(), b.num_rows());
    assert_eq!(a.column("id").unwrap(), b.column("id").unwrap());
    assert_eq!(a.column("amount").unwrap(), b.column("amount").unwrap());
}

#[test]
fn pushdown_reduces_shuffled_rows() {
    // with pushdown the filter runs before the join, so fewer rows shuffle
    let hf = HiFrames::with_workers(2);
    let plan = fig6_plan(&hf).plan().clone();
    let optimized = optimize(plan.clone(), &PassOptions::default()).unwrap();
    // optimized plan: filter is below the join
    let txt = format!("{optimized}");
    let join_pos = txt.find("Join").unwrap();
    let filter_pos = txt.find("Filter").unwrap();
    assert!(
        filter_pos > join_pos,
        "filter should be nested under join:\n{txt}"
    );
}

#[test]
fn rebalance_modes_same_result() {
    let hf = HiFrames::with_workers(4);
    let t = micro(997);
    let df = hf
        .table("t", t)
        .filter(col("x").gt(lit(0.3)))
        .sma("y", "s", 3);
    for mode in [RebalanceMode::Lazy, RebalanceMode::Always] {
        let opts = ExecOptions {
            workers: 4,
            passes: PassOptions {
                rebalance: mode,
                ..Default::default()
            },
            agg_strategy: AggStrategy::RawShuffle,
            mem_budget: None,
            profile: false,
        };
        let optimized = optimize(df.plan().clone(), &opts.passes).unwrap();
        let out = collect_optimized(&optimized, &opts).unwrap();
        // compare against the serial oracle
        let serial = hiframes::exec::collect_serial(df.plan().clone()).unwrap();
        assert_eq!(out.num_rows(), serial.num_rows(), "{mode:?}");
        for (a, b) in out
            .column("s")
            .unwrap()
            .as_f64()
            .iter()
            .zip(serial.column("s").unwrap().as_f64())
        {
            assert!((a - b).abs() < 1e-9, "{mode:?}");
        }
    }
}

#[test]
fn worker_count_invariance() {
    // the same plan must produce identical results on 1..5 workers
    let t = micro(1234);
    let mut reference: Option<Table> = None;
    for w in [1usize, 2, 3, 5] {
        let hf = HiFrames::with_workers(w);
        let out = hf
            .table("t", t.clone())
            .filter(col("x").lt(lit(0.7)))
            .aggregate(
                "id",
                vec![
                    AggExpr::new("n", AggFn::Count, col("x")),
                    AggExpr::new("sy", AggFn::Sum, col("y")),
                    AggExpr::new("mx", AggFn::Max, col("x")),
                ],
            )
            .sort_by("id")
            .collect()
            .unwrap();
        match &reference {
            None => reference = Some(out),
            Some(r) => {
                assert_eq!(out.column("id").unwrap(), r.column("id").unwrap(), "w={w}");
                assert_eq!(out.column("n").unwrap(), r.column("n").unwrap(), "w={w}");
                for (a, b) in out
                    .column("sy")
                    .unwrap()
                    .as_f64()
                    .iter()
                    .zip(r.column("sy").unwrap().as_f64())
                {
                    assert!((a - b).abs() < 1e-6, "w={w}");
                }
            }
        }
    }
}

#[test]
fn hfs_source_pipeline() {
    let dir = std::env::temp_dir().join("hiframes_it");
    std::fs::create_dir_all(&dir).unwrap();
    let p = dir.join("micro.hfs");
    let t = micro(500);
    hiframes::io::write_hfs(&p, &t).unwrap();

    let hf = HiFrames::with_workers(3);
    let df = hf.read_hfs("micro", &p).unwrap();
    let out = df
        .filter(col("id").lt(lit(25i64)))
        .aggregate("id", vec![AggExpr::new("n", AggFn::Count, col("x"))])
        .sort_by("id")
        .collect()
        .unwrap();
    // oracle over the in-memory table
    let serial = hiframes::baseline::serial::aggregate(
        &hiframes::baseline::serial::filter(&t, &col("id").lt(lit(25i64))).unwrap(),
        "id",
        &[AggExpr::new("n", AggFn::Count, col("x"))],
    )
    .unwrap()
    .sorted_by("id")
    .unwrap();
    assert_eq!(out.column("id").unwrap(), serial.column("id").unwrap());
    assert_eq!(out.column("n").unwrap(), serial.column("n").unwrap());
}

#[test]
fn typed_read_checks_schema() {
    let dir = std::env::temp_dir().join("hiframes_it");
    std::fs::create_dir_all(&dir).unwrap();
    let p = dir.join("typed.hfs");
    hiframes::io::write_hfs(&p, &micro(10)).unwrap();
    let hf = HiFrames::with_workers(1);
    let good = Schema::of(&[
        ("id", DType::I64),
        ("x", DType::F64),
        ("y", DType::F64),
    ]);
    assert!(hf.read_hfs_typed("t", &p, good).is_ok());
    let bad = Schema::of(&[("id", DType::F64)]);
    assert!(hf.read_hfs_typed("t", &p, bad).is_err());
}

#[test]
fn multi_join_pipeline() {
    // three-way join with interleaved array computation (the paper's point:
    // relational + non-relational mix in one optimized program)
    let hf = HiFrames::with_workers(3);
    let a = hf.table(
        "a",
        Table::from_pairs(vec![
            ("k1", Column::I64((0..60).collect())),
            ("va", Column::F64((0..60).map(|i| i as f64).collect())),
        ])
        .unwrap(),
    );
    let b = hf.table(
        "b",
        Table::from_pairs(vec![
            ("k2", Column::I64((0..60).rev().collect())),
            ("vb", Column::F64((0..60).map(|i| i as f64 * 2.0).collect())),
        ])
        .unwrap(),
    );
    let c = hf.table(
        "c",
        Table::from_pairs(vec![
            ("k3", Column::I64((0..30).collect())),
            ("vc", Column::F64((0..30).map(|i| i as f64 * 3.0).collect())),
        ])
        .unwrap(),
    );
    let out = a
        .join(&b, "k1", "k2")
        .with_column("vab", col("va").add(col("vb")))
        .join(&c, "k1", "k3")
        .filter(col("vab").gt(lit(10.0)))
        .sort_by("k1")
        .collect()
        .unwrap();
    assert!(out.num_rows() > 0);
    // spot-check one row: k1=20 -> va=20, vb = (59-20)*2... b's k2 is reversed
    let k = out.column("k1").unwrap().as_i64();
    let vab = out.column("vab").unwrap().as_f64();
    for (i, &key) in k.iter().enumerate() {
        let expect = key as f64 + (59 - key) as f64 * 2.0;
        assert!((vab[i] - expect).abs() < 1e-9, "k={key}");
    }
}

#[test]
fn comm_stats_reported() {
    let (out, stats) = hiframes::comm::run_spmd_with_stats(3, |c| {
        let keys: Vec<i64> = (0..30).map(|i| i % 7).collect();
        let vals = Column::F64(vec![1.0; 30]);
        let (k, _) = hiframes::ops::shuffle_by_key(&c, &keys, &[vals]).unwrap();
        k.len()
    });
    assert_eq!(out.iter().sum::<usize>(), 90);
    let (msgs, bytes, _, colls) = stats.snapshot();
    assert!(msgs >= 9); // 3x3 alltoallv
    assert!(bytes > 0);
    assert!(colls >= 3);
}
