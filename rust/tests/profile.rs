//! Query-profiler integration tests: profiling must be a pure observation.
//! Profiled and unprofiled collects are byte-identical on join, aggregate,
//! window and spilling pipelines; per-node row counts sum to the plan's
//! actual cardinalities; shuffle bytes attributed to nodes (plus the final
//! gather) account for *every* byte the communicator saw; and the Q26
//! `explain_analyze` / Chrome-trace surfaces keep their documented shape.
//! Budgets and profiling are passed explicitly through `ExecOptions` —
//! never the env knobs — so parallel test cases cannot race.

use hiframes::bigbench::{generate, q26, GenOptions};
use hiframes::datagen::Rng;
use hiframes::exec::ExecOptions;
use hiframes::prelude::*;

fn hf(workers: usize, mem_budget: Option<usize>) -> HiFrames {
    HiFrames::new(ExecOptions {
        workers,
        mem_budget,
        profile: false,
        ..Default::default()
    })
}

/// A fact/dim pair (same shape as `tests/spill.rs`): duplicate-heavy group
/// keys, a float measure, a ~2/3-matching dimension with a nullable payload.
fn fact_dim(rows: usize) -> (Table, Table) {
    let mut rng = Rng::new(7);
    let grp: Vec<i64> = (0..rows).map(|_| rng.i64_range(0, 40)).collect();
    let left = Table::from_pairs(vec![
        ("id", Column::I64((0..rows as i64).collect())),
        ("grp", Column::I64(grp)),
        (
            "val",
            Column::F64((0..rows).map(|i| (i as f64 * 1.7) % 31.0).collect()),
        ),
    ])
    .unwrap();
    let rid: Vec<i64> = (0..rows as i64).filter(|i| i % 3 != 0).collect();
    let tag: Vec<i64> = rid.iter().map(|i| i * 5).collect();
    let tag_valid: Vec<bool> = rid.iter().map(|i| i % 7 != 0).collect();
    let right = Table::from_pairs(vec![
        ("rid", Column::I64(rid)),
        ("tag", Column::I64(tag)),
    ])
    .unwrap()
    .with_null_mask("tag", ValidityMask::from_bools(&tag_valid))
    .unwrap();
    (left, right)
}

#[test]
fn profiled_collect_is_byte_identical() {
    let (left, right) = fact_dim(600);
    for workers in [2usize, 3] {
        let hf = hf(workers, None);
        let l = hf.table("l", left.clone());
        let r = hf.table("r", right.clone());
        let queries = [
            l.join(&r, "id", "rid").sort_by("id"),
            l.join(&r, "id", "rid")
                .aggregate("grp", vec![AggExpr::new("sv", AggFn::Sum, col("val"))])
                .sort_by("grp"),
            l.window()
                .partition_by(&["grp"])
                .order_by(&[("id", SortOrder::Asc)])
                .rolling(3)
                .agg("s3", WindowFunc::Sum, col("val"))
                .build(),
        ];
        for (qi, q) in queries.iter().enumerate() {
            let plain = q.collect().unwrap();
            let (profiled, prof) = q.collect_profiled().unwrap();
            assert_eq!(
                profiled, plain,
                "workers={workers} query={qi}: profiling changed the result"
            );
            assert!(prof.executed_nodes() > 0, "workers={workers} query={qi}");
            // SPMD: every materialized node ran on every rank, rank order
            for n in prof.nodes.iter().filter(|n| n.executed()) {
                let ranks: Vec<usize> = n.spans.iter().map(|s| s.rank).collect();
                assert_eq!(
                    ranks,
                    (0..workers).collect::<Vec<_>>(),
                    "workers={workers} query={qi} node {}",
                    n.label
                );
            }
        }
    }
}

#[test]
fn node_row_counts_sum_to_cardinalities() {
    let (left, right) = fact_dim(400);
    let hf = hf(2, None);
    let l = hf.table("l", left);
    let r = hf.table("r", right);
    let expected_join_rows = l.join(&r, "id", "rid").collect().unwrap().num_rows() as u64;
    let q = l
        .join(&r, "id", "rid")
        .aggregate("grp", vec![AggExpr::new("sv", AggFn::Sum, col("val"))]);
    let (t, prof) = q.collect_profiled().unwrap();

    let node = |needle: &str| {
        prof.nodes
            .iter()
            .find(|n| n.label.contains(needle))
            .unwrap_or_else(|| panic!("no {needle} node in:\n{}", prof.render()))
    };
    let join = node("Join(");
    assert!(join.executed());
    assert_eq!(
        join.rows_out(),
        expected_join_rows,
        "join output rows must sum to the join cardinality:\n{}",
        prof.render()
    );
    let agg = node("Aggregate(");
    assert_eq!(
        agg.rows_out(),
        t.num_rows() as u64,
        "aggregate output rows must sum to the result cardinality:\n{}",
        prof.render()
    );
    // the aggregate consumes exactly the materialized join output
    assert_eq!(agg.rows_in(), join.rows_out(), "\n{}", prof.render());
}

#[test]
fn shuffle_bytes_attribute_to_nodes() {
    let (left, right) = fact_dim(500);
    let hf = hf(2, None);
    let q = hf
        .table("l", left)
        .join(&hf.table("r", right), "id", "rid")
        .aggregate("grp", vec![AggExpr::new("sv", AggFn::Sum, col("val"))]);
    let (_, prof) = q.collect_profiled().unwrap();
    // every byte the world's communicator counted is attributed: either to
    // the node that sent it or to the final leader gather
    assert_eq!(
        prof.total_bytes_shuffled() + prof.gather_bytes,
        prof.comm_totals.1,
        "unattributed comm bytes:\n{}",
        prof.render()
    );
    let join = prof
        .nodes
        .iter()
        .find(|n| n.label.contains("Join("))
        .unwrap();
    assert!(
        join.bytes_shuffled() > 0,
        "hash join at 2 workers must shuffle:\n{}",
        prof.render()
    );
    assert!(prof.gather_bytes > 0, "result gather moves bytes");
    assert!(prof.comm_totals.1 >= prof.total_bytes_shuffled());
}

#[test]
fn spill_attributes_exactly_to_budgeted_operators() {
    let (left, right) = fact_dim(3000);
    let input_bytes = left.byte_size() + right.byte_size();
    let budget = input_bytes / 20; // 5%: forces join + sort out of core
    let hf_tight = hf(2, Some(budget));
    let q = hf_tight
        .table("l", left.clone())
        .join(&hf_tight.table("r", right.clone()), "id", "rid")
        .sort_by_keys(&[("grp", SortOrder::Asc), ("id", SortOrder::Asc)]);
    let plain = q.collect().unwrap();
    let (t1, p1) = q.collect_profiled().unwrap();
    let (t2, p2) = q.collect_profiled().unwrap();
    assert_eq!(t1, plain, "profiling changed the spilling result");
    assert_eq!(t2, plain);
    assert!(p1.total_bytes_spilled() > 0, "budget {budget} did not spill");
    // spill only ever lands on the out-of-core-capable operators
    for n in p1.nodes.iter().filter(|n| n.bytes_spilled() > 0) {
        assert!(
            ["Join(", "Aggregate(", "Sort("]
                .iter()
                .any(|op| n.label.contains(op)),
            "spill attributed to a non-spilling node: {}",
            n.label
        );
    }
    // the per-query scope is isolated from every other test in this
    // process, so counters are *exact* — identical runs report identical
    // per-node spill profiles (unlike the global `spill_stats()` sink)
    for (a, b) in p1.nodes.iter().zip(p2.nodes.iter()) {
        assert_eq!(a.bytes_spilled(), b.bytes_spilled(), "node {}", a.label);
        assert_eq!(a.spill_passes(), b.spill_passes(), "node {}", a.label);
        assert_eq!(a.merge_passes(), b.merge_passes(), "node {}", a.label);
    }
    // and an unbudgeted run of the same plan reports exactly zero
    let hf_loose = hf(2, None);
    let q = hf_loose
        .table("l", left)
        .join(&hf_loose.table("r", right), "id", "rid")
        .sort_by_keys(&[("grp", SortOrder::Asc), ("id", SortOrder::Asc)]);
    let (t3, p3) = q.collect_profiled().unwrap();
    assert_eq!(t3, plain, "budgeted and unbudgeted results diverged");
    assert_eq!(p3.total_bytes_spilled(), 0);
    assert!(p3.nodes.iter().all(|n| n.spill_passes() == 0));
}

/// Mask the run-varying tokens (times, imbalance) of an `explain_analyze`
/// render, keeping the structural fields (labels, rows, bytes, counts).
fn mask(text: &str) -> String {
    text.lines()
        .map(|line| {
            line.split(" | ")
                .map(|f| {
                    if f.starts_with("wall ") {
                        "wall <T>".to_string()
                    } else if f.starts_with("imb ") {
                        "imb <X>".to_string()
                    } else if f.starts_with("elapsed ") {
                        "elapsed <T>".to_string()
                    } else {
                        f.to_string()
                    }
                })
                .collect::<Vec<_>>()
                .join(" | ")
        })
        .collect::<Vec<_>>()
        .join("\n")
}

#[test]
fn q26_explain_analyze_golden() {
    let db = generate(&GenOptions {
        scale_factor: 0.05,
        ..Default::default()
    });
    let p = q26::Q26Params::default();
    let ctx = hf(2, None);
    let q = q26::hiframes_relational(&ctx, &db, &p);

    let text = q.explain_analyze().unwrap();
    // golden: with times and imbalance masked, the render is byte-stable
    assert_eq!(
        mask(&text),
        mask(&q.explain_analyze().unwrap()),
        "explain_analyze structure must be deterministic"
    );

    let lines: Vec<&str> = text.lines().collect();
    let (footer, nodes) = lines.split_last().unwrap();
    assert!(
        footer.starts_with("-- 2 ranks | "),
        "bad footer: {footer}"
    );
    for field in ["nodes executed", "elapsed ", "shuffle ", "spill ", "cache hits "] {
        assert!(footer.contains(field), "footer misses {field:?}: {footer}");
    }

    // each node line is the plain `explain()` line plus ` | `-separated
    // runtime annotations
    let explain = q.explain();
    assert_eq!(nodes.len(), explain.lines().count());
    let mut executed = 0;
    for (nl, el) in nodes.iter().zip(explain.lines()) {
        let label = nl.split(" | ").next().unwrap().trim_end();
        assert_eq!(label, el, "annotated line must wrap the explain line");
        if nl.contains("(not materialized)") {
            continue;
        }
        executed += 1;
        for field in ["| wall ", "| rows ", "| shuffle ", "| spill ", "| imb "] {
            assert!(nl.contains(field), "node line misses {field:?}: {nl}");
        }
    }
    assert!(executed >= 3, "Q26 runs sources, join and aggregate:\n{text}");
}

#[test]
fn q26_chrome_trace_is_well_formed() {
    let db = generate(&GenOptions {
        scale_factor: 0.05,
        ..Default::default()
    });
    let p = q26::Q26Params::default();
    let ctx = hf(2, None);
    let (_, prof) = q26::hiframes_relational(&ctx, &db, &p)
        .collect_profiled()
        .unwrap();
    let trace = prof.to_chrome_trace();
    let spans: usize = prof.nodes.iter().map(|n| n.spans.len()).sum();
    assert!(spans >= 2, "expected spans on both ranks:\n{}", prof.render());

    assert!(trace.contains("\"traceEvents\""));
    assert!(trace.contains("\"displayTimeUnit\":\"ms\""));
    // one named track per rank, one complete slice per recorded span
    assert_eq!(trace.matches("\"thread_name\"").count(), 2);
    assert_eq!(trace.matches("\"ph\":\"X\"").count(), spans);
    for n in prof.nodes.iter().filter(|n| n.executed()) {
        assert_eq!(n.spans.len(), 2, "one slice per rank for {}", n.label);
    }
    // cheap well-formedness: the structural chars all pair up (labels are
    // escaped by the writer; CI's smoke step runs a real JSON parse)
    assert_eq!(
        trace.matches('{').count(),
        trace.matches('}').count(),
        "unbalanced braces"
    );
    assert_eq!(trace.matches('[').count(), trace.matches(']').count());
}
