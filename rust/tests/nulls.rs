//! Validity-mask null subsystem, end to end:
//!
//! * (a) masks round-trip through the nullable column codec;
//! * (b) outer/left/right joins on I64/Bool/Str keys preserve native dtypes
//!   and all three engines (HiFrames ≥2 workers, serial, sparklike) agree
//!   on values *and* null positions;
//! * (c) nullable `PackedKeys` order nulls first, identically to the KeyRow
//!   path;
//! * aggregation skips null inputs and forms null-key groups consistently.

use hiframes::baseline::{serial, sparklike::SparkLike};
use hiframes::column::{
    decode_nullable_column, encode_nullable_column, scrub_invalid, ValidityMask,
};
use hiframes::datagen::Rng;
use hiframes::ops::keys::{cmp_key_rows, key_rows_nullable, PackedKeys};
use hiframes::prelude::*;
use hiframes::prop::{forall_cases, gen};
use hiframes::types::{JoinType, SortOrder};

// ---------------------------------------------------------------------------
// (a) codec round-trip
// ---------------------------------------------------------------------------

#[test]
fn prop_mask_roundtrips_through_codec() {
    forall_cases(
        "mask-codec-roundtrip",
        48,
        |rng| {
            let n = rng.usize(200);
            let dtype = rng.usize(4) as u8;
            let col = match dtype {
                0 => Column::I64((0..n).map(|_| rng.i64_range(-1000, 1000)).collect()),
                1 => Column::F64((0..n).map(|_| rng.normal()).collect()),
                2 => Column::Bool((0..n).map(|_| rng.bool(0.5)).collect()),
                _ => Column::Str((0..n).map(|i| format!("s{}", i % 7)).collect()),
            };
            let mask = ValidityMask::from_bools(&gen::mask(rng, n, 0.7));
            (col, mask)
        },
        |(col, mask)| {
            let mut col = col.clone();
            scrub_invalid(&mut col, mask);
            // masked framing
            let mut buf = Vec::new();
            encode_nullable_column(&col, Some(mask), &mut buf);
            // a second mask-free column in the same buffer (framing safety)
            encode_nullable_column(&col, None, &mut buf);
            let mut pos = 0;
            let (c1, m1) =
                decode_nullable_column(&buf, &mut pos).map_err(|e| e.to_string())?;
            let (c2, m2) =
                decode_nullable_column(&buf, &mut pos).map_err(|e| e.to_string())?;
            if pos != buf.len() {
                return Err(format!("decoder consumed {pos} of {}", buf.len()));
            }
            if c1 != col || c2 != col {
                return Err("column values changed on the wire".into());
            }
            if m1.as_ref() != Some(mask) {
                return Err("mask changed on the wire".into());
            }
            if m2.is_some() {
                return Err("mask invented for mask-free column".into());
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// (b) outer joins: dtype preservation + three-way engine agreement
// ---------------------------------------------------------------------------

/// Values, dtypes, nullable flags and masks must all be identical.
fn tables_identical(a: &Table, b: &Table, label: &str) -> Result<(), String> {
    if a.num_rows() != b.num_rows() {
        return Err(format!("{label}: rows {} vs {}", a.num_rows(), b.num_rows()));
    }
    if a.schema().names() != b.schema().names() {
        return Err(format!("{label}: schema names differ"));
    }
    for (name, dt) in a.schema().fields() {
        if b.schema().dtype_of(name) != Some(*dt) {
            return Err(format!("{label}: dtype of {name} differs"));
        }
        if a.schema().nullable_of(name) != b.schema().nullable_of(name) {
            return Err(format!("{label}: nullability of {name} differs"));
        }
        if a.mask(name) != b.mask(name) {
            return Err(format!("{label}: null positions of {name} differ"));
        }
        let (ca, cb) = (a.column(name).unwrap(), b.column(name).unwrap());
        match (ca, cb) {
            (Column::F64(x), Column::F64(y)) => {
                for (i, (u, v)) in x.iter().zip(y).enumerate() {
                    let same = (u.is_nan() && v.is_nan())
                        || (u - v).abs() <= 1e-9 * (1.0 + u.abs());
                    if !same {
                        return Err(format!("{label}: {name}[{i}] {u} vs {v}"));
                    }
                }
            }
            _ => {
                if ca != cb {
                    return Err(format!("{label}: column {name} differs"));
                }
            }
        }
    }
    Ok(())
}

fn key_column(rng: &mut Rng, dtype: u8, n: usize, cardinality: i64) -> Column {
    match dtype {
        0 => Column::I64((0..n).map(|_| rng.i64_range(0, cardinality)).collect()),
        1 => Column::Bool((0..n).map(|_| rng.bool(0.5)).collect()),
        _ => Column::Str(
            (0..n)
                .map(|_| format!("k{}", rng.i64_range(0, cardinality)))
                .collect(),
        ),
    }
}

#[test]
fn prop_outer_joins_preserve_dtype_and_engines_agree() {
    forall_cases(
        "outer-join-3way-nulls",
        10,
        |rng| {
            let kdt = rng.usize(3) as u8;
            let nl = 20 + rng.usize(60);
            let nr = 10 + rng.usize(40);
            // left: key + I64 payload with ~20% nulls
            let lkey = key_column(rng, kdt, nl, 8);
            let lpay = Column::I64((0..nl).map(|_| rng.i64_range(0, 100)).collect());
            let lmask = ValidityMask::from_bools(&gen::mask(rng, nl, 0.8));
            // right: key (same dtype) + Bool payload, fully valid
            let rkey = key_column(rng, kdt, nr, 8);
            let rpay = Column::Bool((0..nr).map(|_| rng.bool(0.5)).collect());
            let l = Table::from_pairs(vec![("k", lkey), ("lv", lpay)])
                .unwrap()
                .with_null_mask("lv", lmask)
                .unwrap();
            let r = Table::from_pairs(vec![("rk", rkey), ("rv", rpay)]).unwrap();
            let how = *rng.choose(&[JoinType::Left, JoinType::Right, JoinType::Outer]);
            (l, r, how)
        },
        |(l, r, how)| {
            let kdt = l.schema().dtype_of("k").unwrap();
            // canonical order: nulls-first multi-key sort over every column
            // that is a groupable dtype (payloads included so the row order
            // is fully determined)
            let canon: &[(&str, SortOrder)] = &[
                ("k", SortOrder::Asc),
                ("lv", SortOrder::Asc),
                ("rv", SortOrder::Asc),
            ];
            let hf2 = HiFrames::with_workers(2);
            let hf3 = HiFrames::with_workers(3);
            let mut collected = Vec::new();
            for hf in [&hf2, &hf3] {
                let t = hf
                    .table("l", l.clone())
                    .join_on(&hf.table("r", r.clone()), &[("k", "rk")], *how)
                    .collect()
                    .map_err(|e| e.to_string())?
                    .sorted_by_keys(canon)
                    .map_err(|e| e.to_string())?;
                collected.push(t);
            }
            let srl = serial::join_on(l, r, &[("k", "rk")], *how)
                .map_err(|e| e.to_string())?
                .sorted_by_keys(canon)
                .map_err(|e| e.to_string())?;
            let eng = SparkLike::new(2, 3);
            let spk = eng
                .collect(
                    &eng.join_on(
                        &eng.parallelize(l),
                        &eng.parallelize(r),
                        &[("k", "rk")],
                        *how,
                    )
                    .map_err(|e| e.to_string())?,
                )
                .map_err(|e| e.to_string())?
                .sorted_by_keys(canon)
                .map_err(|e| e.to_string())?;
            // acceptance: native dtypes everywhere, no F64 promotion
            for t in collected.iter().chain([&srl, &spk]) {
                if t.schema().dtype_of("k") != Some(kdt) {
                    return Err(format!("{how:?}: key dtype changed"));
                }
                if t.schema().dtype_of("lv") != Some(DType::I64) {
                    return Err(format!("{how:?}: left payload promoted"));
                }
                if t.schema().dtype_of("rv") != Some(DType::Bool) {
                    return Err(format!("{how:?}: right payload promoted"));
                }
            }
            tables_identical(&collected[0], &srl, &format!("{how:?} w=2 vs serial"))?;
            tables_identical(&collected[1], &srl, &format!("{how:?} w=3 vs serial"))?;
            tables_identical(&srl, &spk, &format!("{how:?} serial vs sparklike"))
        },
    );
}

#[test]
fn prop_nullable_keys_route_and_group_consistently() {
    // nullable *key* columns: the null group must agree across engines and
    // across worker counts, for join and aggregate alike
    forall_cases(
        "nullable-keys-3way",
        10,
        |rng| {
            let n = 20 + rng.usize(60);
            let key = Column::I64((0..n).map(|_| rng.i64_range(0, 6)).collect());
            let kmask = ValidityMask::from_bools(&gen::mask(rng, n, 0.85));
            let x = Column::F64((0..n).map(|_| rng.normal()).collect());
            Table::from_pairs(vec![("k", key), ("x", x)])
                .unwrap()
                .with_null_mask("k", kmask)
                .unwrap()
        },
        |t| {
            let aggs = vec![
                AggExpr::new("n", AggFn::Count, col("x")),
                AggExpr::new("s", AggFn::Sum, col("x")),
            ];
            let canon: &[(&str, SortOrder)] = &[("k", SortOrder::Asc)];
            let srl = serial::aggregate_by(t, &["k"], &aggs)
                .map_err(|e| e.to_string())?
                .sorted_by_keys(canon)
                .map_err(|e| e.to_string())?;
            for workers in [2usize, 3] {
                let hf = HiFrames::with_workers(workers);
                let ours = hf
                    .table("t", t.clone())
                    .aggregate_by(&["k"], aggs.clone())
                    .collect()
                    .map_err(|e| e.to_string())?
                    .sorted_by_keys(canon)
                    .map_err(|e| e.to_string())?;
                tables_identical(&ours, &srl, &format!("agg w={workers} vs serial"))?;
            }
            let eng = SparkLike::new(2, 3);
            let spk = eng
                .collect(
                    &eng.aggregate_by(&eng.parallelize(t), &["k"], &aggs)
                        .map_err(|e| e.to_string())?,
                )
                .map_err(|e| e.to_string())?
                .sorted_by_keys(canon)
                .map_err(|e| e.to_string())?;
            tables_identical(&srl, &spk, "agg serial vs sparklike")?;
            // the null-key group exists iff the input had null keys, and it
            // sorts first
            if t.null_count("k") > 0 {
                if srl.null_count("k") != 1 {
                    return Err("expected exactly one null-key group".into());
                }
                if srl.mask("k").unwrap().get(0) {
                    return Err("null group must sort first".into());
                }
            }
            // a self-join over the nullable key: null keys match null keys,
            // identically across engines
            let j_srl = serial::join_on(
                t,
                &Table::from_pairs(vec![
                    ("rk", t.column("k").unwrap().clone()),
                    ("y", t.column("x").unwrap().clone()),
                ])
                .map_err(|e| e.to_string())?
                .with_null_mask(
                    "rk",
                    t.mask("k")
                        .cloned()
                        .unwrap_or_else(|| ValidityMask::new_valid(t.num_rows())),
                )
                .map_err(|e| e.to_string())?,
                &[("k", "rk")],
                JoinType::Inner,
            )
            .map_err(|e| e.to_string())?;
            let nulls = t.null_count("k");
            let null_matches = j_srl.null_count("k");
            // every null left row matches every null right row
            if null_matches != nulls * nulls {
                return Err(format!(
                    "null-key join produced {null_matches} rows for {nulls} nulls"
                ));
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// (c) packed ordering: nulls first, packed == KeyRow
// ---------------------------------------------------------------------------

#[test]
fn prop_nullable_packed_keys_order_nulls_first_like_key_rows() {
    forall_cases(
        "nullable-packed-vs-keyrow",
        32,
        |rng| {
            let n = 1 + rng.usize(40);
            let ncols = 1 + rng.usize(3);
            let mut cols = Vec::new();
            let mut masks = Vec::new();
            for _ in 0..ncols {
                let dt = rng.usize(3) as u8;
                let mut c = key_column(rng, dt, n, 5);
                let m = if rng.bool(0.7) {
                    let m = ValidityMask::from_bools(&gen::mask(rng, n, 0.7));
                    scrub_invalid(&mut c, &m);
                    Some(m)
                } else {
                    None
                };
                cols.push(c);
                masks.push(m);
            }
            (cols, masks)
        },
        |(cols, masks)| {
            let crefs: Vec<&Column> = cols.iter().collect();
            let mrefs: Vec<Option<&ValidityMask>> =
                masks.iter().map(|m| m.as_ref()).collect();
            let packed =
                PackedKeys::pack_nullable(&crefs, &mrefs).map_err(|e| e.to_string())?;
            let rows = key_rows_nullable(&crefs, &mrefs).map_err(|e| e.to_string())?;
            let n = rows.len();
            for i in 0..n {
                for j in 0..n {
                    let pc = packed.cmp_rows(i, &packed, j);
                    let rc = cmp_key_rows(&rows[i], &rows[j], &[]);
                    if pc != rc {
                        return Err(format!("cmp({i},{j}): packed {pc:?} vs keyrow {rc:?}"));
                    }
                    if packed.eq_rows(i, &packed, j) != (rows[i] == rows[j]) {
                        return Err(format!("eq({i},{j}) disagrees"));
                    }
                    if rows[i] == rows[j]
                        && packed.hash_row(i) != packed.hash_row(j)
                    {
                        return Err(format!("hash({i},{j}) differs for equal tuples"));
                    }
                }
            }
            // nulls-first: any row with a null first cell sorts ≤ every row
            // with a valid first cell when the remaining cells tie is
            // covered by cmp parity above; check the direct statement too
            for i in 0..n {
                for j in 0..n {
                    if rows[i][0].is_null() && !rows[j][0].is_null() {
                        let by_first = rows[i][0].cmp(&rows[j][0]);
                        if by_first != std::cmp::Ordering::Less {
                            return Err("null first cell must order first".into());
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// aggregation null-skipping + nullable outputs across ≥2 workers
// ---------------------------------------------------------------------------

#[test]
fn aggregate_skips_null_inputs_and_nullable_outputs_agree() {
    // keys 0..4; values null whenever value % 3 == 0; group 4 is entirely
    // null so mean/min come back NULL while sum/count collapse to 0
    let n = 40usize;
    let keys = Column::I64((0..n as i64).map(|i| i % 5).collect());
    let vals = Column::I64((0..n as i64).map(|i| i * 7 % 23).collect());
    let vmask = ValidityMask::from_bools(
        &(0..n as i64)
            .map(|i| (i * 7 % 23) % 3 != 0 && i % 5 != 4)
            .collect::<Vec<_>>(),
    );
    let t = Table::from_pairs(vec![("k", keys), ("v", vals)])
        .unwrap()
        .with_null_mask("v", vmask)
        .unwrap();
    let aggs = vec![
        AggExpr::new("n", AggFn::Count, col("v")),
        AggExpr::new("s", AggFn::Sum, col("v")),
        AggExpr::new("m", AggFn::Mean, col("v")),
        AggExpr::new("lo", AggFn::Min, col("v")),
    ];
    let canon: &[(&str, SortOrder)] = &[("k", SortOrder::Asc)];
    let srl = serial::aggregate_by(&t, &["k"], &aggs)
        .unwrap()
        .sorted_by_keys(canon)
        .unwrap();
    // count counts only valid rows
    let counts = srl.column("n").unwrap().as_i64();
    let valid_total: i64 = counts.iter().sum();
    assert_eq!(valid_total as usize, n - t.null_count("v"));
    // group 4: all inputs null → count 0, sum 0, mean/min NULL
    assert_eq!(counts[4], 0);
    assert_eq!(srl.column("s").unwrap().as_i64()[4], 0);
    assert!(!srl.mask("m").unwrap().get(4), "mean of all-null group is NULL");
    assert!(!srl.mask("lo").unwrap().get(4), "min of all-null group is NULL");
    assert_eq!(srl.schema().dtype_of("lo"), Some(DType::I64), "min keeps I64");
    for workers in [2usize, 3] {
        let hf = HiFrames::with_workers(workers);
        let ours = hf
            .table("t", t.clone())
            .aggregate_by(&["k"], aggs.clone())
            .collect()
            .unwrap()
            .sorted_by_keys(canon)
            .unwrap();
        tables_identical(&ours, &srl, &format!("workers={workers}")).unwrap();
    }
    let eng = SparkLike::new(2, 3);
    let spk = eng
        .collect(&eng.aggregate_by(&eng.parallelize(&t), &["k"], &aggs).unwrap())
        .unwrap()
        .sorted_by_keys(canon)
        .unwrap();
    tables_identical(&srl, &spk, "serial vs sparklike").unwrap();
}

// ---------------------------------------------------------------------------
// three-valued boolean logic (Kleene): TRUE OR NULL = TRUE
// ---------------------------------------------------------------------------

#[test]
fn kleene_or_keeps_rows_selected_by_is_null() {
    // ids 0..12; right covers multiples of 3 with w = id*100 → w is null
    // elsewhere. The classic idiom `w.is_null() || w > 500` must keep BOTH
    // the null rows and the big-w rows (TRUE OR NULL = TRUE); the naive
    // null-propagating OR would drop every null row.
    let l = Table::from_pairs(vec![("id", Column::I64((0..12).collect()))]).unwrap();
    let r = Table::from_pairs(vec![
        ("rid", Column::I64((0..12).filter(|i| i % 3 == 0).collect())),
        (
            "w",
            Column::I64((0..12).filter(|i| i % 3 == 0).map(|i| i * 100).collect()),
        ),
    ])
    .unwrap();
    let pred = col("w").is_null().or(col("w").gt(lit(500i64)));
    let expect: Vec<i64> = (0..12)
        .filter(|i| i % 3 != 0 || i * 100 > 500)
        .collect();
    // serial
    let joined = serial::join_on(&l, &r, &[("id", "rid")], JoinType::Left).unwrap();
    let srl = serial::filter(&joined, &pred)
        .unwrap()
        .sorted_by("id")
        .unwrap();
    assert_eq!(srl.column("id").unwrap().as_i64(), expect.as_slice());
    // distributed, ≥2 workers
    for workers in [2usize, 3] {
        let hf = HiFrames::with_workers(workers);
        let ours = hf
            .table("l", l.clone())
            .join_on(&hf.table("r", r.clone()), &[("id", "rid")], JoinType::Left)
            .filter(pred.clone())
            .sort_by("id")
            .collect()
            .unwrap();
        assert_eq!(
            ours.column("id").unwrap().as_i64(),
            expect.as_slice(),
            "workers={workers}"
        );
    }
    // sparklike row engine
    let eng = SparkLike::new(2, 3);
    let jr = eng
        .join_on(
            &eng.parallelize(&l),
            &eng.parallelize(&r),
            &[("id", "rid")],
            JoinType::Left,
        )
        .unwrap();
    let spk = eng
        .collect(&eng.filter(&jr, &pred).unwrap())
        .unwrap()
        .sorted_by("id")
        .unwrap();
    assert_eq!(spk.column("id").unwrap().as_i64(), expect.as_slice());
    // and FALSE AND NULL = FALSE: the dual must drop every row without
    // erroring (dominant false short-circuits the null)
    let none = serial::filter(
        &joined,
        &col("id").lt(lit(0i64)).and(col("w").gt(lit(0i64))),
    )
    .unwrap();
    assert_eq!(none.num_rows(), 0);
}

// ---------------------------------------------------------------------------
// frame-level APIs survive the distributed path
// ---------------------------------------------------------------------------

#[test]
fn fill_drop_is_null_roundtrip_distributed() {
    let l = Table::from_pairs(vec![("id", Column::I64((0..30).collect()))]).unwrap();
    let r = Table::from_pairs(vec![
        ("rid", Column::I64((0..30).filter(|i| i % 4 == 0).collect())),
        (
            "w",
            Column::Str(
                (0..30)
                    .filter(|i| i % 4 == 0)
                    .map(|i| format!("w{i}"))
                    .collect(),
            ),
        ),
    ])
    .unwrap();
    for workers in [2usize, 3] {
        let hf = HiFrames::with_workers(workers);
        let joined = hf
            .table("l", l.clone())
            .join_on(&hf.table("r", r.clone()), &[("id", "rid")], JoinType::Left);
        let out = joined.sort_by("id").collect().unwrap();
        assert_eq!(out.schema().dtype_of("w"), Some(DType::Str));
        assert_eq!(out.null_count("w"), 30 - 8);
        let filled = joined.fill_null("w", "?").sort_by("id").collect().unwrap();
        assert_eq!(filled.null_count("w"), 0);
        assert_eq!(filled.column("w").unwrap().as_str_col()[1], "?");
        let dropped = joined.drop_null(&["w"]).collect().unwrap();
        assert_eq!(dropped.num_rows(), 8);
        let probed = joined.is_null("w").sort_by("id").collect().unwrap();
        let flags = probed.column("w_is_null").unwrap().as_bool();
        for (i, f) in flags.iter().enumerate() {
            assert_eq!(*f, i % 4 != 0, "row {i}");
        }
    }
}
