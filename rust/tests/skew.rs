//! Skew-aware join coverage: three-way engine agreement (HiFrames SPMD with
//! the broadcast path active vs the serial and sparklike baselines, which
//! know nothing about strategies) on Zipf-distributed keys, including a
//! nullable heavy key, across ≥2 workers and every join type; plus the
//! end-to-end planner auto-selection.

use hiframes::baseline::{serial, sparklike::SparkLike};
use hiframes::datagen::{Rng, Zipf};
use hiframes::prelude::*;

/// Probe-side table with Zipf(`alpha`) keys over `key_range` values; every
/// `null_every`-th key is NULL (0 disables), so with a small `null_every`
/// the null tuple is itself a heavy hitter.
fn zipf_left(
    n: usize,
    key_range: usize,
    alpha: f64,
    null_every: usize,
    seed: u64,
) -> Table {
    let mut rng = Rng::new(seed);
    let zipf = Zipf::new(key_range, alpha);
    let mut keys = Vec::with_capacity(n);
    let mut valid = Vec::with_capacity(n);
    let mut pay = Vec::with_capacity(n);
    for i in 0..n {
        if null_every > 0 && i % null_every == 0 {
            keys.push(0);
            valid.push(false);
        } else {
            keys.push(zipf.sample(&mut rng) as i64);
            valid.push(true);
        }
        pay.push(i as i64);
    }
    let t = Table::from_pairs(vec![
        ("id", Column::I64(keys)),
        ("v", Column::I64(pay)),
    ])
    .unwrap();
    if null_every > 0 {
        t.with_null_mask("id", ValidityMask::from_bools(&valid)).unwrap()
    } else {
        t
    }
}

/// Build-side dimension: one row per key in `0..key_range/2` (so the upper
/// half of the probe keys goes unmatched), plus one NULL-keyed row that
/// must meet the probe side's null keys (null == null).
fn dim_right(key_range: usize) -> Table {
    let ids: Vec<i64> = (0..key_range as i64 / 2).collect();
    let mut keys = ids.clone();
    keys.push(0); // value slot under the null bit holds the dtype default
    let mut w: Vec<i64> = ids.iter().map(|k| k * 100).collect();
    w.push(-7);
    let mut valid = vec![true; ids.len()];
    valid.push(false);
    Table::from_pairs(vec![("rid", Column::I64(keys)), ("w", Column::I64(w))])
        .unwrap()
        .with_null_mask("rid", ValidityMask::from_bools(&valid))
        .unwrap()
}

/// Order-free row comparison form: the debug print of every typed row
/// (nulls surface as `Value::Null`), sorted. Engines may emit equal-key
/// groups in different orders, so relations compare as multisets.
fn rows_multiset(t: &Table) -> Vec<String> {
    let mut rows: Vec<String> = (0..t.num_rows())
        .map(|i| format!("{:?}", t.row(i)))
        .collect();
    rows.sort();
    rows
}

#[test]
fn zipf_joins_three_way_agreement_with_forced_skew() {
    // Zipf(1.5) over 40 keys with 20 % nulls: the top key, the runner-up
    // and the null tuple all clear the 5 % hint threshold
    let l = zipf_left(600, 40, 1.5, 5, 3);
    let r = dim_right(40);
    for how in [
        JoinType::Inner,
        JoinType::Left,
        JoinType::Right,
        JoinType::Outer,
        JoinType::Semi,
        JoinType::Anti,
    ] {
        for workers in [2usize, 3] {
            let hf = HiFrames::with_workers(workers);
            let ours = hf
                .table("l", l.clone())
                .join_with(&hf.table("r", r.clone()))
                .on("id", "rid")
                .how(how)
                .skew_hint(0.05)
                .build()
                .collect()
                .unwrap();
            let srl = serial::join_on(&l, &r, &[("id", "rid")], how).unwrap();
            let eng = SparkLike::new(2, workers + 1);
            let spk = eng
                .collect(
                    &eng.join_on(
                        &eng.parallelize(&l),
                        &eng.parallelize(&r),
                        &[("id", "rid")],
                        how,
                    )
                    .unwrap(),
                )
                .unwrap();
            assert_eq!(ours.schema().names(), srl.schema().names(), "{how:?}");
            assert!(ours.num_rows() > 0, "{how:?}: empty result");
            assert_eq!(
                rows_multiset(&ours),
                rows_multiset(&srl),
                "{how:?} workers={workers}: hiframes (skew) vs serial"
            );
            assert_eq!(
                rows_multiset(&srl),
                rows_multiset(&spk),
                "{how:?} workers={workers}: serial vs sparklike"
            );
        }
    }
}

#[test]
fn planner_auto_skew_matches_serial_end_to_end() {
    use hiframes::passes::{optimize, PassOptions};
    // 2000 rows clears the planner's row floor; Zipf(1.5) clears its share
    // threshold — the default pipeline must flip the join on its own
    let l = zipf_left(2000, 60, 1.5, 0, 8);
    let r = dim_right(60);
    let hf = HiFrames::with_workers(3);
    let frame = hf.table("l", l.clone()).join_on(
        &hf.table("r", r.clone()),
        &[("id", "rid")],
        JoinType::Left,
    );
    let optimized =
        optimize(frame.plan().clone(), &PassOptions::default()).unwrap();
    assert!(
        format!("{optimized}").contains("skew-broadcast"),
        "planner did not flip:\n{optimized}"
    );
    let ours = frame.collect().unwrap();
    let srl = serial::join_on(&l, &r, &[("id", "rid")], JoinType::Left).unwrap();
    assert_eq!(ours.num_rows(), srl.num_rows());
    assert_eq!(ours.num_rows(), 2000, "left join keeps every probe row");
    assert_eq!(rows_multiset(&ours), rows_multiset(&srl));
}
