//! Property suite for the arena-graph executor: diamond-shaped plans and
//! self-joins must agree across all three engines at ≥2 workers, the
//! shared-subplan memo must execute each hash-consed arm exactly once per
//! rank (exact reuse-counter assertions), and the cost-based join-reorder
//! pass must be byte-identical to the unreordered plan everywhere.

use hiframes::baseline::sparklike::SparkLike;
use hiframes::datagen::Rng;
use hiframes::exec::{collect_serial, collect_stats, ExecOptions};
use hiframes::passes::optimize_graph;
use hiframes::prelude::*;
use hiframes::prop::forall_cases;
use hiframes::types::{JoinType, SortOrder};

/// Random all-integer table (exact equality across engines, no float eps).
fn random_table(rng: &mut Rng, n: usize, key_range: i64) -> Table {
    Table::from_pairs(vec![
        (
            "id",
            Column::I64((0..n).map(|_| rng.i64_range(0, key_range)).collect()),
        ),
        (
            "x",
            Column::I64((0..n).map(|_| rng.i64_range(-50, 50)).collect()),
        ),
    ])
    .unwrap()
}

fn canon(t: &Table, keys: &[&str]) -> Table {
    let ks: Vec<(&str, SortOrder)> = keys.iter().map(|k| (*k, SortOrder::Asc)).collect();
    t.sorted_by_keys(&ks).unwrap()
}

#[test]
fn prop_diamond_three_engines_agree_and_share_once() {
    forall_cases(
        "graph-diamond-3way",
        10,
        |rng| {
            let n = 40 + rng.usize(200);
            (random_table(rng, n, 20), rng.i64_range(-20, 20))
        },
        |(t, thr)| {
            let pred = col("x").lt(lit(*thr));
            // diamond: one filter arm consumed twice — directly as the join
            // probe and through a with_columns/select chain as the build
            for workers in [2usize, 3] {
                let hf = HiFrames::with_workers(workers);
                let d = hf.table("t", t.clone());
                let shared = d.filter(pred.clone());
                let right = shared
                    .with_columns(&[("rid", col("id")), ("y", col("x"))])
                    .select(&["rid", "y"]);
                let q = shared.join_on(&right, &[("id", "rid")], JoinType::Inner);
                let plan = q.plan().clone();

                let opts = ExecOptions {
                    workers,
                    ..Default::default()
                };
                let (ours, stats) =
                    collect_stats(plan.clone(), &opts).map_err(|e| e.to_string())?;
                // the filter arm has exactly two consumers, so each rank
                // re-fetches it exactly once: reuse == workers
                if stats.reuse_hits != workers as u64 {
                    return Err(format!(
                        "workers={workers}: expected {workers} reuse hits, got {stats:?}"
                    ));
                }

                // dedup off executes the duplicated arm again: no reuse,
                // strictly more nodes
                let mut raw = opts.clone();
                raw.passes.dedup_subplans = false;
                let (raw_out, raw_stats) =
                    collect_stats(plan.clone(), &raw).map_err(|e| e.to_string())?;
                if raw_stats.reuse_hits != 0 {
                    return Err(format!("dedup off but reuse {raw_stats:?}"));
                }
                if raw_stats.nodes_executed <= stats.nodes_executed {
                    return Err(format!(
                        "dedup saved nothing: {stats:?} vs {raw_stats:?}"
                    ));
                }

                // three-engine agreement (exact: all-i64 columns)
                let srl = collect_serial(plan.clone()).map_err(|e| e.to_string())?;
                let eng = SparkLike::new(2, workers + 1);
                let f = eng
                    .filter(&eng.parallelize(t), &pred)
                    .map_err(|e| e.to_string())?;
                let r = eng
                    .with_columns(&f, &[("rid", col("id")), ("y", col("x"))])
                    .and_then(|r| eng.select(&r, &["rid", "y"]))
                    .map_err(|e| e.to_string())?;
                let spk = eng
                    .join_on(&f, &r, &[("id", "rid")], JoinType::Inner)
                    .and_then(|j| eng.collect(&j))
                    .map_err(|e| e.to_string())?;
                let keys = ["id", "x", "y"];
                let a = canon(&ours, &keys);
                if a != canon(&raw_out, &keys) {
                    return Err(format!("workers={workers}: dedup changed the result"));
                }
                if a != canon(&srl, &keys) {
                    return Err(format!("workers={workers}: hiframes != serial"));
                }
                if a != canon(&spk, &keys) {
                    return Err(format!("workers={workers}: hiframes != sparklike"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_self_join_three_engines_agree_and_share_once() {
    forall_cases(
        "graph-selfjoin-3way",
        10,
        |rng| {
            let n = 30 + rng.usize(150);
            random_table(rng, n, 12)
        },
        |t| {
            for workers in [2usize, 3] {
                let hf = HiFrames::with_workers(workers);
                // true self-join: both join inputs are the *same* plan, so
                // hash-consing gives the join one child node used twice
                let p = hf.table("t", t.clone()).select(&["id"]);
                let q = p.join_on(&p, &[("id", "id")], JoinType::Inner);
                let plan = q.plan().clone();

                let opts = ExecOptions {
                    workers,
                    ..Default::default()
                };
                let (ours, stats) =
                    collect_stats(plan.clone(), &opts).map_err(|e| e.to_string())?;
                if stats.reuse_hits != workers as u64 {
                    return Err(format!(
                        "workers={workers}: self-join side must materialize once \
                         per rank, got {stats:?}"
                    ));
                }

                let srl = collect_serial(plan.clone()).map_err(|e| e.to_string())?;
                let eng = SparkLike::new(2, workers + 1);
                let sp = eng
                    .select(&eng.parallelize(t), &["id"])
                    .map_err(|e| e.to_string())?;
                let spk = eng
                    .join_on(&sp, &sp, &[("id", "id")], JoinType::Inner)
                    .and_then(|j| eng.collect(&j))
                    .map_err(|e| e.to_string())?;
                let a = canon(&ours, &["id"]);
                if a != canon(&srl, &["id"]) {
                    return Err(format!("workers={workers}: hiframes != serial"));
                }
                if a != canon(&spk, &["id"]) {
                    return Err(format!("workers={workers}: hiframes != sparklike"));
                }
            }
            Ok(())
        },
    );
}

/// Fixed three-way inner-join chain where the user order is pessimal: the
/// big dimension joins first. The cost pass must flip it — and flip nothing
/// about the result.
fn chain_tables() -> (Table, Table, Table) {
    let base = Table::from_pairs(vec![
        ("id", Column::I64((0..40).collect())),
        ("v", Column::I64((0..40).map(|i| i * 7).collect())),
    ])
    .unwrap();
    let big = Table::from_pairs(vec![
        ("a", Column::I64((0..300).map(|i| i % 40).collect())),
        ("av", Column::I64((0..300).collect())),
    ])
    .unwrap();
    let small = Table::from_pairs(vec![
        ("b", Column::I64((0..20).map(|i| i % 40).collect())),
        ("bv", Column::I64((0..20).collect())),
    ])
    .unwrap();
    (base, big, small)
}

#[test]
fn join_reorder_is_byte_identical_on_all_engines() {
    let (base, big, small) = chain_tables();
    let keys = ["id", "v", "av", "bv"];
    let mut golden: Option<Table> = None;
    for workers in [2usize, 3] {
        let hf = HiFrames::with_workers(workers);
        let q = hf
            .table("base", base.clone())
            .join(&hf.table("big", big.clone()), "id", "a")
            .join(&hf.table("small", small.clone()), "id", "b");
        let plan = q.plan().clone();

        let off = ExecOptions {
            workers,
            ..Default::default()
        };
        let mut on = off.clone();
        on.passes.join_reorder = true;

        // the pass really moves the small build side first…
        let g_off = optimize_graph(plan.clone(), &off.passes).unwrap();
        let g_on = optimize_graph(plan.clone(), &on.passes).unwrap();
        let pos = |g: &str, needle: &str| {
            g.lines()
                .position(|l| l.contains(needle))
                .unwrap_or_else(|| panic!("missing {needle:?} in:\n{g}"))
        };
        let (r_off, r_on) = (g_off.render(false), g_on.render(false));
        assert!(pos(&r_off, "Source(big)") < pos(&r_off, "Source(small)"));
        assert!(
            pos(&r_on, "Source(small)") < pos(&r_on, "Source(big)"),
            "join_reorder did not flip the chain:\n{r_on}"
        );
        assert!(
            r_on.contains("Project("),
            "reordered chain must restore column order:\n{r_on}"
        );

        // …and changes nothing observable: byte-identical relations across
        // reorder on/off, the serial oracle and the sparklike engine
        let t_off = canon(&hiframes::exec::collect(plan.clone(), &off).unwrap(), &keys);
        let t_on = canon(&hiframes::exec::collect(plan.clone(), &on).unwrap(), &keys);
        assert_eq!(t_off.schema().names(), vec!["id", "v", "av", "bv"]);
        assert_eq!(t_on, t_off, "workers={workers}: reorder changed the result");
        let srl = canon(&collect_serial(plan.clone()).unwrap(), &keys);
        assert_eq!(t_on, srl, "workers={workers}: reorder != serial oracle");
        let eng = SparkLike::new(2, workers + 1);
        let j1 = eng
            .join(&eng.parallelize(&base), &eng.parallelize(&big), "id", "a")
            .unwrap();
        let j2 = eng.join(&j1, &eng.parallelize(&small), "id", "b").unwrap();
        let spk = canon(&eng.collect(&j2).unwrap(), &keys);
        assert_eq!(t_on, spk, "workers={workers}: reorder != sparklike");
        // byte-identical across worker counts too
        match &golden {
            Some(g) => assert_eq!(&t_on, g, "result differs across worker counts"),
            None => golden = Some(t_on),
        }
    }
}
