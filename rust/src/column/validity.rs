//! Validity masks — the null model.
//!
//! A [`ValidityMask`] is a packed bitmap over one column's rows: bit = 1
//! means the row holds a real value, bit = 0 means NULL. This is the
//! Arrow-style representation that lets outer joins keep their native
//! dtypes (Int64 stays Int64 with a mask) instead of the former stopgap of
//! promoting to Float64 with NaN holes.
//!
//! Canonical form, relied on by the engine-agreement tests:
//! * a column that is entirely valid carries **no** mask (`None`), never an
//!   all-ones mask — [`normalize_mask`] enforces this at table boundaries;
//! * the *values* under invalid bits are always the dtype default
//!   (0 / 0.0 / false / "") — [`scrub_invalid`] enforces this after
//!   kernels run over null-filled lanes.
//!
//! Bits beyond `len` in the last word are always zero, so word-wise
//! equality, popcount and bitwise combination need no tail masking.

use super::Column;
use crate::types::Value;
use anyhow::{bail, Result};

/// Packed validity bitmap: bit i set ⇔ row i is valid (non-null).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ValidityMask {
    words: Vec<u64>,
    len: usize,
}

#[inline]
fn words_for(len: usize) -> usize {
    (len + 63) / 64
}

impl ValidityMask {
    /// All rows valid.
    pub fn new_valid(len: usize) -> ValidityMask {
        let mut m = ValidityMask {
            words: vec![u64::MAX; words_for(len)],
            len,
        };
        m.clear_tail();
        m
    }

    /// All rows null.
    pub fn new_null(len: usize) -> ValidityMask {
        ValidityMask {
            words: vec![0u64; words_for(len)],
            len,
        }
    }

    /// Build from a bool slice (`true` = valid) — one packed word per 64
    /// input bits, no per-bit set calls.
    pub fn from_bools(bits: &[bool]) -> ValidityMask {
        ValidityMask {
            words: bits.chunks(64).map(super::bool_word).collect(),
            len: bits.len(),
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Is row `i` valid?
    #[inline]
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        self.words[i / 64] >> (i % 64) & 1 == 1
    }

    /// Set row `i`'s validity.
    #[inline]
    pub fn set(&mut self, i: usize, valid: bool) {
        debug_assert!(i < self.len);
        let (w, b) = (i / 64, i % 64);
        if valid {
            self.words[w] |= 1 << b;
        } else {
            self.words[w] &= !(1 << b);
        }
    }

    /// Append one row.
    pub fn push(&mut self, valid: bool) {
        let i = self.len;
        self.len += 1;
        if self.words.len() < words_for(self.len) {
            self.words.push(0);
        }
        if valid {
            self.set(i, true);
        }
    }

    /// Number of valid rows (popcount).
    pub fn count_valid(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// Number of null rows.
    pub fn count_null(&self) -> usize {
        self.len - self.count_valid()
    }

    /// Is every row valid? (A canonical table never stores such a mask —
    /// see [`normalize_mask`].) Word-parallel: full words must be all ones,
    /// the tail word must match the tail mask exactly.
    pub fn all_valid(&self) -> bool {
        let full = self.len / 64;
        if self.words[..full].iter().any(|&w| w != u64::MAX) {
            return false;
        }
        let tail = self.len % 64;
        tail == 0 || self.words[full] == (1u64 << tail) - 1
    }

    /// Bitwise AND (null if either is null) — the null-propagation rule of
    /// element-wise kernels.
    pub fn and(&self, other: &ValidityMask) -> ValidityMask {
        assert_eq!(self.len, other.len, "validity and: length mismatch");
        ValidityMask {
            words: self
                .words
                .iter()
                .zip(&other.words)
                .map(|(a, b)| a & b)
                .collect(),
            len: self.len,
        }
    }

    /// Bitwise OR (valid if either is valid).
    pub fn or(&self, other: &ValidityMask) -> ValidityMask {
        assert_eq!(self.len, other.len, "validity or: length mismatch");
        ValidityMask {
            words: self
                .words
                .iter()
                .zip(&other.words)
                .map(|(a, b)| a | b)
                .collect(),
            len: self.len,
        }
    }

    /// Append all of `other` (vertical concatenation): word-wise shift-or
    /// instead of one push per bit. `other`'s words land at bit offset
    /// `self.len % 64`, each split across at most two destination words.
    pub fn extend(&mut self, other: &ValidityMask) {
        let shift = self.len % 64;
        self.len += other.len;
        if shift == 0 {
            self.words.extend_from_slice(&other.words);
        } else {
            for &w in &other.words {
                *self.words.last_mut().expect("shift != 0 implies a word") |= w << shift;
                self.words.push(w >> (64 - shift));
            }
        }
        // the split may have produced one spare all-tail word
        self.words.truncate(words_for(self.len));
        self.clear_tail();
    }

    /// Append `n` valid rows (word-wise run of ones).
    pub fn extend_valid(&mut self, n: usize) {
        let start = self.len;
        self.len += n;
        self.words.resize(words_for(self.len), 0);
        let mut i = start;
        while i < self.len {
            let b = i % 64;
            let take = (64 - b).min(self.len - i);
            self.words[i / 64] |= super::full_word(take) << b;
            i += take;
        }
    }

    /// Gather rows at `idx` — branch-free bit extract/deposit per index.
    pub fn take(&self, idx: &[usize]) -> ValidityMask {
        let mut words = vec![0u64; words_for(idx.len())];
        for (o, &i) in idx.iter().enumerate() {
            debug_assert!(i < self.len);
            words[o / 64] |= (self.words[i / 64] >> (i % 64) & 1) << (o % 64);
        }
        ValidityMask {
            words,
            len: idx.len(),
        }
    }

    /// Gather with optional indices: `None` entries become null — the
    /// null-introducing gather of Left/Right/Outer join output assembly.
    pub fn take_opt(&self, idx: &[Option<usize>]) -> ValidityMask {
        let mut words = vec![0u64; words_for(idx.len())];
        for (o, oi) in idx.iter().enumerate() {
            if let Some(i) = oi {
                words[o / 64] |= (self.words[i / 64] >> (i % 64) & 1) << (o % 64);
            }
        }
        ValidityMask {
            words,
            len: idx.len(),
        }
    }

    /// Keep rows where `keep` is true — the keep chunk is packed into a
    /// selection word, then only its set bits are visited (zero words cost
    /// one test) while the surviving validity bits are deposited in order.
    pub fn filter(&self, keep: &[bool]) -> ValidityMask {
        assert_eq!(keep.len(), self.len, "validity filter: length mismatch");
        let mut words = vec![0u64; self.words.len()];
        let mut out = 0usize;
        for (w, chunk) in keep.chunks(64).enumerate() {
            let mut kw = super::bool_word(chunk);
            let vw = self.words[w];
            while kw != 0 {
                let b = kw.trailing_zeros() as usize;
                kw &= kw - 1;
                words[out / 64] |= (vw >> b & 1) << (out % 64);
                out += 1;
            }
        }
        words.truncate(words_for(out));
        ValidityMask { words, len: out }
    }

    /// Contiguous sub-range `[start, start+len)` — each output word is the
    /// shift-or of (at most) two source words.
    pub fn slice(&self, start: usize, len: usize) -> ValidityMask {
        debug_assert!(start + len <= self.len);
        let nw = words_for(len);
        let (sw, shift) = (start / 64, start % 64);
        let word_at = |i: usize| self.words.get(i).copied().unwrap_or(0);
        let mut words = Vec::with_capacity(nw);
        for o in 0..nw {
            let lo = word_at(sw + o) >> shift;
            let hi = if shift == 0 {
                0
            } else {
                word_at(sw + o + 1) << (64 - shift)
            };
            words.push(lo | hi);
        }
        let mut m = ValidityMask { words, len };
        m.clear_tail();
        m
    }

    /// Expand to one bool per row (`true` = valid) — word-at-a-time shifts,
    /// no per-row bounds math.
    pub fn to_bools(&self) -> Vec<bool> {
        let mut out = Vec::with_capacity(self.len);
        for (w, &word) in self.words.iter().enumerate() {
            let n = (self.len - w * 64).min(64);
            out.extend((0..n).map(|b| word >> b & 1 == 1));
        }
        out
    }

    /// Approximate heap size in bytes.
    pub fn byte_size(&self) -> usize {
        self.words.len() * 8
    }

    /// Wire-encode: u64 row count + packed words, little-endian.
    pub fn encode(&self, buf: &mut Vec<u8>) {
        buf.extend_from_slice(&(self.len as u64).to_le_bytes());
        for w in &self.words {
            buf.extend_from_slice(&w.to_le_bytes());
        }
    }

    /// Decode a mask written by [`ValidityMask::encode`].
    pub fn decode(buf: &[u8], pos: &mut usize) -> Result<ValidityMask> {
        if *pos + 8 > buf.len() {
            bail!("validity decode: truncated length");
        }
        let mut b = [0u8; 8];
        b.copy_from_slice(&buf[*pos..*pos + 8]);
        *pos += 8;
        let len = u64::from_le_bytes(b) as usize;
        let nw = words_for(len);
        if *pos + nw * 8 > buf.len() {
            bail!("validity decode: truncated words");
        }
        let mut words = Vec::with_capacity(nw);
        for _ in 0..nw {
            let mut b = [0u8; 8];
            b.copy_from_slice(&buf[*pos..*pos + 8]);
            *pos += 8;
            words.push(u64::from_le_bytes(b));
        }
        let mut m = ValidityMask { words, len };
        m.clear_tail(); // defensive: canonical tail bits
        Ok(m)
    }

    /// Exact encoded byte size.
    pub fn encoded_size(&self) -> usize {
        8 + self.words.len() * 8
    }

    fn clear_tail(&mut self) {
        let tail = self.len % 64;
        if tail != 0 {
            if let Some(last) = self.words.last_mut() {
                *last &= (1u64 << tail) - 1;
            }
        }
    }
}

/// Canonicalize: an all-valid (or empty-presence) mask becomes `None`.
pub fn normalize_mask(mask: Option<ValidityMask>) -> Option<ValidityMask> {
    match mask {
        Some(m) if m.all_valid() => None,
        other => other,
    }
}

/// AND-combine two optional masks of equal length (`None` = all valid) —
/// the null-propagation rule for binary kernels.
pub fn combine_masks(
    a: Option<&ValidityMask>,
    b: Option<&ValidityMask>,
) -> Option<ValidityMask> {
    match (a, b) {
        (None, None) => None,
        (Some(m), None) | (None, Some(m)) => Some(m.clone()),
        (Some(x), Some(y)) => Some(x.and(y)),
    }
}

/// Append `incoming` (over `incoming_len` rows) to `acc` (over `acc_len`
/// rows), materializing an all-valid prefix/suffix only when one side has a
/// mask — the column-concatenation rule for shuffles and gathers.
pub fn extend_opt_mask(
    acc: &mut Option<ValidityMask>,
    acc_len: usize,
    incoming: Option<&ValidityMask>,
    incoming_len: usize,
) {
    match (acc.as_mut(), incoming) {
        (None, None) => {}
        (Some(a), Some(b)) => a.extend(b),
        (Some(a), None) => a.extend_valid(incoming_len),
        (None, Some(b)) => {
            let mut m = ValidityMask::new_valid(acc_len);
            m.extend(b);
            *acc = Some(m);
        }
    }
}

/// Overwrite the values under invalid bits with the dtype default, putting
/// the column in canonical form (engines must agree byte-for-byte on the
/// values of null lanes).
pub fn scrub_invalid(col: &mut Column, mask: &ValidityMask) {
    assert_eq!(col.len(), mask.len(), "scrub: length mismatch");
    match col {
        Column::I64(v) => {
            for (i, x) in v.iter_mut().enumerate() {
                if !mask.get(i) {
                    *x = 0;
                }
            }
        }
        Column::F64(v) => {
            for (i, x) in v.iter_mut().enumerate() {
                if !mask.get(i) {
                    *x = 0.0;
                }
            }
        }
        Column::Bool(v) => {
            for (i, x) in v.iter_mut().enumerate() {
                if !mask.get(i) {
                    *x = false;
                }
            }
        }
        Column::Str(v) => {
            for (i, x) in v.iter_mut().enumerate() {
                if !mask.get(i) {
                    x.clear();
                }
            }
        }
    }
}

/// A column plus its optional validity mask — the unit the relational
/// operators exchange once nulls exist. `validity: None` means every row is
/// valid (the canonical form for non-nullable data).
#[derive(Debug, Clone, PartialEq)]
pub struct NullableColumn {
    pub values: Column,
    pub validity: Option<ValidityMask>,
}

impl NullableColumn {
    /// Wrap a fully-valid column.
    pub fn from_column(values: Column) -> NullableColumn {
        NullableColumn {
            values,
            validity: None,
        }
    }

    /// Wrap with a mask (normalized: all-valid masks are dropped).
    pub fn new(values: Column, validity: Option<ValidityMask>) -> NullableColumn {
        if let Some(m) = &validity {
            assert_eq!(values.len(), m.len(), "nullable column: length mismatch");
        }
        NullableColumn {
            values,
            validity: normalize_mask(validity),
        }
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    pub fn dtype(&self) -> crate::types::DType {
        self.values.dtype()
    }

    /// Is row `i` valid?
    pub fn is_valid(&self, i: usize) -> bool {
        self.validity.as_ref().map_or(true, |m| m.get(i))
    }

    pub fn null_count(&self) -> usize {
        self.validity.as_ref().map_or(0, |m| m.count_null())
    }

    /// Row `i` as a typed value ([`Value::Null`] when invalid).
    pub fn get(&self, i: usize) -> Value {
        if self.is_valid(i) {
            self.values.get(i)
        } else {
            Value::Null(self.values.dtype())
        }
    }

    /// Borrowed `(values, mask)` view — the ops-layer argument shape.
    pub fn as_masked(&self) -> (&Column, Option<&ValidityMask>) {
        (&self.values, self.validity.as_ref())
    }
}

/// Push a possibly-null row value: nulls push the dtype default into the
/// column and clear the mask bit (the row-engine → columnar boundary).
pub fn push_nullable(col: &mut Column, mask: &mut ValidityMask, v: &Value) {
    match v {
        Value::Null(dt) => {
            debug_assert_eq!(*dt, col.dtype(), "push_nullable: dtype mismatch");
            col.push(&dt.default_value());
            mask.push(false);
        }
        other => {
            col.push(other);
            mask.push(true);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::DType;

    #[test]
    fn set_get_count() {
        let mut m = ValidityMask::new_valid(70);
        assert_eq!(m.count_valid(), 70);
        assert!(m.all_valid());
        m.set(0, false);
        m.set(69, false);
        assert!(!m.get(0) && !m.get(69) && m.get(1));
        assert_eq!(m.count_null(), 2);
        assert!(!m.all_valid());
    }

    #[test]
    fn push_and_extend_across_word_boundary() {
        let mut m = ValidityMask::new_null(0);
        for i in 0..130 {
            m.push(i % 3 == 0);
        }
        assert_eq!(m.len(), 130);
        assert_eq!(m.count_valid(), (0..130).filter(|i| i % 3 == 0).count());
        let mut a = ValidityMask::from_bools(&[true, false]);
        a.extend(&m);
        assert_eq!(a.len(), 132);
        assert!(a.get(0) && !a.get(1) && a.get(2));
    }

    #[test]
    fn bitwise_and_or() {
        let a = ValidityMask::from_bools(&[true, true, false, false]);
        let b = ValidityMask::from_bools(&[true, false, true, false]);
        assert_eq!(a.and(&b).to_bools(), vec![true, false, false, false]);
        assert_eq!(a.or(&b).to_bools(), vec![true, true, true, false]);
    }

    #[test]
    fn gather_filter_slice() {
        let m = ValidityMask::from_bools(&[true, false, true, false, true]);
        assert_eq!(m.take(&[4, 1, 0]).to_bools(), vec![true, false, true]);
        assert_eq!(
            m.take_opt(&[Some(0), None, Some(1)]).to_bools(),
            vec![true, false, false]
        );
        assert_eq!(
            m.filter(&[true, true, false, false, true]).to_bools(),
            vec![true, false, true]
        );
        assert_eq!(m.slice(1, 3).to_bools(), vec![false, true, false]);
    }

    #[test]
    fn encode_decode_roundtrip() {
        for n in [0usize, 1, 63, 64, 65, 200] {
            let m = ValidityMask::from_bools(
                &(0..n).map(|i| i % 7 != 0).collect::<Vec<_>>(),
            );
            let mut buf = Vec::new();
            m.encode(&mut buf);
            assert_eq!(buf.len(), m.encoded_size());
            let mut pos = 0;
            let back = ValidityMask::decode(&buf, &mut pos).unwrap();
            assert_eq!(pos, buf.len());
            assert_eq!(back, m, "n={n}");
        }
        // truncated buffers error
        let m = ValidityMask::new_valid(100);
        let mut buf = Vec::new();
        m.encode(&mut buf);
        for cut in [0, 4, 9, buf.len() - 1] {
            let mut pos = 0;
            assert!(ValidityMask::decode(&buf[..cut], &mut pos).is_err());
        }
    }

    #[test]
    fn normalize_and_combine() {
        assert!(normalize_mask(Some(ValidityMask::new_valid(10))).is_none());
        let m = ValidityMask::from_bools(&[true, false]);
        assert!(normalize_mask(Some(m.clone())).is_some());
        assert!(combine_masks(None, None).is_none());
        assert_eq!(combine_masks(Some(&m), None), Some(m.clone()));
        let n = ValidityMask::from_bools(&[false, true]);
        assert_eq!(
            combine_masks(Some(&m), Some(&n)).unwrap().to_bools(),
            vec![false, false]
        );
    }

    #[test]
    fn extend_opt_mask_materializes_lazily() {
        let mut acc = None;
        extend_opt_mask(&mut acc, 0, None, 3);
        assert!(acc.is_none());
        let inc = ValidityMask::from_bools(&[false, true]);
        extend_opt_mask(&mut acc, 3, Some(&inc), 2);
        let got = acc.clone().unwrap();
        assert_eq!(got.to_bools(), vec![true, true, true, false, true]);
        extend_opt_mask(&mut acc, 5, None, 1);
        assert_eq!(acc.unwrap().to_bools(), vec![true, true, true, false, true, true]);
    }

    #[test]
    fn scrub_writes_defaults() {
        let mask = ValidityMask::from_bools(&[true, false, true]);
        let mut c = Column::I64(vec![1, 2, 3]);
        scrub_invalid(&mut c, &mask);
        assert_eq!(c.as_i64(), &[1, 0, 3]);
        let mut c = Column::Str(vec!["a".into(), "b".into(), "c".into()]);
        scrub_invalid(&mut c, &mask);
        assert_eq!(c.as_str_col(), &["a".to_string(), "".into(), "c".into()]);
        let mut c = Column::F64(vec![1.0, f64::NAN, 3.0]);
        scrub_invalid(&mut c, &mask);
        assert_eq!(c.as_f64(), &[1.0, 0.0, 3.0]);
    }

    #[test]
    fn nullable_column_accessors() {
        let c = NullableColumn::new(
            Column::I64(vec![5, 0, 7]),
            Some(ValidityMask::from_bools(&[true, false, true])),
        );
        assert_eq!(c.len(), 3);
        assert_eq!(c.null_count(), 1);
        assert!(c.is_valid(0) && !c.is_valid(1));
        assert_eq!(c.get(0), Value::I64(5));
        assert_eq!(c.get(1), Value::Null(DType::I64));
        // all-valid masks normalize away
        let c = NullableColumn::new(
            Column::I64(vec![1]),
            Some(ValidityMask::new_valid(1)),
        );
        assert!(c.validity.is_none());
    }

    #[test]
    fn push_nullable_defaults_and_bits() {
        let mut col = Column::new_empty(DType::F64);
        let mut mask = ValidityMask::new_null(0);
        push_nullable(&mut col, &mut mask, &Value::F64(1.5));
        push_nullable(&mut col, &mut mask, &Value::Null(DType::F64));
        assert_eq!(col.as_f64(), &[1.5, 0.0]);
        assert_eq!(mask.to_bools(), vec![true, false]);
    }
}
