//! Vectorized numeric kernels over columns — the element-wise operations
//! ParallelAccelerator recognizes as having *map* semantics (paper §2.4:
//! `.+`, `.<`, `log`, `exp`, `sin`, …) plus the reductions used by
//! aggregate decomposition (`sum`, `count`, `min`, `max`).
//!
//! These are the only place arithmetic on raw slices happens; the expression
//! evaluator dispatches here so the hot loops stay monomorphic and
//! auto-vectorizable.

use super::{Column, ValidityMask};
use crate::types::{DType, Value};
use anyhow::{bail, Result};

/// Binary arithmetic operator.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ArithOp {
    Add,
    Sub,
    Mul,
    Div,
    Mod,
}

/// Binary comparison operator.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CmpOp {
    Lt,
    Le,
    Gt,
    Ge,
    Eq,
    Ne,
}

/// Unary math function (map semantics).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MathFn {
    Log,
    Exp,
    Sqrt,
    Sin,
    Cos,
    Abs,
    Neg,
}

macro_rules! zip_arith {
    ($a:expr, $b:expr, $op:expr) => {{
        debug_assert_eq!($a.len(), $b.len());
        $a.iter()
            .zip($b.iter())
            .map(|(&x, &y)| apply_arith(x, y, $op))
            .collect()
    }};
}

#[inline(always)]
fn apply_arith<T>(x: T, y: T, op: ArithOp) -> T
where
    T: Copy
        + std::ops::Add<Output = T>
        + std::ops::Sub<Output = T>
        + std::ops::Mul<Output = T>
        + std::ops::Div<Output = T>
        + std::ops::Rem<Output = T>,
{
    match op {
        ArithOp::Add => x + y,
        ArithOp::Sub => x - y,
        ArithOp::Mul => x * y,
        ArithOp::Div => x / y,
        ArithOp::Mod => x % y,
    }
}

/// Element-wise arithmetic between two columns with Julia-style promotion.
pub fn arith(a: &Column, b: &Column, op: ArithOp) -> Column {
    match (a, b) {
        (Column::I64(x), Column::I64(y)) => Column::I64(zip_arith!(x, y, op)),
        (Column::F64(x), Column::F64(y)) => Column::F64(zip_arith!(x, y, op)),
        (Column::I64(x), Column::F64(y)) => {
            let xf: Vec<f64> = x.iter().map(|&v| v as f64).collect();
            Column::F64(zip_arith!(xf, y, op))
        }
        (Column::F64(x), Column::I64(y)) => {
            let yf: Vec<f64> = y.iter().map(|&v| v as f64).collect();
            Column::F64(zip_arith!(x, yf, op))
        }
        _ => panic!(
            "arith: unsupported dtypes {} {}",
            a.dtype(),
            b.dtype()
        ),
    }
}

/// [`arith`] where the right operand carries a validity mask — the
/// window/fill arithmetic hazard fix: an Int64 division (or modulo) by a
/// *nullable* divisor would trap on the scrubbed canonical default 0 in
/// invalid lanes. Those lanes' results are null anyway (the expression
/// layer ANDs the operand masks and re-scrubs), so the invalid divisor
/// lanes are evaluated against a neutral 1 instead of trapping. Every
/// other dtype/operator combination defers to [`arith`] unchanged —
/// including genuine division by a *valid* zero, which still traps like
/// plain Rust integer division.
pub fn arith_masked(
    a: &Column,
    b: &Column,
    op: ArithOp,
    b_valid: Option<&ValidityMask>,
) -> Column {
    if let (Column::I64(y), Some(m), ArithOp::Div | ArithOp::Mod) = (b, b_valid, op) {
        if matches!(a, Column::I64(_)) {
            debug_assert_eq!(y.len(), m.len());
            let safe: Vec<i64> = y
                .iter()
                .enumerate()
                .map(|(i, &v)| if m.get(i) { v } else { 1 })
                .collect();
            return arith(a, &Column::I64(safe), op);
        }
    }
    arith(a, b, op)
}

/// [`arith_scalar`] where the *column* operand is the divisor of an
/// integer modulo (`scalar % col` with `scalar_on_left`) and carries a
/// validity mask — the same trap as [`arith_masked`], through the scalar
/// fast path: `arith_scalar`'s Int64 route admits `Mod`, so a scrubbed
/// null 0 in the column would panic. Invalid lanes are evaluated against a
/// neutral 1 (their results are null anyway); everything else defers to
/// [`arith_scalar`]. (`scalar / col` is safe — that route goes Float64.)
pub fn arith_scalar_masked(
    a: &Column,
    s: f64,
    op: ArithOp,
    scalar_on_left: bool,
    a_valid: Option<&ValidityMask>,
) -> Column {
    if scalar_on_left && op == ArithOp::Mod && s.fract() == 0.0 {
        if let (Column::I64(y), Some(m)) = (a, a_valid) {
            debug_assert_eq!(y.len(), m.len());
            let safe: Vec<i64> = y
                .iter()
                .enumerate()
                .map(|(i, &v)| if m.get(i) { v } else { 1 })
                .collect();
            return arith_scalar(&Column::I64(safe), s, op, true);
        }
    }
    arith_scalar(a, s, op, scalar_on_left)
}

/// Arithmetic against a scalar (broadcast) — the "simple mathematical
/// operators instead of element-wise operators" sugar of paper §3.1.
pub fn arith_scalar(a: &Column, s: f64, op: ArithOp, scalar_on_left: bool) -> Column {
    match a {
        Column::I64(x) if s.fract() == 0.0 && op != ArithOp::Div => {
            let si = s as i64;
            Column::I64(
                x.iter()
                    .map(|&v| {
                        if scalar_on_left {
                            apply_arith(si, v, op)
                        } else {
                            apply_arith(v, si, op)
                        }
                    })
                    .collect(),
            )
        }
        Column::I64(x) => Column::F64(
            x.iter()
                .map(|&v| {
                    let v = v as f64;
                    if scalar_on_left {
                        apply_arith(s, v, op)
                    } else {
                        apply_arith(v, s, op)
                    }
                })
                .collect(),
        ),
        Column::F64(x) => Column::F64(
            x.iter()
                .map(|&v| {
                    if scalar_on_left {
                        apply_arith(s, v, op)
                    } else {
                        apply_arith(v, s, op)
                    }
                })
                .collect(),
        ),
        _ => panic!("arith_scalar: unsupported dtype {}", a.dtype()),
    }
}

#[inline(always)]
fn apply_cmp<T: PartialOrd>(x: T, y: T, op: CmpOp) -> bool {
    match op {
        CmpOp::Lt => x < y,
        CmpOp::Le => x <= y,
        CmpOp::Gt => x > y,
        CmpOp::Ge => x >= y,
        CmpOp::Eq => x == y,
        CmpOp::Ne => x != y,
    }
}

/// Element-wise comparison producing a boolean mask (filter expressions).
pub fn compare(a: &Column, b: &Column, op: CmpOp) -> Column {
    assert_eq!(a.len(), b.len(), "compare: length mismatch");
    let mask: Vec<bool> = match (a, b) {
        (Column::I64(x), Column::I64(y)) => {
            x.iter().zip(y).map(|(&u, &v)| apply_cmp(u, v, op)).collect()
        }
        (Column::F64(x), Column::F64(y)) => {
            x.iter().zip(y).map(|(&u, &v)| apply_cmp(u, v, op)).collect()
        }
        (Column::I64(x), Column::F64(y)) => x
            .iter()
            .zip(y)
            .map(|(&u, &v)| apply_cmp(u as f64, v, op))
            .collect(),
        (Column::F64(x), Column::I64(y)) => x
            .iter()
            .zip(y)
            .map(|(&u, &v)| apply_cmp(u, v as f64, op))
            .collect(),
        (Column::Str(x), Column::Str(y)) => {
            x.iter().zip(y).map(|(u, v)| apply_cmp(u, v, op)).collect()
        }
        (Column::Bool(x), Column::Bool(y)) => {
            x.iter().zip(y).map(|(&u, &v)| apply_cmp(u, v, op)).collect()
        }
        _ => panic!(
            "compare: unsupported dtypes {} {}",
            a.dtype(),
            b.dtype()
        ),
    };
    Column::Bool(mask)
}

/// Comparison against a scalar.
pub fn compare_scalar_f64(a: &Column, s: f64, op: CmpOp) -> Column {
    let mask: Vec<bool> = match a {
        Column::I64(x) => x.iter().map(|&v| apply_cmp(v as f64, s, op)).collect(),
        Column::F64(x) => x.iter().map(|&v| apply_cmp(v, s, op)).collect(),
        _ => panic!("compare_scalar: unsupported dtype {}", a.dtype()),
    };
    Column::Bool(mask)
}

/// String equality against a constant (TPCx-BB category filters).
pub fn compare_scalar_str(a: &Column, s: &str, op: CmpOp) -> Column {
    let v = a.as_str_col();
    let mask: Vec<bool> = match op {
        CmpOp::Eq => v.iter().map(|x| x == s).collect(),
        CmpOp::Ne => v.iter().map(|x| x != s).collect(),
        _ => v.iter().map(|x| apply_cmp(x.as_str(), s, op)).collect(),
    };
    Column::Bool(mask)
}

/// Boolean combinators for composite predicates.
pub fn bool_and(a: &Column, b: &Column) -> Column {
    let (x, y) = (a.as_bool(), b.as_bool());
    Column::Bool(x.iter().zip(y).map(|(&u, &v)| u && v).collect())
}

pub fn bool_or(a: &Column, b: &Column) -> Column {
    let (x, y) = (a.as_bool(), b.as_bool());
    Column::Bool(x.iter().zip(y).map(|(&u, &v)| u || v).collect())
}

pub fn bool_not(a: &Column) -> Column {
    Column::Bool(a.as_bool().iter().map(|&u| !u).collect())
}

/// Unary math map.
pub fn math(a: &Column, f: MathFn) -> Column {
    match a {
        Column::F64(x) => Column::F64(x.iter().map(|&v| apply_math(v, f)).collect()),
        Column::I64(x) => match f {
            MathFn::Abs => Column::I64(x.iter().map(|&v| v.abs()).collect()),
            MathFn::Neg => Column::I64(x.iter().map(|&v| -v).collect()),
            _ => Column::F64(x.iter().map(|&v| apply_math(v as f64, f)).collect()),
        },
        _ => panic!("math: unsupported dtype {}", a.dtype()),
    }
}

#[inline(always)]
fn apply_math(x: f64, f: MathFn) -> f64 {
    match f {
        MathFn::Log => x.ln(),
        MathFn::Exp => x.exp(),
        MathFn::Sqrt => x.sqrt(),
        MathFn::Sin => x.sin(),
        MathFn::Cos => x.cos(),
        MathFn::Abs => x.abs(),
        MathFn::Neg => -x,
    }
}

// ----- local reductions (the per-rank halves of distributed aggregates) ----

pub fn sum_f64(a: &Column) -> f64 {
    match a {
        Column::F64(x) => x.iter().sum(),
        Column::I64(x) => x.iter().map(|&v| v as f64).sum(),
        Column::Bool(x) => x.iter().map(|&b| b as i64 as f64).sum(),
        _ => panic!("sum: unsupported dtype {}", a.dtype()),
    }
}

pub fn min_f64(a: &Column) -> f64 {
    match a {
        Column::F64(x) => x.iter().copied().fold(f64::INFINITY, f64::min),
        Column::I64(x) => x.iter().map(|&v| v as f64).fold(f64::INFINITY, f64::min),
        _ => panic!("min: unsupported dtype {}", a.dtype()),
    }
}

pub fn max_f64(a: &Column) -> f64 {
    match a {
        Column::F64(x) => x.iter().copied().fold(f64::NEG_INFINITY, f64::max),
        Column::I64(x) => x.iter().map(|&v| v as f64).fold(f64::NEG_INFINITY, f64::max),
        _ => panic!("max: unsupported dtype {}", a.dtype()),
    }
}

pub fn mean_f64(a: &Column) -> f64 {
    if a.is_empty() {
        return f64::NAN;
    }
    sum_f64(a) / a.len() as f64
}

/// Population variance (the paper's feature-scaling `var`).
pub fn var_f64(a: &Column) -> f64 {
    if a.is_empty() {
        return f64::NAN;
    }
    let m = mean_f64(a);
    let v = a.to_f64_vec();
    v.iter().map(|&x| (x - m) * (x - m)).sum::<f64>() / v.len() as f64
}

/// Cast helper used by aggregate expression arrays: bool -> i64 (so
/// `sum(:i_class_id==1)` counts matches, per Table 1's aggregate example).
pub fn bool_to_i64(a: &Column) -> Column {
    Column::I64(a.as_bool().iter().map(|&b| b as i64).collect())
}

/// Infer the result dtype of `arith` without evaluating (expression typing).
pub fn arith_result_dtype(a: DType, b: DType) -> Option<DType> {
    a.promote(b)
}

// ----- null kernels (validity-mask aware) ----------------------------------

/// `IS NULL` as a Bool column: true where the mask bit is clear. A missing
/// mask means no row is null.
pub fn is_null_column(mask: Option<&ValidityMask>, len: usize) -> Column {
    match mask {
        Some(m) => {
            debug_assert_eq!(m.len(), len);
            // word-at-a-time expand, then flip (valid → not-null)
            let mut out = m.to_bools();
            for b in &mut out {
                *b = !*b;
            }
            Column::Bool(out)
        }
        None => Column::Bool(vec![false; len]),
    }
}

/// `fill_null(col, v)`: replace null lanes with `v`, producing a fully
/// valid column. The fill value must unify with the column dtype
/// (I64 fills may be written as integer-valued floats and vice versa).
pub fn fill_null(col: &Column, mask: Option<&ValidityMask>, v: &Value) -> Result<Column> {
    let Some(m) = mask else {
        return Ok(col.clone());
    };
    debug_assert_eq!(m.len(), col.len());
    Ok(match (col, v) {
        (Column::I64(xs), _) => {
            let Some(f) = v.as_i64() else {
                bail!("fill_null: cannot fill Int64 column with {v:?}");
            };
            Column::I64(
                xs.iter()
                    .enumerate()
                    .map(|(i, &x)| if m.get(i) { x } else { f })
                    .collect(),
            )
        }
        (Column::F64(xs), _) => {
            let Some(f) = v.as_f64() else {
                bail!("fill_null: cannot fill Float64 column with {v:?}");
            };
            Column::F64(
                xs.iter()
                    .enumerate()
                    .map(|(i, &x)| if m.get(i) { x } else { f })
                    .collect(),
            )
        }
        (Column::Bool(xs), Value::Bool(f)) => Column::Bool(
            xs.iter()
                .enumerate()
                .map(|(i, &x)| if m.get(i) { x } else { *f })
                .collect(),
        ),
        (Column::Str(xs), Value::Str(f)) => Column::Str(
            xs.iter()
                .enumerate()
                .map(|(i, x)| if m.get(i) { x.clone() } else { f.clone() })
                .collect(),
        ),
        (c, v) => bail!("fill_null: cannot fill {} column with {v:?}", c.dtype()),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arith_int_int() {
        let a = Column::I64(vec![1, 2, 3]);
        let b = Column::I64(vec![10, 20, 30]);
        assert_eq!(arith(&a, &b, ArithOp::Add).as_i64(), &[11, 22, 33]);
        assert_eq!(arith(&a, &b, ArithOp::Mul).as_i64(), &[10, 40, 90]);
        assert_eq!(arith(&b, &a, ArithOp::Mod).as_i64(), &[0, 0, 0]);
    }

    #[test]
    fn arith_promotes() {
        let a = Column::I64(vec![1, 2]);
        let b = Column::F64(vec![0.5, 0.5]);
        assert_eq!(arith(&a, &b, ArithOp::Add).as_f64(), &[1.5, 2.5]);
        assert_eq!(arith(&b, &a, ArithOp::Sub).as_f64(), &[-0.5, -1.5]);
    }

    #[test]
    fn arith_scalar_keeps_int_when_exact() {
        let a = Column::I64(vec![10, 20]);
        assert_eq!(arith_scalar(&a, 3.0, ArithOp::Mod, false).as_i64(), &[1, 2]);
        assert_eq!(
            arith_scalar(&a, 2.0, ArithOp::Div, false).as_f64(),
            &[5.0, 10.0]
        );
        // scalar on the left matters for non-commutative ops
        assert_eq!(
            arith_scalar(&a, 100.0, ArithOp::Sub, true).as_i64(),
            &[90, 80]
        );
    }

    #[test]
    fn comparisons() {
        let a = Column::I64(vec![1, 5, 9]);
        assert_eq!(
            compare_scalar_f64(&a, 5.0, CmpOp::Lt).as_bool(),
            &[true, false, false]
        );
        assert_eq!(
            compare_scalar_f64(&a, 5.0, CmpOp::Ge).as_bool(),
            &[false, true, true]
        );
        let b = Column::F64(vec![1.0, 4.0, 10.0]);
        assert_eq!(
            compare(&a, &b, CmpOp::Eq).as_bool(),
            &[true, false, false]
        );
    }

    #[test]
    fn string_compare() {
        let c = Column::Str(vec!["ab".into(), "cd".into()]);
        assert_eq!(
            compare_scalar_str(&c, "ab", CmpOp::Eq).as_bool(),
            &[true, false]
        );
        assert_eq!(
            compare_scalar_str(&c, "b", CmpOp::Lt).as_bool(),
            &[true, false]
        );
    }

    #[test]
    fn boolean_ops() {
        let a = Column::Bool(vec![true, true, false]);
        let b = Column::Bool(vec![true, false, false]);
        assert_eq!(bool_and(&a, &b).as_bool(), &[true, false, false]);
        assert_eq!(bool_or(&a, &b).as_bool(), &[true, true, false]);
        assert_eq!(bool_not(&a).as_bool(), &[false, false, true]);
    }

    #[test]
    fn math_fns() {
        let a = Column::F64(vec![1.0, 4.0]);
        assert_eq!(math(&a, MathFn::Sqrt).as_f64(), &[1.0, 2.0]);
        let b = Column::I64(vec![-3, 3]);
        assert_eq!(math(&b, MathFn::Abs).as_i64(), &[3, 3]);
        assert_eq!(math(&b, MathFn::Neg).as_i64(), &[3, -3]);
        let e = math(&Column::I64(vec![0]), MathFn::Exp);
        assert_eq!(e.as_f64(), &[1.0]);
    }

    #[test]
    fn reductions() {
        let a = Column::F64(vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(sum_f64(&a), 10.0);
        assert_eq!(mean_f64(&a), 2.5);
        assert_eq!(min_f64(&a), 1.0);
        assert_eq!(max_f64(&a), 4.0);
        assert_eq!(var_f64(&a), 1.25);
        assert_eq!(sum_f64(&Column::Bool(vec![true, false, true])), 2.0);
    }

    #[test]
    fn empty_reductions() {
        assert!(mean_f64(&Column::F64(vec![])).is_nan());
        assert!(var_f64(&Column::F64(vec![])).is_nan());
        assert_eq!(sum_f64(&Column::F64(vec![])), 0.0);
    }

    #[test]
    fn bool_cast() {
        let m = Column::Bool(vec![true, false, true]);
        assert_eq!(bool_to_i64(&m).as_i64(), &[1, 0, 1]);
    }

    #[test]
    fn masked_int_division_does_not_trap() {
        let a = Column::I64(vec![10, 20, 30]);
        let b = Column::I64(vec![2, 0, 5]); // lane 1 = scrubbed null default
        let m = ValidityMask::from_bools(&[true, false, true]);
        let q = arith_masked(&a, &b, ArithOp::Div, Some(&m));
        assert_eq!(q.as_i64(), &[5, 20, 6]); // null lane evaluated against 1
        let r = arith_masked(&a, &b, ArithOp::Mod, Some(&m));
        assert_eq!(r.as_i64(), &[0, 0, 0]);
        // no mask / non-div ops defer to the plain kernel
        let c = Column::I64(vec![2, 4, 5]);
        assert_eq!(
            arith_masked(&a, &c, ArithOp::Div, None).as_i64(),
            &[5, 5, 6]
        );
        assert_eq!(
            arith_masked(&a, &b, ArithOp::Add, Some(&m)).as_i64(),
            &[12, 20, 35]
        );
        // scalar-on-left modulo rides the Int64 fast path — same hazard
        let r = arith_scalar_masked(&b, 7.0, ArithOp::Mod, true, Some(&m));
        assert_eq!(r.as_i64(), &[1, 0, 2]); // 7%2, 7%1 (neutral), 7%5
        // scalar divisor and scalar-on-right stay on the plain kernel
        assert_eq!(
            arith_scalar_masked(&b, 2.0, ArithOp::Mod, false, Some(&m)).as_i64(),
            &[0, 0, 1]
        );
    }

    #[test]
    fn null_kernels() {
        let mask = ValidityMask::from_bools(&[true, false, true]);
        assert_eq!(
            is_null_column(Some(&mask), 3).as_bool(),
            &[false, true, false]
        );
        assert_eq!(is_null_column(None, 2).as_bool(), &[false, false]);
        // fill_null preserves dtype and fills only invalid lanes
        let c = Column::I64(vec![7, 0, 9]);
        let f = fill_null(&c, Some(&mask), &Value::I64(-1)).unwrap();
        assert_eq!(f.as_i64(), &[7, -1, 9]);
        // integer-valued fills unify across numeric dtypes
        let f = fill_null(&c, Some(&mask), &Value::F64(3.0)).unwrap();
        assert_eq!(f.as_i64(), &[7, 3, 9]);
        let s = Column::Str(vec!["a".into(), "".into(), "c".into()]);
        let f = fill_null(&s, Some(&mask), &Value::Str("?".into())).unwrap();
        assert_eq!(f.as_str_col(), &["a".to_string(), "?".into(), "c".into()]);
        // dtype mismatch errors
        assert!(fill_null(&s, Some(&mask), &Value::I64(1)).is_err());
        // no mask → clone
        assert_eq!(fill_null(&c, None, &Value::I64(0)).unwrap(), c);
    }
}
