//! Binary column codec used by the comm substrate's `alltoallv` shuffle and
//! by the HFS file format. Layout per column:
//!
//! ```text
//!   u8  dtype tag          (0=I64, 1=F64, 2=Bool, 3=Str, 4=Str dictionary)
//!   u64 row count
//!   payload:
//!     I64/F64: little-endian 8-byte values
//!     Bool:    one byte per value
//!     Str:     u32 length + UTF-8 bytes, per value
//!     StrDict: u32 dictionary entry count, then per entry u32 length +
//!              UTF-8 bytes (first-seen order), then u8 code width
//!              (1 / 2 / 4 bytes) and one little-endian code per row
//! ```
//!
//! String columns choose between the plain and dictionary frames with a
//! *deterministic size heuristic*: the dictionary frame is used exactly when
//! it is smaller than the plain frame for the rows being encoded. The choice
//! is a pure function of the encoded row sequence, so the fused take path
//! ([`encode_column_take`]) stays byte-identical to take-then-encode, and
//! every decoder works off the tag alone. Duplicate-heavy shuffle/spill
//! traffic (string join keys, group keys) ships each distinct string once
//! plus one small code per row instead of escaping the bytes per row.
//! `HIFRAMES_DICT=0` (or [`set_dict_encoding`]) disables the dictionary
//! frame for A/B runs; decode always understands both.
//!
//! The paper packs rows into per-destination MPI buffers (Fig. 5, "pack data
//! in buffers for different processors"); this codec is our wire format and
//! its cost is *measured*, not simulated — eliminating redundant copies here
//! was a §Perf item.

use super::{Column, ValidityMask};
use crate::fxhash::FxHashMap;
use anyhow::{bail, Context, Result};
use std::sync::atomic::{AtomicU8, Ordering as AtomicOrdering};
use std::sync::OnceLock;

const TAG_I64: u8 = 0;
const TAG_F64: u8 = 1;
const TAG_BOOL: u8 = 2;
const TAG_STR: u8 = 3;
const TAG_STR_DICT: u8 = 4;

/// Wire-level dictionary policy for `Str` columns.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DictEncoding {
    /// Size heuristic: dictionary frame iff it is strictly smaller.
    Auto,
    /// Always the plain frame (the pre-dictionary wire format).
    Off,
    /// Always the dictionary frame (fuzzing / width-promotion tests).
    Force,
}

/// Process-wide override; `u8::MAX` = unset, fall back to the env default.
static DICT_OVERRIDE: AtomicU8 = AtomicU8::new(u8::MAX);

fn env_dict_default() -> DictEncoding {
    static CELL: OnceLock<DictEncoding> = OnceLock::new();
    *CELL.get_or_init(
        || match crate::config::env_knob("HIFRAMES_DICT").as_deref() {
            Some("0") | Some("false") | Some("no") | Some("off") => DictEncoding::Off,
            Some("force") => DictEncoding::Force,
            _ => DictEncoding::Auto,
        },
    )
}

/// Current dictionary policy (`HIFRAMES_DICT` unless overridden).
pub fn dict_encoding() -> DictEncoding {
    match DICT_OVERRIDE.load(AtomicOrdering::Relaxed) {
        0 => DictEncoding::Auto,
        1 => DictEncoding::Off,
        2 => DictEncoding::Force,
        _ => env_dict_default(),
    }
}

/// Override the dictionary policy process-wide (A/B sweeps in tests and
/// benches). Either choice decodes identically — the tag is in the stream —
/// so flipping this mid-run can never corrupt data, only change frame sizes.
pub fn set_dict_encoding(mode: DictEncoding) {
    let v = match mode {
        DictEncoding::Auto => 0,
        DictEncoding::Off => 1,
        DictEncoding::Force => 2,
    };
    DICT_OVERRIDE.store(v, AtomicOrdering::Relaxed);
}

/// The dictionary plan for one string-row sequence: distinct strings in
/// first-seen order and the resulting frame size, or `None` when the plain
/// frame wins (or the policy says off). Pure function of (rows, mode).
struct DictPlan<'a> {
    codes: Vec<u32>,
    distinct: Vec<&'a str>,
    code_width: usize,
}

fn code_width_for(distinct: usize) -> usize {
    if distinct <= 1 << 8 {
        1
    } else if distinct <= 1 << 16 {
        2
    } else {
        4
    }
}

fn plan_str_rows<'a>(
    rows: impl Iterator<Item = &'a str>,
    mode: DictEncoding,
) -> Option<DictPlan<'a>> {
    if mode == DictEncoding::Off {
        return None;
    }
    let mut map: FxHashMap<&str, u32> = FxHashMap::default();
    let mut distinct: Vec<&str> = Vec::new();
    let mut codes: Vec<u32> = Vec::new();
    let mut plain_payload = 0usize;
    let mut distinct_payload = 0usize;
    for s in rows {
        plain_payload += 4 + s.len();
        let next = distinct.len() as u32;
        let code = *map.entry(s).or_insert_with(|| {
            distinct_payload += 4 + s.len();
            distinct.push(s);
            next
        });
        codes.push(code);
    }
    let code_width = code_width_for(distinct.len());
    // dict frame = u32 entry count + entries + u8 code width + codes
    let dict_payload = 4 + distinct_payload + 1 + codes.len() * code_width;
    if mode == DictEncoding::Force || dict_payload < plain_payload {
        Some(DictPlan {
            codes,
            distinct,
            code_width,
        })
    } else {
        None
    }
}

/// Exact encoded byte size (used to pre-size send buffers in one pass).
/// For `Str` columns this runs the same deterministic dictionary heuristic
/// as [`encode_column`], so the size stays exact under either frame.
pub fn encoded_size(col: &Column) -> usize {
    9 + match col {
        Column::I64(v) => v.len() * 8,
        Column::F64(v) => v.len() * 8,
        Column::Bool(v) => v.len(),
        Column::Str(v) => match plan_str_rows(v.iter().map(|s| s.as_str()), dict_encoding()) {
            Some(p) => {
                4 + p.distinct.iter().map(|s| 4 + s.len()).sum::<usize>()
                    + 1
                    + p.codes.len() * p.code_width
            }
            None => v.iter().map(|s| 4 + s.len()).sum(),
        },
    }
}

/// Write the string rows as either a plain or dictionary frame (tag + row
/// count included) according to `plan`.
fn encode_str_rows<'a>(
    n: usize,
    rows: impl Iterator<Item = &'a str>,
    plan: Option<DictPlan<'a>>,
    buf: &mut Vec<u8>,
) {
    match plan {
        Some(p) => {
            buf.push(TAG_STR_DICT);
            buf.extend_from_slice(&(n as u64).to_le_bytes());
            buf.extend_from_slice(&(p.distinct.len() as u32).to_le_bytes());
            for s in &p.distinct {
                buf.extend_from_slice(&(s.len() as u32).to_le_bytes());
                buf.extend_from_slice(s.as_bytes());
            }
            buf.push(p.code_width as u8);
            for &c in &p.codes {
                buf.extend_from_slice(&c.to_le_bytes()[..p.code_width]);
            }
        }
        None => {
            buf.push(TAG_STR);
            buf.extend_from_slice(&(n as u64).to_le_bytes());
            for s in rows {
                buf.extend_from_slice(&(s.len() as u32).to_le_bytes());
                buf.extend_from_slice(s.as_bytes());
            }
        }
    }
}

/// Append the encoding of `col` to `buf` under the current dictionary
/// policy ([`dict_encoding`]).
pub fn encode_column(col: &Column, buf: &mut Vec<u8>) {
    encode_column_with(col, dict_encoding(), buf)
}

/// [`encode_column`] with an explicit dictionary policy — lets the fuzz
/// suite and benches compare frames without touching process-global state.
pub fn encode_column_with(col: &Column, mode: DictEncoding, buf: &mut Vec<u8>) {
    match col {
        Column::I64(v) => {
            buf.reserve(9 + v.len() * 8);
            buf.push(TAG_I64);
            buf.extend_from_slice(&(v.len() as u64).to_le_bytes());
            // Bulk-copy the raw words; i64 -> LE bytes is a no-op transmute
            // on little-endian targets but we keep it portable.
            for x in v {
                buf.extend_from_slice(&x.to_le_bytes());
            }
        }
        Column::F64(v) => {
            buf.reserve(9 + v.len() * 8);
            buf.push(TAG_F64);
            buf.extend_from_slice(&(v.len() as u64).to_le_bytes());
            for x in v {
                buf.extend_from_slice(&x.to_le_bytes());
            }
        }
        Column::Bool(v) => {
            buf.reserve(9 + v.len());
            buf.push(TAG_BOOL);
            buf.extend_from_slice(&(v.len() as u64).to_le_bytes());
            buf.extend(v.iter().map(|&b| b as u8));
        }
        Column::Str(v) => {
            let plan = plan_str_rows(v.iter().map(|s| s.as_str()), mode);
            encode_str_rows(v.len(), v.iter().map(|s| s.as_str()), plan, buf);
        }
    }
}

/// Decode one column starting at `*pos`; advances `*pos` past it.
pub fn decode_column(buf: &[u8], pos: &mut usize) -> Result<Column> {
    let tag = *buf.get(*pos).context("codec: truncated (tag)")?;
    *pos += 1;
    let n = read_u64(buf, pos)? as usize;
    let col = match tag {
        TAG_I64 => {
            let mut v = Vec::with_capacity(n);
            for _ in 0..n {
                v.push(i64::from_le_bytes(read_8(buf, pos)?));
            }
            Column::I64(v)
        }
        TAG_F64 => {
            let mut v = Vec::with_capacity(n);
            for _ in 0..n {
                v.push(f64::from_le_bytes(read_8(buf, pos)?));
            }
            Column::F64(v)
        }
        TAG_BOOL => {
            if *pos + n > buf.len() {
                bail!("codec: truncated bool payload");
            }
            let v = buf[*pos..*pos + n].iter().map(|&b| b != 0).collect();
            *pos += n;
            Column::Bool(v)
        }
        TAG_STR => {
            let mut v = Vec::with_capacity(n);
            for _ in 0..n {
                let len = u32::from_le_bytes(read_4(buf, pos)?) as usize;
                if *pos + len > buf.len() {
                    bail!("codec: truncated string payload");
                }
                v.push(
                    std::str::from_utf8(&buf[*pos..*pos + len])
                        .context("codec: invalid utf-8")?
                        .to_string(),
                );
                *pos += len;
            }
            Column::Str(v)
        }
        TAG_STR_DICT => {
            let d = u32::from_le_bytes(read_4(buf, pos)?) as usize;
            let mut dict = Vec::with_capacity(d);
            for _ in 0..d {
                let len = u32::from_le_bytes(read_4(buf, pos)?) as usize;
                if *pos + len > buf.len() {
                    bail!("codec: truncated dictionary entry");
                }
                dict.push(
                    std::str::from_utf8(&buf[*pos..*pos + len])
                        .context("codec: invalid utf-8 in dictionary")?
                        .to_string(),
                );
                *pos += len;
            }
            let cw = *buf.get(*pos).context("codec: truncated (code width)")? as usize;
            *pos += 1;
            if !matches!(cw, 1 | 2 | 4) {
                bail!("codec: bad dictionary code width {cw}");
            }
            if *pos + n * cw > buf.len() {
                bail!("codec: truncated dictionary codes");
            }
            let mut v = Vec::with_capacity(n);
            for _ in 0..n {
                let mut c = 0u32;
                for (k, &b) in buf[*pos..*pos + cw].iter().enumerate() {
                    c |= (b as u32) << (8 * k);
                }
                *pos += cw;
                let s = dict
                    .get(c as usize)
                    .with_context(|| format!("codec: dictionary code {c} out of range"))?;
                v.push(s.clone());
            }
            Column::Str(v)
        }
        t => bail!("codec: unknown dtype tag {t}"),
    };
    Ok(col)
}

/// Encode only the rows at `idx` of `col` — the shuffle pack path fused
/// with the gather, eliminating the intermediate `take()` column (§Perf:
/// one full copy of all shuffled bytes removed). The string dictionary
/// heuristic runs over exactly the gathered row sequence, so the output is
/// byte-identical to `encode_column(&col.take(idx))`.
pub fn encode_column_take(col: &Column, idx: &[usize], buf: &mut Vec<u8>) {
    match col {
        Column::I64(v) => {
            buf.push(TAG_I64);
            buf.extend_from_slice(&(idx.len() as u64).to_le_bytes());
            buf.reserve(idx.len() * 8);
            for &i in idx {
                buf.extend_from_slice(&v[i].to_le_bytes());
            }
        }
        Column::F64(v) => {
            buf.push(TAG_F64);
            buf.extend_from_slice(&(idx.len() as u64).to_le_bytes());
            buf.reserve(idx.len() * 8);
            for &i in idx {
                buf.extend_from_slice(&v[i].to_le_bytes());
            }
        }
        Column::Bool(v) => {
            buf.push(TAG_BOOL);
            buf.extend_from_slice(&(idx.len() as u64).to_le_bytes());
            buf.extend(idx.iter().map(|&i| v[i] as u8));
        }
        Column::Str(v) => {
            let rows = || idx.iter().map(|&i| v[i].as_str());
            let plan = plan_str_rows(rows(), dict_encoding());
            encode_str_rows(idx.len(), rows(), plan, buf);
        }
    }
}

// ---------------------------------------------------------------------------
// Nullable wire format: masks travel with their columns.
//
//   u8  mask flag            (0 = no mask, 1 = mask follows)
//   [mask: u64 row count + packed validity words]
//   column                   (the plain format above)
//
// Shuffles, sorts, rebalance and the driver gather all use this framing so
// null positions survive every redistribution.
// ---------------------------------------------------------------------------

/// Append the encoding of `(col, mask)` to `buf`.
pub fn encode_nullable_column(col: &Column, mask: Option<&ValidityMask>, buf: &mut Vec<u8>) {
    match mask {
        Some(m) => {
            debug_assert_eq!(m.len(), col.len(), "codec: mask length mismatch");
            buf.push(1);
            m.encode(buf);
        }
        None => buf.push(0),
    }
    encode_column(col, buf);
}

/// Decode one nullable column starting at `*pos`; advances `*pos` past it.
pub fn decode_nullable_column(
    buf: &[u8],
    pos: &mut usize,
) -> Result<(Column, Option<ValidityMask>)> {
    let flag = *buf.get(*pos).context("codec: truncated (mask flag)")?;
    *pos += 1;
    let mask = match flag {
        0 => None,
        1 => Some(ValidityMask::decode(buf, pos)?),
        f => bail!("codec: bad mask flag {f}"),
    };
    let col = decode_column(buf, pos)?;
    if let Some(m) = &mask {
        if m.len() != col.len() {
            bail!("codec: mask length {} != column length {}", m.len(), col.len());
        }
    }
    Ok((col, mask))
}

/// Encode only the rows at `idx` of `(col, mask)` — the nullable shuffle
/// pack path, fused with the gather like [`encode_column_take`].
pub fn encode_nullable_column_take(
    col: &Column,
    mask: Option<&ValidityMask>,
    idx: &[usize],
    buf: &mut Vec<u8>,
) {
    match mask {
        Some(m) => {
            buf.push(1);
            m.take(idx).encode(buf);
        }
        None => buf.push(0),
    }
    encode_column_take(col, idx, buf);
}

fn read_u64(buf: &[u8], pos: &mut usize) -> Result<u64> {
    Ok(u64::from_le_bytes(read_8(buf, pos)?))
}

fn read_8(buf: &[u8], pos: &mut usize) -> Result<[u8; 8]> {
    if *pos + 8 > buf.len() {
        bail!("codec: truncated (8-byte read at {})", *pos);
    }
    let mut b = [0u8; 8];
    b.copy_from_slice(&buf[*pos..*pos + 8]);
    *pos += 8;
    Ok(b)
}

fn read_4(buf: &[u8], pos: &mut usize) -> Result<[u8; 4]> {
    if *pos + 4 > buf.len() {
        bail!("codec: truncated (4-byte read at {})", *pos);
    }
    let mut b = [0u8; 4];
    b.copy_from_slice(&buf[*pos..*pos + 4]);
    *pos += 4;
    Ok(b)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(col: Column) {
        let mut buf = Vec::new();
        encode_column(&col, &mut buf);
        assert_eq!(buf.len(), encoded_size(&col));
        let mut pos = 0;
        let back = decode_column(&buf, &mut pos).unwrap();
        assert_eq!(pos, buf.len());
        assert_eq!(back, col);
    }

    #[test]
    fn roundtrip_all_dtypes() {
        roundtrip(Column::I64(vec![-1, 0, i64::MAX, i64::MIN]));
        roundtrip(Column::F64(vec![0.0, -1.5, f64::INFINITY, 1e-300]));
        roundtrip(Column::Bool(vec![true, false, true]));
        roundtrip(Column::Str(vec!["".into(), "héllo".into(), "x".repeat(1000)]));
    }

    #[test]
    fn roundtrip_empty() {
        roundtrip(Column::I64(vec![]));
        roundtrip(Column::Str(vec![]));
    }

    #[test]
    fn multiple_columns_in_one_buffer() {
        let a = Column::I64(vec![1, 2]);
        let b = Column::Str(vec!["x".into()]);
        let mut buf = Vec::new();
        encode_column(&a, &mut buf);
        encode_column(&b, &mut buf);
        let mut pos = 0;
        assert_eq!(decode_column(&buf, &mut pos).unwrap(), a);
        assert_eq!(decode_column(&buf, &mut pos).unwrap(), b);
    }

    #[test]
    fn encode_take_equals_take_then_encode() {
        let cols = [
            Column::I64(vec![1, 2, 3, 4, 5]),
            Column::F64(vec![0.1, 0.2, 0.3, 0.4, 0.5]),
            Column::Bool(vec![true, false, true, false, true]),
            Column::Str(vec!["a".into(), "bb".into(), "".into(), "dddd".into(), "e".into()]),
        ];
        let idx = vec![4usize, 0, 2, 2];
        for col in &cols {
            let mut a = Vec::new();
            encode_column_take(col, &idx, &mut a);
            let mut b = Vec::new();
            encode_column(&col.take(&idx), &mut b);
            assert_eq!(a, b, "{:?}", col.dtype());
        }
    }

    #[test]
    fn truncated_fails() {
        let mut buf = Vec::new();
        encode_column(&Column::I64(vec![1, 2, 3]), &mut buf);
        buf.truncate(buf.len() - 1);
        let mut pos = 0;
        assert!(decode_column(&buf, &mut pos).is_err());
    }

    #[test]
    fn nullable_roundtrip_and_take() {
        let col = Column::I64(vec![1, 0, 3, 0, 5]);
        let mask = ValidityMask::from_bools(&[true, false, true, false, true]);
        // with mask
        let mut buf = Vec::new();
        encode_nullable_column(&col, Some(&mask), &mut buf);
        let mut pos = 0;
        let (c, m) = decode_nullable_column(&buf, &mut pos).unwrap();
        assert_eq!(pos, buf.len());
        assert_eq!(c, col);
        assert_eq!(m, Some(mask.clone()));
        // without mask
        let mut buf = Vec::new();
        encode_nullable_column(&col, None, &mut buf);
        let mut pos = 0;
        let (c, m) = decode_nullable_column(&buf, &mut pos).unwrap();
        assert_eq!(c, col);
        assert!(m.is_none());
        // take path equals take-then-encode
        let idx = vec![4usize, 1, 1, 0];
        let mut a = Vec::new();
        encode_nullable_column_take(&col, Some(&mask), &idx, &mut a);
        let mut b = Vec::new();
        encode_nullable_column(&col.take(&idx), Some(&mask.take(&idx)), &mut b);
        assert_eq!(a, b);
        // truncation anywhere errors, never panics
        let mut full = Vec::new();
        encode_nullable_column(&col, Some(&mask), &mut full);
        for cut in 0..full.len() {
            let mut pos = 0;
            assert!(decode_nullable_column(&full[..cut], &mut pos).is_err(), "cut={cut}");
        }
    }

    #[test]
    fn bad_tag_fails() {
        let buf = vec![9u8, 0, 0, 0, 0, 0, 0, 0, 0];
        let mut pos = 0;
        assert!(decode_column(&buf, &mut pos).is_err());
    }
}
