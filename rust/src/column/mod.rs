//! Typed columns — the "individual array variables" of the paper's dual
//! representation (§4). Every data-frame column is one [`Column`]; data-frame
//! structure exists only as IR metadata. All relational and analytics
//! operators ultimately manipulate these flat arrays.

mod codec;
mod kernels;
mod validity;

pub use codec::{
    decode_column, decode_nullable_column, dict_encoding, encode_column, encode_column_take,
    encode_column_with, encode_nullable_column, encode_nullable_column_take, encoded_size,
    set_dict_encoding, DictEncoding,
};
pub use kernels::*;
pub use validity::{
    combine_masks, extend_opt_mask, normalize_mask, push_nullable, scrub_invalid,
    NullableColumn, ValidityMask,
};

use crate::types::{DType, Value};
use std::fmt;

/// A contiguous, homogeneously-typed array.
#[derive(Debug, Clone, PartialEq)]
pub enum Column {
    I64(Vec<i64>),
    F64(Vec<f64>),
    Bool(Vec<bool>),
    Str(Vec<String>),
}

impl Column {
    pub fn dtype(&self) -> DType {
        match self {
            Column::I64(_) => DType::I64,
            Column::F64(_) => DType::F64,
            Column::Bool(_) => DType::Bool,
            Column::Str(_) => DType::Str,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Column::I64(v) => v.len(),
            Column::F64(v) => v.len(),
            Column::Bool(v) => v.len(),
            Column::Str(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Allocate an empty column of the given dtype (the `alloc` calls in the
    /// paper's generated C — Fig. 5).
    pub fn new_empty(dtype: DType) -> Column {
        match dtype {
            DType::I64 => Column::I64(Vec::new()),
            DType::F64 => Column::F64(Vec::new()),
            DType::Bool => Column::Bool(Vec::new()),
            DType::Str => Column::Str(Vec::new()),
        }
    }

    /// Allocate with capacity, for shuffle receive buffers.
    pub fn with_capacity(dtype: DType, cap: usize) -> Column {
        match dtype {
            DType::I64 => Column::I64(Vec::with_capacity(cap)),
            DType::F64 => Column::F64(Vec::with_capacity(cap)),
            DType::Bool => Column::Bool(Vec::with_capacity(cap)),
            DType::Str => Column::Str(Vec::with_capacity(cap)),
        }
    }

    pub fn get(&self, i: usize) -> Value {
        match self {
            Column::I64(v) => Value::I64(v[i]),
            Column::F64(v) => Value::F64(v[i]),
            Column::Bool(v) => Value::Bool(v[i]),
            Column::Str(v) => Value::Str(v[i].clone()),
        }
    }

    pub fn push(&mut self, v: &Value) {
        match (self, v) {
            (Column::I64(c), Value::I64(x)) => c.push(*x),
            (Column::F64(c), Value::F64(x)) => c.push(*x),
            (Column::Bool(c), Value::Bool(x)) => c.push(*x),
            (Column::Str(c), Value::Str(x)) => c.push(x.clone()),
            (_, Value::Null(_)) => {
                panic!("push: Value::Null needs a validity mask — use push_nullable")
            }
            (c, v) => panic!("push: dtype mismatch {:?} <- {:?}", c.dtype(), v),
        }
    }

    /// Take the rows at `idx` (gather). Used by sort-merge join output
    /// materialization and by rebalance repacking.
    pub fn take(&self, idx: &[usize]) -> Column {
        match self {
            Column::I64(v) => Column::I64(idx.iter().map(|&i| v[i]).collect()),
            Column::F64(v) => Column::F64(idx.iter().map(|&i| v[i]).collect()),
            Column::Bool(v) => Column::Bool(idx.iter().map(|&i| v[i]).collect()),
            Column::Str(v) => Column::Str(idx.iter().map(|&i| v[i].clone()).collect()),
        }
    }

    /// Gather with optional indices — the null-introducing take used by
    /// Left/Right/Outer join output assembly. The dtype is *preserved*:
    /// `None` entries hold the dtype default and the companion
    /// [`ValidityMask`] (built by [`Column::take_opt_masked`] or
    /// [`ValidityMask::take_opt`]) marks them null.
    pub fn take_opt(&self, idx: &[Option<usize>]) -> Column {
        match self {
            Column::I64(v) => Column::I64(
                idx.iter().map(|o| o.map(|i| v[i]).unwrap_or(0)).collect(),
            ),
            Column::F64(v) => Column::F64(
                idx.iter().map(|o| o.map(|i| v[i]).unwrap_or(0.0)).collect(),
            ),
            Column::Bool(v) => Column::Bool(
                idx.iter()
                    .map(|o| o.map(|i| v[i]).unwrap_or(false))
                    .collect(),
            ),
            Column::Str(v) => Column::Str(
                idx.iter()
                    .map(|o| o.map(|i| v[i].clone()).unwrap_or_default())
                    .collect(),
            ),
        }
    }

    /// Null-introducing gather of a nullable column: dtype-preserving values
    /// plus the combined validity (`None` index ⇒ null; present index keeps
    /// the source row's validity).
    pub fn take_opt_masked(
        &self,
        mask: Option<&ValidityMask>,
        idx: &[Option<usize>],
    ) -> NullableColumn {
        let values = self.take_opt(idx);
        let validity = match mask {
            Some(m) => m.take_opt(idx),
            None => {
                let mut v = ValidityMask::new_null(idx.len());
                for (o, oi) in idx.iter().enumerate() {
                    if oi.is_some() {
                        v.set(o, true);
                    }
                }
                v
            }
        };
        NullableColumn::new(values, Some(validity))
    }

    /// Keep only rows where `mask` is true — the filter kernel
    /// (`HiFrames.API.filter`, paper §4.1).
    pub fn filter(&self, mask: &[bool]) -> Column {
        assert_eq!(mask.len(), self.len(), "filter: mask length mismatch");
        match self {
            Column::I64(v) => Column::I64(filter_vec(v, mask)),
            Column::F64(v) => Column::F64(filter_vec(v, mask)),
            Column::Bool(v) => Column::Bool(filter_vec(v, mask)),
            Column::Str(v) => {
                // Same word-at-a-time selection as `filter_vec`, minus the
                // bulk memcpy (strings must be cloned one by one).
                let mut out = Vec::with_capacity(count_true(mask));
                for (ci, chunk) in mask.chunks(64).enumerate() {
                    let mut kw = bool_word(chunk);
                    let base = ci * 64;
                    while kw != 0 {
                        let b = kw.trailing_zeros() as usize;
                        kw &= kw - 1;
                        out.push(v[base + b].clone());
                    }
                }
                Column::Str(out)
            }
        }
    }

    /// Contiguous sub-range `[start, start+len)` — hyperslab slicing.
    pub fn slice(&self, start: usize, len: usize) -> Column {
        match self {
            Column::I64(v) => Column::I64(v[start..start + len].to_vec()),
            Column::F64(v) => Column::F64(v[start..start + len].to_vec()),
            Column::Bool(v) => Column::Bool(v[start..start + len].to_vec()),
            Column::Str(v) => Column::Str(v[start..start + len].to_vec()),
        }
    }

    /// Append all of `other` (vertical concatenation, paper's `vcat`).
    pub fn extend(&mut self, other: &Column) {
        match (self, other) {
            (Column::I64(a), Column::I64(b)) => a.extend_from_slice(b),
            (Column::F64(a), Column::F64(b)) => a.extend_from_slice(b),
            (Column::Bool(a), Column::Bool(b)) => a.extend_from_slice(b),
            (Column::Str(a), Column::Str(b)) => a.extend_from_slice(b),
            (a, b) => panic!("extend: dtype mismatch {:?} vs {:?}", a.dtype(), b.dtype()),
        }
    }

    pub fn as_i64(&self) -> &[i64] {
        match self {
            Column::I64(v) => v,
            other => panic!("expected Int64 column, got {}", other.dtype()),
        }
    }

    pub fn as_f64(&self) -> &[f64] {
        match self {
            Column::F64(v) => v,
            other => panic!("expected Float64 column, got {}", other.dtype()),
        }
    }

    pub fn as_bool(&self) -> &[bool] {
        match self {
            Column::Bool(v) => v,
            other => panic!("expected Bool column, got {}", other.dtype()),
        }
    }

    pub fn as_str_col(&self) -> &[String] {
        match self {
            Column::Str(v) => v,
            other => panic!("expected String column, got {}", other.dtype()),
        }
    }

    /// Cast to f64 (feature assembly before ML; Julia `typed_hcat(Float64,...)`).
    pub fn to_f64_vec(&self) -> Vec<f64> {
        match self {
            Column::I64(v) => v.iter().map(|&x| x as f64).collect(),
            Column::F64(v) => v.clone(),
            Column::Bool(v) => v.iter().map(|&b| b as i64 as f64).collect(),
            Column::Str(_) => panic!("cannot cast String column to Float64"),
        }
    }

    /// Approximate heap size in bytes (metrics / spill-budget accounting).
    /// `Str` counts the UTF-8 payload plus the 24-byte `String` header
    /// (ptr/len/cap) so string-heavy tables aren't systematically
    /// under-budgeted; validity-mask bitmap bytes are accounted separately
    /// by [`ValidityMask::byte_size`] (see `ops::spill::nullable_bytes`).
    pub fn byte_size(&self) -> usize {
        match self {
            Column::I64(v) => v.len() * 8,
            Column::F64(v) => v.len() * 8,
            Column::Bool(v) => v.len(),
            Column::Str(v) => v
                .iter()
                .map(|s| s.len() + std::mem::size_of::<String>())
                .sum(),
        }
    }
}

/// Pack up to 64 bools into one selection word (bit `b` set ⇔ `chunk[b]`).
/// The shared primitive of the word-at-a-time kernels here and in
/// [`ValidityMask`]: once a chunk is a word, all-zero words are skipped,
/// all-ones words become bulk copies, and sparse words iterate only their
/// set bits via `trailing_zeros`.
#[inline]
pub(crate) fn bool_word(chunk: &[bool]) -> u64 {
    debug_assert!(chunk.len() <= 64);
    let mut w = 0u64;
    for (b, &bit) in chunk.iter().enumerate() {
        w |= (bit as u64) << b;
    }
    w
}

/// The all-ones selection word for a (possibly partial) chunk of `n` bits.
#[inline]
pub(crate) fn full_word(n: usize) -> u64 {
    if n >= 64 {
        u64::MAX
    } else {
        (1u64 << n) - 1
    }
}

fn filter_vec<T: Copy>(v: &[T], mask: &[bool]) -> Vec<T> {
    // Word-at-a-time selection: the mask is packed into u64 words so runs of
    // zeros cost one test, runs of ones become a bulk `extend_from_slice`,
    // and mixed words visit only their set bits. Replaced the per-bool
    // branch loop (itself ~2x over iterator chains on 20M-row masks).
    let mut out = Vec::with_capacity(count_true(mask));
    for (ci, chunk) in mask.chunks(64).enumerate() {
        let mut kw = bool_word(chunk);
        let base = ci * 64;
        if kw == full_word(chunk.len()) {
            out.extend_from_slice(&v[base..base + chunk.len()]);
            continue;
        }
        while kw != 0 {
            let b = kw.trailing_zeros() as usize;
            kw &= kw - 1;
            out.push(v[base + b]);
        }
    }
    out
}

/// Population count of a boolean mask (word-packed popcount).
pub fn count_true(mask: &[bool]) -> usize {
    mask.chunks(64)
        .map(|c| bool_word(c).count_ones() as usize)
        .sum()
}

impl fmt::Display for Column {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let n = self.len().min(8);
        write!(f, "{}[", self.dtype())?;
        for i in 0..n {
            if i > 0 {
                write!(f, ", ")?;
            }
            write!(f, "{}", self.get(i))?;
        }
        if self.len() > n {
            write!(f, ", … ({} total)", self.len())?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_accessors() {
        let c = Column::I64(vec![1, 2, 3]);
        assert_eq!(c.len(), 3);
        assert!(!c.is_empty());
        assert_eq!(c.dtype(), DType::I64);
        assert_eq!(c.get(1), Value::I64(2));
        assert_eq!(c.as_i64(), &[1, 2, 3]);
    }

    #[test]
    fn filter_and_take() {
        let c = Column::F64(vec![1.0, 2.0, 3.0, 4.0]);
        let f = c.filter(&[true, false, true, false]);
        assert_eq!(f, Column::F64(vec![1.0, 3.0]));
        let t = c.take(&[3, 0, 0]);
        assert_eq!(t, Column::F64(vec![4.0, 1.0, 1.0]));
    }

    #[test]
    fn take_opt_preserves_dtype_and_masks_holes() {
        let c = Column::I64(vec![10, 20, 30]);
        let out = c.take_opt_masked(None, &[Some(2), None, Some(0)]);
        assert_eq!(out.dtype(), DType::I64); // no F64 promotion
        assert_eq!(out.values.as_i64(), &[30, 0, 10]);
        assert_eq!(out.validity.as_ref().unwrap().to_bools(), vec![true, false, true]);
        // no holes → mask normalizes away, dtype still native
        let full = c.take_opt_masked(None, &[Some(0), Some(1)]);
        assert_eq!(full.dtype(), DType::I64);
        assert!(full.validity.is_none());
        let b = Column::Bool(vec![true, false]);
        let v = b.take_opt_masked(None, &[Some(0), None]);
        assert_eq!(v.values.as_bool(), &[true, false]);
        assert!(!v.is_valid(1));
        let s = Column::Str(vec!["a".into()]);
        let v = s.take_opt(&[None, Some(0)]);
        assert_eq!(v.as_str_col(), &["".to_string(), "a".into()]);
        // source validity propagates through a present index
        let src_mask = ValidityMask::from_bools(&[false, true, true]);
        let g = c.take_opt_masked(Some(&src_mask), &[Some(0), Some(1), None]);
        assert_eq!(g.validity.unwrap().to_bools(), vec![false, true, false]);
    }

    #[test]
    fn filter_strings() {
        let c = Column::Str(vec!["a".into(), "b".into(), "c".into()]);
        let f = c.filter(&[false, true, true]);
        assert_eq!(f.as_str_col(), &["b".to_string(), "c".to_string()]);
    }

    #[test]
    #[should_panic(expected = "mask length mismatch")]
    fn filter_length_mismatch_panics() {
        Column::I64(vec![1, 2]).filter(&[true]);
    }

    #[test]
    fn slice_and_extend() {
        let mut a = Column::I64(vec![1, 2, 3]);
        let b = Column::I64(vec![4, 5]);
        a.extend(&b);
        assert_eq!(a.as_i64(), &[1, 2, 3, 4, 5]);
        assert_eq!(a.slice(1, 3).as_i64(), &[2, 3, 4]);
    }

    #[test]
    #[should_panic(expected = "dtype mismatch")]
    fn extend_mismatch_panics() {
        let mut a = Column::I64(vec![1]);
        a.extend(&Column::F64(vec![1.0]));
    }

    #[test]
    fn push_values() {
        let mut c = Column::new_empty(DType::Str);
        c.push(&Value::Str("x".into()));
        assert_eq!(c.len(), 1);
        let mut c = Column::with_capacity(DType::Bool, 4);
        c.push(&Value::Bool(true));
        assert_eq!(c.as_bool(), &[true]);
    }

    #[test]
    fn to_f64_cast() {
        assert_eq!(Column::I64(vec![1, 2]).to_f64_vec(), vec![1.0, 2.0]);
        assert_eq!(Column::Bool(vec![true, false]).to_f64_vec(), vec![1.0, 0.0]);
    }

    #[test]
    fn byte_sizes() {
        assert_eq!(Column::I64(vec![0; 10]).byte_size(), 80);
        assert_eq!(Column::Bool(vec![false; 10]).byte_size(), 10);
        // payload + String header, so budget accounting sees the real cost
        assert_eq!(
            Column::Str(vec!["ab".into()]).byte_size(),
            2 + std::mem::size_of::<String>()
        );
    }

    #[test]
    fn display_truncates() {
        let c = Column::I64((0..20).collect());
        let s = format!("{c}");
        assert!(s.contains("(20 total)"));
    }
}
