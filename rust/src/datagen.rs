//! Deterministic random data generation.
//!
//! The paper's micro-benchmarks use tables "randomly generated from uniform
//! distribution to avoid load balance issues" (§5) and TPCx-BB's generator
//! for the query benchmarks; Q05 additionally stresses *skewed* keys. We
//! provide a seedable SplitMix64/xoshiro256** PRNG (the offline image has no
//! `rand` crate) plus uniform/normal/Zipf samplers and table generators.

use crate::column::Column;
use crate::table::Table;

/// SplitMix64 — used to seed xoshiro and as a cheap standalone generator.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> SplitMix64 {
        SplitMix64 { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — the workhorse PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal deviate from Box–Muller.
    cached_normal: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Rng {
        let mut sm = SplitMix64::new(seed);
        Rng {
            s: [
                sm.next_u64(),
                sm.next_u64(),
                sm.next_u64(),
                sm.next_u64(),
            ],
            cached_normal: None,
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[lo, hi)`.
    pub fn i64_range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(hi > lo);
        let span = (hi - lo) as u64;
        lo + (self.next_u64() % span) as i64
    }

    /// Uniform usize in `[0, n)`.
    pub fn usize(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.cached_normal.take() {
            return z;
        }
        let (mut u1, u2) = (self.f64(), self.f64());
        if u1 <= f64::MIN_POSITIVE {
            u1 = f64::MIN_POSITIVE;
        }
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.cached_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Pick an element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize(xs.len())]
    }
}

/// Zipf(α) sampler over `{0, …, n-1}` via inverse-CDF on a precomputed
/// table. Used to reproduce the Q05 skewed-join experiment (paper §5.1):
/// "a join on a large table with highly skewed data".
#[derive(Debug, Clone)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, alpha: f64) -> Zipf {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(alpha);
            cdf.push(acc);
        }
        let total = acc;
        for c in &mut cdf {
            *c /= total;
        }
        Zipf { cdf }
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        match self
            .cdf
            .binary_search_by(|c| c.partial_cmp(&u).unwrap())
        {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

/// The micro-benchmark table of §5: an integer key and two floats.
/// `key_range` controls join/aggregate selectivity.
pub fn micro_table(rows: usize, key_range: i64, seed: u64) -> Table {
    let mut rng = Rng::new(seed);
    let mut id = Vec::with_capacity(rows);
    let mut x = Vec::with_capacity(rows);
    let mut y = Vec::with_capacity(rows);
    for _ in 0..rows {
        id.push(rng.i64_range(0, key_range));
        x.push(rng.f64());
        y.push(rng.f64() * 100.0);
    }
    Table::from_pairs(vec![
        ("id", Column::I64(id)),
        ("x", Column::F64(x)),
        ("y", Column::F64(y)),
    ])
    .expect("micro_table construction")
}

/// Single-column series for the advanced-analytics benchmarks (Fig. 8b).
pub fn series(rows: usize, seed: u64) -> Column {
    let mut rng = Rng::new(seed);
    Column::F64((0..rows).map(|_| rng.normal()).collect())
}

/// Skewed key table for the Q05-style experiment.
pub fn skewed_table(rows: usize, key_range: usize, alpha: f64, seed: u64) -> Table {
    let mut rng = Rng::new(seed);
    let zipf = Zipf::new(key_range, alpha);
    let mut id = Vec::with_capacity(rows);
    let mut x = Vec::with_capacity(rows);
    for _ in 0..rows {
        id.push(zipf.sample(&mut rng) as i64);
        x.push(rng.f64());
    }
    Table::from_pairs(vec![("id", Column::I64(id)), ("x", Column::F64(x))])
        .expect("skewed_table construction")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(Rng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_bounds() {
        let mut rng = Rng::new(7);
        for _ in 0..10_000 {
            let f = rng.f64();
            assert!((0.0..1.0).contains(&f));
            let k = rng.i64_range(-5, 5);
            assert!((-5..5).contains(&k));
        }
    }

    #[test]
    fn uniform_mean_close() {
        let mut rng = Rng::new(1);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| rng.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(2);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn zipf_is_skewed() {
        let mut rng = Rng::new(3);
        let z = Zipf::new(1000, 1.2);
        let mut counts = vec![0usize; 1000];
        for _ in 0..50_000 {
            counts[z.sample(&mut rng)] += 1;
        }
        // head key should dominate the tail decisively
        assert!(counts[0] > 20 * counts[500].max(1));
        // all samples in range (indexing above would have panicked)
        assert_eq!(counts.iter().sum::<usize>(), 50_000);
    }

    #[test]
    fn micro_table_shape() {
        let t = micro_table(1000, 50, 9);
        assert_eq!(t.num_rows(), 1000);
        assert_eq!(t.num_cols(), 3);
        let keys = t.column("id").unwrap().as_i64();
        assert!(keys.iter().all(|&k| (0..50).contains(&k)));
        // determinism
        assert_eq!(t, micro_table(1000, 50, 9));
    }

    #[test]
    fn series_len() {
        assert_eq!(series(123, 0).len(), 123);
    }

    #[test]
    fn skewed_table_range() {
        let t = skewed_table(500, 100, 1.5, 4);
        assert!(t
            .column("id")
            .unwrap()
            .as_i64()
            .iter()
            .all(|&k| (0..100).contains(&k)));
    }

    #[test]
    fn choose_covers() {
        let mut rng = Rng::new(5);
        let xs = [1, 2, 3];
        let mut seen = std::collections::BTreeSet::new();
        for _ in 0..100 {
            seen.insert(*rng.choose(&xs));
        }
        assert_eq!(seen.len(), 3);
    }
}
