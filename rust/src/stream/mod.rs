//! Incremental micro-batch execution — standing queries over ticking data
//! (DESIGN.md §4.9).
//!
//! A [`Session`] keeps a compiled [`PlanGraph`] alive across calls. Sources
//! stay appendable ([`Session::push`]) and every [`Session::tick`] flows
//! only the newly pushed record batches through the graph, keeping stateful
//! operator state per rank:
//!
//! * **group-by** holds a packed-key → [`AggState`] map and folds only the
//!   delta rows (the existing null-skip rules apply unchanged);
//! * **hash joins** keep both post-shuffle sides accumulated; when the
//!   build side did not tick, inner/left joins probe only the new rows and
//!   append the result suffix to a cached output;
//! * **partitioned windows** re-scan only the partitions a tick touched,
//!   serving untouched partitions from a per-partition output cache.
//!
//! Everything else — sorts, concats, global windows, stateful-over-stateful
//! shapes — is *recomputed* from full inputs each tick with the ordinary
//! batch interpreter ([`crate::exec`]), and plans with no incremental
//! handle at all (HFS sources, `cache()` points) fall back to a tracked
//! whole-plan recompute. Either way the contract is the same: after any N
//! ticks, `tick()`'s output is byte-identical — values *and* validity
//! masks — to a cold batch `collect()` over the union of all pushed
//! batches.
//!
//! Agreement rests on two facts the batch executor already guarantees.
//! First, key routing is schema-determined: every shuffle site passes
//! `KeyNullability::Static`, so the packed-key layout (and hence each
//! tuple's owner rank) never depends on which rows have arrived. Second,
//! arrival order is mode-independent: sources are split by monotone
//! contiguous [`crate::comm::block_range`] blocks and shuffles concatenate
//! received chunks in source-rank order, so processing ticks in push order
//! yields, on every rank, exactly the post-shuffle row order of the batch
//! run — which pins down fold order, build insertion order and the window
//! sort's stable tie-break alike.

use anyhow::{bail, Context, Result};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::column::{
    decode_nullable_column, encode_nullable_column, extend_opt_mask, normalize_mask, Column,
    NullableColumn, ValidityMask,
};
use crate::comm::{run_spmd_with_stats, Comm};
use crate::exec::{self, ExecOptions, LocalFrame, Program};
use crate::expr::{eval_nullable, AggExpr, AggState};
use crate::fxhash::{FxHashMap, FxHashSet};
use crate::ir::graph::{Node, NodeId, PlanGraph, SourceGenerations};
use crate::ir::{Plan, SourceRef, WindowAgg, WindowFunc};
use crate::ops::{
    self,
    aggregate::{finish_outputs, new_outputs, new_states, push_outputs, AggSpec, AggStrategy},
    join::{assemble_outputs, concat_nullable, join_partition},
    keys::{cmp_key_rows, key_rows_nullable, KeyRow},
    MaskedCol,
};
use crate::table::{Schema, Table};
use crate::types::{JoinStrategy, JoinType, SortOrder};

/// How the incremental walk treats one plan node.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Role {
    /// Source or row-wise operator over a delta-capable input: the tick's
    /// new rows flow straight through (and, where a recomputing consumer
    /// demands it, the operator also re-runs over the accumulated union).
    Delta,
    /// Aggregate / hash-join / partitioned-window directly over delta
    /// inputs: absorbs the tick into per-rank state and emits its full
    /// current output.
    Stateful,
    /// Everything else: re-executed by the batch interpreter over full
    /// inputs every tick.
    Recompute,
}

/// Per-tick accounting, also mirrored into
/// [`crate::metrics::stream_stats`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TickReport {
    /// 1-based tick number.
    pub tick: u64,
    /// Wall-clock seconds for the whole tick (driver side).
    pub wall_secs: f64,
    /// Rows the operators actually touched this tick (summed over ranks).
    pub rows_processed: u64,
    /// Rows held in operator state that did *not* need re-touching.
    pub rows_avoided: u64,
    /// True when this tick ran the whole-plan recompute fallback.
    pub fallback: bool,
}

/// One appendable source: the plan-time schema plus the accumulated union
/// of the initial table and every pushed batch, in push order.
struct SourceState {
    id: NodeId,
    name: String,
    schema: Schema,
    cols: Vec<Column>,
    masks: Vec<Option<ValidityMask>>,
    len: usize,
    /// Union row where the not-yet-ticked region starts.
    delta_from: usize,
    /// Cached union snapshot; invalidated by `push`.
    union_arc: Option<Arc<Table>>,
}

impl SourceState {
    /// The rows pushed since the last tick, as a table under the plan-time
    /// schema (empty when nothing ticked).
    fn delta_table(&self) -> Result<Table> {
        let n = self.len - self.delta_from;
        let cols: Vec<Column> = self.cols.iter().map(|c| c.slice(self.delta_from, n)).collect();
        let masks: Vec<Option<ValidityMask>> = self
            .masks
            .iter()
            .map(|m| normalize_mask(m.as_ref().map(|m| m.slice(self.delta_from, n))))
            .collect();
        Table::new_masked(self.schema.clone(), cols, masks)
    }

    /// Snapshot of the full union (initial table + every pushed batch).
    fn union_table(&mut self) -> Result<Arc<Table>> {
        if let Some(t) = &self.union_arc {
            return Ok(t.clone());
        }
        let masks: Vec<Option<ValidityMask>> =
            self.masks.iter().map(|m| normalize_mask(m.clone())).collect();
        let t = Arc::new(Table::new_masked(
            self.schema.clone(),
            self.cols.clone(),
            masks,
        )?);
        self.union_arc = Some(t.clone());
        Ok(t)
    }
}

/// One rank's persistent operator state, kept across ticks.
#[derive(Default)]
struct PerRankState {
    agg: FxHashMap<NodeId, AggAbsorber>,
    join: FxHashMap<NodeId, JoinAbsorber>,
    win: FxHashMap<NodeId, WinAbsorber>,
}

/// A standing query: compiled once, ticked many times.
pub struct Session {
    opts: ExecOptions,
    prog: Program,
    roles: FxHashMap<NodeId, Role>,
    need_delta: FxHashSet<NodeId>,
    need_full: FxHashSet<NodeId>,
    /// Sources whose union snapshot a recomputing consumer demands.
    union_needed: FxHashSet<NodeId>,
    /// Whole-plan recompute fallback, with the reason.
    fallback: Option<String>,
    /// Completion is delta-capable: gather only each tick's new output rows
    /// and append them driver-side.
    delta_append: bool,
    sources: Vec<SourceState>,
    gens: SourceGenerations,
    ranks: Vec<Mutex<PerRankState>>,
    /// Driver-side accumulated output (delta-append mode only).
    out_cols: Vec<Column>,
    out_masks: Vec<Option<ValidityMask>>,
    ticks: u64,
    reports: Vec<TickReport>,
}

impl Session {
    /// Compile `plan` into a standing query. The executor knobs are forced
    /// to their tick-replicable settings: raw-shuffle aggregation (the
    /// pre-aggregated merge order depends on batch boundaries), no sampled
    /// skew joins, no spilling.
    pub(crate) fn new(plan: Plan, mut opts: ExecOptions) -> Result<Session> {
        opts.agg_strategy = AggStrategy::RawShuffle;
        opts.passes.skew_join = false;
        opts.mem_budget = None;
        opts.profile = false;
        let g = crate::passes::optimize_graph(plan, &opts.passes)?;
        let prog = Program::prepare(&g, None)?;
        let (roles, mut fallback) = classify(&prog);
        let delta_append =
            fallback.is_none() && roles[&prog.graph.completion] == Role::Delta;
        let n_stateful = roles.values().filter(|r| **r == Role::Stateful).count();
        if fallback.is_none() && n_stateful == 0 && !delta_append {
            fallback = Some("no stateful operator over an appendable source".to_string());
        }
        let (need_delta, need_full) = if fallback.is_none() {
            demands(&prog, &roles, delta_append)
        } else {
            (FxHashSet::default(), FxHashSet::default())
        };
        let union_needed = union_sources(&prog, &need_full);
        let mut sources = Vec::new();
        for (id, name) in prog.graph.source_nodes() {
            let Node::Source { src, schema, .. } = &prog.graph.store[id] else {
                unreachable!("source_nodes returns Source ids");
            };
            let SourceRef::InMemory(table) = src else {
                continue; // HFS sources are not appendable (fallback set above)
            };
            let (_, cols, masks) = table.as_ref().clone().into_parts();
            let len = table.num_rows();
            sources.push(SourceState {
                id,
                name,
                schema: schema.clone(),
                cols,
                masks,
                len,
                delta_from: 0,
                union_arc: Some(table.clone()),
            });
        }
        let gens = SourceGenerations::new(&prog.graph);
        let ranks = (0..opts.workers).map(|_| Mutex::new(PerRankState::default())).collect();
        let out_schema = prog.schemas[&prog.graph.completion].clone();
        let out_cols = out_schema.fields().iter().map(|(_, t)| Column::new_empty(*t)).collect();
        let out_masks = vec![None; out_schema.len()];
        Ok(Session {
            opts,
            prog,
            roles,
            need_delta,
            need_full,
            union_needed,
            fallback,
            delta_append,
            sources,
            gens,
            ranks,
            out_cols,
            out_masks,
            ticks: 0,
            reports: Vec::new(),
        })
    }

    /// Append one record batch to the named source. The batch must match
    /// the source's plan-time schema (names and dtypes, in order) and may
    /// only carry nulls in columns the plan marked nullable — the compiled
    /// key routing depends on those flags. Several pushes between ticks
    /// accumulate in push order.
    pub fn push(&mut self, source: &str, batch: Table) -> Result<()> {
        if self.sources.iter().filter(|s| s.name == source).count() > 1 {
            bail!("session: source name :{source} is ambiguous");
        }
        let s = self
            .sources
            .iter_mut()
            .find(|s| s.name == source)
            .with_context(|| format!("session: no appendable source named :{source}"))?;
        if batch.schema().fields() != s.schema.fields() {
            bail!(
                "session push to :{source}: batch schema {:?} does not match \
                 the source's plan schema {:?}",
                batch.schema().fields(),
                s.schema.fields()
            );
        }
        for (i, (n, _)) in s.schema.fields().iter().enumerate() {
            if !s.schema.nullable_at(i) {
                if let Some(m) = batch.mask_at(i) {
                    if m.count_null() > 0 {
                        bail!(
                            "session push to :{source}: column :{n} is non-nullable \
                             in the plan but the batch carries {} null rows",
                            m.count_null()
                        );
                    }
                }
            }
        }
        let n = batch.num_rows();
        let (_, bcols, bmasks) = batch.into_parts();
        for (i, (a, b)) in s.cols.iter_mut().zip(&bcols).enumerate() {
            let before = a.len();
            a.extend(b);
            extend_opt_mask(&mut s.masks[i], before, bmasks[i].as_ref(), n);
        }
        s.len += n;
        s.union_arc = None;
        self.gens.bump(s.id);
        Ok(())
    }

    /// Run one micro-batch: flow the rows pushed since the last tick
    /// through the graph and return the standing query's full current
    /// output — byte-identical to a cold batch `collect()` over the union
    /// of all pushed batches.
    pub fn tick(&mut self) -> Result<Table> {
        let t0 = Instant::now();
        self.ticks += 1;
        if self.fallback.is_some() {
            let rows: u64 = self.sources.iter().map(|s| s.len as u64).sum();
            for s in &mut self.sources {
                s.delta_from = s.len;
            }
            let out = self.collect_batch()?;
            crate::metrics::stream_stats().record_tick(rows, 0, true);
            self.reports.push(TickReport {
                tick: self.ticks,
                wall_secs: t0.elapsed().as_secs_f64(),
                rows_processed: rows,
                rows_avoided: 0,
                fallback: true,
            });
            return Ok(out);
        }
        let mut delta_arcs: FxHashMap<NodeId, Arc<Table>> = FxHashMap::default();
        let mut union_arcs: FxHashMap<NodeId, Arc<Table>> = FxHashMap::default();
        for s in &mut self.sources {
            delta_arcs.insert(s.id, Arc::new(s.delta_table()?));
            if self.union_needed.contains(&s.id) {
                union_arcs.insert(s.id, s.union_table()?);
            }
            s.delta_from = s.len;
        }
        let prog = &self.prog;
        let opts = &self.opts;
        let roles = &self.roles;
        let need_delta = &self.need_delta;
        let need_full = &self.need_full;
        let ranks = &self.ranks;
        let delta_append = self.delta_append;
        let completion = prog.graph.completion;
        type RankOut = Result<(Vec<u8>, u64, u64)>;
        let (results, _) = run_spmd_with_stats(opts.workers, |comm| -> RankOut {
            let mut guard = ranks[comm.rank()].lock().unwrap();
            let st = &mut *guard;
            let mut dmemo: FxHashMap<NodeId, LocalFrame> = FxHashMap::default();
            let mut fmemo: FxHashMap<NodeId, LocalFrame> = FxHashMap::default();
            let mut processed = 0u64;
            let mut avoided = 0u64;
            for &id in &prog.graph.execution_order {
                let nd = need_delta.contains(&id);
                let nf = need_full.contains(&id);
                if !nd && !nf {
                    continue;
                }
                let node = &prog.graph.store[id];
                match roles[&id] {
                    Role::Delta => match node {
                        Node::Source { schema, .. } => {
                            let names: Vec<&str> = schema.names();
                            if nd {
                                let src = SourceRef::InMemory(delta_arcs[&id].clone());
                                dmemo.insert(id, exec::exec_source(&src, schema, &names, &comm)?);
                            }
                            if nf {
                                let src = SourceRef::InMemory(union_arcs[&id].clone());
                                fmemo.insert(id, exec::exec_source(&src, schema, &names, &comm)?);
                            }
                        }
                        // the batch interpreter's column-pruning fast path
                        // reads straight from the source table; mirror it
                        // against this tick's delta / union snapshots
                        Node::Project { input, columns }
                            if matches!(prog.graph.store[*input], Node::Source { .. }) =>
                        {
                            let Node::Source { schema, .. } = &prog.graph.store[*input] else {
                                unreachable!("guard matched Source");
                            };
                            let names: Vec<&str> =
                                columns.iter().map(|s| s.as_str()).collect();
                            let sub = Schema::new_nullable(
                                columns
                                    .iter()
                                    .map(|c| (c.clone(), schema.dtype_of(c).unwrap()))
                                    .collect(),
                                columns
                                    .iter()
                                    .map(|c| schema.nullable_of(c).unwrap_or(false))
                                    .collect(),
                            );
                            if nd {
                                let src = SourceRef::InMemory(delta_arcs[input].clone());
                                dmemo.insert(id, exec::exec_source(&src, &sub, &names, &comm)?);
                            }
                            if nf {
                                let src = SourceRef::InMemory(union_arcs[input].clone());
                                fmemo.insert(id, exec::exec_source(&src, &sub, &names, &comm)?);
                            }
                        }
                        _ => {
                            let input = node.children()[0];
                            if nd {
                                let mut m = FxHashMap::default();
                                let f = dmemo
                                    .get(&input)
                                    .context("stream: delta input missing")?
                                    .clone();
                                m.insert(input, f);
                                dmemo.insert(
                                    id,
                                    exec::exec_one_with_inputs(prog, id, m, &comm, opts)?,
                                );
                            }
                            if nf {
                                let mut m = FxHashMap::default();
                                let f = fmemo
                                    .get(&input)
                                    .context("stream: full input missing")?
                                    .clone();
                                m.insert(input, f);
                                fmemo.insert(
                                    id,
                                    exec::exec_one_with_inputs(prog, id, m, &comm, opts)?,
                                );
                            }
                        }
                    },
                    Role::Stateful => {
                        let out_schema = prog.schemas[&id].clone();
                        match node {
                            Node::Aggregate { input, keys, aggs } => {
                                let frame = dmemo
                                    .get(input)
                                    .context("stream: aggregate delta input missing")?;
                                let ab = st.agg.entry(id).or_default();
                                let (out, p, a) =
                                    ab.absorb(out_schema, keys, aggs, frame, &comm)?;
                                processed += p;
                                avoided += a;
                                fmemo.insert(id, out);
                            }
                            Node::Join {
                                left, right, on, how, ..
                            } => {
                                let lf = dmemo
                                    .get(left)
                                    .context("stream: join left delta missing")?;
                                let rf = dmemo
                                    .get(right)
                                    .context("stream: join right delta missing")?;
                                let jb = st.join.entry(id).or_default();
                                let (out, p, a) =
                                    jb.absorb(out_schema, on, *how, lf, rf, &comm)?;
                                processed += p;
                                avoided += a;
                                fmemo.insert(id, out);
                            }
                            Node::Window {
                                input,
                                partition_by,
                                order_by,
                                aggs,
                            } => {
                                let frame = dmemo
                                    .get(input)
                                    .context("stream: window delta input missing")?;
                                let wb = st.win.entry(id).or_default();
                                let (out, p, a) = wb.absorb(
                                    out_schema,
                                    partition_by,
                                    order_by,
                                    aggs,
                                    frame,
                                    &comm,
                                )?;
                                processed += p;
                                avoided += a;
                                fmemo.insert(id, out);
                            }
                            _ => unreachable!("stateful role is aggregate/join/window only"),
                        }
                    }
                    Role::Recompute => {
                        let mut m: FxHashMap<NodeId, LocalFrame> = FxHashMap::default();
                        let mut in_rows = 0u64;
                        for c in node.children() {
                            if !m.contains_key(&c) {
                                let f = fmemo
                                    .get(&c)
                                    .context("stream: recompute input missing")?
                                    .clone();
                                in_rows += f.num_rows() as u64;
                                m.insert(c, f);
                            }
                        }
                        let f = exec::exec_one_with_inputs(prog, id, m, &comm, opts)?;
                        processed += in_rows;
                        fmemo.insert(id, f);
                    }
                }
            }
            let frame = if delta_append {
                dmemo.remove(&completion)
            } else {
                fmemo.remove(&completion)
            }
            .context("stream: completion frame missing")?;
            drop(guard);
            // final gather, mirroring the batch executor byte for byte
            let mut buf = Vec::new();
            for (c, m) in frame.cols.iter().zip(&frame.masks) {
                encode_nullable_column(c, m.as_ref(), &mut buf);
            }
            let gathered = comm.gather_bytes(0, buf);
            if comm.is_root() {
                let (cols, masks) = exec::concat_rank_chunks(&frame.schema, gathered)?;
                let mut out = Vec::new();
                for (c, m) in cols.iter().zip(&masks) {
                    encode_nullable_column(c, normalize_mask(m.clone()).as_ref(), &mut out);
                }
                Ok((out, processed, avoided))
            } else {
                Ok((Vec::new(), processed, avoided))
            }
        });
        let mut root_buf: Option<Vec<u8>> = None;
        let mut tot_p = 0u64;
        let mut tot_a = 0u64;
        for (rank, r) in results.into_iter().enumerate() {
            let (buf, p, a) = r?;
            tot_p += p;
            tot_a += a;
            if rank == 0 {
                root_buf = Some(buf);
            }
        }
        let root_buf = root_buf.context("no ranks ran")?;
        let schema = self.prog.schemas[&completion].clone();
        let mut pos = 0;
        let mut cols = Vec::new();
        let mut masks = Vec::new();
        for _ in 0..schema.len() {
            let (c, m) = decode_nullable_column(&root_buf, &mut pos)?;
            cols.push(c);
            masks.push(m);
        }
        let table = if self.delta_append {
            for (i, (a, b)) in self.out_cols.iter_mut().zip(&cols).enumerate() {
                let before = a.len();
                a.extend(b);
                extend_opt_mask(&mut self.out_masks[i], before, masks[i].as_ref(), b.len());
            }
            Table::new_masked(schema, self.out_cols.clone(), self.out_masks.clone())?
        } else {
            Table::new_masked(schema, cols, masks)?
        };
        crate::metrics::stream_stats().record_tick(tot_p, tot_a, false);
        self.reports.push(TickReport {
            tick: self.ticks,
            wall_secs: t0.elapsed().as_secs_f64(),
            rows_processed: tot_p,
            rows_avoided: tot_a,
            fallback: false,
        });
        Ok(table)
    }

    /// Cold batch recompute over the union of all pushed batches: the same
    /// compiled graph with each appendable source's plan-time table swapped
    /// for its current union snapshot (no re-optimization, so key layouts
    /// and routing are identical). This is both the whole-plan fallback
    /// path and the agreement oracle the tests compare `tick()` against.
    pub fn collect_batch(&mut self) -> Result<Table> {
        let mut unions: FxHashMap<NodeId, Arc<Table>> = FxHashMap::default();
        for s in &mut self.sources {
            unions.insert(s.id, s.union_table()?);
        }
        let g: PlanGraph = self.prog.graph.rewrite_indexed(|_, id, n| match n {
            Node::Source { name, schema, .. } if unions.contains_key(&id) => Node::Source {
                name,
                src: SourceRef::InMemory(unions[&id].clone()),
                schema,
            },
            other => other,
        });
        Ok(exec::collect_graph(&g, &self.opts, None)?.0)
    }

    /// Render the compiled plan with each node's incremental role —
    /// `[delta]`, `[stateful]` or `[recompute]` — plus the session mode and
    /// the last tick's counters.
    pub fn explain_incremental(&self) -> String {
        let mut out = String::new();
        let mode = if self.fallback.is_some() {
            "full-recompute fallback"
        } else if self.delta_append {
            "incremental (delta-append output)"
        } else {
            "incremental"
        };
        out.push_str(&format!(
            "standing query: {} appendable source(s), mode: {mode}\n",
            self.sources.len()
        ));
        if let Some(reason) = &self.fallback {
            out.push_str(&format!("fallback reason: {reason}\n"));
        }
        for (i, line) in self.prog.graph.render_lines(false).iter().enumerate() {
            let id = self.prog.graph.execution_order[i];
            let marker = if self.fallback.is_some() {
                "[recompute]"
            } else {
                match self.roles[&id] {
                    Role::Delta => "[delta]",
                    Role::Stateful => "[stateful]",
                    Role::Recompute => "[recompute]",
                }
            };
            out.push_str(&format!("{line} {marker}\n"));
        }
        for s in &self.sources {
            out.push_str(&format!(
                "source :{} rows={} generation={}\n",
                s.name,
                s.len,
                self.gens.get(s.id)
            ));
        }
        if let Some(r) = self.reports.last() {
            out.push_str(&format!(
                "last tick #{}: rows_processed={} rows_avoided={} fallback={}\n",
                r.tick, r.rows_processed, r.rows_avoided, r.fallback
            ));
        }
        out
    }

    /// Per-tick reports, oldest first.
    pub fn reports(&self) -> &[TickReport] {
        &self.reports
    }

    /// The most recent tick's report.
    pub fn last_report(&self) -> Option<&TickReport> {
        self.reports.last()
    }

    /// True when this plan runs the tracked whole-plan recompute fallback.
    pub fn is_fallback(&self) -> bool {
        self.fallback.is_some()
    }

    /// Number of ticks run so far.
    pub fn num_ticks(&self) -> u64 {
        self.ticks
    }
}

/// Assign every node its incremental role; returns a whole-plan fallback
/// reason when the graph has no incremental handle at all.
fn classify(prog: &Program) -> (FxHashMap<NodeId, Role>, Option<String>) {
    let mut roles: FxHashMap<NodeId, Role> = FxHashMap::default();
    let mut fallback: Option<String> = None;
    for &id in &prog.graph.execution_order {
        let node = &prog.graph.store[id];
        let role = match node {
            Node::Source { src, name, .. } => match src {
                SourceRef::InMemory(_) => Role::Delta,
                SourceRef::Hfs(_) => {
                    fallback.get_or_insert_with(|| {
                        format!("source :{name} reads HFS (not appendable)")
                    });
                    Role::Recompute
                }
            },
            Node::Filter { input, .. }
            | Node::Project { input, .. }
            | Node::WithColumn { input, .. }
            | Node::Rename { input, .. } => {
                if roles[input] == Role::Delta {
                    Role::Delta
                } else {
                    Role::Recompute
                }
            }
            Node::Aggregate { input, keys, .. } => {
                if roles[input] == Role::Delta
                    && !keys.is_empty()
                    && !key_from_with_column(prog, *input, keys)
                {
                    Role::Stateful
                } else {
                    Role::Recompute
                }
            }
            Node::Join {
                left,
                right,
                on,
                strategy,
                ..
            } => {
                let lk: Vec<String> = on.iter().map(|(l, _)| l.clone()).collect();
                let rk: Vec<String> = on.iter().map(|(_, r)| r.clone()).collect();
                if roles[left] == Role::Delta
                    && roles[right] == Role::Delta
                    && matches!(strategy, JoinStrategy::Hash)
                    && !key_from_with_column(prog, *left, &lk)
                    && !key_from_with_column(prog, *right, &rk)
                {
                    Role::Stateful
                } else {
                    Role::Recompute
                }
            }
            Node::Window {
                input, partition_by, ..
            } => {
                if roles[input] == Role::Delta
                    && !partition_by.is_empty()
                    && !key_from_with_column(prog, *input, partition_by)
                {
                    Role::Stateful
                } else {
                    Role::Recompute
                }
            }
            Node::Cache { .. } => {
                fallback.get_or_insert_with(|| "plan contains a cache() point".to_string());
                Role::Recompute
            }
            _ => Role::Recompute,
        };
        roles.insert(id, role);
    }
    (roles, fallback)
}

/// Does any of `keys` trace back to a `WithColumn` output along the
/// delta chain starting at `id`? Computed columns get their *runtime*
/// nullability (mask presence) as their frame-schema flag, which can
/// change from tick to tick and change the packed-key layout — so a
/// stateful operator keyed on one is demoted to [`Role::Recompute`],
/// where the batch interpreter's own behavior is reproduced exactly.
/// Source / Filter / Project / Rename all carry plan-time flags through
/// unchanged, keeping the static-routing theorem intact.
fn key_from_with_column(prog: &Program, start: NodeId, keys: &[String]) -> bool {
    let mut keys: Vec<String> = keys.to_vec();
    let mut id = start;
    loop {
        match &prog.graph.store[id] {
            Node::WithColumn { input, name, .. } => {
                if keys.iter().any(|k| k == name) {
                    return true;
                }
                id = *input;
            }
            Node::Rename { input, from, to } => {
                for k in keys.iter_mut() {
                    if k == to {
                        *k = from.clone();
                    }
                }
                id = *input;
            }
            Node::Filter { input, .. } | Node::Project { input, .. } => id = *input,
            _ => return false,
        }
    }
}

/// Reverse demand analysis: which nodes must produce this tick's delta
/// frame, and which must produce their full accumulated frame. A node can
/// carry both demands (a delta chain feeding both a stateful operator and
/// a recomputing one).
fn demands(
    prog: &Program,
    roles: &FxHashMap<NodeId, Role>,
    delta_append: bool,
) -> (FxHashSet<NodeId>, FxHashSet<NodeId>) {
    let mut need_delta: FxHashSet<NodeId> = FxHashSet::default();
    let mut need_full: FxHashSet<NodeId> = FxHashSet::default();
    if delta_append {
        need_delta.insert(prog.graph.completion);
    } else {
        need_full.insert(prog.graph.completion);
    }
    for &id in prog.graph.execution_order.iter().rev() {
        let nd = need_delta.contains(&id);
        let nf = need_full.contains(&id);
        if !nd && !nf {
            continue;
        }
        let node = &prog.graph.store[id];
        // a Project straight over a Source reads the source snapshot
        // directly (pruning fast path) — no demand on the Source node
        if let Node::Project { input, .. } = node {
            if matches!(prog.graph.store[*input], Node::Source { .. }) {
                continue;
            }
        }
        match roles[&id] {
            Role::Delta => {
                for c in node.children() {
                    if nd {
                        need_delta.insert(c);
                    }
                    if nf {
                        need_full.insert(c);
                    }
                }
            }
            Role::Stateful => {
                for c in node.children() {
                    need_delta.insert(c);
                }
            }
            Role::Recompute => {
                for c in node.children() {
                    need_full.insert(c);
                }
            }
        }
    }
    (need_delta, need_full)
}

/// Sources whose full union snapshot must be materialized each tick:
/// demanded full directly, or read through a pruning Project that is.
fn union_sources(prog: &Program, need_full: &FxHashSet<NodeId>) -> FxHashSet<NodeId> {
    let mut out: FxHashSet<NodeId> = FxHashSet::default();
    for &id in &prog.graph.execution_order {
        match &prog.graph.store[id] {
            Node::Source { .. } if need_full.contains(&id) => {
                out.insert(id);
            }
            Node::Project { input, .. }
                if need_full.contains(&id)
                    && matches!(prog.graph.store[*input], Node::Source { .. }) =>
            {
                out.insert(*input);
            }
            _ => {}
        }
    }
    out
}

/// Append `(new_cols, new_masks)` onto an accumulated column set,
/// initializing it on first use.
fn append_side(
    cols: &mut Vec<Column>,
    masks: &mut Vec<Option<ValidityMask>>,
    new_cols: &[Column],
    new_masks: &[Option<ValidityMask>],
) {
    if cols.is_empty() {
        *cols = new_cols.to_vec();
        *masks = new_masks.to_vec();
        return;
    }
    for (i, (a, b)) in cols.iter_mut().zip(new_cols).enumerate() {
        let before = a.len();
        a.extend(b);
        extend_opt_mask(&mut masks[i], before, new_masks[i].as_ref(), b.len());
    }
}

/// Non-key columns of `frame` as masked references (the batch join's
/// payload selection, verbatim).
fn payload_refs<'f>(
    frame: &'f LocalFrame,
    on: &[(String, String)],
    is_left: bool,
) -> Vec<MaskedCol<'f>> {
    frame
        .schema
        .fields()
        .iter()
        .enumerate()
        .filter(|(_, (n, _))| {
            !on.iter()
                .any(|(lk, rk)| if is_left { lk == n } else { rk == n })
        })
        .map(|(i, _)| (&frame.cols[i], frame.masks[i].as_ref()))
        .collect()
}

/// Incremental group-by state: packed-key tuples → per-aggregate
/// [`AggState`] vectors, plus the accumulated post-shuffle key columns the
/// emitted key rows are gathered from (so under-null key cells reproduce
/// the batch path byte for byte).
#[derive(Default)]
struct AggAbsorber {
    group_of: FxHashMap<KeyRow, usize>,
    rows: Vec<KeyRow>,
    /// Group → global first-occurrence row in the accumulated key columns.
    reps: Vec<usize>,
    states: Vec<Vec<AggState>>,
    key_cols: Vec<Column>,
    key_masks: Vec<Option<ValidityMask>>,
    acc_len: usize,
}

impl AggAbsorber {
    fn absorb(
        &mut self,
        out_schema: Schema,
        keys: &[String],
        aggs: &[AggExpr],
        frame: &LocalFrame,
        comm: &Comm,
    ) -> Result<(LocalFrame, u64, u64)> {
        // pre-shuffle half: the batch interpreter's Aggregate block over
        // the delta rows only
        let key_cols: Vec<MaskedCol> =
            keys.iter().map(|k| frame.masked(k)).collect::<Result<_>>()?;
        let mut expr_cols: Vec<(Column, Option<ValidityMask>)> = Vec::with_capacity(aggs.len());
        let mut specs = Vec::with_capacity(aggs.len());
        for a in aggs {
            let (c, m) = eval_nullable(&a.input, frame)?;
            specs.push(AggSpec {
                func: a.func,
                input_dtype: c.dtype(),
            });
            expr_cols.push((c, m));
        }
        let keys_nullable = keys
            .iter()
            .any(|k| frame.schema.nullable_of(k).unwrap_or(false));
        let kc: Vec<&Column> = key_cols.iter().map(|(c, _)| *c).collect();
        let km: Vec<Option<&ValidityMask>> = key_cols.iter().map(|(_, m)| *m).collect();
        let with_flags = ops::KeyNullability::Static(keys_nullable)
            .with_flags(comm, km.iter().any(|m| m.is_some()));
        let packed = ops::PackedKeys::pack_masked(&kc, &km, with_flags)?;
        let mut all: Vec<&Column> = kc.clone();
        let mut masks: Vec<Option<&ValidityMask>> = km.clone();
        for (c, m) in &expr_cols {
            all.push(c);
            masks.push(m.as_ref());
        }
        let (recv, rmasks) = ops::shuffle_by_packed_nullable(comm, &packed, &all, &masks)?;
        let nk = keys.len();
        let (rkc, rec) = recv.split_at(nk);
        let (rkm, rem) = rmasks.split_at(nk);
        let n_new = rkc.first().map_or(0, |c| c.len());
        let krefs: Vec<&Column> = rkc.iter().collect();
        let kmrefs: Vec<Option<&ValidityMask>> = rkm.iter().map(|m| m.as_ref()).collect();
        let krows = key_rows_nullable(&krefs, &kmrefs)?;
        let old_acc = self.acc_len;
        append_side(&mut self.key_cols, &mut self.key_masks, rkc, rkm);
        self.acc_len += n_new;
        // fold the delta in arrival order (identical to the batch arrival
        // order), skipping null input lanes exactly like the batch fold
        for (i, krow) in krows.into_iter().enumerate() {
            let g = match self.group_of.get(&krow) {
                Some(&g) => g,
                None => {
                    let g = self.rows.len();
                    self.group_of.insert(krow.clone(), g);
                    self.rows.push(krow);
                    self.reps.push(old_acc + i);
                    self.states.push(new_states(&specs));
                    g
                }
            };
            for (j, s) in self.states[g].iter_mut().enumerate() {
                if rem[j].as_ref().map_or(true, |m| m.get(i)) {
                    s.update_col(&rec[j], i);
                }
            }
        }
        // emit the full current output: ascending key tuples (nulls
        // first), key cells gathered from each group's first occurrence —
        // the batch take-path, so wire-scrubbed under-null cells agree
        let mut order: Vec<usize> = (0..self.rows.len()).collect();
        order.sort_by(|&a, &b| cmp_key_rows(&self.rows[a], &self.rows[b], &[]));
        let rep_idx: Vec<usize> = order.iter().map(|&g| self.reps[g]).collect();
        let key_out: Vec<NullableColumn> = self
            .key_cols
            .iter()
            .zip(&self.key_masks)
            .map(|(c, m)| {
                NullableColumn::new(c.take(&rep_idx), m.as_ref().map(|m| m.take(&rep_idx)))
            })
            .collect();
        let mut outs = new_outputs(&specs);
        for &g in &order {
            push_outputs(&mut outs, &specs, &self.states[g]);
        }
        let mut cols = Vec::with_capacity(out_schema.len());
        let mut out_masks = Vec::with_capacity(out_schema.len());
        for c in key_out.into_iter().chain(finish_outputs(outs)) {
            cols.push(c.values);
            out_masks.push(c.validity);
        }
        Ok((
            LocalFrame {
                schema: out_schema,
                cols,
                masks: out_masks,
            },
            n_new as u64,
            old_acc as u64,
        ))
    }
}

/// Incremental hash-join state: both post-shuffle sides accumulated in
/// arrival order (keys first, the batch wire layout) plus the cached
/// assembled output. When the build side did not tick, inner/left joins
/// probe only the delta and append the suffix; any build-side tick (or a
/// right/outer join) re-joins the accumulated partitions locally — still
/// shuffling only the delta.
#[derive(Default)]
struct JoinAbsorber {
    lcols: Vec<Column>,
    lmasks: Vec<Option<ValidityMask>>,
    rcols: Vec<Column>,
    rmasks: Vec<Option<ValidityMask>>,
    out: Option<LocalFrame>,
}

impl JoinAbsorber {
    fn absorb(
        &mut self,
        out_schema: Schema,
        on: &[(String, String)],
        how: JoinType,
        lframe: &LocalFrame,
        rframe: &LocalFrame,
        comm: &Comm,
    ) -> Result<(LocalFrame, u64, u64)> {
        let nk = on.len();
        let lkeys: Vec<MaskedCol> = on
            .iter()
            .map(|(lk, _)| lframe.masked(lk))
            .collect::<Result<_>>()?;
        let rkeys: Vec<MaskedCol> = on
            .iter()
            .map(|(_, rk)| rframe.masked(rk))
            .collect::<Result<_>>()?;
        let lpay = payload_refs(lframe, on, true);
        let rpay = payload_refs(rframe, on, false);
        let keys_nullable = on.iter().any(|(lk, rk)| {
            lframe.schema.nullable_of(lk).unwrap_or(false)
                || rframe.schema.nullable_of(rk).unwrap_or(false)
        });
        let local_flag = lkeys.iter().chain(&rkeys).any(|(_, m)| m.is_some());
        let with_flags =
            ops::KeyNullability::Static(keys_nullable).with_flags(comm, local_flag);
        let lkc: Vec<&Column> = lkeys.iter().map(|(c, _)| *c).collect();
        let lkm: Vec<Option<&ValidityMask>> = lkeys.iter().map(|(_, m)| *m).collect();
        let rkc: Vec<&Column> = rkeys.iter().map(|(c, _)| *c).collect();
        let rkm: Vec<Option<&ValidityMask>> = rkeys.iter().map(|(_, m)| *m).collect();
        let lpacked = ops::PackedKeys::pack_masked(&lkc, &lkm, with_flags)?;
        let rpacked = ops::PackedKeys::pack_masked(&rkc, &rkm, with_flags)?;
        let mut lall: Vec<&Column> = lkc.clone();
        let mut lm: Vec<Option<&ValidityMask>> = lkm.clone();
        for (c, m) in &lpay {
            lall.push(c);
            lm.push(*m);
        }
        let mut rall: Vec<&Column> = rkc.clone();
        let mut rm: Vec<Option<&ValidityMask>> = rkm.clone();
        for (c, m) in &rpay {
            rall.push(c);
            rm.push(*m);
        }
        let (dl, dlm) = ops::shuffle_by_packed_nullable(comm, &lpacked, &lall, &lm)?;
        let (dr, drm) = ops::shuffle_by_packed_nullable(comm, &rpacked, &rall, &rm)?;
        let n_dl = dl.first().map_or(0, |c| c.len());
        let n_dr = dr.first().map_or(0, |c| c.len());
        let old_l = self.lcols.first().map_or(0, |c| c.len());
        let old_r = self.rcols.first().map_or(0, |c| c.len());
        let spill = ops::SpillCtx::new(ops::MemoryBudget::from_opt(None), comm.rank());
        if self.out.is_some() && n_dl == 0 && n_dr == 0 {
            // nothing arrived on this rank: the cached output still holds
            return Ok((
                self.out.clone().expect("cached join output"),
                0,
                (old_l + old_r) as u64,
            ));
        }
        let fast =
            self.out.is_some() && n_dr == 0 && matches!(how, JoinType::Inner | JoinType::Left);
        if fast {
            // build side unchanged: probe only the delta-left rows and
            // append the resulting suffix (batch pair order is sorted by
            // probe row, so new probe rows only ever extend the output)
            let (pairs, _) =
                join_partition(nk, &dl, &dlm, &self.rcols, &self.rmasks, how, true, &spill)?;
            let (keys_out, lout, rout) =
                assemble_outputs(nk, &dl, &dlm, &self.rcols, &self.rmasks, &pairs, how);
            let suffix = reassemble_join(
                out_schema,
                &lframe.schema,
                &rframe.schema,
                on,
                how,
                keys_out,
                lout,
                rout,
            );
            let out = self.out.as_mut().expect("cached join output");
            for (i, (a, b)) in out.cols.iter_mut().zip(&suffix.cols).enumerate() {
                let before = a.len();
                a.extend(b);
                extend_opt_mask(&mut out.masks[i], before, suffix.masks[i].as_ref(), b.len());
            }
            append_side(&mut self.lcols, &mut self.lmasks, &dl, &dlm);
            Ok((out.clone(), n_dl as u64, old_l as u64))
        } else {
            append_side(&mut self.lcols, &mut self.lmasks, &dl, &dlm);
            append_side(&mut self.rcols, &mut self.rmasks, &dr, &drm);
            let (pairs, _) = join_partition(
                nk,
                &self.lcols,
                &self.lmasks,
                &self.rcols,
                &self.rmasks,
                how,
                true,
                &spill,
            )?;
            let (keys_out, lout, rout) = assemble_outputs(
                nk,
                &self.lcols,
                &self.lmasks,
                &self.rcols,
                &self.rmasks,
                &pairs,
                how,
            );
            let out = reassemble_join(
                out_schema,
                &lframe.schema,
                &rframe.schema,
                on,
                how,
                keys_out,
                lout,
                rout,
            );
            self.out = Some(out.clone());
            Ok((out, (old_l + old_r + n_dl + n_dr) as u64, 0))
        }
    }
}

/// Map a join's `(keys_out, left_out, right_out)` columns back into the
/// output schema's column order — the batch interpreter's reassembly,
/// verbatim.
#[allow(clippy::too_many_arguments)]
fn reassemble_join(
    out_schema: Schema,
    lschema: &Schema,
    rschema: &Schema,
    on: &[(String, String)],
    how: JoinType,
    keys_out: Vec<NullableColumn>,
    lout: Vec<NullableColumn>,
    rout: Vec<NullableColumn>,
) -> LocalFrame {
    let mut cols = Vec::with_capacity(out_schema.len());
    let mut masks = Vec::with_capacity(out_schema.len());
    let mut push = |c: NullableColumn| {
        cols.push(c.values);
        masks.push(c.validity);
    };
    let mut keyed: Vec<Option<NullableColumn>> = keys_out.into_iter().map(Some).collect();
    let mut louts = lout.into_iter();
    for (n, _) in lschema.fields() {
        if let Some(j) = on.iter().position(|(lk, _)| lk == n) {
            push(keyed[j].take().expect("one key column per pair"));
        } else {
            push(louts.next().expect("left payload column"));
        }
    }
    if how.keeps_right_columns() {
        let mut routs = rout.into_iter();
        for (n, _) in rschema.fields() {
            if on.iter().any(|(_, rk)| rk == n) {
                continue;
            }
            push(routs.next().expect("right payload column"));
        }
    }
    LocalFrame {
        schema: out_schema,
        cols,
        masks,
    }
}

/// Incremental partitioned-window state: the accumulated post-shuffle rows
/// in shipped layout (frame columns + shipped expression columns), their
/// sort-key rows, and a per-partition cache of finished aggregate outputs.
/// A tick re-sorts (cheap, index-only) but re-*scans* only the partitions
/// it touched.
#[derive(Default)]
struct WinAbsorber {
    cols: Vec<Column>,
    masks: Vec<Option<ValidityMask>>,
    krows: Vec<KeyRow>,
    cache: FxHashMap<KeyRow, Vec<NullableColumn>>,
}

impl WinAbsorber {
    fn absorb(
        &mut self,
        out_schema: Schema,
        partition_by: &[String],
        order_by: &[(String, SortOrder)],
        aggs: &[WindowAgg],
        frame: &LocalFrame,
        comm: &Comm,
    ) -> Result<(LocalFrame, u64, u64)> {
        // pre-shuffle half of the batch interpreter's partitioned-window
        // block, over the delta rows only
        let mut expr_cols: Vec<Option<(Column, Option<ValidityMask>)>> =
            Vec::with_capacity(aggs.len());
        for a in aggs {
            expr_cols.push(if a.func.is_positional() {
                None
            } else {
                Some(eval_nullable(&a.input, frame)?)
            });
        }
        let key_refs: Vec<MaskedCol> = partition_by
            .iter()
            .map(|k| frame.masked(k))
            .collect::<Result<_>>()?;
        let kc: Vec<&Column> = key_refs.iter().map(|(c, _)| *c).collect();
        let km: Vec<Option<&ValidityMask>> = key_refs.iter().map(|(_, m)| *m).collect();
        let keys_nullable = partition_by
            .iter()
            .any(|k| frame.schema.nullable_of(k).unwrap_or(false));
        let with_flags = ops::KeyNullability::Static(keys_nullable)
            .with_flags(comm, km.iter().any(|m| m.is_some()));
        let packed = ops::PackedKeys::pack_masked(&kc, &km, with_flags)?;
        let mut all: Vec<&Column> = frame.cols.iter().collect();
        let mut masks: Vec<Option<&ValidityMask>> =
            frame.masks.iter().map(|m| m.as_ref()).collect();
        let mut ship_idx: Vec<Option<usize>> = Vec::with_capacity(aggs.len());
        for ec in &expr_cols {
            match ec {
                Some((c, m)) => {
                    ship_idx.push(Some(all.len()));
                    all.push(c);
                    masks.push(m.as_ref());
                }
                None => ship_idx.push(None),
            }
        }
        let (shuffled, shuffled_masks) =
            ops::shuffle_by_packed_nullable(comm, &packed, &all, &masks)?;
        let n_new = shuffled.first().map_or(0, |c| c.len());
        // delta sort-key rows, composed exactly like the batch sort
        let mut sort_cols: Vec<&Column> = Vec::new();
        let mut sort_masks: Vec<Option<&ValidityMask>> = Vec::new();
        let mut orders: Vec<SortOrder> = Vec::new();
        for k in partition_by {
            let i = frame.schema.index_of(k).expect("validated by typing");
            sort_cols.push(&shuffled[i]);
            sort_masks.push(shuffled_masks[i].as_ref());
            orders.push(SortOrder::Asc);
        }
        for (k, o) in order_by {
            let i = frame.schema.index_of(k).expect("validated by typing");
            sort_cols.push(&shuffled[i]);
            sort_masks.push(shuffled_masks[i].as_ref());
            orders.push(*o);
        }
        let new_krows = key_rows_nullable(&sort_cols, &sort_masks)?;
        let old_len = self.krows.len();
        self.krows.extend(new_krows);
        append_side(&mut self.cols, &mut self.masks, &shuffled, &shuffled_masks);
        let np = partition_by.len();
        // the stable sort keys arrival order within ties, and accumulated
        // arrival order equals batch arrival order — so this argsort is
        // the batch argsort
        let (idx, group_starts, breaks) = ops::partition_runs(&self.krows, np, &orders);
        let n_rows = idx.len();
        let mut outs_parts: Vec<Option<NullableColumn>> = vec![None; aggs.len()];
        let mut processed = n_new as u64;
        let mut avoided = 0u64;
        for (gi, &start) in group_starts.iter().enumerate() {
            let end = group_starts.get(gi + 1).copied().unwrap_or(n_rows);
            let part_idx = &idx[start..end];
            let pkey: KeyRow = self.krows[idx[start]][..np].to_vec();
            let touched = part_idx.iter().any(|&j| j >= old_len);
            let part_outs: Vec<NullableColumn> = if touched {
                processed += (end - start) as u64;
                let mut v = Vec::with_capacity(aggs.len());
                for (a, si) in aggs.iter().zip(&ship_idx) {
                    let out = match si {
                        Some(si) => {
                            let ec = self.cols[*si].take(part_idx);
                            let em = normalize_mask(
                                self.masks[*si].as_ref().map(|m| m.take(part_idx)),
                            );
                            ops::window_over_groups(
                                &ec,
                                em.as_ref(),
                                &a.frame,
                                &a.func,
                                &[0],
                                Some(&breaks[start..end]),
                            )?
                        }
                        None => {
                            let part = match &a.func {
                                WindowFunc::RowNumber => ops::row_numbers(end - start, 0),
                                WindowFunc::Rank => {
                                    ops::rank_from_breaks(&breaks[start..end])
                                }
                                other => unreachable!("non-positional {other} not shipped"),
                            };
                            NullableColumn::from_column(part)
                        }
                    };
                    v.push(out);
                }
                self.cache.insert(pkey, v.clone());
                v
            } else {
                avoided += (end - start) as u64;
                self.cache
                    .get(&pkey)
                    .context("stream: window cache miss on untouched partition")?
                    .clone()
            };
            for (acc, p) in outs_parts.iter_mut().zip(part_outs) {
                *acc = Some(match acc.take() {
                    None => p,
                    Some(a) => concat_nullable(a, &p),
                });
            }
        }
        let outs: Vec<NullableColumn> = aggs
            .iter()
            .zip(outs_parts)
            .map(|(a, o)| match o {
                Some(o) => o,
                None => NullableColumn::from_column(Column::new_empty(
                    out_schema
                        .dtype_of(&a.out)
                        .expect("window output column in schema"),
                )),
            })
            .collect();
        let ncols = frame.cols.len();
        let mut cols_sorted: Vec<Column> = Vec::with_capacity(ncols);
        let mut masks_sorted: Vec<Option<ValidityMask>> = Vec::with_capacity(ncols);
        for i in 0..ncols {
            cols_sorted.push(self.cols[i].take(&idx));
            masks_sorted.push(normalize_mask(self.masks[i].as_ref().map(|m| m.take(&idx))));
        }
        let sorted_frame = LocalFrame {
            schema: frame.schema.clone(),
            cols: cols_sorted,
            masks: masks_sorted,
        };
        let out = exec::assemble_window_output(sorted_frame, aggs, outs, out_schema)?;
        Ok((out, processed, avoided))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::expr::{col, lit, AggFn};
    use crate::frame::HiFrames;
    use crate::types::Value;

    fn t(pairs: Vec<(&str, Column)>) -> Table {
        Table::from_pairs(pairs).unwrap()
    }

    #[test]
    fn roles_and_explain_mark_stateful_nodes() {
        let hf = HiFrames::with_workers(2);
        let df = hf
            .table("events", t(vec![("k", Column::I64(vec![])), ("v", Column::I64(vec![]))]))
            .group_by(&["k"])
            .agg("s", AggFn::Sum, col("v"))
            .build();
        let s = hf.session(&df).unwrap();
        assert!(!s.is_fallback());
        let plan = s.explain_incremental();
        assert!(plan.contains("[stateful]"), "{plan}");
        assert!(plan.contains("[delta]"), "{plan}");
    }

    #[test]
    fn sort_rooted_plan_falls_back() {
        let hf = HiFrames::with_workers(2);
        let df = hf
            .table("events", t(vec![("k", Column::I64(vec![1, 2]))]))
            .sort_by_keys(&[("k", SortOrder::Desc)]);
        let s = hf.session(&df).unwrap();
        assert!(s.is_fallback());
        assert!(s.explain_incremental().contains("fallback reason"), "explain names the reason");
    }

    #[test]
    fn push_rejects_schema_and_null_violations() {
        let hf = HiFrames::with_workers(2);
        let df = hf
            .table("events", t(vec![("k", Column::I64(vec![])), ("v", Column::I64(vec![]))]))
            .group_by(&["k"])
            .agg("s", AggFn::Sum, col("v"))
            .build();
        let mut s = hf.session(&df).unwrap();
        let err = s
            .push("nope", t(vec![("k", Column::I64(vec![1]))]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("no appendable source"), "{err}");
        let err = s
            .push("events", t(vec![("k", Column::I64(vec![1]))]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("does not match"), "{err}");
        // nulls in a column the plan typed non-nullable are rejected
        let bad = t(vec![("k", Column::I64(vec![1, 0])), ("v", Column::I64(vec![5, 0]))])
            .with_null_mask("v", ValidityMask::from_bools(&[true, false]))
            .unwrap();
        let err = s.push("events", bad).unwrap_err().to_string();
        assert!(err.contains("non-nullable"), "{err}");
    }

    #[test]
    fn group_by_session_agrees_with_batch_over_three_ticks() {
        let hf = HiFrames::with_workers(2);
        let schema_df = hf
            .table("events", t(vec![("k", Column::I64(vec![])), ("v", Column::I64(vec![]))]))
            .group_by(&["k"])
            .agg("s", AggFn::Sum, col("v"))
            .agg("n", AggFn::Count, col("v"))
            .build();
        let mut s = hf.session(&schema_df).unwrap();
        let batches = [
            t(vec![("k", Column::I64(vec![1, 2, 1])), ("v", Column::I64(vec![10, 20, 30]))]),
            t(vec![("k", Column::I64(vec![3])), ("v", Column::I64(vec![7]))]),
            t(vec![("k", Column::I64(vec![2, 3])), ("v", Column::I64(vec![1, 2]))]),
        ];
        for b in batches {
            s.push("events", b).unwrap();
            let ticked = s.tick().unwrap();
            let oracle = s.collect_batch().unwrap();
            assert_eq!(ticked.num_rows(), oracle.num_rows());
            for i in 0..ticked.num_cols() {
                assert_eq!(ticked.column_at(i), oracle.column_at(i), "col {i}");
                assert_eq!(ticked.mask_at(i), oracle.mask_at(i), "mask {i}");
            }
        }
        assert_eq!(s.num_ticks(), 3);
        let r = s.last_report().unwrap();
        assert!(!r.fallback);
        assert!(r.rows_avoided > 0, "later ticks must avoid refolding old rows");
    }

    #[test]
    fn delta_append_filter_plan_accumulates_rows() {
        let hf = HiFrames::with_workers(3);
        let df = hf
            .table("events", t(vec![("v", Column::I64(vec![]))]))
            .filter(col("v").ge(lit(10i64)));
        let mut s = hf.session(&df).unwrap();
        assert!(!s.is_fallback());
        s.push("events", t(vec![("v", Column::I64(vec![5, 11, 3]))])).unwrap();
        let out = s.tick().unwrap();
        assert_eq!(out.column("v").unwrap().get(0), Value::I64(11));
        s.push("events", t(vec![("v", Column::I64(vec![42]))])).unwrap();
        let out = s.tick().unwrap();
        assert_eq!(out.num_rows(), 2);
        let oracle = s.collect_batch().unwrap();
        assert_eq!(out.column("v").unwrap(), oracle.column("v").unwrap());
    }
}
