//! Bench harness support — the criterion stand-in (offline image has no
//! criterion). Each `rust/benches/*.rs` target sets `harness = false` and
//! drives [`BenchTable`] to print the rows/series of one paper figure.
//!
//! Environment knobs (all benches):
//! * `HIFRAMES_BENCH_SCALE` — fraction of the paper's dataset sizes
//!   (default 0.01: e.g. Fig 8a filter 2B rows → 20M).
//! * `HIFRAMES_BENCH_WORKERS` — rank count for HiFrames/sparklike engines.
//! * `HIFRAMES_BENCH_REPS` — measured repetitions per cell (default 3).
//! * `HIFRAMES_BENCH_SMOKE` — CI smoke mode: clamp scale, 1 rep.
//! * `HIFRAMES_BENCH_OUT` — directory for the `BENCH_<figure>.json` result
//!   files (default `.`), uploaded as workflow artifacts by the CI
//!   `bench-smoke` job so the perf trajectory is tracked per PR.

use crate::metrics::{measure, Stats};

pub fn bench_scale() -> f64 {
    let scale = std::env::var("HIFRAMES_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.01);
    if bench_smoke() {
        // smoke runs bound every figure to seconds, not minutes
        scale.min(2e-4)
    } else {
        scale
    }
}

pub fn bench_workers() -> usize {
    std::env::var("HIFRAMES_BENCH_WORKERS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(crate::config::default_workers)
}

pub fn bench_reps() -> usize {
    std::env::var("HIFRAMES_BENCH_REPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(if bench_smoke() { 1 } else { 3 })
}

/// Quick-mode guard: CI smoke runs set `HIFRAMES_BENCH_SMOKE=1` to shrink
/// everything aggressively (see [`bench_scale`] / [`bench_reps`]).
pub fn bench_smoke() -> bool {
    std::env::var("HIFRAMES_BENCH_SMOKE").is_ok()
}

/// A named measurement cell: system × operation.
#[derive(Debug, Clone)]
pub struct Cell {
    pub system: String,
    pub op: String,
    pub stats: Stats,
    pub rows: usize,
}

/// Collects cells and prints a paper-style table with speedup columns.
pub struct BenchTable {
    pub title: String,
    pub baseline_system: String,
    cells: Vec<Cell>,
    counters: Vec<(String, u64)>,
}

impl BenchTable {
    pub fn new(title: &str, baseline_system: &str) -> BenchTable {
        eprintln!("\n=== {title} ===");
        BenchTable {
            title: title.to_string(),
            baseline_system: baseline_system.to_string(),
            cells: Vec::new(),
            counters: Vec::new(),
        }
    }

    /// Attach a named run-level counter (e.g. spill bytes) to the results
    /// file. Counters ride along in `BENCH_*.json` as a `"counters"`
    /// object; tables without counters serialize exactly as before.
    pub fn add_counter(&mut self, name: &str, value: u64) {
        eprintln!("  counter {name} = {value}");
        self.counters.push((name.to_string(), value));
    }

    /// Measure `f` and record it as `system` doing `op` over `rows` rows.
    pub fn run<R>(
        &mut self,
        system: &str,
        op: &str,
        rows: usize,
        warmup: usize,
        reps: usize,
        f: impl FnMut() -> R,
    ) {
        let stats = measure(warmup, reps, f);
        eprintln!(
            "  {system:<14} {op:<12} {:>12} rows  {}",
            rows,
            stats.display_ms()
        );
        self.cells.push(Cell {
            system: system.to_string(),
            op: op.to_string(),
            stats,
            rows,
        });
    }

    /// Record an externally-measured sample set.
    pub fn record(&mut self, system: &str, op: &str, rows: usize, samples: Vec<f64>) {
        let stats = Stats::from_samples(samples);
        eprintln!(
            "  {system:<14} {op:<12} {:>12} rows  {}",
            rows,
            stats.display_ms()
        );
        self.cells.push(Cell {
            system: system.to_string(),
            op: op.to_string(),
            stats,
            rows,
        });
    }

    pub fn cells(&self) -> &[Cell] {
        &self.cells
    }

    /// Median time of a cell, if present.
    pub fn median(&self, system: &str, op: &str) -> Option<f64> {
        self.cells
            .iter()
            .find(|c| c.system == system && c.op == op)
            .map(|c| c.stats.median)
    }

    /// Print the final figure table: one row per op, one column per system,
    /// plus speedup of every system relative to `baseline_system`.
    pub fn print_summary(&self) {
        println!("\n## {}", self.title);
        let mut ops: Vec<&str> = Vec::new();
        let mut systems: Vec<&str> = Vec::new();
        for c in &self.cells {
            if !ops.contains(&c.op.as_str()) {
                ops.push(&c.op);
            }
            if !systems.contains(&c.system.as_str()) {
                systems.push(&c.system);
            }
        }
        print!("{:<14}", "op");
        for s in &systems {
            print!(" | {s:>16}");
        }
        print!(" | {:>20}", format!("speedup vs {}", self.baseline_system));
        println!();
        for op in &ops {
            print!("{op:<14}");
            let base = self.median(&self.baseline_system, op);
            let mut best_speedup = None;
            for s in &systems {
                match self.median(s, op) {
                    Some(m) => {
                        print!(" | {:>14.1}ms", m * 1e3);
                        if let Some(b) = base {
                            if *s != self.baseline_system {
                                let sp = b / m;
                                if best_speedup.map_or(true, |x: f64| sp > x) {
                                    best_speedup = Some(sp);
                                }
                            }
                        }
                    }
                    None => print!(" | {:>16}", "-"),
                }
            }
            match (base, self.median("hiframes", op)) {
                (Some(b), Some(h)) => print!(" | {:>19.1}x", b / h),
                _ => print!(" | {:>20}", "-"),
            }
            println!();
        }
    }
}

impl BenchTable {
    /// Print the summary table and write the machine-readable results file
    /// (`BENCH_<figure>.json` under `HIFRAMES_BENCH_OUT`, default `.`).
    pub fn finish(&self, figure: &str) {
        self.print_summary();
        match self.write_json(figure) {
            Ok(path) => eprintln!("[{figure}] results written to {}", path.display()),
            Err(e) => eprintln!("[{figure}] could not write results JSON: {e}"),
        }
    }

    /// Serialize the collected cells as `BENCH_<figure>.json` under
    /// `HIFRAMES_BENCH_OUT` (default `.`). Note cargo runs bench binaries
    /// with the *package* root as cwd, so relative paths resolve under
    /// `rust/` — CI passes an absolute path.
    pub fn write_json(&self, figure: &str) -> std::io::Result<std::path::PathBuf> {
        let dir = std::env::var("HIFRAMES_BENCH_OUT").unwrap_or_else(|_| ".".into());
        self.write_json_to(std::path::Path::new(&dir), figure)
    }

    /// Serialize into an explicit directory (created if missing; hand-rolled
    /// JSON — the offline image has no serde). Times are seconds.
    pub fn write_json_to(
        &self,
        dir: &std::path::Path,
        figure: &str,
    ) -> std::io::Result<std::path::PathBuf> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("BENCH_{figure}.json"));
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str(&format!("  \"figure\": {},\n", json_str(figure)));
        s.push_str(&format!("  \"title\": {},\n", json_str(&self.title)));
        s.push_str(&format!(
            "  \"baseline\": {},\n",
            json_str(&self.baseline_system)
        ));
        s.push_str(&format!("  \"smoke\": {},\n", bench_smoke()));
        if !self.counters.is_empty() {
            let body: Vec<String> = self
                .counters
                .iter()
                .map(|(k, v)| format!("{}: {v}", json_str(k)))
                .collect();
            s.push_str(&format!("  \"counters\": {{{}}},\n", body.join(", ")));
        }
        s.push_str("  \"cells\": [\n");
        for (i, c) in self.cells.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"system\": {}, \"op\": {}, \"rows\": {}, \
                 \"median_s\": {:e}, \"mean_s\": {:e}, \"min_s\": {:e}, \
                 \"max_s\": {:e}, \"stddev_s\": {:e}, \"samples\": {}}}{}\n",
                json_str(&c.system),
                json_str(&c.op),
                c.rows,
                c.stats.median,
                c.stats.mean,
                c.stats.min,
                c.stats.max,
                c.stats.stddev,
                c.stats.samples.len(),
                if i + 1 < self.cells.len() { "," } else { "" },
            ));
        }
        s.push_str("  ]\n}\n");
        std::fs::write(&path, s)?;
        Ok(path)
    }
}

/// Minimal JSON string quoting (benches control their own names, so only
/// quotes/backslashes/control characters need care).
fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Parse and ignore the args cargo-bench passes (`--bench`, filters).
pub fn bench_main(figure: &str, run: impl FnOnce()) {
    let args: Vec<String> = std::env::args().skip(1).collect();
    // `cargo bench -- --list` must answer instantly for tooling.
    if args.iter().any(|a| a == "--list") {
        println!("{figure}: bench");
        return;
    }
    eprintln!(
        "[{figure}] scale={} workers={} reps={}",
        bench_scale(),
        bench_workers(),
        bench_reps()
    );
    run();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_collects_and_summarizes() {
        let mut t = BenchTable::new("test-table", "base");
        t.record("base", "op1", 100, vec![0.2, 0.2, 0.2]);
        t.record("hiframes", "op1", 100, vec![0.1, 0.1, 0.1]);
        assert_eq!(t.median("base", "op1"), Some(0.2));
        assert_eq!(t.median("hiframes", "op1"), Some(0.1));
        assert_eq!(t.median("nope", "op1"), None);
        t.print_summary(); // smoke: must not panic
        assert_eq!(t.cells().len(), 2);
    }

    #[test]
    fn json_escaping() {
        assert_eq!(json_str("plain"), "\"plain\"");
        assert_eq!(json_str("a\"b"), "\"a\\\"b\"");
        assert_eq!(json_str("a\\b"), "\"a\\\\b\"");
        assert_eq!(json_str("a\nb"), "\"a\\nb\"");
        assert_eq!(json_str("a\u{1}b"), "\"a\\u0001b\"");
    }

    #[test]
    fn write_json_emits_cells() {
        let dir = std::env::temp_dir().join("hiframes_bench_json_test");
        let mut t = BenchTable::new("json \"table\"", "base");
        t.record("base", "op1", 100, vec![0.2, 0.2]);
        t.record("hiframes", "op1", 100, vec![0.1]);
        let path = t.write_json_to(&dir, "testfig").unwrap();
        assert_eq!(
            path.file_name().unwrap().to_string_lossy(),
            "BENCH_testfig.json"
        );
        let body = std::fs::read_to_string(&path).unwrap();
        assert!(body.contains("\"figure\": \"testfig\""));
        assert!(body.contains("\"system\": \"hiframes\""));
        assert!(body.contains("\"samples\": 2"));
        assert!(body.contains("json \\\"table\\\""));
        // two cells → exactly one separating comma inside the array
        assert_eq!(body.matches("},").count(), 1);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn write_json_emits_counters_when_present() {
        let dir = std::env::temp_dir().join("hiframes_bench_json_counter_test");
        let mut t = BenchTable::new("counters", "base");
        t.record("base", "op1", 10, vec![0.1]);
        t.add_counter("bytes_spilled", 4096);
        t.add_counter("spill_passes", 3);
        let path = t.write_json_to(&dir, "testfig_ctr").unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        assert!(body.contains(
            "\"counters\": {\"bytes_spilled\": 4096, \"spill_passes\": 3},"
        ));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn env_defaults() {
        assert!(bench_scale() > 0.0);
        assert!(bench_workers() >= 1);
        assert!(bench_reps() >= 1);
    }

    #[test]
    fn run_measures() {
        let mut t = BenchTable::new("t2", "a");
        let mut x = 0u64;
        t.run("a", "inc", 1, 0, 2, || {
            x = x.wrapping_add(1);
            x
        });
        assert_eq!(t.cells().len(), 1);
        assert_eq!(t.cells()[0].stats.samples.len(), 2);
    }
}
