//! Aggregate expressions and their distributed decomposition.
//!
//! The paper's `aggregate(df, :key, :out = fn(expr), ...)` (§3.1, Table 1)
//! desugars each output into *(expression array, reduction function)* tuples
//! (§4.1). For distribution, non-trivial reductions are decomposed into
//! partial states that commute with the shuffle: `mean → (sum, count)`,
//! `var → (sum, sumsq, count)`. This is what makes local pre-aggregation
//! before the `alltoallv` legal (a §Perf optimization, ablated in
//! `benches/ablations.rs`).

use super::Expr;
use crate::table::Schema;
use crate::types::{DType, Value};
use anyhow::{bail, Result};
use std::fmt;

/// Reduction functions accepted by `aggregate`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AggFn {
    Sum,
    Count,
    Mean,
    Min,
    Max,
    Var,
    /// Count of *distinct* values of the expression (TPCx-BB Q25 needs
    /// `count(distinct ...)`). Not decomposable into bounded partials;
    /// pre-aggregation keeps a set per (key, column) instead.
    CountDistinct,
    /// First value encountered (used to carry group attributes through).
    First,
}

impl fmt::Display for AggFn {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            AggFn::Sum => "sum",
            AggFn::Count => "length",
            AggFn::Mean => "mean",
            AggFn::Min => "minimum",
            AggFn::Max => "maximum",
            AggFn::Var => "var",
            AggFn::CountDistinct => "count_distinct",
            AggFn::First => "first",
        };
        write!(f, "{s}")
    }
}

/// One output column of an aggregate: `:out = fn(expr)`.
#[derive(Debug, Clone, PartialEq)]
pub struct AggExpr {
    pub out: String,
    pub func: AggFn,
    pub input: Expr,
}

impl AggExpr {
    /// `:out = func(input)` — one output column of an aggregate
    /// (`AggExpr::new("xc", AggFn::Sum, col("x").lt(lit(1.0)))` is the
    /// paper's `:xc = sum(:x < 1.0)`).
    pub fn new(out: &str, func: AggFn, input: Expr) -> AggExpr {
        AggExpr {
            out: out.to_string(),
            func,
            input,
        }
    }

    /// May this output column be NULL? Order/moment statistics over a
    /// nullable input have no value for an all-null group; `sum`/`count`
    /// collapse to their empty value (0) instead.
    pub fn output_nullable(&self, schema: &Schema) -> Result<bool> {
        Ok(func_output_nullable(self.func) && self.input.nullable(schema)?)
    }

    /// Output dtype under `schema` (the "dummy calls … to find the output
    /// type" step of paper §4.1, done statically here).
    pub fn output_dtype(&self, schema: &Schema) -> Result<DType> {
        let in_dt = self.input.dtype(schema)?;
        Ok(match self.func {
            AggFn::Count | AggFn::CountDistinct => DType::I64,
            AggFn::Sum => match in_dt {
                DType::Bool | DType::I64 => DType::I64,
                DType::F64 => DType::F64,
                DType::Str => bail!("sum over String column"),
            },
            AggFn::Mean | AggFn::Var => {
                if !(in_dt.is_numeric() || in_dt == DType::Bool) {
                    bail!("{} over non-numeric column", self.func);
                }
                DType::F64
            }
            AggFn::Min | AggFn::Max => match in_dt {
                DType::I64 => DType::I64,
                DType::F64 => DType::F64,
                _ => bail!("{} over non-numeric column", self.func),
            },
            AggFn::First => in_dt,
        })
    }
}

impl fmt::Display for AggExpr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, ":{} = {}({})", self.out, self.func, self.input)
    }
}

/// Reductions whose all-null-group result is NULL rather than an empty
/// value (`sum`/`count`/`count_distinct` → 0).
pub fn func_output_nullable(func: AggFn) -> bool {
    matches!(
        func,
        AggFn::Mean | AggFn::Var | AggFn::Min | AggFn::Max | AggFn::First
    )
}

/// Running state of one reduction for one group — supports both one-pass
/// accumulation (post-shuffle) and partial-state merge (pre-aggregation).
#[derive(Debug, Clone, PartialEq)]
pub enum AggState {
    Sum { sum: f64, int: bool },
    Count { n: i64 },
    Mean { sum: f64, n: i64 },
    Min { v: f64, int: bool, n: i64 },
    Max { v: f64, int: bool, n: i64 },
    Var { sum: f64, sumsq: f64, n: i64 },
    CountDistinct { seen: std::collections::BTreeSet<i64> },
    First { v: Option<Value> },
}

impl AggState {
    /// The empty accumulator for `func` over a `input_dtype` column (the
    /// identity every partial-aggregation merge starts from).
    pub fn new(func: AggFn, input_dtype: DType) -> AggState {
        let int = input_dtype == DType::I64 || input_dtype == DType::Bool;
        match func {
            AggFn::Sum => AggState::Sum { sum: 0.0, int },
            AggFn::Count => AggState::Count { n: 0 },
            AggFn::Mean => AggState::Mean { sum: 0.0, n: 0 },
            AggFn::Min => AggState::Min {
                v: f64::INFINITY,
                int,
                n: 0,
            },
            AggFn::Max => AggState::Max {
                v: f64::NEG_INFINITY,
                int,
                n: 0,
            },
            AggFn::Var => AggState::Var {
                sum: 0.0,
                sumsq: 0.0,
                n: 0,
            },
            AggFn::CountDistinct => AggState::CountDistinct {
                seen: Default::default(),
            },
            AggFn::First => AggState::First { v: None },
        }
    }

    /// Has this state folded no rows at all? True only for groups whose
    /// inputs were entirely null (null rows are skipped) — the condition
    /// under which nullable reductions emit NULL. `Sum`/`Count` report
    /// `false`: their empty value (0) is a real result.
    pub fn is_empty(&self) -> bool {
        match self {
            AggState::Mean { n, .. }
            | AggState::Var { n, .. }
            | AggState::Min { n, .. }
            | AggState::Max { n, .. } => *n == 0,
            AggState::First { v } => v.is_none(),
            _ => false,
        }
    }

    /// Typed fast-path update from a column cell — avoids constructing a
    /// [`Value`] per row (§Perf: the hash-aggregate inner loop).
    #[inline]
    pub fn update_col(&mut self, col: &crate::column::Column, i: usize) {
        use crate::column::Column as C;
        match (self, col) {
            (AggState::Count { n }, _) => *n += 1,
            (AggState::Sum { sum, .. }, C::F64(v)) => *sum += v[i],
            (AggState::Sum { sum, .. }, C::I64(v)) => *sum += v[i] as f64,
            (AggState::Sum { sum, .. }, C::Bool(v)) => *sum += v[i] as i64 as f64,
            (AggState::Mean { sum, n }, C::F64(v)) => {
                *sum += v[i];
                *n += 1;
            }
            (AggState::Mean { sum, n }, C::I64(v)) => {
                *sum += v[i] as f64;
                *n += 1;
            }
            (AggState::Min { v: m, n, .. }, C::F64(v)) => {
                *m = m.min(v[i]);
                *n += 1;
            }
            (AggState::Min { v: m, n, .. }, C::I64(v)) => {
                *m = m.min(v[i] as f64);
                *n += 1;
            }
            (AggState::Max { v: m, n, .. }, C::F64(v)) => {
                *m = m.max(v[i]);
                *n += 1;
            }
            (AggState::Max { v: m, n, .. }, C::I64(v)) => {
                *m = m.max(v[i] as f64);
                *n += 1;
            }
            (AggState::Var { sum, sumsq, n }, C::F64(v)) => {
                let x = v[i];
                *sum += x;
                *sumsq += x * x;
                *n += 1;
            }
            (AggState::Var { sum, sumsq, n }, C::I64(v)) => {
                let x = v[i] as f64;
                *sum += x;
                *sumsq += x * x;
                *n += 1;
            }
            (AggState::CountDistinct { seen }, C::I64(v)) => {
                seen.insert(v[i]);
            }
            (s, c) => s.update(&c.get(i)),
        }
    }

    /// Fold one row's expression value into the state. Null inputs are
    /// skipped by every reduction (the row-engine counterpart of the
    /// masked columnar loop).
    pub fn update(&mut self, v: &Value) {
        if v.is_null() {
            return;
        }
        match self {
            AggState::Sum { sum, .. } => *sum += v.as_f64().unwrap_or(0.0),
            AggState::Count { n } => *n += 1,
            AggState::Mean { sum, n } => {
                *sum += v.as_f64().unwrap_or(0.0);
                *n += 1;
            }
            AggState::Min { v: m, n, .. } => {
                *m = m.min(v.as_f64().unwrap_or(f64::INFINITY));
                *n += 1;
            }
            AggState::Max { v: m, n, .. } => {
                *m = m.max(v.as_f64().unwrap_or(f64::NEG_INFINITY));
                *n += 1;
            }
            AggState::Var { sum, sumsq, n } => {
                let x = v.as_f64().unwrap_or(0.0);
                *sum += x;
                *sumsq += x * x;
                *n += 1;
            }
            AggState::CountDistinct { seen } => {
                // distinct over i64-representable values (keys / encoded cats)
                if let Some(x) = v.as_i64() {
                    seen.insert(x);
                }
            }
            AggState::First { v: slot } => {
                if slot.is_none() {
                    *slot = Some(v.clone());
                }
            }
        }
    }

    /// Merge another partial state (associative & commutative — the property
    /// the distributed pre-aggregation relies on; property-tested).
    pub fn merge(&mut self, other: &AggState) {
        match (self, other) {
            (AggState::Sum { sum: a, .. }, AggState::Sum { sum: b, .. }) => *a += b,
            (AggState::Count { n: a }, AggState::Count { n: b }) => *a += b,
            (AggState::Mean { sum: a, n: na }, AggState::Mean { sum: b, n: nb }) => {
                *a += b;
                *na += nb;
            }
            (
                AggState::Min { v: a, n: na, .. },
                AggState::Min { v: b, n: nb, .. },
            ) => {
                *a = a.min(*b);
                *na += nb;
            }
            (
                AggState::Max { v: a, n: na, .. },
                AggState::Max { v: b, n: nb, .. },
            ) => {
                *a = a.max(*b);
                *na += nb;
            }
            (
                AggState::Var {
                    sum: a,
                    sumsq: qa,
                    n: na,
                },
                AggState::Var {
                    sum: b,
                    sumsq: qb,
                    n: nb,
                },
            ) => {
                *a += b;
                *qa += qb;
                *na += nb;
            }
            (AggState::CountDistinct { seen: a }, AggState::CountDistinct { seen: b }) => {
                a.extend(b.iter().copied());
            }
            (AggState::First { v: a }, AggState::First { v: b }) => {
                if a.is_none() {
                    *a = b.clone();
                }
            }
            (a, b) => panic!("merge of mismatched agg states {a:?} vs {b:?}"),
        }
    }

    /// Finish the reduction to a scalar.
    pub fn finish(&self) -> Value {
        match self {
            AggState::Sum { sum, int } => {
                if *int {
                    Value::I64(*sum as i64)
                } else {
                    Value::F64(*sum)
                }
            }
            AggState::Count { n } => Value::I64(*n),
            AggState::Mean { sum, n } => Value::F64(if *n == 0 {
                f64::NAN
            } else {
                sum / *n as f64
            }),
            AggState::Min { v, int, n } => {
                if *int && *n > 0 {
                    Value::I64(*v as i64)
                } else {
                    Value::F64(*v)
                }
            }
            AggState::Max { v, int, n } => {
                if *int && *n > 0 {
                    Value::I64(*v as i64)
                } else {
                    Value::F64(*v)
                }
            }
            AggState::Var { sum, sumsq, n } => Value::F64(if *n == 0 {
                f64::NAN
            } else {
                let nf = *n as f64;
                let m = sum / nf;
                (sumsq / nf - m * m).max(0.0)
            }),
            AggState::CountDistinct { seen } => Value::I64(seen.len() as i64),
            AggState::First { v } => v.clone().unwrap_or(Value::I64(0)),
        }
    }

    /// Serialize partial state for the shuffle (pre-aggregation path).
    pub fn encode(&self, buf: &mut Vec<u8>) {
        match self {
            AggState::Sum { sum, .. } => buf.extend_from_slice(&sum.to_le_bytes()),
            AggState::Count { n } => buf.extend_from_slice(&n.to_le_bytes()),
            AggState::Mean { sum, n } => {
                buf.extend_from_slice(&sum.to_le_bytes());
                buf.extend_from_slice(&n.to_le_bytes());
            }
            AggState::Min { v, n, .. } | AggState::Max { v, n, .. } => {
                buf.extend_from_slice(&v.to_le_bytes());
                buf.extend_from_slice(&n.to_le_bytes());
            }
            AggState::Var { sum, sumsq, n } => {
                buf.extend_from_slice(&sum.to_le_bytes());
                buf.extend_from_slice(&sumsq.to_le_bytes());
                buf.extend_from_slice(&n.to_le_bytes());
            }
            AggState::CountDistinct { seen } => {
                buf.extend_from_slice(&(seen.len() as u64).to_le_bytes());
                for v in seen {
                    buf.extend_from_slice(&v.to_le_bytes());
                }
            }
            AggState::First { v } => {
                // only numeric Firsts survive the wire (enough for our queries)
                let x = v.as_ref().and_then(|v| v.as_f64()).unwrap_or(f64::NAN);
                buf.extend_from_slice(&x.to_le_bytes());
            }
        }
    }

    /// Deserialize a partial state previously written by [`encode`].
    pub fn decode(func: AggFn, input_dtype: DType, buf: &[u8], pos: &mut usize) -> AggState {
        let int = input_dtype == DType::I64 || input_dtype == DType::Bool;
        let f64_at = |p: &mut usize| {
            let mut b = [0u8; 8];
            b.copy_from_slice(&buf[*p..*p + 8]);
            *p += 8;
            f64::from_le_bytes(b)
        };
        match func {
            AggFn::Sum => AggState::Sum {
                sum: f64_at(pos),
                int,
            },
            AggFn::Count => {
                let mut b = [0u8; 8];
                b.copy_from_slice(&buf[*pos..*pos + 8]);
                *pos += 8;
                AggState::Count {
                    n: i64::from_le_bytes(b),
                }
            }
            AggFn::Mean => {
                let sum = f64_at(pos);
                let mut b = [0u8; 8];
                b.copy_from_slice(&buf[*pos..*pos + 8]);
                *pos += 8;
                AggState::Mean {
                    sum,
                    n: i64::from_le_bytes(b),
                }
            }
            AggFn::Min => {
                let v = f64_at(pos);
                let mut b = [0u8; 8];
                b.copy_from_slice(&buf[*pos..*pos + 8]);
                *pos += 8;
                AggState::Min {
                    v,
                    int,
                    n: i64::from_le_bytes(b),
                }
            }
            AggFn::Max => {
                let v = f64_at(pos);
                let mut b = [0u8; 8];
                b.copy_from_slice(&buf[*pos..*pos + 8]);
                *pos += 8;
                AggState::Max {
                    v,
                    int,
                    n: i64::from_le_bytes(b),
                }
            }
            AggFn::Var => {
                let sum = f64_at(pos);
                let sumsq = f64_at(pos);
                let mut b = [0u8; 8];
                b.copy_from_slice(&buf[*pos..*pos + 8]);
                *pos += 8;
                AggState::Var {
                    sum,
                    sumsq,
                    n: i64::from_le_bytes(b),
                }
            }
            AggFn::CountDistinct => {
                let mut b = [0u8; 8];
                b.copy_from_slice(&buf[*pos..*pos + 8]);
                *pos += 8;
                let n = u64::from_le_bytes(b) as usize;
                let mut seen = std::collections::BTreeSet::new();
                for _ in 0..n {
                    let mut b = [0u8; 8];
                    b.copy_from_slice(&buf[*pos..*pos + 8]);
                    *pos += 8;
                    seen.insert(i64::from_le_bytes(b));
                }
                AggState::CountDistinct { seen }
            }
            AggFn::First => {
                let x = f64_at(pos);
                AggState::First {
                    v: if x.is_nan() {
                        None
                    } else if int {
                        Some(Value::I64(x as i64))
                    } else {
                        Some(Value::F64(x))
                    },
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::expr::{col, lit};

    #[test]
    fn output_dtypes() {
        let s = Schema::of(&[("id", DType::I64), ("x", DType::F64)]);
        let a = AggExpr::new("n", AggFn::Count, col("id"));
        assert_eq!(a.output_dtype(&s).unwrap(), DType::I64);
        let a = AggExpr::new("s", AggFn::Sum, col("x"));
        assert_eq!(a.output_dtype(&s).unwrap(), DType::F64);
        let a = AggExpr::new("s", AggFn::Sum, col("id").lt(lit(3i64)));
        assert_eq!(a.output_dtype(&s).unwrap(), DType::I64); // sum of bools counts
        let a = AggExpr::new("m", AggFn::Mean, col("id"));
        assert_eq!(a.output_dtype(&s).unwrap(), DType::F64);
    }

    #[test]
    fn sum_mean_var() {
        let mut s = AggState::new(AggFn::Var, DType::F64);
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.update(&Value::F64(x));
        }
        match s.finish() {
            Value::F64(v) => assert!((v - 1.25).abs() < 1e-12),
            other => panic!("{other:?}"),
        }
        let mut m = AggState::new(AggFn::Mean, DType::I64);
        m.update(&Value::I64(2));
        m.update(&Value::I64(4));
        assert_eq!(m.finish(), Value::F64(3.0));
    }

    #[test]
    fn merge_equals_sequential() {
        // split-update-merge must equal one-pass update (pre-agg soundness)
        let data: Vec<f64> = (0..100).map(|i| (i as f64) * 0.37).collect();
        for func in [AggFn::Sum, AggFn::Count, AggFn::Mean, AggFn::Min, AggFn::Max, AggFn::Var] {
            let mut one = AggState::new(func, DType::F64);
            for x in &data {
                one.update(&Value::F64(*x));
            }
            let mut a = AggState::new(func, DType::F64);
            let mut b = AggState::new(func, DType::F64);
            for (i, x) in data.iter().enumerate() {
                if i % 3 == 0 {
                    a.update(&Value::F64(*x));
                } else {
                    b.update(&Value::F64(*x));
                }
            }
            a.merge(&b);
            let (va, vb) = (a.finish(), one.finish());
            let (fa, fb) = (va.as_f64().unwrap(), vb.as_f64().unwrap());
            assert!((fa - fb).abs() < 1e-9, "{func:?}: {fa} vs {fb}");
        }
    }

    #[test]
    fn count_distinct() {
        let mut s = AggState::new(AggFn::CountDistinct, DType::I64);
        for x in [1i64, 2, 2, 3, 1] {
            s.update(&Value::I64(x));
        }
        assert_eq!(s.finish(), Value::I64(3));
        let mut t = AggState::new(AggFn::CountDistinct, DType::I64);
        t.update(&Value::I64(3));
        t.update(&Value::I64(4));
        s.merge(&t);
        assert_eq!(s.finish(), Value::I64(4));
    }

    #[test]
    fn first_semantics() {
        let mut s = AggState::new(AggFn::First, DType::I64);
        s.update(&Value::I64(9));
        s.update(&Value::I64(7));
        assert_eq!(s.finish(), Value::I64(9));
    }

    #[test]
    fn update_col_equals_update_value() {
        use crate::column::Column;
        let cols = [
            Column::F64(vec![1.5, -2.0, 3.25]),
            Column::I64(vec![4, -5, 6]),
            Column::Bool(vec![true, false, true]),
        ];
        for func in [
            AggFn::Sum,
            AggFn::Count,
            AggFn::Mean,
            AggFn::Min,
            AggFn::Max,
            AggFn::Var,
            AggFn::CountDistinct,
            AggFn::First,
        ] {
            for col in &cols {
                if func == AggFn::Min || func == AggFn::Max {
                    if col.dtype() == DType::Bool {
                        continue;
                    }
                }
                let mut a = AggState::new(func, col.dtype());
                let mut b = AggState::new(func, col.dtype());
                for i in 0..col.len() {
                    a.update_col(col, i);
                    b.update(&col.get(i));
                }
                assert_eq!(a.finish(), b.finish(), "{func:?} over {:?}", col.dtype());
            }
        }
    }

    #[test]
    fn encode_decode_roundtrip() {
        let cases: Vec<(AggFn, DType, Vec<f64>)> = vec![
            (AggFn::Sum, DType::F64, vec![1.5, 2.5]),
            (AggFn::Count, DType::I64, vec![1.0, 1.0, 1.0]),
            (AggFn::Mean, DType::F64, vec![2.0, 4.0]),
            (AggFn::Min, DType::I64, vec![5.0, 3.0]),
            (AggFn::Max, DType::F64, vec![5.0, 3.0]),
            (AggFn::Var, DType::F64, vec![1.0, 2.0, 3.0]),
            (AggFn::CountDistinct, DType::I64, vec![1.0, 2.0, 2.0]),
            (AggFn::First, DType::F64, vec![42.0, 1.0]),
        ];
        for (func, dt, xs) in cases {
            let mut s = AggState::new(func, dt);
            for x in &xs {
                let v = if dt == DType::I64 {
                    Value::I64(*x as i64)
                } else {
                    Value::F64(*x)
                };
                s.update(&v);
            }
            let mut buf = Vec::new();
            s.encode(&mut buf);
            let mut pos = 0;
            let back = AggState::decode(func, dt, &buf, &mut pos);
            assert_eq!(pos, buf.len(), "{func:?} consumed {pos} of {}", buf.len());
            assert_eq!(back.finish(), s.finish(), "{func:?}");
        }
    }

    #[test]
    fn null_inputs_are_skipped_and_emptiness_tracked() {
        let mut s = AggState::new(AggFn::Mean, DType::F64);
        assert!(s.is_empty());
        s.update(&Value::Null(DType::F64));
        assert!(s.is_empty(), "null update must not count");
        s.update(&Value::F64(4.0));
        s.update(&Value::Null(DType::F64));
        assert!(!s.is_empty());
        assert_eq!(s.finish(), Value::F64(4.0));
        // count skips nulls too (SQL COUNT(col) semantics)
        let mut c = AggState::new(AggFn::Count, DType::I64);
        c.update(&Value::Null(DType::I64));
        c.update(&Value::I64(1));
        assert_eq!(c.finish(), Value::I64(1));
        // min emptiness survives encode/decode and merge
        let mut m = AggState::new(AggFn::Min, DType::I64);
        let mut buf = Vec::new();
        m.encode(&mut buf);
        let mut pos = 0;
        let back = AggState::decode(AggFn::Min, DType::I64, &buf, &mut pos);
        assert!(back.is_empty());
        let mut other = AggState::new(AggFn::Min, DType::I64);
        other.update(&Value::I64(-5));
        m.merge(&other);
        assert!(!m.is_empty());
        assert_eq!(m.finish(), Value::I64(-5));
        assert!(func_output_nullable(AggFn::Min));
        assert!(!func_output_nullable(AggFn::Sum));
    }

    #[test]
    fn empty_states() {
        assert_eq!(AggState::new(AggFn::Count, DType::I64).finish(), Value::I64(0));
        assert!(AggState::new(AggFn::Mean, DType::F64)
            .finish()
            .as_f64()
            .unwrap()
            .is_nan());
    }
}
