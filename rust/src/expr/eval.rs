//! Vectorized expression evaluation over a column environment.
//!
//! The environment abstraction matters: during SPMD execution each rank
//! evaluates the same expression over *its block* of every column (the
//! `expr_arr1 = map(.<, _df_x)` of the paper's Fig. 4), so the evaluator
//! never sees whole tables, only `name → &Column` lookups.

use super::Expr;
use crate::column::{self, combine_masks, normalize_mask, Column, ValidityMask};
use crate::types::Value;
use anyhow::{bail, Context, Result};

/// A source of named columns of one common length.
pub trait ColumnEnv {
    fn column(&self, name: &str) -> Option<&Column>;
    /// Number of rows in this environment's block (needed so literal-only
    /// expressions can still broadcast to the right length).
    fn num_rows(&self) -> usize;
    /// Validity mask of a column (`None` = fully valid). Environments
    /// without a null model keep the default.
    fn validity(&self, _name: &str) -> Option<&ValidityMask> {
        None
    }
}

/// Environment over a slice of `(name, column)` pairs (tests, small ops).
pub struct SliceEnv<'a> {
    pairs: &'a [(&'a str, &'a Column)],
    rows: usize,
}

impl<'a> SliceEnv<'a> {
    pub fn new(pairs: &'a [(&'a str, &'a Column)]) -> SliceEnv<'a> {
        let rows = pairs.first().map_or(0, |(_, c)| c.len());
        SliceEnv { pairs, rows }
    }
}

impl ColumnEnv for SliceEnv<'_> {
    fn column(&self, name: &str) -> Option<&Column> {
        self.pairs.iter().find(|(n, _)| *n == name).map(|(_, c)| *c)
    }
    fn num_rows(&self) -> usize {
        self.rows
    }
}

impl ColumnEnv for crate::table::Table {
    fn column(&self, name: &str) -> Option<&Column> {
        crate::table::Table::column(self, name)
    }
    fn num_rows(&self) -> usize {
        crate::table::Table::num_rows(self)
    }
    fn validity(&self, name: &str) -> Option<&ValidityMask> {
        crate::table::Table::mask(self, name)
    }
}

/// Evaluation result: a borrowed column (bare column refs — no copy), an
/// owned intermediate, or a scalar that has not been broadcast yet (lets
/// `col < 100.0` avoid materializing the literal). Keeping bare references
/// borrowed was a §Perf win: filter predicates no longer clone their input
/// columns.
enum Evaled<'a> {
    Borrowed(&'a Column),
    Owned(Column),
    Scalar(Value),
}

impl<'a> Evaled<'a> {
    fn as_col(&self) -> Option<&Column> {
        match self {
            Evaled::Borrowed(c) => Some(c),
            Evaled::Owned(c) => Some(c),
            Evaled::Scalar(_) => None,
        }
    }
}

/// Evaluate `expr` to a column of `env.num_rows()` rows.
pub fn eval(expr: &Expr, env: &dyn ColumnEnv) -> Result<Column> {
    match eval_inner(expr, env)? {
        Evaled::Borrowed(c) => Ok(c.clone()),
        Evaled::Owned(c) => Ok(c),
        Evaled::Scalar(v) => Ok(broadcast(&v, env.num_rows())),
    }
}

/// Evaluate a boolean predicate to a mask without cloning borrowed columns.
/// Null predicate lanes count as *false* (SQL `WHERE` semantics): the value
/// mask is ANDed with the predicate's validity.
pub fn eval_mask(expr: &Expr, env: &dyn ColumnEnv) -> Result<Vec<bool>> {
    let mut mask = match eval_inner(expr, env)? {
        Evaled::Borrowed(c) => c.as_bool().to_vec(),
        Evaled::Owned(Column::Bool(v)) => v,
        Evaled::Owned(c) => anyhow::bail!("predicate evaluated to {}", c.dtype()),
        Evaled::Scalar(Value::Bool(b)) => vec![b; env.num_rows()],
        Evaled::Scalar(v) => anyhow::bail!("predicate evaluated to scalar {v}"),
    };
    if let Some(valid) = eval_validity(expr, env)? {
        for (m, i) in mask.iter_mut().zip(0..valid.len()) {
            *m = *m && valid.get(i);
        }
    }
    Ok(mask)
}

/// Evaluate `expr` to `(values, validity)` — the nullable counterpart of
/// [`eval`]. Values under null lanes are scrubbed to dtype defaults so the
/// result is in canonical form.
pub fn eval_nullable(
    expr: &Expr,
    env: &dyn ColumnEnv,
) -> Result<(Column, Option<ValidityMask>)> {
    let mut values = eval(expr, env)?;
    let validity = eval_validity(expr, env)?;
    if let Some(m) = &validity {
        column::scrub_invalid(&mut values, m);
    }
    Ok((values, validity))
}

/// Validity of `expr`'s result (`None` = fully valid): element-wise
/// operators AND their operands' masks (null in ⇒ null out); `&&`/`||`
/// follow SQL's three-valued (Kleene) logic, where a dominant operand
/// (`FALSE AND x`, `TRUE OR x`) yields a *valid* result even when the
/// other side is null; `IS NULL` / `fill_null` are always valid.
pub fn eval_validity(expr: &Expr, env: &dyn ColumnEnv) -> Result<Option<ValidityMask>> {
    Ok(match expr {
        Expr::Col(name) => {
            if env.column(name).is_none() {
                bail!("unknown column :{name}");
            }
            env.validity(name).cloned()
        }
        Expr::Lit(_) | Expr::IsNull(_) | Expr::FillNull(..) => None,
        Expr::Arith(a, _, b) | Expr::Cmp(a, _, b) => normalize_mask(combine_masks(
            eval_validity(a, env)?.as_ref(),
            eval_validity(b, env)?.as_ref(),
        )),
        Expr::And(a, b) => kleene_validity(a, b, env, true)?,
        Expr::Or(a, b) => kleene_validity(a, b, env, false)?,
        Expr::Not(a) | Expr::Math(_, a) | Expr::BoolToInt(a) => eval_validity(a, env)?,
        Expr::Udf(_, args) => {
            let mut acc: Option<ValidityMask> = None;
            for a in args {
                acc = combine_masks(acc.as_ref(), eval_validity(a, env)?.as_ref());
            }
            normalize_mask(acc)
        }
    })
}

/// Kleene validity of `a AND b` / `a OR b`: the result is valid where both
/// operands are, *and* where one valid operand dominates (false for AND,
/// true for OR — `FALSE AND NULL = FALSE`, `TRUE OR NULL = TRUE`). Needs
/// the operand values, so it only runs when a mask is actually present.
fn kleene_validity(
    a: &Expr,
    b: &Expr,
    env: &dyn ColumnEnv,
    is_and: bool,
) -> Result<Option<ValidityMask>> {
    let va = eval_validity(a, env)?;
    let vb = eval_validity(b, env)?;
    if va.is_none() && vb.is_none() {
        return Ok(None);
    }
    let ca = eval(a, env)?;
    let cb = eval(b, env)?;
    let (xs, ys) = (ca.as_bool(), cb.as_bool());
    let mut m = ValidityMask::new_null(xs.len());
    for i in 0..xs.len() {
        let av = va.as_ref().map_or(true, |v| v.get(i));
        let bv = vb.as_ref().map_or(true, |v| v.get(i));
        let dominant = |valid: bool, value: bool| valid && (value != is_and);
        if (av && bv) || dominant(av, xs[i]) || dominant(bv, ys[i]) {
            m.set(i, true);
        }
    }
    Ok(normalize_mask(Some(m)))
}

fn broadcast(v: &Value, n: usize) -> Column {
    match v {
        Value::I64(x) => Column::I64(vec![*x; n]),
        Value::F64(x) => Column::F64(vec![*x; n]),
        Value::Bool(x) => Column::Bool(vec![*x; n]),
        Value::Str(x) => Column::Str(vec![x.clone(); n]),
        Value::Null(_) => panic!("broadcast of a bare null literal"),
    }
}

fn eval_inner<'a>(expr: &Expr, env: &'a dyn ColumnEnv) -> Result<Evaled<'a>> {
    Ok(match expr {
        Expr::Col(name) => Evaled::Borrowed(
            env.column(name)
                .with_context(|| format!("unknown column :{name}"))?,
        ),
        Expr::Lit(v) => Evaled::Scalar(v.clone()),
        Expr::Arith(a, op, b) => {
            let (ea, eb) = (eval_inner(a, env)?, eval_inner(b, env)?);
            match (ea.as_col(), eb.as_col(), &ea, &eb) {
                (Some(x), Some(y), _, _) => {
                    // Int64 division/modulo by a nullable divisor must not
                    // trap on the scrubbed default 0: evaluate under the
                    // divisor's validity (the lanes are null-out anyway).
                    // Only the I64 ÷ I64 route can trap, so the extra
                    // validity walk is gated on it.
                    let hazardous = matches!(
                        op,
                        column::ArithOp::Div | column::ArithOp::Mod
                    ) && matches!((x, y), (Column::I64(_), Column::I64(_)));
                    if hazardous {
                        let bv = eval_validity(b, env)?;
                        Evaled::Owned(column::arith_masked(x, y, *op, bv.as_ref()))
                    } else {
                        Evaled::Owned(column::arith(x, y, *op))
                    }
                }
                (Some(x), None, _, Evaled::Scalar(s)) => {
                    // the scalar is the divisor here, never the null hazard
                    let sf = s.as_f64().context("non-numeric literal in arith")?;
                    Evaled::Owned(column::arith_scalar(x, sf, *op, false))
                }
                (None, Some(y), Evaled::Scalar(s), _) => {
                    let sf = s.as_f64().context("non-numeric literal in arith")?;
                    // `scalar % nullable_int_col` traps through the Int64
                    // scalar fast path — same hazard, same mask treatment
                    if matches!(op, column::ArithOp::Mod)
                        && matches!(y, Column::I64(_))
                    {
                        let bv = eval_validity(b, env)?;
                        Evaled::Owned(column::arith_scalar_masked(
                            y,
                            sf,
                            *op,
                            true,
                            bv.as_ref(),
                        ))
                    } else {
                        Evaled::Owned(column::arith_scalar(y, sf, *op, true))
                    }
                }
                _ => {
                    // fold_constants normally removes this; evaluate anyway
                    match expr.fold_constants() {
                        Expr::Lit(v) => Evaled::Scalar(v),
                        _ => bail!("scalar-scalar arith failed to fold"),
                    }
                }
            }
        }
        Expr::Cmp(a, op, b) => {
            let (ea, eb) = (eval_inner(a, env)?, eval_inner(b, env)?);
            match (ea.as_col(), eb.as_col(), &ea, &eb) {
                (Some(x), Some(y), _, _) => Evaled::Owned(column::compare(x, y, *op)),
                (Some(x), None, _, Evaled::Scalar(s)) => {
                    Evaled::Owned(cmp_scalar(x, s, *op, false)?)
                }
                (None, Some(y), Evaled::Scalar(s), _) => {
                    Evaled::Owned(cmp_scalar(y, s, *op, true)?)
                }
                _ => match expr.fold_constants() {
                    Expr::Lit(v) => Evaled::Scalar(v),
                    _ => bail!("scalar-scalar cmp failed to fold"),
                },
            }
        }
        Expr::And(a, b) => {
            let (ea, eb) = (eval_inner(a, env)?, eval_inner(b, env)?);
            match (ea.as_col(), eb.as_col()) {
                (Some(x), Some(y)) => Evaled::Owned(column::bool_and(x, y)),
                _ => bail!("boolean && over non-columns (fold constants first)"),
            }
        }
        Expr::Or(a, b) => {
            let (ea, eb) = (eval_inner(a, env)?, eval_inner(b, env)?);
            match (ea.as_col(), eb.as_col()) {
                (Some(x), Some(y)) => Evaled::Owned(column::bool_or(x, y)),
                _ => bail!("boolean || over non-columns (fold constants first)"),
            }
        }
        Expr::Not(a) => {
            let ea = eval_inner(a, env)?;
            match ea.as_col() {
                Some(x) => Evaled::Owned(column::bool_not(x)),
                None => bail!("! over non-column"),
            }
        }
        Expr::Math(f, a) => {
            let ea = eval_inner(a, env)?;
            match ea.as_col() {
                Some(x) => Evaled::Owned(column::math(x, *f)),
                None => match expr.fold_constants() {
                    Expr::Lit(v) => Evaled::Scalar(v),
                    _ => bail!("math over scalar failed to fold"),
                },
            }
        }
        Expr::BoolToInt(a) => {
            let ea = eval_inner(a, env)?;
            match ea.as_col() {
                Some(x) => Evaled::Owned(column::bool_to_i64(x)),
                None => bail!("bool_to_int over non-column"),
            }
        }
        Expr::IsNull(a) => {
            // values are irrelevant: IS NULL is the negated validity
            let valid = eval_validity(a, env)?;
            Evaled::Owned(column::is_null_column(valid.as_ref(), env.num_rows()))
        }
        Expr::FillNull(a, v) => {
            let (col, valid) = eval_nullable(a, env)?;
            Evaled::Owned(column::fill_null(&col, valid.as_ref(), v)?)
        }
        Expr::Udf(udf, args) => {
            let cols: Vec<Vec<f64>> = args
                .iter()
                .map(|a| eval(a, env).map(|c| c.to_f64_vec()))
                .collect::<Result<_>>()?;
            let n = cols.first().map_or(env.num_rows(), |c| c.len());
            let mut out = Vec::with_capacity(n);
            let mut argv = vec![0.0f64; cols.len()];
            for i in 0..n {
                for (j, c) in cols.iter().enumerate() {
                    argv[j] = c[i];
                }
                out.push((udf.func)(&argv));
            }
            Evaled::Owned(Column::F64(out))
        }
    })
}

fn cmp_scalar(
    c: &Column,
    s: &Value,
    op: column::CmpOp,
    scalar_on_left: bool,
) -> Result<Column> {
    use column::CmpOp::*;
    // `5 < x` is `x > 5` — flip when the scalar is the left operand.
    let op = if scalar_on_left {
        match op {
            Lt => Gt,
            Le => Ge,
            Gt => Lt,
            Ge => Le,
            Eq => Eq,
            Ne => Ne,
        }
    } else {
        op
    };
    Ok(match s {
        Value::Str(st) => column::compare_scalar_str(c, st, op),
        other => {
            let f = other
                .as_f64()
                .context("non-comparable literal in comparison")?;
            column::compare_scalar_f64(c, f, op)
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::expr::{col, lit, Udf};

    fn env_cols() -> Vec<(String, Column)> {
        vec![
            ("id".to_string(), Column::I64(vec![1, 2, 3, 4])),
            ("x".to_string(), Column::F64(vec![0.5, 1.5, 2.5, 3.5])),
            (
                "name".to_string(),
                Column::Str(vec!["a".into(), "b".into(), "a".into(), "c".into()]),
            ),
        ]
    }

    fn with_env<R>(f: impl FnOnce(&dyn ColumnEnv) -> R) -> R {
        let cols = env_cols();
        let pairs: Vec<(&str, &Column)> =
            cols.iter().map(|(n, c)| (n.as_str(), c)).collect();
        let env = SliceEnv::new(&pairs);
        f(&env)
    }

    #[test]
    fn column_and_literal() {
        with_env(|env| {
            assert_eq!(eval(&col("id"), env).unwrap().as_i64(), &[1, 2, 3, 4]);
            assert_eq!(eval(&lit(7i64), env).unwrap().as_i64(), &[7, 7, 7, 7]);
        });
    }

    #[test]
    fn arith_broadcast() {
        with_env(|env| {
            let e = col("x").mul(lit(2.0)).add(lit(1.0));
            assert_eq!(eval(&e, env).unwrap().as_f64(), &[2.0, 4.0, 6.0, 8.0]);
            // scalar on the left of a subtraction
            let e = lit(10.0).sub(col("x"));
            assert_eq!(eval(&e, env).unwrap().as_f64(), &[9.5, 8.5, 7.5, 6.5]);
        });
    }

    #[test]
    fn comparison_and_boolean() {
        with_env(|env| {
            let e = col("id").lt(lit(3i64)).and(col("x").gt(lit(1.0)));
            assert_eq!(
                eval(&e, env).unwrap().as_bool(),
                &[false, true, false, false]
            );
            // flipped scalar comparison: 2 <= id
            let e = lit(2i64).le(col("id"));
            assert_eq!(
                eval(&e, env).unwrap().as_bool(),
                &[false, true, true, true]
            );
        });
    }

    #[test]
    fn string_predicate() {
        with_env(|env| {
            let e = col("name").eq_(lit("a"));
            assert_eq!(
                eval(&e, env).unwrap().as_bool(),
                &[true, false, true, false]
            );
        });
    }

    #[test]
    fn mixed_dtype_compare() {
        with_env(|env| {
            let e = col("id").gt(col("x")); // i64 vs f64
            assert_eq!(
                eval(&e, env).unwrap().as_bool(),
                &[true, true, true, true]
            );
        });
    }

    #[test]
    fn udf_elementwise() {
        with_env(|env| {
            // the paper's WMA-style lambda: (a + 2b) / 4
            let u = Udf::new("wma2", |a| (a[0] + 2.0 * a[1]) / 4.0);
            let e = Expr::Udf(u, vec![col("id"), col("x")]);
            let out = eval(&e, env).unwrap();
            assert_eq!(out.as_f64(), &[0.5, 1.25, 2.0, 2.75]);
        });
    }

    #[test]
    fn bool_to_int_counts() {
        with_env(|env| {
            let e = Expr::BoolToInt(Box::new(col("name").eq_(lit("a"))));
            assert_eq!(eval(&e, env).unwrap().as_i64(), &[1, 0, 1, 0]);
        });
    }

    #[test]
    fn unknown_column_errors() {
        with_env(|env| {
            assert!(eval(&col("nope"), env).is_err());
        });
    }

    #[test]
    fn nullable_divisor_is_masked_not_trapped() {
        // the window/fill arithmetic hazard: a nullable Int64 divisor holds
        // the scrubbed default 0 under its null lanes — division must
        // evaluate under the mask instead of trapping
        let t = crate::table::Table::from_pairs(vec![
            ("a", Column::I64(vec![10, 20, 30])),
            ("b", Column::I64(vec![2, 0, 5])),
        ])
        .unwrap()
        .with_null_mask("b", ValidityMask::from_bools(&[true, false, true]))
        .unwrap();
        let (vals, mask) = eval_nullable(&col("a").div(col("b")), &t).unwrap();
        assert_eq!(vals.as_i64(), &[5, 0, 6]); // null lane re-scrubbed
        assert_eq!(mask.unwrap().to_bools(), vec![true, false, true]);
        let (vals, mask) = eval_nullable(&col("a").rem(col("b")), &t).unwrap();
        assert_eq!(vals.as_i64(), &[0, 0, 0]);
        assert_eq!(mask.unwrap().to_bools(), vec![true, false, true]);
        // scalar-on-left modulo hits the Int64 scalar fast path — the mask
        // treatment must cover it too
        let (vals, mask) = eval_nullable(&lit(7i64).rem(col("b")), &t).unwrap();
        assert_eq!(vals.as_i64(), &[1, 0, 2]);
        assert_eq!(mask.unwrap().to_bools(), vec![true, false, true]);
        // fill_null first keeps working as the documented workaround
        let (vals, mask) =
            eval_nullable(&col("a").div(col("b").fill_null(1i64)), &t).unwrap();
        assert_eq!(vals.as_i64(), &[5, 20, 6]);
        assert!(mask.is_none());
    }
}
