//! Expression ASTs over data-frame columns.
//!
//! This is the analogue of the paper's Macro-Pass expression handling
//! (§4.1): user-level expressions refer to columns by name and mix scalar
//! and array operations; HiFrames rewrites scalar operators into
//! element-wise ones (`replace_opr_vector`) and column references into the
//! underlying arrays (`replace_column_refs`). Here the rewrite target is a
//! vectorized evaluator over [`Column`]s, so *any* expression — including
//! user-defined functions — compiles to the same array kernels. That is the
//! paper's Fig. 9/10 point: HiFrames UDFs cost nothing because there is one
//! language end-to-end.

mod agg;
mod eval;

pub use agg::{func_output_nullable, AggExpr, AggFn, AggState};
pub use eval::{eval, eval_mask, eval_nullable, eval_validity, ColumnEnv, SliceEnv};

use crate::column::{ArithOp, CmpOp, MathFn};
use crate::table::Schema;
use crate::types::{DType, Value, WindowFrame, WindowFunc};
use anyhow::{bail, Result};
use std::collections::BTreeSet;
use std::fmt;
use std::sync::Arc;

/// Scalar user-defined function applied element-wise (all-numeric).
#[derive(Clone)]
pub struct Udf {
    pub name: String,
    pub func: Arc<dyn Fn(&[f64]) -> f64 + Send + Sync>,
}

impl Udf {
    pub fn new(name: &str, f: impl Fn(&[f64]) -> f64 + Send + Sync + 'static) -> Udf {
        Udf {
            name: name.to_string(),
            func: Arc::new(f),
        }
    }
}

impl fmt::Debug for Udf {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "udf:{}", self.name)
    }
}

/// An expression tree over columns of one data frame.
#[derive(Debug, Clone)]
pub enum Expr {
    /// Column reference `:name`.
    Col(String),
    /// Literal scalar, broadcast to column length.
    Lit(Value),
    /// Element-wise arithmetic.
    Arith(Box<Expr>, ArithOp, Box<Expr>),
    /// Element-wise comparison → Bool column.
    Cmp(Box<Expr>, CmpOp, Box<Expr>),
    And(Box<Expr>, Box<Expr>),
    Or(Box<Expr>, Box<Expr>),
    Not(Box<Expr>),
    /// Unary math map (`log`, `exp`, `sqrt`, …).
    Math(MathFn, Box<Expr>),
    /// Cast Bool → Int64 (inserted by desugaring of `sum(:x == k)`).
    BoolToInt(Box<Expr>),
    /// `IS NULL` — true exactly where the operand's validity bit is clear.
    /// Never null itself.
    IsNull(Box<Expr>),
    /// `fill_null(expr, v)` — replace null lanes with the literal,
    /// producing a fully valid column of the operand's dtype.
    FillNull(Box<Expr>, Value),
    /// Scalar UDF applied element-wise over evaluated argument columns.
    Udf(Udf, Vec<Expr>),
}

impl PartialEq for Expr {
    fn eq(&self, other: &Self) -> bool {
        use Expr::*;
        match (self, other) {
            (Col(a), Col(b)) => a == b,
            (Lit(a), Lit(b)) => a == b,
            (Arith(a1, o1, b1), Arith(a2, o2, b2)) => o1 == o2 && a1 == a2 && b1 == b2,
            (Cmp(a1, o1, b1), Cmp(a2, o2, b2)) => o1 == o2 && a1 == a2 && b1 == b2,
            (And(a1, b1), And(a2, b2)) | (Or(a1, b1), Or(a2, b2)) => a1 == a2 && b1 == b2,
            (Not(a), Not(b)) => a == b,
            (Math(f1, a), Math(f2, b)) => f1 == f2 && a == b,
            (BoolToInt(a), BoolToInt(b)) => a == b,
            (IsNull(a), IsNull(b)) => a == b,
            (FillNull(a1, v1), FillNull(a2, v2)) => a1 == a2 && v1 == v2,
            (Udf(u1, a1), Udf(u2, a2)) => u1.name == u2.name && a1 == a2,
            _ => false,
        }
    }
}

/// An expression wrapped in a window frame + function — what the
/// expression-level window sugar (`col("x").shift(1)`, `.cum_sum()`, …)
/// produces. It is *not* an [`Expr`]: window computations need neighbor
/// rows (communication), so they live on their own plan node
/// ([`crate::ir::Plan::Window`]) rather than inside the element-wise
/// evaluator. Consume one with `df.with_window(out, wexpr)` or the fluent
/// `df.window()…agg_expr(out, wexpr)` builder.
#[derive(Debug, Clone, PartialEq)]
pub struct WindowExpr {
    pub input: Expr,
    pub frame: WindowFrame,
    pub func: WindowFunc,
}

/// Builders mirroring the paper's surface syntax.
pub fn col(name: &str) -> Expr {
    Expr::Col(name.to_string())
}
pub fn lit<V: Into<Value>>(v: V) -> Expr {
    Expr::Lit(v.into())
}

impl Expr {
    pub fn add(self, rhs: Expr) -> Expr {
        Expr::Arith(Box::new(self), ArithOp::Add, Box::new(rhs))
    }
    pub fn sub(self, rhs: Expr) -> Expr {
        Expr::Arith(Box::new(self), ArithOp::Sub, Box::new(rhs))
    }
    pub fn mul(self, rhs: Expr) -> Expr {
        Expr::Arith(Box::new(self), ArithOp::Mul, Box::new(rhs))
    }
    pub fn div(self, rhs: Expr) -> Expr {
        Expr::Arith(Box::new(self), ArithOp::Div, Box::new(rhs))
    }
    pub fn rem(self, rhs: Expr) -> Expr {
        Expr::Arith(Box::new(self), ArithOp::Mod, Box::new(rhs))
    }
    pub fn lt(self, rhs: Expr) -> Expr {
        Expr::Cmp(Box::new(self), CmpOp::Lt, Box::new(rhs))
    }
    pub fn le(self, rhs: Expr) -> Expr {
        Expr::Cmp(Box::new(self), CmpOp::Le, Box::new(rhs))
    }
    pub fn gt(self, rhs: Expr) -> Expr {
        Expr::Cmp(Box::new(self), CmpOp::Gt, Box::new(rhs))
    }
    pub fn ge(self, rhs: Expr) -> Expr {
        Expr::Cmp(Box::new(self), CmpOp::Ge, Box::new(rhs))
    }
    pub fn eq_(self, rhs: Expr) -> Expr {
        Expr::Cmp(Box::new(self), CmpOp::Eq, Box::new(rhs))
    }
    pub fn ne_(self, rhs: Expr) -> Expr {
        Expr::Cmp(Box::new(self), CmpOp::Ne, Box::new(rhs))
    }
    pub fn and(self, rhs: Expr) -> Expr {
        Expr::And(Box::new(self), Box::new(rhs))
    }
    pub fn or(self, rhs: Expr) -> Expr {
        Expr::Or(Box::new(self), Box::new(rhs))
    }
    pub fn not(self) -> Expr {
        Expr::Not(Box::new(self))
    }
    pub fn math(self, f: MathFn) -> Expr {
        Expr::Math(f, Box::new(self))
    }
    /// `IS NULL` predicate over this expression.
    pub fn is_null(self) -> Expr {
        Expr::IsNull(Box::new(self))
    }
    /// `IS NOT NULL` (sugar for `!is_null()`).
    pub fn is_not_null(self) -> Expr {
        Expr::Not(Box::new(Expr::IsNull(Box::new(self))))
    }
    /// Replace null lanes with `v`.
    pub fn fill_null<V: Into<Value>>(self, v: V) -> Expr {
        Expr::FillNull(Box::new(self), v.into())
    }

    // ---- window sugar: these leave the element-wise expression world and
    // ---- produce a [`WindowExpr`] for `df.with_window` / `df.window()` ----

    /// The value `offset` rows back (positive = lag, negative = lead); the
    /// out-of-range edge rows are NULL.
    pub fn shift(self, offset: i64) -> WindowExpr {
        WindowExpr {
            input: self,
            frame: WindowFrame::Shift(offset),
            func: WindowFunc::Value,
        }
    }

    /// `lag(n)` — the value `n` rows earlier (`shift(n)`).
    pub fn lag(self, n: usize) -> WindowExpr {
        self.shift(n as i64)
    }

    /// `lead(n)` — the value `n` rows later (`shift(-n)`).
    pub fn lead(self, n: usize) -> WindowExpr {
        self.shift(-(n as i64))
    }

    /// Running (cumulative) sum up to and including the current row.
    pub fn cum_sum(self) -> WindowExpr {
        WindowExpr {
            input: self,
            frame: WindowFrame::CumulativeToCurrent,
            func: WindowFunc::Sum,
        }
    }

    /// `func` over the rolling frame `[i-preceding, i+following]`.
    pub fn rolling(self, preceding: usize, following: usize, func: WindowFunc) -> WindowExpr {
        WindowExpr {
            input: self,
            frame: WindowFrame::Rolling {
                preceding,
                following,
            },
            func,
        }
    }

    /// The set of column names this expression reads — the liveness facts
    /// the DataFrame-Pass uses for pushdown validity and column pruning.
    pub fn columns_used(&self) -> BTreeSet<String> {
        let mut out = BTreeSet::new();
        self.visit_cols(&mut |c| {
            out.insert(c.to_string());
        });
        out
    }

    fn visit_cols(&self, f: &mut impl FnMut(&str)) {
        match self {
            Expr::Col(c) => f(c),
            Expr::Lit(_) => {}
            Expr::Arith(a, _, b) | Expr::Cmp(a, _, b) | Expr::And(a, b) | Expr::Or(a, b) => {
                a.visit_cols(f);
                b.visit_cols(f);
            }
            Expr::Not(a)
            | Expr::Math(_, a)
            | Expr::BoolToInt(a)
            | Expr::IsNull(a)
            | Expr::FillNull(a, _) => a.visit_cols(f),
            Expr::Udf(_, args) => args.iter().for_each(|a| a.visit_cols(f)),
        }
    }

    /// Rewrite column references through `rename` (used when pushing a
    /// predicate through a join: output names → input-table names).
    pub fn rename_columns(&self, rename: &dyn Fn(&str) -> Option<String>) -> Option<Expr> {
        Some(match self {
            Expr::Col(c) => Expr::Col(rename(c)?),
            Expr::Lit(v) => Expr::Lit(v.clone()),
            Expr::Arith(a, op, b) => Expr::Arith(
                Box::new(a.rename_columns(rename)?),
                *op,
                Box::new(b.rename_columns(rename)?),
            ),
            Expr::Cmp(a, op, b) => Expr::Cmp(
                Box::new(a.rename_columns(rename)?),
                *op,
                Box::new(b.rename_columns(rename)?),
            ),
            Expr::And(a, b) => Expr::And(
                Box::new(a.rename_columns(rename)?),
                Box::new(b.rename_columns(rename)?),
            ),
            Expr::Or(a, b) => Expr::Or(
                Box::new(a.rename_columns(rename)?),
                Box::new(b.rename_columns(rename)?),
            ),
            Expr::Not(a) => Expr::Not(Box::new(a.rename_columns(rename)?)),
            Expr::Math(f, a) => Expr::Math(*f, Box::new(a.rename_columns(rename)?)),
            Expr::BoolToInt(a) => Expr::BoolToInt(Box::new(a.rename_columns(rename)?)),
            Expr::IsNull(a) => Expr::IsNull(Box::new(a.rename_columns(rename)?)),
            Expr::FillNull(a, v) => {
                Expr::FillNull(Box::new(a.rename_columns(rename)?), v.clone())
            }
            Expr::Udf(u, args) => Expr::Udf(
                u.clone(),
                args.iter()
                    .map(|a| a.rename_columns(rename))
                    .collect::<Option<Vec<_>>>()?,
            ),
        })
    }

    /// Static result dtype under `schema` — the Macro-Pass type annotation
    /// step ("types of all variables should be available", §4.1).
    pub fn dtype(&self, schema: &Schema) -> Result<DType> {
        match self {
            Expr::Col(c) => schema
                .dtype_of(c)
                .ok_or_else(|| anyhow::anyhow!("unknown column :{c} in {schema}")),
            Expr::Lit(v) => Ok(v.dtype()),
            Expr::Arith(a, _, b) => {
                let (ta, tb) = (a.dtype(schema)?, b.dtype(schema)?);
                match ta.promote(tb) {
                    Some(t) => Ok(t),
                    None => bail!("arith on non-numeric dtypes {ta} and {tb}"),
                }
            }
            Expr::Cmp(a, _, b) => {
                let (ta, tb) = (a.dtype(schema)?, b.dtype(schema)?);
                let ok = ta.promote(tb).is_some()
                    || (ta == DType::Str && tb == DType::Str)
                    || (ta == DType::Bool && tb == DType::Bool);
                if !ok {
                    bail!("cannot compare {ta} with {tb}");
                }
                Ok(DType::Bool)
            }
            Expr::And(a, b) | Expr::Or(a, b) => {
                for (side, e) in [("lhs", a), ("rhs", b)] {
                    if e.dtype(schema)? != DType::Bool {
                        bail!("boolean op {side} is not Bool");
                    }
                }
                Ok(DType::Bool)
            }
            Expr::Not(a) => {
                if a.dtype(schema)? != DType::Bool {
                    bail!("! applied to non-Bool");
                }
                Ok(DType::Bool)
            }
            Expr::Math(f, a) => {
                let t = a.dtype(schema)?;
                if !t.is_numeric() {
                    bail!("math fn on non-numeric dtype {t}");
                }
                match (f, t) {
                    (MathFn::Abs | MathFn::Neg, DType::I64) => Ok(DType::I64),
                    _ => Ok(DType::F64),
                }
            }
            Expr::BoolToInt(a) => {
                if a.dtype(schema)? != DType::Bool {
                    bail!("bool_to_int on non-Bool");
                }
                Ok(DType::I64)
            }
            Expr::IsNull(a) => {
                let _ = a.dtype(schema)?; // operand must type-check
                Ok(DType::Bool)
            }
            Expr::FillNull(a, v) => {
                let t = a.dtype(schema)?;
                let vt = v.dtype();
                let ok = vt == t || (t.is_numeric() && vt.is_numeric());
                if v.is_null() || !ok {
                    bail!("fill_null: cannot fill {t} with {v:?}");
                }
                Ok(t)
            }
            Expr::Udf(_, args) => {
                for a in args {
                    let t = a.dtype(schema)?;
                    if !t.is_numeric() {
                        bail!("UDF argument has non-numeric dtype {t}");
                    }
                }
                Ok(DType::F64)
            }
        }
    }

    /// Static nullability under `schema` — mirrors the runtime validity
    /// propagation: a column reference is nullable iff its schema field is;
    /// element-wise operators propagate (null in ⇒ null out); `IS NULL` and
    /// `fill_null` are never null.
    pub fn nullable(&self, schema: &Schema) -> Result<bool> {
        Ok(match self {
            Expr::Col(c) => schema
                .nullable_of(c)
                .ok_or_else(|| anyhow::anyhow!("unknown column :{c} in {schema}"))?,
            Expr::Lit(_) => false,
            Expr::IsNull(_) | Expr::FillNull(..) => false,
            Expr::Arith(a, _, b) | Expr::Cmp(a, _, b) | Expr::And(a, b) | Expr::Or(a, b) => {
                a.nullable(schema)? || b.nullable(schema)?
            }
            Expr::Not(a) | Expr::Math(_, a) | Expr::BoolToInt(a) => a.nullable(schema)?,
            Expr::Udf(_, args) => {
                let mut any = false;
                for a in args {
                    any |= a.nullable(schema)?;
                }
                any
            }
        })
    }

    /// Constant folding — one of the optimizations HiFrames gets "for free"
    /// from the host compiler (paper §4.3); we implement the analogue.
    pub fn fold_constants(&self) -> Expr {
        match self {
            Expr::Arith(a, op, b) => {
                let (a, b) = (a.fold_constants(), b.fold_constants());
                if let (Expr::Lit(x), Expr::Lit(y)) = (&a, &b) {
                    if let (Some(xf), Some(yf)) = (x.as_f64(), y.as_f64()) {
                        let r = match op {
                            ArithOp::Add => xf + yf,
                            ArithOp::Sub => xf - yf,
                            ArithOp::Mul => xf * yf,
                            ArithOp::Div => xf / yf,
                            ArithOp::Mod => xf % yf,
                        };
                        // preserve integer-ness when both sides were ints
                        if x.dtype() == DType::I64
                            && y.dtype() == DType::I64
                            && *op != ArithOp::Div
                        {
                            return Expr::Lit(Value::I64(r as i64));
                        }
                        return Expr::Lit(Value::F64(r));
                    }
                }
                Expr::Arith(Box::new(a), *op, Box::new(b))
            }
            Expr::Cmp(a, op, b) => {
                let (a, b) = (a.fold_constants(), b.fold_constants());
                if let (Expr::Lit(x), Expr::Lit(y)) = (&a, &b) {
                    if let (Some(xf), Some(yf)) = (x.as_f64(), y.as_f64()) {
                        let r = match op {
                            CmpOp::Lt => xf < yf,
                            CmpOp::Le => xf <= yf,
                            CmpOp::Gt => xf > yf,
                            CmpOp::Ge => xf >= yf,
                            CmpOp::Eq => xf == yf,
                            CmpOp::Ne => xf != yf,
                        };
                        return Expr::Lit(Value::Bool(r));
                    }
                }
                Expr::Cmp(Box::new(a), *op, Box::new(b))
            }
            Expr::And(a, b) => {
                let (a, b) = (a.fold_constants(), b.fold_constants());
                match (&a, &b) {
                    (Expr::Lit(Value::Bool(true)), _) => b,
                    (_, Expr::Lit(Value::Bool(true))) => a,
                    (Expr::Lit(Value::Bool(false)), _) | (_, Expr::Lit(Value::Bool(false))) => {
                        Expr::Lit(Value::Bool(false))
                    }
                    _ => Expr::And(Box::new(a), Box::new(b)),
                }
            }
            Expr::Or(a, b) => {
                let (a, b) = (a.fold_constants(), b.fold_constants());
                match (&a, &b) {
                    (Expr::Lit(Value::Bool(false)), _) => b,
                    (_, Expr::Lit(Value::Bool(false))) => a,
                    (Expr::Lit(Value::Bool(true)), _) | (_, Expr::Lit(Value::Bool(true))) => {
                        Expr::Lit(Value::Bool(true))
                    }
                    _ => Expr::Or(Box::new(a), Box::new(b)),
                }
            }
            Expr::Not(a) => {
                let a = a.fold_constants();
                if let Expr::Lit(Value::Bool(v)) = a {
                    return Expr::Lit(Value::Bool(!v));
                }
                if let Expr::Not(inner) = a {
                    return *inner;
                }
                Expr::Not(Box::new(a))
            }
            Expr::Math(f, a) => {
                let a = a.fold_constants();
                if let Expr::Lit(v) = &a {
                    if let Some(x) = v.as_f64() {
                        let r = match f {
                            MathFn::Log => x.ln(),
                            MathFn::Exp => x.exp(),
                            MathFn::Sqrt => x.sqrt(),
                            MathFn::Sin => x.sin(),
                            MathFn::Cos => x.cos(),
                            MathFn::Abs => x.abs(),
                            MathFn::Neg => -x,
                        };
                        return Expr::Lit(Value::F64(r));
                    }
                }
                Expr::Math(*f, Box::new(a))
            }
            Expr::BoolToInt(a) => Expr::BoolToInt(Box::new(a.fold_constants())),
            Expr::IsNull(a) => {
                let a = a.fold_constants();
                // a non-null literal is never null
                if let Expr::Lit(v) = &a {
                    if !v.is_null() {
                        return Expr::Lit(Value::Bool(false));
                    }
                }
                Expr::IsNull(Box::new(a))
            }
            Expr::FillNull(a, v) => Expr::FillNull(Box::new(a.fold_constants()), v.clone()),
            Expr::Udf(u, args) => Expr::Udf(
                u.clone(),
                args.iter().map(|a| a.fold_constants()).collect(),
            ),
            other => other.clone(),
        }
    }
}

impl fmt::Display for Expr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Expr::Col(c) => write!(f, ":{c}"),
            Expr::Lit(v) => write!(f, "{v}"),
            Expr::Arith(a, op, b) => {
                let s = match op {
                    ArithOp::Add => "+",
                    ArithOp::Sub => "-",
                    ArithOp::Mul => "*",
                    ArithOp::Div => "/",
                    ArithOp::Mod => "%",
                };
                write!(f, "({a} {s} {b})")
            }
            Expr::Cmp(a, op, b) => {
                let s = match op {
                    CmpOp::Lt => "<",
                    CmpOp::Le => "<=",
                    CmpOp::Gt => ">",
                    CmpOp::Ge => ">=",
                    CmpOp::Eq => "==",
                    CmpOp::Ne => "!=",
                };
                write!(f, "({a} {s} {b})")
            }
            Expr::And(a, b) => write!(f, "({a} && {b})"),
            Expr::Or(a, b) => write!(f, "({a} || {b})"),
            Expr::Not(a) => write!(f, "!{a}"),
            Expr::Math(m, a) => write!(f, "{m:?}({a})"),
            Expr::BoolToInt(a) => write!(f, "int({a})"),
            Expr::IsNull(a) => write!(f, "is_null({a})"),
            Expr::FillNull(a, v) => write!(f, "fill_null({a}, {v})"),
            Expr::Udf(u, args) => {
                write!(f, "{}(", u.name)?;
                for (i, a) in args.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{a}")?;
                }
                write!(f, ")")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn columns_used_collects() {
        let e = col("a").add(col("b")).lt(lit(1.0)).and(col("c").gt(lit(0i64)));
        let used = e.columns_used();
        assert_eq!(
            used.into_iter().collect::<Vec<_>>(),
            vec!["a".to_string(), "b".into(), "c".into()]
        );
    }

    #[test]
    fn rename_total_or_none() {
        let e = col("amount").gt(lit(100.0));
        let r = e
            .rename_columns(&|c| (c == "amount").then(|| "o_amount".to_string()))
            .unwrap();
        assert_eq!(r.columns_used().into_iter().next().unwrap(), "o_amount");
        // a reference that cannot be renamed makes the whole rewrite fail
        let e2 = col("amount").add(col("other")).gt(lit(1.0));
        assert!(e2
            .rename_columns(&|c| (c == "amount").then(|| "x".to_string()))
            .is_none());
    }

    #[test]
    fn dtype_inference() {
        let s = Schema::of(&[
            ("id", DType::I64),
            ("x", DType::F64),
            ("name", DType::Str),
        ]);
        assert_eq!(col("id").add(lit(1i64)).dtype(&s).unwrap(), DType::I64);
        assert_eq!(col("id").add(col("x")).dtype(&s).unwrap(), DType::F64);
        assert_eq!(col("x").lt(lit(1.0)).dtype(&s).unwrap(), DType::Bool);
        assert_eq!(
            col("name").eq_(lit("a")).dtype(&s).unwrap(),
            DType::Bool
        );
        assert!(col("name").add(lit(1i64)).dtype(&s).is_err());
        assert!(col("missing").dtype(&s).is_err());
        assert!(col("x").and(col("id").lt(lit(0i64))).dtype(&s).is_err());
    }

    #[test]
    fn fold_constants_arith() {
        let e = lit(2i64).add(lit(3i64)).mul(col("x"));
        let f = e.fold_constants();
        assert_eq!(f, lit(5i64).mul(col("x")));
        let e = lit(1.0).div(lit(4.0));
        assert_eq!(e.fold_constants(), lit(0.25));
    }

    #[test]
    fn fold_constants_bool() {
        let e = lit(true).and(col("x").lt(lit(1.0)));
        assert_eq!(e.fold_constants(), col("x").lt(lit(1.0)));
        let e = lit(false).and(col("x").lt(lit(1.0)));
        assert_eq!(e.fold_constants(), lit(false));
        let e = col("x").lt(lit(1.0)).or(lit(true));
        assert_eq!(e.fold_constants(), lit(true));
        let e = col("m").not().not();
        assert_eq!(e.fold_constants(), col("m"));
    }

    #[test]
    fn fold_constants_cmp_math() {
        assert_eq!(lit(2.0).lt(lit(3.0)).fold_constants(), lit(true));
        assert_eq!(lit(4.0).math(MathFn::Sqrt).fold_constants(), lit(2.0));
    }

    #[test]
    fn display_roundtrips_structure() {
        let e = col("a").add(lit(1i64)).lt(col("b"));
        assert_eq!(format!("{e}"), "((:a + 1) < :b)");
    }

    #[test]
    fn window_sugar_builds_frames() {
        let w = col("x").lag(2);
        assert_eq!(w.frame, WindowFrame::Shift(2));
        assert_eq!(w.func, WindowFunc::Value);
        assert_eq!(w.input, col("x"));
        assert_eq!(col("x").lead(1).frame, WindowFrame::Shift(-1));
        assert_eq!(col("x").shift(-3).frame, WindowFrame::Shift(-3));
        let c = col("a").add(col("b")).cum_sum();
        assert_eq!(c.frame, WindowFrame::CumulativeToCurrent);
        assert_eq!(c.func, WindowFunc::Sum);
        let r = col("x").rolling(2, 0, WindowFunc::Mean);
        assert_eq!(
            r.frame,
            WindowFrame::Rolling {
                preceding: 2,
                following: 0
            }
        );
    }

    #[test]
    fn udf_equality_by_name() {
        let u1 = Expr::Udf(Udf::new("f", |a| a[0]), vec![col("x")]);
        let u2 = Expr::Udf(Udf::new("f", |a| a[0] * 2.0), vec![col("x")]);
        assert_eq!(u1, u2); // structural equality is by name
    }
}
