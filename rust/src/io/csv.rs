//! CSV reader/writer (header row, comma-separated, no quoting of commas —
//! enough for examples and external-tool interchange; HFS is the real
//! storage format).

use crate::column::Column;
use crate::table::{Schema, Table};
use crate::types::DType;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Write `table` as CSV with a `name:dtype` header line. The format has no
/// null representation — `fill_null`/`drop_null` nullable data first.
pub fn write_csv(path: &Path, table: &Table) -> Result<()> {
    for (i, (name, _)) in table.schema().fields().iter().enumerate() {
        if table.mask_at(i).is_some() {
            bail!("csv write: column {name} has nulls — fill_null/drop_null first");
        }
    }
    let mut out = String::new();
    let header: Vec<String> = table
        .schema()
        .fields()
        .iter()
        .map(|(n, t)| format!("{n}:{t}"))
        .collect();
    out.push_str(&header.join(","));
    out.push('\n');
    for i in 0..table.num_rows() {
        let row: Vec<String> = table.row(i).iter().map(|v| v.to_string()).collect();
        out.push_str(&row.join(","));
        out.push('\n');
    }
    std::fs::write(path, out).with_context(|| format!("csv write {}", path.display()))
}

/// Read a CSV produced by [`write_csv`] (typed header).
pub fn read_csv(path: &Path) -> Result<Table> {
    let text =
        std::fs::read_to_string(path).with_context(|| format!("csv read {}", path.display()))?;
    let mut lines = text.lines();
    let header = lines.next().context("csv: empty file")?;
    let mut fields = Vec::new();
    for part in header.split(',') {
        let Some((name, ty)) = part.split_once(':') else {
            bail!("csv: header field {part:?} missing :dtype");
        };
        let dt = match ty {
            "Int64" => DType::I64,
            "Float64" => DType::F64,
            "Bool" => DType::Bool,
            "String" => DType::Str,
            other => bail!("csv: unknown dtype {other}"),
        };
        fields.push((name.to_string(), dt));
    }
    let schema = Schema::new(fields);
    let mut cols: Vec<Column> = schema
        .fields()
        .iter()
        .map(|(_, t)| Column::new_empty(*t))
        .collect();
    for (lineno, line) in lines.enumerate() {
        if line.is_empty() {
            continue;
        }
        let parts: Vec<&str> = line.split(',').collect();
        if parts.len() != cols.len() {
            bail!(
                "csv line {}: {} fields, expected {}",
                lineno + 2,
                parts.len(),
                cols.len()
            );
        }
        for ((col, part), (_, dt)) in cols.iter_mut().zip(&parts).zip(schema.fields()) {
            match dt {
                DType::I64 => col.push(&crate::types::Value::I64(
                    part.parse().with_context(|| format!("csv i64 {part:?}"))?,
                )),
                DType::F64 => col.push(&crate::types::Value::F64(
                    part.parse().with_context(|| format!("csv f64 {part:?}"))?,
                )),
                DType::Bool => col.push(&crate::types::Value::Bool(match *part {
                    "true" => true,
                    "false" => false,
                    other => bail!("csv bool {other:?}"),
                })),
                DType::Str => col.push(&crate::types::Value::Str(part.to_string())),
            }
        }
    }
    Table::new(schema, cols)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("hiframes_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.csv");
        let t = Table::from_pairs(vec![
            ("id", Column::I64(vec![1, 2])),
            ("x", Column::F64(vec![0.5, 1.5])),
            ("ok", Column::Bool(vec![true, false])),
            ("s", Column::Str(vec!["a".into(), "b".into()])),
        ])
        .unwrap();
        write_csv(&p, &t).unwrap();
        let back = read_csv(&p).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn rejects_malformed() {
        let dir = std::env::temp_dir().join("hiframes_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.csv");
        std::fs::write(&p, "a:Int64\n1,2\n").unwrap();
        assert!(read_csv(&p).is_err());
        std::fs::write(&p, "a:Nope\n").unwrap();
        assert!(read_csv(&p).is_err());
        std::fs::write(&p, "a\n").unwrap();
        assert!(read_csv(&p).is_err());
    }
}
