//! I/O substrates.
//!
//! * [`hfs`] — the HDF5 stand-in: a chunked columnar binary format with
//!   per-column hyperslab reads, so ranks read exactly their 1D_BLOCK slice
//!   (the paper's `H5Sselect_hyperslab` / `H5Dread` pattern, Fig. 5).
//! * [`csv`] — plain-text interchange for examples and external tools.

pub mod csv;
pub mod hfs;

pub use csv::{read_csv, write_csv};
pub use hfs::{read_hfs_schema, read_hfs_slice, read_hfs_table, write_hfs};
