//! HFS — "HiFrames storage": a minimal chunked columnar file format.
//!
//! Layout (all integers little-endian):
//! ```text
//!   magic   "HFS1"                     (4 bytes)
//!   u32     ncols
//!   u64     nrows
//!   per column:
//!     u16   name length, name bytes (UTF-8)
//!     u8    dtype tag (column codec tags)
//!     u64   payload byte offset (from file start)
//!     u64   payload byte length
//!   payloads…  (fixed-width dtypes: raw LE values; Str: u32-len + bytes)
//! ```
//!
//! Fixed-width columns support `read_hfs_slice(offset, len)` — a true
//! hyperslab read that seeks and reads only the requested rows, which is
//! what makes parallel 1D_BLOCK source reads scale. String columns fall
//! back to a scan (documented; TPCx-BB string columns are dictionary-coded
//! to I64 before being stored where performance matters).

use crate::column::Column;
use crate::table::{Schema, Table};
use crate::types::DType;
use anyhow::{bail, Context, Result};
use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"HFS1";

fn dtype_tag(dt: DType) -> u8 {
    match dt {
        DType::I64 => 0,
        DType::F64 => 1,
        DType::Bool => 2,
        DType::Str => 3,
    }
}

fn tag_dtype(tag: u8) -> Result<DType> {
    Ok(match tag {
        0 => DType::I64,
        1 => DType::F64,
        2 => DType::Bool,
        3 => DType::Str,
        t => bail!("hfs: bad dtype tag {t}"),
    })
}

/// Write `table` to `path`. The HFS format has no validity-mask section,
/// so nullable data is rejected rather than silently flattening nulls into
/// dtype defaults — `fill_null` (or `drop_null`) before writing.
pub fn write_hfs(path: &Path, table: &Table) -> Result<()> {
    for (i, (name, _)) in table.schema().fields().iter().enumerate() {
        if table.mask_at(i).is_some() {
            bail!("hfs write: column {name} has nulls — fill_null/drop_null first");
        }
    }
    let f = File::create(path).with_context(|| format!("hfs create {}", path.display()))?;
    let mut w = BufWriter::new(f);
    w.write_all(MAGIC)?;
    w.write_all(&(table.num_cols() as u32).to_le_bytes())?;
    w.write_all(&(table.num_rows() as u64).to_le_bytes())?;

    // header size: fixed part + per-column entries
    let mut header_len = 4 + 4 + 8;
    for (name, _) in table.schema().fields() {
        header_len += 2 + name.len() + 1 + 8 + 8;
    }
    // compute payload offsets
    let mut offsets = Vec::new();
    let mut cursor = header_len as u64;
    for col in table.columns() {
        let len = payload_len(col) as u64;
        offsets.push((cursor, len));
        cursor += len;
    }
    for ((name, dt), (off, len)) in table.schema().fields().iter().zip(&offsets) {
        w.write_all(&(name.len() as u16).to_le_bytes())?;
        w.write_all(name.as_bytes())?;
        w.write_all(&[dtype_tag(*dt)])?;
        w.write_all(&off.to_le_bytes())?;
        w.write_all(&len.to_le_bytes())?;
    }
    for col in table.columns() {
        write_payload(&mut w, col)?;
    }
    w.flush()?;
    Ok(())
}

fn payload_len(col: &Column) -> usize {
    match col {
        Column::I64(v) => v.len() * 8,
        Column::F64(v) => v.len() * 8,
        Column::Bool(v) => v.len(),
        Column::Str(v) => v.iter().map(|s| 4 + s.len()).sum(),
    }
}

fn write_payload<W: Write>(w: &mut W, col: &Column) -> Result<()> {
    match col {
        Column::I64(v) => {
            for x in v {
                w.write_all(&x.to_le_bytes())?;
            }
        }
        Column::F64(v) => {
            for x in v {
                w.write_all(&x.to_le_bytes())?;
            }
        }
        Column::Bool(v) => {
            for &b in v {
                w.write_all(&[b as u8])?;
            }
        }
        Column::Str(v) => {
            for s in v {
                w.write_all(&(s.len() as u32).to_le_bytes())?;
                w.write_all(s.as_bytes())?;
            }
        }
    }
    Ok(())
}

struct ColEntry {
    name: String,
    dtype: DType,
    offset: u64,
    len: u64,
}

fn read_header(r: &mut (impl Read + Seek)) -> Result<(u64, Vec<ColEntry>)> {
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("hfs: bad magic {magic:?}");
    }
    let mut b4 = [0u8; 4];
    r.read_exact(&mut b4)?;
    let ncols = u32::from_le_bytes(b4) as usize;
    let mut b8 = [0u8; 8];
    r.read_exact(&mut b8)?;
    let nrows = u64::from_le_bytes(b8);
    let mut cols = Vec::with_capacity(ncols);
    for _ in 0..ncols {
        let mut b2 = [0u8; 2];
        r.read_exact(&mut b2)?;
        let nlen = u16::from_le_bytes(b2) as usize;
        let mut name = vec![0u8; nlen];
        r.read_exact(&mut name)?;
        let mut tag = [0u8; 1];
        r.read_exact(&mut tag)?;
        r.read_exact(&mut b8)?;
        let offset = u64::from_le_bytes(b8);
        r.read_exact(&mut b8)?;
        let len = u64::from_le_bytes(b8);
        cols.push(ColEntry {
            name: String::from_utf8(name).context("hfs: column name utf-8")?,
            dtype: tag_dtype(tag[0])?,
            offset,
            len,
        });
    }
    Ok((nrows, cols))
}

/// Read just the schema and row count (the paper's `get_h5_size` step).
pub fn read_hfs_schema(path: &Path) -> Result<(Schema, usize)> {
    let f = File::open(path).with_context(|| format!("hfs open {}", path.display()))?;
    let mut r = BufReader::new(f);
    let (nrows, cols) = read_header(&mut r)?;
    let schema = Schema::new(cols.iter().map(|c| (c.name.clone(), c.dtype)).collect());
    Ok((schema, nrows as usize))
}

/// Read rows `[start, start+len)` of the named columns — the hyperslab read
/// each rank performs for its 1D_BLOCK slice.
pub fn read_hfs_slice(
    path: &Path,
    columns: &[&str],
    start: usize,
    len: usize,
) -> Result<Vec<Column>> {
    let f = File::open(path).with_context(|| format!("hfs open {}", path.display()))?;
    let mut r = BufReader::new(f);
    let (nrows, entries) = read_header(&mut r)?;
    if start + len > nrows as usize {
        bail!("hfs: slice [{start}, {}) out of {nrows} rows", start + len);
    }
    let mut out = Vec::with_capacity(columns.len());
    for want in columns {
        let e = entries
            .iter()
            .find(|e| e.name == *want)
            .with_context(|| format!("hfs: no column {want}"))?;
        let col = match e.dtype {
            DType::I64 => {
                r.seek(SeekFrom::Start(e.offset + (start * 8) as u64))?;
                let mut buf = vec![0u8; len * 8];
                r.read_exact(&mut buf)?;
                Column::I64(
                    buf.chunks_exact(8)
                        .map(|c| i64::from_le_bytes(c.try_into().unwrap()))
                        .collect(),
                )
            }
            DType::F64 => {
                r.seek(SeekFrom::Start(e.offset + (start * 8) as u64))?;
                let mut buf = vec![0u8; len * 8];
                r.read_exact(&mut buf)?;
                Column::F64(
                    buf.chunks_exact(8)
                        .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
                        .collect(),
                )
            }
            DType::Bool => {
                r.seek(SeekFrom::Start(e.offset + start as u64))?;
                let mut buf = vec![0u8; len];
                r.read_exact(&mut buf)?;
                Column::Bool(buf.iter().map(|&b| b != 0).collect())
            }
            DType::Str => {
                // variable width: scan from the payload start
                r.seek(SeekFrom::Start(e.offset))?;
                let mut buf = vec![0u8; e.len as usize];
                r.read_exact(&mut buf)?;
                let mut pos = 0usize;
                let mut vals = Vec::with_capacity(len);
                for i in 0..start + len {
                    let slen = u32::from_le_bytes(buf[pos..pos + 4].try_into().unwrap()) as usize;
                    pos += 4;
                    if i >= start {
                        vals.push(
                            std::str::from_utf8(&buf[pos..pos + slen])
                                .context("hfs: string utf-8")?
                                .to_string(),
                        );
                    }
                    pos += slen;
                }
                Column::Str(vals)
            }
        };
        out.push(col);
    }
    Ok(out)
}

/// Read the whole table.
pub fn read_hfs_table(path: &Path) -> Result<Table> {
    let (schema, nrows) = read_hfs_schema(path)?;
    let names: Vec<&str> = schema.names();
    let cols = read_hfs_slice(path, &names, 0, nrows)?;
    Table::new(schema, cols)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("hiframes_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn sample() -> Table {
        Table::from_pairs(vec![
            ("id", Column::I64((0..10).collect())),
            ("x", Column::F64((0..10).map(|i| i as f64 * 0.5).collect())),
            ("flag", Column::Bool((0..10).map(|i| i % 2 == 0).collect())),
            (
                "name",
                Column::Str((0..10).map(|i| format!("row{i}")).collect()),
            ),
        ])
        .unwrap()
    }

    #[test]
    fn roundtrip_full_table() {
        let p = tmpfile("roundtrip.hfs");
        let t = sample();
        write_hfs(&p, &t).unwrap();
        let back = read_hfs_table(&p).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn schema_only_read() {
        let p = tmpfile("schema.hfs");
        write_hfs(&p, &sample()).unwrap();
        let (s, n) = read_hfs_schema(&p).unwrap();
        assert_eq!(n, 10);
        assert_eq!(s.names(), vec!["id", "x", "flag", "name"]);
        assert_eq!(s.dtype_of("x"), Some(DType::F64));
    }

    #[test]
    fn hyperslab_reads() {
        let p = tmpfile("slice.hfs");
        write_hfs(&p, &sample()).unwrap();
        let cols = read_hfs_slice(&p, &["x", "id"], 3, 4).unwrap();
        assert_eq!(cols[0].as_f64(), &[1.5, 2.0, 2.5, 3.0]);
        assert_eq!(cols[1].as_i64(), &[3, 4, 5, 6]);
        // string hyperslab
        let cols = read_hfs_slice(&p, &["name"], 8, 2).unwrap();
        assert_eq!(cols[0].as_str_col(), &["row8".to_string(), "row9".into()]);
        // bool hyperslab
        let cols = read_hfs_slice(&p, &["flag"], 0, 3).unwrap();
        assert_eq!(cols[0].as_bool(), &[true, false, true]);
    }

    #[test]
    fn out_of_range_slice_fails() {
        let p = tmpfile("oob.hfs");
        write_hfs(&p, &sample()).unwrap();
        assert!(read_hfs_slice(&p, &["id"], 8, 5).is_err());
        assert!(read_hfs_slice(&p, &["nope"], 0, 1).is_err());
    }

    #[test]
    fn bad_magic_rejected() {
        let p = tmpfile("bad.hfs");
        std::fs::write(&p, b"NOPE....").unwrap();
        assert!(read_hfs_schema(&p).is_err());
    }

    #[test]
    fn empty_table_roundtrip() {
        let p = tmpfile("empty.hfs");
        let t = Table::from_pairs(vec![("id", Column::I64(vec![]))]).unwrap();
        write_hfs(&p, &t).unwrap();
        let back = read_hfs_table(&p).unwrap();
        assert_eq!(back.num_rows(), 0);
    }
}
