//! Query profiler — per-node, per-rank runtime attribution.
//!
//! One profiled `collect()` produces a [`QueryProfile`]: for every node of
//! the executed [`PlanGraph`](crate::ir::graph::PlanGraph), one
//! [`NodeSpan`] per rank recording wall time, rows in/out, bytes shuffled,
//! collective count/time, spill counters and memo-reuse hits. The graph
//! executor's memo walk records the spans (`exec/mod.rs`); the comm layer
//! attributes its counters to the active node through the scope mechanism
//! on [`Comm`](crate::comm::Comm) (`scope_begin`/`scope_end`); spilling
//! operators route their counters through [`SpillScope`] (attached to the
//! per-operator `SpillCtx`) in addition to the process-global
//! [`crate::metrics::spill_stats`] sink.
//!
//! Three surfaces (see DESIGN.md §4.7):
//! * `df.explain_analyze()` — the optimized graph annotated with
//!   aggregated runtime stats plus a per-rank imbalance factor
//!   ([`QueryProfile::render`]).
//! * `df.collect_profiled()` — `(Table, QueryProfile)` programmatically.
//! * [`QueryProfile::to_chrome_trace`] — a `chrome://tracing` / Perfetto
//!   compatible JSON timeline: one track per rank, one slice per node
//!   execution.
//!
//! Profiling is **off by default** (`ExecOptions::profile` /
//! `HIFRAMES_PROFILE=1`) and never changes results: the spans are pure
//! observations of the unchanged execution, so profiled and unprofiled
//! collects are byte-identical.

use crate::comm::CommScope;
use std::cell::Cell;
use std::time::Instant;

/// Per-rank imbalance (max/mean node wall time) above which
/// [`QueryProfile::render`] flags a node as skewed.
pub const SKEW_IMBALANCE: f64 = 1.5;

/// Shared t=0 for one profiled query. Every rank stamps its spans relative
/// to this clock (the driver starts it just before launching the world), so
/// the per-rank tracks of the Chrome trace align on a common timeline.
#[derive(Debug, Clone, Copy)]
pub struct QueryClock {
    start: Instant,
}

impl QueryClock {
    pub fn start() -> QueryClock {
        QueryClock {
            start: Instant::now(),
        }
    }

    /// Nanoseconds since the query started.
    pub fn now_ns(&self) -> u64 {
        self.start.elapsed().as_nanos() as u64
    }
}

/// One node execution on one rank.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct NodeSpan {
    /// Execution-order position of the node — the `%i` of the render.
    pub pos: usize,
    pub rank: usize,
    /// Start offset from the [`QueryClock`], nanoseconds.
    pub start_ns: u64,
    pub wall_ns: u64,
    /// Rows consumed from materialized inputs (sources report 0).
    pub rows_in: u64,
    pub rows_out: u64,
    /// Point-to-point messages this rank sent while executing the node.
    pub messages: u64,
    /// Bytes this rank put on the wire while executing the node.
    pub bytes_shuffled: u64,
    /// Collective calls issued while executing the node.
    pub collectives: u64,
    /// Wall time spent inside those collectives (includes wait time —
    /// the skew signal).
    pub collective_ns: u64,
    pub bytes_spilled: u64,
    pub partitions_spilled: u64,
    pub spill_passes: u64,
    pub merge_passes: u64,
    /// Memo fetches beyond first while executing this node — inputs that
    /// subplan sharing saved from re-execution.
    pub reuse_hits: u64,
}

/// Per-node spill counters for the active profiling scope. `Cell`-based:
/// each rank thread owns its own instance (shared `Rc` between the
/// executor and the operator's `SpillCtx`), never crossing threads.
#[derive(Debug, Default)]
pub struct SpillScope {
    pub bytes_spilled: Cell<u64>,
    pub partitions_spilled: Cell<u64>,
    pub spill_passes: Cell<u64>,
    pub merge_passes: Cell<u64>,
}

impl SpillScope {
    /// Mirror of [`crate::metrics::SpillStats::record_spill_pass`].
    pub fn record_spill_pass(&self, partitions: u64, bytes: u64) {
        self.spill_passes.set(self.spill_passes.get() + 1);
        self.partitions_spilled
            .set(self.partitions_spilled.get() + partitions);
        self.bytes_spilled.set(self.bytes_spilled.get() + bytes);
    }

    /// Mirror of [`crate::metrics::SpillStats::record_merge_pass`].
    pub fn record_merge_pass(&self) {
        self.merge_passes.set(self.merge_passes.get() + 1);
    }
}

/// One graph node's profile: its canonical render line plus one span per
/// rank that materialized it (rank order). Nodes only demanded through the
/// `Project(Source)` fast path are never materialized and have no spans.
#[derive(Debug, Clone)]
pub struct NodeProfile {
    /// Execution-order position (matches the `%i` prefix of `label`).
    pub pos: usize,
    /// The node's `df.explain()` render line.
    pub label: String,
    pub spans: Vec<NodeSpan>,
}

impl NodeProfile {
    pub fn executed(&self) -> bool {
        !self.spans.is_empty()
    }

    pub fn wall_max_ns(&self) -> u64 {
        self.spans.iter().map(|s| s.wall_ns).max().unwrap_or(0)
    }

    pub fn wall_mean_ns(&self) -> f64 {
        if self.spans.is_empty() {
            return 0.0;
        }
        self.spans.iter().map(|s| s.wall_ns).sum::<u64>() as f64 / self.spans.len() as f64
    }

    /// Per-rank imbalance factor: max/mean wall time across ranks. `1.0`
    /// for balanced nodes (and degenerate cases: one rank, zero time);
    /// large values flag skew — one rank did most of the work.
    pub fn imbalance(&self) -> f64 {
        let mean = self.wall_mean_ns();
        if self.spans.len() <= 1 || mean <= 0.0 {
            return 1.0;
        }
        self.wall_max_ns() as f64 / mean
    }

    pub fn rows_in(&self) -> u64 {
        self.spans.iter().map(|s| s.rows_in).sum()
    }

    pub fn rows_out(&self) -> u64 {
        self.spans.iter().map(|s| s.rows_out).sum()
    }

    pub fn messages(&self) -> u64 {
        self.spans.iter().map(|s| s.messages).sum()
    }

    pub fn bytes_shuffled(&self) -> u64 {
        self.spans.iter().map(|s| s.bytes_shuffled).sum()
    }

    pub fn collectives(&self) -> u64 {
        self.spans.iter().map(|s| s.collectives).sum()
    }

    /// Max over ranks — the critical-path collective time for this node.
    pub fn collective_ns_max(&self) -> u64 {
        self.spans.iter().map(|s| s.collective_ns).max().unwrap_or(0)
    }

    pub fn bytes_spilled(&self) -> u64 {
        self.spans.iter().map(|s| s.bytes_spilled).sum()
    }

    pub fn spill_passes(&self) -> u64 {
        self.spans.iter().map(|s| s.spill_passes).sum()
    }

    pub fn merge_passes(&self) -> u64 {
        self.spans.iter().map(|s| s.merge_passes).sum()
    }

    pub fn reuse_hits(&self) -> u64 {
        self.spans.iter().map(|s| s.reuse_hits).sum()
    }
}

/// The merged runtime profile of one `collect()`: one [`NodeProfile`] per
/// node of the executed graph (execution order), the unattributed driver
/// gather, and the whole-world communication totals.
#[derive(Debug, Clone)]
pub struct QueryProfile {
    pub workers: usize,
    /// `Plan::Cache` nodes served from the `PlanCache` without executing.
    pub cache_hits: u64,
    pub nodes: Vec<NodeProfile>,
    /// Bytes of the final leader gather (result assembly — after the last
    /// node, so not attributable to any of them). Summed over ranks.
    pub gather_bytes: u64,
    /// Max over ranks of the wall time spent in that final gather.
    pub gather_ns: u64,
    /// Whole-world [`crate::comm::CommStats`] totals for the run:
    /// `(messages, bytes, barriers, collectives)`. Invariant:
    /// `sum(node bytes) + gather_bytes == comm_totals.1`.
    pub comm_totals: (u64, u64, u64, u64),
}

impl QueryProfile {
    /// An empty profile over the graph's render lines (one per node in
    /// execution order); the driver fills spans in with [`Self::add_span`].
    pub fn new(workers: usize, labels: Vec<String>, cache_hits: u64) -> QueryProfile {
        QueryProfile {
            workers,
            cache_hits,
            nodes: labels
                .into_iter()
                .enumerate()
                .map(|(pos, label)| NodeProfile {
                    pos,
                    label,
                    spans: Vec::new(),
                })
                .collect(),
            gather_bytes: 0,
            gather_ns: 0,
            comm_totals: (0, 0, 0, 0),
        }
    }

    /// File one rank's span under its node. Ranks are merged in rank order,
    /// so each node's `spans` stay rank-sorted.
    pub fn add_span(&mut self, span: NodeSpan) {
        self.nodes
            .get_mut(span.pos)
            .expect("span position inside the executed graph")
            .spans
            .push(span);
    }

    /// Fold one rank's final-gather deltas in.
    pub fn add_gather(&mut self, scope: CommScope) {
        self.gather_bytes += scope.bytes;
        self.gather_ns = self.gather_ns.max(scope.collective_ns);
    }

    pub fn executed_nodes(&self) -> usize {
        self.nodes.iter().filter(|n| n.executed()).count()
    }

    /// End of the last span on any rank, relative to the query clock —
    /// the executed portion's elapsed wall time.
    pub fn elapsed_ns(&self) -> u64 {
        self.nodes
            .iter()
            .flat_map(|n| &n.spans)
            .map(|s| s.start_ns + s.wall_ns)
            .max()
            .unwrap_or(0)
    }

    pub fn total_bytes_shuffled(&self) -> u64 {
        self.nodes.iter().map(|n| n.bytes_shuffled()).sum()
    }

    pub fn total_bytes_spilled(&self) -> u64 {
        self.nodes.iter().map(|n| n.bytes_spilled()).sum()
    }

    pub fn total_collectives(&self) -> u64 {
        self.nodes.iter().map(|n| n.collectives()).sum()
    }

    pub fn total_reuse_hits(&self) -> u64 {
        self.nodes.iter().map(|n| n.reuse_hits()).sum()
    }

    /// Worst per-node imbalance factor across executed nodes.
    pub fn max_imbalance(&self) -> f64 {
        self.nodes
            .iter()
            .filter(|n| n.executed())
            .map(|n| n.imbalance())
            .fold(1.0, f64::max)
    }

    /// The `explain_analyze` text: every graph render line annotated with
    /// aggregated runtime stats (` | `-separated fields), plus a `-- `
    /// summary footer. Structure is deterministic for a plan + options;
    /// only the time and imbalance values vary run to run (golden tests
    /// mask the tokens after `wall`, `imb` and `elapsed`).
    pub fn render(&self) -> String {
        let width = self.nodes.iter().map(|n| n.label.len()).max().unwrap_or(0);
        let mut out = String::new();
        for n in &self.nodes {
            if !n.executed() {
                out.push_str(&format!(
                    "{:<width$} | (not materialized)\n",
                    n.label,
                    width = width
                ));
                continue;
            }
            let imb = n.imbalance();
            let skew = if imb > SKEW_IMBALANCE && self.workers > 1 {
                " SKEW"
            } else {
                ""
            };
            out.push_str(&format!(
                "{:<width$} | wall {} | rows {}->{} | shuffle {} | spill {} | imb {:.2}x{}\n",
                n.label,
                fmt_ns(n.wall_max_ns()),
                n.rows_in(),
                n.rows_out(),
                fmt_bytes(n.bytes_shuffled()),
                fmt_bytes(n.bytes_spilled()),
                imb,
                skew,
                width = width
            ));
        }
        out.push_str(&format!(
            "-- {} ranks | {}/{} nodes executed | elapsed {} | shuffle {} | spill {} | \
             collectives {} | reuse {} | cache hits {}\n",
            self.workers,
            self.executed_nodes(),
            self.nodes.len(),
            fmt_ns(self.elapsed_ns()),
            fmt_bytes(self.total_bytes_shuffled()),
            fmt_bytes(self.total_bytes_spilled()),
            self.total_collectives(),
            self.total_reuse_hits(),
            self.cache_hits,
        ));
        out
    }

    /// Serialize as Chrome trace-event JSON (`chrome://tracing`, Perfetto):
    /// one process, one track (`tid`) per rank, one `"X"` (complete) slice
    /// per node execution with the counters in `args`. Times are in
    /// microseconds relative to the query clock. Hand-rolled JSON — the
    /// offline image has no serde.
    pub fn to_chrome_trace(&self) -> String {
        let mut ev: Vec<String> = Vec::new();
        ev.push(
            "{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":0,\"tid\":0,\
             \"args\":{\"name\":\"hiframes query\"}}"
                .to_string(),
        );
        for r in 0..self.workers {
            ev.push(format!(
                "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":0,\"tid\":{r},\
                 \"args\":{{\"name\":\"rank {r}\"}}}}"
            ));
        }
        for n in &self.nodes {
            for s in &n.spans {
                ev.push(format!(
                    "{{\"name\":{},\"cat\":\"node\",\"ph\":\"X\",\"ts\":{:.3},\
                     \"dur\":{:.3},\"pid\":0,\"tid\":{},\"args\":{{\
                     \"pos\":{},\"rows_in\":{},\"rows_out\":{},\
                     \"bytes_shuffled\":{},\"bytes_spilled\":{},\
                     \"collectives\":{},\"collective_us\":{:.3},\
                     \"reuse_hits\":{}}}}}",
                    json_str(&n.label),
                    s.start_ns as f64 / 1e3,
                    s.wall_ns as f64 / 1e3,
                    s.rank,
                    n.pos,
                    s.rows_in,
                    s.rows_out,
                    s.bytes_shuffled,
                    s.bytes_spilled,
                    s.collectives,
                    s.collective_ns as f64 / 1e3,
                    s.reuse_hits,
                ));
            }
        }
        format!(
            "{{\"displayTimeUnit\":\"ms\",\"traceEvents\":[\n{}\n]}}\n",
            ev.join(",\n")
        )
    }

    /// Write the Chrome trace as `TRACE_<name>.json` under
    /// `HIFRAMES_BENCH_OUT` (cwd when unset) — the bench/CI convention,
    /// mirroring `BENCH_<figure>.json`.
    pub fn write_chrome_trace(&self, name: &str) -> std::io::Result<std::path::PathBuf> {
        let dir = std::env::var("HIFRAMES_BENCH_OUT").unwrap_or_else(|_| ".".into());
        let dir = std::path::Path::new(&dir);
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("TRACE_{name}.json"));
        std::fs::write(&path, self.to_chrome_trace())?;
        Ok(path)
    }
}

/// Auto-scaled duration: `…ns`, `…µs`, `…ms` or `…s`.
pub fn fmt_ns(ns: u64) -> String {
    let v = ns as f64;
    if v < 1e3 {
        format!("{ns}ns")
    } else if v < 1e6 {
        format!("{:.2}µs", v / 1e3)
    } else if v < 1e9 {
        format!("{:.2}ms", v / 1e6)
    } else {
        format!("{:.2}s", v / 1e9)
    }
}

/// Auto-scaled byte count: `…B`, `…KiB`, `…MiB` or `…GiB`.
pub fn fmt_bytes(b: u64) -> String {
    const K: f64 = 1024.0;
    let v = b as f64;
    if v < K {
        format!("{b}B")
    } else if v < K * K {
        format!("{:.1}KiB", v / K)
    } else if v < K * K * K {
        format!("{:.1}MiB", v / (K * K))
    } else {
        format!("{:.1}GiB", v / (K * K * K))
    }
}

/// Minimal JSON string quoting (same contract as the bench writer: labels
/// are engine-generated, so only quotes/backslashes/control chars occur).
fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(pos: usize, rank: usize, wall_ns: u64) -> NodeSpan {
        NodeSpan {
            pos,
            rank,
            wall_ns,
            ..NodeSpan::default()
        }
    }

    #[test]
    fn imbalance_math() {
        let mut n = NodeProfile {
            pos: 0,
            label: "%0 = Source(t) [1D_BLOCK]".into(),
            spans: vec![span(0, 0, 100), span(0, 1, 300)],
        };
        // max 300 / mean 200
        assert!((n.imbalance() - 1.5).abs() < 1e-9);
        n.spans.pop();
        assert_eq!(n.imbalance(), 1.0, "single rank is balanced by definition");
        n.spans.clear();
        assert_eq!(n.imbalance(), 1.0);
        assert!(!n.executed());
    }

    #[test]
    fn aggregation_sums_and_maxes() {
        let mut p = QueryProfile::new(2, vec!["%0 = A".into(), "%1 = B".into()], 0);
        p.add_span(NodeSpan {
            pos: 0,
            rank: 0,
            start_ns: 0,
            wall_ns: 50,
            rows_in: 1,
            rows_out: 2,
            bytes_shuffled: 10,
            ..NodeSpan::default()
        });
        p.add_span(NodeSpan {
            pos: 0,
            rank: 1,
            start_ns: 20,
            wall_ns: 80,
            rows_in: 3,
            rows_out: 4,
            bytes_shuffled: 30,
            ..NodeSpan::default()
        });
        assert_eq!(p.executed_nodes(), 1);
        assert_eq!(p.nodes[0].rows_in(), 4);
        assert_eq!(p.nodes[0].rows_out(), 6);
        assert_eq!(p.nodes[0].bytes_shuffled(), 40);
        assert_eq!(p.nodes[0].wall_max_ns(), 80);
        assert_eq!(p.elapsed_ns(), 100);
        assert!(!p.nodes[1].executed());
    }

    #[test]
    fn render_structure() {
        let mut p = QueryProfile::new(2, vec!["%0 = A [REP]".into(), "%1 = B [REP]".into()], 1);
        p.add_span(span(0, 0, 1_000));
        p.add_span(span(0, 1, 500_000));
        let text = p.render();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[0].starts_with("%0 = A [REP]"));
        assert!(lines[0].contains(" | wall "));
        assert!(lines[0].contains(" | imb "));
        assert!(lines[0].ends_with("SKEW"), "{}", lines[0]);
        assert!(lines[1].contains("(not materialized)"));
        assert!(lines[2].starts_with("-- 2 ranks | 1/2 nodes executed"));
        assert!(lines[2].contains("cache hits 1"));
    }

    #[test]
    fn chrome_trace_shape() {
        let mut p = QueryProfile::new(2, vec!["%0 = \"A\"\\B".into()], 0);
        p.add_span(span(0, 0, 1_500));
        p.add_span(span(0, 1, 2_500));
        let t = p.to_chrome_trace();
        assert!(t.starts_with('{') && t.trim_end().ends_with('}'));
        assert!(t.contains("\"traceEvents\""));
        // one thread_name metadata event per rank
        assert_eq!(t.matches("\"thread_name\"").count(), 2);
        // one X slice per span
        assert_eq!(t.matches("\"ph\":\"X\"").count(), 2);
        // quotes and backslashes in labels are escaped
        assert!(t.contains("\\\"A\\\"\\\\B"));
        // balanced braces/brackets (cheap well-formedness check)
        let opens = t.matches('{').count() + t.matches('[').count();
        let closes = t.matches('}').count() + t.matches(']').count();
        assert_eq!(opens, closes);
    }

    #[test]
    fn spill_scope_accumulates() {
        let s = SpillScope::default();
        s.record_spill_pass(4, 1000);
        s.record_spill_pass(2, 500);
        s.record_merge_pass();
        assert_eq!(s.bytes_spilled.get(), 1500);
        assert_eq!(s.partitions_spilled.get(), 6);
        assert_eq!(s.spill_passes.get(), 2);
        assert_eq!(s.merge_passes.get(), 1);
    }

    #[test]
    fn units_auto_scale() {
        assert_eq!(fmt_ns(999), "999ns");
        assert_eq!(fmt_ns(1_500), "1.50µs");
        assert_eq!(fmt_ns(2_000_000), "2.00ms");
        assert_eq!(fmt_ns(3_500_000_000), "3.50s");
        assert_eq!(fmt_bytes(512), "512B");
        assert_eq!(fmt_bytes(2048), "2.0KiB");
        assert_eq!(fmt_bytes(3 << 20), "3.0MiB");
        assert_eq!(fmt_bytes(5 << 30), "5.0GiB");
    }
}
