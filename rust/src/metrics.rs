//! Timing and throughput instrumentation used by benches, examples and the
//! bench runs, plus the out-of-core spill counters surfaced in
//! `BENCH_*.json` (see [`crate::ops::spill`]).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

/// A simple stopwatch.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Timer {
        Timer {
            start: Instant::now(),
        }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
}

/// Measure `f`, returning `(result, seconds)`.
pub fn time_it<R>(f: impl FnOnce() -> R) -> (R, f64) {
    let t = Timer::start();
    let r = f();
    (r, t.elapsed_secs())
}

/// Run `f` `reps` times (after `warmup` unmeasured runs) and return summary
/// statistics of the per-run seconds. This is our criterion stand-in.
pub fn measure<R>(warmup: usize, reps: usize, mut f: impl FnMut() -> R) -> Stats {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t = Timer::start();
        std::hint::black_box(f());
        samples.push(t.elapsed_secs());
    }
    Stats::from_samples(samples)
}

/// Summary statistics over per-run times (seconds).
#[derive(Debug, Clone)]
pub struct Stats {
    pub samples: Vec<f64>,
    pub min: f64,
    pub max: f64,
    pub mean: f64,
    pub median: f64,
    pub stddev: f64,
}

impl Stats {
    pub fn from_samples(mut samples: Vec<f64>) -> Stats {
        assert!(!samples.is_empty(), "Stats::from_samples: no samples");
        assert!(
            samples.iter().all(|x| !x.is_nan()),
            "Stats::from_samples: NaN sample (a timed closure returned NaN \
             seconds); drop or repair the sample before summarizing"
        );
        samples.sort_by(|a, b| a.total_cmp(b));
        let n = samples.len() as f64;
        let mean = samples.iter().sum::<f64>() / n;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
        let median = if samples.len() % 2 == 1 {
            samples[samples.len() / 2]
        } else {
            0.5 * (samples[samples.len() / 2 - 1] + samples[samples.len() / 2])
        };
        Stats {
            min: samples[0],
            max: *samples.last().unwrap(),
            mean,
            median,
            stddev: var.sqrt(),
            samples,
        }
    }

    /// Render like `12.345ms ±0.400`, auto-scaling the unit (µs/ms/s) to
    /// the median so sub-millisecond micro-benches and multi-second scale
    /// runs both stay readable. The stddev shares the median's unit.
    pub fn display_ms(&self) -> String {
        let (scale, unit) = if self.median < 1e-3 {
            (1e6, "µs")
        } else if self.median < 1.0 {
            (1e3, "ms")
        } else {
            (1.0, "s")
        };
        format!(
            "{:9.3}{unit} ±{:.3}",
            self.median * scale,
            self.stddev * scale
        )
    }
}

/// Throughput in million rows per second.
pub fn mrows_per_sec(rows: usize, secs: f64) -> f64 {
    rows as f64 / secs / 1e6
}

/// Process-global counters for the out-of-core spill subsystem. All ranks
/// share one instance (ranks are threads), so readings are whole-process
/// totals; tests asserting on *this* sink use monotonic deltas because the
/// test harness runs cases in parallel. For exact per-query values, run
/// with `ExecOptions::profile` on: the same recordings are then also
/// routed into the query's [`crate::trace::QueryProfile`] through the
/// per-node [`crate::trace::SpillScope`], which nothing else writes to.
#[derive(Debug, Default)]
pub struct SpillStats {
    bytes_spilled: AtomicU64,
    partitions_spilled: AtomicU64,
    spill_passes: AtomicU64,
    merge_passes: AtomicU64,
}

/// One consistent-enough reading of [`SpillStats`] (fields are sampled
/// individually; pair with quiescent points or delta assertions).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpillSnapshot {
    pub bytes_spilled: u64,
    pub partitions_spilled: u64,
    pub spill_passes: u64,
    pub merge_passes: u64,
}

impl SpillStats {
    const fn new() -> SpillStats {
        SpillStats {
            bytes_spilled: AtomicU64::new(0),
            partitions_spilled: AtomicU64::new(0),
            spill_passes: AtomicU64::new(0),
            merge_passes: AtomicU64::new(0),
        }
    }

    /// One hash-partition pass that wrote `partitions` non-empty partition
    /// files totalling `bytes` on disk.
    pub fn record_spill_pass(&self, partitions: u64, bytes: u64) {
        self.spill_passes.fetch_add(1, Ordering::Relaxed);
        self.partitions_spilled.fetch_add(partitions, Ordering::Relaxed);
        self.bytes_spilled.fetch_add(bytes, Ordering::Relaxed);
    }

    /// One merge pass (k-way run merge or partition-at-a-time merge).
    pub fn record_merge_pass(&self) {
        self.merge_passes.fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> SpillSnapshot {
        SpillSnapshot {
            bytes_spilled: self.bytes_spilled.load(Ordering::Relaxed),
            partitions_spilled: self.partitions_spilled.load(Ordering::Relaxed),
            spill_passes: self.spill_passes.load(Ordering::Relaxed),
            merge_passes: self.merge_passes.load(Ordering::Relaxed),
        }
    }

    /// Zero all counters (bench runs reset between tables).
    pub fn reset(&self) {
        self.bytes_spilled.store(0, Ordering::Relaxed);
        self.partitions_spilled.store(0, Ordering::Relaxed);
        self.spill_passes.store(0, Ordering::Relaxed);
        self.merge_passes.store(0, Ordering::Relaxed);
    }
}

static SPILL: SpillStats = SpillStats::new();

/// The process-global spill counters.
pub fn spill_stats() -> &'static SpillStats {
    &SPILL
}

/// Process-global counters for the graph executor: how many plan nodes ran,
/// how many shared-subplan materializations were reused instead of
/// re-executed, and how many `cache()` points were served from a
/// [`crate::exec::PlanCache`]. Same conventions as [`SpillStats`]: all
/// ranks share one instance, so prefer delta assertions.
#[derive(Debug, Default)]
pub struct PlanStats {
    nodes_executed: AtomicU64,
    subplans_reused: AtomicU64,
    plan_cache_hits: AtomicU64,
}

/// One consistent-enough reading of [`PlanStats`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PlanSnapshot {
    pub nodes_executed: u64,
    pub subplans_reused: u64,
    pub plan_cache_hits: u64,
}

impl PlanStats {
    const fn new() -> PlanStats {
        PlanStats {
            nodes_executed: AtomicU64::new(0),
            subplans_reused: AtomicU64::new(0),
            plan_cache_hits: AtomicU64::new(0),
        }
    }

    /// Fold one collect run's totals (already summed over ranks) in.
    pub fn record_run(&self, nodes_executed: u64, subplans_reused: u64, cache_hits: u64) {
        self.nodes_executed.fetch_add(nodes_executed, Ordering::Relaxed);
        self.subplans_reused.fetch_add(subplans_reused, Ordering::Relaxed);
        self.plan_cache_hits.fetch_add(cache_hits, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> PlanSnapshot {
        PlanSnapshot {
            nodes_executed: self.nodes_executed.load(Ordering::Relaxed),
            subplans_reused: self.subplans_reused.load(Ordering::Relaxed),
            plan_cache_hits: self.plan_cache_hits.load(Ordering::Relaxed),
        }
    }

    /// Zero all counters (bench runs reset between tables).
    pub fn reset(&self) {
        self.nodes_executed.store(0, Ordering::Relaxed);
        self.subplans_reused.store(0, Ordering::Relaxed);
        self.plan_cache_hits.store(0, Ordering::Relaxed);
    }
}

static PLAN: PlanStats = PlanStats::new();

/// The process-global graph-executor counters.
pub fn plan_stats() -> &'static PlanStats {
    &PLAN
}

/// Process-global counters for the incremental stream executor: ticks run,
/// rows actually pushed through operators vs rows the stateful operators
/// avoided re-touching, and how often a session fell back to a tracked full
/// recompute. Same conventions as [`SpillStats`]: all ranks share one
/// instance, so prefer delta assertions in tests.
#[derive(Debug, Default)]
pub struct StreamStats {
    ticks: AtomicU64,
    rows_processed: AtomicU64,
    rows_avoided: AtomicU64,
    fallbacks: AtomicU64,
}

/// One consistent-enough reading of [`StreamStats`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StreamSnapshot {
    pub ticks: u64,
    pub rows_processed: u64,
    pub rows_avoided: u64,
    pub fallbacks: u64,
}

impl StreamStats {
    const fn new() -> StreamStats {
        StreamStats {
            ticks: AtomicU64::new(0),
            rows_processed: AtomicU64::new(0),
            rows_avoided: AtomicU64::new(0),
            fallbacks: AtomicU64::new(0),
        }
    }

    /// Fold one tick's totals (already summed over ranks) in.
    pub fn record_tick(&self, rows_processed: u64, rows_avoided: u64, fallback: bool) {
        self.ticks.fetch_add(1, Ordering::Relaxed);
        self.rows_processed.fetch_add(rows_processed, Ordering::Relaxed);
        self.rows_avoided.fetch_add(rows_avoided, Ordering::Relaxed);
        if fallback {
            self.fallbacks.fetch_add(1, Ordering::Relaxed);
        }
    }

    pub fn snapshot(&self) -> StreamSnapshot {
        StreamSnapshot {
            ticks: self.ticks.load(Ordering::Relaxed),
            rows_processed: self.rows_processed.load(Ordering::Relaxed),
            rows_avoided: self.rows_avoided.load(Ordering::Relaxed),
            fallbacks: self.fallbacks.load(Ordering::Relaxed),
        }
    }

    /// Zero all counters (bench runs reset between tables).
    pub fn reset(&self) {
        self.ticks.store(0, Ordering::Relaxed);
        self.rows_processed.store(0, Ordering::Relaxed);
        self.rows_avoided.store(0, Ordering::Relaxed);
        self.fallbacks.store(0, Ordering::Relaxed);
    }
}

static STREAM: StreamStats = StreamStats::new();

/// The process-global incremental-execution counters.
pub fn stream_stats() -> &'static StreamStats {
    &STREAM
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basics() {
        let s = Stats::from_samples(vec![3.0, 1.0, 2.0]);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert_eq!(s.median, 2.0);
        assert_eq!(s.mean, 2.0);
        let s = Stats::from_samples(vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.median, 2.5);
    }

    #[test]
    #[should_panic(expected = "NaN")]
    fn stats_reject_nan() {
        Stats::from_samples(vec![1.0, f64::NAN, 2.0]);
    }

    #[test]
    fn display_auto_scales_units() {
        let us = Stats::from_samples(vec![250e-6]);
        assert!(us.display_ms().contains("µs"), "{}", us.display_ms());
        let ms = Stats::from_samples(vec![0.012]);
        assert!(ms.display_ms().contains("ms"), "{}", ms.display_ms());
        let s = Stats::from_samples(vec![2.5]);
        let d = s.display_ms();
        assert!(d.trim_end().ends_with("±0.000") && d.contains('s'), "{d}");
        assert!(!d.contains("ms"), "seconds must not render as ms: {d}");
    }

    #[test]
    fn time_it_measures() {
        let (v, secs) = time_it(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }

    #[test]
    fn measure_collects_reps() {
        let stats = measure(1, 5, || std::hint::black_box(1 + 1));
        assert_eq!(stats.samples.len(), 5);
        assert!(stats.min <= stats.median && stats.median <= stats.max);
    }

    #[test]
    fn throughput() {
        assert_eq!(mrows_per_sec(2_000_000, 2.0), 1.0);
    }

    #[test]
    fn spill_stats_accumulate() {
        // The global instance is shared across parallel tests; use a local
        // one for exact arithmetic.
        let s = SpillStats::new();
        assert_eq!(s.snapshot().bytes_spilled, 0);
        s.record_spill_pass(4, 1000);
        s.record_spill_pass(2, 500);
        s.record_merge_pass();
        let snap = s.snapshot();
        assert_eq!(snap.bytes_spilled, 1500);
        assert_eq!(snap.partitions_spilled, 6);
        assert_eq!(snap.spill_passes, 2);
        assert_eq!(snap.merge_passes, 1);
        s.reset();
        assert_eq!(s.snapshot().spill_passes, 0);
        // The global accessor hands out the same instance.
        let before = spill_stats().snapshot();
        spill_stats().record_merge_pass();
        assert!(spill_stats().snapshot().merge_passes > before.merge_passes);
    }

    #[test]
    fn plan_stats_accumulate() {
        let s = PlanStats::new();
        s.record_run(10, 2, 1);
        s.record_run(4, 0, 0);
        let snap = s.snapshot();
        assert_eq!(snap.nodes_executed, 14);
        assert_eq!(snap.subplans_reused, 2);
        assert_eq!(snap.plan_cache_hits, 1);
        s.reset();
        assert_eq!(s.snapshot().nodes_executed, 0);
        let before = plan_stats().snapshot();
        plan_stats().record_run(1, 1, 0);
        assert!(plan_stats().snapshot().subplans_reused > before.subplans_reused);
    }

    #[test]
    fn stream_stats_accumulate() {
        let s = StreamStats::new();
        s.record_tick(100, 900, false);
        s.record_tick(50, 0, true);
        let snap = s.snapshot();
        assert_eq!(snap.ticks, 2);
        assert_eq!(snap.rows_processed, 150);
        assert_eq!(snap.rows_avoided, 900);
        assert_eq!(snap.fallbacks, 1);
        s.reset();
        assert_eq!(s.snapshot().ticks, 0);
        let before = stream_stats().snapshot();
        stream_stats().record_tick(1, 2, false);
        assert!(stream_stats().snapshot().ticks > before.ticks);
    }
}
