//! Timing and throughput instrumentation used by benches, examples and the
//! bench runs.

use std::time::{Duration, Instant};

/// A simple stopwatch.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Timer {
        Timer {
            start: Instant::now(),
        }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
}

/// Measure `f`, returning `(result, seconds)`.
pub fn time_it<R>(f: impl FnOnce() -> R) -> (R, f64) {
    let t = Timer::start();
    let r = f();
    (r, t.elapsed_secs())
}

/// Run `f` `reps` times (after `warmup` unmeasured runs) and return summary
/// statistics of the per-run seconds. This is our criterion stand-in.
pub fn measure<R>(warmup: usize, reps: usize, mut f: impl FnMut() -> R) -> Stats {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t = Timer::start();
        std::hint::black_box(f());
        samples.push(t.elapsed_secs());
    }
    Stats::from_samples(samples)
}

/// Summary statistics over per-run times (seconds).
#[derive(Debug, Clone)]
pub struct Stats {
    pub samples: Vec<f64>,
    pub min: f64,
    pub max: f64,
    pub mean: f64,
    pub median: f64,
    pub stddev: f64,
}

impl Stats {
    pub fn from_samples(mut samples: Vec<f64>) -> Stats {
        assert!(!samples.is_empty());
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len() as f64;
        let mean = samples.iter().sum::<f64>() / n;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
        let median = if samples.len() % 2 == 1 {
            samples[samples.len() / 2]
        } else {
            0.5 * (samples[samples.len() / 2 - 1] + samples[samples.len() / 2])
        };
        Stats {
            min: samples[0],
            max: *samples.last().unwrap(),
            mean,
            median,
            stddev: var.sqrt(),
            samples,
        }
    }

    /// Render like `12.3ms ±0.4`.
    pub fn display_ms(&self) -> String {
        format!(
            "{:9.3}ms ±{:.3}",
            self.median * 1e3,
            self.stddev * 1e3
        )
    }
}

/// Throughput in million rows per second.
pub fn mrows_per_sec(rows: usize, secs: f64) -> f64 {
    rows as f64 / secs / 1e6
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basics() {
        let s = Stats::from_samples(vec![3.0, 1.0, 2.0]);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert_eq!(s.median, 2.0);
        assert_eq!(s.mean, 2.0);
        let s = Stats::from_samples(vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.median, 2.5);
    }

    #[test]
    fn time_it_measures() {
        let (v, secs) = time_it(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }

    #[test]
    fn measure_collects_reps() {
        let stats = measure(1, 5, || std::hint::black_box(1 + 1));
        assert_eq!(stats.samples.len(), 5);
        assert!(stats.min <= stats.median && stats.median <= stats.max);
    }

    #[test]
    fn throughput() {
        assert_eq!(mrows_per_sec(2_000_000, 2.0), 1.0);
    }
}
