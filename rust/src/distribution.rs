//! The distribution meet-semilattice (paper §4.4, Fig. 7).
//!
//! HPAT's distribution analysis assigns each array and each parallel loop a
//! distribution drawn from a meet-semilattice; HiFrames *extends* it with
//! `1D_VAR` — one-dimensional block distribution with variable-length
//! chunks — so relational outputs (whose sizes are data-dependent) stay
//! parallel without immediate rebalancing:
//!
//! ```text
//!        1D_BLOCK            (top: equal chunks, default)
//!           |
//!        1D_VAR              (new: variable-length chunks)
//!           |
//!        2D_BLOCK_CYCLIC     (linear-algebra layouts)
//!           |
//!          REP               (bottom: replicated / sequential)
//! ```
//!
//! Inference runs a fixed-point dataflow where each IR node's transfer
//! function *meets* the distributions of its inputs/outputs, so arrays can
//! only move *down* the lattice — which guarantees termination.

use std::fmt;

/// A point in the distribution meet-semilattice. Order: `Rep < TwoD <
/// OneDVar < OneD` (higher = more parallel structure).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dist {
    /// `1D_BLOCK`: equal contiguous chunks except possibly the last rank.
    OneD,
    /// `1D_VAR`: contiguous chunks of data-dependent length (the paper's
    /// novel element; outputs of filter/join/aggregate).
    OneDVar,
    /// `2D_BLOCK_CYCLIC`: ScaLAPACK-style layouts.
    TwoD,
    /// `REP`: replicated on every rank — i.e. sequential.
    Rep,
}

impl Dist {
    /// Height in the lattice (larger = higher).
    fn rank_in_lattice(self) -> u8 {
        match self {
            Dist::OneD => 3,
            Dist::OneDVar => 2,
            Dist::TwoD => 1,
            Dist::Rep => 0,
        }
    }

    /// The meet (greatest lower bound). The paper's transfer functions are
    /// all expressed as meets, e.g.
    /// `dist[out] = 1D_VAR ∧ dist[in1] ∧ dist[in2] …`.
    pub fn meet(self, other: Dist) -> Dist {
        if self.rank_in_lattice() <= other.rank_in_lattice() {
            self
        } else {
            other
        }
    }

    /// Fold `meet` over an iterator (identity = top = `OneD`).
    pub fn meet_all(dists: impl IntoIterator<Item = Dist>) -> Dist {
        dists.into_iter().fold(Dist::OneD, Dist::meet)
    }

    /// Is this distribution parallel (any form of partitioning)?
    pub fn is_parallel(self) -> bool {
        !matches!(self, Dist::Rep)
    }

    /// `a ⊑ b` — is `a` at or below `b` in the lattice?
    pub fn le(self, other: Dist) -> bool {
        self.rank_in_lattice() <= other.rank_in_lattice()
    }
}

impl fmt::Display for Dist {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Dist::OneD => "1D_BLOCK",
            Dist::OneDVar => "1D_VAR",
            Dist::TwoD => "2D_BLOCK_CYCLIC",
            Dist::Rep => "REP",
        };
        write!(f, "{s}")
    }
}

pub const ALL_DISTS: [Dist; 4] = [Dist::OneD, Dist::OneDVar, Dist::TwoD, Dist::Rep];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meet_is_glb() {
        assert_eq!(Dist::OneD.meet(Dist::OneDVar), Dist::OneDVar);
        assert_eq!(Dist::OneDVar.meet(Dist::Rep), Dist::Rep);
        assert_eq!(Dist::OneD.meet(Dist::OneD), Dist::OneD);
        assert_eq!(Dist::TwoD.meet(Dist::OneDVar), Dist::TwoD);
    }

    #[test]
    fn lattice_laws() {
        // idempotent, commutative, associative — checked exhaustively
        for a in ALL_DISTS {
            assert_eq!(a.meet(a), a);
            for b in ALL_DISTS {
                assert_eq!(a.meet(b), b.meet(a));
                for c in ALL_DISTS {
                    assert_eq!(a.meet(b).meet(c), a.meet(b.meet(c)));
                }
            }
        }
    }

    #[test]
    fn top_is_identity() {
        for a in ALL_DISTS {
            assert_eq!(Dist::OneD.meet(a), a);
        }
        assert_eq!(Dist::meet_all([]), Dist::OneD);
    }

    #[test]
    fn meet_all_folds() {
        assert_eq!(
            Dist::meet_all([Dist::OneD, Dist::OneDVar, Dist::OneD]),
            Dist::OneDVar
        );
        assert_eq!(
            Dist::meet_all([Dist::OneDVar, Dist::Rep]),
            Dist::Rep
        );
    }

    #[test]
    fn ordering() {
        assert!(Dist::Rep.le(Dist::OneD));
        assert!(Dist::OneDVar.le(Dist::OneD));
        assert!(!Dist::OneD.le(Dist::OneDVar));
        assert!(Dist::Rep.is_parallel() == false);
        assert!(Dist::OneDVar.is_parallel());
    }

    #[test]
    fn display_names_match_paper() {
        assert_eq!(Dist::OneD.to_string(), "1D_BLOCK");
        assert_eq!(Dist::OneDVar.to_string(), "1D_VAR");
        assert_eq!(Dist::Rep.to_string(), "REP");
    }
}
