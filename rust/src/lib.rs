//! # HiFrames (reproduction)
//!
//! A compiler-based distributed data-frame system, reproducing
//! *HiFrames: High Performance Data Frames in a Scripting Language*
//! (Totoni, Hassan, Anderson, Shpeisman — 2017) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the HiFrames compiler & runtime: a data-frame
//!   API ([`frame`]) that builds a logical IR ([`ir`]), optimized by the
//!   paper's passes ([`passes`]: predicate pushdown through join, column
//!   pruning, distribution inference over the `1D_BLOCK/1D_VAR/2D/REP`
//!   meet-semilattice), lowered to a physical SPMD plan ([`exec`]) whose
//!   operators ([`ops`]) run on rank-threads over a simulated-MPI
//!   communicator ([`comm`]).
//! * **L2/L1 (python/compile)** — JAX analytics models (k-means step,
//!   logistic regression) calling Pallas kernels, AOT-lowered to HLO text
//!   and executed from Rust via PJRT ([`runtime`], [`ml`]).
//!
//! Comparison engines live in [`baseline`] (`sparklike` map-reduce engine,
//! `serial` pandas-like engine) and the TPCx-BB workload in [`bigbench`].
//!
//! See `DESIGN.md` (repository root) for the module map and the pass
//! pipeline.

pub mod baseline;
pub mod bench;
pub mod bigbench;
pub mod column;
pub mod comm;
pub mod config;
pub mod datagen;
pub mod distribution;
pub mod exec;
pub mod expr;
pub mod frame;
pub mod fxhash;
pub mod io;
pub mod ir;
pub mod metrics;
pub mod ml;
pub mod ops;
pub mod passes;
pub mod prop;
pub mod runtime;
pub mod stream;
pub mod table;
pub mod trace;
pub mod types;

/// Convenience re-exports for examples and tests.
pub mod prelude {
    pub use crate::column::{ArithOp, CmpOp, Column, MathFn, NullableColumn, ValidityMask};
    pub use crate::expr::{col, lit, AggExpr, AggFn, Expr, Udf, WindowExpr};
    pub use crate::frame::*;
    pub use crate::stream::{Session, TickReport};
    pub use crate::table::{Schema, Table};
    pub use crate::trace::QueryProfile;
    pub use crate::types::{DType, JoinType, SortOrder, Value, WindowFrame, WindowFunc};
}
