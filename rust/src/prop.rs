//! Minimal property-based testing harness.
//!
//! The offline image does not vendor `proptest`, so this module provides the
//! subset we need: seeded generators, a `forall` driver that runs N cases,
//! and on failure reports the seed + a best-effort shrink (halving vector
//! inputs while the property still fails). Every property suite in
//! `rust/tests/properties.rs`, the differential kernel-fuzz suite in
//! `rust/tests/kernels.rs`, and the module-level invariant tests build on
//! this.
//!
//! Knobs (environment):
//! * `HIFRAMES_PROP_CASES` — cases per property (default 64). CI's
//!   kernel-fuzz step sets 256 for a heavier randomized pass.
//! * `HIFRAMES_PROP_SEED` — base seed (default `0xC0FFEE`). A failure
//!   panic prints the exact `HIFRAMES_PROP_SEED=<s> HIFRAMES_PROP_CASES=1`
//!   pair that replays just the failing case.

use crate::datagen::Rng;

/// Number of cases per property (override with `HIFRAMES_PROP_CASES`).
pub fn default_cases() -> usize {
    std::env::var("HIFRAMES_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(64)
}

/// Scale a suite's per-property multiplier by the configured case count:
/// a property declared with `mult` runs `mult` cases when
/// `HIFRAMES_PROP_CASES` is at the default 64, and proportionally more
/// under a heavier CI pass (always at least one case).
pub fn scaled_cases(mult: usize) -> usize {
    (mult * default_cases()).div_ceil(64).max(1)
}

fn base_seed() -> u64 {
    std::env::var("HIFRAMES_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE_u64)
}

fn case_seed(base: u64, case: usize) -> u64 {
    base.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15)
}

/// The failure report: which case of how many, the derived RNG seed, and
/// the exact environment that replays *only* the failing case — with
/// `HIFRAMES_PROP_CASES=1`, case 0 under base seed `base + case` derives
/// the same [`case_seed`] as the failure.
fn failure_header(name: &str, case: usize, cases: usize, base: u64) -> String {
    format!(
        "property '{name}' failed (case {case} of {cases}, seed {seed:#x})\n\
         reproduce with HIFRAMES_PROP_SEED={repro} HIFRAMES_PROP_CASES=1",
        seed = case_seed(base, case),
        repro = base.wrapping_add(case as u64),
    )
}

/// Run `prop` on [`default_cases`] random inputs drawn by `gen`. Panics
/// with the seed, the re-run command, and the debug representation of the
/// counter-example.
pub fn forall<T, G, P>(name: &str, gen: G, prop: P)
where
    T: std::fmt::Debug + Clone,
    G: Fn(&mut Rng) -> T,
    P: Fn(&T) -> Result<(), String>,
{
    forall_cases(name, default_cases(), gen, prop)
}

/// Like [`forall`] with an explicit case count (pair with [`scaled_cases`]
/// to declare a per-property multiplier that tracks the CI knob).
pub fn forall_cases<T, G, P>(name: &str, cases: usize, gen: G, prop: P)
where
    T: std::fmt::Debug + Clone,
    G: Fn(&mut Rng) -> T,
    P: Fn(&T) -> Result<(), String>,
{
    let base = base_seed();
    for case in 0..cases {
        let mut rng = Rng::new(case_seed(base, case));
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "{}: {msg}\ncounter-example: {input:?}",
                failure_header(name, case, cases, base)
            );
        }
    }
}

/// Shrinking `forall` for `Vec<T>` inputs: on failure, repeatedly try
/// halves of the failing vector to present a smaller counter-example.
pub fn forall_vec<T, G, P>(name: &str, gen: G, prop: P)
where
    T: std::fmt::Debug + Clone,
    G: Fn(&mut Rng) -> Vec<T>,
    P: Fn(&[T]) -> Result<(), String>,
{
    let cases = default_cases();
    let base = base_seed();
    for case in 0..cases {
        let mut rng = Rng::new(case_seed(base, case));
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            let shrunk = shrink_vec(&input, &prop);
            panic!(
                "{}: {msg}\nshrunk counter-example ({} of {} elems): {shrunk:?}",
                failure_header(name, case, cases, base),
                shrunk.len(),
                input.len()
            );
        }
    }
}

fn shrink_vec<T: Clone + std::fmt::Debug>(
    failing: &[T],
    prop: &impl Fn(&[T]) -> Result<(), String>,
) -> Vec<T> {
    let mut cur = failing.to_vec();
    loop {
        if cur.len() <= 1 {
            return cur;
        }
        let half = cur.len() / 2;
        let first = &cur[..half];
        let second = &cur[half..];
        if prop(first).is_err() {
            cur = first.to_vec();
        } else if prop(second).is_err() {
            cur = second.to_vec();
        } else {
            return cur;
        }
    }
}

/// Common generators.
pub mod gen {
    use crate::datagen::Rng;

    pub fn vec_i64(rng: &mut Rng, max_len: usize, lo: i64, hi: i64) -> Vec<i64> {
        let n = rng.usize(max_len + 1);
        (0..n).map(|_| rng.i64_range(lo, hi)).collect()
    }

    pub fn vec_f64(rng: &mut Rng, max_len: usize) -> Vec<f64> {
        let n = rng.usize(max_len + 1);
        (0..n).map(|_| rng.normal() * 10.0).collect()
    }

    pub fn mask(rng: &mut Rng, len: usize, p: f64) -> Vec<bool> {
        (0..len).map(|_| rng.bool(p)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall(
            "reverse-reverse-id",
            |rng| gen::vec_i64(rng, 50, -100, 100),
            |v| {
                let mut r = v.clone();
                r.reverse();
                r.reverse();
                if r == *v {
                    Ok(())
                } else {
                    Err("reverse twice changed vec".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn failing_property_reports() {
        forall(
            "always-fails",
            |rng| rng.i64_range(0, 10),
            |_| Err("nope".to_string()),
        );
    }

    #[test]
    #[should_panic(expected = "shrunk counter-example")]
    fn shrinker_reduces() {
        forall_vec(
            "has-a-negative",
            |rng| gen::vec_i64(rng, 64, -5, 100),
            |v| {
                if v.iter().any(|&x| x < 0) {
                    Err("found negative".into())
                } else {
                    Ok(())
                }
            },
        );
    }

    #[test]
    fn failure_message_carries_seed_and_repro_command() {
        // Fail at case 3: the panic must name the case, the derived seed in
        // hex, and the one-case re-run environment.
        let fails_at_3 = std::sync::atomic::AtomicUsize::new(0);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            forall_cases(
                "fails-at-case-3",
                8,
                |rng| rng.i64_range(0, 10),
                |_| {
                    let c = fails_at_3.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
                    if c == 3 {
                        Err("boom".into())
                    } else {
                        Ok(())
                    }
                },
            );
        }));
        let err = result.expect_err("property must fail");
        let msg = err
            .downcast_ref::<String>()
            .expect("panic payload is a String")
            .clone();
        let base = base_seed();
        let seed = case_seed(base, 3);
        assert!(msg.contains("case 3 of 8"), "missing case count: {msg}");
        assert!(
            msg.contains(&format!("{seed:#x}")),
            "missing derived seed: {msg}"
        );
        assert!(
            msg.contains(&format!(
                "HIFRAMES_PROP_SEED={} HIFRAMES_PROP_CASES=1",
                base.wrapping_add(3)
            )),
            "missing repro command: {msg}"
        );
        // and the advertised re-run really replays the same case seed
        assert_eq!(case_seed(base.wrapping_add(3), 0), seed);
    }

    #[test]
    fn scaled_cases_tracks_the_env_knob() {
        // Under the default 64-case configuration the multiplier passes
        // through unchanged; the scaling never rounds to zero.
        if default_cases() == 64 {
            assert_eq!(scaled_cases(16), 16);
            assert_eq!(scaled_cases(256), 256);
        }
        assert!(scaled_cases(1) >= 1);
    }

    #[test]
    fn generators_in_bounds() {
        let mut rng = crate::datagen::Rng::new(1);
        for _ in 0..100 {
            let v = gen::vec_i64(&mut rng, 10, 0, 5);
            assert!(v.len() <= 10);
            assert!(v.iter().all(|&x| (0..5).contains(&x)));
            let m = gen::mask(&mut rng, 8, 0.5);
            assert_eq!(m.len(), 8);
        }
    }
}
