//! Minimal property-based testing harness.
//!
//! The offline image does not vendor `proptest`, so this module provides the
//! subset we need: seeded generators, a `forall` driver that runs N cases,
//! and on failure reports the seed + a best-effort shrink (halving vector
//! inputs while the property still fails). Every property suite in
//! `rust/tests/properties.rs` and the module-level invariant tests build on
//! this.

use crate::datagen::Rng;

/// Number of cases per property (override with `HIFRAMES_PROP_CASES`).
pub fn default_cases() -> usize {
    std::env::var("HIFRAMES_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(64)
}

/// Run `prop` on `cases` random inputs drawn by `gen`. Panics with the seed
/// and debug representation of the (shrunk, if possible) counter-example.
pub fn forall<T, G, P>(name: &str, gen: G, prop: P)
where
    T: std::fmt::Debug + Clone,
    G: Fn(&mut Rng) -> T,
    P: Fn(&T) -> Result<(), String>,
{
    forall_cases(name, default_cases(), gen, prop)
}

/// Like [`forall`] with an explicit case count.
pub fn forall_cases<T, G, P>(name: &str, cases: usize, gen: G, prop: P)
where
    T: std::fmt::Debug + Clone,
    G: Fn(&mut Rng) -> T,
    P: Fn(&T) -> Result<(), String>,
{
    let base_seed = std::env::var("HIFRAMES_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE_u64);
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property '{name}' failed (case {case}, seed {seed:#x}): {msg}\n\
                 counter-example: {input:?}\n\
                 reproduce with HIFRAMES_PROP_SEED={base_seed}"
            );
        }
    }
}

/// Shrinking `forall` for `Vec<T>` inputs: on failure, repeatedly try
/// halves of the failing vector to present a smaller counter-example.
pub fn forall_vec<T, G, P>(name: &str, gen: G, prop: P)
where
    T: std::fmt::Debug + Clone,
    G: Fn(&mut Rng) -> Vec<T>,
    P: Fn(&[T]) -> Result<(), String>,
{
    let cases = default_cases();
    let base_seed = std::env::var("HIFRAMES_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE_u64);
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            let shrunk = shrink_vec(&input, &prop);
            panic!(
                "property '{name}' failed (case {case}, seed {seed:#x}): {msg}\n\
                 shrunk counter-example ({} of {} elems): {shrunk:?}",
                shrunk.len(),
                input.len()
            );
        }
    }
}

fn shrink_vec<T: Clone + std::fmt::Debug>(
    failing: &[T],
    prop: &impl Fn(&[T]) -> Result<(), String>,
) -> Vec<T> {
    let mut cur = failing.to_vec();
    loop {
        if cur.len() <= 1 {
            return cur;
        }
        let half = cur.len() / 2;
        let first = &cur[..half];
        let second = &cur[half..];
        if prop(first).is_err() {
            cur = first.to_vec();
        } else if prop(second).is_err() {
            cur = second.to_vec();
        } else {
            return cur;
        }
    }
}

/// Common generators.
pub mod gen {
    use crate::datagen::Rng;

    pub fn vec_i64(rng: &mut Rng, max_len: usize, lo: i64, hi: i64) -> Vec<i64> {
        let n = rng.usize(max_len + 1);
        (0..n).map(|_| rng.i64_range(lo, hi)).collect()
    }

    pub fn vec_f64(rng: &mut Rng, max_len: usize) -> Vec<f64> {
        let n = rng.usize(max_len + 1);
        (0..n).map(|_| rng.normal() * 10.0).collect()
    }

    pub fn mask(rng: &mut Rng, len: usize, p: f64) -> Vec<bool> {
        (0..len).map(|_| rng.bool(p)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall(
            "reverse-reverse-id",
            |rng| gen::vec_i64(rng, 50, -100, 100),
            |v| {
                let mut r = v.clone();
                r.reverse();
                r.reverse();
                if r == *v {
                    Ok(())
                } else {
                    Err("reverse twice changed vec".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn failing_property_reports() {
        forall(
            "always-fails",
            |rng| rng.i64_range(0, 10),
            |_| Err("nope".to_string()),
        );
    }

    #[test]
    #[should_panic(expected = "shrunk counter-example")]
    fn shrinker_reduces() {
        forall_vec(
            "has-a-negative",
            |rng| gen::vec_i64(rng, 64, -5, 100),
            |v| {
                if v.iter().any(|&x| x < 0) {
                    Err("found negative".into())
                } else {
                    Ok(())
                }
            },
        );
    }

    #[test]
    fn generators_in_bounds() {
        let mut rng = crate::datagen::Rng::new(1);
        for _ in 0..100 {
            let v = gen::vec_i64(&mut rng, 10, 0, 5);
            assert!(v.len() <= 10);
            assert!(v.iter().all(|&x| (0..5).contains(&x)));
            let m = gen::mask(&mut rng, 8, 0.5);
            assert_eq!(m.len(), 8);
        }
    }
}
