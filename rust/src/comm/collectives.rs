//! Collective operations over [`Comm`] — the `MPI_*` calls the paper's
//! CGen emits (§4.5), with identical semantics:
//!
//! * [`Comm::alltoallv_bytes`] — the shuffle primitive for join/aggregate.
//!   The paper first runs an `MPI_Alltoall` of counts so receivers can size
//!   buffers; our channels carry length-prefixed payloads so the counts
//!   exchange is implicit, but we still expose [`Comm::alltoall_counts`]
//!   because the rebalance planner needs it.
//! * [`Comm::exscan_f64`] / [`Comm::exscan_i64`] — `MPI_Exscan` for cumsum.
//! * [`Comm::allreduce_f64`] / [`Comm::allreduce_i64`] — sums/min/max of
//!   scalars (feature scaling's `mean`/`var`, global row counts).
//! * [`Comm::halo_exchange`] — near-neighbor exchange for stencils
//!   (the `MPI_Isend/Irecv/Wait` pattern).
//! * [`Comm::gather_bytes`] / [`Comm::bcast_bytes`] / [`Comm::allgather_bytes`].
//! * [`Comm::allreduce_bytes_or`] — `MPI_Allreduce(MPI_BOR)` over byte
//!   vectors; the skew-aware join's global matched-flag merge.

use super::Comm;

/// Reduction operator for scalar collectives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReduceOp {
    Sum,
    Min,
    Max,
}

impl Comm {
    /// Exchange one byte-buffer with every rank (including self).
    /// `bufs[d]` is sent to rank `d`; returns `out[s]` = buffer from rank `s`.
    pub fn alltoallv_bytes(&self, bufs: Vec<Vec<u8>>) -> Vec<Vec<u8>> {
        assert_eq!(bufs.len(), self.nranks(), "alltoallv: need one buf per rank");
        self.count_collective();
        let _t = self.collective_timer();
        for (d, buf) in bufs.into_iter().enumerate() {
            self.send(d, buf);
        }
        (0..self.nranks()).map(|s| self.recv(s)).collect()
    }

    /// `MPI_Alltoall` of one u64 per rank (the counts pre-exchange).
    pub fn alltoall_counts(&self, counts: &[u64]) -> Vec<u64> {
        assert_eq!(counts.len(), self.nranks());
        self.count_collective();
        let _t = self.collective_timer();
        for (d, &c) in counts.iter().enumerate() {
            self.send(d, c.to_le_bytes().to_vec());
        }
        (0..self.nranks())
            .map(|s| {
                let b = self.recv(s);
                u64::from_le_bytes(b.try_into().expect("counts: 8 bytes"))
            })
            .collect()
    }

    /// Exclusive scan: rank r receives `op` over ranks 0..r (0/identity on
    /// rank 0). Matches `MPI_Exscan` with undefined-on-root replaced by the
    /// identity, which is what the paper's cumsum codegen wants.
    pub fn exscan_f64(&self, value: f64, op: ReduceOp) -> f64 {
        self.count_collective();
        let _t = self.collective_timer();
        // Post value to all higher ranks, then fold contributions from lower.
        for d in self.rank() + 1..self.nranks() {
            self.send(d, value.to_le_bytes().to_vec());
        }
        let mut acc = identity_f64(op);
        for s in 0..self.rank() {
            let b = self.recv(s);
            let v = f64::from_le_bytes(b.try_into().expect("exscan: 8 bytes"));
            acc = apply_f64(acc, v, op);
        }
        acc
    }

    /// Integer twin of [`Comm::exscan_f64`].
    pub fn exscan_i64(&self, value: i64, op: ReduceOp) -> i64 {
        self.count_collective();
        let _t = self.collective_timer();
        for d in self.rank() + 1..self.nranks() {
            self.send(d, value.to_le_bytes().to_vec());
        }
        let mut acc = identity_i64(op);
        for s in 0..self.rank() {
            let b = self.recv(s);
            let v = i64::from_le_bytes(b.try_into().expect("exscan: 8 bytes"));
            acc = apply_i64(acc, v, op);
        }
        acc
    }

    /// Allreduce of one f64 (sum/min/max on every rank).
    pub fn allreduce_f64(&self, value: f64, op: ReduceOp) -> f64 {
        self.count_collective();
        let _t = self.collective_timer();
        for d in 0..self.nranks() {
            if d != self.rank() {
                self.send(d, value.to_le_bytes().to_vec());
            }
        }
        // fold strictly in rank order so every rank computes a bit-identical
        // result (floating-point reduction order matters; HPAT-generated
        // MPI_Allreduce has the same determinism guarantee per run)
        let mut acc = identity_f64(op);
        for s in 0..self.nranks() {
            let v = if s == self.rank() {
                value
            } else {
                let b = self.recv(s);
                f64::from_le_bytes(b.try_into().expect("allreduce: 8 bytes"))
            };
            acc = apply_f64(acc, v, op);
        }
        acc
    }

    /// Integer twin of [`Comm::allreduce_f64`].
    pub fn allreduce_i64(&self, value: i64, op: ReduceOp) -> i64 {
        self.count_collective();
        let _t = self.collective_timer();
        for d in 0..self.nranks() {
            if d != self.rank() {
                self.send(d, value.to_le_bytes().to_vec());
            }
        }
        let mut acc = value;
        for s in 0..self.nranks() {
            if s != self.rank() {
                let b = self.recv(s);
                let v = i64::from_le_bytes(b.try_into().expect("allreduce: 8 bytes"));
                acc = apply_i64(acc, v, op);
            }
        }
        acc
    }

    /// Element-wise allreduce of an f64 vector (k-means centroid partials).
    pub fn allreduce_f64_vec(&self, values: &[f64], op: ReduceOp) -> Vec<f64> {
        self.count_collective();
        let _t = self.collective_timer();
        let mut payload = Vec::with_capacity(values.len() * 8);
        for v in values {
            payload.extend_from_slice(&v.to_le_bytes());
        }
        for d in 0..self.nranks() {
            if d != self.rank() {
                self.send(d, payload.clone());
            }
        }
        // rank-ordered fold: bit-identical across ranks (see allreduce_f64)
        let mut acc = vec![identity_f64(op); values.len()];
        for s in 0..self.nranks() {
            if s == self.rank() {
                for (a, &v) in acc.iter_mut().zip(values) {
                    *a = apply_f64(*a, v, op);
                }
            } else {
                let b = self.recv(s);
                assert_eq!(b.len(), values.len() * 8, "allreduce_vec: length mismatch");
                for (i, chunk) in b.chunks_exact(8).enumerate() {
                    let v = f64::from_le_bytes(chunk.try_into().unwrap());
                    acc[i] = apply_f64(acc[i], v, op);
                }
            }
        }
        acc
    }

    /// Gather byte-buffers on `root`; non-root ranks get an empty vec.
    pub fn gather_bytes(&self, root: usize, payload: Vec<u8>) -> Vec<Vec<u8>> {
        self.count_collective();
        let _t = self.collective_timer();
        if self.rank() == root {
            let mut out: Vec<Vec<u8>> = (0..self.nranks()).map(|_| Vec::new()).collect();
            out[root] = payload;
            for s in 0..self.nranks() {
                if s != root {
                    out[s] = self.recv(s);
                }
            }
            out
        } else {
            self.send(root, payload);
            Vec::new()
        }
    }

    /// Broadcast a byte-buffer from `root` to every rank.
    pub fn bcast_bytes(&self, root: usize, payload: Vec<u8>) -> Vec<u8> {
        self.count_collective();
        let _t = self.collective_timer();
        if self.rank() == root {
            for d in 0..self.nranks() {
                if d != root {
                    self.send(d, payload.clone());
                }
            }
            payload
        } else {
            self.recv(root)
        }
    }

    /// Allgather: every rank receives every rank's buffer, in rank order.
    pub fn allgather_bytes(&self, payload: Vec<u8>) -> Vec<Vec<u8>> {
        self.count_collective();
        let _t = self.collective_timer();
        for d in 0..self.nranks() {
            if d != self.rank() {
                self.send(d, payload.clone());
            }
        }
        let mut out: Vec<Vec<u8>> = (0..self.nranks()).map(|_| Vec::new()).collect();
        for s in 0..self.nranks() {
            if s == self.rank() {
                out[s] = payload.clone();
            } else {
                out[s] = self.recv(s);
            }
        }
        out
    }

    /// Element-wise bitwise-OR allreduce over equal-length byte vectors —
    /// `MPI_Allreduce(MPI_BOR)`. The skew-aware join uses it to merge the
    /// per-rank "which replicated build rows did *I* match" flags into the
    /// global matched set before emitting the unmatched rows of a
    /// Right/Outer join exactly once.
    pub fn allreduce_bytes_or(&self, payload: Vec<u8>) -> Vec<u8> {
        self.count_collective();
        let _t = self.collective_timer();
        for d in 0..self.nranks() {
            if d != self.rank() {
                self.send(d, payload.clone());
            }
        }
        let mut acc = payload;
        for s in 0..self.nranks() {
            if s != self.rank() {
                let b = self.recv(s);
                assert_eq!(
                    b.len(),
                    acc.len(),
                    "allreduce_bytes_or: length mismatch"
                );
                for (a, v) in acc.iter_mut().zip(b) {
                    *a |= v;
                }
            }
        }
        acc
    }

    /// Near-neighbor halo exchange for 1-D stencils: send `to_prev` to rank
    /// r-1 and `to_next` to rank r+1; receive `(from_prev, from_next)`.
    /// Edge ranks get `None` on the missing side. The paper overlaps this
    /// with computation via `MPI_Isend/Irecv`; our sends are already
    /// non-blocking so the structure is identical.
    pub fn halo_exchange(
        &self,
        to_prev: Vec<u8>,
        to_next: Vec<u8>,
    ) -> (Option<Vec<u8>>, Option<Vec<u8>>) {
        self.count_collective();
        let _t = self.collective_timer();
        let r = self.rank();
        let n = self.nranks();
        if r > 0 {
            self.send(r - 1, to_prev);
        }
        if r + 1 < n {
            self.send(r + 1, to_next);
        }
        let from_prev = (r > 0).then(|| self.recv(r - 1));
        let from_next = (r + 1 < n).then(|| self.recv(r + 1));
        (from_prev, from_next)
    }
}

fn identity_f64(op: ReduceOp) -> f64 {
    match op {
        ReduceOp::Sum => 0.0,
        ReduceOp::Min => f64::INFINITY,
        ReduceOp::Max => f64::NEG_INFINITY,
    }
}

fn identity_i64(op: ReduceOp) -> i64 {
    match op {
        ReduceOp::Sum => 0,
        ReduceOp::Min => i64::MAX,
        ReduceOp::Max => i64::MIN,
    }
}

fn apply_f64(a: f64, b: f64, op: ReduceOp) -> f64 {
    match op {
        ReduceOp::Sum => a + b,
        ReduceOp::Min => a.min(b),
        ReduceOp::Max => a.max(b),
    }
}

fn apply_i64(a: i64, b: i64, op: ReduceOp) -> i64 {
    match op {
        ReduceOp::Sum => a + b,
        ReduceOp::Min => a.min(b),
        ReduceOp::Max => a.max(b),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::run_spmd;

    #[test]
    fn alltoallv_transposes() {
        let out = run_spmd(3, |c| {
            let bufs: Vec<Vec<u8>> = (0..3)
                .map(|d| vec![(c.rank() * 10 + d) as u8])
                .collect();
            c.alltoallv_bytes(bufs)
        });
        // rank r receives [s*10 + r for s in 0..3]
        for (r, received) in out.iter().enumerate() {
            for (s, buf) in received.iter().enumerate() {
                assert_eq!(buf, &vec![(s * 10 + r) as u8]);
            }
        }
    }

    #[test]
    fn alltoall_counts_exchange() {
        let out = run_spmd(4, |c| {
            let counts: Vec<u64> = (0..4).map(|d| (c.rank() * 100 + d) as u64).collect();
            c.alltoall_counts(&counts)
        });
        for (r, recv) in out.iter().enumerate() {
            for (s, &v) in recv.iter().enumerate() {
                assert_eq!(v, (s * 100 + r) as u64);
            }
        }
    }

    #[test]
    fn exscan_matches_prefix() {
        let out = run_spmd(5, |c| c.exscan_f64((c.rank() + 1) as f64, ReduceOp::Sum));
        // rank r gets sum of 1..=r
        assert_eq!(out, vec![0.0, 1.0, 3.0, 6.0, 10.0]);
        let out = run_spmd(4, |c| c.exscan_i64(c.rank() as i64, ReduceOp::Max));
        assert_eq!(out, vec![i64::MIN, 0, 1, 2]);
    }

    #[test]
    fn allreduce_all_ops() {
        let sums = run_spmd(4, |c| c.allreduce_f64(c.rank() as f64, ReduceOp::Sum));
        assert!(sums.iter().all(|&s| s == 6.0));
        let mins = run_spmd(4, |c| c.allreduce_i64(c.rank() as i64 + 5, ReduceOp::Min));
        assert!(mins.iter().all(|&m| m == 5));
        let maxs = run_spmd(3, |c| c.allreduce_f64(-(c.rank() as f64), ReduceOp::Max));
        assert!(maxs.iter().all(|&m| m == 0.0));
    }

    #[test]
    fn allreduce_vec_sums_elementwise() {
        let out = run_spmd(3, |c| {
            c.allreduce_f64_vec(&[c.rank() as f64, 1.0], ReduceOp::Sum)
        });
        for v in out {
            assert_eq!(v, vec![3.0, 3.0]);
        }
    }

    #[test]
    fn gather_and_bcast() {
        let out = run_spmd(3, |c| {
            let gathered = c.gather_bytes(0, vec![c.rank() as u8]);
            if c.rank() == 0 {
                assert_eq!(gathered, vec![vec![0u8], vec![1], vec![2]]);
            } else {
                assert!(gathered.is_empty());
            }
            let b = c.bcast_bytes(0, if c.rank() == 0 { vec![42] } else { Vec::new() });
            b[0]
        });
        assert_eq!(out, vec![42, 42, 42]);
    }

    #[test]
    fn allgather_orders_by_rank() {
        let out = run_spmd(4, |c| c.allgather_bytes(vec![c.rank() as u8; 2]));
        for per_rank in out {
            assert_eq!(
                per_rank,
                vec![vec![0u8, 0], vec![1, 1], vec![2, 2], vec![3, 3]]
            );
        }
    }

    #[test]
    fn allreduce_bytes_or_merges_flags() {
        let out = run_spmd(3, |c| {
            // rank r sets byte r (and everyone sets byte 3)
            let mut flags = vec![0u8; 4];
            flags[c.rank()] = 1;
            flags[3] = 1;
            c.allreduce_bytes_or(flags)
        });
        for per_rank in out {
            assert_eq!(per_rank, vec![1u8, 1, 1, 1]);
        }
        // empty payloads are a no-op on every rank
        let out = run_spmd(2, |c| {
            let _ = c.rank();
            c.allreduce_bytes_or(Vec::new())
        });
        assert!(out.iter().all(|v| v.is_empty()));
    }

    #[test]
    fn halo_exchange_neighbors() {
        let out = run_spmd(4, |c| {
            let (p, n) = c.halo_exchange(vec![c.rank() as u8], vec![c.rank() as u8]);
            (p.map(|b| b[0]), n.map(|b| b[0]))
        });
        assert_eq!(out[0], (None, Some(1)));
        assert_eq!(out[1], (Some(0), Some(2)));
        assert_eq!(out[2], (Some(1), Some(3)));
        assert_eq!(out[3], (Some(2), None));
    }

    #[test]
    fn halo_exchange_single_rank() {
        let out = run_spmd(1, |c| c.halo_exchange(vec![1], vec![2]));
        assert_eq!(out[0], (None, None));
    }
}
