//! Simulated-MPI communicator — the distributed substrate.
//!
//! The paper generates MPI: `MPI_Alltoall(v)` for shuffles, `MPI_Exscan`
//! for cumulative sums, `MPI_Isend/Irecv/Wait` for stencil halos (§4.5).
//! This module reproduces those collective *semantics* with N rank-threads
//! in one process connected by per-pair byte channels. Payload serialization
//! is real (the column codec), so per-rank communication volumes — the
//! quantity the paper's performance analysis turns on — are measured, not
//! modeled. See DESIGN.md §3 for the substitution argument.
//!
//! Deadlock discipline: channels are unbounded, so sends never block; every
//! collective is written as "post all sends, then drain receives", which is
//! safe for any interleaving across ranks.

mod collectives;

pub use collectives::*;

use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Barrier, Mutex};
use std::time::Instant;

/// Shared communication counters (read by the benches).
#[derive(Debug, Default)]
pub struct CommStats {
    pub messages: AtomicU64,
    pub bytes: AtomicU64,
    pub barriers: AtomicU64,
    pub collectives: AtomicU64,
}

impl CommStats {
    /// `(messages, bytes, barriers, collectives)` at this instant.
    pub fn snapshot(&self) -> (u64, u64, u64, u64) {
        (
            self.messages.load(Ordering::Relaxed),
            self.bytes.load(Ordering::Relaxed),
            self.barriers.load(Ordering::Relaxed),
            self.collectives.load(Ordering::Relaxed),
        )
    }
}

/// Per-scope communication deltas — what one rank sent and how long it
/// waited in collectives while a profiling scope was open. The graph
/// executor opens a scope around each node execution (`scope_begin` /
/// `scope_end`) to attribute traffic to the issuing plan node; this is the
/// per-query tagging the ROADMAP serving item asks for. See DESIGN.md §4.7.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct CommScope {
    /// Point-to-point messages sent by this rank inside the scope.
    pub messages: u64,
    /// Payload bytes sent by this rank inside the scope.
    pub bytes: u64,
    /// Collective operations issued inside the scope.
    pub collectives: u64,
    /// Wall time spent inside those collectives (nanoseconds; includes
    /// wait time, which is the skew signal).
    pub collective_ns: u64,
}

/// One rank's endpoint of the world: `MPI_COMM_WORLD` from that rank's view.
pub struct Comm {
    rank: usize,
    nranks: usize,
    /// senders[d] sends to rank d.
    senders: Vec<Sender<Vec<u8>>>,
    /// receivers[s] receives from rank s.
    receivers: Vec<Receiver<Vec<u8>>>,
    barrier: Arc<Barrier>,
    stats: Arc<CommStats>,
    /// Active profiling scope, if any. `RefCell` (not atomic): a `Comm` is
    /// owned by exactly one rank thread. `None` on the unprofiled path, so
    /// the only overhead when off is one borrow + `is_some` check.
    scope: RefCell<Option<CommScope>>,
}

impl Comm {
    /// This rank's id (`MPI_Comm_rank`).
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// World size (`MPI_Comm_size`).
    pub fn nranks(&self) -> usize {
        self.nranks
    }

    /// Shared communication counters (read by the benches).
    pub fn stats(&self) -> &CommStats {
        &self.stats
    }

    /// Is this rank 0 (the driver/leader rank)?
    pub fn is_root(&self) -> bool {
        self.rank == 0
    }

    /// Point-to-point send (non-blocking, like a completed `MPI_Isend`).
    pub fn send(&self, dst: usize, payload: Vec<u8>) {
        self.stats.messages.fetch_add(1, Ordering::Relaxed);
        self.stats
            .bytes
            .fetch_add(payload.len() as u64, Ordering::Relaxed);
        if let Some(s) = self.scope.borrow_mut().as_mut() {
            s.messages += 1;
            s.bytes += payload.len() as u64;
        }
        self.senders[dst]
            .send(payload)
            .expect("comm: send to dead rank");
    }

    /// Blocking receive from a specific source rank.
    pub fn recv(&self, src: usize) -> Vec<u8> {
        self.receivers[src]
            .recv()
            .expect("comm: recv from dead rank")
    }

    /// `MPI_Barrier`.
    pub fn barrier(&self) {
        self.stats.barriers.fetch_add(1, Ordering::Relaxed);
        self.barrier.wait();
    }

    pub(crate) fn count_collective(&self) {
        self.stats.collectives.fetch_add(1, Ordering::Relaxed);
        if let Some(s) = self.scope.borrow_mut().as_mut() {
            s.collectives += 1;
        }
    }

    /// Open a fresh profiling scope: subsequent sends and collectives on
    /// this rank accumulate into it until [`Self::scope_end`]. Scopes do
    /// not nest — beginning a new one discards any open scope.
    pub fn scope_begin(&self) {
        *self.scope.borrow_mut() = Some(CommScope::default());
    }

    /// Close the active scope and return its deltas (zeros if none open).
    pub fn scope_end(&self) -> CommScope {
        self.scope.borrow_mut().take().unwrap_or_default()
    }

    /// RAII timer charging its lifetime to the active scope's collective
    /// wall time. When no scope is open (`start == None`) the drop is a
    /// no-op and `Instant::now` is never called — the unprofiled path
    /// stays clock-free.
    pub(crate) fn collective_timer(&self) -> CollectiveTimer<'_> {
        CollectiveTimer {
            comm: self,
            start: self.scope.borrow().is_some().then(Instant::now),
        }
    }
}

/// See [`Comm::collective_timer`].
pub(crate) struct CollectiveTimer<'a> {
    comm: &'a Comm,
    start: Option<Instant>,
}

impl Drop for CollectiveTimer<'_> {
    fn drop(&mut self) {
        if let Some(start) = self.start {
            if let Some(s) = self.comm.scope.borrow_mut().as_mut() {
                s.collective_ns += start.elapsed().as_nanos() as u64;
            }
        }
    }
}

/// Create an `n`-rank world and run `f` on every rank concurrently,
/// returning the per-rank results in rank order. This is the launcher the
/// paper gets from `mpiexec`.
pub fn run_spmd<R, F>(nranks: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(Comm) -> R + Sync,
{
    assert!(nranks > 0, "run_spmd: need at least one rank");
    let stats = Arc::new(CommStats::default());
    let comms = build_world(nranks, stats);
    let mut results: Vec<Option<R>> = (0..nranks).map(|_| None).collect();
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for comm in comms {
            let fref = &f;
            handles.push(scope.spawn(move || fref(comm)));
        }
        for (slot, h) in results.iter_mut().zip(handles) {
            *slot = Some(h.join().expect("comm: rank panicked"));
        }
    });
    results.into_iter().map(|r| r.unwrap()).collect()
}

/// Like [`run_spmd`] but also returns the shared [`CommStats`].
pub fn run_spmd_with_stats<R, F>(nranks: usize, f: F) -> (Vec<R>, Arc<CommStats>)
where
    R: Send,
    F: Fn(Comm) -> R + Sync,
{
    let stats = Arc::new(CommStats::default());
    let comms = build_world(nranks, stats.clone());
    let mut results: Vec<Option<R>> = (0..nranks).map(|_| None).collect();
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for comm in comms {
            let fref = &f;
            handles.push(scope.spawn(move || fref(comm)));
        }
        for (slot, h) in results.iter_mut().zip(handles) {
            *slot = Some(h.join().expect("comm: rank panicked"));
        }
    });
    (results.into_iter().map(|r| r.unwrap()).collect(), stats)
}

fn build_world(nranks: usize, stats: Arc<CommStats>) -> Vec<Comm> {
    // channels[s][d] is the (tx, rx) pair for s -> d.
    let mut txs: Vec<Vec<Option<Sender<Vec<u8>>>>> = (0..nranks)
        .map(|_| (0..nranks).map(|_| None).collect())
        .collect();
    let mut rxs: Vec<Vec<Option<Receiver<Vec<u8>>>>> = (0..nranks)
        .map(|_| (0..nranks).map(|_| None).collect())
        .collect();
    for s in 0..nranks {
        for d in 0..nranks {
            let (tx, rx) = channel();
            txs[s][d] = Some(tx);
            rxs[d][s] = Some(rx); // indexed by receiver, then source
        }
    }
    let barrier = Arc::new(Barrier::new(nranks));
    let mut comms = Vec::with_capacity(nranks);
    for (r, (tx_row, rx_row)) in txs.into_iter().zip(rxs).enumerate() {
        comms.push(Comm {
            rank: r,
            nranks,
            senders: tx_row.into_iter().map(|t| t.unwrap()).collect(),
            receivers: rx_row.into_iter().map(|r| r.unwrap()).collect(),
            barrier: barrier.clone(),
            stats: stats.clone(),
            scope: RefCell::new(None),
        });
    }
    comms
}

/// Split `total` rows into `nranks` 1D_BLOCK chunks: all ranks get
/// `ceil(total/nranks)` except possibly the last (paper §4.4: "all
/// processors have equal chunks of data except possibly the last").
pub fn block_range(total: usize, nranks: usize, rank: usize) -> (usize, usize) {
    let chunk = total.div_ceil(nranks);
    let start = (chunk * rank).min(total);
    let end = (chunk * (rank + 1)).min(total);
    (start, end - start)
}

/// A shared one-shot cell for returning a value computed on one rank to the
/// caller of `run_spmd` without threading it through every rank's result.
pub struct OnceCellSync<T>(Mutex<Option<T>>);

impl<T> Default for OnceCellSync<T> {
    fn default() -> Self {
        OnceCellSync(Mutex::new(None))
    }
}

impl<T> OnceCellSync<T> {
    /// Store a value (overwriting any previous one).
    pub fn set(&self, v: T) {
        *self.0.lock().unwrap() = Some(v);
    }

    /// Remove and return the stored value, if any.
    pub fn take(&self) -> Option<T> {
        self.0.lock().unwrap().take()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spmd_runs_all_ranks() {
        let out = run_spmd(4, |c| c.rank() * 10);
        assert_eq!(out, vec![0, 10, 20, 30]);
    }

    #[test]
    fn point_to_point_ring() {
        let out = run_spmd(4, |c| {
            let next = (c.rank() + 1) % c.nranks();
            let prev = (c.rank() + c.nranks() - 1) % c.nranks();
            c.send(next, vec![c.rank() as u8]);
            let got = c.recv(prev);
            got[0] as usize
        });
        assert_eq!(out, vec![3, 0, 1, 2]);
    }

    #[test]
    fn barrier_synchronizes() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let flag = AtomicUsize::new(0);
        run_spmd(4, |c| {
            if c.rank() == 0 {
                flag.store(1, Ordering::SeqCst);
            }
            c.barrier();
            assert_eq!(flag.load(Ordering::SeqCst), 1);
        });
    }

    #[test]
    fn stats_count_messages() {
        let (_, stats) = run_spmd_with_stats(2, |c| {
            c.send(1 - c.rank(), vec![0u8; 100]);
            c.recv(1 - c.rank());
        });
        let (msgs, bytes, _, _) = stats.snapshot();
        assert_eq!(msgs, 2);
        assert_eq!(bytes, 200);
    }

    #[test]
    fn scope_attributes_sends_and_collectives() {
        let (out, stats) = run_spmd_with_stats(2, |c| {
            // traffic before the scope: global stats only
            c.send(1 - c.rank(), vec![0u8; 10]);
            c.recv(1 - c.rank());
            c.scope_begin();
            c.send(1 - c.rank(), vec![0u8; 25]);
            c.recv(1 - c.rank());
            let _ = c.allreduce_i64(c.rank() as i64, ReduceOp::Sum);
            let scope = c.scope_end();
            // after the scope: untracked again
            c.send(1 - c.rank(), vec![0u8; 7]);
            c.recv(1 - c.rank());
            scope
        });
        for s in &out {
            assert_eq!(s.messages, 2, "scoped send + allreduce exchange");
            assert!(s.bytes >= 25, "scoped bytes include the 25B payload");
            assert_eq!(s.collectives, 1);
        }
        // the global sink still saw everything: per rank, two unscoped
        // sends (10B, 7B) plus the two scoped messages counted above
        let (msgs, bytes, _, colls) = stats.snapshot();
        assert_eq!(msgs, 2 * 2 + out.iter().map(|s| s.messages).sum::<u64>());
        assert!(bytes >= 2 * (10 + 25 + 7));
        assert_eq!(colls, 2);
        // no open scope -> zeros
        let zero = run_spmd(1, |c| c.scope_end());
        assert_eq!(zero[0], CommScope::default());
    }

    #[test]
    fn block_range_covers_exactly() {
        for total in [0usize, 1, 7, 100, 101] {
            for p in [1usize, 2, 3, 8] {
                let mut covered = 0;
                let mut next_start = 0;
                for r in 0..p {
                    let (s, l) = block_range(total, p, r);
                    assert_eq!(s, next_start.min(total));
                    covered += l;
                    next_start = s + l;
                }
                assert_eq!(covered, total, "total={total} p={p}");
            }
        }
    }

    #[test]
    fn block_range_equal_chunks_except_last() {
        let (_, l0) = block_range(10, 4, 0);
        let (_, l1) = block_range(10, 4, 1);
        let (_, l2) = block_range(10, 4, 2);
        let (_, l3) = block_range(10, 4, 3);
        assert_eq!((l0, l1, l2, l3), (3, 3, 3, 1));
    }

    #[test]
    fn single_rank_world() {
        let out = run_spmd(1, |c| {
            c.barrier();
            c.nranks()
        });
        assert_eq!(out, vec![1]);
    }
}
