//! Minimal Fx-style hasher for i64 keys (the offline image has no
//! `rustc-hash` in our dependency set, and std's SipHash dominated the
//! hash-aggregate profile — §Perf: aggregate 133→~90ms on Fig. 8a after
//! switching the per-row group lookups to this hasher).

use std::hash::{BuildHasherDefault, Hasher};

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

/// Multiply-rotate hasher (the rustc FxHasher recipe).
#[derive(Default)]
pub struct FxHasher {
    hash: u64,
}

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.add(b as u64);
        }
    }

    #[inline]
    fn write_u64(&mut self, n: u64) {
        self.add(n);
    }

    #[inline]
    fn write_i64(&mut self, n: i64) {
        self.add(n as u64);
    }

    #[inline]
    fn write_usize(&mut self, n: usize) {
        self.add(n as u64);
    }
}

impl FxHasher {
    #[inline]
    fn add(&mut self, word: u64) {
        self.hash = (self.hash.rotate_left(5) ^ word).wrapping_mul(SEED);
    }
}

/// `HashMap` with the Fx hasher.
pub type FxHashMap<K, V> = std::collections::HashMap<K, V, BuildHasherDefault<FxHasher>>;

/// `HashSet` with the Fx hasher.
pub type FxHashSet<K> = std::collections::HashSet<K, BuildHasherDefault<FxHasher>>;

/// One-shot Fx hash of a single word (the packed single-I64 key path).
#[inline]
pub fn hash_u64(x: u64) -> u64 {
    let mut h = FxHasher::default();
    h.write_u64(x);
    h.finish()
}

/// One-shot Fx hash of a byte string, folded 8 bytes at a time — the
/// byte-at-a-time `write` loop dominated the packed-key routing profile.
/// The length is mixed in so zero-padded tails of different lengths don't
/// trivially collide.
#[inline]
pub fn hash_bytes(bytes: &[u8]) -> u64 {
    let mut h = FxHasher::default();
    let mut chunks = bytes.chunks_exact(8);
    for c in chunks.by_ref() {
        h.write_u64(u64::from_le_bytes(c.try_into().expect("8-byte chunk")));
    }
    let rem = chunks.remainder();
    if !rem.is_empty() {
        let mut tail = [0u8; 8];
        tail[..rem.len()].copy_from_slice(rem);
        h.write_u64(u64::from_le_bytes(tail));
    }
    h.write_u64(bytes.len() as u64);
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn distributes_keys() {
        let mut m: FxHashMap<i64, usize> = FxHashMap::default();
        for i in 0..10_000i64 {
            *m.entry(i % 97).or_insert(0) += 1;
        }
        assert_eq!(m.len(), 97);
        assert!(m.values().all(|&v| v > 0));
    }

    #[test]
    fn one_shot_helpers_deterministic() {
        assert_eq!(hash_u64(42), hash_u64(42));
        assert_ne!(hash_u64(42), hash_u64(43));
        let a = b"composite-key-bytes";
        assert_eq!(hash_bytes(a), hash_bytes(a));
        assert_ne!(hash_bytes(b"ab"), hash_bytes(b"ba"));
        // length mixing: a zero tail is not the same as no tail
        assert_ne!(hash_bytes(b"abcdefgh"), hash_bytes(b"abcdefgh\0"));
        assert_eq!(hash_bytes(b""), hash_bytes(b""));
    }

    #[test]
    fn deterministic() {
        use std::hash::{BuildHasher, BuildHasherDefault};
        let b: BuildHasherDefault<FxHasher> = Default::default();
        let h1 = b.hash_one(42i64);
        let h2 = b.hash_one(42i64);
        assert_eq!(h1, h2);
        assert_ne!(b.hash_one(42i64), b.hash_one(43i64));
    }
}
