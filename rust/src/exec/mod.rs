//! SPMD execution — the CGen analogue (paper §4.5).
//!
//! Every rank interprets the *same* optimized [`PlanGraph`] over its
//! partition of the data, walking the graph's topological execution order
//! and calling into [`crate::ops`] wherever the paper's generated C would
//! issue MPI collectives. The per-rank state is a [`LocalFrame`]: a flat
//! `name → Column (+ optional validity mask)` environment, i.e. every
//! data-frame column is an individual array variable plus its null bitmap
//! (dual representation, validity-mask null model).
//!
//! Because the graph hash-conses identical subplans, a shared node is
//! materialized **once per rank** and its frame handed to every consumer
//! (cloned until the last use, which takes ownership). The
//! [`GraphRunStats`] returned alongside each result — and mirrored into
//! [`crate::metrics::plan_stats`] — count those reuses.

use crate::column::{
    decode_nullable_column, encode_nullable_column, extend_opt_mask, normalize_mask, Column,
    NullableColumn, ValidityMask,
};
use crate::comm::{block_range, run_spmd, run_spmd_with_stats, Comm, CommScope};
use crate::expr::{eval_nullable, ColumnEnv};
use crate::fxhash::{FxHashMap, FxHashSet};
use crate::ir::graph::{Node, NodeId, PlanGraph, Store};
use crate::ir::{Plan, SourceRef, WindowAgg};
use crate::ops::{self, aggregate::AggSpec, aggregate::AggStrategy, MaskedCol};
use crate::passes::{optimize_graph, PassOptions};
use crate::table::{Schema, Table};
use crate::trace::{self, QueryProfile};
use crate::types::SortOrder;
use anyhow::{Context, Result};
use std::rc::Rc;
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Execution options: worker (rank) count, optimizer toggles, the
/// aggregation strategy (ablations flip these) and the per-rank memory
/// budget gating out-of-core execution.
#[derive(Debug, Clone)]
pub struct ExecOptions {
    pub workers: usize,
    pub passes: PassOptions,
    pub agg_strategy: AggStrategy,
    /// Per-rank memory budget in bytes for join/aggregate/sort working
    /// sets; operators exceeding it spill to disk (see `ops/spill.rs` and
    /// DESIGN.md §4.5). `None` (or `Some(0)`) = unlimited, the in-memory
    /// paths bit for bit. Defaults from `HIFRAMES_MEM_BUDGET`.
    pub mem_budget: Option<usize>,
    /// Record a per-node/per-rank [`QueryProfile`] for every collect (see
    /// `trace.rs` and DESIGN.md §4.7). Never changes results — profiled
    /// and unprofiled collects are byte-identical. Defaults from
    /// `HIFRAMES_PROFILE`.
    pub profile: bool,
}

impl Default for ExecOptions {
    fn default() -> Self {
        ExecOptions {
            workers: crate::config::default_workers(),
            passes: PassOptions::default(),
            agg_strategy: AggStrategy::RawShuffle,
            mem_budget: crate::config::mem_budget_from_env(),
            profile: crate::config::profile_from_env(),
        }
    }
}

/// One rank's chunk of a distributed data frame. `masks[i]` is column i's
/// validity (`None` = fully valid — the canonical form).
#[derive(Debug, Clone)]
pub struct LocalFrame {
    pub schema: Schema,
    pub cols: Vec<Column>,
    pub masks: Vec<Option<ValidityMask>>,
}

impl LocalFrame {
    /// A frame with no nulls anywhere.
    pub fn new(schema: Schema, cols: Vec<Column>) -> LocalFrame {
        let masks = vec![None; cols.len()];
        LocalFrame {
            schema,
            cols,
            masks,
        }
    }

    pub fn num_rows(&self) -> usize {
        self.cols.first().map_or(0, |c| c.len())
    }

    pub fn col(&self, name: &str) -> Result<&Column> {
        let i = self
            .schema
            .index_of(name)
            .with_context(|| format!("local frame: no column :{name}"))?;
        Ok(&self.cols[i])
    }

    /// `(values, mask)` view of one column — the ops-layer argument shape.
    pub fn masked(&self, name: &str) -> Result<MaskedCol<'_>> {
        let i = self
            .schema
            .index_of(name)
            .with_context(|| format!("local frame: no column :{name}"))?;
        Ok((&self.cols[i], self.masks[i].as_ref()))
    }

    /// Materialize this rank's chunk as a table (debug/inspection).
    pub fn into_table(self) -> Result<Table> {
        Table::new_masked(self.schema, self.cols, self.masks)
    }
}

impl ColumnEnv for LocalFrame {
    fn column(&self, name: &str) -> Option<&Column> {
        self.schema.index_of(name).map(|i| &self.cols[i])
    }
    fn num_rows(&self) -> usize {
        LocalFrame::num_rows(self)
    }
    fn validity(&self, name: &str) -> Option<&ValidityMask> {
        self.schema
            .index_of(name)
            .and_then(|i| self.masks[i].as_ref())
    }
}

/// Per-run execution counters, summed over all ranks by the driver. The
/// shared-subplan dedup and the plan cache surface here (and in
/// [`crate::metrics::plan_stats`]) so tests and benches can assert "the
/// diamond's shared arm ran exactly once per rank".
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct GraphRunStats {
    /// Graph nodes actually interpreted (per rank, summed over ranks).
    pub nodes_executed: u64,
    /// Memo fetches beyond a node's first — each one is a subplan that
    /// hash-consing saved from re-execution.
    pub reuse_hits: u64,
    /// `Plan::Cache` nodes satisfied from a [`PlanCache`] without
    /// executing their subplan (counted once per run, not per rank).
    pub cache_hits: u64,
}

/// One pinned result of an explicit `df.cache()` materialization point.
#[derive(Debug)]
struct CacheEntry {
    /// Clones of every source reference under the cached subplan. In-memory
    /// sources key their identity by `Arc` address ([`crate::ir::graph`]),
    /// so the entry must keep those `Arc`s alive: a freed table's address
    /// could be recycled by a brand-new table and alias the cache key.
    _pins: Vec<SourceRef>,
    table: Arc<Table>,
}

/// Cross-`collect` store for `Plan::Cache` results, keyed by the cached
/// subplan's structural key (position-independent, deterministic for one
/// process). A [`crate::frame::HiFrames`] context owns one and threads it
/// through every collect, pinning shared subplans across separate queries.
#[derive(Debug, Default)]
pub struct PlanCache {
    entries: Mutex<FxHashMap<String, CacheEntry>>,
}

impl PlanCache {
    pub fn new() -> PlanCache {
        PlanCache::default()
    }

    pub fn len(&self) -> usize {
        self.entries.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop every pinned result (and with it the source pins).
    pub fn clear(&self) {
        self.entries.lock().unwrap().clear();
    }

    fn lookup(&self, key: &str) -> Option<Arc<Table>> {
        self.entries
            .lock()
            .unwrap()
            .get(key)
            .map(|e| Arc::clone(&e.table))
    }

    fn insert(&self, key: String, pins: Vec<SourceRef>, table: Table) {
        self.entries.lock().unwrap().insert(
            key,
            CacheEntry {
                _pins: pins,
                table: Arc::new(table),
            },
        );
    }
}

/// Optimize `plan` and execute it on `opts.workers` ranks; gather the
/// result on the leader and return it as a table (rank-order concatenation
/// preserves global row order for ordered plans).
pub fn collect(plan: Plan, opts: &ExecOptions) -> Result<Table> {
    let g = optimize_graph(plan, &opts.passes)?;
    Ok(collect_graph(&g, opts, None)?.0)
}

/// [`collect`] plus the per-run execution counters (tests and benches).
pub fn collect_stats(plan: Plan, opts: &ExecOptions) -> Result<(Table, GraphRunStats)> {
    let g = optimize_graph(plan, &opts.passes)?;
    collect_graph(&g, opts, None)
}

/// Optimize and execute with an explicit [`PlanCache`]: `Plan::Cache`
/// nodes publish their result into `cache` on first execution and are
/// substituted by it on later runs.
pub fn collect_cached(
    plan: Plan,
    opts: &ExecOptions,
    cache: &PlanCache,
) -> Result<(Table, GraphRunStats)> {
    let g = optimize_graph(plan, &opts.passes)?;
    collect_graph(&g, opts, Some(cache))
}

/// Execute an already-optimized plan (ablations call this directly). The
/// tree is interned into a graph with the options' dedup policy first.
pub fn collect_optimized(plan: &Plan, opts: &ExecOptions) -> Result<Table> {
    let g = PlanGraph::from_plan(plan, opts.passes.dedup_subplans);
    Ok(collect_graph(&g, opts, None)?.0)
}

/// Execute an optimized [`PlanGraph`] on `opts.workers` ranks, returning
/// the gathered table and the summed per-rank counters. Records a profile
/// only when `opts.profile` is set (and discards it — use
/// [`collect_graph_profiled`] to get it back).
pub fn collect_graph(
    g: &PlanGraph,
    opts: &ExecOptions,
    cache: Option<&PlanCache>,
) -> Result<(Table, GraphRunStats)> {
    let (table, stats, _) = collect_graph_inner(g, opts, cache, opts.profile)?;
    Ok((table, stats))
}

/// [`collect_graph`] with profiling forced on: also returns the per-node/
/// per-rank [`QueryProfile`] of this run.
pub fn collect_graph_profiled(
    g: &PlanGraph,
    opts: &ExecOptions,
    cache: Option<&PlanCache>,
) -> Result<(Table, GraphRunStats, QueryProfile)> {
    let (table, stats, prof) = collect_graph_inner(g, opts, cache, true)?;
    Ok((table, stats, prof.expect("profiled run must produce a profile")))
}

/// Optimize, execute with a [`PlanCache`], and profile — the engine behind
/// `df.collect_profiled()` / `df.explain_analyze()`.
pub fn collect_cached_profiled(
    plan: Plan,
    opts: &ExecOptions,
    cache: &PlanCache,
) -> Result<(Table, GraphRunStats, QueryProfile)> {
    let g = optimize_graph(plan, &opts.passes)?;
    collect_graph_profiled(&g, opts, Some(cache))
}

/// The one executor under every `collect_*` flavor. With `profile` off the
/// rank closure runs span-free (no clocks, no comm scopes — the hot path
/// is unchanged); with it on, each rank returns one [`trace::NodeSpan`]
/// per executed node plus the final-gather comm deltas, and the driver
/// merges them into a [`QueryProfile`] over the executed graph's render.
fn collect_graph_inner(
    g: &PlanGraph,
    opts: &ExecOptions,
    cache: Option<&PlanCache>,
    profile: bool,
) -> Result<(Table, GraphRunStats, Option<QueryProfile>)> {
    let prog = Program::prepare(g, cache)?;
    let schema = prog.schemas[&prog.graph.completion].clone();
    let clock = profile.then(trace::QueryClock::start);
    type RankOut = Result<(Vec<u8>, GraphRunStats, Vec<trace::NodeSpan>, CommScope)>;
    let (results, world_stats): (Vec<RankOut>, _) =
        run_spmd_with_stats(opts.workers, |comm| -> RankOut {
            let (frame, stats, spans) = exec_graph(&prog, &comm, opts, cache, clock.as_ref())?;
            // every rank serializes its chunk (masks included); leader
            // assembles
            let mut buf = Vec::new();
            for (c, m) in frame.cols.iter().zip(&frame.masks) {
                encode_nullable_column(c, m.as_ref(), &mut buf);
            }
            // the result gather happens after the last node, so its bytes
            // are profiled as their own pseudo-span, not charged to a node
            if clock.is_some() {
                comm.scope_begin();
            }
            let gathered = comm.gather_bytes(0, buf);
            let gscope = if clock.is_some() {
                comm.scope_end()
            } else {
                CommScope::default()
            };
            if comm.is_root() {
                let (cols, masks) = concat_rank_chunks(&frame.schema, gathered)?;
                let mut out = Vec::new();
                for (c, m) in cols.iter().zip(&masks) {
                    encode_nullable_column(c, normalize_mask(m.clone()).as_ref(), &mut out);
                }
                Ok((out, stats, spans, gscope))
            } else {
                Ok((Vec::new(), stats, spans, gscope))
            }
        });
    let mut total = GraphRunStats {
        cache_hits: prog.cache_hits,
        ..GraphRunStats::default()
    };
    let mut prof = clock.map(|_| {
        let budgeted = matches!(opts.mem_budget, Some(b) if b > 0);
        QueryProfile::new(
            opts.workers,
            prog.graph.render_lines(budgeted),
            prog.cache_hits,
        )
    });
    let mut root_buf: Option<Vec<u8>> = None;
    for (rank, r) in results.into_iter().enumerate() {
        let (buf, stats, spans, gscope) = r?;
        total.nodes_executed += stats.nodes_executed;
        total.reuse_hits += stats.reuse_hits;
        if let Some(p) = prof.as_mut() {
            // ranks are merged in rank order, keeping each node's spans
            // rank-sorted
            for s in spans {
                p.add_span(s);
            }
            p.add_gather(gscope);
        }
        if rank == 0 {
            root_buf = Some(buf);
        }
    }
    if let Some(p) = prof.as_mut() {
        p.comm_totals = world_stats.snapshot();
    }
    let root_buf = root_buf.context("no ranks ran")?;
    let mut pos = 0;
    let mut cols = Vec::new();
    let mut masks = Vec::new();
    for _ in 0..schema.len() {
        let (c, m) = decode_nullable_column(&root_buf, &mut pos)?;
        cols.push(c);
        masks.push(m);
    }
    crate::metrics::plan_stats().record_run(
        total.nodes_executed,
        total.reuse_hits,
        total.cache_hits,
    );
    Ok((Table::new_masked(schema, cols, masks)?, total, prof))
}

/// Optimize and execute, returning only the global row count (no driver
/// gather) — the fair timing primitive for operation benchmarks, analogous
/// to Spark's `.count()` action.
pub fn collect_count(plan: Plan, opts: &ExecOptions) -> Result<usize> {
    let g = optimize_graph(plan, &opts.passes)?;
    let prog = Program::prepare(&g, None)?;
    let counts: Vec<Result<usize>> = run_spmd(opts.workers, |comm| -> Result<usize> {
        let (frame, _, _) = exec_graph(&prog, &comm, opts, None, None)?;
        Ok(frame.num_rows())
    });
    counts.into_iter().try_fold(0usize, |acc, r| r.map(|n| acc + n))
}

/// Serial reference execution of a plan (single rank) — the oracle the
/// engine-agreement tests compare against. Runs the exact user tree: no
/// passes, no subplan dedup, always in memory.
pub fn collect_serial(plan: Plan) -> Result<Table> {
    let opts = ExecOptions {
        workers: 1,
        passes: PassOptions::none(),
        agg_strategy: AggStrategy::RawShuffle,
        // the oracle always runs in memory and unprofiled, whatever the
        // env says
        mem_budget: None,
        profile: false,
    };
    collect(plan, &opts)
}

/// A graph plus everything the driver pre-computes once so the per-rank
/// interpreter never re-derives schemas, demand counts or cache keys.
pub(crate) struct Program {
    pub(crate) graph: PlanGraph,
    pub(crate) schemas: FxHashMap<NodeId, Schema>,
    /// Demand count per node (consumer edges + 1 for the completion).
    /// Edges from a `Project` straight into a `Source` are *not* counted:
    /// the projection reads the needed column subset from the source
    /// directly (the pruning fast path), so the full source frame is never
    /// materialized for it.
    pub(crate) uses: FxHashMap<NodeId, usize>,
    /// Structural cache key for every surviving `Cache` node.
    cache_keys: FxHashMap<NodeId, String>,
    /// Source pins for every surviving `Cache` node (see [`CacheEntry`]).
    cache_pins: FxHashMap<NodeId, Vec<SourceRef>>,
    /// `Cache` nodes substituted by a cached table before execution.
    cache_hits: u64,
}

impl Program {
    /// Substitute cache hits (a hit `Cache` node becomes an in-memory
    /// source over the pinned table), key the surviving `Cache` nodes, and
    /// pre-compute schemas and demand counts.
    ///
    /// Keys are computed on the **pre-substitution** optimized graph: that
    /// is the form every future run optimizes to, so lookup and insert
    /// agree even when caches nest.
    pub(crate) fn prepare(g: &PlanGraph, cache: Option<&PlanCache>) -> Result<Program> {
        let mut store = Store::like(&g.store);
        let mut map: FxHashMap<NodeId, NodeId> = FxHashMap::default();
        let mut cache_keys: FxHashMap<NodeId, String> = FxHashMap::default();
        let mut cache_pins: FxHashMap<NodeId, Vec<SourceRef>> = FxHashMap::default();
        let mut cache_hits = 0u64;
        for &id in &g.execution_order {
            let node = g.store[id].clone().remap(&map);
            let new = if matches!(node, Node::Cache { .. }) {
                let key = g.store.structural_key(id);
                match cache.and_then(|c| c.lookup(&key)) {
                    Some(table) => {
                        cache_hits += 1;
                        let schema = table.schema().clone();
                        store.intern(Node::Source {
                            name: "cached".to_string(),
                            src: SourceRef::InMemory(table),
                            schema,
                        })
                    }
                    None => {
                        let nid = store.intern(node);
                        if cache.is_some() {
                            cache_keys.insert(nid, key);
                            cache_pins.insert(nid, source_refs_under(&g.store, id));
                        }
                        nid
                    }
                }
            } else {
                store.intern(node)
            };
            map.insert(id, new);
        }
        let graph = PlanGraph::new(store, map[&g.completion]);
        let schemas = graph.schemas()?;
        let uses = use_counts(&graph);
        Ok(Program {
            graph,
            schemas,
            uses,
            cache_keys,
            cache_pins,
            cache_hits,
        })
    }
}

/// Every source reference reachable under `root` (cache entry pins).
fn source_refs_under(store: &Store, root: NodeId) -> Vec<SourceRef> {
    let mut out = Vec::new();
    let mut seen: FxHashSet<NodeId> = FxHashSet::default();
    let mut stack = vec![root];
    while let Some(id) = stack.pop() {
        if !seen.insert(id) {
            continue;
        }
        if let Node::Source { src, .. } = &store[id] {
            out.push(src.clone());
        }
        stack.extend(store[id].children());
    }
    out
}

/// Demand count per node, with multiplicity (a self-join demands its
/// shared input twice) and +1 for the completion's own fetch. The edge
/// from a `Project` into a `Source` is skipped — the projection's fast
/// path reads the column subset from the source directly.
fn use_counts(g: &PlanGraph) -> FxHashMap<NodeId, usize> {
    let mut uses: FxHashMap<NodeId, usize> = FxHashMap::default();
    for &id in &g.execution_order {
        let node = &g.store[id];
        if let Node::Project { input, .. } = node {
            if matches!(g.store[*input], Node::Source { .. }) {
                continue;
            }
        }
        for c in node.children() {
            *uses.entry(c).or_default() += 1;
        }
    }
    *uses.entry(g.completion).or_default() += 1;
    uses
}

/// One rank's interpreter state: the node memo and the remaining-use
/// bookkeeping that drives take-on-last-use.
struct RankState {
    memo: FxHashMap<NodeId, LocalFrame>,
    remaining: FxHashMap<NodeId, usize>,
    fetched: FxHashSet<NodeId>,
    stats: GraphRunStats,
    /// Profiling sink the current node's `SpillCtx` reports into (`None`
    /// on the unprofiled path; replaced per node when profiling).
    spill_scope: Option<Rc<trace::SpillScope>>,
}

impl RankState {
    /// Hand `id`'s materialized frame to one consumer. The last consumer
    /// takes ownership (no clone); earlier ones clone. Every fetch after
    /// the first is a reuse hash-consing bought us.
    fn fetch(&mut self, id: NodeId) -> LocalFrame {
        let r = self
            .remaining
            .get_mut(&id)
            .expect("fetch of an undemanded node");
        *r -= 1;
        if !self.fetched.insert(id) {
            self.stats.reuse_hits += 1;
        }
        if *r == 0 {
            self.memo.remove(&id).expect("node executed before use")
        } else {
            self.memo
                .get(&id)
                .expect("node executed before use")
                .clone()
        }
    }
}

/// Interpret the whole program on this rank: walk the topological order,
/// materializing each demanded node exactly once. With `clock` set (the
/// profiled path) every execution is bracketed by a comm scope + spill
/// scope + wall timer and recorded as a [`trace::NodeSpan`]; with it
/// `None` the loop body is exactly the pre-profiler code.
fn exec_graph(
    prog: &Program,
    comm: &Comm,
    opts: &ExecOptions,
    cache: Option<&PlanCache>,
    clock: Option<&trace::QueryClock>,
) -> Result<(LocalFrame, GraphRunStats, Vec<trace::NodeSpan>)> {
    let mut st = RankState {
        memo: FxHashMap::default(),
        remaining: prog.uses.clone(),
        fetched: FxHashSet::default(),
        stats: GraphRunStats::default(),
        spill_scope: None,
    };
    let mut spans: Vec<trace::NodeSpan> = Vec::new();
    for (pos, &id) in prog.graph.execution_order.iter().enumerate() {
        if prog.uses.get(&id).copied().unwrap_or(0) == 0 {
            // only demanded through Project fast paths — never materialized
            continue;
        }
        let frame = if let Some(clk) = clock {
            // rows_in: rows consumed from already-materialized inputs
            // (before exec_one's fetches can take them out of the memo);
            // a self-join's doubly-consumed input counts twice
            let rows_in: u64 = prog.graph.store[id]
                .children()
                .iter()
                .filter_map(|c| st.memo.get(c))
                .map(|f| f.num_rows() as u64)
                .sum();
            let reuse_before = st.stats.reuse_hits;
            st.spill_scope = Some(Rc::new(trace::SpillScope::default()));
            comm.scope_begin();
            let start_ns = clk.now_ns();
            let t = Instant::now();
            let frame = exec_one(prog, id, &mut st, comm, opts, cache)?;
            let wall_ns = t.elapsed().as_nanos() as u64;
            let cs = comm.scope_end();
            let sc = st.spill_scope.take().expect("spill scope set above");
            spans.push(trace::NodeSpan {
                pos,
                rank: comm.rank(),
                start_ns,
                wall_ns,
                rows_in,
                rows_out: frame.num_rows() as u64,
                messages: cs.messages,
                bytes_shuffled: cs.bytes,
                collectives: cs.collectives,
                collective_ns: cs.collective_ns,
                bytes_spilled: sc.bytes_spilled.get(),
                partitions_spilled: sc.partitions_spilled.get(),
                spill_passes: sc.spill_passes.get(),
                merge_passes: sc.merge_passes.get(),
                reuse_hits: st.stats.reuse_hits - reuse_before,
            });
            frame
        } else {
            exec_one(prog, id, &mut st, comm, opts, cache)?
        };
        st.stats.nodes_executed += 1;
        st.memo.insert(id, frame);
    }
    let out = st.fetch(prog.graph.completion);
    Ok((out, st.stats, spans))
}

/// Interpret one graph node with its child frames supplied directly (the
/// stream interpreter's replay path: it keeps its own memo across ticks and
/// hands a node exactly the inputs it demands for this tick). Builds a
/// throwaway [`RankState`] whose memo holds only `frames`, with remaining-use
/// counts equal to each child's edge multiplicity so `fetch` bookkeeping
/// balances.
pub(crate) fn exec_one_with_inputs(
    prog: &Program,
    id: NodeId,
    frames: FxHashMap<NodeId, LocalFrame>,
    comm: &Comm,
    opts: &ExecOptions,
) -> Result<LocalFrame> {
    let mut remaining: FxHashMap<NodeId, usize> = FxHashMap::default();
    for c in prog.graph.store[id].children() {
        *remaining.entry(c).or_default() += 1;
    }
    let mut st = RankState {
        memo: frames,
        remaining,
        fetched: FxHashSet::default(),
        stats: GraphRunStats::default(),
        spill_scope: None,
    };
    exec_one(prog, id, &mut st, comm, opts, None)
}

/// Interpret one graph node on this rank, fetching child frames from the
/// memo.
fn exec_one(
    prog: &Program,
    id: NodeId,
    st: &mut RankState,
    comm: &Comm,
    opts: &ExecOptions,
    cache: Option<&PlanCache>,
) -> Result<LocalFrame> {
    let node = &prog.graph.store[id];
    match node {
        Node::Source { src, schema, .. } => {
            let names: Vec<&str> = schema.names();
            exec_source(src, schema, &names, comm)
        }
        // pruning inserts Project(Source): read only the needed columns —
        // this is where column pruning actually saves I/O
        Node::Project { input, columns } => {
            if let Node::Source { src, schema, .. } = &prog.graph.store[*input] {
                let names: Vec<&str> = columns.iter().map(|s| s.as_str()).collect();
                let sub = Schema::new_nullable(
                    columns
                        .iter()
                        .map(|c| (c.clone(), schema.dtype_of(c).unwrap()))
                        .collect(),
                    columns
                        .iter()
                        .map(|c| schema.nullable_of(c).unwrap_or(false))
                        .collect(),
                );
                return exec_source(src, &sub, &names, comm);
            }
            let frame = st.fetch(*input);
            let mut cols = Vec::new();
            let mut masks = Vec::new();
            let mut fields = Vec::new();
            let mut nullable = Vec::new();
            for c in columns {
                let i = frame
                    .schema
                    .index_of(c)
                    .with_context(|| format!("project: no column :{c}"))?;
                fields.push(frame.schema.fields()[i].clone());
                nullable.push(frame.schema.nullable_at(i));
                cols.push(frame.cols[i].clone());
                masks.push(frame.masks[i].clone());
            }
            Ok(LocalFrame {
                schema: Schema::new_nullable(fields, nullable),
                cols,
                masks,
            })
        }
        Node::Filter { input, predicate } => {
            let frame = st.fetch(*input);
            // expr_arr = map(pred, cols) — the paper's Fig. 4 expression
            // array; eval_mask ANDs the predicate's own validity (null
            // predicate lanes drop the row, SQL WHERE semantics)
            let keep = crate::expr::eval_mask(predicate, &frame)?;
            let cols = frame.cols.iter().map(|c| c.filter(&keep)).collect();
            let masks = frame
                .masks
                .iter()
                .map(|m| normalize_mask(m.as_ref().map(|m| m.filter(&keep))))
                .collect();
            Ok(LocalFrame {
                schema: frame.schema.clone(),
                cols,
                masks,
            })
        }
        Node::WithColumn { input, name, expr } => {
            let frame = st.fetch(*input);
            let (new_col, new_mask) = eval_nullable(expr, &frame)?;
            let mut fields = Vec::new();
            let mut nullable = Vec::new();
            let mut cols = Vec::new();
            let mut masks = Vec::new();
            for (i, ((n, t), c)) in frame.schema.fields().iter().zip(&frame.cols).enumerate()
            {
                if n != name {
                    fields.push((n.clone(), *t));
                    nullable.push(frame.schema.nullable_at(i));
                    cols.push(c.clone());
                    masks.push(frame.masks[i].clone());
                }
            }
            fields.push((name.clone(), new_col.dtype()));
            nullable.push(new_mask.is_some());
            cols.push(new_col);
            masks.push(new_mask);
            Ok(LocalFrame {
                schema: Schema::new_nullable(fields, nullable),
                cols,
                masks,
            })
        }
        Node::Rename { input, from, to } => {
            let frame = st.fetch(*input);
            let fields = frame
                .schema
                .fields()
                .iter()
                .map(|(n, t)| {
                    if n == from {
                        (to.clone(), *t)
                    } else {
                        (n.clone(), *t)
                    }
                })
                .collect();
            Ok(LocalFrame {
                schema: Schema::new_nullable(
                    fields,
                    frame.schema.nullable_flags().to_vec(),
                ),
                cols: frame.cols,
                masks: frame.masks,
            })
        }
        Node::Join {
            left,
            right,
            on,
            how,
            strategy,
        } => {
            let lframe = st.fetch(*left);
            let rframe = st.fetch(*right);
            // key/payload column *references* with masks — the packed-key
            // ops shuffle straight out of the frame, no clones at the exec
            // boundary
            let lkeys: Vec<MaskedCol> = on
                .iter()
                .map(|(lk, _)| lframe.masked(lk))
                .collect::<Result<_>>()?;
            let rkeys: Vec<MaskedCol> = on
                .iter()
                .map(|(_, rk)| rframe.masked(rk))
                .collect::<Result<_>>()?;
            // payload columns exclude the key columns (reinserted after)
            fn payload_refs<'f>(
                frame: &'f LocalFrame,
                on: &[(String, String)],
                is_left: bool,
            ) -> Vec<MaskedCol<'f>> {
                frame
                    .schema
                    .fields()
                    .iter()
                    .enumerate()
                    .filter(|(_, (n, _))| {
                        !on.iter()
                            .any(|(lk, rk)| if is_left { lk == n } else { rk == n })
                    })
                    .map(|(i, _)| (&frame.cols[i], frame.masks[i].as_ref()))
                    .collect()
            }
            let lpay = payload_refs(&lframe, on, true);
            let rpay = payload_refs(&rframe, on, false);
            // the plan schema knows statically whether any key slot can be
            // null — every rank shares it, so no layout allgather is needed
            let keys_nullable = on.iter().any(|(lk, rk)| {
                lframe.schema.nullable_of(lk).unwrap_or(false)
                    || rframe.schema.nullable_of(rk).unwrap_or(false)
            });
            let spill =
                ops::SpillCtx::new(ops::MemoryBudget::from_opt(opts.mem_budget), comm.rank())
                    .with_scope(st.spill_scope.clone());
            let (keys_out, lout, rout) = ops::distributed_join_on_budgeted(
                comm,
                &lkeys,
                &lpay,
                &rkeys,
                &rpay,
                *how,
                *strategy,
                ops::KeyNullability::Static(keys_nullable),
                &spill,
            )?;
            // assemble output per the join schema: left fields in order
            // (each key slot takes its joined key column), then — unless the
            // join type drops them — right fields minus the right keys
            let schema = prog.schemas[&id].clone();
            let mut cols = Vec::with_capacity(schema.len());
            let mut masks = Vec::with_capacity(schema.len());
            let mut push = |c: NullableColumn| {
                cols.push(c.values);
                masks.push(c.validity);
            };
            // key columns come back in `on`-pair order; left payloads in
            // left schema order minus the keys
            let mut keyed: Vec<Option<NullableColumn>> =
                keys_out.into_iter().map(Some).collect();
            let mut louts = lout.into_iter();
            for (n, _) in lframe.schema.fields() {
                if let Some(j) = on.iter().position(|(lk, _)| lk == n) {
                    push(keyed[j].take().expect("one key column per pair"));
                } else {
                    push(louts.next().expect("left payload column"));
                }
            }
            if how.keeps_right_columns() {
                let mut routs = rout.into_iter();
                for (n, _) in rframe.schema.fields() {
                    if on.iter().any(|(_, rk)| rk == n) {
                        continue;
                    }
                    push(routs.next().expect("right payload column"));
                }
            }
            Ok(LocalFrame {
                schema,
                cols,
                masks,
            })
        }
        Node::Aggregate { input, keys, aggs } => {
            let frame = st.fetch(*input);
            let key_cols: Vec<MaskedCol> = keys
                .iter()
                .map(|k| frame.masked(k))
                .collect::<Result<_>>()?;
            // evaluate the expression array of every aggregate locally
            // (pre-shuffle), exactly like the paper's desugaring; null
            // lanes are scrubbed to canonical defaults by eval_nullable
            let mut expr_cols: Vec<(Column, Option<ValidityMask>)> =
                Vec::with_capacity(aggs.len());
            let mut specs = Vec::with_capacity(aggs.len());
            for a in aggs {
                let (c, m) = eval_nullable(&a.input, &frame)?;
                specs.push(AggSpec {
                    func: a.func,
                    input_dtype: c.dtype(),
                });
                expr_cols.push((c, m));
            }
            let expr_refs: Vec<MaskedCol> = expr_cols
                .iter()
                .map(|(c, m)| (c, m.as_ref()))
                .collect();
            let keys_nullable = keys
                .iter()
                .any(|k| frame.schema.nullable_of(k).unwrap_or(false));
            let spill =
                ops::SpillCtx::new(ops::MemoryBudget::from_opt(opts.mem_budget), comm.rank())
                    .with_scope(st.spill_scope.clone());
            let (key_out, out_cols) = ops::distributed_aggregate_keys_budgeted(
                comm,
                &key_cols,
                &expr_refs,
                &specs,
                opts.agg_strategy,
                ops::KeyNullability::Static(keys_nullable),
                &spill,
            )?;
            let schema = prog.schemas[&id].clone();
            let mut cols = Vec::with_capacity(schema.len());
            let mut masks = Vec::with_capacity(schema.len());
            for c in key_out.into_iter().chain(out_cols) {
                cols.push(c.values);
                masks.push(c.validity);
            }
            Ok(LocalFrame {
                schema,
                cols,
                masks,
            })
        }
        Node::Concat { inputs } => {
            let mut frames = Vec::new();
            for p in inputs {
                frames.push(st.fetch(*p));
            }
            let first = frames.remove(0);
            let mut cols = first.cols;
            let mut masks = first.masks;
            for f in frames {
                for (i, (a, b)) in cols.iter_mut().zip(&f.cols).enumerate() {
                    let before = a.len();
                    a.extend(b);
                    extend_opt_mask(&mut masks[i], before, f.masks[i].as_ref(), b.len());
                }
            }
            Ok(LocalFrame {
                schema: first.schema,
                cols,
                masks,
            })
        }
        Node::Window {
            input,
            partition_by,
            order_by,
            aggs,
        } => {
            let frame = st.fetch(*input);
            let out_schema = prog.schemas[&id].clone();
            // evaluate the aggregate input expressions locally (pre-shuffle,
            // the paper's expression-array desugaring); record each one's
            // *static* nullability so every rank picks the same kernel path.
            // position functions (rank/row_number) never read their input —
            // their placeholder expression is not materialized at all
            let mut expr_cols: Vec<Option<(Column, Option<ValidityMask>)>> =
                Vec::with_capacity(aggs.len());
            let mut static_nulls: Vec<bool> = Vec::with_capacity(aggs.len());
            for a in aggs {
                expr_cols.push(if a.func.is_positional() {
                    None
                } else {
                    Some(eval_nullable(&a.input, &frame)?)
                });
                static_nulls.push(a.input.nullable(&frame.schema)?);
            }
            if partition_by.is_empty() {
                // global window: rows keep their 1D-block order; each
                // aggregate lowers to a halo exchange or an exscan scan
                let mut outs: Vec<NullableColumn> = Vec::with_capacity(aggs.len());
                for (a, (ec, stat)) in
                    aggs.iter().zip(expr_cols.iter().zip(&static_nulls))
                {
                    let out = match ec {
                        Some((c, m)) => ops::window_1d(
                            comm,
                            c,
                            m.as_ref(),
                            &a.frame,
                            &a.func,
                            *stat,
                        )?,
                        // mirrors window_1d's positional path without
                        // materializing the placeholder input column
                        None => match &a.func {
                            crate::ir::WindowFunc::RowNumber => {
                                let start = comm.exscan_i64(
                                    frame.num_rows() as i64,
                                    crate::comm::ReduceOp::Sum,
                                );
                                NullableColumn::from_column(ops::row_numbers(
                                    frame.num_rows(),
                                    start,
                                ))
                            }
                            other => anyhow::bail!(
                                "global {other} requires partition_by \
                                 (rejected at plan typing)"
                            ),
                        },
                    };
                    outs.push(out);
                }
                return assemble_window_output(frame, aggs, outs, out_schema);
            }
            // ---- partitioned window: PackedKeys shuffle colocates each
            // partition, a local stable sort orders it, per-group scans
            // compute the frames — no halo crosses a partition boundary ----
            let key_refs: Vec<MaskedCol> = partition_by
                .iter()
                .map(|k| frame.masked(k))
                .collect::<Result<_>>()?;
            let kc: Vec<&Column> = key_refs.iter().map(|(c, _)| *c).collect();
            let km: Vec<Option<&ValidityMask>> =
                key_refs.iter().map(|(_, m)| *m).collect();
            let keys_nullable = partition_by
                .iter()
                .any(|k| frame.schema.nullable_of(k).unwrap_or(false));
            let with_flags = ops::KeyNullability::Static(keys_nullable)
                .with_flags(comm, km.iter().any(|m| m.is_some()));
            let packed = ops::PackedKeys::pack_masked(&kc, &km, with_flags)?;
            // ship every frame column plus the evaluated expression columns;
            // position functions (rank/row_number) never read their input,
            // so their placeholder columns stay off the wire
            let mut all: Vec<&Column> = frame.cols.iter().collect();
            let mut masks: Vec<Option<&ValidityMask>> =
                frame.masks.iter().map(|m| m.as_ref()).collect();
            let mut ship_idx: Vec<Option<usize>> = Vec::with_capacity(aggs.len());
            for ec in &expr_cols {
                match ec {
                    Some((c, m)) => {
                        ship_idx.push(Some(all.len()));
                        all.push(c);
                        masks.push(m.as_ref());
                    }
                    None => ship_idx.push(None),
                }
            }
            let (shuffled, shuffled_masks) =
                ops::shuffle_by_packed_nullable(comm, &packed, &all, &masks)?;
            let ncols = frame.cols.len();
            // local stable sort by (partition keys asc nulls-first, order
            // keys in their directions); stability keeps arrival (global
            // row) order within ties, so every engine agrees
            let mut sort_cols: Vec<&Column> = Vec::new();
            let mut sort_masks: Vec<Option<&ValidityMask>> = Vec::new();
            let mut orders: Vec<SortOrder> = Vec::new();
            for k in partition_by {
                let i = frame.schema.index_of(k).expect("validated by typing");
                sort_cols.push(&shuffled[i]);
                sort_masks.push(shuffled_masks[i].as_ref());
                orders.push(SortOrder::Asc);
            }
            for (k, o) in order_by {
                let i = frame.schema.index_of(k).expect("validated by typing");
                sort_cols.push(&shuffled[i]);
                sort_masks.push(shuffled_masks[i].as_ref());
                orders.push(*o);
            }
            let krows = ops::keys::key_rows_nullable(&sort_cols, &sort_masks)?;
            let (idx, group_starts, breaks) =
                ops::partition_runs(&krows, partition_by.len(), &orders);
            let take = |c: &Column, m: &Option<ValidityMask>| {
                (
                    c.take(&idx),
                    normalize_mask(m.as_ref().map(|m| m.take(&idx))),
                )
            };
            let mut cols_sorted: Vec<Column> = Vec::with_capacity(ncols);
            let mut masks_sorted: Vec<Option<ValidityMask>> = Vec::with_capacity(ncols);
            for i in 0..ncols {
                let (c, m) = take(&shuffled[i], &shuffled_masks[i]);
                cols_sorted.push(c);
                masks_sorted.push(m);
            }
            let mut outs: Vec<NullableColumn> = Vec::with_capacity(aggs.len());
            for (a, si) in aggs.iter().zip(&ship_idx) {
                let out = match si {
                    Some(si) => {
                        let (ec, em) = take(&shuffled[*si], &shuffled_masks[*si]);
                        ops::window_over_groups(
                            &ec,
                            em.as_ref(),
                            &a.frame,
                            &a.func,
                            &group_starts,
                            Some(&breaks),
                        )?
                    }
                    // positional functions never read values: emit the
                    // per-run ranks / row numbers directly
                    None => {
                        let n_rows = idx.len();
                        let mut vals =
                            Column::new_empty(crate::types::DType::I64);
                        for (gi, &start) in group_starts.iter().enumerate() {
                            let end =
                                group_starts.get(gi + 1).copied().unwrap_or(n_rows);
                            let part = match &a.func {
                                crate::ir::WindowFunc::RowNumber => {
                                    ops::row_numbers(end - start, 0)
                                }
                                crate::ir::WindowFunc::Rank => {
                                    ops::rank_from_breaks(&breaks[start..end])
                                }
                                other => {
                                    unreachable!("non-positional {other} not shipped")
                                }
                            };
                            vals.extend(&part);
                        }
                        NullableColumn::from_column(vals)
                    }
                };
                outs.push(out);
            }
            let sorted_frame = LocalFrame {
                schema: frame.schema.clone(),
                cols: cols_sorted,
                masks: masks_sorted,
            };
            assemble_window_output(sorted_frame, aggs, outs, out_schema)
        }
        Node::Sort { input, keys } => {
            let frame = st.fetch(*input);
            let key_cols: Vec<MaskedCol> = keys
                .iter()
                .map(|(k, _)| frame.masked(k))
                .collect::<Result<_>>()?;
            let orders: Vec<SortOrder> = keys.iter().map(|(_, o)| *o).collect();
            let others: Vec<MaskedCol> = frame
                .schema
                .fields()
                .iter()
                .enumerate()
                .filter(|(_, (n, _))| !keys.iter().any(|(k, _)| k == n))
                .map(|(i, _)| (&frame.cols[i], frame.masks[i].as_ref()))
                .collect();
            let keys_nullable = keys
                .iter()
                .any(|(k, _)| frame.schema.nullable_of(k).unwrap_or(false));
            let spill =
                ops::SpillCtx::new(ops::MemoryBudget::from_opt(opts.mem_budget), comm.rank())
                    .with_scope(st.spill_scope.clone());
            let (skeys, scols) = ops::distributed_sort_keys_budgeted(
                comm,
                &key_cols,
                &orders,
                &others,
                ops::KeyNullability::Static(keys_nullable),
                &spill,
            )?;
            let mut cols = Vec::with_capacity(frame.cols.len());
            let mut masks = Vec::with_capacity(frame.cols.len());
            // distributed_sort_keys returns keys in `keys` order and
            // payload in frame order minus keys; reassemble frame order
            let mut sorted_keys: Vec<Option<NullableColumn>> =
                skeys.into_iter().map(Some).collect();
            let mut os = scols.into_iter();
            for (n, _) in frame.schema.fields() {
                if let Some(j) = keys.iter().position(|(k, _)| k == n) {
                    let c = sorted_keys[j].take().expect("sorted key column");
                    cols.push(c.values);
                    masks.push(c.validity);
                } else {
                    let c = os.next().expect("sorted payload column");
                    cols.push(c.values);
                    masks.push(c.validity);
                }
            }
            Ok(LocalFrame {
                schema: frame.schema,
                cols,
                masks,
            })
        }
        Node::Rebalance { input } => {
            let frame = st.fetch(*input);
            let refs: Vec<MaskedCol> = frame
                .cols
                .iter()
                .zip(&frame.masks)
                .map(|(c, m)| (c, m.as_ref()))
                .collect();
            let (cols, masks) = ops::rebalance_block_nullable(comm, &refs)?;
            Ok(LocalFrame {
                schema: frame.schema,
                cols,
                masks: masks.into_iter().map(normalize_mask).collect(),
            })
        }
        Node::MatrixAssembly { input, columns } => {
            // schema typing rejects nullable feature columns
            let frame = st.fetch(*input);
            let schema = prog.schemas[&id].clone();
            let cols: Vec<Column> = columns
                .iter()
                .map(|c| frame.col(c).map(|col| Column::F64(col.to_f64_vec())))
                .collect::<Result<_>>()?;
            Ok(LocalFrame::new(schema, cols))
        }
        Node::MlCall { input, params } => {
            let frame = st.fetch(*input);
            let features: Vec<Vec<f64>> =
                frame.cols.iter().map(|c| c.to_f64_vec()).collect();
            let result = crate::ml::run_mlcall(comm, &features, params)?;
            // result: k rows × (d features + cluster id), replicated
            let schema = prog.schemas[&id].clone();
            let mut cols: Vec<Column> = result
                .centroids
                .into_iter()
                .map(Column::F64)
                .collect();
            cols.push(Column::I64(result.cluster_ids));
            if comm.is_root() {
                Ok(LocalFrame::new(schema, cols))
            } else {
                // replicated output: only the leader reports it upward so the
                // gather in `collect` doesn't duplicate rows
                let empty = schema
                    .fields()
                    .iter()
                    .map(|(_, t)| Column::new_empty(*t))
                    .collect();
                Ok(LocalFrame::new(schema, empty))
            }
        }
        Node::Cache { input } => {
            // identity at exec level; with a PlanCache attached, publish
            // the full table (gathered on the leader) under the node's
            // structural key so later collects substitute it
            let frame = st.fetch(*input);
            if let (Some(cache), Some(key)) = (cache, prog.cache_keys.get(&id)) {
                let mut buf = Vec::new();
                for (c, m) in frame.cols.iter().zip(&frame.masks) {
                    encode_nullable_column(c, m.as_ref(), &mut buf);
                }
                let gathered = comm.gather_bytes(0, buf);
                if comm.is_root() {
                    let schema = prog.schemas[&id].clone();
                    let (cols, masks) = concat_rank_chunks(&schema, gathered)?;
                    let masks: Vec<Option<ValidityMask>> =
                        masks.into_iter().map(normalize_mask).collect();
                    let table = Table::new_masked(schema, cols, masks)?;
                    let pins = prog.cache_pins.get(&id).cloned().unwrap_or_default();
                    cache.insert(key.clone(), pins, table);
                }
            }
            Ok(frame)
        }
    }
}

/// Concatenate per-rank encoded chunks column-wise, in rank order.
pub(crate) fn concat_rank_chunks(
    schema: &Schema,
    gathered: Vec<Vec<u8>>,
) -> Result<(Vec<Column>, Vec<Option<ValidityMask>>)> {
    let mut cols: Vec<Column> = schema
        .fields()
        .iter()
        .map(|(_, t)| Column::new_empty(*t))
        .collect();
    let mut masks: Vec<Option<ValidityMask>> = vec![None; cols.len()];
    for rank_buf in gathered {
        let mut pos = 0;
        for (c, m) in cols.iter_mut().zip(masks.iter_mut()) {
            let before = c.len();
            let (chunk, cm) = decode_nullable_column(&rank_buf, &mut pos)?;
            c.extend(&chunk);
            extend_opt_mask(m, before, cm.as_ref(), chunk.len());
        }
    }
    Ok((cols, masks))
}

pub(crate) fn exec_source(
    src: &SourceRef,
    schema: &Schema,
    names: &[&str],
    comm: &Comm,
) -> Result<LocalFrame> {
    match src {
        SourceRef::InMemory(table) => {
            let (start, len) = block_range(table.num_rows(), comm.nranks(), comm.rank());
            let mut cols = Vec::with_capacity(names.len());
            let mut masks = Vec::with_capacity(names.len());
            for n in names {
                let c = table
                    .column(n)
                    .with_context(|| format!("source: no column :{n}"))?;
                cols.push(c.slice(start, len));
                masks.push(normalize_mask(
                    table.mask(n).map(|m| m.slice(start, len)),
                ));
            }
            Ok(LocalFrame {
                schema: schema.clone(),
                cols,
                masks,
            })
        }
        SourceRef::Hfs(path) => {
            let (_, nrows) = crate::io::read_hfs_schema(path)?;
            let (start, len) = block_range(nrows, comm.nranks(), comm.rank());
            let cols = crate::io::read_hfs_slice(path, names, start, len)?;
            Ok(LocalFrame::new(schema.clone(), cols))
        }
    }
}

/// Assemble a window node's local output: the input frame's columns (minus
/// any replaced by an aggregate's `out` name) followed by the aggregate
/// outputs, in the order the plan schema fixed.
pub(crate) fn assemble_window_output(
    frame: LocalFrame,
    aggs: &[WindowAgg],
    outs: Vec<NullableColumn>,
    schema: Schema,
) -> Result<LocalFrame> {
    let mut cols = Vec::with_capacity(schema.len());
    let mut masks = Vec::with_capacity(schema.len());
    for (i, (n, _)) in frame.schema.fields().iter().enumerate() {
        if aggs.iter().any(|a| &a.out == n) {
            continue;
        }
        cols.push(frame.cols[i].clone());
        masks.push(frame.masks[i].clone());
    }
    for o in outs {
        cols.push(o.values);
        masks.push(o.validity);
    }
    Ok(LocalFrame {
        schema,
        cols,
        masks,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::expr::{col, lit, AggExpr, AggFn};
    use crate::ir::source_mem;

    fn table() -> Table {
        Table::from_pairs(vec![
            ("id", Column::I64(vec![0, 1, 2, 3, 4, 5, 6, 7])),
            (
                "x",
                Column::F64(vec![0.0, 0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7]),
            ),
        ])
        .unwrap()
    }

    fn opts(workers: usize) -> ExecOptions {
        ExecOptions {
            workers,
            ..Default::default()
        }
    }

    #[test]
    fn source_roundtrip_any_workers() {
        for w in [1, 2, 3, 5] {
            let t = collect(source_mem("t", table()), &opts(w)).unwrap();
            assert_eq!(t, table(), "workers={w}");
        }
    }

    #[test]
    fn masked_source_roundtrip() {
        let t = Table::from_pairs(vec![
            ("id", Column::I64((0..10).collect())),
            ("v", Column::I64((0..10).map(|i| i * 10).collect())),
        ])
        .unwrap()
        .with_null_mask(
            "v",
            ValidityMask::from_bools(&(0..10).map(|i| i % 3 != 0).collect::<Vec<_>>()),
        )
        .unwrap();
        for w in [1, 2, 4] {
            let got = collect(source_mem("t", t.clone()), &opts(w)).unwrap();
            assert_eq!(got, t, "workers={w}");
            assert_eq!(got.null_count("v"), 4);
        }
    }

    #[test]
    fn filter_matches_serial() {
        let plan = Plan::Filter {
            input: Box::new(source_mem("t", table())),
            predicate: col("x").lt(lit(0.35)),
        };
        let got = collect(plan, &opts(3)).unwrap();
        assert_eq!(got.column("id").unwrap().as_i64(), &[0, 1, 2, 3]);
    }

    #[test]
    fn withcolumn_and_project() {
        let plan = Plan::Project {
            input: Box::new(Plan::WithColumn {
                input: Box::new(source_mem("t", table())),
                name: "y".into(),
                expr: col("x").mul(lit(10.0)),
            }),
            columns: vec!["y".into()],
        };
        let got = collect(plan, &opts(2)).unwrap();
        let y = got.column("y").unwrap().as_f64();
        for (i, v) in y.iter().enumerate() {
            assert!((v - i as f64).abs() < 1e-9);
        }
    }

    #[test]
    fn join_two_tables() {
        let right = Table::from_pairs(vec![
            ("rid", Column::I64(vec![1, 3, 5, 9])),
            ("tag", Column::I64(vec![10, 30, 50, 90])),
        ])
        .unwrap();
        let plan = Plan::Sort {
            input: Box::new(Plan::Join {
                left: Box::new(source_mem("t", table())),
                right: Box::new(source_mem("r", right)),
                on: vec![("id".into(), "rid".into())],
                how: crate::ir::JoinType::Inner,
                strategy: crate::ir::JoinStrategy::Hash,
            }),
            keys: vec![("id".into(), SortOrder::Asc)],
        };
        let got = collect(plan, &opts(3)).unwrap();
        assert_eq!(got.column("id").unwrap().as_i64(), &[1, 3, 5]);
        assert_eq!(got.column("tag").unwrap().as_i64(), &[10, 30, 50]);
    }

    #[test]
    fn left_join_preserves_dtype_with_mask() {
        // the acceptance shape: join output keeps Int64 + validity mask and
        // null positions survive the distributed sort + driver gather
        let right = Table::from_pairs(vec![
            ("rid", Column::I64(vec![0, 2, 4, 6])),
            ("tag", Column::I64(vec![100, 102, 104, 106])),
        ])
        .unwrap();
        for w in [1, 2, 3] {
            let plan = Plan::Sort {
                input: Box::new(Plan::Join {
                    left: Box::new(source_mem("t", table())),
                    right: Box::new(source_mem("r", right.clone())),
                    on: vec![("id".into(), "rid".into())],
                    how: crate::ir::JoinType::Left,
                    strategy: crate::ir::JoinStrategy::Hash,
                }),
                keys: vec![("id".into(), SortOrder::Asc)],
            };
            let got = collect(plan, &opts(w)).unwrap();
            assert_eq!(
                got.schema().dtype_of("tag"),
                Some(crate::types::DType::I64),
                "workers={w}: dtype must be preserved"
            );
            assert_eq!(got.schema().nullable_of("tag"), Some(true));
            let tags = got.column("tag").unwrap().as_i64();
            let mask = got.mask("tag").unwrap();
            for i in 0..8 {
                if i % 2 == 0 {
                    assert!(mask.get(i), "workers={w} row {i}");
                    assert_eq!(tags[i], 100 + i as i64);
                } else {
                    assert!(!mask.get(i), "workers={w} row {i}");
                    assert_eq!(tags[i], 0, "null lanes hold the default");
                }
            }
        }
    }

    #[test]
    fn is_null_fill_null_filter_pipeline() {
        let right = Table::from_pairs(vec![
            ("rid", Column::I64(vec![0, 2, 4, 6])),
            ("tag", Column::I64(vec![100, 102, 104, 106])),
        ])
        .unwrap();
        let join = Plan::Join {
            left: Box::new(source_mem("t", table())),
            right: Box::new(source_mem("r", right)),
            on: vec![("id".into(), "rid".into())],
            how: crate::ir::JoinType::Left,
            strategy: crate::ir::JoinStrategy::Hash,
        };
        // drop_null semantics: filter on IS NOT NULL
        let plan = Plan::Sort {
            input: Box::new(Plan::Filter {
                input: Box::new(join.clone()),
                predicate: col("tag").is_not_null(),
            }),
            keys: vec![("id".into(), SortOrder::Asc)],
        };
        let got = collect(plan, &opts(3)).unwrap();
        assert_eq!(got.column("id").unwrap().as_i64(), &[0, 2, 4, 6]);
        assert_eq!(got.null_count("tag"), 0);
        // fill_null makes the column fully valid with the fill value
        let plan = Plan::Sort {
            input: Box::new(Plan::WithColumn {
                input: Box::new(join),
                name: "tag".into(),
                expr: col("tag").fill_null(-1i64),
            }),
            keys: vec![("id".into(), SortOrder::Asc)],
        };
        let got = collect(plan, &opts(2)).unwrap();
        assert_eq!(got.schema().nullable_of("tag"), Some(false));
        assert_eq!(
            got.column("tag").unwrap().as_i64(),
            &[100, -1, 102, -1, 104, -1, 106, -1]
        );
    }

    #[test]
    fn aggregate_both_strategies() {
        for strat in [AggStrategy::RawShuffle, AggStrategy::PreAggregate] {
            // make ids collide: id % 2
            let plan = Plan::Sort {
                input: Box::new(Plan::Aggregate {
                    input: Box::new(Plan::WithColumn {
                        input: Box::new(source_mem("t", table())),
                        name: "id".into(),
                        expr: col("id").rem(lit(2i64)),
                    }),
                    keys: vec!["id".into()],
                    aggs: vec![AggExpr::new("s", AggFn::Sum, col("x"))],
                }),
                keys: vec![("id".into(), SortOrder::Asc)],
            };
            let mut o = opts(4);
            o.agg_strategy = strat;
            let got = collect(plan, &o).unwrap();
            assert_eq!(got.column("id").unwrap().as_i64(), &[0, 1]);
            let s = got.column("s").unwrap().as_f64();
            assert!((s[0] - 1.2).abs() < 1e-9, "{strat:?}: {s:?}");
            assert!((s[1] - 1.6).abs() < 1e-9, "{strat:?}: {s:?}");
        }
    }

    fn global_window(input: Plan, aggs: Vec<WindowAgg>) -> Plan {
        Plan::Window {
            input: Box::new(input),
            partition_by: vec![],
            order_by: vec![],
            aggs,
        }
    }

    #[test]
    fn cumsum_ordered() {
        use crate::ir::{WindowFrame, WindowFunc};
        let plan = global_window(
            source_mem("t", table()),
            vec![WindowAgg::new(
                "cs",
                WindowFunc::Sum,
                WindowFrame::CumulativeToCurrent,
                col("id"),
            )],
        );
        let got = collect(plan, &opts(3)).unwrap();
        assert_eq!(
            got.column("cs").unwrap().as_i64(),
            &[0, 1, 3, 6, 10, 15, 21, 28]
        );
    }

    #[test]
    fn stencil_after_filter_gets_rebalanced() {
        use crate::ir::{WindowFrame, WindowFunc};
        // filter (1D_VAR) then a halo window (needs 1D_BLOCK): the optimizer
        // must insert a rebalance and the result must match the serial oracle
        let plan = global_window(
            Plan::Filter {
                input: Box::new(source_mem("t", table())),
                predicate: col("id").ne_(lit(3i64)),
            },
            vec![WindowAgg::new(
                "sma",
                WindowFunc::Weighted(vec![1.0 / 3.0; 3]),
                WindowFrame::Rolling {
                    preceding: 1,
                    following: 1,
                },
                col("x"),
            )],
        );
        let expect = collect_serial(plan.clone()).unwrap();
        let got = collect(plan, &opts(4)).unwrap();
        let (e, g) = (
            expect.column("sma").unwrap().as_f64(),
            got.column("sma").unwrap().as_f64(),
        );
        assert_eq!(e.len(), g.len());
        for (a, b) in e.iter().zip(g) {
            assert!((a - b).abs() < 1e-9);
        }
    }

    #[test]
    fn partitioned_window_shift_and_rank() {
        use crate::ir::{WindowFrame, WindowFunc};
        // partition by id % 2, order by id desc: shifts stay inside their
        // partition and ranks follow the order keys
        let plan = Plan::Window {
            input: Box::new(Plan::WithColumn {
                input: Box::new(source_mem("t", table())),
                name: "p".into(),
                expr: col("id").rem(lit(2i64)),
            }),
            partition_by: vec!["p".into()],
            order_by: vec![("id".into(), SortOrder::Desc)],
            aggs: vec![
                WindowAgg::new("prev", WindowFunc::Value, WindowFrame::Shift(1), col("id")),
                WindowAgg::new(
                    "r",
                    WindowFunc::Rank,
                    WindowFrame::CumulativeToCurrent,
                    lit(0i64),
                ),
            ],
        };
        for w in [1usize, 3] {
            let got = collect(plan.clone(), &opts(w)).unwrap();
            let got = got
                .sorted_by_keys(&[
                    ("p", SortOrder::Asc),
                    ("id", SortOrder::Desc),
                ])
                .unwrap();
            // partition 0: ids 6,4,2,0 — prev = null,6,4,2; rank 1..4
            // partition 1: ids 7,5,3,1 — prev = null,7,5,3
            assert_eq!(got.column("id").unwrap().as_i64(), &[6, 4, 2, 0, 7, 5, 3, 1]);
            assert_eq!(got.column("prev").unwrap().as_i64(), &[0, 6, 4, 2, 0, 7, 5, 3]);
            let m = got.mask("prev").unwrap();
            assert!(!m.get(0) && !m.get(4), "workers={w}: partition heads null");
            assert!(m.get(1) && m.get(5));
            assert_eq!(got.column("r").unwrap().as_i64(), &[1, 2, 3, 4, 1, 2, 3, 4]);
        }
    }

    #[test]
    fn concat_multiset() {
        let plan = Plan::Sort {
            input: Box::new(Plan::Concat {
                inputs: vec![
                    Box::new(source_mem("a", table())),
                    Box::new(source_mem("b", table())),
                ],
            }),
            keys: vec![("id".into(), SortOrder::Asc)],
        };
        let got = collect(plan, &opts(2)).unwrap();
        assert_eq!(got.num_rows(), 16);
        let ids = got.column("id").unwrap().as_i64();
        assert_eq!(&ids[..4], &[0, 0, 1, 1]);
    }

    #[test]
    fn hfs_source_parallel_read() {
        let dir = std::env::temp_dir().join("hiframes_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("exec_src.hfs");
        crate::io::write_hfs(&p, &table()).unwrap();
        let plan = crate::ir::source_hfs("t", p, table().schema().clone());
        let got = collect(plan, &opts(3)).unwrap();
        assert_eq!(got, table());
    }

    #[test]
    fn pruned_source_reads_subset() {
        // Project(Source) fast path
        let plan = Plan::Project {
            input: Box::new(source_mem("t", table())),
            columns: vec!["x".into()],
        };
        let got = collect(plan, &opts(2)).unwrap();
        assert_eq!(got.num_cols(), 1);
        assert_eq!(got.num_rows(), 8);
    }

    fn diamond() -> Plan {
        // filter shared by both join arms — hash-consing merges them
        let f = Plan::Filter {
            input: Box::new(source_mem("t", table())),
            predicate: col("x").lt(lit(0.35)),
        };
        let renamed = Plan::Rename {
            input: Box::new(Plan::Rename {
                input: Box::new(f.clone()),
                from: "id".into(),
                to: "rid".into(),
            }),
            from: "x".into(),
            to: "y".into(),
        };
        Plan::Sort {
            input: Box::new(Plan::Join {
                left: Box::new(f),
                right: Box::new(renamed),
                on: vec![("id".into(), "rid".into())],
                how: crate::ir::JoinType::Inner,
                strategy: crate::ir::JoinStrategy::Hash,
            }),
            keys: vec![("id".into(), SortOrder::Asc)],
        }
    }

    #[test]
    fn diamond_reuses_shared_subplan() {
        let plan = diamond();
        let serial = collect_serial(plan.clone()).unwrap();
        for w in [2usize, 3] {
            let (got, stats) = collect_stats(plan.clone(), &opts(w)).unwrap();
            assert_eq!(got, serial, "workers={w}");
            // the shared filter is fetched twice per rank: one reuse each
            assert_eq!(stats.reuse_hits, w as u64, "workers={w}");
        }
        // without dedup the same plan executes the filter twice, no reuse
        let mut o = opts(2);
        o.passes.dedup_subplans = false;
        let (got, stats) = collect_stats(plan, &o).unwrap();
        assert_eq!(got, serial);
        assert_eq!(stats.reuse_hits, 0);
    }

    #[test]
    fn profiled_collect_matches_and_attributes() {
        let plan = diamond();
        let o = opts(2);
        let base = collect(plan.clone(), &o).unwrap();
        let g = optimize_graph(plan, &o.passes).unwrap();
        let (t, stats, prof) = collect_graph_profiled(&g, &o, None).unwrap();
        assert_eq!(t, base, "profiling must not change results");
        assert_eq!(prof.workers, 2);
        // each executed node ran once per rank, spans in rank order
        assert_eq!(prof.executed_nodes() as u64 * 2, stats.nodes_executed);
        for n in prof.nodes.iter().filter(|n| n.executed()) {
            assert_eq!(n.spans.len(), 2, "{}", n.label);
            assert_eq!(n.spans[0].rank, 0);
            assert_eq!(n.spans[1].rank, 1);
        }
        assert_eq!(prof.total_reuse_hits(), stats.reuse_hits);
        // every byte on the wire is attributed to a node or to the final
        // result gather — nothing leaks out of the scopes
        assert_eq!(
            prof.total_bytes_shuffled() + prof.gather_bytes,
            prof.comm_totals.1
        );
        // render carries the stats surface explain_analyze promises
        let text = prof.render();
        for needle in ["wall ", "rows ", "shuffle ", "spill ", "imb ", "-- 2 ranks"] {
            assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
        }
    }

    #[test]
    fn plan_cache_publishes_and_substitutes() {
        let cache = PlanCache::new();
        let plan = Plan::Sort {
            input: Box::new(Plan::Cache {
                input: Box::new(Plan::Filter {
                    input: Box::new(source_mem("t", table())),
                    predicate: col("x").lt(lit(0.35)),
                }),
            }),
            keys: vec![("id".into(), SortOrder::Asc)],
        };
        let o = opts(2);
        let (a, s1) = collect_cached(plan.clone(), &o, &cache).unwrap();
        assert_eq!(s1.cache_hits, 0);
        assert_eq!(cache.len(), 1, "first run publishes the entry");
        let (b, s2) = collect_cached(plan.clone(), &o, &cache).unwrap();
        assert_eq!(s2.cache_hits, 1, "second run substitutes it");
        assert!(s2.nodes_executed < s1.nodes_executed);
        assert_eq!(a, b);
        cache.clear();
        assert!(cache.is_empty());
        // without a cache the node is a plain identity barrier
        let plain = collect(plan, &o).unwrap();
        assert_eq!(plain, a);
    }
}
