//! ML drivers — the "call a library" tail of the paper's analytics
//! pipelines (HPAT generates calls into DAAL/ScaLAPACK; we call our
//! AOT-compiled JAX/Pallas artifacts through PJRT, or a pure-rust kernel).
//!
//! Two execution modes:
//! * **distributed rust kernel** (default): each rank computes assignment
//!   partials over its block; `allreduce` merges them — the HPAT-style
//!   distributed ML path that scales with ranks.
//! * **PJRT leader mode** (`use_pjrt`): features are gathered on the
//!   leader, which drives the `kmeans_step` artifact (L2 JAX calling the
//!   L1 Pallas distance kernel) and broadcasts the result. This is the
//!   path that proves the three-layer AOT stack end-to-end.

use crate::comm::{Comm, ReduceOp};
use crate::ir::MlParams;
use anyhow::{bail, Context, Result};

/// Result of an [`crate::ir::Plan::MlCall`]: per-feature centroid columns
/// (k rows each) plus cluster ids 0..k.
#[derive(Debug, Clone)]
pub struct MlResult {
    pub centroids: Vec<Vec<f64>>,
    pub cluster_ids: Vec<i64>,
    pub inertia: f64,
    pub iters_run: usize,
}

/// Entry point used by the executor.
pub fn run_mlcall(comm: &Comm, features: &[Vec<f64>], params: &MlParams) -> Result<MlResult> {
    match params.model.as_str() {
        "kmeans" => {
            if params.use_pjrt {
                kmeans_pjrt_leader(comm, features, params.k, params.iters)
            } else {
                kmeans_distributed(comm, features, params.k, params.iters)
            }
        }
        other => bail!("MlCall: unknown model {other}"),
    }
}

// --------------------------------------------------------------------------
// k-means
// --------------------------------------------------------------------------

/// Deterministic initialization: the first k global rows (gathered in rank
/// order) — reproducible across worker counts.
fn kmeans_init(comm: &Comm, features: &[Vec<f64>], k: usize) -> Result<Vec<Vec<f64>>> {
    let d = features.len();
    let n_local = features.first().map_or(0, |c| c.len());
    // collective precondition check: every rank learns the global row count
    // and bails *together*, keeping the collectives below aligned
    let total = comm.allreduce_i64(n_local as i64, ReduceOp::Sum);
    if (total as usize) < k {
        bail!("kmeans: {total} rows total but k={k}");
    }
    let take = n_local.min(k);
    let mut payload = Vec::with_capacity(take * d * 8);
    for i in 0..take {
        for c in features {
            payload.extend_from_slice(&c[i].to_le_bytes());
        }
    }
    let gathered = comm.gather_bytes(0, payload);
    let mut init = Vec::new();
    if comm.is_root() {
        let mut rows: Vec<Vec<f64>> = Vec::new();
        for buf in gathered {
            for row in buf.chunks_exact(d * 8) {
                rows.push(
                    row.chunks_exact(8)
                        .map(|b| f64::from_le_bytes(b.try_into().unwrap()))
                        .collect(),
                );
            }
        }
        rows.truncate(k);
        for row in rows {
            for x in row {
                init.extend_from_slice(&x.to_le_bytes());
            }
        }
    }
    let init = comm.bcast_bytes(0, init);
    let flat: Vec<f64> = init
        .chunks_exact(8)
        .map(|b| f64::from_le_bytes(b.try_into().unwrap()))
        .collect();
    if flat.len() != k * d {
        bail!("kmeans init: expected {} values, got {}", k * d, flat.len());
    }
    // column-major per feature: centroids[f][j]
    let mut cents = vec![vec![0.0; k]; d];
    for j in 0..k {
        for (f, cf) in cents.iter_mut().enumerate() {
            cf[j] = flat[j * d + f];
        }
    }
    Ok(cents)
}

/// Assign each local row to its nearest centroid; accumulate per-cluster
/// sums and counts (the partials the paper's generated code allreduces).
fn assign_partials(
    features: &[Vec<f64>],
    centroids: &[Vec<f64>],
    k: usize,
) -> (Vec<f64>, Vec<f64>, f64) {
    let d = features.len();
    let n = features.first().map_or(0, |c| c.len());
    let mut sums = vec![0.0f64; k * d];
    let mut counts = vec![0.0f64; k];
    let mut inertia = 0.0f64;
    for i in 0..n {
        let mut best = 0usize;
        let mut best_d = f64::INFINITY;
        for j in 0..k {
            let mut dist = 0.0;
            for (f, col) in features.iter().enumerate() {
                let diff = col[i] - centroids[f][j];
                dist += diff * diff;
            }
            if dist < best_d {
                best_d = dist;
                best = j;
            }
        }
        inertia += best_d;
        counts[best] += 1.0;
        for (f, col) in features.iter().enumerate() {
            sums[best * d + f] += col[i];
        }
    }
    (sums, counts, inertia)
}

/// Distributed k-means over 1D-partitioned feature columns.
pub fn kmeans_distributed(
    comm: &Comm,
    features: &[Vec<f64>],
    k: usize,
    iters: usize,
) -> Result<MlResult> {
    let d = features.len();
    if d == 0 {
        bail!("kmeans: no feature columns");
    }
    let mut centroids = kmeans_init(comm, features, k)?;
    let mut inertia = f64::INFINITY;
    let mut iters_run = 0;
    for _ in 0..iters {
        let (sums, counts, local_inertia) = assign_partials(features, &centroids, k);
        // one allreduce for [sums | counts | inertia]
        let mut partial = sums;
        partial.extend_from_slice(&counts);
        partial.push(local_inertia);
        let total = comm.allreduce_f64_vec(&partial, ReduceOp::Sum);
        let (sums, rest) = total.split_at(k * d);
        let (counts, inertia_slice) = rest.split_at(k);
        for j in 0..k {
            if counts[j] > 0.0 {
                for (f, cf) in centroids.iter_mut().enumerate() {
                    cf[j] = sums[j * d + f] / counts[j];
                }
            }
        }
        let new_inertia = inertia_slice[0];
        iters_run += 1;
        if (inertia - new_inertia).abs() < 1e-12 * (1.0 + inertia.abs()) {
            inertia = new_inertia;
            break;
        }
        inertia = new_inertia;
    }
    Ok(MlResult {
        centroids,
        cluster_ids: (0..k as i64).collect(),
        inertia,
        iters_run,
    })
}

/// PJRT leader mode: gather → drive the `kmeans_step` artifact → broadcast.
pub fn kmeans_pjrt_leader(
    comm: &Comm,
    features: &[Vec<f64>],
    k: usize,
    iters: usize,
) -> Result<MlResult> {
    let d = features.len();
    let n_local = features.first().map_or(0, |c| c.len());
    // gather row-major f64 blocks on the leader
    let mut payload = Vec::with_capacity(n_local * d * 8);
    for i in 0..n_local {
        for c in features {
            payload.extend_from_slice(&c[i].to_le_bytes());
        }
    }
    let gathered = comm.gather_bytes(0, payload);

    let mut result_payload = Vec::new();
    let mut err: Option<String> = None;
    if comm.is_root() {
        match kmeans_pjrt_on_rows(&gathered, d, k, iters) {
            Ok((cents_flat, inertia, iters_run)) => {
                result_payload.extend_from_slice(&inertia.to_le_bytes());
                result_payload.extend_from_slice(&(iters_run as u64).to_le_bytes());
                for x in cents_flat {
                    result_payload.extend_from_slice(&x.to_le_bytes());
                }
            }
            Err(e) => err = Some(format!("{e:#}")),
        }
    }
    // propagate success/failure consistently to all ranks
    let status = comm.bcast_bytes(0, if err.is_some() { vec![1] } else { vec![0] });
    if status[0] == 1 {
        let msg = comm.bcast_bytes(
            0,
            err.map(|s| s.into_bytes()).unwrap_or_default(),
        );
        bail!("kmeans pjrt: {}", String::from_utf8_lossy(&msg));
    }
    let result_payload = comm.bcast_bytes(0, result_payload);
    let inertia = f64::from_le_bytes(result_payload[0..8].try_into().unwrap());
    let iters_run = u64::from_le_bytes(result_payload[8..16].try_into().unwrap()) as usize;
    let flat: Vec<f64> = result_payload[16..]
        .chunks_exact(8)
        .map(|b| f64::from_le_bytes(b.try_into().unwrap()))
        .collect();
    let mut centroids = vec![vec![0.0; k]; d];
    for j in 0..k {
        for (f, cf) in centroids.iter_mut().enumerate() {
            cf[j] = flat[j * d + f];
        }
    }
    Ok(MlResult {
        centroids,
        cluster_ids: (0..k as i64).collect(),
        inertia,
        iters_run,
    })
}

/// Leader-side PJRT k-means loop over gathered row-major blocks.
fn kmeans_pjrt_on_rows(
    gathered: &[Vec<u8>],
    d: usize,
    k: usize,
    iters: usize,
) -> Result<(Vec<f64>, f64, usize)> {
    let engine = crate::runtime::Engine::load_default()
        .context("loading artifacts (run `make artifacts`)")?;
    let entry = engine.entry("kmeans_step")?;
    let (cap_n, art_d, art_k) = (
        entry.param("n")?,
        entry.param("d")?,
        entry.param("k")?,
    );
    if art_d != d || art_k != k {
        bail!(
            "kmeans artifact compiled for d={art_d}, k={art_k}; query needs d={d}, k={k} \
             (re-run `make artifacts` with matching dims)"
        );
    }
    let rows: Vec<f32> = gathered
        .iter()
        .flat_map(|b| {
            b.chunks_exact(8)
                .map(|c| f64::from_le_bytes(c.try_into().unwrap()) as f32)
        })
        .collect();
    let n = rows.len() / d;
    if n < k {
        bail!("kmeans: {n} rows but k={k}");
    }
    if n > cap_n {
        bail!("kmeans artifact capacity n={cap_n} exceeded ({n} rows); re-run aot with larger n");
    }
    // pad to artifact capacity with masked rows
    let mut points = rows;
    points.resize(cap_n * d, 0.0);
    let mut mask = vec![1.0f32; n];
    mask.resize(cap_n, 0.0);
    // init: first k rows
    let mut centroids: Vec<f32> = points[..k * d].to_vec();
    let mut inertia = f64::INFINITY;
    let mut iters_run = 0;
    for _ in 0..iters {
        let (sums, counts, step_inertia) = engine.kmeans_step(&points, &mask, &centroids)?;
        for j in 0..k {
            if counts[j] > 0.0 {
                for f in 0..d {
                    centroids[j * d + f] = sums[j * d + f] / counts[j];
                }
            }
        }
        iters_run += 1;
        let ni = step_inertia as f64;
        if (inertia - ni).abs() < 1e-7 * (1.0 + inertia.abs()) {
            inertia = ni;
            break;
        }
        inertia = ni;
    }
    Ok((
        centroids.iter().map(|&x| x as f64).collect(),
        inertia,
        iters_run,
    ))
}

// --------------------------------------------------------------------------
// logistic regression (TPCx-BB Q05's model step)
// --------------------------------------------------------------------------

/// Result of logistic-regression training.
#[derive(Debug, Clone)]
pub struct LogRegResult {
    /// weights[d] + bias at the end.
    pub weights: Vec<f64>,
    pub loss: f64,
    pub iters_run: usize,
}

fn sigmoid(z: f64) -> f64 {
    1.0 / (1.0 + (-z).exp())
}

/// Local gradient/loss partials for binary logistic regression.
fn logreg_partials(
    features: &[Vec<f64>],
    labels: &[f64],
    weights: &[f64],
) -> (Vec<f64>, f64) {
    let d = features.len();
    let n = labels.len();
    let mut grad = vec![0.0; d + 1];
    let mut loss = 0.0;
    for i in 0..n {
        let mut z = weights[d]; // bias
        for (f, col) in features.iter().enumerate() {
            z += weights[f] * col[i];
        }
        let p = sigmoid(z);
        let err = p - labels[i];
        for (f, col) in features.iter().enumerate() {
            grad[f] += err * col[i];
        }
        grad[d] += err;
        let p_clamped = p.clamp(1e-12, 1.0 - 1e-12);
        loss -= labels[i] * p_clamped.ln() + (1.0 - labels[i]) * (1.0 - p_clamped).ln();
    }
    (grad, loss)
}

/// Distributed batch gradient descent.
pub fn logreg_distributed(
    comm: &Comm,
    features: &[Vec<f64>],
    labels: &[f64],
    iters: usize,
    lr: f64,
) -> Result<LogRegResult> {
    let d = features.len();
    let n_total = comm.allreduce_i64(labels.len() as i64, ReduceOp::Sum) as f64;
    if n_total == 0.0 {
        bail!("logreg: no rows");
    }
    let mut weights = vec![0.0; d + 1];
    let mut loss = f64::INFINITY;
    let mut iters_run = 0;
    for _ in 0..iters {
        let (grad, local_loss) = logreg_partials(features, labels, &weights);
        let mut partial = grad;
        partial.push(local_loss);
        let total = comm.allreduce_f64_vec(&partial, ReduceOp::Sum);
        let (grad, loss_slice) = total.split_at(d + 1);
        for (w, g) in weights.iter_mut().zip(grad) {
            *w -= lr * g / n_total;
        }
        loss = loss_slice[0] / n_total;
        iters_run += 1;
    }
    Ok(LogRegResult {
        weights,
        loss,
        iters_run,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{block_range, run_spmd};
    use crate::datagen::Rng;

    /// Two well-separated blobs in 2-D.
    fn blobs(n: usize, seed: u64) -> (Vec<f64>, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let mut xs = Vec::with_capacity(n);
        let mut ys = Vec::with_capacity(n);
        for i in 0..n {
            let (cx, cy) = if i % 2 == 0 { (0.0, 0.0) } else { (10.0, 10.0) };
            xs.push(cx + rng.normal() * 0.5);
            ys.push(cy + rng.normal() * 0.5);
        }
        (xs, ys)
    }

    #[test]
    fn kmeans_separates_blobs() {
        let (xs, ys) = blobs(200, 1);
        for p in [1usize, 3] {
            let out = run_spmd(p, |c| {
                let (s, l) = block_range(xs.len(), p, c.rank());
                let feats = vec![xs[s..s + l].to_vec(), ys[s..s + l].to_vec()];
                kmeans_distributed(&c, &feats, 2, 20).unwrap()
            });
            let r = &out[0];
            // all ranks agree (replicated output)
            for other in &out[1..] {
                assert_eq!(other.centroids, r.centroids);
            }
            // centroids near (0,0) and (10,10) in some order
            let mut cs: Vec<(f64, f64)> = (0..2)
                .map(|j| (r.centroids[0][j], r.centroids[1][j]))
                .collect();
            cs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            assert!(cs[0].0.abs() < 1.0 && cs[0].1.abs() < 1.0, "{cs:?}");
            assert!((cs[1].0 - 10.0).abs() < 1.0 && (cs[1].1 - 10.0).abs() < 1.0);
            assert!(r.inertia < 200.0);
        }
    }

    #[test]
    fn kmeans_deterministic_across_worker_counts() {
        let (xs, ys) = blobs(120, 7);
        let mut results = Vec::new();
        for p in [1usize, 2, 4] {
            let out = run_spmd(p, |c| {
                let (s, l) = block_range(xs.len(), p, c.rank());
                let feats = vec![xs[s..s + l].to_vec(), ys[s..s + l].to_vec()];
                kmeans_distributed(&c, &feats, 2, 10).unwrap()
            });
            results.push(out[0].clone());
        }
        for r in &results[1..] {
            for (a, b) in r.centroids.iter().flatten().zip(results[0].centroids.iter().flatten()) {
                assert!((a - b).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn kmeans_k_larger_than_rows_errors() {
        let out = run_spmd(2, |c| {
            let feats = vec![vec![c.rank() as f64]];
            kmeans_distributed(&c, &feats, 5, 3).map(|_| ()).is_err()
        });
        assert!(out.iter().all(|&e| e));
    }

    #[test]
    fn logreg_learns_separator() {
        // y = 1 iff x0 + x1 > 10
        let (xs, ys_feat) = blobs(300, 3);
        let labels: Vec<f64> = xs
            .iter()
            .zip(&ys_feat)
            .map(|(a, b)| ((a + b) > 10.0) as i64 as f64)
            .collect();
        let out = run_spmd(3, |c| {
            let (s, l) = block_range(xs.len(), 3, c.rank());
            let feats = vec![xs[s..s + l].to_vec(), ys_feat[s..s + l].to_vec()];
            logreg_distributed(&c, &feats, &labels[s..s + l], 200, 0.5).unwrap()
        });
        let r = &out[0];
        assert!(r.loss < 0.2, "loss {}", r.loss);
        // replicated across ranks
        for o in &out[1..] {
            for (a, b) in o.weights.iter().zip(&r.weights) {
                assert!((a - b).abs() < 1e-9);
            }
        }
        // check classification accuracy on the training data
        let mut correct = 0;
        for i in 0..xs.len() {
            let z = r.weights[0] * xs[i] + r.weights[1] * ys_feat[i] + r.weights[2];
            if ((z > 0.0) as i64 as f64 - labels[i]).abs() < 0.5 {
                correct += 1;
            }
        }
        assert!(correct as f64 / xs.len() as f64 > 0.95);
    }

    #[test]
    fn mlcall_dispatch() {
        let out = run_spmd(1, |c| {
            let feats = vec![vec![0.0, 0.1, 10.0, 10.1]];
            let params = MlParams {
                model: "kmeans".into(),
                k: 2,
                iters: 5,
                use_pjrt: false,
            };
            run_mlcall(&c, &feats, &params).unwrap().centroids
        });
        assert_eq!(out[0].len(), 1);
        assert_eq!(out[0][0].len(), 2);
        let bad = run_spmd(1, |c| {
            run_mlcall(
                &c,
                &[vec![1.0]],
                &MlParams {
                    model: "nope".into(),
                    k: 1,
                    iters: 1,
                    use_pjrt: false,
                },
            )
            .is_err()
        });
        assert!(bad[0]);
    }
}
