//! TPCx-BB Q05 — logistic regression over clickstream behaviour.
//!
//! Relational stage (Fig. 11c):
//! 1. `clicks_cat = join(web_clickstream, item, :wcs_item_sk == :i_item_sk)`
//!    — the paper's *skewed* join: with Zipf-distributed item keys, hash
//!    partitioning puts the hot keys on few ranks ("high load imbalance
//!    among processors, a well-known problem in the parallel database
//!    literature");
//! 2. per-user aggregate: clicks in the target category + per-category
//!    counts;
//! 3. join with customer, then customer_demographics;
//! 4. derive the label (`clicked in category`) and features
//!    (`college_education`, `male`, per-category click counts).
//!
//! ML tail: logistic regression (distributed GD).

use super::BbTables;
use crate::baseline::sparklike::{Rdd, SparkLike};
use crate::comm::run_spmd;
use crate::expr::{col, lit, AggExpr, AggFn};
use crate::frame::{DataFrame, HiFrames};
use crate::ml::LogRegResult;
use crate::table::Table;
use crate::types::JoinType;
use anyhow::Result;

/// The category whose clicks become the label.
pub const TARGET_CATEGORY_ID: i64 = 1; // "Books"
/// Feature categories (per-category click counts).
pub const N_CATS: i64 = 6;

/// The relational stage as a HiFrames data frame.
pub fn hiframes_relational(hf: &HiFrames, db: &BbTables) -> DataFrame {
    let clicks = hf.table("web_clickstream", db.web_clickstream.clone());
    let item = hf.table("item", db.item.clone());
    let customer = hf.table("customer", db.customer.clone());
    let demo = hf.table("customer_demographics", db.customer_demographics.clone());

    let clicks_cat = clicks.join(&item, "wcs_item_sk", "i_item_sk");
    let mut aggs = vec![AggExpr::new(
        "clicks_in_category",
        AggFn::Sum,
        col("i_category_id").eq_(lit(TARGET_CATEGORY_ID)),
    )];
    for c in 1..=N_CATS {
        aggs.push(AggExpr::new(
            &format!("cat{c}"),
            AggFn::Sum,
            col("i_category_id").eq_(lit(c)),
        ));
    }
    let user_cat = clicks_cat.aggregate("wcs_user_sk", aggs);
    let with_cust = user_cat.join(&customer, "wcs_user_sk", "c_customer_sk");
    // demographics is a *sparse* dimension: a LEFT join keeps users whose
    // demo row is missing. The cd_* columns stay Int64 and become nullable
    // (validity masks); the derived 0/1 features use explicit IS NULL
    // semantics — fill_null(0) — so a missing demo row contributes 0, which
    // is what the old NaN-comparison fallback silently did.
    let with_demo = with_cust.join_on(
        &demo,
        &[("c_current_cdemo_sk", "cd_demo_sk")],
        JoinType::Left,
    );
    with_demo.with_columns(&[
        (
            "college_education",
            crate::expr::Expr::BoolToInt(Box::new(
                col("cd_education").fill_null(0i64).ge(lit(3i64)),
            )),
        ),
        (
            "male",
            crate::expr::Expr::BoolToInt(Box::new(
                col("cd_gender").fill_null(0i64).eq_(lit(1i64)),
            )),
        ),
        (
            "label",
            crate::expr::Expr::BoolToInt(Box::new(col("clicks_in_category").gt(lit(0i64)))),
        ),
    ])
}

/// Feature column names for the logreg stage.
pub fn feature_columns() -> Vec<String> {
    let mut cols = vec!["college_education".to_string(), "male".to_string()];
    for c in 2..=N_CATS {
        cols.push(format!("cat{c}"));
    }
    cols
}

/// Full pipeline: relational stage + distributed logistic regression.
pub fn hiframes_full(
    hf: &HiFrames,
    db: &BbTables,
    iters: usize,
) -> Result<(Table, LogRegResult)> {
    let frame = hiframes_relational(hf, db);
    let relational = frame.clone().sort_by("wcs_user_sk").collect()?;
    // train distributed over the collected feature table
    let feats = feature_columns();
    let feat_cols: Vec<Vec<f64>> = feats
        .iter()
        .map(|c| relational.column(c).unwrap().to_f64_vec())
        .collect();
    let labels: Vec<f64> = relational.column("label").unwrap().to_f64_vec();
    let workers = hf.options().workers;
    let results = run_spmd(workers, |comm| {
        let (s, l) = crate::comm::block_range(labels.len(), comm.nranks(), comm.rank());
        let local_feats: Vec<Vec<f64>> =
            feat_cols.iter().map(|c| c[s..s + l].to_vec()).collect();
        crate::ml::logreg_distributed(&comm, &local_feats, &labels[s..s + l], iters, 0.1)
    });
    let lr = results.into_iter().next().unwrap()?;
    Ok((relational, lr))
}

/// The relational stage on the sparklike engine.
pub fn sparklike_relational(eng: &SparkLike, db: &BbTables) -> Result<Rdd> {
    let clicks = eng.parallelize(&db.web_clickstream);
    let item = eng.parallelize(&db.item);
    let customer = eng.parallelize(&db.customer);
    let demo = eng.parallelize(&db.customer_demographics);

    let clicks_cat = eng.join(&clicks, &item, "wcs_item_sk", "i_item_sk")?;
    let mut aggs = vec![AggExpr::new(
        "clicks_in_category",
        AggFn::Sum,
        col("i_category_id").eq_(lit(TARGET_CATEGORY_ID)),
    )];
    for c in 1..=N_CATS {
        aggs.push(AggExpr::new(
            &format!("cat{c}"),
            AggFn::Sum,
            col("i_category_id").eq_(lit(c)),
        ));
    }
    let user_cat = eng.aggregate(&clicks_cat, "wcs_user_sk", &aggs)?;
    let with_cust = eng.join(&user_cat, &customer, "wcs_user_sk", "c_customer_sk")?;
    let with_demo = eng.join_on(
        &with_cust,
        &demo,
        &[("c_current_cdemo_sk", "cd_demo_sk")],
        JoinType::Left,
    )?;
    eng.with_columns(
        &with_demo,
        &[
            (
                "college_education",
                crate::expr::Expr::BoolToInt(Box::new(
                    col("cd_education").fill_null(0i64).ge(lit(3i64)),
                )),
            ),
            (
                "male",
                crate::expr::Expr::BoolToInt(Box::new(
                    col("cd_gender").fill_null(0i64).eq_(lit(1i64)),
                )),
            ),
            (
                "label",
                crate::expr::Expr::BoolToInt(Box::new(
                    col("clicks_in_category").gt(lit(0i64)),
                )),
            ),
        ],
    )
}

/// Per-rank row counts after the skewed join — the load-imbalance metric
/// reported for Fig. 11c (the paper reports Spark OOM; we report the
/// imbalance factor max/mean that causes it).
pub fn join_imbalance(db: &BbTables, workers: usize) -> Result<(f64, Vec<usize>)> {
    let clicks = &db.web_clickstream;
    let item = &db.item;
    let click_keys = clicks.column("wcs_item_sk").unwrap().as_i64().to_vec();
    let item_keys = item.column("i_item_sk").unwrap().as_i64().to_vec();
    let counts = run_spmd(workers, |comm| {
        let (cs, cl) = crate::comm::block_range(click_keys.len(), comm.nranks(), comm.rank());
        let (is, il) = crate::comm::block_range(item_keys.len(), comm.nranks(), comm.rank());
        let (keys, _, _) = crate::ops::distributed_join(
            &comm,
            &click_keys[cs..cs + cl],
            &[],
            &item_keys[is..is + il],
            &[],
        )
        .unwrap();
        keys.len()
    });
    let mean = counts.iter().sum::<usize>() as f64 / counts.len() as f64;
    let max = *counts.iter().max().unwrap() as f64;
    Ok((if mean > 0.0 { max / mean } else { 1.0 }, counts))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bigbench::{generate, GenOptions};

    #[test]
    fn engines_agree_on_q05() {
        let db = generate(&GenOptions {
            scale_factor: 0.15,
            ..Default::default()
        });
        let hf = HiFrames::with_workers(3);
        let ours = hiframes_relational(&hf, &db)
            .sort_by("wcs_user_sk")
            .collect()
            .unwrap();
        let eng = SparkLike::new(2, 3);
        let theirs = eng
            .collect(&sparklike_relational(&eng, &db).unwrap())
            .unwrap()
            .sorted_by("wcs_user_sk")
            .unwrap();
        assert!(ours.num_rows() > 0);
        assert_eq!(ours.num_rows(), theirs.num_rows());
        for c in ["wcs_user_sk", "label", "college_education", "male", "cat2"] {
            assert_eq!(ours.column(c).unwrap(), theirs.column(c).unwrap(), "{c}");
        }
    }

    #[test]
    fn engines_agree_on_q05_with_sparse_demographics() {
        // drop half the demographics rows: the LEFT join must keep every
        // user, null-masking the missing cd_* features identically on both
        // engines (the derived 0/1 features then agree exactly via
        // fill_null(0))
        let mut db = generate(&GenOptions {
            scale_factor: 0.15,
            ..Default::default()
        });
        let full = db.customer_demographics.num_rows();
        db.customer_demographics = db.customer_demographics.slice(0, full / 2);

        let hf = HiFrames::with_workers(3);
        let frame = hiframes_relational(&hf, &db);
        let ours = frame.sort_by("wcs_user_sk").collect().unwrap();
        let eng = SparkLike::new(2, 3);
        let theirs = eng
            .collect(&sparklike_relational(&eng, &db).unwrap())
            .unwrap()
            .sorted_by("wcs_user_sk")
            .unwrap();
        assert!(ours.num_rows() > 0);
        assert_eq!(ours.num_rows(), theirs.num_rows());
        for c in ["wcs_user_sk", "label", "college_education", "male"] {
            assert_eq!(ours.column(c).unwrap(), theirs.column(c).unwrap(), "{c}");
        }
        // the cd_* features keep Int64 dtype and mark missing rows in their
        // validity masks — no NaN promotion anywhere
        assert_eq!(
            ours.schema().dtype_of("cd_education"),
            Some(crate::types::DType::I64)
        );
        let missing = ours.null_count("cd_education");
        assert!(missing > 0, "expected null-masked demographics");
        // engines agree on the null positions too (masks compare in ==)
        assert_eq!(
            ours.mask("cd_education"),
            theirs.mask("cd_education"),
            "null positions must agree"
        );
        // real IS NULL filtering: dropping users without demographics
        // removes exactly the masked rows
        let kept = frame
            .drop_null(&["cd_education"])
            .sort_by("wcs_user_sk")
            .collect()
            .unwrap();
        assert_eq!(kept.num_rows(), ours.num_rows() - missing);
        assert_eq!(kept.null_count("cd_education"), 0);
        // and is_null exposes the same row set as a Bool feature
        let flagged = frame.is_null("cd_education").collect().unwrap();
        let nulls = flagged
            .column("cd_education_is_null")
            .unwrap()
            .as_bool()
            .iter()
            .filter(|&&b| b)
            .count();
        assert_eq!(nulls, missing);
    }

    #[test]
    fn logreg_trains_on_q05() {
        let db = generate(&GenOptions {
            scale_factor: 0.3,
            ..Default::default()
        });
        let hf = HiFrames::with_workers(2);
        let (rel, lr) = hiframes_full(&hf, &db, 30).unwrap();
        assert!(rel.num_rows() > 10);
        assert_eq!(lr.weights.len(), feature_columns().len() + 1);
        assert!(lr.loss.is_finite());
    }

    #[test]
    fn skewed_q05_auto_selects_broadcast_and_matches_serial() {
        use crate::exec::collect_serial;
        use crate::passes::{optimize, PassOptions};
        // Zipf-skewed clickstream: the planner must flip the clicks⋈item
        // join to the skew-broadcast strategy on its own…
        let db = generate(&GenOptions {
            scale_factor: 0.15,
            click_skew: 1.5,
            ..Default::default()
        });
        let hf = HiFrames::with_workers(3);
        let frame = hiframes_relational(&hf, &db).sort_by("wcs_user_sk");
        let optimized =
            optimize(frame.plan().clone(), &PassOptions::default()).unwrap();
        assert!(
            format!("{optimized}").contains("skew-broadcast"),
            "planner did not engage the skew path:\n{optimized}"
        );
        // …and the distributed result (skew path active) must be
        // byte-identical to the serial baseline (masks included — Table
        // equality compares values *and* null positions)
        let ours = frame.collect().unwrap();
        let serial = collect_serial(frame.plan().clone()).unwrap();
        assert!(ours.num_rows() > 0);
        assert_eq!(ours, serial);
        // the uniform clickstream must NOT flip (below the threshold)
        let db = generate(&GenOptions {
            scale_factor: 0.15,
            ..Default::default()
        });
        let frame = hiframes_relational(&hf, &db);
        let optimized =
            optimize(frame.plan().clone(), &PassOptions::default()).unwrap();
        assert!(
            !format!("{optimized}").contains("skew-broadcast"),
            "uniform keys flipped unexpectedly:\n{optimized}"
        );
    }

    #[test]
    fn skew_increases_imbalance() {
        let uniform = generate(&GenOptions {
            scale_factor: 0.3,
            ..Default::default()
        });
        let skewed = generate(&GenOptions {
            scale_factor: 0.3,
            click_skew: 1.5,
            ..Default::default()
        });
        let (fu, _) = join_imbalance(&uniform, 4).unwrap();
        let (fs, _) = join_imbalance(&skewed, 4).unwrap();
        assert!(
            fs > fu * 1.5,
            "skewed imbalance {fs:.2} not >> uniform {fu:.2}"
        );
    }
}
