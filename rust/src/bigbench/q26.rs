//! TPCx-BB Q26 — the paper's running example (§3.2): cluster customers by
//! their in-category purchase behaviour.
//!
//! Relational stage (timed for Fig. 11a / Fig. 12):
//! 1. filter `item` to one category ("Books", as in the kit);
//! 2. `sale_items = join(store_sales, item, :ss_item_sk == :i_item_sk)`;
//! 3. `aggregate(sale_items, :ss_customer_sk, :cnt = length(...),
//!    :id1..:id5 = sum(:i_class_id == k))`;
//! 4. filter `:cnt > min_count`;
//! 5. feature-scale `:id3` by mean/var (the paper's §3.2 example).
//!
//! ML tail (excluded from the relational timing, as in the paper):
//! matrix assembly + k-means.

use super::BbTables;
use crate::baseline::sparklike::{Rdd, SparkLike};
use crate::expr::{col, lit, AggExpr, AggFn};
use crate::frame::{DataFrame, HiFrames};
use crate::table::Table;
use crate::types::SortOrder;
use anyhow::Result;

/// Q26 parameters (kit defaults scaled down).
#[derive(Debug, Clone)]
pub struct Q26Params {
    pub category: String,
    pub min_count: i64,
    pub k: usize,
    pub iters: usize,
}

impl Default for Q26Params {
    fn default() -> Self {
        Q26Params {
            category: "Books".to_string(),
            min_count: 1,
            k: 8,
            iters: 10,
        }
    }
}

/// Number of class-count features (id1..idN).
pub const N_FEATURES: i64 = 5;

/// The relational stage as a HiFrames data frame (lazy).
pub fn hiframes_relational(hf: &HiFrames, db: &BbTables, p: &Q26Params) -> DataFrame {
    let store_sales = hf.table("store_sales", db.store_sales.clone());
    let item = hf.table("item", db.item.clone());

    let books = item.filter(col("i_category").eq_(lit(p.category.as_str())));
    let sale_items = store_sales.join(&books, "ss_item_sk", "i_item_sk");

    let mut gb = sale_items
        .group_by(&["ss_customer_sk"])
        .agg("cnt", AggFn::Count, col("i_class_id"));
    for k in 1..=N_FEATURES {
        gb = gb.agg(&format!("id{k}"), AggFn::Sum, col("i_class_id").eq_(lit(k)));
    }
    gb.build().filter(col("cnt").gt(lit(p.min_count)))
}

/// Top-N customers by in-category purchase count — the kit's ORDER-BY-then-
/// LIMIT tail, expressed as a multi-key distributed sort
/// (`cnt` descending, customer ascending for determinism).
pub fn top_customers(
    hf: &HiFrames,
    db: &BbTables,
    p: &Q26Params,
    n: usize,
) -> Result<Table> {
    let sorted = hiframes_relational(hf, db, p)
        .sort_by_keys(&[
            ("cnt", SortOrder::Desc),
            ("ss_customer_sk", SortOrder::Asc),
        ])
        .collect()?;
    Ok(sorted.slice(0, n.min(sorted.num_rows())))
}

/// Full HiFrames Q26: relational stage + feature scaling + k-means.
/// Returns `(relational result, centroids table)`.
pub fn hiframes_full(
    hf: &HiFrames,
    db: &BbTables,
    p: &Q26Params,
    use_pjrt: bool,
) -> Result<(Table, Table)> {
    let c_i_points = hiframes_relational(hf, db, p);
    // feature scaling on :id3 — §3.2's (id3 - mean) / var
    let m = c_i_points.mean("id3")?;
    let v = c_i_points.var("id3")?.max(1e-9);
    let scaled = c_i_points.with_column("id3", col("id3").sub(lit(m)).div(lit(v)));
    let relational = scaled.clone().sort_by("ss_customer_sk").collect()?;
    let feature_names: Vec<String> = std::iter::once("cnt".to_string())
        .chain((1..=N_FEATURES).map(|k| format!("id{k}")))
        .collect();
    let feature_refs: Vec<&str> = feature_names.iter().map(|s| s.as_str()).collect();
    let centroids = scaled
        .matrix_assembly(&feature_refs)
        .kmeans(p.k, p.iters, use_pjrt)
        .collect()?;
    Ok((relational, centroids))
}

/// The relational stage on the sparklike engine.
pub fn sparklike_relational(eng: &SparkLike, db: &BbTables, p: &Q26Params) -> Result<Rdd> {
    let store_sales = eng.parallelize(&db.store_sales);
    let item = eng.parallelize(&db.item);
    let books = eng.filter(&item, &col("i_category").eq_(lit(p.category.as_str())))?;
    let sale_items = eng.join(&store_sales, &books, "ss_item_sk", "i_item_sk")?;
    let mut aggs = vec![AggExpr::new("cnt", AggFn::Count, col("i_class_id"))];
    for k in 1..=N_FEATURES {
        aggs.push(AggExpr::new(
            &format!("id{k}"),
            AggFn::Sum,
            col("i_class_id").eq_(lit(k)),
        ));
    }
    let agg = eng.aggregate(&sale_items, "ss_customer_sk", &aggs)?;
    eng.filter(&agg, &col("cnt").gt(lit(p.min_count)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bigbench::{generate, GenOptions};

    #[test]
    fn engines_agree_on_q26_relational() {
        let db = generate(&GenOptions {
            scale_factor: 0.2,
            ..Default::default()
        });
        let p = Q26Params::default();
        let hf = HiFrames::with_workers(3);
        let ours = hiframes_relational(&hf, &db, &p)
            .sort_by("ss_customer_sk")
            .collect()
            .unwrap();
        let eng = SparkLike::new(2, 4);
        let theirs = eng
            .collect(&sparklike_relational(&eng, &db, &p).unwrap())
            .unwrap()
            .sorted_by("ss_customer_sk")
            .unwrap();
        assert!(ours.num_rows() > 0, "empty Q26 result");
        assert_eq!(ours.num_rows(), theirs.num_rows());
        assert_eq!(
            ours.column("ss_customer_sk").unwrap(),
            theirs.column("ss_customer_sk").unwrap()
        );
        assert_eq!(ours.column("cnt").unwrap(), theirs.column("cnt").unwrap());
        assert_eq!(ours.column("id3").unwrap(), theirs.column("id3").unwrap());
    }

    #[test]
    fn top_customers_matches_serial_order_by() {
        let db = generate(&GenOptions {
            scale_factor: 0.2,
            ..Default::default()
        });
        let p = Q26Params::default();
        let hf = HiFrames::with_workers(3);
        let top = top_customers(&hf, &db, &p, 10).unwrap();
        // serial oracle: collect unsorted, canonicalize with the Table-level
        // multi-key sort, take the same prefix
        let all = hiframes_relational(&hf, &db, &p).collect().unwrap();
        let expect = all
            .sorted_by_keys(&[
                ("cnt", SortOrder::Desc),
                ("ss_customer_sk", SortOrder::Asc),
            ])
            .unwrap()
            .slice(0, top.num_rows());
        assert!(top.num_rows() > 0);
        assert_eq!(
            top.column("ss_customer_sk").unwrap(),
            expect.column("ss_customer_sk").unwrap()
        );
        assert_eq!(top.column("cnt").unwrap(), expect.column("cnt").unwrap());
        // counts are non-increasing
        let cnt = top.column("cnt").unwrap().as_i64();
        assert!(cnt.windows(2).all(|w| w[0] >= w[1]));
    }

    #[test]
    fn explain_snapshot_q26() {
        let db = generate(&GenOptions {
            scale_factor: 0.05,
            ..Default::default()
        });
        let p = Q26Params::default();
        let hf = HiFrames::with_workers(2);
        let q = hiframes_relational(&hf, &db, &p);
        let text = q.explain();
        // golden properties: byte-stable across calls and across contexts
        // (node numbers are execution-order positions, so the render is
        // canonical for the plan + options)
        assert_eq!(text, q.explain(), "explain must be deterministic");
        let hf3 = HiFrames::with_workers(3);
        assert_eq!(
            hiframes_relational(&hf3, &db, &p).explain(),
            text,
            "worker count must not change the logical plan"
        );
        // every line renders as `%i = Op(…) [dist]`
        for (i, line) in text.lines().enumerate() {
            assert!(
                line.starts_with(&format!("%{i} = ")),
                "bad line {i}: {line}\n{text}"
            );
            assert!(line.contains('['), "missing dist annotation: {line}");
        }
        // the pipeline appears in execution order: sources, then the
        // category filter below the join, then aggregate, then HAVING
        let idx = |needle: &str| {
            text.lines()
                .position(|l| l.contains(needle))
                .unwrap_or_else(|| panic!("missing {needle:?} in:\n{text}"))
        };
        assert!(idx("Source(store_sales)") < idx("Join("));
        assert!(idx("Source(item)") < idx("Join("));
        assert!(
            idx("i_category") < idx("Join("),
            "category filter must stay below the join:\n{text}"
        );
        assert!(idx("Join(") < idx("Aggregate("));
        assert!(
            idx("Aggregate(") < idx(":cnt >"),
            "HAVING filter must sit above the aggregate:\n{text}"
        );
    }

    #[test]
    fn full_pipeline_produces_centroids() {
        let db = generate(&GenOptions {
            scale_factor: 0.3,
            ..Default::default()
        });
        let p = Q26Params {
            k: 4,
            iters: 5,
            ..Default::default()
        };
        let hf = HiFrames::with_workers(2);
        let (rel, cents) = hiframes_full(&hf, &db, &p, false).unwrap();
        assert!(rel.num_rows() >= p.k);
        assert_eq!(cents.num_rows(), 4);
        assert_eq!(cents.num_cols(), N_FEATURES as usize + 2); // cnt + id1..5 + cluster
    }
}
