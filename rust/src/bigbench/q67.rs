//! TPCx-BB/TPC-DS-style Q67 — ranked sales per category, the query family
//! the unified window subsystem opens: `rank() OVER (PARTITION BY category
//! ORDER BY n DESC)` cannot be phrased as a join/aggregate tree, and the
//! map-reduce baseline must shuffle + sort whole partitions to answer it.
//!
//! Shape:
//! 1. `store_sales ⋈ item` on the item surrogate key;
//! 2. aggregate the sale count per `(category, item)`;
//! 3. window: partition by category, order by `(n desc, item asc)` —
//!    `rank()` plus `lead(n, 1)` (each item's gap to the runner-up);
//! 4. keep the top [`TOP_K`] items of every category and derive the
//!    `gap = n - next_n` margin column in one batched
//!    [`DataFrame::with_columns`] call.

use super::BbTables;
use crate::baseline::serial;
use crate::expr::{col, lit, AggExpr, AggFn};
use crate::frame::{DataFrame, HiFrames};
use crate::ir::{SortOrder, WindowAgg, WindowFrame, WindowFunc};
use crate::table::Table;
use crate::types::JoinType;
use anyhow::Result;

/// Items kept per category.
pub const TOP_K: i64 = 3;

/// HiFrames implementation: join → multi-key aggregate → partitioned
/// window (rank + lead) → filter to the top K per category.
pub fn hiframes_query(hf: &HiFrames, db: &BbTables) -> DataFrame {
    let ss = hf.table("store_sales", db.store_sales.clone());
    let item = hf.table("item", db.item.clone());
    ss.join_on(&item, &[("ss_item_sk", "i_item_sk")], JoinType::Inner)
        .group_by(&["i_category", "ss_item_sk"])
        .agg("n", AggFn::Count, col("ss_item_sk"))
        .build()
        .window()
        .partition_by(&["i_category"])
        .order_by(&[("n", SortOrder::Desc), ("ss_item_sk", SortOrder::Asc)])
        .rank("r")
        .agg_expr("next_n", col("n").lead(1))
        .build()
        .filter(col("r").le(lit(TOP_K)))
        .with_columns(&[("gap", col("n").sub(col("next_n").fill_null(0i64)))])
}

/// The serial (Pandas-like) oracle for the same query.
pub fn serial_query(db: &BbTables) -> Result<Table> {
    let joined = serial::join_on(
        &db.store_sales,
        &db.item,
        &[("ss_item_sk", "i_item_sk")],
        JoinType::Inner,
    )?;
    let agg = serial::aggregate_by(
        &joined,
        &["i_category", "ss_item_sk"],
        &[AggExpr::new("n", AggFn::Count, col("ss_item_sk"))],
    )?;
    let win = serial::window(
        &agg,
        &["i_category"],
        &[("n", SortOrder::Desc), ("ss_item_sk", SortOrder::Asc)],
        &[
            WindowAgg::new(
                "r",
                WindowFunc::Rank,
                WindowFrame::CumulativeToCurrent,
                lit(0i64),
            ),
            WindowAgg::new("next_n", WindowFunc::Value, WindowFrame::Shift(-1), col("n")),
        ],
    )?;
    serial::filter(&win, &col("r").le(lit(TOP_K)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bigbench::{generate, GenOptions};
    use crate::types::SortOrder;

    #[test]
    fn hiframes_matches_serial_across_workers() {
        let db = generate(&GenOptions {
            scale_factor: 0.02,
            ..Default::default()
        });
        let expect = serial_query(&db)
            .unwrap()
            .sorted_by_keys(&[
                ("i_category", SortOrder::Asc),
                ("r", SortOrder::Asc),
            ])
            .unwrap();
        assert!(expect.num_rows() > 0);
        for workers in [1usize, 3] {
            let hf = HiFrames::with_workers(workers);
            let got = hiframes_query(&hf, &db)
                .collect()
                .unwrap()
                .sorted_by_keys(&[
                    ("i_category", SortOrder::Asc),
                    ("r", SortOrder::Asc),
                ])
                .unwrap();
            assert_eq!(got.num_rows(), expect.num_rows(), "workers={workers}");
            for c in ["i_category", "ss_item_sk", "n", "r", "next_n"] {
                assert_eq!(
                    got.column(c).unwrap(),
                    expect.column(c).unwrap(),
                    "workers={workers} column {c}"
                );
                assert_eq!(
                    got.mask(c),
                    expect.mask(c),
                    "workers={workers} mask {c}"
                );
            }
            // the batched derived column: gap = n - fill_null(next_n, 0)
            let n = expect.column("n").unwrap().as_i64();
            let next = expect.column("next_n").unwrap().as_i64();
            let nm = expect.mask("next_n");
            let want_gap: Vec<i64> = n
                .iter()
                .zip(next)
                .enumerate()
                .map(|(i, (a, b))| a - if nm.map_or(true, |m| m.get(i)) { *b } else { 0 })
                .collect();
            assert_eq!(
                got.column("gap").unwrap().as_i64(),
                &want_gap[..],
                "workers={workers} gap"
            );
            // every category keeps at most TOP_K ranked rows, rank starts at 1
            let ranks = got.column("r").unwrap().as_i64();
            assert!(ranks.iter().all(|&r| r >= 1 && r <= TOP_K));
        }
    }
}
