//! TPCx-BB (BigBench) workload substrate (paper §5.1).
//!
//! The paper evaluates Q05, Q25 and Q26 using the official data generator;
//! we synthesize the same *relational structure* — schemas, join
//! cardinalities, key skew — with a deterministic generator ([`gen`]) whose
//! row counts scale linearly in the scale factor (DESIGN.md §3 documents
//! the substitution). Each query module provides both the HiFrames
//! implementation and the sparklike one so every Fig. 11 bar has its two
//! systems, plus the ML tail (k-means for Q25/Q26, logistic regression for
//! Q05) used by the end-to-end example.

pub mod gen;
pub mod q01;
pub mod q05;
pub mod q25;
pub mod q26;
pub mod q67;

pub use gen::{generate, BbTables, GenOptions};
