//! TPCx-BB Q25 — customer RFM segmentation over store AND web sales.
//!
//! Relational stage (Fig. 11b), redesigned around the composite-key API:
//! 1. filter both fact tables to `sold_date > cutoff`;
//! 2. normalize each channel to a common `(cid, ticket, date, paid)` schema
//!    and tag it with a `chan` id (store = 0, web = 1);
//! 3. concat the raw line items and aggregate **by the composite key
//!    `(cid, chan)`**: `frequency = count_distinct(ticket)`,
//!    `totalspend = sum(paid)`, `recency = max(date)` — count-distinct is
//!    the "computationally expensive operation" the paper credits for
//!    Q25's wider gap, and the channel key keeps ticket numbers from
//!    colliding across channels;
//! 4. re-aggregate by customer: `max(recency), sum(frequency),
//!    sum(totalspend)`.
//!
//! ML tail: k-means over (recency, frequency, totalspend).

use super::gen::Q25_CUTOFF;
use super::BbTables;
use crate::baseline::sparklike::{Rdd, SparkLike};
use crate::expr::{col, lit, AggExpr, AggFn};
use crate::frame::{DataFrame, HiFrames};
use crate::table::Table;
use anyhow::Result;

/// The per-(customer, channel) RFM aggregates.
fn rfm_aggs() -> Vec<AggExpr> {
    vec![
        AggExpr::new("recency", AggFn::Max, col("date")),
        AggExpr::new("frequency", AggFn::CountDistinct, col("ticket")),
        AggExpr::new("totalspend", AggFn::Sum, col("paid")),
    ]
}

/// Normalize one channel to the common line-item schema, HiFrames side.
fn channel_hiframes(
    df: &DataFrame,
    cust: &str,
    ticket: &str,
    date: &str,
    paid: &str,
    chan: i64,
) -> DataFrame {
    df.filter(col(date).gt(lit(Q25_CUTOFF)))
        .rename(cust, "cid")
        .rename(ticket, "ticket")
        .rename(date, "date")
        .rename(paid, "paid")
        .select(&["cid", "ticket", "date", "paid"])
        .with_columns(&[("chan", lit(chan))])
}

/// The relational stage as a HiFrames data frame.
pub fn hiframes_relational(hf: &HiFrames, db: &BbTables) -> DataFrame {
    let ss = hf.table("store_sales", db.store_sales.clone());
    let ws = hf.table("web_sales", db.web_sales.clone());
    let s = channel_hiframes(
        &ss,
        "ss_customer_sk",
        "ss_ticket_number",
        "ss_sold_date_sk",
        "ss_net_paid",
        0,
    );
    let w = channel_hiframes(
        &ws,
        "ws_bill_customer_sk",
        "ws_order_number",
        "ws_sold_date_sk",
        "ws_net_paid",
        1,
    );
    s.concat(&w)
        .aggregate_by(&["cid", "chan"], rfm_aggs())
        .aggregate(
            "cid",
            vec![
                AggExpr::new("recency", AggFn::Max, col("recency")),
                AggExpr::new("frequency", AggFn::Sum, col("frequency")),
                AggExpr::new("totalspend", AggFn::Sum, col("totalspend")),
            ],
        )
}

/// Full pipeline: relational + k-means.
pub fn hiframes_full(
    hf: &HiFrames,
    db: &BbTables,
    k: usize,
    iters: usize,
    use_pjrt: bool,
) -> Result<(Table, Table)> {
    let rfm = hiframes_relational(hf, db);
    let relational = rfm.clone().sort_by("cid").collect()?;
    let centroids = rfm
        .matrix_assembly(&["recency", "frequency", "totalspend"])
        .kmeans(k, iters, use_pjrt)
        .collect()?;
    Ok((relational, centroids))
}

/// Rename columns of an RDD (schema metadata only — rows are positional).
fn rename_rdd(rdd: Rdd, renames: &[(&str, &str)]) -> Rdd {
    Rdd {
        schema: crate::table::Schema::new(
            rdd.schema
                .fields()
                .iter()
                .map(|(n, t)| {
                    match renames.iter().find(|(from, _)| *from == n.as_str()) {
                        Some((_, to)) => (to.to_string(), *t),
                        None => (n.clone(), *t),
                    }
                })
                .collect(),
        ),
        parts: rdd.parts,
    }
}

/// Normalize one channel to the common line-item schema, sparklike side.
fn channel_sparklike(
    eng: &SparkLike,
    rdd: &Rdd,
    cust: &str,
    ticket: &str,
    date: &str,
    paid: &str,
    chan: i64,
) -> Result<Rdd> {
    let filtered = eng.filter(rdd, &col(date).gt(lit(Q25_CUTOFF)))?;
    let renamed = rename_rdd(
        filtered,
        &[
            (cust, "cid"),
            (ticket, "ticket"),
            (date, "date"),
            (paid, "paid"),
        ],
    );
    let sel = eng.select(&renamed, &["cid", "ticket", "date", "paid"])?;
    eng.with_columns(&sel, &[("chan", lit(chan))])
}

/// The relational stage on the sparklike engine.
pub fn sparklike_relational(eng: &SparkLike, db: &BbTables) -> Result<Rdd> {
    let ss = eng.parallelize(&db.store_sales);
    let ws = eng.parallelize(&db.web_sales);
    let s = channel_sparklike(
        eng,
        &ss,
        "ss_customer_sk",
        "ss_ticket_number",
        "ss_sold_date_sk",
        "ss_net_paid",
        0,
    )?;
    let w = channel_sparklike(
        eng,
        &ws,
        "ws_bill_customer_sk",
        "ws_order_number",
        "ws_sold_date_sk",
        "ws_net_paid",
        1,
    )?;
    // union: concat partition lists (schemas identical)
    let union = Rdd {
        schema: s.schema.clone(),
        parts: s.parts.into_iter().chain(w.parts).collect(),
    };
    let per_chan = eng.aggregate_by(&union, &["cid", "chan"], &rfm_aggs())?;
    eng.aggregate(
        &per_chan,
        "cid",
        &[
            AggExpr::new("recency", AggFn::Max, col("recency")),
            AggExpr::new("frequency", AggFn::Sum, col("frequency")),
            AggExpr::new("totalspend", AggFn::Sum, col("totalspend")),
        ],
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bigbench::{generate, GenOptions};

    #[test]
    fn engines_agree_on_q25() {
        let db = generate(&GenOptions {
            scale_factor: 0.2,
            ..Default::default()
        });
        let hf = HiFrames::with_workers(3);
        let ours = hiframes_relational(&hf, &db)
            .sort_by("cid")
            .collect()
            .unwrap();
        let eng = SparkLike::new(2, 3);
        let theirs = eng
            .collect(&sparklike_relational(&eng, &db).unwrap())
            .unwrap()
            .sorted_by("cid")
            .unwrap();
        assert!(ours.num_rows() > 0);
        assert_eq!(ours.num_rows(), theirs.num_rows());
        for c in ["cid", "recency", "frequency"] {
            assert_eq!(ours.column(c).unwrap(), theirs.column(c).unwrap(), "{c}");
        }
        // float column: compare approximately
        for (a, b) in ours
            .column("totalspend")
            .unwrap()
            .as_f64()
            .iter()
            .zip(theirs.column("totalspend").unwrap().as_f64())
        {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn frequency_counts_distinct_tickets() {
        // 4 line items on 2 tickets for one customer → frequency 2
        let db = {
            let mut db = generate(&GenOptions {
                scale_factor: 0.1,
                ..Default::default()
            });
            let t = crate::table::Table::from_pairs(vec![
                ("ss_item_sk", crate::column::Column::I64(vec![0, 1, 2, 3])),
                ("ss_customer_sk", crate::column::Column::I64(vec![7, 7, 7, 7])),
                ("ss_ticket_number", crate::column::Column::I64(vec![1, 1, 2, 2])),
                (
                    "ss_sold_date_sk",
                    crate::column::Column::I64(vec![
                        Q25_CUTOFF + 1,
                        Q25_CUTOFF + 2,
                        Q25_CUTOFF + 3,
                        Q25_CUTOFF + 4,
                    ]),
                ),
                (
                    "ss_net_paid",
                    crate::column::Column::F64(vec![1.0, 2.0, 3.0, 4.0]),
                ),
            ])
            .unwrap();
            db.store_sales = t;
            // empty web channel
            db.web_sales = db.web_sales.slice(0, 0);
            db
        };
        let hf = HiFrames::with_workers(2);
        let out = hiframes_relational(&hf, &db).collect().unwrap();
        assert_eq!(out.num_rows(), 1);
        assert_eq!(out.column("frequency").unwrap().as_i64(), &[2]);
        assert_eq!(out.column("recency").unwrap().as_i64(), &[Q25_CUTOFF + 4]);
        let ts = out.column("totalspend").unwrap().as_f64();
        assert!((ts[0] - 10.0).abs() < 1e-9);
    }

    #[test]
    fn full_pipeline_runs() {
        let db = generate(&GenOptions {
            scale_factor: 0.3,
            ..Default::default()
        });
        let hf = HiFrames::with_workers(2);
        let (rel, cents) = hiframes_full(&hf, &db, 4, 5, false).unwrap();
        assert!(rel.num_rows() >= 4);
        assert_eq!(cents.num_rows(), 4);
    }
}
