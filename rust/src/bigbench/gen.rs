//! Deterministic TPCx-BB-shaped data generator.
//!
//! Cardinalities (rows per scale factor) follow TPCx-BB's linear growth for
//! fact tables and sublinear growth for dimensions; the Q05 clickstream can
//! be generated with Zipf-skewed item keys to reproduce the paper's skewed
//! join experiment ("a join on a large table with highly skewed data").

use crate::column::Column;
use crate::datagen::{Rng, Zipf};
use crate::table::Table;

/// Item categories (subset of TPCx-BB's).
pub const CATEGORIES: [&str; 6] = [
    "Books",
    "Electronics",
    "Home & Kitchen",
    "Clothing",
    "Sports",
    "Toys",
];

/// Number of item classes referenced by Q26 features.
pub const N_CLASSES: i64 = 15;

/// Date surrogate-key range (days).
pub const DATE_MIN: i64 = 36_000;
pub const DATE_MAX: i64 = 38_000;
/// Q25's recency cutoff ('2002-01-02' in the real kit).
pub const Q25_CUTOFF: i64 = 37_000;

/// Generation options.
#[derive(Debug, Clone)]
pub struct GenOptions {
    pub scale_factor: f64,
    /// Zipf exponent for clickstream item keys (0.0 = uniform). The paper's
    /// Q05 skew experiment uses a heavily skewed distribution.
    pub click_skew: f64,
    pub seed: u64,
}

impl Default for GenOptions {
    fn default() -> Self {
        GenOptions {
            scale_factor: 1.0,
            click_skew: 0.0,
            seed: 42,
        }
    }
}

/// The generated database.
#[derive(Debug, Clone)]
pub struct BbTables {
    pub store_sales: Table,
    pub web_sales: Table,
    pub web_clickstream: Table,
    pub item: Table,
    pub customer: Table,
    pub customer_demographics: Table,
}

/// Row counts at a scale factor (fact tables linear, dims sublinear).
pub fn sizes(sf: f64) -> (usize, usize, usize, usize, usize) {
    let store_sales = (30_000.0 * sf) as usize;
    let web_sales = (15_000.0 * sf) as usize;
    let clicks = (50_000.0 * sf) as usize;
    let items = (400.0 + 120.0 * sf.sqrt() * 10.0).min(4000.0) as usize;
    let customers = (2_000.0 * sf.sqrt() * 2.0).max(200.0) as usize;
    (store_sales, web_sales, clicks, items, customers)
}

/// Generate the database.
pub fn generate(opts: &GenOptions) -> BbTables {
    let mut rng = Rng::new(opts.seed);
    let (n_ss, n_ws, n_clicks, n_items, n_cust) = sizes(opts.scale_factor);

    // ---- item dimension ---------------------------------------------------
    let mut i_item_sk = Vec::with_capacity(n_items);
    let mut i_class_id = Vec::with_capacity(n_items);
    let mut i_category_id = Vec::with_capacity(n_items);
    let mut i_category = Vec::with_capacity(n_items);
    for sk in 0..n_items as i64 {
        i_item_sk.push(sk);
        i_class_id.push(rng.i64_range(1, N_CLASSES + 1));
        let cat = rng.usize(CATEGORIES.len());
        i_category_id.push(cat as i64 + 1);
        i_category.push(CATEGORIES[cat].to_string());
    }
    let item = Table::from_pairs(vec![
        ("i_item_sk", Column::I64(i_item_sk)),
        ("i_class_id", Column::I64(i_class_id)),
        ("i_category_id", Column::I64(i_category_id)),
        ("i_category", Column::Str(i_category)),
    ])
    .expect("item table");

    // ---- customer + demographics ------------------------------------------
    let mut c_customer_sk = Vec::with_capacity(n_cust);
    let mut c_current_cdemo_sk = Vec::with_capacity(n_cust);
    for sk in 0..n_cust as i64 {
        c_customer_sk.push(sk);
        c_current_cdemo_sk.push(sk); // 1:1 demographics
    }
    let customer = Table::from_pairs(vec![
        ("c_customer_sk", Column::I64(c_customer_sk)),
        ("c_current_cdemo_sk", Column::I64(c_current_cdemo_sk)),
    ])
    .expect("customer table");

    let mut cd_demo_sk = Vec::with_capacity(n_cust);
    let mut cd_gender = Vec::with_capacity(n_cust);
    let mut cd_education = Vec::with_capacity(n_cust);
    for sk in 0..n_cust as i64 {
        cd_demo_sk.push(sk);
        cd_gender.push(rng.i64_range(0, 2));
        cd_education.push(rng.i64_range(0, 7));
    }
    let customer_demographics = Table::from_pairs(vec![
        ("cd_demo_sk", Column::I64(cd_demo_sk)),
        ("cd_gender", Column::I64(cd_gender)),
        ("cd_education", Column::I64(cd_education)),
    ])
    .expect("demographics table");

    // ---- store_sales fact --------------------------------------------------
    // ticket numbers group ~3 line items per basket (Q25's count-distinct)
    let mut ss_item_sk = Vec::with_capacity(n_ss);
    let mut ss_customer_sk = Vec::with_capacity(n_ss);
    let mut ss_ticket_number = Vec::with_capacity(n_ss);
    let mut ss_sold_date_sk = Vec::with_capacity(n_ss);
    let mut ss_net_paid = Vec::with_capacity(n_ss);
    for i in 0..n_ss {
        ss_item_sk.push(rng.i64_range(0, n_items as i64));
        ss_customer_sk.push(rng.i64_range(0, n_cust as i64));
        ss_ticket_number.push((i / 3) as i64);
        ss_sold_date_sk.push(rng.i64_range(DATE_MIN, DATE_MAX));
        ss_net_paid.push((rng.f64() * 200.0 * 100.0).round() / 100.0);
    }
    let store_sales = Table::from_pairs(vec![
        ("ss_item_sk", Column::I64(ss_item_sk)),
        ("ss_customer_sk", Column::I64(ss_customer_sk)),
        ("ss_ticket_number", Column::I64(ss_ticket_number)),
        ("ss_sold_date_sk", Column::I64(ss_sold_date_sk)),
        ("ss_net_paid", Column::F64(ss_net_paid)),
    ])
    .expect("store_sales table");

    // ---- web_sales fact ----------------------------------------------------
    let mut ws_item_sk = Vec::with_capacity(n_ws);
    let mut ws_bill_customer_sk = Vec::with_capacity(n_ws);
    let mut ws_order_number = Vec::with_capacity(n_ws);
    let mut ws_sold_date_sk = Vec::with_capacity(n_ws);
    let mut ws_net_paid = Vec::with_capacity(n_ws);
    for i in 0..n_ws {
        ws_item_sk.push(rng.i64_range(0, n_items as i64));
        ws_bill_customer_sk.push(rng.i64_range(0, n_cust as i64));
        ws_order_number.push((i / 2) as i64);
        ws_sold_date_sk.push(rng.i64_range(DATE_MIN, DATE_MAX));
        ws_net_paid.push((rng.f64() * 150.0 * 100.0).round() / 100.0);
    }
    let web_sales = Table::from_pairs(vec![
        ("ws_item_sk", Column::I64(ws_item_sk)),
        ("ws_bill_customer_sk", Column::I64(ws_bill_customer_sk)),
        ("ws_order_number", Column::I64(ws_order_number)),
        ("ws_sold_date_sk", Column::I64(ws_sold_date_sk)),
        ("ws_net_paid", Column::F64(ws_net_paid)),
    ])
    .expect("web_sales table");

    // ---- web_clickstream fact (optionally skewed item keys) ----------------
    let zipf = (opts.click_skew > 0.0).then(|| Zipf::new(n_items, opts.click_skew));
    let mut wcs_item_sk = Vec::with_capacity(n_clicks);
    let mut wcs_user_sk = Vec::with_capacity(n_clicks);
    let mut wcs_click_date_sk = Vec::with_capacity(n_clicks);
    for _ in 0..n_clicks {
        let item_sk = match &zipf {
            Some(z) => z.sample(&mut rng) as i64,
            None => rng.i64_range(0, n_items as i64),
        };
        wcs_item_sk.push(item_sk);
        wcs_user_sk.push(rng.i64_range(0, n_cust as i64));
        wcs_click_date_sk.push(rng.i64_range(DATE_MIN, DATE_MAX));
    }
    let web_clickstream = Table::from_pairs(vec![
        ("wcs_item_sk", Column::I64(wcs_item_sk)),
        ("wcs_user_sk", Column::I64(wcs_user_sk)),
        ("wcs_click_date_sk", Column::I64(wcs_click_date_sk)),
    ])
    .expect("web_clickstream table");

    BbTables {
        store_sales,
        web_sales,
        web_clickstream,
        item,
        customer,
        customer_demographics,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_generation() {
        let a = generate(&GenOptions::default());
        let b = generate(&GenOptions::default());
        assert_eq!(a.store_sales, b.store_sales);
        assert_eq!(a.item, b.item);
    }

    #[test]
    fn cardinalities_scale() {
        let small = generate(&GenOptions {
            scale_factor: 0.5,
            ..Default::default()
        });
        let big = generate(&GenOptions {
            scale_factor: 2.0,
            ..Default::default()
        });
        assert!(big.store_sales.num_rows() > 3 * small.store_sales.num_rows());
        assert!(big.item.num_rows() >= small.item.num_rows());
    }

    #[test]
    fn referential_integrity() {
        let db = generate(&GenOptions::default());
        let n_items = db.item.num_rows() as i64;
        let n_cust = db.customer.num_rows() as i64;
        assert!(db
            .store_sales
            .column("ss_item_sk")
            .unwrap()
            .as_i64()
            .iter()
            .all(|&k| (0..n_items).contains(&k)));
        assert!(db
            .web_clickstream
            .column("wcs_user_sk")
            .unwrap()
            .as_i64()
            .iter()
            .all(|&k| (0..n_cust).contains(&k)));
        // demographics keys match customer fk
        assert!(db
            .customer
            .column("c_current_cdemo_sk")
            .unwrap()
            .as_i64()
            .iter()
            .all(|&k| (0..n_cust).contains(&k)));
    }

    #[test]
    fn skew_concentrates_clicks() {
        let uniform = generate(&GenOptions::default());
        let skewed = generate(&GenOptions {
            click_skew: 1.5,
            ..Default::default()
        });
        let count_top = |t: &Table| {
            let keys = t.column("wcs_item_sk").unwrap().as_i64();
            keys.iter().filter(|&&k| k == 0).count()
        };
        assert!(count_top(&skewed.web_clickstream) > 10 * count_top(&uniform.web_clickstream));
    }
}
