//! TPCx-BB-style Q01 — top spenders per category, the workload the
//! incremental subsystem (DESIGN.md §4.9) targets: a dashboard-style
//! standing query over a ticking fact table. Web sales arrive in
//! micro-batches; after every tick the dashboard wants the current top
//! [`TOP_K`] `(item, customer)` pairs of each category by revenue.
//!
//! Shape:
//! 1. aggregate `web_sales` per `(ws_item_sk, ws_bill_customer_sk)` —
//!    order count `n` and revenue `rev = sum(ws_net_paid)`;
//! 2. left-join the `item` dimension for `i_category`;
//! 3. window: partition by category, order by `(rev desc, keys asc)`,
//!    `rank()`;
//! 4. keep rank ≤ [`TOP_K`].
//!
//! [`hiframes_query`] runs it as one batch collect; [`standing_query`]
//! drives the same plan through a [`Session`], pushing the fact table in
//! `n_ticks` micro-batches. The aggregate is the only stateful node — the
//! join and window replay over its (dimension-sized) output — so per-tick
//! work tracks the delta, not the accumulated history.

use super::BbTables;
use crate::baseline::serial;
use crate::expr::{col, lit, AggExpr, AggFn};
use crate::frame::{DataFrame, HiFrames};
use crate::ir::{SortOrder, WindowAgg, WindowFrame, WindowFunc};
use crate::stream::{Session, TickReport};
use crate::table::Table;
use crate::types::JoinType;
use anyhow::Result;

/// `(item, customer)` pairs kept per category.
pub const TOP_K: i64 = 5;

/// The standing plan over whatever `web_sales` rows the source holds.
fn plan(hf: &HiFrames, db: &BbTables, web_sales: Table) -> DataFrame {
    let ws = hf.table("web_sales", web_sales);
    let item = hf.table("item", db.item.clone());
    ws.group_by(&["ws_item_sk", "ws_bill_customer_sk"])
        .agg("n", AggFn::Count, col("ws_net_paid"))
        .agg("rev", AggFn::Sum, col("ws_net_paid"))
        .build()
        .join_on(&item, &[("ws_item_sk", "i_item_sk")], JoinType::Left)
        .window()
        .partition_by(&["i_category"])
        .order_by(&[
            ("rev", SortOrder::Desc),
            ("ws_item_sk", SortOrder::Asc),
            ("ws_bill_customer_sk", SortOrder::Asc),
        ])
        .rank("r")
        .build()
        .filter(col("r").le(lit(TOP_K)))
}

/// HiFrames implementation, one batch collect over the whole fact table.
pub fn hiframes_query(hf: &HiFrames, db: &BbTables) -> DataFrame {
    plan(hf, db, db.web_sales.clone())
}

/// The same query as a standing [`Session`]: seed with an empty fact
/// table, push `web_sales` in `n_ticks` micro-batches, tick after each.
/// Returns the final output — byte-identical to
/// `hiframes_query(...).collect()` — and the per-tick reports.
pub fn standing_query(
    hf: &HiFrames,
    db: &BbTables,
    n_ticks: usize,
) -> Result<(Table, Vec<TickReport>)> {
    let mut session = standing_session(hf, db)?;
    let total = db.web_sales.num_rows();
    let chunk = total.div_ceil(n_ticks.max(1));
    let mut out = session.tick()?; // tick 0: empty dashboard
    let mut start = 0;
    while start < total {
        let len = chunk.min(total - start);
        session.push("web_sales", db.web_sales.slice(start, len))?;
        start += len;
        out = session.tick()?;
    }
    Ok((out, session.reports().to_vec()))
}

/// The standing-query session itself (empty fact table; caller pushes).
pub fn standing_session(hf: &HiFrames, db: &BbTables) -> Result<Session> {
    let seed = Table::empty(db.web_sales.schema().clone());
    hf.session(&plan(hf, db, seed))
}

/// The serial (Pandas-like) oracle for the batch query.
pub fn serial_query(db: &BbTables) -> Result<Table> {
    let agg = serial::aggregate_by(
        &db.web_sales,
        &["ws_item_sk", "ws_bill_customer_sk"],
        &[
            AggExpr::new("n", AggFn::Count, col("ws_net_paid")),
            AggExpr::new("rev", AggFn::Sum, col("ws_net_paid")),
        ],
    )?;
    let joined =
        serial::join_on(&agg, &db.item, &[("ws_item_sk", "i_item_sk")], JoinType::Left)?;
    let win = serial::window(
        &joined,
        &["i_category"],
        &[
            ("rev", SortOrder::Desc),
            ("ws_item_sk", SortOrder::Asc),
            ("ws_bill_customer_sk", SortOrder::Asc),
        ],
        &[WindowAgg::new(
            "r",
            WindowFunc::Rank,
            WindowFrame::CumulativeToCurrent,
            lit(0i64),
        )],
    )?;
    serial::filter(&win, &col("r").le(lit(TOP_K)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bigbench::{generate, GenOptions};
    use crate::exec::ExecOptions;
    use crate::ops::aggregate::AggStrategy;
    use crate::passes::PassOptions;
    use crate::types::SortOrder;

    fn db() -> BbTables {
        generate(&GenOptions {
            scale_factor: 0.02,
            ..Default::default()
        })
    }

    /// Context matching the knobs a [`Session`] forces, so batch collects
    /// in it are byte-comparable with ticked output.
    fn ctx(workers: usize) -> HiFrames {
        HiFrames::new(ExecOptions {
            workers,
            agg_strategy: AggStrategy::RawShuffle,
            mem_budget: None,
            profile: false,
            passes: PassOptions {
                skew_join: false,
                ..Default::default()
            },
        })
    }

    const SORT: [(&str, SortOrder); 3] = [
        ("i_category", SortOrder::Asc),
        ("r", SortOrder::Asc),
        ("ws_item_sk", SortOrder::Asc),
    ];

    #[test]
    fn ticked_standing_query_matches_batch() {
        let db = db();
        for workers in [2usize, 3] {
            let hf = ctx(workers);
            let batch = hiframes_query(&hf, &db).collect().unwrap();
            assert!(batch.num_rows() > 0);
            let (ticked, reports) = standing_query(&hf, &db, 7).unwrap();
            assert_eq!(batch.schema().names(), ticked.schema().names());
            for i in 0..batch.num_cols() {
                assert_eq!(
                    batch.column_at(i),
                    ticked.column_at(i),
                    "workers={workers} column {i}"
                );
                assert_eq!(
                    batch.mask_at(i),
                    ticked.mask_at(i),
                    "workers={workers} mask {i}"
                );
            }
            // the aggregate keeps state: later ticks must avoid re-folding
            let last = reports.last().unwrap();
            assert!(!last.fallback, "q01 must not fall back");
            assert!(
                last.rows_avoided > 0,
                "workers={workers}: no rows avoided: {last:?}"
            );
        }
    }

    #[test]
    fn hiframes_matches_serial() {
        let db = db();
        let expect = serial_query(&db).unwrap().sorted_by_keys(&SORT).unwrap();
        assert!(expect.num_rows() > 0);
        let hf = ctx(3);
        let got = hiframes_query(&hf, &db)
            .collect()
            .unwrap()
            .sorted_by_keys(&SORT)
            .unwrap();
        assert_eq!(got.num_rows(), expect.num_rows());
        for c in ["i_category", "ws_item_sk", "ws_bill_customer_sk", "n", "rev", "r"] {
            assert_eq!(got.column(c).unwrap(), expect.column(c).unwrap(), "column {c}");
            assert_eq!(got.mask(c), expect.mask(c), "mask {c}");
        }
        let ranks = got.column("r").unwrap().as_i64();
        assert!(ranks.iter().all(|&r| r >= 1 && r <= TOP_K));
    }
}
