//! The user-facing HiFrames API (paper §3, Table 1).
//!
//! | Paper syntax                               | Here                                   |
//! |--------------------------------------------|----------------------------------------|
//! | `DataSource(DataFrame{...}, HDF5, file)`   | [`HiFrames::read_hfs`]                 |
//! | `v = df[:id]` (projection)                 | [`DataFrame::select`]                  |
//! | `df2 = df[:id < 100]`                      | [`DataFrame::filter`]                  |
//! | `join(df1, df2, :id == :cid)`              | [`DataFrame::join`]                    |
//! | `aggregate(df, :id, :xc = sum(:x < 1.0))`  | [`DataFrame::aggregate`]               |
//! | `[df1; df2]`                               | [`DataFrame::concat`]                  |
//! | `cumsum(df[:x])`                           | [`DataFrame::cumsum`]                  |
//! | `stencil(x -> …, df[:x])` (SMA/WMA)        | [`DataFrame::stencil`] / [`sma`] / [`wma`] |
//! | `df[:id3] = (…)/var(…)` (array compute)    | [`DataFrame::with_column`]             |
//! | `transpose(typed_hcat(Float64, …))`        | [`DataFrame::matrix_assembly`]         |
//! | `HPAT.Kmeans(samples, k)`                  | [`DataFrame::kmeans`]                  |
//!
//! A `DataFrame` is a lazy logical plan; [`DataFrame::collect`] compiles it
//! through the full pass pipeline and runs it SPMD. Scalar helpers
//! ([`DataFrame::mean`], [`DataFrame::var`]) mirror the paper's feature
//! scaling idiom.

use crate::exec::{collect, ExecOptions};
use crate::expr::{AggExpr, Expr};
use crate::ir::{source_hfs, source_mem, MlParams, Plan};
use crate::ops::stencil::{sma_weights, wma_weights_124};
use crate::table::{Schema, Table};
use anyhow::Result;
use std::path::Path;
use std::sync::Arc;

/// The HiFrames context: execution options shared by the frames it creates.
#[derive(Clone)]
pub struct HiFrames {
    opts: Arc<ExecOptions>,
}

impl Default for HiFrames {
    fn default() -> Self {
        HiFrames::new(ExecOptions::default())
    }
}

impl HiFrames {
    pub fn new(opts: ExecOptions) -> HiFrames {
        HiFrames {
            opts: Arc::new(opts),
        }
    }

    /// Context with `workers` ranks and default optimizations.
    pub fn with_workers(workers: usize) -> HiFrames {
        HiFrames::new(ExecOptions {
            workers,
            ..Default::default()
        })
    }

    pub fn options(&self) -> &ExecOptions {
        &self.opts
    }

    /// Wrap an in-memory table as a data frame source.
    pub fn table(&self, name: &str, table: Table) -> DataFrame {
        DataFrame {
            ctx: self.clone(),
            plan: source_mem(name, table),
        }
    }

    /// Read a data frame from an HFS file (schema comes from the file;
    /// the `DataSource` construct of §3.1).
    pub fn read_hfs(&self, name: &str, path: &Path) -> Result<DataFrame> {
        let (schema, _) = crate::io::read_hfs_schema(path)?;
        Ok(DataFrame {
            ctx: self.clone(),
            plan: source_hfs(name, path.to_path_buf(), schema),
        })
    }

    /// Read with an explicit expected schema (checked against the file) —
    /// the typed `DataSource(DataFrame{:id=Int64,…})` form.
    pub fn read_hfs_typed(&self, name: &str, path: &Path, schema: Schema) -> Result<DataFrame> {
        let (actual, _) = crate::io::read_hfs_schema(path)?;
        if !actual.same_as(&schema) {
            anyhow::bail!("schema mismatch: file has {actual}, declared {schema}");
        }
        Ok(DataFrame {
            ctx: self.clone(),
            plan: source_hfs(name, path.to_path_buf(), schema),
        })
    }
}

/// A lazy, typed, distributed data frame.
#[derive(Clone)]
pub struct DataFrame {
    ctx: HiFrames,
    plan: Plan,
}

impl DataFrame {
    /// The underlying logical plan (inspection / tests).
    pub fn plan(&self) -> &Plan {
        &self.plan
    }

    /// Output schema (type inference runs eagerly, like the Macro-Pass).
    pub fn schema(&self) -> Result<Schema> {
        self.plan.schema()
    }

    fn wrap(&self, plan: Plan) -> DataFrame {
        DataFrame {
            ctx: self.ctx.clone(),
            plan,
        }
    }

    /// `df[pred]`.
    pub fn filter(&self, predicate: Expr) -> DataFrame {
        self.wrap(Plan::Filter {
            input: Box::new(self.plan.clone()),
            predicate,
        })
    }

    /// Projection: keep the listed columns.
    pub fn select(&self, columns: &[&str]) -> DataFrame {
        self.wrap(Plan::Project {
            input: Box::new(self.plan.clone()),
            columns: columns.iter().map(|s| s.to_string()).collect(),
        })
    }

    /// `df[:name] = expr` — array computation over columns.
    pub fn with_column(&self, name: &str, expr: Expr) -> DataFrame {
        self.wrap(Plan::WithColumn {
            input: Box::new(self.plan.clone()),
            name: name.to_string(),
            expr,
        })
    }

    /// `rename!(df, :from, :to)`.
    pub fn rename(&self, from: &str, to: &str) -> DataFrame {
        self.wrap(Plan::Rename {
            input: Box::new(self.plan.clone()),
            from: from.to_string(),
            to: to.to_string(),
        })
    }

    /// `join(self, other, :lk == :rk)` — inner equi-join; unlike Julia's
    /// DataFrames.jl the two key columns may have different names (§3.1).
    pub fn join(&self, other: &DataFrame, left_key: &str, right_key: &str) -> DataFrame {
        self.wrap(Plan::Join {
            left: Box::new(self.plan.clone()),
            right: Box::new(other.plan.clone()),
            left_key: left_key.to_string(),
            right_key: right_key.to_string(),
        })
    }

    /// `aggregate(df, :key, :out = fn(expr), …)`.
    pub fn aggregate(&self, key: &str, aggs: Vec<AggExpr>) -> DataFrame {
        self.wrap(Plan::Aggregate {
            input: Box::new(self.plan.clone()),
            key: key.to_string(),
            aggs,
        })
    }

    /// `[self; other]`.
    pub fn concat(&self, other: &DataFrame) -> DataFrame {
        self.wrap(Plan::Concat {
            inputs: vec![Box::new(self.plan.clone()), Box::new(other.plan.clone())],
        })
    }

    /// `df[:out] = cumsum(df[:col])`.
    pub fn cumsum(&self, column: &str, out: &str) -> DataFrame {
        self.wrap(Plan::Cumsum {
            input: Box::new(self.plan.clone()),
            column: column.to_string(),
            out: out.to_string(),
        })
    }

    /// General 1-D stencil with explicit weights.
    pub fn stencil(&self, column: &str, out: &str, weights: Vec<f64>) -> DataFrame {
        self.wrap(Plan::Stencil {
            input: Box::new(self.plan.clone()),
            column: column.to_string(),
            out: out.to_string(),
            weights,
        })
    }

    /// Simple moving average of window `w` (`stencil(x->(x[-1]+x[0]+x[1])/3)`).
    pub fn sma(&self, column: &str, out: &str, window: usize) -> DataFrame {
        self.stencil(column, out, sma_weights(window))
    }

    /// The paper's weighted moving average `(x[-1]+2x[0]+x[1])/4`.
    pub fn wma(&self, column: &str, out: &str) -> DataFrame {
        self.stencil(column, out, wma_weights_124())
    }

    /// Global sort by an Int64 column.
    pub fn sort_by(&self, key: &str) -> DataFrame {
        self.wrap(Plan::Sort {
            input: Box::new(self.plan.clone()),
            key: key.to_string(),
        })
    }

    /// `samples = transpose(typed_hcat(Float64, cols…))` — assemble the ML
    /// feature matrix (pattern-matched into one node, §4.2).
    pub fn matrix_assembly(&self, columns: &[&str]) -> DataFrame {
        self.wrap(Plan::MatrixAssembly {
            input: Box::new(self.plan.clone()),
            columns: columns.iter().map(|s| s.to_string()).collect(),
        })
    }

    /// `HPAT.Kmeans(samples, k)` over the assembled matrix.
    pub fn kmeans(&self, k: usize, iters: usize, use_pjrt: bool) -> DataFrame {
        self.wrap(Plan::MlCall {
            input: Box::new(self.plan.clone()),
            params: MlParams {
                model: "kmeans".to_string(),
                k,
                iters,
                use_pjrt,
            },
        })
    }

    /// Compile (all passes) + SPMD execute + gather on the leader.
    pub fn collect(&self) -> Result<Table> {
        collect(self.plan.clone(), &self.ctx.opts)
    }

    /// Scalar mean of a column (the paper's `mean(c_i_points[:id3])` —
    /// computed distributed via aggregate-to-scalar).
    pub fn mean(&self, column: &str) -> Result<f64> {
        let t = self
            .with_column("__one", crate::expr::lit(0i64))
            .aggregate(
                "__one",
                vec![AggExpr::new(
                    "m",
                    crate::expr::AggFn::Mean,
                    crate::expr::col(column),
                )],
            )
            .collect()?;
        Ok(t.column("m").unwrap().as_f64()[0])
    }

    /// Scalar population variance of a column.
    pub fn var(&self, column: &str) -> Result<f64> {
        let t = self
            .with_column("__one", crate::expr::lit(0i64))
            .aggregate(
                "__one",
                vec![AggExpr::new(
                    "v",
                    crate::expr::AggFn::Var,
                    crate::expr::col(column),
                )],
            )
            .collect()?;
        Ok(t.column("v").unwrap().as_f64()[0])
    }

    /// Row count (distributed execute + sum of local counts; no driver
    /// gather of the data itself).
    pub fn count(&self) -> Result<usize> {
        crate::exec::collect_count(self.plan.clone(), &self.ctx.opts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::column::Column;
    use crate::expr::{col, lit, AggFn};

    fn ctx() -> HiFrames {
        HiFrames::with_workers(3)
    }

    fn df(hf: &HiFrames) -> DataFrame {
        hf.table(
            "t",
            Table::from_pairs(vec![
                ("id", Column::I64(vec![1, 2, 1, 3, 2, 1])),
                ("x", Column::F64(vec![0.5, 1.5, 2.5, 3.5, 4.5, 5.5])),
            ])
            .unwrap(),
        )
    }

    #[test]
    fn filter_select_collect() {
        let hf = ctx();
        let out = df(&hf)
            .filter(col("x").gt(lit(2.0)))
            .select(&["id"])
            .collect()
            .unwrap();
        assert_eq!(out.column("id").unwrap().as_i64(), &[1, 3, 2, 1]);
    }

    #[test]
    fn aggregate_table1_style() {
        // Table 1: df2 = aggregate(df1, :id, :xc = sum(:x<1.0), :ym = mean(:y))
        let hf = ctx();
        let out = df(&hf)
            .aggregate(
                "id",
                vec![
                    AggExpr::new("xc", AggFn::Sum, col("x").lt(lit(3.0))),
                    AggExpr::new("ym", AggFn::Mean, col("x")),
                ],
            )
            .sort_by("id")
            .collect()
            .unwrap();
        assert_eq!(out.column("id").unwrap().as_i64(), &[1, 2, 3]);
        assert_eq!(out.column("xc").unwrap().as_i64(), &[2, 1, 0]);
        let ym = out.column("ym").unwrap().as_f64();
        assert!((ym[0] - (0.5 + 2.5 + 5.5) / 3.0).abs() < 1e-9);
    }

    #[test]
    fn join_with_rename() {
        let hf = ctx();
        let other = hf.table(
            "r",
            Table::from_pairs(vec![
                ("cid", Column::I64(vec![1, 2])),
                ("w", Column::F64(vec![10.0, 20.0])),
            ])
            .unwrap(),
        );
        let out = df(&hf)
            .join(&other, "id", "cid")
            .sort_by("id")
            .collect()
            .unwrap();
        assert_eq!(out.num_rows(), 5); // ids 1,1,1,2,2
        assert_eq!(out.schema().names(), vec!["id", "x", "w"]);
    }

    #[test]
    fn concat_and_count() {
        let hf = ctx();
        let d = df(&hf);
        let c = d.concat(&d);
        assert_eq!(c.count().unwrap(), 12);
    }

    #[test]
    fn scalar_mean_var() {
        let hf = ctx();
        let m = df(&hf).mean("x").unwrap();
        assert!((m - 3.0).abs() < 1e-9);
        let v = df(&hf).var("x").unwrap();
        // population variance of 0.5..5.5 step1 = 35/12
        assert!((v - 35.0 / 12.0).abs() < 1e-9, "{v}");
    }

    #[test]
    fn cumsum_and_sma() {
        let hf = ctx();
        let out = df(&hf).cumsum("x", "cs").collect().unwrap();
        let cs = out.column("cs").unwrap().as_f64();
        assert!((cs[5] - 18.0).abs() < 1e-9);
        let out = df(&hf).sma("x", "sma", 3).collect().unwrap();
        let sma = out.column("sma").unwrap().as_f64();
        assert!((sma[1] - 1.5).abs() < 1e-9);
    }

    #[test]
    fn feature_scaling_pipeline() {
        // the paper's Q26 idiom: (col - mean) / var as array compute
        let hf = ctx();
        let d = df(&hf);
        let (m, v) = (d.mean("x").unwrap(), d.var("x").unwrap());
        let scaled = d.with_column("x", col("x").sub(lit(m)).div(lit(v)));
        let out = scaled.collect().unwrap();
        let xs = out.column("x").unwrap().as_f64();
        assert!((xs.iter().sum::<f64>()).abs() < 1e-9); // centered
    }

    #[test]
    fn kmeans_end_to_end_rust_kernel() {
        let hf = HiFrames::with_workers(2);
        let t = Table::from_pairs(vec![
            ("a", Column::F64(vec![0.0, 0.1, 10.0, 10.1, 0.05, 9.95])),
            ("b", Column::F64(vec![0.0, 0.1, 10.0, 10.1, 0.05, 9.95])),
        ])
        .unwrap();
        let out = hf
            .table("pts", t)
            .matrix_assembly(&["a", "b"])
            .kmeans(2, 10, false)
            .collect()
            .unwrap();
        assert_eq!(out.num_rows(), 2);
        assert_eq!(out.schema().names(), vec!["f0", "f1", "cluster"]);
        let f0 = out.column("f0").unwrap().as_f64();
        let mut c: Vec<f64> = f0.to_vec();
        c.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert!(c[0] < 1.0 && c[1] > 9.0);
    }

    #[test]
    fn schema_errors_surface_eagerly() {
        let hf = ctx();
        assert!(df(&hf).filter(col("nope").lt(lit(1.0))).schema().is_err());
        assert!(df(&hf).select(&["missing"]).schema().is_err());
    }
}
