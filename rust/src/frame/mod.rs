//! The user-facing HiFrames API (paper §3, Table 1), extended with the
//! composite-key relational surface real TPCx-BB workloads need.
//!
//! | Paper syntax                               | Here                                   |
//! |--------------------------------------------|----------------------------------------|
//! | `DataSource(DataFrame{...}, HDF5, file)`   | [`HiFrames::read_hfs`]                 |
//! | `v = df[:id]` (projection)                 | [`DataFrame::select`]                  |
//! | `df2 = df[:id < 100]`                      | [`DataFrame::filter`]                  |
//! | `join(df1, df2, :id == :cid)`              | [`DataFrame::join`] (inner, one key)   |
//! | `join(df1, df2, [:a==:b, :c==:d], how)`    | [`DataFrame::join_on`] / [`DataFrame::join_with`] (builder) |
//! | `aggregate(df, :id, :xc = sum(:x < 1.0))`  | [`DataFrame::aggregate`]               |
//! | `aggregate(df, [:k1,:k2], …)`              | [`DataFrame::aggregate_by`] / [`DataFrame::group_by`] (builder) |
//! | `sort(df, [:k1 desc, :k2])`                | [`DataFrame::sort_by_keys`] ([`DataFrame::sort_by`] = one key asc) |
//! | `[df1; df2]`                               | [`DataFrame::concat`]                  |
//! | `cumsum(df[:x])`                           | [`DataFrame::cumsum`] (wrapper over the window node) |
//! | `stencil(x -> …, df[:x])` (SMA/WMA)        | [`DataFrame::stencil`] / [`sma`] / [`wma`] (wrappers) |
//! | window functions / `OVER (PARTITION BY …)` | [`DataFrame::window`] (builder) / [`DataFrame::with_window`] |
//! | `df[:id3] = (…)/var(…)` (array compute)    | [`DataFrame::with_column`] / [`DataFrame::with_columns`] |
//! | `transpose(typed_hcat(Float64, …))`        | [`DataFrame::matrix_assembly`]         |
//! | `HPAT.Kmeans(samples, k)`                  | [`DataFrame::kmeans`]                  |
//!
//! Join types follow [`JoinType`]: `Inner`, `Left`, `Right`, `Outer`,
//! `Semi`, `Anti`. Null-introduced columns of outer joins keep their native
//! dtype and become *nullable* (validity-mask null model); inspect and
//! repair nulls with [`DataFrame::is_null`], [`DataFrame::fill_null`] and
//! [`DataFrame::drop_null`].
//!
//! Joins additionally carry a physical [`JoinStrategy`]: the optimizer
//! auto-selects the skew-aware heavy-hitter broadcast path when source
//! statistics warrant it, and `df.join_with(&r).on(..).skew_hint(0.05)
//! .build()` forces it with an explicit frequency threshold (see
//! ARCHITECTURE.md and DESIGN.md §4.3).
//!
//! A `DataFrame` is a lazy logical plan; [`DataFrame::collect`] compiles it
//! through the full pass pipeline into a [`PlanGraph`](crate::ir::graph::PlanGraph)
//! and runs it SPMD. [`DataFrame::explain`] renders that optimized graph
//! one line per node; [`DataFrame::cache`] marks an explicit
//! materialization point whose result the context's [`PlanCache`] pins
//! across separate `collect()` calls. Scalar helpers ([`DataFrame::mean`],
//! [`DataFrame::var`]) mirror the paper's feature scaling idiom.

use crate::exec::{collect_cached, ExecOptions, PlanCache};
use crate::expr::{col, AggExpr, AggFn, Expr, WindowExpr};
use crate::ir::{
    source_hfs, source_mem, JoinStrategy, JoinType, MlParams, Plan, SortOrder, WindowAgg,
    WindowFrame, WindowFunc,
};
use crate::ops::stencil::{sma_weights, wma_weights_124};
use crate::table::{Schema, Table};
use crate::trace::QueryProfile;
use anyhow::Result;
use std::path::Path;
use std::sync::Arc;

/// The HiFrames context: execution options and the [`PlanCache`] shared by
/// the frames it creates.
#[derive(Clone)]
pub struct HiFrames {
    opts: Arc<ExecOptions>,
    cache: Arc<PlanCache>,
}

impl Default for HiFrames {
    fn default() -> Self {
        HiFrames::new(ExecOptions::default())
    }
}

impl HiFrames {
    /// Context with explicit [`ExecOptions`] (worker count, pass toggles,
    /// aggregation strategy).
    pub fn new(opts: ExecOptions) -> HiFrames {
        HiFrames {
            opts: Arc::new(opts),
            cache: Arc::new(PlanCache::new()),
        }
    }

    /// Context with `workers` ranks and default optimizations.
    pub fn with_workers(workers: usize) -> HiFrames {
        HiFrames::new(ExecOptions {
            workers,
            ..Default::default()
        })
    }

    /// The execution options shared by every frame of this context.
    pub fn options(&self) -> &ExecOptions {
        &self.opts
    }

    /// The context's [`PlanCache`]: results of [`DataFrame::cache`] points
    /// live here, pinned across separate `collect()` calls until
    /// [`PlanCache::clear`].
    pub fn plan_cache(&self) -> &PlanCache {
        &self.cache
    }

    /// Wrap an in-memory table as a data frame source.
    pub fn table(&self, name: &str, table: Table) -> DataFrame {
        DataFrame {
            ctx: self.clone(),
            plan: source_mem(name, table),
        }
    }

    /// Read a data frame from an HFS file (schema comes from the file;
    /// the `DataSource` construct of §3.1).
    pub fn read_hfs(&self, name: &str, path: &Path) -> Result<DataFrame> {
        let (schema, _) = crate::io::read_hfs_schema(path)?;
        Ok(DataFrame {
            ctx: self.clone(),
            plan: source_hfs(name, path.to_path_buf(), schema),
        })
    }

    /// Compile `df` into a standing query: a [`Session`](crate::stream::Session)
    /// keeps the optimized plan and per-rank operator state alive so that
    /// [`push`](crate::stream::Session::push)ed record batches flow through
    /// incrementally on every [`tick`](crate::stream::Session::tick)
    /// (DESIGN.md §4.9).
    pub fn session(&self, df: &DataFrame) -> Result<crate::stream::Session> {
        crate::stream::Session::new(df.plan().clone(), self.options().clone())
    }

    /// Read with an explicit expected schema (checked against the file) —
    /// the typed `DataSource(DataFrame{:id=Int64,…})` form.
    pub fn read_hfs_typed(&self, name: &str, path: &Path, schema: Schema) -> Result<DataFrame> {
        let (actual, _) = crate::io::read_hfs_schema(path)?;
        if !actual.same_as(&schema) {
            anyhow::bail!("schema mismatch: file has {actual}, declared {schema}");
        }
        Ok(DataFrame {
            ctx: self.clone(),
            plan: source_hfs(name, path.to_path_buf(), schema),
        })
    }
}

/// A lazy, typed, distributed data frame.
#[derive(Clone)]
pub struct DataFrame {
    ctx: HiFrames,
    plan: Plan,
}

impl DataFrame {
    /// The underlying logical plan (inspection / tests).
    pub fn plan(&self) -> &Plan {
        &self.plan
    }

    /// Output schema (type inference runs eagerly, like the Macro-Pass).
    pub fn schema(&self) -> Result<Schema> {
        self.plan.schema()
    }

    fn wrap(&self, plan: Plan) -> DataFrame {
        DataFrame {
            ctx: self.ctx.clone(),
            plan,
        }
    }

    /// `df[pred]`.
    pub fn filter(&self, predicate: Expr) -> DataFrame {
        self.wrap(Plan::Filter {
            input: Box::new(self.plan.clone()),
            predicate,
        })
    }

    /// Projection: keep the listed columns.
    pub fn select(&self, columns: &[&str]) -> DataFrame {
        self.wrap(Plan::Project {
            input: Box::new(self.plan.clone()),
            columns: columns.iter().map(|s| s.to_string()).collect(),
        })
    }

    /// `df[:name] = expr` — array computation over columns. Thin
    /// single-column wrapper over [`DataFrame::with_columns`].
    pub fn with_column(&self, name: &str, expr: Expr) -> DataFrame {
        self.with_columns(&[(name, expr)])
    }

    /// Batch array computation: add (or replace) several columns in one
    /// call, left to right, so later expressions can reference earlier
    /// outputs: `df.with_columns(&[("a", col("x").add(lit(1.0))),
    /// ("b", col("a").mul(lit(2.0)))])`.
    pub fn with_columns(&self, columns: &[(&str, Expr)]) -> DataFrame {
        let mut plan = self.plan.clone();
        for (name, expr) in columns {
            plan = Plan::WithColumn {
                input: Box::new(plan),
                name: name.to_string(),
                expr: expr.clone(),
            };
        }
        self.wrap(plan)
    }

    /// Append a Bool column `:<column>_is_null` marking the null rows of
    /// `column` (true = null). The probe side of `IS NULL` analyses.
    pub fn is_null(&self, column: &str) -> DataFrame {
        self.with_column(
            &format!("{column}_is_null"),
            crate::expr::col(column).is_null(),
        )
    }

    /// Replace the nulls of `column` with `value` in place; the column
    /// becomes non-nullable with its dtype unchanged.
    pub fn fill_null<V: Into<crate::types::Value>>(&self, column: &str, value: V) -> DataFrame {
        self.with_column(column, crate::expr::col(column).fill_null(value))
    }

    /// Keep only the rows where *every* listed column is non-null
    /// (Pandas `dropna(subset=...)`).
    pub fn drop_null(&self, columns: &[&str]) -> DataFrame {
        let mut pred: Option<Expr> = None;
        for c in columns {
            let p = crate::expr::col(c).is_not_null();
            pred = Some(match pred {
                Some(acc) => acc.and(p),
                None => p,
            });
        }
        match pred {
            Some(p) => self.filter(p),
            None => self.clone(),
        }
    }

    /// `rename!(df, :from, :to)`.
    pub fn rename(&self, from: &str, to: &str) -> DataFrame {
        self.wrap(Plan::Rename {
            input: Box::new(self.plan.clone()),
            from: from.to_string(),
            to: to.to_string(),
        })
    }

    /// `join(self, other, :lk == :rk)` — inner equi-join; unlike Julia's
    /// DataFrames.jl the two key columns may have different names (§3.1).
    /// Thin single-key wrapper over [`DataFrame::join_on`].
    pub fn join(&self, other: &DataFrame, left_key: &str, right_key: &str) -> DataFrame {
        self.join_on(other, &[(left_key, right_key)], JoinType::Inner)
    }

    /// Composite-key join with an explicit join type:
    /// `join_on(&other, &[("a","b"), ("c","d")], JoinType::Left)`. Output
    /// key columns keep the left names; Semi/Anti drop the right columns.
    pub fn join_on(
        &self,
        other: &DataFrame,
        on: &[(&str, &str)],
        how: JoinType,
    ) -> DataFrame {
        self.wrap(Plan::Join {
            left: Box::new(self.plan.clone()),
            right: Box::new(other.plan.clone()),
            on: on
                .iter()
                .map(|(l, r)| (l.to_string(), r.to_string()))
                .collect(),
            how,
            strategy: JoinStrategy::Hash,
        })
    }

    /// Fluent join entry point:
    /// `df.join_with(&other).on("a", "b").on("c", "d").how(JoinType::Left).build()`.
    pub fn join_with(&self, other: &DataFrame) -> JoinBuilder {
        JoinBuilder {
            ctx: self.ctx.clone(),
            left: self.plan.clone(),
            right: other.plan.clone(),
            on: Vec::new(),
            how: JoinType::Inner,
            strategy: JoinStrategy::Hash,
        }
    }

    /// `aggregate(df, :key, :out = fn(expr), …)` — thin single-key wrapper
    /// over [`DataFrame::aggregate_by`].
    pub fn aggregate(&self, key: &str, aggs: Vec<AggExpr>) -> DataFrame {
        self.aggregate_by(&[key], aggs)
    }

    /// Composite-key group-by: `aggregate_by(&["k1","k2"], aggs)`. The
    /// output carries one column per key (dtypes preserved) followed by the
    /// aggregate outputs.
    pub fn aggregate_by(&self, keys: &[&str], aggs: Vec<AggExpr>) -> DataFrame {
        self.wrap(Plan::Aggregate {
            input: Box::new(self.plan.clone()),
            keys: keys.iter().map(|k| k.to_string()).collect(),
            aggs,
        })
    }

    /// Fluent group-by entry point:
    /// `df.group_by(&["k1","k2"]).agg("n", AggFn::Count, col("x")).build()`.
    pub fn group_by(&self, keys: &[&str]) -> GroupBy {
        GroupBy {
            ctx: self.ctx.clone(),
            input: self.plan.clone(),
            keys: keys.iter().map(|k| k.to_string()).collect(),
            aggs: Vec::new(),
        }
    }

    /// `[self; other]`.
    pub fn concat(&self, other: &DataFrame) -> DataFrame {
        self.wrap(Plan::Concat {
            inputs: vec![Box::new(self.plan.clone()), Box::new(other.plan.clone())],
        })
    }

    /// Materialize one windowed expression as `:out` over a *global* window
    /// (rows in block order, no partitioning):
    /// `df.with_window("prev", col("x").shift(1))`,
    /// `df.with_window("cs", col("x").cum_sum())`.
    pub fn with_window(&self, out: &str, w: WindowExpr) -> DataFrame {
        self.wrap(Plan::Window {
            input: Box::new(self.plan.clone()),
            partition_by: vec![],
            order_by: vec![],
            aggs: vec![WindowAgg::new(out, w.func, w.frame, w.input)],
        })
    }

    /// Fluent window-function entry point (the SQL `OVER` clause):
    /// `df.window().partition_by(&["store"]).order_by(&[("sales",
    /// SortOrder::Desc)]).rank("r").build()`, or rolling frames via
    /// `.rolling(3).agg("s3", WindowFunc::Sum, col("x"))`. Without
    /// `partition_by` the window is global and runs in block row order.
    pub fn window(&self) -> WindowBuilder {
        WindowBuilder {
            ctx: self.ctx.clone(),
            input: self.plan.clone(),
            partition_by: Vec::new(),
            order_by: Vec::new(),
            frame: WindowFrame::CumulativeToCurrent,
            aggs: Vec::new(),
        }
    }

    /// `df[:out] = cumsum(df[:col])` — thin wrapper over the unified
    /// [`Plan::Window`] node (`cumulative` frame, `sum` function); kept for
    /// the paper's Table 1 surface.
    pub fn cumsum(&self, column: &str, out: &str) -> DataFrame {
        self.with_window(out, col(column).cum_sum())
    }

    /// General 1-D stencil with explicit weights — thin wrapper over the
    /// unified [`Plan::Window`] node (`rolling[r,r]` frame, `weighted`
    /// function with truncated-renormalized edges, bit-for-bit the
    /// historical stencil semantics).
    pub fn stencil(&self, column: &str, out: &str, weights: Vec<f64>) -> DataFrame {
        let r = weights.len() / 2;
        self.with_window(out, col(column).rolling(r, r, WindowFunc::Weighted(weights)))
    }

    /// Simple moving average of window `w` (`stencil(x->(x[-1]+x[0]+x[1])/3)`)
    /// — thin wrapper over [`DataFrame::stencil`].
    pub fn sma(&self, column: &str, out: &str, window: usize) -> DataFrame {
        self.stencil(column, out, sma_weights(window))
    }

    /// The paper's weighted moving average `(x[-1]+2x[0]+x[1])/4` — thin
    /// wrapper over [`DataFrame::stencil`].
    pub fn wma(&self, column: &str, out: &str) -> DataFrame {
        self.stencil(column, out, wma_weights_124())
    }

    /// Global sort by one key, ascending — thin wrapper over
    /// [`DataFrame::sort_by_keys`].
    pub fn sort_by(&self, key: &str) -> DataFrame {
        self.sort_by_keys(&[(key, SortOrder::Asc)])
    }

    /// Global sort by a composite key list with per-key directions:
    /// `sort_by_keys(&[("cnt", SortOrder::Desc), ("id", SortOrder::Asc)])`.
    pub fn sort_by_keys(&self, keys: &[(&str, SortOrder)]) -> DataFrame {
        self.wrap(Plan::Sort {
            input: Box::new(self.plan.clone()),
            keys: keys.iter().map(|(k, o)| (k.to_string(), *o)).collect(),
        })
    }

    /// `samples = transpose(typed_hcat(Float64, cols…))` — assemble the ML
    /// feature matrix (pattern-matched into one node, §4.2).
    pub fn matrix_assembly(&self, columns: &[&str]) -> DataFrame {
        self.wrap(Plan::MatrixAssembly {
            input: Box::new(self.plan.clone()),
            columns: columns.iter().map(|s| s.to_string()).collect(),
        })
    }

    /// `HPAT.Kmeans(samples, k)` over the assembled matrix.
    pub fn kmeans(&self, k: usize, iters: usize, use_pjrt: bool) -> DataFrame {
        self.wrap(Plan::MlCall {
            input: Box::new(self.plan.clone()),
            params: MlParams {
                model: "kmeans".to_string(),
                k,
                iters,
                use_pjrt,
            },
        })
    }

    /// Mark an explicit materialization point: the subplan below executes
    /// at most once per context — its gathered result is published into the
    /// context's [`PlanCache`] on the first `collect()` touching it, and
    /// every later `collect()` (of this frame or any other sharing the
    /// subplan) is served from the cache. A no-op for semantics: the cache
    /// node changes neither schema nor rows.
    pub fn cache(&self) -> DataFrame {
        self.wrap(Plan::Cache {
            input: Box::new(self.plan.clone()),
        })
    }

    /// Render the *optimized* plan graph this frame would execute: one line
    /// per node in execution order, `[shared]` on hash-consed multi-consumer
    /// nodes, the selected join strategies, and `[spill]` on operators that
    /// can go out-of-core when a memory budget is active. Output is stable
    /// for a given plan and options. Planning errors render as a one-line
    /// `explain error: …` instead of panicking.
    pub fn explain(&self) -> String {
        let budgeted = matches!(self.ctx.opts.mem_budget, Some(b) if b > 0);
        match crate::passes::optimize_graph(self.plan.clone(), &self.ctx.opts.passes) {
            Ok(g) => g.render(budgeted),
            Err(e) => format!("explain error: {e}"),
        }
    }

    /// Run the query with profiling on and render the executed graph with
    /// per-node runtime annotations: max wall time over ranks, rows in/out,
    /// shuffle and spill bytes, and the per-rank imbalance factor
    /// (max/mean wall time — `SKEW`-flagged above
    /// [`crate::trace::SKEW_IMBALANCE`]), plus a run-summary footer. The
    /// line structure is byte-stable for a plan + options; only the time
    /// and imbalance values vary run to run.
    pub fn explain_analyze(&self) -> Result<String> {
        Ok(self.collect_profiled()?.1.render())
    }

    /// Compile (all passes) + SPMD execute + gather on the leader.
    /// [`DataFrame::cache`] points are looked up in (and published to) the
    /// context's [`PlanCache`].
    pub fn collect(&self) -> Result<Table> {
        Ok(collect_cached(self.plan.clone(), &self.ctx.opts, &self.ctx.cache)?.0)
    }

    /// [`DataFrame::collect`] with profiling forced on: also returns the
    /// run's [`QueryProfile`] (per-node/per-rank wall time, rows, shuffle/
    /// spill bytes, collective time, reuse and cache hits). The table is
    /// byte-identical to an unprofiled `collect()`. See DESIGN.md §4.7.
    pub fn collect_profiled(&self) -> Result<(Table, QueryProfile)> {
        let (table, _, prof) = crate::exec::collect_cached_profiled(
            self.plan.clone(),
            &self.ctx.opts,
            &self.ctx.cache,
        )?;
        Ok((table, prof))
    }

    /// Scalar mean of a column (the paper's `mean(c_i_points[:id3])` —
    /// computed distributed via aggregate-to-scalar).
    pub fn mean(&self, column: &str) -> Result<f64> {
        let t = self
            .with_column("__one", crate::expr::lit(0i64))
            .aggregate(
                "__one",
                vec![AggExpr::new(
                    "m",
                    crate::expr::AggFn::Mean,
                    crate::expr::col(column),
                )],
            )
            .collect()?;
        Ok(t.column("m").unwrap().as_f64()[0])
    }

    /// Scalar population variance of a column.
    pub fn var(&self, column: &str) -> Result<f64> {
        let t = self
            .with_column("__one", crate::expr::lit(0i64))
            .aggregate(
                "__one",
                vec![AggExpr::new(
                    "v",
                    crate::expr::AggFn::Var,
                    crate::expr::col(column),
                )],
            )
            .collect()?;
        Ok(t.column("v").unwrap().as_f64()[0])
    }

    /// Row count (distributed execute + sum of local counts; no driver
    /// gather of the data itself).
    pub fn count(&self) -> Result<usize> {
        crate::exec::collect_count(self.plan.clone(), &self.ctx.opts)
    }
}

/// Fluent builder for composite-key joins (created by
/// [`DataFrame::join_with`]). Accumulates `on` pairs and a [`JoinType`],
/// then [`JoinBuilder::build`] yields the lazy joined frame.
pub struct JoinBuilder {
    ctx: HiFrames,
    left: Plan,
    right: Plan,
    on: Vec<(String, String)>,
    how: JoinType,
    strategy: JoinStrategy,
}

impl JoinBuilder {
    /// Add one `left == right` key pair.
    pub fn on(mut self, left_key: &str, right_key: &str) -> JoinBuilder {
        self.on.push((left_key.to_string(), right_key.to_string()));
        self
    }

    /// Set the join type (default [`JoinType::Inner`]).
    pub fn how(mut self, how: JoinType) -> JoinBuilder {
        self.how = how;
        self
    }

    /// Force the skew-aware broadcast path: keys whose global frequency
    /// share reaches `threshold` (a fraction, clamped to `[0.001, 1.0]`)
    /// are detected by the runtime sampling pass and joined via
    /// broadcast/replication instead of the hash shuffle. Overrides the
    /// planner's automatic selection; the output relation is identical
    /// either way.
    pub fn skew_hint(mut self, threshold: f64) -> JoinBuilder {
        self.strategy = JoinStrategy::skew_with_threshold(threshold);
        self
    }

    /// Set the physical [`JoinStrategy`] explicitly (default
    /// [`JoinStrategy::Hash`], which the optimizer may upgrade when source
    /// statistics show skew).
    pub fn strategy(mut self, strategy: JoinStrategy) -> JoinBuilder {
        self.strategy = strategy;
        self
    }

    /// Finish: produce the lazy joined [`DataFrame`]. Key-pair validation
    /// (non-empty, matching groupable dtypes) happens at schema time, like
    /// every other plan error.
    pub fn build(self) -> DataFrame {
        DataFrame {
            ctx: self.ctx,
            plan: Plan::Join {
                left: Box::new(self.left),
                right: Box::new(self.right),
                on: self.on,
                how: self.how,
                strategy: self.strategy,
            },
        }
    }
}

/// Fluent builder for composite-key group-bys (created by
/// [`DataFrame::group_by`]). Accumulates aggregate outputs, then
/// [`GroupBy::build`] yields the lazy aggregated frame.
pub struct GroupBy {
    ctx: HiFrames,
    input: Plan,
    keys: Vec<String>,
    aggs: Vec<AggExpr>,
}

impl GroupBy {
    /// Add one output column `:out = func(expr)`.
    pub fn agg(mut self, out: &str, func: AggFn, input: Expr) -> GroupBy {
        self.aggs.push(AggExpr::new(out, func, input));
        self
    }

    /// Finish: produce the lazy aggregated [`DataFrame`].
    pub fn build(self) -> DataFrame {
        DataFrame {
            ctx: self.ctx,
            plan: Plan::Aggregate {
                input: Box::new(self.input),
                keys: self.keys,
                aggs: self.aggs,
            },
        }
    }
}

/// Fluent builder for window functions (created by [`DataFrame::window`]) —
/// the SQL `OVER (PARTITION BY … ORDER BY … ROWS …)` clause as a builder.
///
/// Frame setters ([`WindowBuilder::rolling`], [`WindowBuilder::cumulative`],
/// [`WindowBuilder::shift`]) set the *current* frame; each subsequent
/// [`WindowBuilder::agg`] uses it, so several frames can coexist in one
/// window node. [`WindowBuilder::agg_expr`] takes a self-contained
/// [`WindowExpr`] (`col("x").lag(1)`, …) regardless of the current frame.
pub struct WindowBuilder {
    ctx: HiFrames,
    input: Plan,
    partition_by: Vec<String>,
    order_by: Vec<(String, SortOrder)>,
    frame: WindowFrame,
    aggs: Vec<WindowAgg>,
}

impl WindowBuilder {
    /// Colocate rows by these keys; every frame stays inside its partition.
    /// Without this the window is *global* over the block row order.
    pub fn partition_by(mut self, keys: &[&str]) -> WindowBuilder {
        self.partition_by = keys.iter().map(|k| k.to_string()).collect();
        self
    }

    /// Order rows within each partition (requires `partition_by`; ties keep
    /// their incoming global row order — the sort is stable).
    pub fn order_by(mut self, keys: &[(&str, SortOrder)]) -> WindowBuilder {
        self.order_by = keys.iter().map(|(k, o)| (k.to_string(), *o)).collect();
        self
    }

    /// Trailing frame of `window` rows (`ROWS window-1 PRECEDING ..
    /// CURRENT ROW`) for the following `agg` calls.
    pub fn rolling(mut self, window: usize) -> WindowBuilder {
        self.frame = WindowFrame::Rolling {
            preceding: window.saturating_sub(1),
            following: 0,
        };
        self
    }

    /// General frame `ROWS preceding PRECEDING .. following FOLLOWING`.
    pub fn rolling_between(mut self, preceding: usize, following: usize) -> WindowBuilder {
        self.frame = WindowFrame::Rolling {
            preceding,
            following,
        };
        self
    }

    /// Running frame `ROWS UNBOUNDED PRECEDING .. CURRENT ROW` (the
    /// default).
    pub fn cumulative(mut self) -> WindowBuilder {
        self.frame = WindowFrame::CumulativeToCurrent;
        self
    }

    /// Single-row frame at `offset` back (positive = lag, negative = lead)
    /// for the following `agg` calls (use with [`WindowFunc::Value`]).
    pub fn shift(mut self, offset: i64) -> WindowBuilder {
        self.frame = WindowFrame::Shift(offset);
        self
    }

    /// Add `:out = func(input)` over the current frame.
    pub fn agg(mut self, out: &str, func: WindowFunc, input: Expr) -> WindowBuilder {
        self.aggs
            .push(WindowAgg::new(out, func, self.frame.clone(), input));
        self
    }

    /// Add a self-contained windowed expression (its own frame):
    /// `.agg_expr("prev", col("x").lag(1))`.
    pub fn agg_expr(mut self, out: &str, w: WindowExpr) -> WindowBuilder {
        self.aggs.push(WindowAgg::new(out, w.func, w.frame, w.input));
        self
    }

    /// Competition rank (1, 1, 3, …) of each row within its partition under
    /// the `order_by` keys.
    pub fn rank(mut self, out: &str) -> WindowBuilder {
        self.aggs.push(WindowAgg::new(
            out,
            WindowFunc::Rank,
            WindowFrame::CumulativeToCurrent,
            crate::expr::lit(0i64),
        ));
        self
    }

    /// 1-based position of each row within its partition (global row number
    /// for an un-partitioned window).
    pub fn row_number(mut self, out: &str) -> WindowBuilder {
        self.aggs.push(WindowAgg::new(
            out,
            WindowFunc::RowNumber,
            WindowFrame::CumulativeToCurrent,
            crate::expr::lit(0i64),
        ));
        self
    }

    /// Finish: produce the lazy windowed [`DataFrame`]. Frame/function
    /// validation happens at schema time, like every other plan error.
    pub fn build(self) -> DataFrame {
        DataFrame {
            ctx: self.ctx,
            plan: Plan::Window {
                input: Box::new(self.input),
                partition_by: self.partition_by,
                order_by: self.order_by,
                aggs: self.aggs,
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::column::Column;
    use crate::expr::{col, lit, AggFn};

    fn ctx() -> HiFrames {
        HiFrames::with_workers(3)
    }

    fn df(hf: &HiFrames) -> DataFrame {
        hf.table(
            "t",
            Table::from_pairs(vec![
                ("id", Column::I64(vec![1, 2, 1, 3, 2, 1])),
                ("x", Column::F64(vec![0.5, 1.5, 2.5, 3.5, 4.5, 5.5])),
            ])
            .unwrap(),
        )
    }

    #[test]
    fn filter_select_collect() {
        let hf = ctx();
        let out = df(&hf)
            .filter(col("x").gt(lit(2.0)))
            .select(&["id"])
            .collect()
            .unwrap();
        assert_eq!(out.column("id").unwrap().as_i64(), &[1, 3, 2, 1]);
    }

    #[test]
    fn aggregate_table1_style() {
        // Table 1: df2 = aggregate(df1, :id, :xc = sum(:x<1.0), :ym = mean(:y))
        let hf = ctx();
        let out = df(&hf)
            .aggregate(
                "id",
                vec![
                    AggExpr::new("xc", AggFn::Sum, col("x").lt(lit(3.0))),
                    AggExpr::new("ym", AggFn::Mean, col("x")),
                ],
            )
            .sort_by("id")
            .collect()
            .unwrap();
        assert_eq!(out.column("id").unwrap().as_i64(), &[1, 2, 3]);
        assert_eq!(out.column("xc").unwrap().as_i64(), &[2, 1, 0]);
        let ym = out.column("ym").unwrap().as_f64();
        assert!((ym[0] - (0.5 + 2.5 + 5.5) / 3.0).abs() < 1e-9);
    }

    #[test]
    fn join_with_rename() {
        let hf = ctx();
        let other = hf.table(
            "r",
            Table::from_pairs(vec![
                ("cid", Column::I64(vec![1, 2])),
                ("w", Column::F64(vec![10.0, 20.0])),
            ])
            .unwrap(),
        );
        let out = df(&hf)
            .join(&other, "id", "cid")
            .sort_by("id")
            .collect()
            .unwrap();
        assert_eq!(out.num_rows(), 5); // ids 1,1,1,2,2
        assert_eq!(out.schema().names(), vec!["id", "x", "w"]);
    }

    #[test]
    fn concat_and_count() {
        let hf = ctx();
        let d = df(&hf);
        let c = d.concat(&d);
        assert_eq!(c.count().unwrap(), 12);
    }

    #[test]
    fn scalar_mean_var() {
        let hf = ctx();
        let m = df(&hf).mean("x").unwrap();
        assert!((m - 3.0).abs() < 1e-9);
        let v = df(&hf).var("x").unwrap();
        // population variance of 0.5..5.5 step1 = 35/12
        assert!((v - 35.0 / 12.0).abs() < 1e-9, "{v}");
    }

    #[test]
    fn cumsum_and_sma() {
        let hf = ctx();
        let out = df(&hf).cumsum("x", "cs").collect().unwrap();
        let cs = out.column("cs").unwrap().as_f64();
        assert!((cs[5] - 18.0).abs() < 1e-9);
        let out = df(&hf).sma("x", "sma", 3).collect().unwrap();
        let sma = out.column("sma").unwrap().as_f64();
        assert!((sma[1] - 1.5).abs() < 1e-9);
    }

    #[test]
    fn window_sugar_shift_and_cum_sum() {
        let hf = ctx();
        // global lag: first row is NULL, values shift down by one
        let out = df(&hf)
            .with_window("prev", col("x").lag(1))
            .collect()
            .unwrap();
        assert_eq!(out.schema().nullable_of("prev"), Some(true));
        let prev = out.column("prev").unwrap().as_f64();
        let mask = out.mask("prev").unwrap();
        assert!(!mask.get(0));
        assert!((prev[1] - 0.5).abs() < 1e-12);
        assert!((prev[5] - 4.5).abs() < 1e-12);
        // cum_sum sugar matches the cumsum wrapper exactly
        let a = df(&hf).cumsum("x", "cs").collect().unwrap();
        let b = df(&hf)
            .with_window("cs", col("x").cum_sum())
            .collect()
            .unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn window_builder_partitioned_rank_and_rolling() {
        let hf = ctx();
        // order keys must be groupable (Int64 here — F64 order keys are
        // rejected at typing, like every other relational key)
        let t = hf.table(
            "t",
            Table::from_pairs(vec![
                ("id", Column::I64(vec![1, 2, 1, 3, 2, 1])),
                ("v", Column::I64(vec![5, 15, 25, 35, 45, 55])),
            ])
            .unwrap(),
        );
        let out = t
            .window()
            .partition_by(&["id"])
            .order_by(&[("v", SortOrder::Desc)])
            .rank("r")
            .rolling(2)
            .agg("s2", WindowFunc::Sum, col("v"))
            .build()
            // sorts are stable, so within each id the window's own v-desc
            // order survives the canonicalizing sort
            .sort_by("id")
            .collect()
            .unwrap();
        // id groups: 1 -> v [55, 25, 5], 2 -> [45, 15], 3 -> [35]
        assert_eq!(out.column("id").unwrap().as_i64(), &[1, 1, 1, 2, 2, 3]);
        assert_eq!(out.column("v").unwrap().as_i64(), &[55, 25, 5, 45, 15, 35]);
        assert_eq!(out.column("r").unwrap().as_i64(), &[1, 2, 3, 1, 2, 1]);
        // trailing window of 2 within the partition's desc order
        assert_eq!(
            out.column("s2").unwrap().as_i64(),
            &[55, 80, 30, 45, 60, 35]
        );
        // eager typing: order_by without partition_by is rejected, and so
        // are F64 order keys
        assert!(t
            .window()
            .order_by(&[("id", SortOrder::Asc)])
            .rank("r")
            .build()
            .schema()
            .is_err());
        assert!(df(&hf)
            .window()
            .partition_by(&["id"])
            .order_by(&[("x", SortOrder::Desc)])
            .rank("r")
            .build()
            .schema()
            .is_err());
    }

    #[test]
    fn feature_scaling_pipeline() {
        // the paper's Q26 idiom: (col - mean) / var as array compute
        let hf = ctx();
        let d = df(&hf);
        let (m, v) = (d.mean("x").unwrap(), d.var("x").unwrap());
        let scaled = d.with_column("x", col("x").sub(lit(m)).div(lit(v)));
        let out = scaled.collect().unwrap();
        let xs = out.column("x").unwrap().as_f64();
        assert!((xs.iter().sum::<f64>()).abs() < 1e-9); // centered
    }

    #[test]
    fn kmeans_end_to_end_rust_kernel() {
        let hf = HiFrames::with_workers(2);
        let t = Table::from_pairs(vec![
            ("a", Column::F64(vec![0.0, 0.1, 10.0, 10.1, 0.05, 9.95])),
            ("b", Column::F64(vec![0.0, 0.1, 10.0, 10.1, 0.05, 9.95])),
        ])
        .unwrap();
        let out = hf
            .table("pts", t)
            .matrix_assembly(&["a", "b"])
            .kmeans(2, 10, false)
            .collect()
            .unwrap();
        assert_eq!(out.num_rows(), 2);
        assert_eq!(out.schema().names(), vec!["f0", "f1", "cluster"]);
        let f0 = out.column("f0").unwrap().as_f64();
        let mut c: Vec<f64> = f0.to_vec();
        c.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert!(c[0] < 1.0 && c[1] > 9.0);
    }

    #[test]
    fn schema_errors_surface_eagerly() {
        let hf = ctx();
        assert!(df(&hf).filter(col("nope").lt(lit(1.0))).schema().is_err());
        assert!(df(&hf).select(&["missing"]).schema().is_err());
        // composite-key validation is eager too
        let other = df(&hf);
        assert!(df(&hf)
            .join_on(&other, &[], JoinType::Inner)
            .schema()
            .is_err());
        assert!(df(&hf).aggregate_by(&["x"], vec![]).schema().is_err()); // F64 key
    }

    #[test]
    fn multi_key_aggregate_collects() {
        let hf = ctx();
        let t = hf.table(
            "t",
            Table::from_pairs(vec![
                ("k1", Column::I64(vec![1, 1, 2, 2, 1])),
                ("k2", Column::I64(vec![0, 1, 0, 0, 0])),
                ("x", Column::F64(vec![1.0, 2.0, 3.0, 4.0, 5.0])),
            ])
            .unwrap(),
        );
        let out = t
            .aggregate_by(
                &["k1", "k2"],
                vec![AggExpr::new("s", AggFn::Sum, col("x"))],
            )
            .sort_by_keys(&[("k1", SortOrder::Asc), ("k2", SortOrder::Asc)])
            .collect()
            .unwrap();
        assert_eq!(out.schema().names(), vec!["k1", "k2", "s"]);
        assert_eq!(out.column("k1").unwrap().as_i64(), &[1, 1, 2]);
        assert_eq!(out.column("k2").unwrap().as_i64(), &[0, 1, 0]);
        assert_eq!(out.column("s").unwrap().as_f64(), &[6.0, 2.0, 7.0]);
    }

    #[test]
    fn left_join_masks_missing_rows() {
        let hf = ctx();
        let left = hf.table(
            "l",
            Table::from_pairs(vec![("id", Column::I64(vec![1, 2, 3]))]).unwrap(),
        );
        let right = hf.table(
            "r",
            Table::from_pairs(vec![
                ("rid", Column::I64(vec![1, 3])),
                ("w", Column::I64(vec![10, 30])),
            ])
            .unwrap(),
        );
        let joined = left.join_on(&right, &[("id", "rid")], JoinType::Left);
        let out = joined.sort_by("id").collect().unwrap();
        assert_eq!(out.column("id").unwrap().as_i64(), &[1, 2, 3]);
        // dtype preserved; the unmatched row is null under the mask
        assert_eq!(out.schema().dtype_of("w"), Some(crate::types::DType::I64));
        assert_eq!(out.column("w").unwrap().as_i64(), &[10, 0, 30]);
        assert_eq!(out.mask("w").unwrap().to_bools(), vec![true, false, true]);

        // frame-level null APIs over the same join
        let flagged = joined.is_null("w").sort_by("id").collect().unwrap();
        assert_eq!(
            flagged.column("w_is_null").unwrap().as_bool(),
            &[false, true, false]
        );
        let filled = joined.fill_null("w", -7i64).sort_by("id").collect().unwrap();
        assert_eq!(filled.column("w").unwrap().as_i64(), &[10, -7, 30]);
        assert_eq!(filled.null_count("w"), 0);
        assert_eq!(filled.schema().nullable_of("w"), Some(false));
        let kept = joined.drop_null(&["w"]).sort_by("id").collect().unwrap();
        assert_eq!(kept.column("id").unwrap().as_i64(), &[1, 3]);
        assert_eq!(kept.null_count("w"), 0);
    }

    #[test]
    fn join_builder_and_group_by_builder() {
        let hf = ctx();
        let l = hf.table(
            "l",
            Table::from_pairs(vec![
                ("a", Column::I64(vec![1, 1, 2])),
                ("b", Column::I64(vec![7, 8, 7])),
                ("x", Column::F64(vec![0.5, 1.5, 2.5])),
            ])
            .unwrap(),
        );
        let r = hf.table(
            "r",
            Table::from_pairs(vec![
                ("ra", Column::I64(vec![1, 1, 2])),
                ("rb", Column::I64(vec![7, 9, 7])),
                ("w", Column::I64(vec![100, 200, 300])),
            ])
            .unwrap(),
        );
        // composite join: only (1,7) and (2,7) tuples match
        let joined = l
            .join_with(&r)
            .on("a", "ra")
            .on("b", "rb")
            .how(JoinType::Inner)
            .build()
            .sort_by("a")
            .collect()
            .unwrap();
        assert_eq!(joined.num_rows(), 2);
        assert_eq!(joined.column("w").unwrap().as_i64(), &[100, 300]);
        // group-by builder over two keys
        let agg = l
            .group_by(&["a", "b"])
            .agg("n", AggFn::Count, col("x"))
            .agg("s", AggFn::Sum, col("x"))
            .build()
            .sort_by_keys(&[("a", SortOrder::Asc), ("b", SortOrder::Asc)])
            .collect()
            .unwrap();
        assert_eq!(agg.num_rows(), 3);
        assert_eq!(agg.schema().names(), vec!["a", "b", "n", "s"]);
    }

    #[test]
    fn semi_and_anti_join() {
        let hf = ctx();
        let left = df(&hf); // ids 1,2,1,3,2,1
        let right = hf.table(
            "r",
            Table::from_pairs(vec![("cid", Column::I64(vec![2, 3]))]).unwrap(),
        );
        let semi = left
            .join_on(&right, &[("id", "cid")], JoinType::Semi)
            .collect()
            .unwrap();
        assert_eq!(semi.schema().names(), vec!["id", "x"]); // left schema only
        assert_eq!(semi.num_rows(), 3); // ids 2,3,2
        let anti = left
            .join_on(&right, &[("id", "cid")], JoinType::Anti)
            .collect()
            .unwrap();
        assert_eq!(anti.num_rows(), 3); // the three id=1 rows
        assert!(anti.column("id").unwrap().as_i64().iter().all(|&i| i == 1));
    }

    #[test]
    fn skew_hint_sets_strategy_and_matches_hash_join() {
        let hf = ctx();
        let left = df(&hf); // ids 1,2,1,3,2,1 — id 1 is the hot key
        let right = hf.table(
            "r",
            Table::from_pairs(vec![
                ("cid", Column::I64(vec![1, 2])),
                ("w", Column::I64(vec![10, 20])),
            ])
            .unwrap(),
        );
        let hinted = left
            .join_with(&right)
            .on("id", "cid")
            .how(JoinType::Left)
            .skew_hint(0.25)
            .build();
        match hinted.plan() {
            Plan::Join { strategy, .. } => assert_eq!(
                *strategy,
                JoinStrategy::SkewBroadcast {
                    threshold_permille: 250
                }
            ),
            other => panic!("expected join plan, got:\n{other}"),
        }
        let skew = hinted.sort_by("id").collect().unwrap();
        let hash = left
            .join_on(&right, &[("id", "cid")], JoinType::Left)
            .sort_by("id")
            .collect()
            .unwrap();
        assert_eq!(skew.column("id").unwrap(), hash.column("id").unwrap());
        assert_eq!(skew.mask("w"), hash.mask("w"));
        assert_eq!(skew.num_rows(), 6);
    }

    #[test]
    fn with_columns_batch_matches_chained() {
        let hf = ctx();
        let batch = df(&hf)
            .with_columns(&[
                ("y", col("x").add(lit(1.0))),
                ("z", col("y").mul(lit(2.0))),
            ])
            .collect()
            .unwrap();
        let chained = df(&hf)
            .with_column("y", col("x").add(lit(1.0)))
            .with_column("z", col("y").mul(lit(2.0)))
            .collect()
            .unwrap();
        assert_eq!(batch, chained);
        assert_eq!(batch.schema().names(), vec!["id", "x", "y", "z"]);
        // empty batch is the identity
        let same = df(&hf).with_columns(&[]).collect().unwrap();
        assert_eq!(same, df(&hf).collect().unwrap());
    }

    #[test]
    fn explain_renders_shared_nodes_stably() {
        let hf = ctx();
        let d = df(&hf);
        let shared = d.filter(col("x").lt(lit(4.0)));
        let right = shared.rename("id", "rid").rename("x", "y");
        let j = shared
            .join_on(&right, &[("id", "rid")], JoinType::Inner)
            .sort_by("id");
        let a = j.explain();
        assert_eq!(a, j.explain(), "explain must be deterministic");
        assert!(a.contains("[shared]"), "diamond arm not marked shared:\n{a}");
        assert!(a.contains("Join"), "{a}");
        assert!(a.contains("Sort"), "{a}");
        // planning errors render instead of panicking
        assert!(d.select(&["missing"]).explain().starts_with("explain error:"));
    }

    #[test]
    fn cache_pins_results_across_collects() {
        let hf = ctx();
        let cached = df(&hf).filter(col("x").gt(lit(1.0))).cache();
        let a = cached.sort_by("id").collect().unwrap();
        assert_eq!(hf.plan_cache().len(), 1);
        // the semantics are unchanged by the cache node
        let plain = df(&hf)
            .filter(col("x").gt(lit(1.0)))
            .sort_by("id")
            .collect()
            .unwrap();
        assert_eq!(a, plain);
        // a second collect (and a different query over the same cached
        // subplan) are served from the context's PlanCache
        let before = crate::metrics::plan_stats().snapshot();
        let b = cached.sort_by("id").collect().unwrap();
        assert_eq!(a, b);
        let c = cached.select(&["id"]).collect().unwrap();
        assert_eq!(c.num_rows(), a.num_rows());
        let after = crate::metrics::plan_stats().snapshot();
        assert!(after.plan_cache_hits >= before.plan_cache_hits + 2);
        hf.plan_cache().clear();
        assert!(hf.plan_cache().is_empty());
    }

    #[test]
    fn sort_by_keys_desc() {
        let hf = ctx();
        let out = df(&hf)
            .sort_by_keys(&[("id", SortOrder::Desc)])
            .collect()
            .unwrap();
        assert_eq!(out.column("id").unwrap().as_i64(), &[3, 2, 2, 1, 1, 1]);
    }
}
