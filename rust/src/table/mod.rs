//! Schemas and materialized tables.
//!
//! A [`Table`] is what a data frame *materializes to*: named columns of equal
//! length. During execution nothing ever holds a `Table` on the hot path —
//! the executor environment maps `name → Column` (dual representation) — but
//! sources, sinks, tests and the baseline engines exchange `Table`s.

use crate::column::Column;
use crate::types::{DType, Value};
use anyhow::{bail, Result};
use std::fmt;

/// An ordered list of `(column name, dtype)` pairs.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Schema {
    fields: Vec<(String, DType)>,
}

impl Schema {
    pub fn new(fields: Vec<(String, DType)>) -> Schema {
        Schema { fields }
    }

    /// Convenience constructor: `Schema::of(&[("id", DType::I64), ...])`.
    pub fn of(fields: &[(&str, DType)]) -> Schema {
        Schema {
            fields: fields.iter().map(|(n, t)| (n.to_string(), *t)).collect(),
        }
    }

    pub fn fields(&self) -> &[(String, DType)] {
        &self.fields
    }

    pub fn len(&self) -> usize {
        self.fields.len()
    }

    pub fn is_empty(&self) -> bool {
        self.fields.is_empty()
    }

    pub fn names(&self) -> Vec<&str> {
        self.fields.iter().map(|(n, _)| n.as_str()).collect()
    }

    pub fn index_of(&self, name: &str) -> Option<usize> {
        self.fields.iter().position(|(n, _)| n == name)
    }

    pub fn dtype_of(&self, name: &str) -> Option<DType> {
        self.fields
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, t)| *t)
    }

    pub fn push(&mut self, name: &str, dtype: DType) {
        self.fields.push((name.to_string(), dtype));
    }

    /// Schema equality up to column order is NOT allowed for concatenation —
    /// the paper requires identical schemas for `[df1; df2]`.
    pub fn same_as(&self, other: &Schema) -> bool {
        self == other
    }
}

impl fmt::Display for Schema {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{{")?;
        for (i, (n, t)) in self.fields.iter().enumerate() {
            if i > 0 {
                write!(f, ", ")?;
            }
            write!(f, ":{n}={t}")?;
        }
        write!(f, "}}")
    }
}

/// A materialized table: schema + columns of identical length.
#[derive(Debug, Clone, PartialEq)]
pub struct Table {
    schema: Schema,
    columns: Vec<Column>,
}

impl Table {
    pub fn new(schema: Schema, columns: Vec<Column>) -> Result<Table> {
        if schema.len() != columns.len() {
            bail!(
                "table: {} fields but {} columns",
                schema.len(),
                columns.len()
            );
        }
        let mut n = None;
        for ((name, dt), col) in schema.fields().iter().zip(&columns) {
            if col.dtype() != *dt {
                bail!("table: column {name} declared {dt} but is {}", col.dtype());
            }
            match n {
                None => n = Some(col.len()),
                Some(m) if m != col.len() => {
                    bail!("table: column {name} length {} != {m}", col.len())
                }
                _ => {}
            }
        }
        Ok(Table { schema, columns })
    }

    /// Build from `(name, column)` pairs, inferring the schema.
    pub fn from_pairs(pairs: Vec<(&str, Column)>) -> Result<Table> {
        let schema = Schema::new(
            pairs
                .iter()
                .map(|(n, c)| (n.to_string(), c.dtype()))
                .collect(),
        );
        let columns = pairs.into_iter().map(|(_, c)| c).collect();
        Table::new(schema, columns)
    }

    pub fn empty(schema: Schema) -> Table {
        let columns = schema
            .fields()
            .iter()
            .map(|(_, t)| Column::new_empty(*t))
            .collect();
        Table { schema, columns }
    }

    pub fn schema(&self) -> &Schema {
        &self.schema
    }

    pub fn num_rows(&self) -> usize {
        self.columns.first().map_or(0, |c| c.len())
    }

    pub fn num_cols(&self) -> usize {
        self.columns.len()
    }

    pub fn column(&self, name: &str) -> Option<&Column> {
        self.schema.index_of(name).map(|i| &self.columns[i])
    }

    pub fn column_at(&self, i: usize) -> &Column {
        &self.columns[i]
    }

    pub fn columns(&self) -> &[Column] {
        &self.columns
    }

    pub fn into_columns(self) -> (Schema, Vec<Column>) {
        (self.schema, self.columns)
    }

    pub fn row(&self, i: usize) -> Vec<Value> {
        self.columns.iter().map(|c| c.get(i)).collect()
    }

    /// Row-slice `[start, start+len)` of every column (1D_BLOCK partitioning).
    pub fn slice(&self, start: usize, len: usize) -> Table {
        Table {
            schema: self.schema.clone(),
            columns: self.columns.iter().map(|c| c.slice(start, len)).collect(),
        }
    }

    /// Filter all columns with one mask.
    pub fn filter(&self, mask: &[bool]) -> Table {
        Table {
            schema: self.schema.clone(),
            columns: self.columns.iter().map(|c| c.filter(mask)).collect(),
        }
    }

    /// Vertical concatenation (paper's `[df1; df2]`); schemas must match.
    pub fn concat(&self, other: &Table) -> Result<Table> {
        if !self.schema.same_as(&other.schema) {
            bail!(
                "concat: schema mismatch {} vs {}",
                self.schema,
                other.schema
            );
        }
        let mut cols = self.columns.clone();
        for (a, b) in cols.iter_mut().zip(&other.columns) {
            a.extend(b);
        }
        Ok(Table {
            schema: self.schema.clone(),
            columns: cols,
        })
    }

    /// Keep only `names`, in order (projection).
    pub fn project(&self, names: &[&str]) -> Result<Table> {
        let mut fields = Vec::new();
        let mut cols = Vec::new();
        for &n in names {
            let Some(i) = self.schema.index_of(n) else {
                bail!("project: unknown column {n}");
            };
            fields.push(self.schema.fields()[i].clone());
            cols.push(self.columns[i].clone());
        }
        Ok(Table {
            schema: Schema::new(fields),
            columns: cols,
        })
    }

    /// Sort the whole table by an I64 key column (ascending, stable) —
    /// canonicalization for engine-agreement tests.
    pub fn sorted_by(&self, key: &str) -> Result<Table> {
        let Some(kc) = self.column(key) else {
            bail!("sorted_by: unknown column {key}")
        };
        let keys = kc.as_i64();
        let mut idx: Vec<usize> = (0..self.num_rows()).collect();
        idx.sort_by_key(|&i| keys[i]);
        Ok(Table {
            schema: self.schema.clone(),
            columns: self.columns.iter().map(|c| c.take(&idx)).collect(),
        })
    }

    /// Sort by a composite key list with per-key directions (stable) — the
    /// serial counterpart of the distributed `sort_by_keys`.
    pub fn sorted_by_keys(&self, keys: &[(&str, crate::types::SortOrder)]) -> Result<Table> {
        let key_cols: Vec<&Column> = keys
            .iter()
            .map(|(k, _)| {
                self.column(k)
                    .ok_or_else(|| anyhow::anyhow!("sorted_by_keys: unknown column {k}"))
            })
            .collect::<Result<_>>()?;
        let orders: Vec<crate::types::SortOrder> = keys.iter().map(|(_, o)| *o).collect();
        let rows = crate::ops::keys::key_rows(&key_cols)?;
        let mut idx: Vec<usize> = (0..self.num_rows()).collect();
        idx.sort_by(|&a, &b| crate::ops::keys::cmp_key_rows(&rows[a], &rows[b], &orders));
        Ok(Table {
            schema: self.schema.clone(),
            columns: self.columns.iter().map(|c| c.take(&idx)).collect(),
        })
    }

    pub fn byte_size(&self) -> usize {
        self.columns.iter().map(|c| c.byte_size()).sum()
    }
}

impl fmt::Display for Table {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "{} ({} rows)", self.schema, self.num_rows())?;
        let n = self.num_rows().min(10);
        for i in 0..n {
            let row: Vec<String> = self.row(i).iter().map(|v| v.to_string()).collect();
            writeln!(f, "  {}", row.join(" | "))?;
        }
        if self.num_rows() > n {
            writeln!(f, "  … {} more rows", self.num_rows() - n)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t() -> Table {
        Table::from_pairs(vec![
            ("id", Column::I64(vec![3, 1, 2])),
            ("x", Column::F64(vec![0.3, 0.1, 0.2])),
        ])
        .unwrap()
    }

    #[test]
    fn construction_checks() {
        assert!(Table::new(
            Schema::of(&[("a", DType::I64)]),
            vec![Column::F64(vec![1.0])]
        )
        .is_err());
        assert!(Table::new(
            Schema::of(&[("a", DType::I64), ("b", DType::I64)]),
            vec![Column::I64(vec![1]), Column::I64(vec![1, 2])]
        )
        .is_err());
        assert!(Table::new(Schema::of(&[("a", DType::I64)]), vec![]).is_err());
    }

    #[test]
    fn accessors() {
        let t = t();
        assert_eq!(t.num_rows(), 3);
        assert_eq!(t.num_cols(), 2);
        assert_eq!(t.column("id").unwrap().as_i64(), &[3, 1, 2]);
        assert!(t.column("nope").is_none());
        assert_eq!(t.row(0), vec![Value::I64(3), Value::F64(0.3)]);
        assert_eq!(t.schema().dtype_of("x"), Some(DType::F64));
    }

    #[test]
    fn slice_filter_concat() {
        let t = t();
        assert_eq!(t.slice(1, 2).column("id").unwrap().as_i64(), &[1, 2]);
        let f = t.filter(&[true, false, true]);
        assert_eq!(f.column("id").unwrap().as_i64(), &[3, 2]);
        let c = t.concat(&t).unwrap();
        assert_eq!(c.num_rows(), 6);
        let other = Table::from_pairs(vec![("id", Column::I64(vec![1]))]).unwrap();
        assert!(t.concat(&other).is_err());
    }

    #[test]
    fn project_and_sort() {
        let t = t();
        let p = t.project(&["x"]).unwrap();
        assert_eq!(p.num_cols(), 1);
        assert!(t.project(&["zzz"]).is_err());
        let s = t.sorted_by("id").unwrap();
        assert_eq!(s.column("id").unwrap().as_i64(), &[1, 2, 3]);
        assert_eq!(s.column("x").unwrap().as_f64(), &[0.1, 0.2, 0.3]);
    }

    #[test]
    fn multi_key_sort_with_directions() {
        let t = Table::from_pairs(vec![
            ("g", Column::I64(vec![1, 2, 1, 2])),
            ("x", Column::I64(vec![10, 20, 30, 40])),
        ])
        .unwrap();
        use crate::types::SortOrder::*;
        let s = t.sorted_by_keys(&[("g", Desc), ("x", Asc)]).unwrap();
        assert_eq!(s.column("g").unwrap().as_i64(), &[2, 2, 1, 1]);
        assert_eq!(s.column("x").unwrap().as_i64(), &[20, 40, 10, 30]);
        assert!(t.sorted_by_keys(&[("nope", Asc)]).is_err());
    }

    #[test]
    fn empty_table() {
        let e = Table::empty(Schema::of(&[("a", DType::Str)]));
        assert_eq!(e.num_rows(), 0);
        assert_eq!(e.num_cols(), 1);
    }

    #[test]
    fn display_smoke() {
        let s = format!("{}", t());
        assert!(s.contains("3 rows"));
    }
}
