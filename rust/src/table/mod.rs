//! Schemas and materialized tables.
//!
//! A [`Table`] is what a data frame *materializes to*: named columns of equal
//! length, each with an optional validity mask (the null model). During
//! execution nothing ever holds a `Table` on the hot path — the executor
//! environment maps `name → Column (+ mask)` (dual representation) — but
//! sources, sinks, tests and the baseline engines exchange `Table`s.
//!
//! Canonical form: all-valid masks are stored as `None` and values under
//! null bits are dtype defaults, so `Table` equality compares both values
//! *and* null positions — the engine-agreement tests lean on this.

use crate::column::{normalize_mask, Column, ValidityMask};
use crate::types::{DType, Value};
use anyhow::{bail, Result};
use std::fmt;

/// An ordered list of `(column name, dtype)` pairs plus per-column
/// nullability. Sources start non-nullable; Left/Right/Outer joins mark the
/// null-introduced side nullable while keeping its native dtype.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Schema {
    fields: Vec<(String, DType)>,
    nullable: Vec<bool>,
}

impl Schema {
    pub fn new(fields: Vec<(String, DType)>) -> Schema {
        let n = fields.len();
        Schema {
            fields,
            nullable: vec![false; n],
        }
    }

    /// Construct with explicit per-field nullability.
    pub fn new_nullable(fields: Vec<(String, DType)>, nullable: Vec<bool>) -> Schema {
        assert_eq!(fields.len(), nullable.len(), "schema: nullable flag count");
        Schema { fields, nullable }
    }

    /// Convenience constructor: `Schema::of(&[("id", DType::I64), ...])`.
    pub fn of(fields: &[(&str, DType)]) -> Schema {
        Schema::new(fields.iter().map(|(n, t)| (n.to_string(), *t)).collect())
    }

    pub fn fields(&self) -> &[(String, DType)] {
        &self.fields
    }

    pub fn len(&self) -> usize {
        self.fields.len()
    }

    pub fn is_empty(&self) -> bool {
        self.fields.is_empty()
    }

    pub fn names(&self) -> Vec<&str> {
        self.fields.iter().map(|(n, _)| n.as_str()).collect()
    }

    pub fn index_of(&self, name: &str) -> Option<usize> {
        self.fields.iter().position(|(n, _)| n == name)
    }

    pub fn dtype_of(&self, name: &str) -> Option<DType> {
        self.fields
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, t)| *t)
    }

    /// May this column contain nulls?
    pub fn nullable_of(&self, name: &str) -> Option<bool> {
        self.index_of(name).map(|i| self.nullable[i])
    }

    pub fn nullable_at(&self, i: usize) -> bool {
        self.nullable[i]
    }

    pub fn nullable_flags(&self) -> &[bool] {
        &self.nullable
    }

    pub fn push(&mut self, name: &str, dtype: DType) {
        self.push_field(name, dtype, false);
    }

    pub fn push_field(&mut self, name: &str, dtype: DType, nullable: bool) {
        self.fields.push((name.to_string(), dtype));
        self.nullable.push(nullable);
    }

    pub fn set_nullable(&mut self, i: usize, nullable: bool) {
        self.nullable[i] = nullable;
    }

    /// Schema equality up to column order is NOT allowed for concatenation —
    /// the paper requires identical schemas for `[df1; df2]`. Nullability is
    /// part of the schema.
    pub fn same_as(&self, other: &Schema) -> bool {
        self == other
    }
}

impl fmt::Display for Schema {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{{")?;
        for (i, (n, t)) in self.fields.iter().enumerate() {
            if i > 0 {
                write!(f, ", ")?;
            }
            let q = if self.nullable[i] { "?" } else { "" };
            write!(f, ":{n}={t}{q}")?;
        }
        write!(f, "}}")
    }
}

/// A materialized table: schema + columns of identical length + optional
/// per-column validity masks.
#[derive(Debug, Clone, PartialEq)]
pub struct Table {
    schema: Schema,
    columns: Vec<Column>,
    masks: Vec<Option<ValidityMask>>,
}

impl Table {
    pub fn new(schema: Schema, columns: Vec<Column>) -> Result<Table> {
        let masks = vec![None; columns.len()];
        Table::new_masked(schema, columns, masks)
    }

    /// Construct with validity masks (one slot per column; `None` = fully
    /// valid). All-valid masks are normalized away; a present mask promotes
    /// its schema field to nullable.
    pub fn new_masked(
        schema: Schema,
        columns: Vec<Column>,
        masks: Vec<Option<ValidityMask>>,
    ) -> Result<Table> {
        if schema.len() != columns.len() {
            bail!(
                "table: {} fields but {} columns",
                schema.len(),
                columns.len()
            );
        }
        if masks.len() != columns.len() {
            bail!("table: {} columns but {} mask slots", columns.len(), masks.len());
        }
        let mut schema = schema;
        let mut n = None;
        for (i, ((name, dt), col)) in schema.fields().iter().zip(&columns).enumerate() {
            if col.dtype() != *dt {
                bail!("table: column {name} declared {dt} but is {}", col.dtype());
            }
            match n {
                None => n = Some(col.len()),
                Some(m) if m != col.len() => {
                    bail!("table: column {name} length {} != {m}", col.len())
                }
                _ => {}
            }
            if let Some(m) = &masks[i] {
                if m.len() != col.len() {
                    bail!(
                        "table: column {name} mask length {} != {}",
                        m.len(),
                        col.len()
                    );
                }
            }
        }
        let masks: Vec<Option<ValidityMask>> =
            masks.into_iter().map(normalize_mask).collect();
        for (i, m) in masks.iter().enumerate() {
            if m.is_some() {
                schema.set_nullable(i, true);
            }
        }
        Ok(Table {
            schema,
            columns,
            masks,
        })
    }

    /// Build from `(name, column)` pairs, inferring the schema.
    pub fn from_pairs(pairs: Vec<(&str, Column)>) -> Result<Table> {
        let schema = Schema::new(
            pairs
                .iter()
                .map(|(n, c)| (n.to_string(), c.dtype()))
                .collect(),
        );
        let columns = pairs.into_iter().map(|(_, c)| c).collect();
        Table::new(schema, columns)
    }

    /// Attach a validity mask to one column (test/data construction helper).
    /// Values under null bits are scrubbed to dtype defaults so the table is
    /// canonical.
    pub fn with_null_mask(mut self, name: &str, mask: ValidityMask) -> Result<Table> {
        let Some(i) = self.schema.index_of(name) else {
            bail!("with_null_mask: unknown column {name}");
        };
        if mask.len() != self.columns[i].len() {
            bail!("with_null_mask: mask length mismatch for {name}");
        }
        crate::column::scrub_invalid(&mut self.columns[i], &mask);
        let m = normalize_mask(Some(mask));
        if m.is_some() {
            self.schema.set_nullable(i, true);
        }
        self.masks[i] = m;
        Ok(self)
    }

    pub fn empty(schema: Schema) -> Table {
        let columns: Vec<Column> = schema
            .fields()
            .iter()
            .map(|(_, t)| Column::new_empty(*t))
            .collect();
        let masks = vec![None; columns.len()];
        Table {
            schema,
            columns,
            masks,
        }
    }

    pub fn schema(&self) -> &Schema {
        &self.schema
    }

    pub fn num_rows(&self) -> usize {
        self.columns.first().map_or(0, |c| c.len())
    }

    pub fn num_cols(&self) -> usize {
        self.columns.len()
    }

    pub fn column(&self, name: &str) -> Option<&Column> {
        self.schema.index_of(name).map(|i| &self.columns[i])
    }

    pub fn column_at(&self, i: usize) -> &Column {
        &self.columns[i]
    }

    pub fn columns(&self) -> &[Column] {
        &self.columns
    }

    /// Validity mask of one column (`None` = fully valid).
    pub fn mask(&self, name: &str) -> Option<&ValidityMask> {
        self.schema
            .index_of(name)
            .and_then(|i| self.masks[i].as_ref())
    }

    pub fn mask_at(&self, i: usize) -> Option<&ValidityMask> {
        self.masks[i].as_ref()
    }

    pub fn masks(&self) -> &[Option<ValidityMask>] {
        &self.masks
    }

    /// Number of null rows in one column (0 for unknown/absent mask).
    pub fn null_count(&self, name: &str) -> usize {
        self.mask(name).map_or(0, |m| m.count_null())
    }

    pub fn into_columns(self) -> (Schema, Vec<Column>) {
        (self.schema, self.columns)
    }

    /// Decompose into all parts, masks included.
    pub fn into_parts(self) -> (Schema, Vec<Column>, Vec<Option<ValidityMask>>) {
        (self.schema, self.columns, self.masks)
    }

    /// Row `i` as typed values; null lanes surface as [`Value::Null`] — the
    /// columnar → row-engine boundary.
    pub fn row(&self, i: usize) -> Vec<Value> {
        self.columns
            .iter()
            .zip(&self.masks)
            .map(|(c, m)| match m {
                Some(m) if !m.get(i) => Value::Null(c.dtype()),
                _ => c.get(i),
            })
            .collect()
    }

    /// Row-slice `[start, start+len)` of every column (1D_BLOCK partitioning).
    pub fn slice(&self, start: usize, len: usize) -> Table {
        Table {
            schema: self.schema.clone(),
            columns: self.columns.iter().map(|c| c.slice(start, len)).collect(),
            masks: self
                .masks
                .iter()
                .map(|m| normalize_mask(m.as_ref().map(|m| m.slice(start, len))))
                .collect(),
        }
    }

    /// Filter all columns with one mask.
    pub fn filter(&self, mask: &[bool]) -> Table {
        Table {
            schema: self.schema.clone(),
            columns: self.columns.iter().map(|c| c.filter(mask)).collect(),
            masks: self
                .masks
                .iter()
                .map(|m| normalize_mask(m.as_ref().map(|m| m.filter(mask))))
                .collect(),
        }
    }

    /// Vertical concatenation (paper's `[df1; df2]`); schemas must match.
    pub fn concat(&self, other: &Table) -> Result<Table> {
        if !self.schema.same_as(&other.schema) {
            bail!(
                "concat: schema mismatch {} vs {}",
                self.schema,
                other.schema
            );
        }
        let mut cols = self.columns.clone();
        let mut masks = self.masks.clone();
        for (i, (a, b)) in cols.iter_mut().zip(&other.columns).enumerate() {
            let before = a.len();
            a.extend(b);
            crate::column::extend_opt_mask(
                &mut masks[i],
                before,
                other.masks[i].as_ref(),
                b.len(),
            );
        }
        let masks = masks.into_iter().map(normalize_mask).collect();
        Ok(Table {
            schema: self.schema.clone(),
            columns: cols,
            masks,
        })
    }

    /// Keep only `names`, in order (projection).
    pub fn project(&self, names: &[&str]) -> Result<Table> {
        let mut fields = Vec::new();
        let mut nullable = Vec::new();
        let mut cols = Vec::new();
        let mut masks = Vec::new();
        for &n in names {
            let Some(i) = self.schema.index_of(n) else {
                bail!("project: unknown column {n}");
            };
            fields.push(self.schema.fields()[i].clone());
            nullable.push(self.schema.nullable_at(i));
            cols.push(self.columns[i].clone());
            masks.push(self.masks[i].clone());
        }
        Ok(Table {
            schema: Schema::new_nullable(fields, nullable),
            columns: cols,
            masks,
        })
    }

    fn take_all(&self, idx: &[usize]) -> Table {
        Table {
            schema: self.schema.clone(),
            columns: self.columns.iter().map(|c| c.take(idx)).collect(),
            masks: self
                .masks
                .iter()
                .map(|m| normalize_mask(m.as_ref().map(|m| m.take(idx))))
                .collect(),
        }
    }

    /// Sort the whole table by one key column (ascending, stable, nulls
    /// first) — canonicalization for engine-agreement tests. Thin wrapper
    /// over [`Table::sorted_by_keys`], so null keys order exactly like the
    /// engines' nulls-first rule instead of by their scrubbed default.
    pub fn sorted_by(&self, key: &str) -> Result<Table> {
        self.sorted_by_keys(&[(key, crate::types::SortOrder::Asc)])
    }

    /// Sort by a composite key list with per-key directions (stable) — the
    /// serial counterpart of the distributed `sort_by_keys`. Null keys order
    /// before every value (nulls-first under ascending).
    pub fn sorted_by_keys(&self, keys: &[(&str, crate::types::SortOrder)]) -> Result<Table> {
        let mut key_cols = Vec::new();
        let mut key_masks = Vec::new();
        for (k, _) in keys {
            let Some(i) = self.schema.index_of(k) else {
                bail!("sorted_by_keys: unknown column {k}");
            };
            key_cols.push(&self.columns[i]);
            key_masks.push(self.masks[i].as_ref());
        }
        let orders: Vec<crate::types::SortOrder> = keys.iter().map(|(_, o)| *o).collect();
        let rows = crate::ops::keys::key_rows_nullable(&key_cols, &key_masks)?;
        let mut idx: Vec<usize> = (0..self.num_rows()).collect();
        idx.sort_by(|&a, &b| crate::ops::keys::cmp_key_rows(&rows[a], &rows[b], &orders));
        Ok(self.take_all(&idx))
    }

    pub fn byte_size(&self) -> usize {
        self.columns.iter().map(|c| c.byte_size()).sum::<usize>()
            + self
                .masks
                .iter()
                .map(|m| m.as_ref().map_or(0, |m| m.byte_size()))
                .sum::<usize>()
    }
}

impl fmt::Display for Table {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "{} ({} rows)", self.schema, self.num_rows())?;
        let n = self.num_rows().min(10);
        for i in 0..n {
            let row: Vec<String> = self.row(i).iter().map(|v| v.to_string()).collect();
            writeln!(f, "  {}", row.join(" | "))?;
        }
        if self.num_rows() > n {
            writeln!(f, "  … {} more rows", self.num_rows() - n)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t() -> Table {
        Table::from_pairs(vec![
            ("id", Column::I64(vec![3, 1, 2])),
            ("x", Column::F64(vec![0.3, 0.1, 0.2])),
        ])
        .unwrap()
    }

    #[test]
    fn construction_checks() {
        assert!(Table::new(
            Schema::of(&[("a", DType::I64)]),
            vec![Column::F64(vec![1.0])]
        )
        .is_err());
        assert!(Table::new(
            Schema::of(&[("a", DType::I64), ("b", DType::I64)]),
            vec![Column::I64(vec![1]), Column::I64(vec![1, 2])]
        )
        .is_err());
        assert!(Table::new(Schema::of(&[("a", DType::I64)]), vec![]).is_err());
        // mask length must match its column
        assert!(Table::new_masked(
            Schema::of(&[("a", DType::I64)]),
            vec![Column::I64(vec![1, 2])],
            vec![Some(ValidityMask::new_valid(3))],
        )
        .is_err());
    }

    #[test]
    fn accessors() {
        let t = t();
        assert_eq!(t.num_rows(), 3);
        assert_eq!(t.num_cols(), 2);
        assert_eq!(t.column("id").unwrap().as_i64(), &[3, 1, 2]);
        assert!(t.column("nope").is_none());
        assert_eq!(t.row(0), vec![Value::I64(3), Value::F64(0.3)]);
        assert_eq!(t.schema().dtype_of("x"), Some(DType::F64));
        assert_eq!(t.schema().nullable_of("x"), Some(false));
        assert!(t.mask("x").is_none());
        assert_eq!(t.null_count("x"), 0);
    }

    #[test]
    fn masked_table_roundtrip() {
        let t = Table::from_pairs(vec![("v", Column::I64(vec![10, 99, 30]))])
            .unwrap()
            .with_null_mask("v", ValidityMask::from_bools(&[true, false, true]))
            .unwrap();
        assert_eq!(t.schema().nullable_of("v"), Some(true));
        assert_eq!(t.null_count("v"), 1);
        // values under nulls are scrubbed to the dtype default
        assert_eq!(t.column("v").unwrap().as_i64(), &[10, 0, 30]);
        assert_eq!(t.row(1), vec![Value::Null(DType::I64)]);
        // all-valid masks normalize away
        let u = Table::from_pairs(vec![("v", Column::I64(vec![1]))])
            .unwrap()
            .with_null_mask("v", ValidityMask::new_valid(1))
            .unwrap();
        assert!(u.mask("v").is_none());
        assert_eq!(u.schema().nullable_of("v"), Some(false));
    }

    #[test]
    fn masks_follow_slice_filter_concat_sort() {
        let t = Table::from_pairs(vec![
            ("id", Column::I64(vec![3, 1, 2, 4])),
            ("v", Column::I64(vec![0, 10, 0, 40])),
        ])
        .unwrap()
        .with_null_mask("v", ValidityMask::from_bools(&[false, true, false, true]))
        .unwrap();
        assert_eq!(t.slice(0, 2).null_count("v"), 1);
        let f = t.filter(&[true, true, false, false]);
        assert_eq!(f.null_count("v"), 1);
        let c = t.concat(&t).unwrap();
        assert_eq!(c.null_count("v"), 4);
        let s = t.sorted_by("id").unwrap();
        assert_eq!(s.column("id").unwrap().as_i64(), &[1, 2, 3, 4]);
        assert_eq!(
            s.mask("v").unwrap().to_bools(),
            vec![true, false, false, true]
        );
        // concat with a mask-free table of the *same nullable schema* works
        let (schema, cols, _) = t.clone().into_parts();
        let nomask = Table::new_masked(schema, cols, vec![None, None]).unwrap();
        let c2 = t.concat(&nomask).unwrap();
        assert_eq!(c2.null_count("v"), 2);
        assert_eq!(c2.mask("v").unwrap().len(), 8);
    }

    #[test]
    fn nullable_schema_display_and_equality() {
        let a = Schema::new_nullable(
            vec![("v".into(), DType::I64)],
            vec![true],
        );
        let b = Schema::of(&[("v", DType::I64)]);
        assert_ne!(a, b); // nullability is part of the schema
        assert_eq!(format!("{a}"), "{:v=Int64?}");
        assert_eq!(format!("{b}"), "{:v=Int64}");
    }

    #[test]
    fn slice_filter_concat() {
        let t = t();
        assert_eq!(t.slice(1, 2).column("id").unwrap().as_i64(), &[1, 2]);
        let f = t.filter(&[true, false, true]);
        assert_eq!(f.column("id").unwrap().as_i64(), &[3, 2]);
        let c = t.concat(&t).unwrap();
        assert_eq!(c.num_rows(), 6);
        let other = Table::from_pairs(vec![("id", Column::I64(vec![1]))]).unwrap();
        assert!(t.concat(&other).is_err());
    }

    #[test]
    fn project_and_sort() {
        let t = t();
        let p = t.project(&["x"]).unwrap();
        assert_eq!(p.num_cols(), 1);
        assert!(t.project(&["zzz"]).is_err());
        let s = t.sorted_by("id").unwrap();
        assert_eq!(s.column("id").unwrap().as_i64(), &[1, 2, 3]);
        assert_eq!(s.column("x").unwrap().as_f64(), &[0.1, 0.2, 0.3]);
    }

    #[test]
    fn multi_key_sort_with_directions() {
        let t = Table::from_pairs(vec![
            ("g", Column::I64(vec![1, 2, 1, 2])),
            ("x", Column::I64(vec![10, 20, 30, 40])),
        ])
        .unwrap();
        use crate::types::SortOrder::*;
        let s = t.sorted_by_keys(&[("g", Desc), ("x", Asc)]).unwrap();
        assert_eq!(s.column("g").unwrap().as_i64(), &[2, 2, 1, 1]);
        assert_eq!(s.column("x").unwrap().as_i64(), &[20, 40, 10, 30]);
        assert!(t.sorted_by_keys(&[("nope", Asc)]).is_err());
    }

    #[test]
    fn null_keys_sort_first() {
        use crate::types::SortOrder::*;
        let t = Table::from_pairs(vec![("k", Column::I64(vec![5, 0, 1]))])
            .unwrap()
            .with_null_mask("k", ValidityMask::from_bools(&[true, false, true]))
            .unwrap();
        let s = t.sorted_by_keys(&[("k", Asc)]).unwrap();
        assert_eq!(s.row(0), vec![Value::Null(DType::I64)]);
        assert_eq!(s.column("k").unwrap().as_i64(), &[0, 1, 5]);
        // descending puts nulls last
        let d = t.sorted_by_keys(&[("k", Desc)]).unwrap();
        assert_eq!(d.row(2), vec![Value::Null(DType::I64)]);
    }

    #[test]
    fn empty_table() {
        let e = Table::empty(Schema::of(&[("a", DType::Str)]));
        assert_eq!(e.num_rows(), 0);
        assert_eq!(e.num_cols(), 1);
    }

    #[test]
    fn display_smoke() {
        let s = format!("{}", t());
        assert!(s.contains("3 rows"));
    }
}
