//! PJRT runtime — loads the AOT artifacts produced by `python/compile/aot.py`
//! (HLO **text**, see /opt/xla-example/README.md for why not serialized
//! protos) and executes them on the PJRT CPU client via the `xla` crate.
//!
//! Python never runs here: `make artifacts` is the only Python invocation,
//! and the resulting `artifacts/*.hlo.txt` + `manifest.txt` are all this
//! module needs. Executables are compiled once per process and cached.
//!
//! Shape discipline: every entry point was lowered at fixed shapes
//! (recorded in the manifest). Callers pad row dimensions up to the
//! artifact's `n` and pass a 0/1 mask so padded rows are inert — the same
//! trick the L2 model uses to keep one executable per model variant.

use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// One manifest entry: artifact file + integer parameters.
#[derive(Debug, Clone)]
pub struct Entry {
    pub file: PathBuf,
    pub params: HashMap<String, usize>,
}

impl Entry {
    pub fn param(&self, key: &str) -> Result<usize> {
        self.params
            .get(key)
            .copied()
            .with_context(|| format!("manifest entry missing param {key}"))
    }
}

/// The artifact engine: manifest + lazily compiled executables.
pub struct Engine {
    client: xla::PjRtClient,
    entries: HashMap<String, Entry>,
    compiled: Mutex<HashMap<String, xla::PjRtLoadedExecutable>>,
}

/// Default artifacts directory: `$HIFRAMES_ARTIFACTS` or `./artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var("HIFRAMES_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// Quick existence check so tests can skip gracefully before `make
/// artifacts` has run.
pub fn artifacts_available() -> bool {
    default_artifacts_dir().join("manifest.txt").exists()
}

impl Engine {
    /// Load the manifest in `dir` and create the PJRT CPU client.
    pub fn load(dir: &Path) -> Result<Engine> {
        let manifest = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&manifest)
            .with_context(|| format!("reading {}", manifest.display()))?;
        let mut entries = HashMap::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut parts = line.split_whitespace();
            let Some("entry") = parts.next() else {
                bail!("manifest: expected 'entry', got {line:?}");
            };
            let name = parts.next().context("manifest: missing entry name")?;
            let mut file = None;
            let mut params = HashMap::new();
            for kv in parts {
                let (k, v) = kv
                    .split_once('=')
                    .with_context(|| format!("manifest: bad kv {kv:?}"))?;
                if k == "file" {
                    file = Some(dir.join(v));
                } else {
                    params.insert(
                        k.to_string(),
                        v.parse::<usize>()
                            .with_context(|| format!("manifest: non-integer {kv:?}"))?,
                    );
                }
            }
            entries.insert(
                name.to_string(),
                Entry {
                    file: file.with_context(|| format!("manifest entry {name}: no file"))?,
                    params,
                },
            );
        }
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("pjrt cpu: {e:?}"))?;
        Ok(Engine {
            client,
            entries,
            compiled: Mutex::new(HashMap::new()),
        })
    }

    /// Load from the default artifacts directory.
    pub fn load_default() -> Result<Engine> {
        Engine::load(&default_artifacts_dir())
    }

    pub fn entry(&self, name: &str) -> Result<&Entry> {
        self.entries
            .get(name)
            .with_context(|| format!("no artifact entry {name}"))
    }

    pub fn entry_names(&self) -> Vec<&str> {
        self.entries.keys().map(|s| s.as_str()).collect()
    }

    fn ensure_compiled(&self, name: &str) -> Result<()> {
        let mut cache = self.compiled.lock().unwrap();
        if cache.contains_key(name) {
            return Ok(());
        }
        let entry = self.entry(name)?;
        let proto = xla::HloModuleProto::from_text_file(
            entry
                .file
                .to_str()
                .context("artifact path not utf-8")?,
        )
        .map_err(|e| anyhow::anyhow!("hlo parse {}: {e:?}", entry.file.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("pjrt compile {name}: {e:?}"))?;
        cache.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute `name` with the given input literals; returns the flattened
    /// tuple of outputs (entry points are lowered with `return_tuple=True`).
    pub fn execute(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        self.ensure_compiled(name)?;
        let cache = self.compiled.lock().unwrap();
        let exe = cache.get(name).unwrap();
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow::anyhow!("pjrt execute {name}: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("pjrt readback {name}: {e:?}"))?;
        lit.to_tuple()
            .map_err(|e| anyhow::anyhow!("pjrt tuple {name}: {e:?}"))
    }

    /// One k-means step over (possibly padded) points. Inputs are f32
    /// row-major; `mask[i] ∈ {0,1}` marks real rows. Returns
    /// `(sums[k*d], counts[k], inertia)` — the *partials*, so the caller can
    /// allreduce them in distributed mode before dividing.
    pub fn kmeans_step(
        &self,
        points: &[f32],
        mask: &[f32],
        centroids: &[f32],
    ) -> Result<(Vec<f32>, Vec<f32>, f32)> {
        let e = self.entry("kmeans_step")?;
        let (n, d, k) = (e.param("n")?, e.param("d")?, e.param("k")?);
        if points.len() != n * d || mask.len() != n || centroids.len() != k * d {
            bail!(
                "kmeans_step: shape mismatch points={} (want {}), mask={} (want {n}), centroids={} (want {})",
                points.len(),
                n * d,
                mask.len(),
                centroids.len(),
                k * d
            );
        }
        let px = xla::Literal::vec1(points)
            .reshape(&[n as i64, d as i64])
            .map_err(|e| anyhow::anyhow!("reshape points: {e:?}"))?;
        let mx = xla::Literal::vec1(mask);
        let cx = xla::Literal::vec1(centroids)
            .reshape(&[k as i64, d as i64])
            .map_err(|e| anyhow::anyhow!("reshape centroids: {e:?}"))?;
        let outs = self.execute("kmeans_step", &[px, mx, cx])?;
        let sums = outs[0]
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("sums readback: {e:?}"))?;
        let counts = outs[1]
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("counts readback: {e:?}"))?;
        let inertia = outs[2]
            .get_first_element::<f32>()
            .map_err(|e| anyhow::anyhow!("inertia readback: {e:?}"))?;
        Ok((sums, counts, inertia))
    }

    /// One logistic-regression gradient step (padded, masked). Returns
    /// `(grad[d+1], loss)` partials.
    pub fn logreg_step(
        &self,
        xs: &[f32],
        ys: &[f32],
        mask: &[f32],
        weights: &[f32],
    ) -> Result<(Vec<f32>, f32)> {
        let e = self.entry("logreg_step")?;
        let (n, d) = (e.param("n")?, e.param("d")?);
        if xs.len() != n * d || ys.len() != n || mask.len() != n || weights.len() != d + 1 {
            bail!("logreg_step: shape mismatch");
        }
        let xl = xla::Literal::vec1(xs)
            .reshape(&[n as i64, d as i64])
            .map_err(|e| anyhow::anyhow!("reshape xs: {e:?}"))?;
        let yl = xla::Literal::vec1(ys);
        let ml = xla::Literal::vec1(mask);
        let wl = xla::Literal::vec1(weights);
        let outs = self.execute("logreg_step", &[xl, yl, ml, wl])?;
        let grad = outs[0]
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("grad readback: {e:?}"))?;
        let loss = outs[1]
            .get_first_element::<f32>()
            .map_err(|e| anyhow::anyhow!("loss readback: {e:?}"))?;
        Ok((grad, loss))
    }

    /// Weighted moving average via the Pallas stencil kernel artifact.
    /// `x` is padded to the artifact length; returns the same length.
    pub fn wma(&self, x: &[f32], weights3: &[f32; 3]) -> Result<Vec<f32>> {
        let e = self.entry("wma")?;
        let n = e.param("n")?;
        if x.len() != n {
            bail!("wma: expected {n} samples, got {}", x.len());
        }
        let xl = xla::Literal::vec1(x);
        let wl = xla::Literal::vec1(&weights3[..]);
        let outs = self.execute("wma", &[xl, wl])?;
        outs[0]
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("wma readback: {e:?}"))
    }

    /// Feature standardization `(x - mean) / var` (the paper's Q26 step).
    pub fn standardize(&self, x: &[f32]) -> Result<Vec<f32>> {
        let e = self.entry("standardize")?;
        let n = e.param("n")?;
        if x.len() != n {
            bail!("standardize: expected {n} samples, got {}", x.len());
        }
        let xl = xla::Literal::vec1(x);
        let outs = self.execute("standardize", &[xl])?;
        outs[0]
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("standardize readback: {e:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parsing() {
        let dir = std::env::temp_dir().join("hiframes_test_rt");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.txt"),
            "# comment\nentry foo file=foo.hlo.txt n=8 d=2\n\nentry bar file=b.hlo.txt k=3\n",
        )
        .unwrap();
        let eng = Engine::load(&dir).unwrap();
        let e = eng.entry("foo").unwrap();
        assert_eq!(e.param("n").unwrap(), 8);
        assert_eq!(e.param("d").unwrap(), 2);
        assert!(e.param("zzz").is_err());
        assert!(eng.entry("nope").is_err());
        let mut names = eng.entry_names();
        names.sort();
        assert_eq!(names, vec!["bar", "foo"]);
    }

    #[test]
    fn manifest_errors() {
        let dir = std::env::temp_dir().join("hiframes_test_rt2");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.txt"), "entry foo n=8\n").unwrap();
        assert!(Engine::load(&dir).is_err()); // no file=
        std::fs::write(dir.join("manifest.txt"), "bogus foo file=x\n").unwrap();
        assert!(Engine::load(&dir).is_err());
        std::fs::write(dir.join("manifest.txt"), "entry foo file=x n=abc\n").unwrap();
        assert!(Engine::load(&dir).is_err());
    }
}
