//! Run configuration: a small `key = value` file format plus environment
//! overrides (`HIFRAMES_<KEY>`). The launcher, examples and benches all
//! read a [`Config`] so experiments are reproducible from checked-in files.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Parsed configuration map with typed accessors.
#[derive(Debug, Clone, Default)]
pub struct Config {
    values: BTreeMap<String, String>,
}

impl Config {
    pub fn new() -> Config {
        Config::default()
    }

    /// Parse from `key = value` text. `#` starts a comment; blank lines are
    /// ignored; later keys override earlier ones.
    pub fn from_str_cfg(text: &str) -> Result<Config> {
        let mut values = BTreeMap::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let Some((k, v)) = line.split_once('=') else {
                bail!("config line {}: expected key = value, got {raw:?}", lineno + 1);
            };
            values.insert(k.trim().to_string(), v.trim().to_string());
        }
        Ok(Config { values })
    }

    pub fn from_file(path: &Path) -> Result<Config> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Config::from_str_cfg(&text)
    }

    /// Apply `HIFRAMES_<KEY>` environment overrides for every known key and
    /// any extra keys listed in `extra_keys`.
    pub fn with_env_overrides(mut self, extra_keys: &[&str]) -> Config {
        let keys: Vec<String> = self
            .values
            .keys()
            .cloned()
            .chain(extra_keys.iter().map(|s| s.to_string()))
            .collect();
        for k in keys {
            let env_key = format!("HIFRAMES_{}", k.to_uppercase());
            if let Ok(v) = std::env::var(&env_key) {
                self.values.insert(k, v);
            }
        }
        self
    }

    pub fn set(&mut self, key: &str, value: &str) {
        self.values.insert(key.to_string(), value.to_string());
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.values.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .with_context(|| format!("config key {key}={v}: expected usize")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.values.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .with_context(|| format!("config key {key}={v}: expected f64")),
        }
    }

    pub fn get_bool(&self, key: &str, default: bool) -> Result<bool> {
        match self.values.get(key).map(|s| s.as_str()) {
            None => Ok(default),
            Some("true") | Some("1") | Some("yes") => Ok(true),
            Some("false") | Some("0") | Some("no") => Ok(false),
            Some(v) => bail!("config key {key}={v}: expected bool"),
        }
    }

    pub fn get_str<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.values.get(key).map(|s| s.as_str()).unwrap_or(default)
    }
}

/// Parse a byte size: a plain integer, optionally suffixed with `k`, `m`
/// or `g` (binary units, case-insensitive, optional trailing `b` as in
/// `64mb`). Returns `None` for empty, zero or unparseable input — zero is
/// the documented "unlimited" spelling for budget knobs.
pub fn parse_byte_size(s: &str) -> Option<usize> {
    let mut s = s.trim();
    if s.len() > 1 && s.as_bytes()[s.len() - 1].eq_ignore_ascii_case(&b'b') {
        s = &s[..s.len() - 1];
    }
    if s.is_empty() {
        return None;
    }
    let (num, mult) = match s.as_bytes()[s.len() - 1].to_ascii_lowercase() {
        b'k' => (&s[..s.len() - 1], 1usize << 10),
        b'm' => (&s[..s.len() - 1], 1usize << 20),
        b'g' => (&s[..s.len() - 1], 1usize << 30),
        _ => (s, 1),
    };
    let n: usize = num.trim().parse().ok()?;
    n.checked_mul(mult).filter(|&n| n > 0)
}

/// Raw value of one `HIFRAMES_*` environment knob. Unset and blank both
/// mean "use the default" (`None`); anything else comes back trimmed. Every
/// env knob (`HIFRAMES_MEM_BUDGET`, `HIFRAMES_DICT`, `HIFRAMES_PROFILE`,
/// `HIFRAMES_TICK_ROWS`, …) reads through this one helper so unset/blank
/// handling can't drift between knobs.
pub fn env_knob(var: &str) -> Option<String> {
    let v = std::env::var(var).ok()?;
    let t = v.trim();
    if t.is_empty() {
        None
    } else {
        Some(t.to_string())
    }
}

/// Uniform rejection message for a malformed knob value: names the
/// variable, echoes the offending text, and says what was expected.
pub fn knob_error(var: &str, value: &str, expected: &str) -> anyhow::Error {
    anyhow::anyhow!("{var}={value:?}: expected {expected}")
}

/// Per-rank memory budget from `HIFRAMES_MEM_BUDGET` (e.g. `64m`, `1g`,
/// `500000`). `None` — unset, empty, or `0` — means unlimited: every
/// operator stays on the in-memory path. See `ops/spill.rs` and
/// DESIGN.md §4.5.
pub fn mem_budget_from_env() -> Option<usize> {
    parse_byte_size(&env_knob("HIFRAMES_MEM_BUDGET")?)
}

/// Query profiling default from `HIFRAMES_PROFILE` (`1`/`true`/`yes`).
/// When on, every `collect()` records a [`crate::trace::QueryProfile`]
/// (per-node/per-rank spans); off — the default — the executor takes the
/// span-free hot path. See DESIGN.md §4.7.
pub fn profile_from_env() -> bool {
    matches!(
        env_knob("HIFRAMES_PROFILE").as_deref(),
        Some("1") | Some("true") | Some("yes")
    )
}

/// Parse one `HIFRAMES_TICK_ROWS` value: a positive row count. Split from
/// [`tick_rows_from_env`] so the rejection messages are testable without
/// mutating the process environment.
pub fn parse_tick_rows(s: &str) -> Result<usize> {
    match s.trim().parse::<usize>() {
        Ok(n) if n > 0 => Ok(n),
        _ => Err(knob_error(
            "HIFRAMES_TICK_ROWS",
            s,
            "a positive row count (e.g. 1024)",
        )),
    }
}

/// Default micro-batch size for streaming drivers from
/// `HIFRAMES_TICK_ROWS`: how many rows the fig13 bench (and any other
/// ticking driver) pushes per `Session::tick`. `None` — unset or blank —
/// leaves the driver's own default in force; a set but malformed value is
/// an error (knobs fail loudly, they are never silently ignored).
pub fn tick_rows_from_env() -> Result<Option<usize>> {
    env_knob("HIFRAMES_TICK_ROWS")
        .map(|v| parse_tick_rows(&v))
        .transpose()
}

/// Default worker count for this machine: physical-ish parallelism capped
/// at 8 (the benches sweep explicitly; this is just the default).
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(8)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_basics() {
        let c = Config::from_str_cfg(
            "workers = 4\n# comment\nrows=100  # trailing\n\nname = q26\n",
        )
        .unwrap();
        assert_eq!(c.get_usize("workers", 0).unwrap(), 4);
        assert_eq!(c.get_usize("rows", 0).unwrap(), 100);
        assert_eq!(c.get_str("name", ""), "q26");
        assert_eq!(c.get_usize("missing", 7).unwrap(), 7);
    }

    #[test]
    fn parse_errors() {
        assert!(Config::from_str_cfg("novalue\n").is_err());
        let c = Config::from_str_cfg("x = abc\n").unwrap();
        assert!(c.get_usize("x", 0).is_err());
        assert!(c.get_f64("x", 0.0).is_err());
        assert!(c.get_bool("x", false).is_err());
    }

    #[test]
    fn bools_and_floats() {
        let c = Config::from_str_cfg("a = true\nb = 0\nf = 2.5\n").unwrap();
        assert!(c.get_bool("a", false).unwrap());
        assert!(!c.get_bool("b", true).unwrap());
        assert_eq!(c.get_f64("f", 0.0).unwrap(), 2.5);
        assert!(c.get_bool("missing", true).unwrap());
    }

    #[test]
    fn later_overrides_earlier() {
        let c = Config::from_str_cfg("x = 1\nx = 2\n").unwrap();
        assert_eq!(c.get_usize("x", 0).unwrap(), 2);
    }

    #[test]
    fn env_override() {
        std::env::set_var("HIFRAMES_TESTKEY_UNIQ", "99");
        let c = Config::from_str_cfg("testkey_uniq = 1\n")
            .unwrap()
            .with_env_overrides(&[]);
        assert_eq!(c.get_usize("testkey_uniq", 0).unwrap(), 99);
        std::env::remove_var("HIFRAMES_TESTKEY_UNIQ");
    }

    #[test]
    fn profile_env_parses() {
        // No set_var round-trip here: flipping HIFRAMES_PROFILE mid-run
        // would change sibling tests' ExecOptions defaults. Profiling is
        // result-identical either way, but keep the suite deterministic.
        match std::env::var("HIFRAMES_PROFILE").as_deref() {
            Ok("1") | Ok("true") | Ok("yes") => assert!(profile_from_env()),
            _ => assert!(!profile_from_env()),
        }
    }

    #[test]
    fn default_workers_positive() {
        assert!(default_workers() >= 1);
    }

    #[test]
    fn env_knob_trims_and_blanks() {
        // Unique throwaway keys so parallel tests can't collide.
        std::env::set_var("HIFRAMES_KNOBTEST_UNIQ", "  42  ");
        assert_eq!(env_knob("HIFRAMES_KNOBTEST_UNIQ").as_deref(), Some("42"));
        std::env::set_var("HIFRAMES_KNOBTEST_UNIQ", "   ");
        assert_eq!(env_knob("HIFRAMES_KNOBTEST_UNIQ"), None, "blank = unset");
        std::env::remove_var("HIFRAMES_KNOBTEST_UNIQ");
        assert_eq!(env_knob("HIFRAMES_KNOBTEST_UNIQ"), None);
    }

    #[test]
    fn tick_rows_accepts_positive_counts() {
        assert_eq!(parse_tick_rows("1").unwrap(), 1);
        assert_eq!(parse_tick_rows(" 1024 ").unwrap(), 1024);
    }

    #[test]
    fn tick_rows_rejects_malformed_values_with_named_messages() {
        for bad in ["0", "-3", "1.5", "abc", "1k", ""] {
            let err = parse_tick_rows(bad).unwrap_err().to_string();
            assert!(
                err.contains("HIFRAMES_TICK_ROWS") && err.contains("positive row count"),
                "rejection for {bad:?} must name the knob and the expected form: {err}"
            );
        }
    }

    #[test]
    fn tick_rows_env_parses() {
        // Like profile_env_parses: no set_var round-trip on a knob that a
        // sibling test's driver might read mid-run.
        match env_knob("HIFRAMES_TICK_ROWS") {
            None => assert!(tick_rows_from_env().unwrap().is_none()),
            Some(v) => match parse_tick_rows(&v) {
                Ok(n) => assert_eq!(tick_rows_from_env().unwrap(), Some(n)),
                Err(_) => assert!(tick_rows_from_env().is_err()),
            },
        }
    }

    #[test]
    fn byte_sizes_parse() {
        assert_eq!(parse_byte_size("12345"), Some(12345));
        assert_eq!(parse_byte_size(" 4k "), Some(4096));
        assert_eq!(parse_byte_size("2K"), Some(2048));
        assert_eq!(parse_byte_size("3m"), Some(3 << 20));
        assert_eq!(parse_byte_size("64mb"), Some(64 << 20));
        assert_eq!(parse_byte_size("1G"), Some(1 << 30));
        assert_eq!(parse_byte_size("0"), None, "zero means unlimited");
        assert_eq!(parse_byte_size("0k"), None);
        assert_eq!(parse_byte_size(""), None);
        assert_eq!(parse_byte_size("nope"), None);
        assert_eq!(parse_byte_size("b"), None);
    }
}
