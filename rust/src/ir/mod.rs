//! The logical IR — the paper's Domain-Pass output (§4.2): relational
//! operations as first-class plan nodes living in the *same* graph as
//! non-relational array computations ([`Plan::WithColumn`]) and ML calls
//! ([`Plan::MlCall`]). This is what lets the DataFrame-Pass build a "query
//! tree over only the relational nodes while other nodes are ignored" and
//! still validate transformations against the whole program (liveness).

use crate::distribution::Dist;
use crate::expr::{AggExpr, Expr};
use crate::table::{Schema, Table};
use crate::types::DType;
pub use crate::types::{JoinStrategy, JoinType, SortOrder, WindowFrame, WindowFunc};
use anyhow::{bail, Context, Result};
use std::collections::BTreeSet;
use std::fmt;
use std::path::PathBuf;
use std::sync::Arc;

pub mod graph;

/// Where a source data frame's rows come from.
#[derive(Debug, Clone)]
pub enum SourceRef {
    /// Shared in-memory table (tests, generated workloads).
    InMemory(Arc<Table>),
    /// HFS columnar file — ranks read their hyperslab (paper's
    /// `H5Sselect_hyperslab` pattern, Fig. 5).
    Hfs(PathBuf),
}

/// Parameters of an [`Plan::MlCall`].
#[derive(Debug, Clone, PartialEq)]
pub struct MlParams {
    /// `"kmeans"` or `"logreg"`.
    pub model: String,
    /// Number of clusters (kmeans) / classes (logreg).
    pub k: usize,
    pub iters: usize,
    /// Execute via PJRT artifacts (L2/L1 path) or the pure-rust kernel.
    pub use_pjrt: bool,
}

/// One output column of a [`Plan::Window`]: `:out = func frame(input)`.
/// The input expression is evaluated *before* the window (the paper's
/// expression-array desugaring), so any expression — not just a bare column
/// reference — can feed a window.
#[derive(Debug, Clone, PartialEq)]
pub struct WindowAgg {
    pub out: String,
    pub func: WindowFunc,
    pub frame: WindowFrame,
    pub input: Expr,
}

impl WindowAgg {
    pub fn new(out: &str, func: WindowFunc, frame: WindowFrame, input: Expr) -> WindowAgg {
        WindowAgg {
            out: out.to_string(),
            func,
            frame,
            input,
        }
    }

    /// Does this aggregate need neighbor rows beyond the local block (i.e.
    /// a halo exchange when the window is global)? Position functions and
    /// scans never do.
    pub fn needs_halo(&self) -> bool {
        !self.func.is_positional() && self.frame.halo() != (0, 0)
    }
}

impl fmt::Display for WindowAgg {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, ":{} = {} {}({})", self.out, self.func, self.frame, self.input)
    }
}

/// A logical plan tree. Each node's output is a data frame whose columns
/// are, at execution time, individual arrays per rank (dual representation).
#[derive(Debug, Clone)]
pub enum Plan {
    /// Read a named data frame (the `DataSource` construct, §3.1).
    Source {
        name: String,
        src: SourceRef,
        schema: Schema,
    },
    /// `df[pred]` — row filter.
    Filter { input: Box<Plan>, predicate: Expr },
    /// Keep a subset of columns (projection; also inserted by pruning).
    Project {
        input: Box<Plan>,
        columns: Vec<String>,
    },
    /// `df[:new] = expr` — non-relational array computation on columns.
    WithColumn {
        input: Box<Plan>,
        name: String,
        expr: Expr,
    },
    /// Rename one column (used by pushdown plumbing and self-joins).
    Rename {
        input: Box<Plan>,
        from: String,
        to: String,
    },
    /// Equi-join over a composite key list with a join type:
    /// `join(l, r, [:lk1 == :rk1, :lk2 == :rk2], how)`. Output key columns
    /// keep the left names; for Left/Right/Outer the nullable side's payload
    /// columns keep their native dtype and become *nullable* (validity
    /// masks); Semi/Anti keep only the left schema.
    Join {
        left: Box<Plan>,
        right: Box<Plan>,
        /// `(left_key, right_key)` pairs; equal, groupable dtypes per pair.
        on: Vec<(String, String)>,
        how: JoinType,
        /// Physical strategy hint: plain hash shuffle or the skew-aware
        /// heavy-hitter broadcast path. Purely an execution hint — it never
        /// changes the output relation, only how rows are routed.
        strategy: JoinStrategy,
    },
    /// `aggregate(df, [:k1, :k2], :out = fn(expr), …)` — group-by over a
    /// composite key list.
    Aggregate {
        input: Box<Plan>,
        keys: Vec<String>,
        aggs: Vec<AggExpr>,
    },
    /// Vertical concatenation `[df1; df2]` (same schema).
    Concat { inputs: Vec<Box<Plan>> },
    /// Window functions over frames (the unified analytics node subsuming
    /// the former `Cumsum`/`Stencil` special cases): each [`WindowAgg`]
    /// applies a [`WindowFunc`] over a [`WindowFrame`] around every row.
    /// With an empty `partition_by` the window is *global* — rows keep
    /// their 1D-block order and the lowering is a halo exchange / `exscan`
    /// (the communication patterns map-reduce cannot express, §4.5). With
    /// partition keys the rows of each partition are colocated by a hash
    /// shuffle, ordered locally by `order_by` (partition order is
    /// rank-local, like every relational output), and scanned per group —
    /// no halo ever crosses a partition boundary.
    Window {
        input: Box<Plan>,
        /// Hash-colocation keys; empty = one global window in row order.
        partition_by: Vec<String>,
        /// Within-partition ordering (requires `partition_by`; global
        /// windows run in the frame's existing row order — sort first).
        order_by: Vec<(String, SortOrder)>,
        aggs: Vec<WindowAgg>,
    },
    /// Global sort by a composite key list with per-key directions (result
    /// canonicalization; TPCx-BB multi-column ORDER BY / top-N).
    Sort {
        input: Box<Plan>,
        keys: Vec<(String, SortOrder)>,
    },
    /// Redistribute a 1D_VAR frame to 1D_BLOCK (inserted by the
    /// Distributed-Pass; never written by users).
    Rebalance { input: Box<Plan> },
    /// `transpose(typed_hcat(Float64, cols…))` — ML matrix assembly
    /// (pattern-matched by Domain-Pass in the paper, §4.2).
    MatrixAssembly {
        input: Box<Plan>,
        columns: Vec<String>,
    },
    /// Call into the AOT-compiled analytics model (k-means / logreg).
    MlCall {
        input: Box<Plan>,
        params: MlParams,
    },
    /// Explicit materialization point (`df.cache()`): relationally the
    /// identity, but the executor memoizes through it and the plan cache
    /// keys cached tables by the structural identity of `input` — so users
    /// can pin a shared subplan that hash-consing cannot see across
    /// separate `collect()` calls. Opaque to pushdown and pruning (the
    /// pinned result must not depend on what a particular consumer reads).
    Cache { input: Box<Plan> },
}

impl Plan {
    /// Output schema. Errors surface unknown columns / type errors — the
    /// "complete type inference" requirement of the Macro-Pass (§4.1).
    pub fn schema(&self) -> Result<Schema> {
        match self {
            Plan::Source { schema, .. } => Ok(schema.clone()),
            Plan::Filter { input, predicate } => {
                let s = input.schema()?;
                let t = predicate.dtype(&s)?;
                if t != DType::Bool {
                    bail!("filter predicate has dtype {t}, expected Bool");
                }
                Ok(s)
            }
            Plan::Project { input, columns } => {
                let s = input.schema()?;
                let mut fields = Vec::new();
                let mut nullable = Vec::new();
                for c in columns {
                    let dt = s
                        .dtype_of(c)
                        .with_context(|| format!("project: unknown column :{c}"))?;
                    fields.push((c.clone(), dt));
                    nullable.push(s.nullable_of(c).unwrap_or(false));
                }
                Ok(Schema::new_nullable(fields, nullable))
            }
            Plan::WithColumn { input, name, expr } => {
                let s = input.schema()?;
                let dt = expr.dtype(&s)?;
                let nl = expr.nullable(&s)?;
                let mut fields: Vec<(String, DType)> = Vec::new();
                let mut nullable = Vec::new();
                for (i, (n, t)) in s.fields().iter().enumerate() {
                    if n != name {
                        fields.push((n.clone(), *t));
                        nullable.push(s.nullable_at(i));
                    }
                }
                fields.push((name.clone(), dt));
                nullable.push(nl);
                Ok(Schema::new_nullable(fields, nullable))
            }
            Plan::Rename { input, from, to } => {
                let s = input.schema()?;
                if s.dtype_of(from).is_none() {
                    bail!("rename: unknown column :{from}");
                }
                if s.dtype_of(to).is_some() {
                    bail!("rename: column :{to} already exists");
                }
                Ok(Schema::new_nullable(
                    s.fields()
                        .iter()
                        .map(|(n, t)| {
                            if n == from {
                                (to.clone(), *t)
                            } else {
                                (n.clone(), *t)
                            }
                        })
                        .collect(),
                    s.nullable_flags().to_vec(),
                ))
            }
            Plan::Join {
                left,
                right,
                on,
                how,
                ..
            } => {
                let ls = left.schema()?;
                let rs = right.schema()?;
                if on.is_empty() {
                    bail!("join: needs at least one key pair");
                }
                let mut lkeys: BTreeSet<&str> = BTreeSet::new();
                let mut rkeys: BTreeSet<&str> = BTreeSet::new();
                for (lk, rk) in on {
                    let lt = ls
                        .dtype_of(lk)
                        .with_context(|| format!("join: unknown left key :{lk}"))?;
                    let rt = rs
                        .dtype_of(rk)
                        .with_context(|| format!("join: unknown right key :{rk}"))?;
                    if lt != rt {
                        bail!("join: key pair :{lk} ({lt}) vs :{rk} ({rt}) dtype mismatch");
                    }
                    if !lt.is_groupable() {
                        bail!("join key :{lk} must be Int64/Bool/String, got {lt}");
                    }
                    if !lkeys.insert(lk.as_str()) {
                        bail!("join: duplicate left key :{lk}");
                    }
                    if !rkeys.insert(rk.as_str()) {
                        bail!("join: duplicate right key :{rk}");
                    }
                }
                // Semi/Anti only filter the left side
                if !how.keeps_right_columns() {
                    return Ok(ls);
                }
                // output: all left columns in order, then right columns
                // minus its keys. Dtypes are *preserved*; the
                // null-introducing side(s) become nullable instead of
                // promoting to F64/NaN. A key slot is nullable iff either
                // input key is (null keys match null keys).
                let mut fields = Vec::new();
                let mut nullable = Vec::new();
                for (i, (n, t)) in ls.fields().iter().enumerate() {
                    fields.push((n.clone(), *t));
                    if let Some((_, rk)) = on.iter().find(|(lk, _)| lk == n) {
                        nullable.push(
                            ls.nullable_at(i) || rs.nullable_of(rk).unwrap_or(false),
                        );
                    } else {
                        nullable.push(ls.nullable_at(i) || how.nullable_left());
                    }
                }
                for (i, (n, t)) in rs.fields().iter().enumerate() {
                    if rkeys.contains(n.as_str()) {
                        continue;
                    }
                    if ls.dtype_of(n).is_some() {
                        bail!("join: column :{n} exists on both sides — rename first");
                    }
                    fields.push((n.clone(), *t));
                    nullable.push(rs.nullable_at(i) || how.nullable_right());
                }
                Ok(Schema::new_nullable(fields, nullable))
            }
            Plan::Aggregate { input, keys, aggs } => {
                let s = input.schema()?;
                if keys.is_empty() {
                    bail!("aggregate: needs at least one key column");
                }
                let mut fields = Vec::new();
                let mut nullable = Vec::new();
                for key in keys {
                    let kt = s
                        .dtype_of(key)
                        .with_context(|| format!("aggregate: unknown key :{key}"))?;
                    if !kt.is_groupable() {
                        bail!("aggregate key :{key} must be Int64/Bool/String, got {kt}");
                    }
                    if fields.iter().any(|(n, _)| n == key) {
                        bail!("aggregate: duplicate key :{key}");
                    }
                    fields.push((key.clone(), kt));
                    // a nullable key keeps its null group in the output
                    nullable.push(s.nullable_of(key).unwrap_or(false));
                }
                for a in aggs {
                    if fields.iter().any(|(n, _)| n == &a.out) {
                        bail!("aggregate: duplicate output column :{}", a.out);
                    }
                    fields.push((a.out.clone(), a.output_dtype(&s)?));
                    nullable.push(a.output_nullable(&s)?);
                }
                Ok(Schema::new_nullable(fields, nullable))
            }
            Plan::Concat { inputs } => {
                let first = inputs
                    .first()
                    .context("concat: needs at least one input")?
                    .schema()?;
                for other in &inputs[1..] {
                    let s = other.schema()?;
                    if !first.same_as(&s) {
                        bail!("concat: schema mismatch {first} vs {s}");
                    }
                }
                Ok(first)
            }
            Plan::Window {
                input,
                partition_by,
                order_by,
                aggs,
            } => {
                let s = input.schema()?;
                if aggs.is_empty() {
                    bail!("window: needs at least one aggregate");
                }
                if partition_by.is_empty() && !order_by.is_empty() {
                    bail!(
                        "window: order_by requires partition_by — global windows \
                         run in block row order (sort the frame first)"
                    );
                }
                let mut seen_keys: BTreeSet<&str> = BTreeSet::new();
                for key in partition_by {
                    let kt = s
                        .dtype_of(key)
                        .with_context(|| format!("window: unknown partition key :{key}"))?;
                    if !kt.is_groupable() {
                        bail!("window partition key :{key} must be Int64/Bool/String, got {kt}");
                    }
                    if !seen_keys.insert(key.as_str()) {
                        bail!("window: duplicate partition key :{key}");
                    }
                }
                for (key, _) in order_by {
                    let kt = s
                        .dtype_of(key)
                        .with_context(|| format!("window: unknown order key :{key}"))?;
                    if !kt.is_groupable() {
                        bail!("window order key :{key} must be Int64/Bool/String, got {kt}");
                    }
                }
                // validate each aggregate and compute its output field
                let mut outs: Vec<(String, DType, bool)> = Vec::new();
                for a in aggs {
                    let dt = a.input.dtype(&s)?;
                    let nl = a.input.nullable(&s)?;
                    if a.func.needs_numeric_input() && !dt.is_numeric() {
                        bail!("window {}: non-numeric input column ({dt})", a.func);
                    }
                    match (&a.func, &a.frame) {
                        (WindowFunc::Value, WindowFrame::Shift(_)) => {}
                        (WindowFunc::Value, f) => {
                            bail!("window value() requires a shift frame, got {f}")
                        }
                        (_, WindowFrame::Shift(_)) => bail!(
                            "window shift frame only carries value() — use \
                             col(..).shift(n)/lag(n)/lead(n)"
                        ),
                        (WindowFunc::Weighted(w), WindowFrame::Rolling { preceding, following }) => {
                            if w.is_empty() || w.len() != preceding + following + 1 {
                                bail!(
                                    "window weighted({}) does not match rolling[{preceding},\
                                     {following}] (need {} weights)",
                                    w.len(),
                                    preceding + following + 1
                                );
                            }
                        }
                        (WindowFunc::Weighted(_), f) => {
                            bail!("window weighted() requires a rolling frame, got {f}")
                        }
                        _ => {}
                    }
                    if matches!(a.func, WindowFunc::Rank) && order_by.is_empty() {
                        bail!("window rank() requires order_by keys");
                    }
                    if partition_by.iter().any(|k| k == &a.out)
                        || order_by.iter().any(|(k, _)| k == &a.out)
                    {
                        bail!("window: output :{} collides with a window key", a.out);
                    }
                    if outs.iter().any(|(n, _, _)| n == &a.out) {
                        bail!("window: duplicate output column :{}", a.out);
                    }
                    outs.push((
                        a.out.clone(),
                        a.func.output_dtype(dt),
                        a.func.output_nullable(&a.frame, nl),
                    ));
                }
                // input fields (minus replaced outputs), then the outputs
                let mut fields: Vec<(String, DType)> = Vec::new();
                let mut nullable = Vec::new();
                for (i, (n, t)) in s.fields().iter().enumerate() {
                    if !outs.iter().any(|(o, _, _)| o == n) {
                        fields.push((n.clone(), *t));
                        nullable.push(s.nullable_at(i));
                    }
                }
                for (n, t, nl) in outs {
                    fields.push((n, t));
                    nullable.push(nl);
                }
                Ok(Schema::new_nullable(fields, nullable))
            }
            Plan::Sort { input, keys } => {
                let s = input.schema()?;
                if keys.is_empty() {
                    bail!("sort: needs at least one key column");
                }
                for (key, _) in keys {
                    let kt = s
                        .dtype_of(key)
                        .with_context(|| format!("sort: unknown key :{key}"))?;
                    if !kt.is_groupable() {
                        bail!("sort key :{key} must be Int64/Bool/String, got {kt}");
                    }
                }
                Ok(s)
            }
            Plan::Rebalance { input } => input.schema(),
            Plan::MatrixAssembly { input, columns } => {
                let s = input.schema()?;
                let mut fields = Vec::new();
                for (i, c) in columns.iter().enumerate() {
                    let dt = s
                        .dtype_of(c)
                        .with_context(|| format!("matrix assembly: unknown column :{c}"))?;
                    if !(dt.is_numeric() || dt == DType::Bool) {
                        bail!("matrix assembly: column :{c} is {dt}, not castable");
                    }
                    if s.nullable_of(c) == Some(true) {
                        bail!("matrix assembly: column :{c} is nullable — fill_null first");
                    }
                    fields.push((format!("f{i}"), DType::F64));
                }
                Ok(Schema::new(fields))
            }
            Plan::MlCall { input, params } => {
                let s = input.schema()?;
                // kmeans: k centroid rows over the input features, tagged
                // with :cluster; logreg: one row of weights (+bias as f_n).
                let mut fields = s.fields().to_vec();
                fields.push(("cluster".to_string(), DType::I64));
                let _ = params;
                Ok(Schema::new(fields))
            }
            Plan::Cache { input } => input.schema(),
        }
    }

    /// Children accessor (for generic traversals).
    pub fn children(&self) -> Vec<&Plan> {
        match self {
            Plan::Source { .. } => vec![],
            Plan::Filter { input, .. }
            | Plan::Project { input, .. }
            | Plan::WithColumn { input, .. }
            | Plan::Rename { input, .. }
            | Plan::Aggregate { input, .. }
            | Plan::Window { input, .. }
            | Plan::Sort { input, .. }
            | Plan::Rebalance { input }
            | Plan::MatrixAssembly { input, .. }
            | Plan::MlCall { input, .. }
            | Plan::Cache { input } => vec![input],
            Plan::Join { left, right, .. } => vec![left, right],
            Plan::Concat { inputs } => inputs.iter().map(|b| b.as_ref()).collect(),
        }
    }

    /// Distribution transfer function (paper §4.4): bottom-up meet over the
    /// semilattice. A tree has no cycles, so one pass *is* the fixed point.
    pub fn dist(&self) -> Dist {
        match self {
            Plan::Source { .. } => Dist::OneD,
            // relational outputs have data-dependent sizes: meet with 1D_VAR
            Plan::Filter { input, .. } => Dist::OneDVar.meet(input.dist()),
            Plan::Join { left, right, .. } => {
                Dist::OneDVar.meet(left.dist()).meet(right.dist())
            }
            Plan::Aggregate { input, .. } => Dist::OneDVar.meet(input.dist()),
            Plan::Concat { inputs } => {
                Dist::meet_all(inputs.iter().map(|p| p.dist())).meet(Dist::OneDVar)
            }
            // element-wise ops preserve their input's distribution
            Plan::Project { input, .. }
            | Plan::WithColumn { input, .. }
            | Plan::Rename { input, .. } => input.dist(),
            // a global window is element-wise over the row order; a
            // partitioned window shuffles, so its chunks are data dependent
            Plan::Window {
                input,
                partition_by,
                ..
            } => {
                if partition_by.is_empty() {
                    input.dist()
                } else {
                    Dist::OneDVar.meet(input.dist())
                }
            }
            // sort range-repartitions → chunk sizes are data-dependent
            Plan::Sort { input, .. } => Dist::OneDVar.meet(input.dist()),
            Plan::Rebalance { .. } => Dist::OneD,
            Plan::MatrixAssembly { input, .. } => input.dist(),
            // model output is replicated on every rank
            Plan::MlCall { .. } => Dist::Rep,
            // identity: rows stay where the input left them
            Plan::Cache { input } => input.dist(),
        }
    }

    /// Does this node require its input in `1D_BLOCK` (paper §4.4: "some
    /// operations … require 1D_BLOCK distribution for their input arrays")?
    /// Global windows with a halo-carrying frame do — their near-neighbor
    /// exchange assumes block-sized chunks (with a gather fallback for tiny
    /// blocks); scans (`exscan`) and partitioned windows (shuffle) don't.
    pub fn requires_block_input(&self) -> bool {
        match self {
            Plan::MatrixAssembly { .. } => true,
            Plan::Window {
                partition_by, aggs, ..
            } => partition_by.is_empty() && aggs.iter().any(|a| a.needs_halo()),
            _ => false,
        }
    }

    /// Number of nodes (plan-size metric for pass tests).
    pub fn size(&self) -> usize {
        1 + self.children().iter().map(|c| c.size()).sum::<usize>()
    }

    /// Rebuild this node with each direct child replaced by `f(child)` —
    /// the one-level counterpart of [`crate::passes::domain::map_plan`],
    /// for passes that need to control their own recursion order (the
    /// join-reorder pass walks top-down so it can see whole join chains).
    pub fn map_children(self, f: &mut dyn FnMut(Plan) -> Plan) -> Plan {
        let mut one = |b: Box<Plan>| Box::new(f(*b));
        match self {
            s @ Plan::Source { .. } => s,
            Plan::Filter { input, predicate } => Plan::Filter {
                input: one(input),
                predicate,
            },
            Plan::Project { input, columns } => Plan::Project {
                input: one(input),
                columns,
            },
            Plan::WithColumn { input, name, expr } => Plan::WithColumn {
                input: one(input),
                name,
                expr,
            },
            Plan::Rename { input, from, to } => Plan::Rename {
                input: one(input),
                from,
                to,
            },
            Plan::Join {
                left,
                right,
                on,
                how,
                strategy,
            } => {
                let left = one(left);
                let right = one(right);
                Plan::Join {
                    left,
                    right,
                    on,
                    how,
                    strategy,
                }
            }
            Plan::Aggregate { input, keys, aggs } => Plan::Aggregate {
                input: one(input),
                keys,
                aggs,
            },
            Plan::Concat { inputs } => Plan::Concat {
                inputs: inputs.into_iter().map(&mut one).collect(),
            },
            Plan::Window {
                input,
                partition_by,
                order_by,
                aggs,
            } => Plan::Window {
                input: one(input),
                partition_by,
                order_by,
                aggs,
            },
            Plan::Sort { input, keys } => Plan::Sort {
                input: one(input),
                keys,
            },
            Plan::Rebalance { input } => Plan::Rebalance { input: one(input) },
            Plan::MatrixAssembly { input, columns } => Plan::MatrixAssembly {
                input: one(input),
                columns,
            },
            Plan::MlCall { input, params } => Plan::MlCall {
                input: one(input),
                params,
            },
            Plan::Cache { input } => Plan::Cache { input: one(input) },
        }
    }

    fn fmt_indent(&self, f: &mut fmt::Formatter<'_>, depth: usize) -> fmt::Result {
        let pad = "  ".repeat(depth);
        let dist = self.dist();
        match self {
            Plan::Source { name, .. } => writeln!(f, "{pad}Source({name}) [{dist}]")?,
            Plan::Filter { predicate, .. } => writeln!(f, "{pad}Filter({predicate}) [{dist}]")?,
            Plan::Project { columns, .. } => {
                writeln!(f, "{pad}Project({}) [{dist}]", columns.join(", "))?
            }
            Plan::WithColumn { name, expr, .. } => {
                writeln!(f, "{pad}WithColumn(:{name} = {expr}) [{dist}]")?
            }
            Plan::Rename { from, to, .. } => {
                writeln!(f, "{pad}Rename(:{from} -> :{to}) [{dist}]")?
            }
            Plan::Join {
                on, how, strategy, ..
            } => {
                let pairs: Vec<String> = on
                    .iter()
                    .map(|(lk, rk)| format!(":{lk} == :{rk}"))
                    .collect();
                match strategy {
                    JoinStrategy::Hash => writeln!(
                        f,
                        "{pad}Join({}, how={how}) [{dist}]",
                        pairs.join(" && ")
                    )?,
                    other => writeln!(
                        f,
                        "{pad}Join({}, how={how}, strategy={other}) [{dist}]",
                        pairs.join(" && ")
                    )?,
                }
            }
            Plan::Aggregate { keys, aggs, .. } => {
                let ks: Vec<String> = keys.iter().map(|k| format!(":{k}")).collect();
                let parts: Vec<String> = aggs.iter().map(|a| a.to_string()).collect();
                writeln!(
                    f,
                    "{pad}Aggregate({}; {}) [{dist}]",
                    ks.join(", "),
                    parts.join(", ")
                )?
            }
            Plan::Concat { inputs } => {
                writeln!(f, "{pad}Concat({} inputs) [{dist}]", inputs.len())?
            }
            Plan::Window {
                partition_by,
                order_by,
                aggs,
                ..
            } => {
                let parts: Vec<String> = aggs.iter().map(|a| a.to_string()).collect();
                if partition_by.is_empty() {
                    writeln!(f, "{pad}Window({}) [{dist}]", parts.join(", "))?
                } else {
                    let ks: Vec<String> =
                        partition_by.iter().map(|k| format!(":{k}")).collect();
                    let os: Vec<String> = order_by
                        .iter()
                        .map(|(k, o)| format!(":{k} {o}"))
                        .collect();
                    writeln!(
                        f,
                        "{pad}Window(partition_by=[{}], order_by=[{}]; {}) [{dist}]",
                        ks.join(", "),
                        os.join(", "),
                        parts.join(", ")
                    )?
                }
            }
            Plan::Sort { keys, .. } => {
                let ks: Vec<String> = keys
                    .iter()
                    .map(|(k, o)| format!(":{k} {o}"))
                    .collect();
                writeln!(f, "{pad}Sort({}) [{dist}]", ks.join(", "))?
            }
            Plan::Rebalance { .. } => writeln!(f, "{pad}Rebalance [{dist}]")?,
            Plan::MatrixAssembly { columns, .. } => {
                writeln!(f, "{pad}MatrixAssembly({}) [{dist}]", columns.join(", "))?
            }
            Plan::MlCall { params, .. } => writeln!(
                f,
                "{pad}MlCall({}, k={}, iters={}, pjrt={}) [{dist}]",
                params.model, params.k, params.iters, params.use_pjrt
            )?,
            Plan::Cache { .. } => writeln!(f, "{pad}Cache [{dist}]")?,
        }
        for c in self.children() {
            c.fmt_indent(f, depth + 1)?;
        }
        Ok(())
    }
}

impl fmt::Display for Plan {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        self.fmt_indent(f, 0)
    }
}

/// Build an in-memory source node.
pub fn source_mem(name: &str, table: Table) -> Plan {
    let schema = table.schema().clone();
    Plan::Source {
        name: name.to_string(),
        src: SourceRef::InMemory(Arc::new(table)),
        schema,
    }
}

/// Build an HFS file source node.
pub fn source_hfs(name: &str, path: PathBuf, schema: Schema) -> Plan {
    Plan::Source {
        name: name.to_string(),
        src: SourceRef::Hfs(path),
        schema,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::column::Column;
    use crate::expr::{col, lit, AggExpr, AggFn};

    fn src() -> Plan {
        source_mem(
            "t",
            Table::from_pairs(vec![
                ("id", Column::I64(vec![1, 2])),
                ("x", Column::F64(vec![0.5, 1.5])),
            ])
            .unwrap(),
        )
    }

    #[test]
    fn schema_filter_ok_and_type_checked() {
        let p = Plan::Filter {
            input: Box::new(src()),
            predicate: col("x").lt(lit(1.0)),
        };
        assert_eq!(p.schema().unwrap().names(), vec!["id", "x"]);
        let bad = Plan::Filter {
            input: Box::new(src()),
            predicate: col("x").add(lit(1.0)),
        };
        assert!(bad.schema().is_err());
    }

    fn right_src() -> Plan {
        source_mem(
            "r",
            Table::from_pairs(vec![
                ("cid", Column::I64(vec![1])),
                ("y", Column::F64(vec![2.0])),
                ("tag", Column::I64(vec![9])),
            ])
            .unwrap(),
        )
    }

    #[test]
    fn schema_join_merges_and_rejects_collisions() {
        let j = Plan::Join {
            left: Box::new(src()),
            right: Box::new(right_src()),
            on: vec![("id".into(), "cid".into())],
            how: JoinType::Inner,
            strategy: JoinStrategy::Hash,
        };
        assert_eq!(j.schema().unwrap().names(), vec!["id", "x", "y", "tag"]);

        let collide = Plan::Join {
            left: Box::new(src()),
            right: Box::new(src()),
            on: vec![("id".into(), "id".into())],
            how: JoinType::Inner,
            strategy: JoinStrategy::Hash,
        };
        assert!(collide.schema().is_err()); // :x on both sides
    }

    #[test]
    fn schema_join_validates_key_pairs() {
        // dtype mismatch across a pair
        let bad = Plan::Join {
            left: Box::new(src()),
            right: Box::new(right_src()),
            on: vec![("x".into(), "cid".into())],
            how: JoinType::Inner,
            strategy: JoinStrategy::Hash,
        };
        assert!(bad.schema().is_err()); // F64 key and mismatch
        // empty key list
        let empty = Plan::Join {
            left: Box::new(src()),
            right: Box::new(right_src()),
            on: vec![],
            how: JoinType::Inner,
            strategy: JoinStrategy::Hash,
        };
        assert!(empty.schema().is_err());
        // duplicate left key
        let dup = Plan::Join {
            left: Box::new(src()),
            right: Box::new(right_src()),
            on: vec![("id".into(), "cid".into()), ("id".into(), "tag".into())],
            how: JoinType::Inner,
            strategy: JoinStrategy::Hash,
        };
        assert!(dup.schema().is_err());
    }

    #[test]
    fn schema_outer_joins_introduce_nullability_not_promotion() {
        // Left join: right payload keeps its dtype and becomes *nullable*
        let j = Plan::Join {
            left: Box::new(src()),
            right: Box::new(right_src()),
            on: vec![("id".into(), "cid".into())],
            how: JoinType::Left,
            strategy: JoinStrategy::Hash,
        };
        let s = j.schema().unwrap();
        assert_eq!(s.dtype_of("id"), Some(DType::I64)); // key slot
        assert_eq!(s.nullable_of("id"), Some(false)); // non-null inputs → non-null key
        assert_eq!(s.dtype_of("x"), Some(DType::F64)); // left side intact
        assert_eq!(s.nullable_of("x"), Some(false));
        assert_eq!(s.dtype_of("tag"), Some(DType::I64)); // dtype preserved!
        assert_eq!(s.nullable_of("tag"), Some(true)); // …but nullable
        // Right join: left payload becomes nullable instead
        let j = Plan::Join {
            left: Box::new(src()),
            right: Box::new(right_src()),
            on: vec![("id".into(), "cid".into())],
            how: JoinType::Right,
            strategy: JoinStrategy::Hash,
        };
        let s = j.schema().unwrap();
        assert_eq!(s.nullable_of("x"), Some(true));
        assert_eq!(s.dtype_of("tag"), Some(DType::I64));
        assert_eq!(s.nullable_of("tag"), Some(false)); // right side intact
        // Outer: both payloads nullable, dtypes still native
        let j = Plan::Join {
            left: Box::new(src()),
            right: Box::new(right_src()),
            on: vec![("id".into(), "cid".into())],
            how: JoinType::Outer,
            strategy: JoinStrategy::Hash,
        };
        let s = j.schema().unwrap();
        assert_eq!(s.dtype_of("id"), Some(DType::I64));
        assert_eq!(s.nullable_of("id"), Some(false));
        assert_eq!(s.dtype_of("tag"), Some(DType::I64));
        assert_eq!(s.nullable_of("x"), Some(true));
        assert_eq!(s.nullable_of("tag"), Some(true));
    }

    #[test]
    fn nullable_inputs_propagate_and_window_accepts_them() {
        // a left join output feeding further ops: nullable columns propagate
        // through WithColumn expressions; windows accept nullable inputs and
        // type the outputs through the null-aware rules (matrix assembly
        // still rejects nullable features until fill_null)
        let join = Plan::Join {
            left: Box::new(src()),
            right: Box::new(right_src()),
            on: vec![("id".into(), "cid".into())],
            how: JoinType::Left,
            strategy: JoinStrategy::Hash,
        };
        let wc = Plan::WithColumn {
            input: Box::new(join.clone()),
            name: "t2".into(),
            expr: col("tag").add(lit(1i64)),
        };
        assert_eq!(wc.schema().unwrap().nullable_of("t2"), Some(true));
        let filled = Plan::WithColumn {
            input: Box::new(join.clone()),
            name: "t3".into(),
            expr: col("tag").fill_null(0i64),
        };
        assert_eq!(filled.schema().unwrap().nullable_of("t3"), Some(false));
        // cumulative sum over the nullable column: accepted, never NULL
        let cs = Plan::Window {
            input: Box::new(join.clone()),
            partition_by: vec![],
            order_by: vec![],
            aggs: vec![WindowAgg::new(
                "cs",
                WindowFunc::Sum,
                WindowFrame::CumulativeToCurrent,
                col("tag"),
            )],
        };
        let s = cs.schema().unwrap();
        assert_eq!(s.dtype_of("cs"), Some(DType::I64));
        assert_eq!(s.nullable_of("cs"), Some(false));
        // rolling mean over the nullable column: output stays nullable
        let rm = Plan::Window {
            input: Box::new(join.clone()),
            partition_by: vec![],
            order_by: vec![],
            aggs: vec![WindowAgg::new(
                "m",
                WindowFunc::Mean,
                WindowFrame::Rolling {
                    preceding: 1,
                    following: 1,
                },
                col("tag"),
            )],
        };
        assert_eq!(rm.schema().unwrap().nullable_of("m"), Some(true));
        let bad = Plan::MatrixAssembly {
            input: Box::new(join),
            columns: vec!["tag".into()],
        };
        assert!(bad.schema().is_err());
    }

    #[test]
    fn schema_semi_anti_keep_left_only() {
        for how in [JoinType::Semi, JoinType::Anti] {
            let j = Plan::Join {
                left: Box::new(src()),
                right: Box::new(right_src()),
                on: vec![("id".into(), "cid".into())],
                how,
                strategy: JoinStrategy::Hash,
            };
            assert_eq!(j.schema().unwrap().names(), vec!["id", "x"], "{how:?}");
        }
    }

    #[test]
    fn schema_aggregate() {
        let a = Plan::Aggregate {
            input: Box::new(src()),
            keys: vec!["id".into()],
            aggs: vec![
                AggExpr::new("n", AggFn::Count, col("x")),
                AggExpr::new("m", AggFn::Mean, col("x")),
            ],
        };
        let s = a.schema().unwrap();
        assert_eq!(s.names(), vec!["id", "n", "m"]);
        assert_eq!(s.dtype_of("n"), Some(DType::I64));
        assert_eq!(s.dtype_of("m"), Some(DType::F64));
    }

    #[test]
    fn schema_aggregate_multi_key() {
        let input = source_mem(
            "t",
            Table::from_pairs(vec![
                ("k1", Column::I64(vec![1])),
                ("k2", Column::Str(vec!["a".into()])),
                ("x", Column::F64(vec![0.5])),
            ])
            .unwrap(),
        );
        let a = Plan::Aggregate {
            input: Box::new(input.clone()),
            keys: vec!["k1".into(), "k2".into()],
            aggs: vec![AggExpr::new("s", AggFn::Sum, col("x"))],
        };
        let s = a.schema().unwrap();
        assert_eq!(s.names(), vec!["k1", "k2", "s"]);
        assert_eq!(s.dtype_of("k2"), Some(DType::Str));
        // F64 keys rejected; duplicate keys rejected
        let bad = Plan::Aggregate {
            input: Box::new(input.clone()),
            keys: vec!["x".into()],
            aggs: vec![],
        };
        assert!(bad.schema().is_err());
        let dup = Plan::Aggregate {
            input: Box::new(input),
            keys: vec!["k1".into(), "k1".into()],
            aggs: vec![],
        };
        assert!(dup.schema().is_err());
    }

    #[test]
    fn schema_withcolumn_replaces() {
        let p = Plan::WithColumn {
            input: Box::new(src()),
            name: "x".into(),
            expr: col("x").mul(lit(2.0)),
        };
        let s = p.schema().unwrap();
        assert_eq!(s.len(), 2);
        assert_eq!(s.dtype_of("x"), Some(DType::F64));
    }

    fn window_of(aggs: Vec<WindowAgg>) -> Plan {
        Plan::Window {
            input: Box::new(src()),
            partition_by: vec![],
            order_by: vec![],
            aggs,
        }
    }

    #[test]
    fn schema_window_validates_frames_and_funcs() {
        // weighted taps must match the rolling width
        let bad = window_of(vec![WindowAgg::new(
            "sma",
            WindowFunc::Weighted(vec![0.5, 0.5]),
            WindowFrame::Rolling {
                preceding: 1,
                following: 1,
            },
            col("x"),
        )]);
        assert!(bad.schema().is_err());
        let good = window_of(vec![WindowAgg::new(
            "sma",
            WindowFunc::Weighted(vec![1.0 / 3.0; 3]),
            WindowFrame::Rolling {
                preceding: 1,
                following: 1,
            },
            col("x"),
        )]);
        assert_eq!(good.schema().unwrap().dtype_of("sma"), Some(DType::F64));
        // value() needs a shift frame; shift frames carry only value()
        assert!(window_of(vec![WindowAgg::new(
            "v",
            WindowFunc::Value,
            WindowFrame::CumulativeToCurrent,
            col("x"),
        )])
        .schema()
        .is_err());
        assert!(window_of(vec![WindowAgg::new(
            "v",
            WindowFunc::Sum,
            WindowFrame::Shift(1),
            col("x"),
        )])
        .schema()
        .is_err());
        // shift introduces edge nulls
        let sh = window_of(vec![WindowAgg::new(
            "prev",
            WindowFunc::Value,
            WindowFrame::Shift(1),
            col("x"),
        )]);
        let s = sh.schema().unwrap();
        assert_eq!(s.dtype_of("prev"), Some(DType::F64));
        assert_eq!(s.nullable_of("prev"), Some(true));
        // rank needs order_by; order_by needs partition_by; empty aggs bail
        assert!(window_of(vec![WindowAgg::new(
            "r",
            WindowFunc::Rank,
            WindowFrame::CumulativeToCurrent,
            col("id"),
        )])
        .schema()
        .is_err());
        let no_part = Plan::Window {
            input: Box::new(src()),
            partition_by: vec![],
            order_by: vec![("id".into(), SortOrder::Asc)],
            aggs: vec![WindowAgg::new(
                "cs",
                WindowFunc::Sum,
                WindowFrame::CumulativeToCurrent,
                col("x"),
            )],
        };
        assert!(no_part.schema().is_err());
        assert!(window_of(vec![]).schema().is_err());
        // F64 partition keys rejected like every other relational key
        let bad_key = Plan::Window {
            input: Box::new(src()),
            partition_by: vec!["x".into()],
            order_by: vec![],
            aggs: vec![WindowAgg::new(
                "cs",
                WindowFunc::Sum,
                WindowFrame::CumulativeToCurrent,
                col("x"),
            )],
        };
        assert!(bad_key.schema().is_err());
    }

    #[test]
    fn schema_partitioned_window_with_rank() {
        let w = Plan::Window {
            input: Box::new(src()),
            partition_by: vec!["id".into()],
            order_by: vec![("id".into(), SortOrder::Asc)],
            aggs: vec![
                WindowAgg::new(
                    "r",
                    WindowFunc::Rank,
                    WindowFrame::CumulativeToCurrent,
                    lit(0i64),
                ),
                WindowAgg::new(
                    "cs",
                    WindowFunc::Sum,
                    WindowFrame::CumulativeToCurrent,
                    col("x"),
                ),
            ],
        };
        let s = w.schema().unwrap();
        assert_eq!(s.names(), vec!["id", "x", "r", "cs"]);
        assert_eq!(s.dtype_of("r"), Some(DType::I64));
        assert_eq!(s.dtype_of("cs"), Some(DType::F64));
        assert_eq!(w.dist(), crate::distribution::Dist::OneDVar);
    }

    #[test]
    fn dist_transfer_functions() {
        let s = src();
        assert_eq!(s.dist(), Dist::OneD);
        let f = Plan::Filter {
            input: Box::new(src()),
            predicate: col("x").lt(lit(1.0)),
        };
        assert_eq!(f.dist(), Dist::OneDVar);
        let reb = Plan::Rebalance {
            input: Box::new(f.clone()),
        };
        assert_eq!(reb.dist(), Dist::OneD);
        let ml = Plan::MlCall {
            input: Box::new(src()),
            params: MlParams {
                model: "kmeans".into(),
                k: 2,
                iters: 1,
                use_pjrt: false,
            },
        };
        assert_eq!(ml.dist(), Dist::Rep);
    }

    #[test]
    fn requires_block() {
        // halo-carrying global window (rolling) requires 1D_BLOCK input
        let st = window_of(vec![WindowAgg::new(
            "o",
            WindowFunc::Mean,
            WindowFrame::Rolling {
                preceding: 1,
                following: 1,
            },
            col("x"),
        )]);
        assert!(st.requires_block_input());
        assert_eq!(st.dist(), Dist::OneD); // element-wise over row order
        // scans and position functions need no halo → no block requirement
        let cs = window_of(vec![WindowAgg::new(
            "o",
            WindowFunc::Sum,
            WindowFrame::CumulativeToCurrent,
            col("x"),
        )]);
        assert!(!cs.requires_block_input());
        // partitioned windows shuffle instead of exchanging halos
        let pw = Plan::Window {
            input: Box::new(src()),
            partition_by: vec!["id".into()],
            order_by: vec![],
            aggs: vec![WindowAgg::new(
                "o",
                WindowFunc::Mean,
                WindowFrame::Rolling {
                    preceding: 2,
                    following: 0,
                },
                col("x"),
            )],
        };
        assert!(!pw.requires_block_input());
        assert!(!src().requires_block_input());
    }

    #[test]
    fn display_tree() {
        let f = Plan::Filter {
            input: Box::new(src()),
            predicate: col("x").lt(lit(1.0)),
        };
        let txt = format!("{f}");
        assert!(txt.contains("Filter"));
        assert!(txt.contains("Source(t)"));
        assert!(txt.contains("1D_VAR"));
        assert_eq!(f.size(), 2);
    }

    #[test]
    fn concat_schema_checked() {
        let c = Plan::Concat {
            inputs: vec![Box::new(src()), Box::new(src())],
        };
        assert!(c.schema().is_ok());
        let other = source_mem(
            "o",
            Table::from_pairs(vec![("z", Column::I64(vec![1]))]).unwrap(),
        );
        let bad = Plan::Concat {
            inputs: vec![Box::new(src()), Box::new(other)],
        };
        assert!(bad.schema().is_err());
    }
}
