//! Arena-graph logical IR: the DAG form of [`Plan`].
//!
//! `Box<Plan>` trees duplicate every upstream operator of a self-join and
//! re-execute shared scans once per consumer. This module gives plans the
//! MIR shape the ROADMAP names (toasty's `LogicalPlan`): nodes live in a
//! [`Store`] arena, reference children by [`NodeId`], and are *hash-consed*
//! on construction — interning a node whose operator, parameters and child
//! ids match an existing node returns the existing id, so identical
//! subplans collapse to one node and the executor materializes them once
//! per rank.
//!
//! Hash-consing rule: a node's identity is its operator + parameters +
//! child `NodeId`s. In-memory sources are identified by table *pointer*
//! (two `source_mem` calls over equal data stay distinct; a cloned
//! `DataFrame` shares), HFS sources by path. Nodes whose expressions embed
//! scalar UDFs are never deduplicated — UDF identity is a closure, which
//! only debug-prints its name, and a name collision must not merge
//! different functions.
//!
//! A [`PlanGraph`] pairs a store with a `completion` node (the plan's
//! output) and a children-first `execution_order`; the executor walks that
//! order with a `NodeId → frame` memo. Passes transform graphs with
//! [`PlanGraph::rewrite`], which rebuilds into a fresh store bottom-up and
//! re-interns — sharing discovered upstream is preserved, and rewrites
//! that make two subplans equal merge them for free.

use super::{MlParams, Plan, SourceRef, WindowAgg};
use crate::distribution::Dist;
use crate::expr::{AggExpr, Expr};
use crate::fxhash::FxHashMap;
use crate::table::Schema;
use crate::types::{JoinStrategy, JoinType, SortOrder};
use anyhow::Result;
use std::fmt;
use std::ops::Index;
use std::path::PathBuf;
use std::sync::Arc;

/// Index of a node in a [`Store`] arena.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeId(pub u32);

impl NodeId {
    pub fn index(self) -> usize {
        self.0 as usize
    }
}

/// One plan operator with children by [`NodeId`] — the graph counterpart
/// of [`Plan`], field-for-field.
#[derive(Debug, Clone)]
pub enum Node {
    Source {
        name: String,
        src: SourceRef,
        schema: Schema,
    },
    Filter {
        input: NodeId,
        predicate: Expr,
    },
    Project {
        input: NodeId,
        columns: Vec<String>,
    },
    WithColumn {
        input: NodeId,
        name: String,
        expr: Expr,
    },
    Rename {
        input: NodeId,
        from: String,
        to: String,
    },
    Join {
        left: NodeId,
        right: NodeId,
        on: Vec<(String, String)>,
        how: JoinType,
        strategy: JoinStrategy,
    },
    Aggregate {
        input: NodeId,
        keys: Vec<String>,
        aggs: Vec<AggExpr>,
    },
    Concat {
        inputs: Vec<NodeId>,
    },
    Window {
        input: NodeId,
        partition_by: Vec<String>,
        order_by: Vec<(String, SortOrder)>,
        aggs: Vec<WindowAgg>,
    },
    Sort {
        input: NodeId,
        keys: Vec<(String, SortOrder)>,
    },
    Rebalance {
        input: NodeId,
    },
    MatrixAssembly {
        input: NodeId,
        columns: Vec<String>,
    },
    MlCall {
        input: NodeId,
        params: MlParams,
    },
    Cache {
        input: NodeId,
    },
}

impl Node {
    /// Children in execution order (same order as [`Plan::children`]).
    pub fn children(&self) -> Vec<NodeId> {
        match self {
            Node::Source { .. } => vec![],
            Node::Filter { input, .. }
            | Node::Project { input, .. }
            | Node::WithColumn { input, .. }
            | Node::Rename { input, .. }
            | Node::Aggregate { input, .. }
            | Node::Window { input, .. }
            | Node::Sort { input, .. }
            | Node::Rebalance { input }
            | Node::MatrixAssembly { input, .. }
            | Node::MlCall { input, .. }
            | Node::Cache { input } => vec![*input],
            Node::Join { left, right, .. } => vec![*left, *right],
            Node::Concat { inputs } => inputs.clone(),
        }
    }

    /// Rebuild with every child id sent through `map` (ids absent from the
    /// map are kept — rewrites only map already-processed nodes).
    pub fn remap(self, map: &FxHashMap<NodeId, NodeId>) -> Node {
        let m = |id: NodeId| map.get(&id).copied().unwrap_or(id);
        match self {
            n @ Node::Source { .. } => n,
            Node::Filter { input, predicate } => Node::Filter {
                input: m(input),
                predicate,
            },
            Node::Project { input, columns } => Node::Project {
                input: m(input),
                columns,
            },
            Node::WithColumn { input, name, expr } => Node::WithColumn {
                input: m(input),
                name,
                expr,
            },
            Node::Rename { input, from, to } => Node::Rename {
                input: m(input),
                from,
                to,
            },
            Node::Join {
                left,
                right,
                on,
                how,
                strategy,
            } => Node::Join {
                left: m(left),
                right: m(right),
                on,
                how,
                strategy,
            },
            Node::Aggregate { input, keys, aggs } => Node::Aggregate {
                input: m(input),
                keys,
                aggs,
            },
            Node::Concat { inputs } => Node::Concat {
                inputs: inputs.into_iter().map(m).collect(),
            },
            Node::Window {
                input,
                partition_by,
                order_by,
                aggs,
            } => Node::Window {
                input: m(input),
                partition_by,
                order_by,
                aggs,
            },
            Node::Sort { input, keys } => Node::Sort {
                input: m(input),
                keys,
            },
            Node::Rebalance { input } => Node::Rebalance { input: m(input) },
            Node::MatrixAssembly { input, columns } => Node::MatrixAssembly {
                input: m(input),
                columns,
            },
            Node::MlCall { input, params } => Node::MlCall {
                input: m(input),
                params,
            },
            Node::Cache { input } => Node::Cache { input: m(input) },
        }
    }

    /// Operator + parameters, children excluded — the "local" half of the
    /// hash-consing identity. Also the building block of the structural
    /// cache key ([`Store::structural_key`]).
    fn local_signature(&self) -> String {
        match self {
            Node::Source { name, src, schema } => {
                let ident = match src {
                    // pointer identity: equal-valued but separately loaded
                    // tables must NOT merge (they may diverge), while every
                    // clone of one DataFrame shares its Arc
                    SourceRef::InMemory(t) => format!("mem:{:p}", Arc::as_ptr(t)),
                    SourceRef::Hfs(p) => format!("hfs:{}", p.display()),
                };
                format!("source|{name}|{ident}|{schema}")
            }
            Node::Filter { predicate, .. } => format!("filter|{predicate:?}"),
            Node::Project { columns, .. } => format!("project|{columns:?}"),
            Node::WithColumn { name, expr, .. } => {
                format!("withcolumn|{name}|{expr:?}")
            }
            Node::Rename { from, to, .. } => format!("rename|{from}|{to}"),
            Node::Join {
                on, how, strategy, ..
            } => format!("join|{on:?}|{how:?}|{strategy:?}"),
            Node::Aggregate { keys, aggs, .. } => {
                format!("aggregate|{keys:?}|{aggs:?}")
            }
            Node::Concat { .. } => "concat".to_string(),
            Node::Window {
                partition_by,
                order_by,
                aggs,
                ..
            } => format!("window|{partition_by:?}|{order_by:?}|{aggs:?}"),
            Node::Sort { keys, .. } => format!("sort|{keys:?}"),
            Node::Rebalance { .. } => "rebalance".to_string(),
            Node::MatrixAssembly { columns, .. } => {
                format!("matrix|{columns:?}")
            }
            Node::MlCall { params, .. } => format!("mlcall|{params:?}"),
            Node::Cache { .. } => "cache".to_string(),
        }
    }

    /// Full hash-consing signature: local identity + child ids.
    pub fn signature(&self) -> String {
        let kids: Vec<String> = self.children().iter().map(|c| c.0.to_string()).collect();
        format!("{}<-{}", self.local_signature(), kids.join(","))
    }

    /// Output schema given the already-computed child schemas. Delegates to
    /// [`Plan::schema`] through shallow source stubs so the tree typing
    /// rules stay the single source of truth.
    pub fn local_schema(&self, kids: &[Schema]) -> Result<Schema> {
        fn stub(s: &Schema) -> Box<Plan> {
            Box::new(Plan::Source {
                name: "·".to_string(),
                src: SourceRef::Hfs(PathBuf::new()),
                schema: s.clone(),
            })
        }
        let shallow = match self {
            Node::Source { schema, .. } => return Ok(schema.clone()),
            Node::Cache { .. } => return Ok(kids[0].clone()),
            Node::Filter { predicate, .. } => Plan::Filter {
                input: stub(&kids[0]),
                predicate: predicate.clone(),
            },
            Node::Project { columns, .. } => Plan::Project {
                input: stub(&kids[0]),
                columns: columns.clone(),
            },
            Node::WithColumn { name, expr, .. } => Plan::WithColumn {
                input: stub(&kids[0]),
                name: name.clone(),
                expr: expr.clone(),
            },
            Node::Rename { from, to, .. } => Plan::Rename {
                input: stub(&kids[0]),
                from: from.clone(),
                to: to.clone(),
            },
            Node::Join {
                on, how, strategy, ..
            } => Plan::Join {
                left: stub(&kids[0]),
                right: stub(&kids[1]),
                on: on.clone(),
                how: *how,
                strategy: *strategy,
            },
            Node::Aggregate { keys, aggs, .. } => Plan::Aggregate {
                input: stub(&kids[0]),
                keys: keys.clone(),
                aggs: aggs.clone(),
            },
            Node::Concat { .. } => Plan::Concat {
                inputs: kids.iter().map(|s| stub(s)).collect(),
            },
            Node::Window {
                partition_by,
                order_by,
                aggs,
                ..
            } => Plan::Window {
                input: stub(&kids[0]),
                partition_by: partition_by.clone(),
                order_by: order_by.clone(),
                aggs: aggs.clone(),
            },
            Node::Sort { keys, .. } => Plan::Sort {
                input: stub(&kids[0]),
                keys: keys.clone(),
            },
            Node::Rebalance { .. } => Plan::Rebalance {
                input: stub(&kids[0]),
            },
            Node::MatrixAssembly { columns, .. } => Plan::MatrixAssembly {
                input: stub(&kids[0]),
                columns: columns.clone(),
            },
            Node::MlCall { params, .. } => Plan::MlCall {
                input: stub(&kids[0]),
                params: params.clone(),
            },
        };
        shallow.schema()
    }

    /// Graph counterpart of [`Plan::requires_block_input`].
    pub fn requires_block_input(&self) -> bool {
        match self {
            Node::MatrixAssembly { .. } => true,
            Node::Window {
                partition_by, aggs, ..
            } => partition_by.is_empty() && aggs.iter().any(|a| a.needs_halo()),
            _ => false,
        }
    }

    /// One-line description with children rendered as `%<position>` — the
    /// canonical text form. Positions (not raw arena ids) make isomorphic
    /// graphs print identically, which the pushdown fixpoint and the
    /// explain snapshots rely on.
    fn describe(&self, pos: &FxHashMap<NodeId, usize>) -> String {
        let r = |id: &NodeId| format!("%{}", pos[id]);
        match self {
            Node::Source { name, .. } => format!("Source({name})"),
            Node::Filter { input, predicate } => {
                format!("Filter({}, {predicate})", r(input))
            }
            Node::Project { input, columns } => {
                format!("Project({}, {})", r(input), columns.join(", "))
            }
            Node::WithColumn { input, name, expr } => {
                format!("WithColumn({}, :{name} = {expr})", r(input))
            }
            Node::Rename { input, from, to } => {
                format!("Rename({}, :{from} -> :{to})", r(input))
            }
            Node::Join {
                left,
                right,
                on,
                how,
                strategy,
            } => {
                let pairs: Vec<String> = on
                    .iter()
                    .map(|(lk, rk)| format!(":{lk} == :{rk}"))
                    .collect();
                match strategy {
                    JoinStrategy::Hash => format!(
                        "Join({}, {}, {}, how={how})",
                        r(left),
                        r(right),
                        pairs.join(" && ")
                    ),
                    other => format!(
                        "Join({}, {}, {}, how={how}, strategy={other})",
                        r(left),
                        r(right),
                        pairs.join(" && ")
                    ),
                }
            }
            Node::Aggregate { input, keys, aggs } => {
                let ks: Vec<String> = keys.iter().map(|k| format!(":{k}")).collect();
                let parts: Vec<String> = aggs.iter().map(|a| a.to_string()).collect();
                format!(
                    "Aggregate({}, {}; {})",
                    r(input),
                    ks.join(", "),
                    parts.join(", ")
                )
            }
            Node::Concat { inputs } => {
                let refs: Vec<String> = inputs.iter().map(|i| r(i)).collect();
                format!("Concat({})", refs.join(", "))
            }
            Node::Window {
                input,
                partition_by,
                order_by,
                aggs,
            } => {
                let parts: Vec<String> = aggs.iter().map(|a| a.to_string()).collect();
                if partition_by.is_empty() {
                    format!("Window({}, {})", r(input), parts.join(", "))
                } else {
                    let ks: Vec<String> =
                        partition_by.iter().map(|k| format!(":{k}")).collect();
                    let os: Vec<String> = order_by
                        .iter()
                        .map(|(k, o)| format!(":{k} {o}"))
                        .collect();
                    format!(
                        "Window({}, partition_by=[{}], order_by=[{}]; {})",
                        r(input),
                        ks.join(", "),
                        os.join(", "),
                        parts.join(", ")
                    )
                }
            }
            Node::Sort { input, keys } => {
                let ks: Vec<String> = keys
                    .iter()
                    .map(|(k, o)| format!(":{k} {o}"))
                    .collect();
                format!("Sort({}, {})", r(input), ks.join(", "))
            }
            Node::Rebalance { input } => format!("Rebalance({})", r(input)),
            Node::MatrixAssembly { input, columns } => {
                format!("MatrixAssembly({}, {})", r(input), columns.join(", "))
            }
            Node::MlCall { input, params } => format!(
                "MlCall({}, {}, k={}, iters={}, pjrt={})",
                r(input),
                params.model,
                params.k,
                params.iters,
                params.use_pjrt
            ),
            Node::Cache { input } => format!("Cache({})", r(input)),
        }
    }
}

/// Append-only node arena with optional hash-consing.
#[derive(Debug, Clone, Default)]
pub struct Store {
    nodes: Vec<Node>,
    /// `signature → id` interning map; `None` disables dedup (the serial
    /// oracle and `PassOptions::none()` run with exact tree shapes).
    dedup: Option<FxHashMap<String, NodeId>>,
}

impl Store {
    /// Arena with hash-consing on.
    pub fn new() -> Store {
        Store {
            nodes: Vec::new(),
            dedup: Some(FxHashMap::default()),
        }
    }

    /// Arena that interns every node fresh (plain tree flattening).
    pub fn without_dedup() -> Store {
        Store {
            nodes: Vec::new(),
            dedup: None,
        }
    }

    /// Empty arena with the same dedup setting as `other` (rewrites keep
    /// the policy of the graph they transform).
    pub fn like(other: &Store) -> Store {
        if other.dedup.is_some() {
            Store::new()
        } else {
            Store::without_dedup()
        }
    }

    pub fn dedup_enabled(&self) -> bool {
        self.dedup.is_some()
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Add a node, hash-consing when enabled: an identical node (operator,
    /// parameters, children) returns the existing [`NodeId`]. Nodes whose
    /// expressions carry UDFs are never merged (closure identity is not
    /// observable — see the module docs).
    pub fn intern(&mut self, node: Node) -> NodeId {
        if let Some(map) = &mut self.dedup {
            let sig = node.signature();
            if !sig.contains("udf:") {
                if let Some(&id) = map.get(&sig) {
                    return id;
                }
                let id = NodeId(self.nodes.len() as u32);
                map.insert(sig, id);
                self.nodes.push(node);
                return id;
            }
        }
        let id = NodeId(self.nodes.len() as u32);
        self.nodes.push(node);
        id
    }

    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id.index()]
    }

    /// Output schema of `id`, computed bottom-up with a memo so shared
    /// subgraphs type once.
    pub fn schema_of(&self, id: NodeId) -> Result<Schema> {
        let mut memo: FxHashMap<NodeId, Schema> = FxHashMap::default();
        self.schema_rec(id, &mut memo)
    }

    fn schema_rec(&self, id: NodeId, memo: &mut FxHashMap<NodeId, Schema>) -> Result<Schema> {
        if let Some(s) = memo.get(&id) {
            return Ok(s.clone());
        }
        let kids: Vec<Schema> = self[id]
            .children()
            .into_iter()
            .map(|c| self.schema_rec(c, memo))
            .collect::<Result<_>>()?;
        let s = self[id].local_schema(&kids)?;
        memo.insert(id, s.clone());
        Ok(s)
    }

    /// Distribution of `id` (graph form of [`Plan::dist`], memoized so the
    /// meet over a DAG stays linear).
    pub fn dist_of(&self, id: NodeId) -> Dist {
        let mut memo: FxHashMap<NodeId, Dist> = FxHashMap::default();
        self.dist_rec(id, &mut memo)
    }

    fn dist_rec(&self, id: NodeId, memo: &mut FxHashMap<NodeId, Dist>) -> Dist {
        if let Some(d) = memo.get(&id) {
            return *d;
        }
        let d = match &self[id] {
            Node::Source { .. } => Dist::OneD,
            Node::Filter { input, .. } | Node::Aggregate { input, .. } => {
                Dist::OneDVar.meet(self.dist_rec(*input, memo))
            }
            Node::Join { left, right, .. } => Dist::OneDVar
                .meet(self.dist_rec(*left, memo))
                .meet(self.dist_rec(*right, memo)),
            Node::Concat { inputs } => {
                Dist::meet_all(inputs.iter().map(|i| self.dist_rec(*i, memo)))
                    .meet(Dist::OneDVar)
            }
            Node::Project { input, .. }
            | Node::WithColumn { input, .. }
            | Node::Rename { input, .. }
            | Node::Cache { input } => self.dist_rec(*input, memo),
            Node::Window {
                input,
                partition_by,
                ..
            } => {
                if partition_by.is_empty() {
                    self.dist_rec(*input, memo)
                } else {
                    Dist::OneDVar.meet(self.dist_rec(*input, memo))
                }
            }
            Node::Sort { input, .. } => Dist::OneDVar.meet(self.dist_rec(*input, memo)),
            Node::Rebalance { .. } => Dist::OneD,
            Node::MatrixAssembly { input, .. } => self.dist_rec(*input, memo),
            Node::MlCall { .. } => Dist::Rep,
        };
        memo.insert(id, d);
        d
    }

    /// Position-independent structural identity of the subgraph rooted at
    /// `id` — the plan-cache key. Two plans built in different sessions
    /// over the same sources (same table Arcs / HFS paths) produce the
    /// same key for the same logical subplan.
    pub fn structural_key(&self, id: NodeId) -> String {
        let mut memo: FxHashMap<NodeId, String> = FxHashMap::default();
        self.key_rec(id, &mut memo)
    }

    fn key_rec(&self, id: NodeId, memo: &mut FxHashMap<NodeId, String>) -> String {
        if let Some(k) = memo.get(&id) {
            return k.clone();
        }
        let kids: Vec<String> = self[id]
            .children()
            .into_iter()
            .map(|c| self.key_rec(c, memo))
            .collect();
        let k = format!("({} {})", self[id].local_signature(), kids.join(" "));
        memo.insert(id, k.clone());
        k
    }

    /// Expand the subgraph at `id` back to a [`Plan`] tree (shared nodes
    /// are cloned into each consumer — the tree has no way to share).
    pub fn to_plan(&self, id: NodeId) -> Plan {
        let mut memo: FxHashMap<NodeId, Plan> = FxHashMap::default();
        self.plan_rec(id, &mut memo)
    }

    fn plan_rec(&self, id: NodeId, memo: &mut FxHashMap<NodeId, Plan>) -> Plan {
        if let Some(p) = memo.get(&id) {
            return p.clone();
        }
        let kids: Vec<Plan> = self[id]
            .children()
            .into_iter()
            .map(|c| self.plan_rec(c, memo))
            .collect();
        let mut kids = kids.into_iter();
        fn one(kids: &mut std::vec::IntoIter<Plan>) -> Box<Plan> {
            Box::new(kids.next().expect("node arity"))
        }
        let p = match &self[id] {
            Node::Source { name, src, schema } => Plan::Source {
                name: name.clone(),
                src: src.clone(),
                schema: schema.clone(),
            },
            Node::Filter { predicate, .. } => Plan::Filter {
                input: one(&mut kids),
                predicate: predicate.clone(),
            },
            Node::Project { columns, .. } => Plan::Project {
                input: one(&mut kids),
                columns: columns.clone(),
            },
            Node::WithColumn { name, expr, .. } => Plan::WithColumn {
                input: one(&mut kids),
                name: name.clone(),
                expr: expr.clone(),
            },
            Node::Rename { from, to, .. } => Plan::Rename {
                input: one(&mut kids),
                from: from.clone(),
                to: to.clone(),
            },
            Node::Join {
                on, how, strategy, ..
            } => Plan::Join {
                left: one(&mut kids),
                right: one(&mut kids),
                on: on.clone(),
                how: *how,
                strategy: *strategy,
            },
            Node::Aggregate { keys, aggs, .. } => Plan::Aggregate {
                input: one(&mut kids),
                keys: keys.clone(),
                aggs: aggs.clone(),
            },
            Node::Concat { .. } => Plan::Concat {
                inputs: kids.by_ref().map(Box::new).collect(),
            },
            Node::Window {
                partition_by,
                order_by,
                aggs,
                ..
            } => Plan::Window {
                input: one(&mut kids),
                partition_by: partition_by.clone(),
                order_by: order_by.clone(),
                aggs: aggs.clone(),
            },
            Node::Sort { keys, .. } => Plan::Sort {
                input: one(&mut kids),
                keys: keys.clone(),
            },
            Node::Rebalance { .. } => Plan::Rebalance {
                input: one(&mut kids),
            },
            Node::MatrixAssembly { columns, .. } => Plan::MatrixAssembly {
                input: one(&mut kids),
                columns: columns.clone(),
            },
            Node::MlCall { params, .. } => Plan::MlCall {
                input: one(&mut kids),
                params: params.clone(),
            },
            Node::Cache { .. } => Plan::Cache {
                input: one(&mut kids),
            },
        };
        memo.insert(id, p.clone());
        p
    }
}

impl Index<NodeId> for Store {
    type Output = Node;
    fn index(&self, id: NodeId) -> &Node {
        &self.nodes[id.index()]
    }
}

/// A complete logical plan as a DAG: arena + output node + topological
/// execution order (children strictly before consumers; only nodes
/// reachable from `completion` appear).
#[derive(Debug, Clone)]
pub struct PlanGraph {
    pub store: Store,
    /// The node whose output is the plan's result.
    pub completion: NodeId,
    /// Children-first topological order over the reachable nodes; the
    /// executor materializes exactly this sequence.
    pub execution_order: Vec<NodeId>,
}

impl PlanGraph {
    /// Wrap a store + output node, computing the execution order (iterative
    /// post-order DFS; each shared node appears once). Unreachable arena
    /// garbage — e.g. nodes orphaned by a rewrite — is simply skipped.
    pub fn new(store: Store, completion: NodeId) -> PlanGraph {
        let mut order = Vec::new();
        let mut visited: FxHashMap<NodeId, ()> = FxHashMap::default();
        let mut stack: Vec<(NodeId, usize)> = vec![(completion, 0)];
        visited.insert(completion, ());
        while let Some((id, cursor)) = stack.pop() {
            let kids = store[id].children();
            if cursor < kids.len() {
                stack.push((id, cursor + 1));
                let k = kids[cursor];
                if visited.insert(k, ()).is_none() {
                    stack.push((k, 0));
                }
            } else {
                order.push(id);
            }
        }
        PlanGraph {
            store,
            completion,
            execution_order: order,
        }
    }

    /// Intern a [`Plan`] tree. With `dedup` on, identical subtrees (e.g.
    /// both sides of a self-join) collapse into one node.
    pub fn from_plan(plan: &Plan, dedup: bool) -> PlanGraph {
        fn intern_rec(store: &mut Store, plan: &Plan) -> NodeId {
            let kids: Vec<NodeId> = plan
                .children()
                .iter()
                .map(|c| intern_rec(store, c))
                .collect();
            let node = node_from_plan(plan, &kids);
            store.intern(node)
        }
        let mut store = if dedup {
            Store::new()
        } else {
            Store::without_dedup()
        };
        let completion = intern_rec(&mut store, plan);
        PlanGraph::new(store, completion)
    }

    /// Expand back to a tree (inverse of [`PlanGraph::from_plan`] up to
    /// sharing).
    pub fn to_plan(&self) -> Plan {
        self.store.to_plan(self.completion)
    }

    /// Number of distinct (reachable) nodes.
    pub fn node_count(&self) -> usize {
        self.execution_order.len()
    }

    pub fn schema(&self) -> Result<Schema> {
        self.store.schema_of(self.completion)
    }

    /// Schema of every reachable node, computed bottom-up in one pass.
    pub fn schemas(&self) -> Result<FxHashMap<NodeId, Schema>> {
        let mut out: FxHashMap<NodeId, Schema> = FxHashMap::default();
        for &id in &self.execution_order {
            let kids: Vec<Schema> = self.store[id]
                .children()
                .into_iter()
                .map(|c| out[&c].clone())
                .collect();
            let s = self.store[id].local_schema(&kids)?;
            out.insert(id, s);
        }
        Ok(out)
    }

    /// Consumer-edge count per node, with multiplicity (a self-join counts
    /// its shared input twice); the completion node gets one implicit use
    /// (the driver reads it). `> 1` ⇒ the node is shared.
    pub fn consumer_counts(&self) -> FxHashMap<NodeId, usize> {
        let mut counts: FxHashMap<NodeId, usize> = FxHashMap::default();
        for &id in &self.execution_order {
            for c in self.store[id].children() {
                *counts.entry(c).or_insert(0) += 1;
            }
        }
        *counts.entry(self.completion).or_insert(0) += 1;
        counts
    }

    /// Every `Source` node in execution order as `(id, name)` — the handles
    /// a streaming [`crate::stream::Session`] exposes for `push`.
    pub fn source_nodes(&self) -> Vec<(NodeId, String)> {
        self.execution_order
            .iter()
            .filter_map(|&id| match &self.store[id] {
                Node::Source { name, .. } => Some((id, name.clone())),
                _ => None,
            })
            .collect()
    }

    /// Functional bottom-up rewrite: each node (children already remapped
    /// into the new store) goes through `rule`, and the result is interned.
    /// Sharing survives by construction — a shared node is processed once
    /// and every consumer is remapped to its single image.
    pub fn rewrite<F>(&self, mut rule: F) -> PlanGraph
    where
        F: FnMut(&mut Store, Node) -> Node,
    {
        self.rewrite_indexed(|st, _, n| rule(st, n))
    }

    /// [`PlanGraph::rewrite`] variant that also hands the rule the node's
    /// id in the *old* graph (for rules keyed on precomputed per-node
    /// facts, e.g. the plan-cache substitution).
    pub fn rewrite_indexed<F>(&self, mut rule: F) -> PlanGraph
    where
        F: FnMut(&mut Store, NodeId, Node) -> Node,
    {
        let mut out = Store::like(&self.store);
        let mut map: FxHashMap<NodeId, NodeId> = FxHashMap::default();
        for &id in &self.execution_order {
            let node = self.store[id].clone().remap(&map);
            let node = rule(&mut out, id, node);
            let nid = out.intern(node);
            map.insert(id, nid);
        }
        PlanGraph::new(out, map[&self.completion])
    }

    /// One line per node in execution order: `%i = Op(%child…, params)
    /// [dist]` plus `[shared]` on multi-consumer nodes and — when
    /// `annotate_spill` is set (a memory budget is active) — `[spill]` on
    /// the operators that can go out-of-core. Output is canonical: node
    /// numbers are execution-order positions, so isomorphic graphs render
    /// byte-identically.
    pub fn render(&self, annotate_spill: bool) -> String {
        let mut out = String::new();
        for line in self.render_lines(annotate_spill) {
            out.push_str(&line);
            out.push('\n');
        }
        out
    }

    /// [`Self::render`] as one `String` per node, in execution order — the
    /// profiler keys its per-node annotations to these lines (the index in
    /// the returned vec IS the `%i` position).
    pub fn render_lines(&self, annotate_spill: bool) -> Vec<String> {
        let pos: FxHashMap<NodeId, usize> = self
            .execution_order
            .iter()
            .enumerate()
            .map(|(i, &id)| (id, i))
            .collect();
        let shared = self.consumer_counts();
        let mut lines = Vec::with_capacity(self.execution_order.len());
        for (i, &id) in self.execution_order.iter().enumerate() {
            let node = &self.store[id];
            let dist = self.store.dist_of(id);
            let mut line = format!("%{i} = {} [{dist}]", node.describe(&pos));
            if shared.get(&id).copied().unwrap_or(0) > 1 {
                line.push_str(" [shared]");
            }
            if annotate_spill
                && matches!(
                    node,
                    Node::Join { .. } | Node::Aggregate { .. } | Node::Sort { .. }
                )
            {
                line.push_str(" [spill]");
            }
            lines.push(line);
        }
        lines
    }
}

impl fmt::Display for PlanGraph {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.render(false))
    }
}

/// Per-source version counters on a compiled graph's `Source` nodes. A
/// streaming [`crate::stream::Session`] bumps a source's generation on every
/// appended batch; operator state downstream is valid only for the
/// generation vector it was built against, so comparing snapshots tells an
/// incremental walk exactly which sources moved since the last tick.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct SourceGenerations {
    gens: FxHashMap<NodeId, u64>,
}

impl SourceGenerations {
    /// Zero generation for every `Source` node in `g`.
    pub fn new(g: &PlanGraph) -> SourceGenerations {
        SourceGenerations {
            gens: g.source_nodes().into_iter().map(|(id, _)| (id, 0)).collect(),
        }
    }

    /// Bump `id`'s generation (one appended batch) and return the new value.
    pub fn bump(&mut self, id: NodeId) -> u64 {
        let g = self.gens.entry(id).or_insert(0);
        *g += 1;
        *g
    }

    /// Current generation of `id` (0 if never bumped / not a source).
    pub fn get(&self, id: NodeId) -> u64 {
        self.gens.get(&id).copied().unwrap_or(0)
    }

    /// Sources whose generation moved relative to `since`, ascending by id.
    pub fn changed_since(&self, since: &SourceGenerations) -> Vec<NodeId> {
        let mut out: Vec<NodeId> = self
            .gens
            .iter()
            .filter(|(id, g)| **g != since.get(**id))
            .map(|(id, _)| *id)
            .collect();
        out.sort();
        out
    }
}

/// Shallow [`Plan`] → [`Node`] conversion given already-interned children
/// (in [`Plan::children`] order).
fn node_from_plan(plan: &Plan, kids: &[NodeId]) -> Node {
    match plan {
        Plan::Source { name, src, schema } => Node::Source {
            name: name.clone(),
            src: src.clone(),
            schema: schema.clone(),
        },
        Plan::Filter { predicate, .. } => Node::Filter {
            input: kids[0],
            predicate: predicate.clone(),
        },
        Plan::Project { columns, .. } => Node::Project {
            input: kids[0],
            columns: columns.clone(),
        },
        Plan::WithColumn { name, expr, .. } => Node::WithColumn {
            input: kids[0],
            name: name.clone(),
            expr: expr.clone(),
        },
        Plan::Rename { from, to, .. } => Node::Rename {
            input: kids[0],
            from: from.clone(),
            to: to.clone(),
        },
        Plan::Join {
            on, how, strategy, ..
        } => Node::Join {
            left: kids[0],
            right: kids[1],
            on: on.clone(),
            how: *how,
            strategy: *strategy,
        },
        Plan::Aggregate { keys, aggs, .. } => Node::Aggregate {
            input: kids[0],
            keys: keys.clone(),
            aggs: aggs.clone(),
        },
        Plan::Concat { .. } => Node::Concat {
            inputs: kids.to_vec(),
        },
        Plan::Window {
            partition_by,
            order_by,
            aggs,
            ..
        } => Node::Window {
            input: kids[0],
            partition_by: partition_by.clone(),
            order_by: order_by.clone(),
            aggs: aggs.clone(),
        },
        Plan::Sort { keys, .. } => Node::Sort {
            input: kids[0],
            keys: keys.clone(),
        },
        Plan::Rebalance { .. } => Node::Rebalance { input: kids[0] },
        Plan::MatrixAssembly { columns, .. } => Node::MatrixAssembly {
            input: kids[0],
            columns: columns.clone(),
        },
        Plan::MlCall { params, .. } => Node::MlCall {
            input: kids[0],
            params: params.clone(),
        },
        Plan::Cache { .. } => Node::Cache { input: kids[0] },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::column::Column;
    use crate::expr::{col, lit};
    use crate::ir::source_mem;
    use crate::table::Table;

    fn src() -> Plan {
        source_mem(
            "t",
            Table::from_pairs(vec![
                ("id", Column::I64(vec![1, 2])),
                ("x", Column::F64(vec![0.5, 1.5])),
            ])
            .unwrap(),
        )
    }

    fn self_join(base: &Plan) -> Plan {
        // rename both right columns to dodge the collision check
        let renamed = Plan::Rename {
            input: Box::new(Plan::Rename {
                input: Box::new(base.clone()),
                from: "id".into(),
                to: "rid".into(),
            }),
            from: "x".into(),
            to: "y".into(),
        };
        Plan::Join {
            left: Box::new(base.clone()),
            right: Box::new(renamed),
            on: vec![("id".into(), "rid".into())],
            how: JoinType::Inner,
            strategy: JoinStrategy::Hash,
        }
    }

    #[test]
    fn hash_consing_merges_self_join_scan() {
        let plan = self_join(&src());
        // tree: join + 2 renames + 2 copies of the scan = 5 nodes
        assert_eq!(plan.size(), 5);
        let g = PlanGraph::from_plan(&plan, true);
        // graph: the two scan copies share one node
        assert_eq!(g.node_count(), 4);
        let shared = g.consumer_counts();
        let n_shared = g
            .execution_order
            .iter()
            .filter(|id| shared[id] > 1)
            .count();
        assert_eq!(n_shared, 1);
        // without dedup the flattening is exactly the tree
        let g2 = PlanGraph::from_plan(&plan, false);
        assert_eq!(g2.node_count(), 5);
    }

    #[test]
    fn separately_loaded_equal_tables_stay_distinct() {
        // same values, different Arc: pointer identity must keep them apart
        let j = Plan::Join {
            left: Box::new(src()),
            right: Box::new(Plan::Rename {
                input: Box::new(Plan::Rename {
                    input: Box::new(src()),
                    from: "id".into(),
                    to: "rid".into(),
                }),
                from: "x".into(),
                to: "y".into(),
            }),
            on: vec![("id".into(), "rid".into())],
            how: JoinType::Inner,
            strategy: JoinStrategy::Hash,
        };
        let g = PlanGraph::from_plan(&j, true);
        assert_eq!(g.node_count(), 5);
    }

    #[test]
    fn round_trip_preserves_tree() {
        let plan = self_join(&src());
        for dedup in [true, false] {
            let g = PlanGraph::from_plan(&plan, dedup);
            assert_eq!(format!("{}", g.to_plan()), format!("{plan}"));
            assert_eq!(g.to_plan().size(), plan.size());
        }
    }

    #[test]
    fn execution_order_is_children_first() {
        let g = PlanGraph::from_plan(&self_join(&src()), true);
        let pos: FxHashMap<NodeId, usize> = g
            .execution_order
            .iter()
            .enumerate()
            .map(|(i, &id)| (id, i))
            .collect();
        for &id in &g.execution_order {
            for c in g.store[id].children() {
                assert!(pos[&c] < pos[&id], "child after consumer");
            }
        }
        assert_eq!(*g.execution_order.last().unwrap(), g.completion);
    }

    #[test]
    fn schema_and_dist_match_tree() {
        let plan = self_join(&src());
        let g = PlanGraph::from_plan(&plan, true);
        assert_eq!(g.schema().unwrap(), plan.schema().unwrap());
        assert_eq!(g.store.dist_of(g.completion), plan.dist());
        let schemas = g.schemas().unwrap();
        assert_eq!(schemas[&g.completion], plan.schema().unwrap());
    }

    #[test]
    fn render_golden_diamond() {
        // diamond: one filtered scan feeding both sides of a join — the
        // exact text is the explain() contract, keep it stable
        let base = Plan::Filter {
            input: Box::new(src()),
            predicate: col("x").lt(lit(9.0)),
        };
        let plan = self_join(&base);
        let g = PlanGraph::from_plan(&plan, true);
        let expected = "\
%0 = Source(t) [1D]
%1 = Filter(%0, (:x < 9)) [1D_VAR] [shared]
%2 = Rename(%1, :id -> :rid) [1D_VAR]
%3 = Rename(%2, :x -> :y) [1D_VAR]
%4 = Join(%1, %3, :id == :rid, how=inner) [1D_VAR]
";
        assert_eq!(g.render(false), expected);
        // spill annotation marks the out-of-core-capable operators
        assert!(g.render(true).contains("how=inner) [1D_VAR] [spill]"));
        // Display is the unannotated rendering
        assert_eq!(format!("{g}"), g.render(false));
    }

    #[test]
    fn structural_key_is_position_independent() {
        let plan = self_join(&src());
        let a = PlanGraph::from_plan(&plan, true);
        let b = PlanGraph::from_plan(&plan, false);
        assert_eq!(
            a.store.structural_key(a.completion),
            b.store.structural_key(b.completion)
        );
        // wrapping in Cache changes the key of the root but not the input
        let cached = Plan::Cache {
            input: Box::new(plan),
        };
        let c = PlanGraph::from_plan(&cached, true);
        let Node::Cache { input } = &c.store[c.completion] else {
            panic!("expected cache at completion");
        };
        assert_eq!(
            c.store.structural_key(*input),
            a.store.structural_key(a.completion)
        );
    }

    #[test]
    fn rewrite_preserves_sharing() {
        let plan = self_join(&src());
        let g = PlanGraph::from_plan(&plan, true);
        // identity rewrite: same node count, same render
        let g2 = g.rewrite(|_, n| n);
        assert_eq!(g2.node_count(), g.node_count());
        assert_eq!(g2.render(false), g.render(false));
    }

    #[test]
    fn udf_nodes_never_merge() {
        use crate::expr::Udf;
        let mk = || Plan::Filter {
            input: Box::new(src()),
            predicate: Expr::Udf(Udf::new("f", |v| v[0] * 2.0), vec![col("x")])
                .lt(lit(1.0)),
        };
        let plan = Plan::Concat {
            inputs: vec![Box::new(mk()), Box::new(mk())],
        };
        let g = PlanGraph::from_plan(&plan, true);
        // the scan merges; the two udf filters must not
        assert_eq!(g.node_count(), 4);
    }
}
