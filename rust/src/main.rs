//! `hiframes` — the launcher CLI (hand-rolled arg parsing; clap is not in
//! the offline image).
//!
//! Subcommands:
//!   gen-data   --sf <f> --out <dir> [--skew <a>]   generate TPCx-BB HFS files
//!   query      --q <05|25|26> --sf <f> [--workers N] [--engine hiframes|sparklike]
//!   plan       --q <05|25|26>                       show optimized logical plan
//!   pipeline   [--sf f] [--workers N] [--pjrt]      Q26 end-to-end incl. k-means
//!   micro      --op <filter|join|aggregate|cumsum|sma|wma> --rows N [--workers N]
//!   info                                            environment + artifacts

use anyhow::{bail, Context, Result};
use hiframes::baseline::sparklike::SparkLike;
use hiframes::bigbench::{self, q05, q25, q26};
use hiframes::frame::HiFrames;
use hiframes::metrics::time_it;
use hiframes::prelude::*;
use std::collections::HashMap;

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut out = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            match args.get(i + 1).filter(|v| !v.starts_with("--")) {
                Some(val) => {
                    out.insert(key.to_string(), val.clone());
                    i += 2;
                }
                None => {
                    out.insert(key.to_string(), "true".to_string());
                    i += 1;
                }
            }
        } else {
            i += 1;
        }
    }
    out
}

fn flag_f64(flags: &HashMap<String, String>, key: &str, default: f64) -> f64 {
    flags.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn flag_usize(flags: &HashMap<String, String>, key: &str, default: usize) -> usize {
    flags.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else {
        print_usage();
        return Ok(());
    };
    let flags = parse_flags(&args[1..]);
    let workers = flag_usize(&flags, "workers", hiframes::config::default_workers());
    match cmd.as_str() {
        "gen-data" => gen_data(&flags),
        "query" => query(&flags, workers),
        "plan" => show_plan(&flags),
        "pipeline" => pipeline(&flags, workers),
        "micro" => micro(&flags, workers),
        "info" => info(),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => {
            print_usage();
            bail!("unknown command {other}");
        }
    }
}

fn print_usage() {
    eprintln!(
        "hiframes — compiler-based distributed data frames (HiFrames reproduction)\n\
         usage: hiframes <gen-data|query|plan|pipeline|micro|info> [flags]\n\
         \n\
         gen-data  --sf <f> --out <dir> [--skew <a>]\n\
         query     --q <05|25|26> [--sf f] [--workers N] [--engine hiframes|sparklike] [--skew a]\n\
         plan      --q <05|25|26> [--no-opt]\n\
         pipeline  [--sf f] [--workers N] [--pjrt]\n\
         micro     --op <filter|join|aggregate|cumsum|sma|wma> [--rows N] [--workers N]\n\
         info"
    );
}

fn db_for(flags: &HashMap<String, String>) -> bigbench::BbTables {
    bigbench::generate(&bigbench::GenOptions {
        scale_factor: flag_f64(flags, "sf", 1.0),
        click_skew: flag_f64(flags, "skew", 0.0),
        seed: 42,
    })
}

fn gen_data(flags: &HashMap<String, String>) -> Result<()> {
    let out = flags.get("out").context("gen-data: need --out <dir>")?;
    let dir = std::path::Path::new(out);
    std::fs::create_dir_all(dir)?;
    let db = db_for(flags);
    for (name, t) in [
        ("store_sales", &db.store_sales),
        ("web_sales", &db.web_sales),
        ("web_clickstream", &db.web_clickstream),
        ("item", &db.item),
        ("customer", &db.customer),
        ("customer_demographics", &db.customer_demographics),
    ] {
        let p = dir.join(format!("{name}.hfs"));
        hiframes::io::write_hfs(&p, t)?;
        println!("{}: {} rows", p.display(), t.num_rows());
    }
    Ok(())
}

fn query(flags: &HashMap<String, String>, workers: usize) -> Result<()> {
    let q = flags.get("q").context("query: need --q <05|25|26>")?;
    let engine = flags.get("engine").map(|s| s.as_str()).unwrap_or("hiframes");
    let db = db_for(flags);
    let (rows, secs) = match (q.as_str(), engine) {
        ("26", "hiframes") => {
            let hf = HiFrames::with_workers(workers);
            let p = q26::Q26Params::default();
            time_it(|| {
                q26::hiframes_relational(&hf, &db, &p)
                    .collect()
                    .unwrap()
                    .num_rows()
            })
        }
        ("26", "sparklike") => {
            let eng = SparkLike::new(workers, workers * 2);
            let p = q26::Q26Params::default();
            time_it(|| {
                eng.collect(&q26::sparklike_relational(&eng, &db, &p).unwrap())
                    .unwrap()
                    .num_rows()
            })
        }
        ("25", "hiframes") => {
            let hf = HiFrames::with_workers(workers);
            time_it(|| q25::hiframes_relational(&hf, &db).collect().unwrap().num_rows())
        }
        ("25", "sparklike") => {
            let eng = SparkLike::new(workers, workers * 2);
            time_it(|| {
                eng.collect(&q25::sparklike_relational(&eng, &db).unwrap())
                    .unwrap()
                    .num_rows()
            })
        }
        ("05", "hiframes") => {
            let hf = HiFrames::with_workers(workers);
            time_it(|| q05::hiframes_relational(&hf, &db).collect().unwrap().num_rows())
        }
        ("05", "sparklike") => {
            let eng = SparkLike::new(workers, workers * 2);
            time_it(|| {
                eng.collect(&q05::sparklike_relational(&eng, &db).unwrap())
                    .unwrap()
                    .num_rows()
            })
        }
        (q, e) => bail!("unknown query/engine: {q}/{e}"),
    };
    println!("Q{q} on {engine}: {rows} rows in {:.1} ms ({workers} workers)", secs * 1e3);
    Ok(())
}

fn show_plan(flags: &HashMap<String, String>) -> Result<()> {
    let q = flags.get("q").context("plan: need --q <05|25|26>")?;
    let db = db_for(flags);
    let hf = HiFrames::with_workers(2);
    let plan = match q.as_str() {
        "26" => q26::hiframes_relational(&hf, &db, &q26::Q26Params::default())
            .plan()
            .clone(),
        "25" => q25::hiframes_relational(&hf, &db).plan().clone(),
        "05" => q05::hiframes_relational(&hf, &db).plan().clone(),
        other => bail!("unknown query {other}"),
    };
    if flags.contains_key("no-opt") {
        println!("unoptimized plan:\n{plan}");
    } else {
        let opt = hiframes::passes::optimize(plan, &hiframes::passes::PassOptions::default())?;
        println!("optimized plan:\n{opt}");
    }
    Ok(())
}

fn pipeline(flags: &HashMap<String, String>, workers: usize) -> Result<()> {
    let db = db_for(flags);
    let hf = HiFrames::with_workers(workers);
    let use_pjrt =
        flags.contains_key("pjrt") && hiframes::runtime::artifacts_available();
    let p = q26::Q26Params::default();
    let ((rel, cents), secs) = time_it(|| q26::hiframes_full(&hf, &db, &p, use_pjrt).unwrap());
    println!(
        "Q26 end-to-end ({}): {} customers -> {} centroids in {:.1} ms",
        if use_pjrt { "pjrt" } else { "rust kernel" },
        rel.num_rows(),
        cents.num_rows(),
        secs * 1e3
    );
    println!("{cents}");
    Ok(())
}

fn micro(flags: &HashMap<String, String>, workers: usize) -> Result<()> {
    let op = flags.get("op").context("micro: need --op")?;
    let rows = flag_usize(flags, "rows", 1_000_000);
    let hf = HiFrames::with_workers(workers);
    let secs = match op.as_str() {
        "filter" => {
            let t = hiframes::datagen::micro_table(rows, 1000, 1);
            let df = hf.table("t", t);
            time_it(|| df.filter(col("x").lt(lit(0.5))).collect().unwrap()).1
        }
        "join" => {
            let l = hiframes::datagen::micro_table(rows, rows as i64 / 2, 1);
            let r = hiframes::datagen::micro_table(rows / 4, rows as i64 / 2, 2);
            let rdf = hf.table("r", r).rename("id", "rid").select(&["rid"]);
            let df = hf.table("l", l);
            time_it(|| df.join(&rdf, "id", "rid").count().unwrap()).1
        }
        "aggregate" => {
            let t = hiframes::datagen::micro_table(rows, 10_000, 1);
            let df = hf.table("t", t);
            time_it(|| {
                df.aggregate(
                    "id",
                    vec![
                        AggExpr::new("s", AggFn::Sum, col("x")),
                        AggExpr::new("m", AggFn::Mean, col("y")),
                    ],
                )
                .collect()
                .unwrap()
            })
            .1
        }
        "cumsum" => {
            let t = Table::from_pairs(vec![("x", hiframes::datagen::series(rows, 1))])?;
            let df = hf.table("t", t);
            time_it(|| df.cumsum("x", "cs").collect().unwrap()).1
        }
        "sma" => {
            let t = Table::from_pairs(vec![("x", hiframes::datagen::series(rows, 1))])?;
            let df = hf.table("t", t);
            time_it(|| df.sma("x", "s", 3).collect().unwrap()).1
        }
        "wma" => {
            let t = Table::from_pairs(vec![("x", hiframes::datagen::series(rows, 1))])?;
            let df = hf.table("t", t);
            time_it(|| df.wma("x", "w").collect().unwrap()).1
        }
        other => bail!("unknown op {other}"),
    };
    println!(
        "{op} over {rows} rows on {workers} workers: {:.1} ms ({:.2} M rows/s)",
        secs * 1e3,
        hiframes::metrics::mrows_per_sec(rows, secs)
    );
    Ok(())
}

fn info() -> Result<()> {
    println!("hiframes {} — HiFrames (2017) reproduction", env!("CARGO_PKG_VERSION"));
    println!("default workers: {}", hiframes::config::default_workers());
    println!(
        "artifacts: {}",
        if hiframes::runtime::artifacts_available() {
            "available"
        } else {
            "missing (run `make artifacts`)"
        }
    );
    if hiframes::runtime::artifacts_available() {
        let engine = hiframes::runtime::Engine::load_default()?;
        let mut names = engine.entry_names();
        names.sort();
        for n in names {
            let e = engine.entry(n)?;
            println!("  entry {n}: {:?}", e.params);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn flags(args: &[&str]) -> HashMap<String, String> {
        parse_flags(&args.iter().map(|s| s.to_string()).collect::<Vec<_>>())
    }

    #[test]
    fn parse_flags_values_and_booleans() {
        let f = flags(&["--sf", "2.5", "--pjrt", "--workers", "4"]);
        assert_eq!(f.get("sf").map(|s| s.as_str()), Some("2.5"));
        assert_eq!(f.get("pjrt").map(|s| s.as_str()), Some("true"));
        assert_eq!(flag_usize(&f, "workers", 0), 4);
        assert_eq!(flag_f64(&f, "sf", 0.0), 2.5);
        assert_eq!(flag_usize(&f, "missing", 7), 7);
    }

    #[test]
    fn parse_flags_trailing_boolean() {
        let f = flags(&["--q", "26", "--no-opt"]);
        assert_eq!(f.get("q").map(|s| s.as_str()), Some("26"));
        assert!(f.contains_key("no-opt"));
    }

    #[test]
    fn parse_flags_last_wins() {
        let f = flags(&["--sf", "1", "--sf", "2"]);
        assert_eq!(flag_f64(&f, "sf", 0.0), 2.0);
    }
}
