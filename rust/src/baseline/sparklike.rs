//! Sparklike — an architectural model of the Spark SQL execution engine the
//! paper compares against (§2.2, §2.3, §5).
//!
//! What is modeled (and measured, not simulated with sleeps):
//!
//! * **master/driver bottleneck** — a single work queue behind a mutex;
//!   every task dispatch and result return serializes through it.
//! * **per-task scheduling** — stages are split into one task per
//!   partition; workers pull tasks one at a time.
//! * **row-oriented processing** — partitions are `Vec<Row>` with `Value`
//!   cells (deserialized JVM objects), not columnar arrays.
//! * **serialized shuffle** — map outputs are encoded to bytes into a
//!   shuffle store keyed `(shuffle_id, map, reduce)` and decoded by the
//!   reduce side (Spark's shuffle write/read).
//! * **map-reduce-only communication** — no scan or halo primitives:
//!   `cumsum`/window ops repartition everything to ONE partition and run
//!   sequentially (exactly the behaviour the paper measures in Fig. 8b).
//! * **boxed per-row UDFs** vs built-in expressions (Fig. 9/10).
//!
//! Map-side combiners for aggregation ARE implemented (Spark has them) so
//! the comparison is not a strawman.

use super::rowexpr::{compile_row_expr, eval_row, RowExpr};
use super::Row;
use crate::column::Column;
use crate::expr::{AggExpr, AggFn, AggState, Expr};
use crate::table::{Schema, Table};
use crate::types::{DType, Value};
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};

type Job = Box<dyn FnOnce() + Send>;

/// Scheduler / shuffle statistics (reported by benches).
#[derive(Debug, Default)]
pub struct EngineStats {
    pub tasks_scheduled: AtomicU64,
    pub shuffle_bytes: AtomicU64,
    pub stages: AtomicU64,
}

/// The driver: owns the executor pool and the shuffle store.
pub struct SparkLike {
    job_tx: Sender<Job>,
    handles: Vec<std::thread::JoinHandle<()>>,
    pub partitions: usize,
    pub stats: Arc<EngineStats>,
}

impl SparkLike {
    /// `workers` executor threads, `partitions` partitions per RDD.
    pub fn new(workers: usize, partitions: usize) -> SparkLike {
        assert!(workers > 0 && partitions > 0);
        let (tx, rx) = channel::<Job>();
        // ONE shared receiver behind a mutex: the central scheduler all
        // executors contend on — the master bottleneck, made concrete
        let rx = Arc::new(Mutex::new(rx));
        let mut handles = Vec::new();
        for _ in 0..workers {
            let rx = rx.clone();
            handles.push(std::thread::spawn(move || loop {
                let job = {
                    let guard = rx.lock().unwrap();
                    guard.recv()
                };
                match job {
                    Ok(job) => job(),
                    Err(_) => break,
                }
            }));
        }
        SparkLike {
            job_tx: tx,
            handles,
            partitions,
            stats: Arc::new(EngineStats::default()),
        }
    }

    /// Run one stage: one task per input item, results in input order.
    fn run_stage<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(usize, T) -> R + Send + Sync + 'static,
    {
        self.stats.stages.fetch_add(1, Ordering::Relaxed);
        let n = items.len();
        let f = Arc::new(f);
        let (res_tx, res_rx): (Sender<(usize, R)>, Receiver<(usize, R)>) = channel();
        for (i, item) in items.into_iter().enumerate() {
            self.stats.tasks_scheduled.fetch_add(1, Ordering::Relaxed);
            let f = f.clone();
            let res_tx = res_tx.clone();
            self.job_tx
                .send(Box::new(move || {
                    let r = f(i, item);
                    let _ = res_tx.send((i, r));
                }))
                .expect("executor pool is gone");
        }
        drop(res_tx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (i, r) = res_rx.recv().expect("task lost");
            out[i] = Some(r);
        }
        out.into_iter().map(|r| r.unwrap()).collect()
    }

    /// Create an RDD from a table (split into `partitions` row blocks).
    pub fn parallelize(&self, table: &Table) -> Rdd {
        let n = table.num_rows();
        let mut parts = Vec::with_capacity(self.partitions);
        for p in 0..self.partitions {
            let (start, len) = crate::comm::block_range(n, self.partitions, p);
            let mut rows = Vec::with_capacity(len);
            for i in start..start + len {
                rows.push(table.row(i));
            }
            parts.push(rows);
        }
        Rdd {
            schema: table.schema().clone(),
            parts,
        }
    }

    /// Built-in (non-UDF) filter.
    pub fn filter(&self, rdd: &Rdd, predicate: &Expr) -> Result<Rdd> {
        let compiled = compile_row_expr(predicate, &rdd.schema)?;
        let parts = self.run_stage(rdd.parts.clone(), move |_, rows: Vec<Row>| {
            rows.into_iter()
                .filter(|r| {
                    eval_row(&compiled, r)
                        .ok()
                        .and_then(|v| v.as_bool())
                        .unwrap_or(false)
                })
                .collect::<Vec<Row>>()
        });
        Ok(Rdd {
            schema: rdd.schema.clone(),
            parts,
        })
    }

    /// Add/replace a column from an expression (`withColumn`).
    pub fn with_column(&self, rdd: &Rdd, name: &str, expr: &Expr) -> Result<Rdd> {
        let compiled = compile_row_expr(expr, &rdd.schema)?;
        let dt = expr.dtype(&rdd.schema)?;
        let replace_at = rdd.schema.index_of(name);
        let parts = self.run_stage(rdd.parts.clone(), move |_, rows: Vec<Row>| {
            rows.into_iter()
                .map(|mut r| {
                    let v = eval_row(&compiled, &r).expect("row eval");
                    match replace_at {
                        Some(i) => r[i] = v,
                        None => r.push(v),
                    }
                    r
                })
                .collect::<Vec<Row>>()
        });
        let mut fields = rdd.schema.fields().to_vec();
        match replace_at {
            Some(i) => fields[i].1 = dt,
            None => fields.push((name.to_string(), dt)),
        }
        Ok(Rdd {
            schema: Schema::new(fields),
            parts,
        })
    }

    /// Projection.
    pub fn select(&self, rdd: &Rdd, columns: &[&str]) -> Result<Rdd> {
        let idx: Vec<usize> = columns
            .iter()
            .map(|c| {
                rdd.schema
                    .index_of(c)
                    .with_context(|| format!("select: no column {c}"))
            })
            .collect::<Result<_>>()?;
        let idx2 = idx.clone();
        let parts = self.run_stage(rdd.parts.clone(), move |_, rows: Vec<Row>| {
            rows.into_iter()
                .map(|r| idx2.iter().map(|&i| r[i].clone()).collect::<Row>())
                .collect::<Vec<Row>>()
        });
        let fields = idx
            .iter()
            .map(|&i| rdd.schema.fields()[i].clone())
            .collect();
        Ok(Rdd {
            schema: Schema::new(fields),
            parts,
        })
    }

    // ---- shuffle machinery -------------------------------------------------

    /// Serialize rows into per-reduce-partition buffers, then decode — the
    /// shuffle write/read boundary with real ser/de cost.
    fn shuffle_rows(
        &self,
        rdd_parts: Vec<Vec<(i64, Row)>>,
        nreduce: usize,
    ) -> Vec<Vec<(i64, Row)>> {
        let stats = self.stats.clone();
        // map side: encode each partition's output per reduce bucket
        let written: Vec<Vec<Vec<u8>>> =
            self.run_stage(rdd_parts, move |_, rows: Vec<(i64, Row)>| {
                let mut bufs: Vec<Vec<u8>> = (0..nreduce).map(|_| Vec::new()).collect();
                for (k, row) in rows {
                    let dst = (k.rem_euclid(nreduce as i64)) as usize;
                    encode_row(k, &row, &mut bufs[dst]);
                }
                for b in &bufs {
                    stats.shuffle_bytes.fetch_add(b.len() as u64, Ordering::Relaxed);
                }
                bufs
            });
        // shuffle store hand-off + reduce side decode
        let written = Arc::new(written);
        let w2 = written.clone();
        self.run_stage(
            (0..nreduce).collect::<Vec<usize>>(),
            move |_, reduce_id: usize| {
                let mut rows = Vec::new();
                for map_out in w2.iter() {
                    decode_rows(&map_out[reduce_id], &mut rows);
                }
                rows
            },
        )
    }

    /// Inner equi-join via hash shuffle on both sides.
    pub fn join(&self, left: &Rdd, right: &Rdd, lk: &str, rk: &str) -> Result<Rdd> {
        let li = left
            .schema
            .index_of(lk)
            .with_context(|| format!("join: no column {lk}"))?;
        let ri = right
            .schema
            .index_of(rk)
            .with_context(|| format!("join: no column {rk}"))?;
        let keyed_l: Vec<Vec<(i64, Row)>> = self.run_stage(left.parts.clone(), move |_, rows| {
            keyed_by(rows, li)
        });
        let keyed_r: Vec<Vec<(i64, Row)>> = self.run_stage(right.parts.clone(), move |_, rows| {
            keyed_by(rows, ri)
        });
        let nreduce = self.partitions;
        let lparts = self.shuffle_rows(keyed_l, nreduce);
        let rparts = self.shuffle_rows(keyed_r, nreduce);
        // reduce side: per-partition hash join
        let joined: Vec<Vec<Row>> = self.run_stage(
            lparts.into_iter().zip(rparts).collect::<Vec<_>>(),
            move |_, (lrows, rrows): (Vec<(i64, Row)>, Vec<(i64, Row)>)| {
                let mut index: HashMap<i64, Vec<Row>> = HashMap::new();
                for (k, row) in rrows {
                    let mut slim = row;
                    slim.remove(ri);
                    index.entry(k).or_default().push(slim);
                }
                let mut out = Vec::new();
                for (k, lrow) in lrows {
                    if let Some(matches) = index.get(&k) {
                        for m in matches {
                            let mut row = lrow.clone();
                            row.extend(m.iter().cloned());
                            out.push(row);
                        }
                    }
                }
                out
            },
        );
        let mut fields = left.schema.fields().to_vec();
        for (n, t) in right.schema.fields() {
            if n == rk {
                continue;
            }
            if left.schema.dtype_of(n).is_some() {
                bail!("join: column {n} on both sides");
            }
            fields.push((n.clone(), *t));
        }
        Ok(Rdd {
            schema: Schema::new(fields),
            parts: joined,
        })
    }

    /// Group-by aggregation with map-side combine.
    pub fn aggregate(&self, rdd: &Rdd, key: &str, aggs: &[AggExpr]) -> Result<Rdd> {
        let ki = rdd
            .schema
            .index_of(key)
            .with_context(|| format!("aggregate: no column {key}"))?;
        let compiled: Vec<(RowExpr, AggFn, DType)> = aggs
            .iter()
            .map(|a| {
                Ok((
                    compile_row_expr(&a.input, &rdd.schema)?,
                    a.func,
                    a.input.dtype(&rdd.schema)?,
                ))
            })
            .collect::<Result<_>>()?;
        let compiled = Arc::new(compiled);
        let c2 = compiled.clone();
        // map side: partial states per key (the combiner)
        let combined: Vec<Vec<(i64, Row)>> =
            self.run_stage(rdd.parts.clone(), move |_, rows: Vec<Row>| {
                let mut table: HashMap<i64, Vec<AggState>> = HashMap::new();
                for row in rows {
                    let k = row[ki].as_i64().expect("agg key not int");
                    let states = table.entry(k).or_insert_with(|| {
                        c2.iter()
                            .map(|(_, f, dt)| AggState::new(*f, *dt))
                            .collect()
                    });
                    for ((e, _, _), s) in c2.iter().zip(states.iter_mut()) {
                        s.update(&eval_row(e, &row).expect("agg expr"));
                    }
                }
                // partial states travel the shuffle as encoded rows
                table
                    .into_iter()
                    .map(|(k, states)| {
                        let mut buf = Vec::new();
                        for s in &states {
                            s.encode(&mut buf);
                        }
                        (k, vec![Value::Str(unsafe_bytes_to_str(buf))])
                    })
                    .collect()
            });
        let merged = self.shuffle_rows(combined, self.partitions);
        let c3 = compiled.clone();
        let parts: Vec<Vec<Row>> = self.run_stage(merged, move |_, rows: Vec<(i64, Row)>| {
            let mut table: HashMap<i64, Vec<AggState>> = HashMap::new();
            for (k, row) in rows {
                let Value::Str(ref encoded) = row[0] else {
                    panic!("agg shuffle row")
                };
                let bytes = str_to_bytes(encoded);
                let mut pos = 0usize;
                let incoming: Vec<AggState> = c3
                    .iter()
                    .map(|(_, f, dt)| AggState::decode(*f, *dt, &bytes, &mut pos))
                    .collect();
                match table.entry(k) {
                    std::collections::hash_map::Entry::Occupied(mut e) => {
                        for (a, b) in e.get_mut().iter_mut().zip(&incoming) {
                            a.merge(b);
                        }
                    }
                    std::collections::hash_map::Entry::Vacant(e) => {
                        e.insert(incoming);
                    }
                }
            }
            let mut keys: Vec<i64> = table.keys().copied().collect();
            keys.sort_unstable();
            keys.into_iter()
                .map(|k| {
                    let mut row: Row = vec![Value::I64(k)];
                    for s in &table[&k] {
                        row.push(s.finish());
                    }
                    row
                })
                .collect()
        });
        let mut fields = vec![(key.to_string(), DType::I64)];
        for a in aggs {
            fields.push((a.out.clone(), a.output_dtype(&rdd.schema)?));
        }
        Ok(Rdd {
            schema: Schema::new(fields),
            parts,
        })
    }

    /// Window/scan operations: repartition EVERYTHING to one partition and
    /// run sequentially — the map-reduce limitation of §5/Fig. 8b.
    pub fn window_one_executor(
        &self,
        rdd: &Rdd,
        column: &str,
        out: &str,
        kind: WindowKind,
    ) -> Result<Rdd> {
        let ci = rdd
            .schema
            .index_of(column)
            .with_context(|| format!("window: no column {column}"))?;
        // gather: key everything to partition 0 through the shuffle store
        // (serialization cost included, as in Spark)
        let keyed: Vec<Vec<(i64, Row)>> = self.run_stage(rdd.parts.clone(), move |pi, rows| {
            rows.into_iter()
                .map(|r| ((pi as i64) << 32, r)) // preserve partition order in key high bits
                .collect()
        });
        let mut gathered = self.shuffle_rows(keyed, 1);
        let mut rows = std::mem::take(&mut gathered[0]);
        rows.sort_by_key(|(k, _)| *k); // restore global order
        let mut rows: Vec<Row> = rows.into_iter().map(|(_, r)| r).collect();
        // sequential computation on the single executor
        let xs: Vec<f64> = rows
            .iter()
            .map(|r| r[ci].as_f64().context("window col"))
            .collect::<Result<_>>()?;
        let vals: Vec<f64> = match &kind {
            WindowKind::Cumsum => {
                let mut acc = 0.0;
                xs.iter()
                    .map(|&x| {
                        acc += x;
                        acc
                    })
                    .collect()
            }
            WindowKind::Stencil(weights) => crate::ops::stencil_serial(&xs, weights),
            WindowKind::StencilUdf { window, func } => {
                let r = window / 2;
                let n = xs.len();
                (0..n)
                    .map(|i| {
                        let lo = i.saturating_sub(r);
                        let hi = (i + r + 1).min(n);
                        let win: Vec<f64> = xs[lo..hi].to_vec();
                        func(&win)
                    })
                    .collect()
            }
        };
        for (row, v) in rows.iter_mut().zip(vals) {
            row.push(Value::F64(v));
        }
        let mut fields = rdd.schema.fields().to_vec();
        fields.push((out.to_string(), DType::F64));
        // output stays on ONE partition (Spark leaves it that way too)
        let mut parts: Vec<Vec<Row>> = (0..self.partitions).map(|_| Vec::new()).collect();
        parts[0] = rows;
        Ok(Rdd {
            schema: Schema::new(fields),
            parts,
        })
    }

    /// Materialize an RDD back on the driver.
    pub fn collect(&self, rdd: &Rdd) -> Result<Table> {
        let mut cols: Vec<Column> = rdd
            .schema
            .fields()
            .iter()
            .map(|(_, t)| Column::new_empty(*t))
            .collect();
        for part in &rdd.parts {
            for row in part {
                for (c, v) in cols.iter_mut().zip(row) {
                    c.push(v);
                }
            }
        }
        Table::new(rdd.schema.clone(), cols)
    }
}

impl Drop for SparkLike {
    fn drop(&mut self) {
        // close the queue and join executors
        let (tx, _) = channel();
        let old = std::mem::replace(&mut self.job_tx, tx);
        drop(old);
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Window computation kinds for [`SparkLike::window_one_executor`].
pub enum WindowKind {
    Cumsum,
    Stencil(Vec<f64>),
    StencilUdf {
        window: usize,
        func: Arc<dyn Fn(&[f64]) -> f64 + Send + Sync>,
    },
}

/// A row-oriented distributed collection.
#[derive(Debug, Clone)]
pub struct Rdd {
    pub schema: Schema,
    pub parts: Vec<Vec<Row>>,
}

impl Rdd {
    pub fn num_rows(&self) -> usize {
        self.parts.iter().map(|p| p.len()).sum()
    }
}

fn keyed_by(rows: Vec<Row>, key_idx: usize) -> Vec<(i64, Row)> {
    rows.into_iter()
        .map(|r| {
            let k = r[key_idx].as_i64().expect("join key not int");
            (k, r)
        })
        .collect()
}

// row wire format: key + cell-tagged values
fn encode_row(key: i64, row: &Row, buf: &mut Vec<u8>) {
    buf.extend_from_slice(&key.to_le_bytes());
    buf.extend_from_slice(&(row.len() as u16).to_le_bytes());
    for v in row {
        match v {
            Value::I64(x) => {
                buf.push(0);
                buf.extend_from_slice(&x.to_le_bytes());
            }
            Value::F64(x) => {
                buf.push(1);
                buf.extend_from_slice(&x.to_le_bytes());
            }
            Value::Bool(x) => {
                buf.push(2);
                buf.push(*x as u8);
            }
            Value::Str(s) => {
                buf.push(3);
                buf.extend_from_slice(&(s.len() as u32).to_le_bytes());
                buf.extend_from_slice(s.as_bytes());
            }
        }
    }
}

fn decode_rows(buf: &[u8], out: &mut Vec<(i64, Row)>) {
    let mut pos = 0usize;
    while pos < buf.len() {
        let key = i64::from_le_bytes(buf[pos..pos + 8].try_into().unwrap());
        pos += 8;
        let n = u16::from_le_bytes(buf[pos..pos + 2].try_into().unwrap()) as usize;
        pos += 2;
        let mut row = Vec::with_capacity(n);
        for _ in 0..n {
            let tag = buf[pos];
            pos += 1;
            match tag {
                0 => {
                    row.push(Value::I64(i64::from_le_bytes(
                        buf[pos..pos + 8].try_into().unwrap(),
                    )));
                    pos += 8;
                }
                1 => {
                    row.push(Value::F64(f64::from_le_bytes(
                        buf[pos..pos + 8].try_into().unwrap(),
                    )));
                    pos += 8;
                }
                2 => {
                    row.push(Value::Bool(buf[pos] != 0));
                    pos += 1;
                }
                3 => {
                    let len =
                        u32::from_le_bytes(buf[pos..pos + 4].try_into().unwrap()) as usize;
                    pos += 4;
                    row.push(Value::Str(
                        String::from_utf8_lossy(&buf[pos..pos + len]).into_owned(),
                    ));
                    pos += len;
                }
                t => panic!("bad row tag {t}"),
            }
        }
        out.push((key, row));
    }
}

// agg partial states ride in a Str cell; latin-1-safe transport
fn unsafe_bytes_to_str(bytes: Vec<u8>) -> String {
    bytes.iter().map(|&b| b as char).collect()
}

fn str_to_bytes(s: &str) -> Vec<u8> {
    s.chars().map(|c| c as u8).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::expr::{col, lit};

    fn table() -> Table {
        Table::from_pairs(vec![
            ("id", Column::I64(vec![0, 1, 2, 3, 4, 5, 6, 7])),
            (
                "x",
                Column::F64(vec![0.0, 0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7]),
            ),
        ])
        .unwrap()
    }

    #[test]
    fn filter_and_collect() {
        let eng = SparkLike::new(2, 4);
        let rdd = eng.parallelize(&table());
        let f = eng.filter(&rdd, &col("x").lt(lit(0.35))).unwrap();
        let t = eng.collect(&f).unwrap();
        assert_eq!(t.column("id").unwrap().as_i64(), &[0, 1, 2, 3]);
        assert!(eng.stats.tasks_scheduled.load(Ordering::Relaxed) >= 4);
    }

    #[test]
    fn join_matches_serial() {
        let eng = SparkLike::new(3, 3);
        let right = Table::from_pairs(vec![
            ("rid", Column::I64(vec![1, 3, 5, 9])),
            ("tag", Column::I64(vec![10, 30, 50, 90])),
        ])
        .unwrap();
        let j = eng
            .join(
                &eng.parallelize(&table()),
                &eng.parallelize(&right),
                "id",
                "rid",
            )
            .unwrap();
        let t = eng.collect(&j).unwrap().sorted_by("id").unwrap();
        assert_eq!(t.column("id").unwrap().as_i64(), &[1, 3, 5]);
        assert_eq!(t.column("tag").unwrap().as_i64(), &[10, 30, 50]);
        assert!(eng.stats.shuffle_bytes.load(Ordering::Relaxed) > 0);
    }

    #[test]
    fn aggregate_with_combiner() {
        let eng = SparkLike::new(2, 4);
        let rdd = eng.parallelize(&table());
        let keyed = eng
            .with_column(&rdd, "id", &col("id").rem(lit(2i64)))
            .unwrap();
        let agg = eng
            .aggregate(
                &keyed,
                "id",
                &[
                    AggExpr::new("s", AggFn::Sum, col("x")),
                    AggExpr::new("n", AggFn::Count, col("x")),
                ],
            )
            .unwrap();
        let t = eng.collect(&agg).unwrap().sorted_by("id").unwrap();
        assert_eq!(t.column("id").unwrap().as_i64(), &[0, 1]);
        let s = t.column("s").unwrap().as_f64();
        assert!((s[0] - 1.2).abs() < 1e-9);
        assert!((s[1] - 1.6).abs() < 1e-9);
        assert_eq!(t.column("n").unwrap().as_i64(), &[4, 4]);
    }

    #[test]
    fn window_gathers_to_one_partition() {
        let eng = SparkLike::new(2, 4);
        let rdd = eng.parallelize(&table());
        let w = eng
            .window_one_executor(&rdd, "x", "cs", WindowKind::Cumsum)
            .unwrap();
        // everything on partition 0 — the map-reduce limitation
        assert_eq!(w.parts[0].len(), 8);
        assert!(w.parts[1..].iter().all(|p| p.is_empty()));
        let t = eng.collect(&w).unwrap();
        let cs = t.column("cs").unwrap().as_f64();
        assert!((cs[7] - 2.8).abs() < 1e-9);
    }

    #[test]
    fn window_stencil_matches_hiframes_semantics() {
        let eng = SparkLike::new(2, 3);
        let rdd = eng.parallelize(&table());
        let w = eng
            .window_one_executor(
                &rdd,
                "x",
                "sma",
                WindowKind::Stencil(crate::ops::stencil::sma_weights(3)),
            )
            .unwrap();
        let t = eng.collect(&w).unwrap();
        let expect = crate::ops::stencil_serial(
            &table().column("x").unwrap().to_f64_vec(),
            &crate::ops::stencil::sma_weights(3),
        );
        for (a, b) in t.column("sma").unwrap().as_f64().iter().zip(&expect) {
            assert!((a - b).abs() < 1e-9);
        }
    }

    #[test]
    fn select_and_udf_window() {
        let eng = SparkLike::new(2, 2);
        let rdd = eng.parallelize(&table());
        let s = eng.select(&rdd, &["x"]).unwrap();
        assert_eq!(s.schema.names(), vec!["x"]);
        let w = eng
            .window_one_executor(
                &s,
                "x",
                "wma",
                WindowKind::StencilUdf {
                    window: 3,
                    func: Arc::new(|w: &[f64]| w.iter().sum::<f64>() / w.len() as f64),
                },
            )
            .unwrap();
        assert_eq!(eng.collect(&w).unwrap().num_rows(), 8);
    }

    #[test]
    fn string_roundtrip_through_shuffle() {
        let eng = SparkLike::new(2, 2);
        let t = Table::from_pairs(vec![
            ("id", Column::I64(vec![1, 2, 3, 4])),
            (
                "s",
                Column::Str(vec!["a".into(), "b".into(), "c".into(), "d".into()]),
            ),
        ])
        .unwrap();
        let r = Table::from_pairs(vec![("rid", Column::I64(vec![2, 4]))]).unwrap();
        let j = eng
            .join(&eng.parallelize(&t), &eng.parallelize(&r), "id", "rid")
            .unwrap();
        let out = eng.collect(&j).unwrap().sorted_by("id").unwrap();
        assert_eq!(out.column("s").unwrap().as_str_col(), &["b".to_string(), "d".into()]);
    }
}
