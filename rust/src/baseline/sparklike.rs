//! Sparklike — an architectural model of the Spark SQL execution engine the
//! paper compares against (§2.2, §2.3, §5).
//!
//! What is modeled (and measured, not simulated with sleeps):
//!
//! * **master/driver bottleneck** — a single work queue behind a mutex;
//!   every task dispatch and result return serializes through it.
//! * **per-task scheduling** — stages are split into one task per
//!   partition; workers pull tasks one at a time.
//! * **row-oriented processing** — partitions are `Vec<Row>` with `Value`
//!   cells (deserialized JVM objects), not columnar arrays.
//! * **serialized shuffle** — map outputs are encoded to bytes into a
//!   shuffle store keyed `(shuffle_id, map, reduce)` and decoded by the
//!   reduce side (Spark's shuffle write/read).
//! * **map-reduce-only communication** — no scan or halo primitives:
//!   `cumsum`/window ops repartition everything to ONE partition and run
//!   sequentially (exactly the behaviour the paper measures in Fig. 8b).
//! * **boxed per-row UDFs** vs built-in expressions (Fig. 9/10).
//!
//! Map-side combiners for aggregation ARE implemented (Spark has them) so
//! the comparison is not a strawman.

use super::rowexpr::{compile_row_expr, eval_row, RowExpr};
use super::Row;
use crate::column::Column;
use crate::expr::{AggExpr, AggFn, AggState, Expr};
use crate::ir::WindowAgg;
use crate::ops::join::local_join_pairs;
use crate::ops::keys::{hash_key_row, KeyRow, KeyVal};
use crate::ops::window::{partition_runs, rank_from_breaks};
use crate::table::{Schema, Table};
use crate::types::{DType, JoinType, SortOrder, Value, WindowFrame, WindowFunc};
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};

type Job = Box<dyn FnOnce() + Send>;

/// Scheduler / shuffle statistics (reported by benches).
#[derive(Debug, Default)]
pub struct EngineStats {
    pub tasks_scheduled: AtomicU64,
    pub shuffle_bytes: AtomicU64,
    pub stages: AtomicU64,
}

/// The driver: owns the executor pool and the shuffle store.
pub struct SparkLike {
    job_tx: Sender<Job>,
    handles: Vec<std::thread::JoinHandle<()>>,
    pub partitions: usize,
    pub stats: Arc<EngineStats>,
}

impl SparkLike {
    /// `workers` executor threads, `partitions` partitions per RDD.
    pub fn new(workers: usize, partitions: usize) -> SparkLike {
        assert!(workers > 0 && partitions > 0);
        let (tx, rx) = channel::<Job>();
        // ONE shared receiver behind a mutex: the central scheduler all
        // executors contend on — the master bottleneck, made concrete
        let rx = Arc::new(Mutex::new(rx));
        let mut handles = Vec::new();
        for _ in 0..workers {
            let rx = rx.clone();
            handles.push(std::thread::spawn(move || loop {
                let job = {
                    let guard = rx.lock().unwrap();
                    guard.recv()
                };
                match job {
                    Ok(job) => job(),
                    Err(_) => break,
                }
            }));
        }
        SparkLike {
            job_tx: tx,
            handles,
            partitions,
            stats: Arc::new(EngineStats::default()),
        }
    }

    /// Run one stage: one task per input item, results in input order.
    fn run_stage<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(usize, T) -> R + Send + Sync + 'static,
    {
        self.stats.stages.fetch_add(1, Ordering::Relaxed);
        let n = items.len();
        let f = Arc::new(f);
        let (res_tx, res_rx): (Sender<(usize, R)>, Receiver<(usize, R)>) = channel();
        for (i, item) in items.into_iter().enumerate() {
            self.stats.tasks_scheduled.fetch_add(1, Ordering::Relaxed);
            let f = f.clone();
            let res_tx = res_tx.clone();
            self.job_tx
                .send(Box::new(move || {
                    let r = f(i, item);
                    let _ = res_tx.send((i, r));
                }))
                .expect("executor pool is gone");
        }
        drop(res_tx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (i, r) = res_rx.recv().expect("task lost");
            out[i] = Some(r);
        }
        out.into_iter().map(|r| r.unwrap()).collect()
    }

    /// Create an RDD from a table (split into `partitions` row blocks).
    pub fn parallelize(&self, table: &Table) -> Rdd {
        let n = table.num_rows();
        let mut parts = Vec::with_capacity(self.partitions);
        for p in 0..self.partitions {
            let (start, len) = crate::comm::block_range(n, self.partitions, p);
            let mut rows = Vec::with_capacity(len);
            for i in start..start + len {
                rows.push(table.row(i));
            }
            parts.push(rows);
        }
        Rdd {
            schema: table.schema().clone(),
            parts,
        }
    }

    /// Built-in (non-UDF) filter.
    pub fn filter(&self, rdd: &Rdd, predicate: &Expr) -> Result<Rdd> {
        let compiled = compile_row_expr(predicate, &rdd.schema)?;
        let parts = self.run_stage(rdd.parts.clone(), move |_, rows: Vec<Row>| {
            rows.into_iter()
                .filter(|r| {
                    eval_row(&compiled, r)
                        .ok()
                        .and_then(|v| v.as_bool())
                        .unwrap_or(false)
                })
                .collect::<Vec<Row>>()
        });
        Ok(Rdd {
            schema: rdd.schema.clone(),
            parts,
        })
    }

    /// Add/replace a column from an expression (`withColumn`). Nullability
    /// follows the expression (null operands propagate through row eval).
    pub fn with_column(&self, rdd: &Rdd, name: &str, expr: &Expr) -> Result<Rdd> {
        let compiled = compile_row_expr(expr, &rdd.schema)?;
        let dt = expr.dtype(&rdd.schema)?;
        let nl = expr.nullable(&rdd.schema)?;
        let replace_at = rdd.schema.index_of(name);
        let parts = self.run_stage(rdd.parts.clone(), move |_, rows: Vec<Row>| {
            rows.into_iter()
                .map(|mut r| {
                    let v = eval_row(&compiled, &r).expect("row eval");
                    match replace_at {
                        Some(i) => r[i] = v,
                        None => r.push(v),
                    }
                    r
                })
                .collect::<Vec<Row>>()
        });
        let mut fields = rdd.schema.fields().to_vec();
        let mut nullable = rdd.schema.nullable_flags().to_vec();
        match replace_at {
            Some(i) => {
                fields[i].1 = dt;
                nullable[i] = nl;
            }
            None => {
                fields.push((name.to_string(), dt));
                nullable.push(nl);
            }
        }
        Ok(Rdd {
            schema: Schema::new_nullable(fields, nullable),
            parts,
        })
    }

    /// Batch `withColumn`: apply several `(name, expr)` pairs left to
    /// right, each one a separate stage, so later expressions can reference
    /// earlier outputs — the RDD mirror of
    /// [`crate::frame::DataFrame::with_columns`].
    pub fn with_columns(&self, rdd: &Rdd, columns: &[(&str, Expr)]) -> Result<Rdd> {
        let mut out = rdd.clone();
        for (name, expr) in columns {
            out = self.with_column(&out, name, expr)?;
        }
        Ok(out)
    }

    /// Projection.
    pub fn select(&self, rdd: &Rdd, columns: &[&str]) -> Result<Rdd> {
        let idx: Vec<usize> = columns
            .iter()
            .map(|c| {
                rdd.schema
                    .index_of(c)
                    .with_context(|| format!("select: no column {c}"))
            })
            .collect::<Result<_>>()?;
        let idx2 = idx.clone();
        let parts = self.run_stage(rdd.parts.clone(), move |_, rows: Vec<Row>| {
            rows.into_iter()
                .map(|r| idx2.iter().map(|&i| r[i].clone()).collect::<Row>())
                .collect::<Vec<Row>>()
        });
        let fields = idx
            .iter()
            .map(|&i| rdd.schema.fields()[i].clone())
            .collect();
        let nullable = idx.iter().map(|&i| rdd.schema.nullable_at(i)).collect();
        Ok(Rdd {
            schema: Schema::new_nullable(fields, nullable),
            parts,
        })
    }

    // ---- shuffle machinery -------------------------------------------------

    /// Serialize rows into per-reduce-partition buffers, then decode — the
    /// shuffle write/read boundary with real ser/de cost.
    fn shuffle_rows(
        &self,
        rdd_parts: Vec<Vec<(i64, Row)>>,
        nreduce: usize,
    ) -> Vec<Vec<(i64, Row)>> {
        let stats = self.stats.clone();
        // map side: encode each partition's output per reduce bucket
        let written: Vec<Vec<Vec<u8>>> =
            self.run_stage(rdd_parts, move |_, rows: Vec<(i64, Row)>| {
                let mut bufs: Vec<Vec<u8>> = (0..nreduce).map(|_| Vec::new()).collect();
                for (k, row) in rows {
                    let dst = (k.rem_euclid(nreduce as i64)) as usize;
                    encode_row(k, &row, &mut bufs[dst]);
                }
                for b in &bufs {
                    stats.shuffle_bytes.fetch_add(b.len() as u64, Ordering::Relaxed);
                }
                bufs
            });
        // shuffle store hand-off + reduce side decode
        let written = Arc::new(written);
        let w2 = written.clone();
        self.run_stage(
            (0..nreduce).collect::<Vec<usize>>(),
            move |_, reduce_id: usize| {
                let mut rows = Vec::new();
                for map_out in w2.iter() {
                    decode_rows(&map_out[reduce_id], &mut rows);
                }
                rows
            },
        )
    }

    /// Inner equi-join via hash shuffle on both sides — thin single-key
    /// wrapper over [`SparkLike::join_on`].
    pub fn join(&self, left: &Rdd, right: &Rdd, lk: &str, rk: &str) -> Result<Rdd> {
        self.join_on(left, right, &[(lk, rk)], JoinType::Inner)
    }

    /// Composite-key join with join-type semantics. Rows route by the Fx
    /// hash of their key tuple; the reduce side runs the same
    /// [`local_join_pairs`] kernel as the HiFrames engine, then assembles
    /// rows with the null-introducing promotions of the output schema.
    pub fn join_on(
        &self,
        left: &Rdd,
        right: &Rdd,
        on: &[(&str, &str)],
        how: JoinType,
    ) -> Result<Rdd> {
        if on.is_empty() {
            bail!("join: needs at least one key pair");
        }
        let li: Vec<usize> = on
            .iter()
            .map(|(lk, _)| {
                left.schema
                    .index_of(lk)
                    .with_context(|| format!("join: no column {lk}"))
            })
            .collect::<Result<_>>()?;
        let ri: Vec<usize> = on
            .iter()
            .map(|(_, rk)| {
                right
                    .schema
                    .index_of(rk)
                    .with_context(|| format!("join: no column {rk}"))
            })
            .collect::<Result<_>>()?;
        for (&l, &r) in li.iter().zip(&ri) {
            let (lt, rt) = (left.schema.fields()[l].1, right.schema.fields()[r].1);
            if lt != rt {
                bail!("join: key pair dtype mismatch {lt} vs {rt}");
            }
            if !lt.is_groupable() {
                bail!("join key must be Int64/Bool/String, got {lt}");
            }
        }
        // output schema (mirrors the IR typing rule): dtypes preserved,
        // null-introduced sides become nullable
        let mut fields: Vec<(String, DType)> = Vec::new();
        let mut nullable: Vec<bool> = Vec::new();
        for (i, (n, t)) in left.schema.fields().iter().enumerate() {
            fields.push((n.clone(), *t));
            if let Some((_, rk)) = on.iter().find(|(lk, _)| *lk == n.as_str()) {
                nullable.push(
                    left.schema.nullable_at(i)
                        || right.schema.nullable_of(rk).unwrap_or(false),
                );
            } else {
                nullable.push(left.schema.nullable_at(i) || how.nullable_left());
            }
        }
        if how.keeps_right_columns() {
            for (i, (n, t)) in right.schema.fields().iter().enumerate() {
                if on.iter().any(|(_, rk)| *rk == n.as_str()) {
                    continue;
                }
                if left.schema.dtype_of(n).is_some() {
                    bail!("join: column {n} on both sides");
                }
                fields.push((n.clone(), *t));
                nullable.push(right.schema.nullable_at(i) || how.nullable_right());
            }
        }
        let schema = Schema::new_nullable(fields, nullable);

        let li2 = li.clone();
        let keyed_l: Vec<Vec<(i64, Row)>> =
            self.run_stage(left.parts.clone(), move |_, rows| {
                keyed_by_hash(rows, &li2)
            });
        let ri2 = ri.clone();
        let keyed_r: Vec<Vec<(i64, Row)>> =
            self.run_stage(right.parts.clone(), move |_, rows| {
                keyed_by_hash(rows, &ri2)
            });
        let nreduce = self.partitions;
        let lparts = self.shuffle_rows(keyed_l, nreduce);
        let rparts = self.shuffle_rows(keyed_r, nreduce);
        // reduce side: per-partition typed hash join over key tuples
        let lfields = left.schema.fields().to_vec();
        let rfields = right.schema.fields().to_vec();
        let joined: Vec<Vec<Row>> = self.run_stage(
            lparts.into_iter().zip(rparts).collect::<Vec<_>>(),
            move |_, (lrows, rrows): (Vec<(i64, Row)>, Vec<(i64, Row)>)| {
                let lrows: Vec<Row> = lrows.into_iter().map(|(_, r)| r).collect();
                let rrows: Vec<Row> = rrows.into_iter().map(|(_, r)| r).collect();
                let lkeys: Vec<KeyRow> = lrows.iter().map(|r| row_key(r, &li)).collect();
                let rkeys: Vec<KeyRow> = rrows.iter().map(|r| row_key(r, &ri)).collect();
                let pairs = local_join_pairs(&lkeys, &rkeys, how);
                let mut out = Vec::with_capacity(pairs.len());
                for (lo, ro) in pairs {
                    let mut row: Row = Vec::new();
                    // left slots, keys taken from whichever side is present
                    for (ci, (_, t)) in lfields.iter().enumerate() {
                        if let Some(kj) = li.iter().position(|&k| k == ci) {
                            let v = match (lo, ro) {
                                (Some(i), _) => lrows[i][ci].clone(),
                                (None, Some(j)) => rrows[j][ri[kj]].clone(),
                                (None, None) => unreachable!("join pair with no sides"),
                            };
                            row.push(v);
                        } else if how.nullable_left() {
                            row.push(match lo {
                                Some(i) => lrows[i][ci].clone(),
                                None => Value::Null(*t),
                            });
                        } else {
                            row.push(lrows[lo.expect("left row")][ci].clone());
                        }
                    }
                    if how.keeps_right_columns() {
                        for (ci, (_, t)) in rfields.iter().enumerate() {
                            if ri.contains(&ci) {
                                continue;
                            }
                            if how.nullable_right() {
                                row.push(match ro {
                                    Some(j) => rrows[j][ci].clone(),
                                    None => Value::Null(*t),
                                });
                            } else {
                                row.push(rrows[ro.expect("right row")][ci].clone());
                            }
                        }
                    }
                    out.push(row);
                }
                out
            },
        );
        Ok(Rdd {
            schema,
            parts: joined,
        })
    }

    /// Group-by aggregation with map-side combine — thin single-key wrapper
    /// over [`SparkLike::aggregate_by`].
    pub fn aggregate(&self, rdd: &Rdd, key: &str, aggs: &[AggExpr]) -> Result<Rdd> {
        self.aggregate_by(rdd, &[key], aggs)
    }

    /// Composite-key group-by aggregation with map-side combine. Partial
    /// states travel the shuffle as encoded rows keyed by the hash of the
    /// key tuple; the key cells ride along so the reduce side can merge by
    /// the actual tuple.
    pub fn aggregate_by(&self, rdd: &Rdd, keys: &[&str], aggs: &[AggExpr]) -> Result<Rdd> {
        if keys.is_empty() {
            bail!("aggregate: needs at least one key column");
        }
        let ki: Vec<usize> = keys
            .iter()
            .map(|k| {
                rdd.schema
                    .index_of(k)
                    .with_context(|| format!("aggregate: no column {k}"))
            })
            .collect::<Result<_>>()?;
        for &i in &ki {
            let kt = rdd.schema.fields()[i].1;
            if !kt.is_groupable() {
                bail!("aggregate key must be Int64/Bool/String, got {kt}");
            }
        }
        let compiled: Vec<(RowExpr, AggFn, DType)> = aggs
            .iter()
            .map(|a| {
                Ok((
                    compile_row_expr(&a.input, &rdd.schema)?,
                    a.func,
                    a.input.dtype(&rdd.schema)?,
                ))
            })
            .collect::<Result<_>>()?;
        let compiled = Arc::new(compiled);
        let c2 = compiled.clone();
        let ki2 = ki.clone();
        let key_dts: Vec<DType> = ki.iter().map(|&i| rdd.schema.fields()[i].1).collect();
        let key_dts2 = key_dts.clone();
        // (output dtype, may-be-null) per aggregate — an all-null group's
        // order/moment statistics come back as typed nulls
        let out_meta: Vec<(DType, bool)> = aggs
            .iter()
            .map(|a| {
                Ok((
                    a.output_dtype(&rdd.schema)?,
                    a.output_nullable(&rdd.schema)?,
                ))
            })
            .collect::<Result<_>>()?;
        // map side: partial states per key tuple (the combiner)
        let combined: Vec<Vec<(i64, Row)>> =
            self.run_stage(rdd.parts.clone(), move |_, rows: Vec<Row>| {
                let mut table: HashMap<KeyRow, Vec<AggState>> = HashMap::new();
                for row in rows {
                    let k = row_key(&row, &ki2);
                    let states = table.entry(k).or_insert_with(|| {
                        c2.iter()
                            .map(|(_, f, dt)| AggState::new(*f, *dt))
                            .collect()
                    });
                    for ((e, _, _), s) in c2.iter().zip(states.iter_mut()) {
                        s.update(&eval_row(e, &row).expect("agg expr"));
                    }
                }
                // partial states travel the shuffle as encoded rows: the key
                // cells first, then the state bytes in one Str cell
                table
                    .into_iter()
                    .map(|(k, states)| {
                        let mut buf = Vec::new();
                        for s in &states {
                            s.encode(&mut buf);
                        }
                        let hash = hash_key_row(&k) as i64;
                        let mut row: Row = k
                            .iter()
                            .zip(&key_dts2)
                            .map(|(v, dt)| v.to_value_typed(*dt))
                            .collect();
                        row.push(Value::Str(unsafe_bytes_to_str(buf)));
                        (hash, row)
                    })
                    .collect()
            });
        let merged = self.shuffle_rows(combined, self.partitions);
        let c3 = compiled.clone();
        let nkeys = ki.len();
        let parts: Vec<Vec<Row>> = self.run_stage(merged, move |_, rows: Vec<(i64, Row)>| {
            let mut table: HashMap<KeyRow, Vec<AggState>> = HashMap::new();
            for (_, row) in rows {
                let k: KeyRow = row[..nkeys]
                    .iter()
                    .map(|v| KeyVal::from_value(v).expect("agg key cell"))
                    .collect();
                let Value::Str(ref encoded) = row[nkeys] else {
                    panic!("agg shuffle row")
                };
                let bytes = str_to_bytes(encoded);
                let mut pos = 0usize;
                let incoming: Vec<AggState> = c3
                    .iter()
                    .map(|(_, f, dt)| AggState::decode(*f, *dt, &bytes, &mut pos))
                    .collect();
                match table.entry(k) {
                    std::collections::hash_map::Entry::Occupied(mut e) => {
                        for (a, b) in e.get_mut().iter_mut().zip(&incoming) {
                            a.merge(b);
                        }
                    }
                    std::collections::hash_map::Entry::Vacant(e) => {
                        e.insert(incoming);
                    }
                }
            }
            let mut krows: Vec<KeyRow> = table.keys().cloned().collect();
            krows.sort();
            krows
                .into_iter()
                .map(|k| {
                    let mut row: Row = k
                        .iter()
                        .zip(&key_dts)
                        .map(|(v, dt)| v.to_value_typed(*dt))
                        .collect();
                    for (s, (dt, nullable)) in table[&k].iter().zip(&out_meta) {
                        if *nullable && s.is_empty() {
                            row.push(Value::Null(*dt));
                        } else {
                            row.push(s.finish());
                        }
                    }
                    row
                })
                .collect()
        });
        let mut fields: Vec<(String, DType)> = Vec::new();
        let mut nullable: Vec<bool> = Vec::new();
        for k in keys {
            let kt = rdd.schema.dtype_of(k).unwrap();
            fields.push((k.to_string(), kt));
            nullable.push(rdd.schema.nullable_of(k).unwrap_or(false));
        }
        for a in aggs {
            fields.push((a.out.clone(), a.output_dtype(&rdd.schema)?));
            nullable.push(a.output_nullable(&rdd.schema)?);
        }
        Ok(Rdd {
            schema: Schema::new_nullable(fields, nullable),
            parts,
        })
    }

    /// Window/scan operations: repartition EVERYTHING to one partition and
    /// run sequentially — the map-reduce limitation of §5/Fig. 8b.
    pub fn window_one_executor(
        &self,
        rdd: &Rdd,
        column: &str,
        out: &str,
        kind: WindowKind,
    ) -> Result<Rdd> {
        let ci = rdd
            .schema
            .index_of(column)
            .with_context(|| format!("window: no column {column}"))?;
        // gather: key everything to partition 0 through the shuffle store
        // (serialization cost included, as in Spark)
        let keyed: Vec<Vec<(i64, Row)>> = self.run_stage(rdd.parts.clone(), move |pi, rows| {
            rows.into_iter()
                .map(|r| ((pi as i64) << 32, r)) // preserve partition order in key high bits
                .collect()
        });
        let mut gathered = self.shuffle_rows(keyed, 1);
        let mut rows = std::mem::take(&mut gathered[0]);
        rows.sort_by_key(|(k, _)| *k); // restore global order
        let mut rows: Vec<Row> = rows.into_iter().map(|(_, r)| r).collect();
        // sequential computation on the single executor
        let xs: Vec<f64> = rows
            .iter()
            .map(|r| r[ci].as_f64().context("window col"))
            .collect::<Result<_>>()?;
        let vals: Vec<f64> = match &kind {
            WindowKind::Cumsum => {
                let mut acc = 0.0;
                xs.iter()
                    .map(|&x| {
                        acc += x;
                        acc
                    })
                    .collect()
            }
            WindowKind::Stencil(weights) => crate::ops::stencil_serial(&xs, weights),
            WindowKind::StencilUdf { window, func } => {
                let r = window / 2;
                let n = xs.len();
                (0..n)
                    .map(|i| {
                        let lo = i.saturating_sub(r);
                        let hi = (i + r + 1).min(n);
                        let win: Vec<f64> = xs[lo..hi].to_vec();
                        func(&win)
                    })
                    .collect()
            }
        };
        for (row, v) in rows.iter_mut().zip(vals) {
            row.push(Value::F64(v));
        }
        let mut fields = rdd.schema.fields().to_vec();
        fields.push((out.to_string(), DType::F64));
        let mut nullable = rdd.schema.nullable_flags().to_vec();
        nullable.push(false);
        // output stays on ONE partition (Spark leaves it that way too)
        let mut parts: Vec<Vec<Row>> = (0..self.partitions).map(|_| Vec::new()).collect();
        parts[0] = rows;
        Ok(Rdd {
            schema: Schema::new_nullable(fields, nullable),
            parts,
        })
    }

    /// Partitioned window functions (Spark's `OVER (PARTITION BY … ORDER BY
    /// …)`): rows route by the hash of their partition-key tuple through the
    /// serialized shuffle store, each reduce partition sorts its rows by
    /// (partition keys asc nulls-first, order keys) with a stable sort and
    /// evaluates every frame with boxed per-row loops — the row-eval parity
    /// side of the three-engine window agreement tests. Global windows (no
    /// partition keys) keep using [`SparkLike::window_one_executor`]'s
    /// single-executor gather, the map-reduce limitation of Fig. 8b.
    pub fn window_over(
        &self,
        rdd: &Rdd,
        partition_by: &[&str],
        order_by: &[(&str, SortOrder)],
        aggs: &[WindowAgg],
    ) -> Result<Rdd> {
        if partition_by.is_empty() {
            bail!("window_over: needs partition keys (global windows gather to one executor)");
        }
        let pi: Vec<usize> = partition_by
            .iter()
            .map(|k| {
                rdd.schema
                    .index_of(k)
                    .with_context(|| format!("window: no column {k}"))
            })
            .collect::<Result<_>>()?;
        let oi: Vec<usize> = order_by
            .iter()
            .map(|(k, _)| {
                rdd.schema
                    .index_of(k)
                    .with_context(|| format!("window: no column {k}"))
            })
            .collect::<Result<_>>()?;
        for &i in pi.iter().chain(&oi) {
            let kt = rdd.schema.fields()[i].1;
            if !kt.is_groupable() {
                bail!("window key must be Int64/Bool/String, got {kt}");
            }
        }
        let mut orders: Vec<SortOrder> = vec![SortOrder::Asc; pi.len()];
        orders.extend(order_by.iter().map(|(_, o)| *o));

        // compile the aggregate inputs; record (func, frame, out dtype,
        // static nullable) per aggregate — the same typing rule as the IR
        let mut exprs: Vec<RowExpr> = Vec::with_capacity(aggs.len());
        let mut metas: Vec<(WindowFunc, WindowFrame, DType)> = Vec::with_capacity(aggs.len());
        let mut fields: Vec<(String, DType)> = Vec::new();
        let mut nullable: Vec<bool> = Vec::new();
        let mut kept_idx: Vec<usize> = Vec::new();
        for (i, (n, t)) in rdd.schema.fields().iter().enumerate() {
            if aggs.iter().any(|a| &a.out == n) {
                continue;
            }
            kept_idx.push(i);
            fields.push((n.clone(), *t));
            nullable.push(rdd.schema.nullable_at(i));
        }
        for a in aggs {
            exprs.push(compile_row_expr(&a.input, &rdd.schema)?);
            let dt = a.input.dtype(&rdd.schema)?;
            let odt = a.func.output_dtype(dt);
            metas.push((a.func.clone(), a.frame.clone(), odt));
            fields.push((a.out.clone(), odt));
            nullable.push(
                a.func
                    .output_nullable(&a.frame, a.input.nullable(&rdd.schema)?),
            );
        }
        let schema = Schema::new_nullable(fields, nullable);
        let nin = rdd.schema.len();

        // map: evaluate the inputs per row (boxed row eval), append them to
        // the row tail so they ride the shuffle; key by the partition tuple
        let exprs = Arc::new(exprs);
        let e2 = exprs.clone();
        let pi_map = pi.clone();
        let keyed: Vec<Vec<(i64, Row)>> =
            self.run_stage(rdd.parts.clone(), move |_, rows: Vec<Row>| {
                rows.into_iter()
                    .map(|mut r| {
                        let tail: Vec<Value> = e2
                            .iter()
                            .map(|e| eval_row(e, &r).expect("window expr"))
                            .collect();
                        r.extend(tail);
                        let h = hash_key_row(&row_key(&r, &pi_map)) as i64;
                        (h, r)
                    })
                    .collect::<Vec<(i64, Row)>>()
            });
        let shuffled = self.shuffle_rows(keyed, self.partitions);

        // reduce: per partition sort + per-group frame evaluation
        let metas = Arc::new(metas);
        let m2 = metas.clone();
        let oi2 = oi.clone();
        let pi2 = pi.clone();
        let orders2 = orders.clone();
        let kept2 = kept_idx.clone();
        let parts: Vec<Vec<Row>> =
            self.run_stage(shuffled, move |_, rows: Vec<(i64, Row)>| {
                let mut rows: Vec<Row> = rows.into_iter().map(|(_, r)| r).collect();
                let krows: Vec<KeyRow> = rows
                    .iter()
                    .map(|r| {
                        pi2.iter()
                            .chain(&oi2)
                            .map(|&i| KeyVal::from_value(&r[i]).expect("window key"))
                            .collect()
                    })
                    .collect();
                // the same run/break rule as the hiframes exec path and the
                // serial engine — shared so the engines cannot diverge
                let (idx, group_starts, breaks) =
                    partition_runs(&krows, pi2.len(), &orders2);
                let n = idx.len();
                let sorted: Vec<Row> = idx
                    .iter()
                    .map(|&i| std::mem::take(&mut rows[i]))
                    .collect();
                let mut out_cols: Vec<Vec<Value>> = Vec::with_capacity(m2.len());
                for (j, (func, frame, odt)) in m2.iter().enumerate() {
                    let vals: Vec<Value> =
                        sorted.iter().map(|r| r[nin + j].clone()).collect();
                    let mut col: Vec<Value> = Vec::with_capacity(n);
                    for (gi, &start) in group_starts.iter().enumerate() {
                        let end = group_starts.get(gi + 1).copied().unwrap_or(n);
                        col.extend(row_window_group(
                            &vals[start..end],
                            frame,
                            func,
                            &breaks[start..end],
                            *odt,
                        ));
                    }
                    out_cols.push(col);
                }
                sorted
                    .into_iter()
                    .enumerate()
                    .map(|(i, r)| {
                        let mut out: Row =
                            kept2.iter().map(|&ci| r[ci].clone()).collect();
                        for c in &out_cols {
                            out.push(c[i].clone());
                        }
                        out
                    })
                    .collect::<Vec<Row>>()
            });
        Ok(Rdd { schema, parts })
    }

    /// Materialize an RDD back on the driver. Null cells become cleared
    /// validity bits over dtype-default values (canonical columnar form).
    pub fn collect(&self, rdd: &Rdd) -> Result<Table> {
        let mut cols: Vec<Column> = rdd
            .schema
            .fields()
            .iter()
            .map(|(_, t)| Column::new_empty(*t))
            .collect();
        let mut masks: Vec<crate::column::ValidityMask> = rdd
            .schema
            .fields()
            .iter()
            .map(|_| crate::column::ValidityMask::new_null(0))
            .collect();
        for part in &rdd.parts {
            for row in part {
                for ((c, m), v) in cols.iter_mut().zip(masks.iter_mut()).zip(row) {
                    crate::column::push_nullable(c, m, v);
                }
            }
        }
        Table::new_masked(
            rdd.schema.clone(),
            cols,
            masks.into_iter().map(Some).collect(),
        )
    }
}

impl Drop for SparkLike {
    fn drop(&mut self) {
        // close the queue and join executors
        let (tx, _) = channel();
        let old = std::mem::replace(&mut self.job_tx, tx);
        drop(old);
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Window computation kinds for [`SparkLike::window_one_executor`].
pub enum WindowKind {
    Cumsum,
    Stencil(Vec<f64>),
    StencilUdf {
        window: usize,
        func: Arc<dyn Fn(&[f64]) -> f64 + Send + Sync>,
    },
}

/// A row-oriented distributed collection.
#[derive(Debug, Clone)]
pub struct Rdd {
    pub schema: Schema,
    pub parts: Vec<Vec<Row>>,
}

impl Rdd {
    pub fn num_rows(&self) -> usize {
        self.parts.iter().map(|p| p.len()).sum()
    }
}

/// Key tuple of one row (cells at `key_idx`). Panics on F64 key cells —
/// callers validate key dtypes against the schema first.
fn row_key(row: &Row, key_idx: &[usize]) -> KeyRow {
    key_idx
        .iter()
        .map(|&i| KeyVal::from_value(&row[i]).expect("F64 join/group key"))
        .collect()
}

/// One group's window outputs from row cells — the per-row twin of the
/// columnar kernels in [`crate::ops::window`]: identical skip-null rules,
/// identical accumulation order, so values *and* null positions agree.
fn row_window_group(
    vals: &[Value],
    frame: &WindowFrame,
    func: &WindowFunc,
    breaks: &[bool],
    out_dtype: DType,
) -> Vec<Value> {
    let n = vals.len();
    match func {
        WindowFunc::RowNumber => (1..=n as i64).map(Value::I64).collect(),
        WindowFunc::Rank => rank_from_breaks(breaks)
            .as_i64()
            .iter()
            .map(|&r| Value::I64(r))
            .collect(),
        WindowFunc::Value => {
            let WindowFrame::Shift(k) = frame else {
                panic!("window value() requires a shift frame")
            };
            (0..n)
                .map(|i| {
                    let j = i as i64 - k;
                    if j >= 0 && (j as usize) < n {
                        vals[j as usize].clone()
                    } else {
                        Value::Null(out_dtype)
                    }
                })
                .collect()
        }
        _ => {
            let bounds: Box<dyn Fn(usize) -> (usize, usize)> = match frame {
                WindowFrame::Rolling {
                    preceding,
                    following,
                } => {
                    let (p, f) = (*preceding, *following);
                    Box::new(move |i: usize| (i.saturating_sub(p), (i + f + 1).min(n)))
                }
                WindowFrame::CumulativeToCurrent => Box::new(move |i: usize| (0, i + 1)),
                WindowFrame::Shift(_) => panic!("shift frames only carry value()"),
            };
            (0..n)
                .map(|i| {
                    let (lo, hi) = bounds(i);
                    match func {
                        WindowFunc::Count => Value::I64(
                            vals[lo..hi].iter().filter(|v| !v.is_null()).count() as i64,
                        ),
                        WindowFunc::Sum if out_dtype == DType::I64 => {
                            let mut acc = 0i64;
                            for v in &vals[lo..hi] {
                                if let Some(x) = if v.is_null() { None } else { v.as_i64() } {
                                    acc += x;
                                }
                            }
                            Value::I64(acc)
                        }
                        WindowFunc::Sum => {
                            let mut acc = 0.0;
                            for v in &vals[lo..hi] {
                                if let Some(x) = if v.is_null() { None } else { v.as_f64() } {
                                    acc += x;
                                }
                            }
                            Value::F64(acc)
                        }
                        WindowFunc::Mean => {
                            let mut acc = 0.0;
                            let mut cnt = 0usize;
                            for v in &vals[lo..hi] {
                                if let Some(x) = if v.is_null() { None } else { v.as_f64() } {
                                    acc += x;
                                    cnt += 1;
                                }
                            }
                            if cnt == 0 {
                                Value::Null(DType::F64)
                            } else {
                                Value::F64(acc / cnt as f64)
                            }
                        }
                        WindowFunc::Min | WindowFunc::Max if out_dtype == DType::I64 => {
                            let want_min = matches!(func, WindowFunc::Min);
                            let mut best: Option<i64> = None;
                            for v in &vals[lo..hi] {
                                if let Some(x) = if v.is_null() { None } else { v.as_i64() } {
                                    best = Some(match best {
                                        None => x,
                                        Some(b) if want_min => b.min(x),
                                        Some(b) => b.max(x),
                                    });
                                }
                            }
                            best.map(Value::I64).unwrap_or(Value::Null(DType::I64))
                        }
                        WindowFunc::Min | WindowFunc::Max => {
                            let want_min = matches!(func, WindowFunc::Min);
                            let mut best: Option<f64> = None;
                            for v in &vals[lo..hi] {
                                if let Some(x) = if v.is_null() { None } else { v.as_f64() } {
                                    best = Some(match best {
                                        None => x,
                                        Some(b) if want_min => b.min(x),
                                        Some(b) => b.max(x),
                                    });
                                }
                            }
                            best.map(Value::F64).unwrap_or(Value::Null(DType::F64))
                        }
                        WindowFunc::Weighted(w) => {
                            let WindowFrame::Rolling { preceding, .. } = frame else {
                                panic!("weighted() requires a rolling frame")
                            };
                            let mut acc = 0.0;
                            let mut used = 0.0;
                            let mut seen = false;
                            let wtotal: f64 = w.iter().sum();
                            for (j, &wj) in w.iter().enumerate() {
                                let idx = i as isize + j as isize - *preceding as isize;
                                if idx >= 0 && (idx as usize) < n {
                                    let v = &vals[idx as usize];
                                    if let Some(x) =
                                        if v.is_null() { None } else { v.as_f64() }
                                    {
                                        acc += wj * x;
                                        used += wj;
                                        seen = true;
                                    }
                                }
                            }
                            if !seen {
                                Value::Null(DType::F64)
                            } else if used != 0.0 {
                                Value::F64(acc * wtotal / used)
                            } else {
                                Value::F64(0.0)
                            }
                        }
                        _ => unreachable!("positional/value funcs handled above"),
                    }
                })
                .collect()
        }
    }
}

/// Key every row by the Fx hash of its key tuple (routing only; the reduce
/// side re-derives the tuple from the row cells).
fn keyed_by_hash(rows: Vec<Row>, key_idx: &[usize]) -> Vec<(i64, Row)> {
    rows.into_iter()
        .map(|r| {
            let h = hash_key_row(&row_key(&r, key_idx)) as i64;
            (h, r)
        })
        .collect()
}

// row wire format: key + cell-tagged values (tag 4 = typed null)
fn encode_row(key: i64, row: &Row, buf: &mut Vec<u8>) {
    buf.extend_from_slice(&key.to_le_bytes());
    buf.extend_from_slice(&(row.len() as u16).to_le_bytes());
    for v in row {
        match v {
            Value::I64(x) => {
                buf.push(0);
                buf.extend_from_slice(&x.to_le_bytes());
            }
            Value::F64(x) => {
                buf.push(1);
                buf.extend_from_slice(&x.to_le_bytes());
            }
            Value::Bool(x) => {
                buf.push(2);
                buf.push(*x as u8);
            }
            Value::Str(s) => {
                buf.push(3);
                buf.extend_from_slice(&(s.len() as u32).to_le_bytes());
                buf.extend_from_slice(s.as_bytes());
            }
            Value::Null(dt) => {
                buf.push(4);
                buf.push(match dt {
                    DType::I64 => 0,
                    DType::F64 => 1,
                    DType::Bool => 2,
                    DType::Str => 3,
                });
            }
        }
    }
}

fn decode_rows(buf: &[u8], out: &mut Vec<(i64, Row)>) {
    let mut pos = 0usize;
    while pos < buf.len() {
        let key = i64::from_le_bytes(buf[pos..pos + 8].try_into().unwrap());
        pos += 8;
        let n = u16::from_le_bytes(buf[pos..pos + 2].try_into().unwrap()) as usize;
        pos += 2;
        let mut row = Vec::with_capacity(n);
        for _ in 0..n {
            let tag = buf[pos];
            pos += 1;
            match tag {
                0 => {
                    row.push(Value::I64(i64::from_le_bytes(
                        buf[pos..pos + 8].try_into().unwrap(),
                    )));
                    pos += 8;
                }
                1 => {
                    row.push(Value::F64(f64::from_le_bytes(
                        buf[pos..pos + 8].try_into().unwrap(),
                    )));
                    pos += 8;
                }
                2 => {
                    row.push(Value::Bool(buf[pos] != 0));
                    pos += 1;
                }
                3 => {
                    let len =
                        u32::from_le_bytes(buf[pos..pos + 4].try_into().unwrap()) as usize;
                    pos += 4;
                    row.push(Value::Str(
                        String::from_utf8_lossy(&buf[pos..pos + len]).into_owned(),
                    ));
                    pos += len;
                }
                4 => {
                    let dt = match buf[pos] {
                        0 => DType::I64,
                        1 => DType::F64,
                        2 => DType::Bool,
                        3 => DType::Str,
                        d => panic!("bad null dtype tag {d}"),
                    };
                    pos += 1;
                    row.push(Value::Null(dt));
                }
                t => panic!("bad row tag {t}"),
            }
        }
        out.push((key, row));
    }
}

// agg partial states ride in a Str cell; latin-1-safe transport
fn unsafe_bytes_to_str(bytes: Vec<u8>) -> String {
    bytes.iter().map(|&b| b as char).collect()
}

fn str_to_bytes(s: &str) -> Vec<u8> {
    s.chars().map(|c| c as u8).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::expr::{col, lit};

    fn table() -> Table {
        Table::from_pairs(vec![
            ("id", Column::I64(vec![0, 1, 2, 3, 4, 5, 6, 7])),
            (
                "x",
                Column::F64(vec![0.0, 0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7]),
            ),
        ])
        .unwrap()
    }

    #[test]
    fn filter_and_collect() {
        let eng = SparkLike::new(2, 4);
        let rdd = eng.parallelize(&table());
        let f = eng.filter(&rdd, &col("x").lt(lit(0.35))).unwrap();
        let t = eng.collect(&f).unwrap();
        assert_eq!(t.column("id").unwrap().as_i64(), &[0, 1, 2, 3]);
        assert!(eng.stats.tasks_scheduled.load(Ordering::Relaxed) >= 4);
    }

    #[test]
    fn join_matches_serial() {
        let eng = SparkLike::new(3, 3);
        let right = Table::from_pairs(vec![
            ("rid", Column::I64(vec![1, 3, 5, 9])),
            ("tag", Column::I64(vec![10, 30, 50, 90])),
        ])
        .unwrap();
        let j = eng
            .join(
                &eng.parallelize(&table()),
                &eng.parallelize(&right),
                "id",
                "rid",
            )
            .unwrap();
        let t = eng.collect(&j).unwrap().sorted_by("id").unwrap();
        assert_eq!(t.column("id").unwrap().as_i64(), &[1, 3, 5]);
        assert_eq!(t.column("tag").unwrap().as_i64(), &[10, 30, 50]);
        assert!(eng.stats.shuffle_bytes.load(Ordering::Relaxed) > 0);
    }

    #[test]
    fn aggregate_with_combiner() {
        let eng = SparkLike::new(2, 4);
        let rdd = eng.parallelize(&table());
        let keyed = eng
            .with_column(&rdd, "id", &col("id").rem(lit(2i64)))
            .unwrap();
        let agg = eng
            .aggregate(
                &keyed,
                "id",
                &[
                    AggExpr::new("s", AggFn::Sum, col("x")),
                    AggExpr::new("n", AggFn::Count, col("x")),
                ],
            )
            .unwrap();
        let t = eng.collect(&agg).unwrap().sorted_by("id").unwrap();
        assert_eq!(t.column("id").unwrap().as_i64(), &[0, 1]);
        let s = t.column("s").unwrap().as_f64();
        assert!((s[0] - 1.2).abs() < 1e-9);
        assert!((s[1] - 1.6).abs() < 1e-9);
        assert_eq!(t.column("n").unwrap().as_i64(), &[4, 4]);
    }

    #[test]
    fn with_columns_batch_matches_chained() {
        let eng = SparkLike::new(2, 4);
        let rdd = eng.parallelize(&table());
        let batch = eng
            .with_columns(
                &rdd,
                &[
                    ("y", col("x").add(lit(1.0))),
                    ("z", col("y").mul(lit(2.0))),
                ],
            )
            .unwrap();
        let step = eng.with_column(&rdd, "y", &col("x").add(lit(1.0))).unwrap();
        let step = eng.with_column(&step, "z", &col("y").mul(lit(2.0))).unwrap();
        assert_eq!(eng.collect(&batch).unwrap(), eng.collect(&step).unwrap());
        assert_eq!(batch.schema.names(), vec!["id", "x", "y", "z"]);
    }

    #[test]
    fn window_gathers_to_one_partition() {
        let eng = SparkLike::new(2, 4);
        let rdd = eng.parallelize(&table());
        let w = eng
            .window_one_executor(&rdd, "x", "cs", WindowKind::Cumsum)
            .unwrap();
        // everything on partition 0 — the map-reduce limitation
        assert_eq!(w.parts[0].len(), 8);
        assert!(w.parts[1..].iter().all(|p| p.is_empty()));
        let t = eng.collect(&w).unwrap();
        let cs = t.column("cs").unwrap().as_f64();
        assert!((cs[7] - 2.8).abs() < 1e-9);
    }

    #[test]
    fn window_stencil_matches_hiframes_semantics() {
        let eng = SparkLike::new(2, 3);
        let rdd = eng.parallelize(&table());
        let w = eng
            .window_one_executor(
                &rdd,
                "x",
                "sma",
                WindowKind::Stencil(crate::ops::stencil::sma_weights(3)),
            )
            .unwrap();
        let t = eng.collect(&w).unwrap();
        let expect = crate::ops::stencil_serial(
            &table().column("x").unwrap().to_f64_vec(),
            &crate::ops::stencil::sma_weights(3),
        );
        for (a, b) in t.column("sma").unwrap().as_f64().iter().zip(&expect) {
            assert!((a - b).abs() < 1e-9);
        }
    }

    #[test]
    fn select_and_udf_window() {
        let eng = SparkLike::new(2, 2);
        let rdd = eng.parallelize(&table());
        let s = eng.select(&rdd, &["x"]).unwrap();
        assert_eq!(s.schema.names(), vec!["x"]);
        let w = eng
            .window_one_executor(
                &s,
                "x",
                "wma",
                WindowKind::StencilUdf {
                    window: 3,
                    func: Arc::new(|w: &[f64]| w.iter().sum::<f64>() / w.len() as f64),
                },
            )
            .unwrap();
        assert_eq!(eng.collect(&w).unwrap().num_rows(), 8);
    }

    #[test]
    fn left_join_and_multi_key_aggregate_parity() {
        let eng = SparkLike::new(2, 3);
        let left = Table::from_pairs(vec![
            ("id", Column::I64(vec![1, 2, 3, 4])),
            ("x", Column::F64(vec![0.1, 0.2, 0.3, 0.4])),
        ])
        .unwrap();
        let right = Table::from_pairs(vec![
            ("rid", Column::I64(vec![2, 4])),
            ("w", Column::I64(vec![20, 40])),
        ])
        .unwrap();
        let j = eng
            .join_on(
                &eng.parallelize(&left),
                &eng.parallelize(&right),
                &[("id", "rid")],
                JoinType::Left,
            )
            .unwrap();
        // dtype preserved, column marked nullable
        assert_eq!(j.schema.dtype_of("w"), Some(DType::I64));
        assert_eq!(j.schema.nullable_of("w"), Some(true));
        let t = eng.collect(&j).unwrap().sorted_by("id").unwrap();
        assert_eq!(t.num_rows(), 4);
        let w = t.column("w").unwrap().as_i64();
        let m = t.mask("w").unwrap();
        assert!(!m.get(0) && !m.get(2), "unmatched ids 1 and 3 are null");
        assert_eq!((w[0], w[2]), (0, 0), "null lanes hold the default");
        assert_eq!(w[1], 20);
        assert_eq!(w[3], 40);
        // multi-key aggregate over (id % 2, id): 4 singleton groups in
        // lexicographic tuple order
        let keyed = eng
            .with_column(
                &eng.parallelize(&left),
                "k2",
                &col("id").rem(lit(2i64)),
            )
            .unwrap();
        let agg = eng
            .aggregate_by(
                &keyed,
                &["k2", "id"],
                &[AggExpr::new("s", AggFn::Sum, col("x"))],
            )
            .unwrap();
        assert_eq!(agg.schema.names(), vec!["k2", "id", "s"]);
        let t = eng.collect(&agg).unwrap();
        let t = t
            .sorted_by_keys(&[
                ("k2", crate::types::SortOrder::Asc),
                ("id", crate::types::SortOrder::Asc),
            ])
            .unwrap();
        assert_eq!(t.column("k2").unwrap().as_i64(), &[0, 0, 1, 1]);
        assert_eq!(t.column("id").unwrap().as_i64(), &[2, 4, 1, 3]);
        let s = t.column("s").unwrap().as_f64();
        for (got, want) in s.iter().zip(&[0.2, 0.4, 0.1, 0.3]) {
            assert!((got - want).abs() < 1e-9);
        }
    }

    #[test]
    fn partitioned_window_rows() {
        let eng = SparkLike::new(2, 3);
        let t = Table::from_pairs(vec![
            ("g", Column::I64(vec![1, 2, 1, 2, 1])),
            ("o", Column::I64(vec![5, 1, 3, 2, 4])),
            ("v", Column::I64(vec![10, 20, 30, 40, 50])),
        ])
        .unwrap();
        let aggs = vec![
            WindowAgg::new(
                "prev",
                WindowFunc::Value,
                WindowFrame::Shift(1),
                crate::expr::col("v"),
            ),
            WindowAgg::new(
                "cs",
                WindowFunc::Sum,
                WindowFrame::CumulativeToCurrent,
                crate::expr::col("v"),
            ),
            WindowAgg::new(
                "r",
                WindowFunc::Rank,
                WindowFrame::CumulativeToCurrent,
                crate::expr::lit(0i64),
            ),
        ];
        let w = eng
            .window_over(
                &eng.parallelize(&t),
                &["g"],
                &[("o", SortOrder::Asc)],
                &aggs,
            )
            .unwrap();
        assert_eq!(w.schema.nullable_of("prev"), Some(true));
        assert_eq!(w.schema.nullable_of("cs"), Some(false));
        let out = eng
            .collect(&w)
            .unwrap()
            .sorted_by_keys(&[("g", SortOrder::Asc), ("o", SortOrder::Asc)])
            .unwrap();
        assert_eq!(out.column("v").unwrap().as_i64(), &[30, 50, 10, 20, 40]);
        assert_eq!(out.column("prev").unwrap().as_i64(), &[0, 30, 50, 0, 20]);
        let m = out.mask("prev").unwrap();
        assert!(!m.get(0) && !m.get(3), "group heads null");
        assert_eq!(out.column("cs").unwrap().as_i64(), &[30, 80, 90, 20, 60]);
        assert_eq!(out.column("r").unwrap().as_i64(), &[1, 2, 3, 1, 2]);
    }

    #[test]
    fn string_roundtrip_through_shuffle() {
        let eng = SparkLike::new(2, 2);
        let t = Table::from_pairs(vec![
            ("id", Column::I64(vec![1, 2, 3, 4])),
            (
                "s",
                Column::Str(vec!["a".into(), "b".into(), "c".into(), "d".into()]),
            ),
        ])
        .unwrap();
        let r = Table::from_pairs(vec![("rid", Column::I64(vec![2, 4]))]).unwrap();
        let j = eng
            .join(&eng.parallelize(&t), &eng.parallelize(&r), "id", "rid")
            .unwrap();
        let out = eng.collect(&j).unwrap().sorted_by("id").unwrap();
        assert_eq!(out.column("s").unwrap().as_str_col(), &["b".to_string(), "d".into()]);
    }
}
