//! Row-at-a-time expression evaluation for the sparklike engine.
//!
//! Two flavors, mirroring the paper's Fig. 9/10 experiment:
//! * [`compile_row_expr`] — "built-in" path: the expression tree is
//!   resolved to column indices once and interpreted per row without any
//!   boxing beyond the engine's `Value` rows (Spark SQL's hard-coded
//!   `Column` operations).
//! * [`RowUdf`] — the UDF path: a boxed closure receiving a freshly
//!   allocated `Vec<f64>` argument buffer per row (models the
//!   serialize-call-deserialize boundary UDFs cross in Spark).

use super::Row;
use crate::column::{ArithOp, CmpOp, MathFn};
use crate::expr::Expr;
use crate::table::Schema;
use crate::types::Value;
use anyhow::{Context, Result};
use std::sync::Arc;

/// Index-resolved row expression.
#[derive(Clone)]
pub enum RowExpr {
    Col(usize),
    Lit(Value),
    Arith(Box<RowExpr>, ArithOp, Box<RowExpr>),
    Cmp(Box<RowExpr>, CmpOp, Box<RowExpr>),
    And(Box<RowExpr>, Box<RowExpr>),
    Or(Box<RowExpr>, Box<RowExpr>),
    Not(Box<RowExpr>),
    Math(MathFn, Box<RowExpr>),
    BoolToInt(Box<RowExpr>),
    IsNull(Box<RowExpr>),
    FillNull(Box<RowExpr>, Value),
    Udf(RowUdf, Vec<RowExpr>),
}

/// Boxed per-row UDF.
#[derive(Clone)]
pub struct RowUdf {
    pub name: String,
    pub func: Arc<dyn Fn(&[f64]) -> f64 + Send + Sync>,
}

/// Resolve column names to indices against `schema`.
pub fn compile_row_expr(expr: &Expr, schema: &Schema) -> Result<RowExpr> {
    Ok(match expr {
        Expr::Col(name) => RowExpr::Col(
            schema
                .index_of(name)
                .with_context(|| format!("row expr: unknown column :{name}"))?,
        ),
        Expr::Lit(v) => RowExpr::Lit(v.clone()),
        Expr::Arith(a, op, b) => RowExpr::Arith(
            Box::new(compile_row_expr(a, schema)?),
            *op,
            Box::new(compile_row_expr(b, schema)?),
        ),
        Expr::Cmp(a, op, b) => RowExpr::Cmp(
            Box::new(compile_row_expr(a, schema)?),
            *op,
            Box::new(compile_row_expr(b, schema)?),
        ),
        Expr::And(a, b) => RowExpr::And(
            Box::new(compile_row_expr(a, schema)?),
            Box::new(compile_row_expr(b, schema)?),
        ),
        Expr::Or(a, b) => RowExpr::Or(
            Box::new(compile_row_expr(a, schema)?),
            Box::new(compile_row_expr(b, schema)?),
        ),
        Expr::Not(a) => RowExpr::Not(Box::new(compile_row_expr(a, schema)?)),
        Expr::Math(f, a) => RowExpr::Math(*f, Box::new(compile_row_expr(a, schema)?)),
        Expr::BoolToInt(a) => RowExpr::BoolToInt(Box::new(compile_row_expr(a, schema)?)),
        Expr::IsNull(a) => RowExpr::IsNull(Box::new(compile_row_expr(a, schema)?)),
        Expr::FillNull(a, v) => {
            RowExpr::FillNull(Box::new(compile_row_expr(a, schema)?), v.clone())
        }
        Expr::Udf(u, args) => RowExpr::Udf(
            RowUdf {
                name: u.name.clone(),
                func: u.func.clone(),
            },
            args.iter()
                .map(|a| compile_row_expr(a, schema))
                .collect::<Result<_>>()?,
        ),
    })
}

/// Evaluate over one row. Typed nulls propagate through every element-wise
/// operator (null in ⇒ null out, mirroring the columnar validity AND);
/// `IS NULL` / `fill_null` stop the propagation.
pub fn eval_row(e: &RowExpr, row: &Row) -> Result<Value> {
    Ok(match e {
        RowExpr::Col(i) => row[*i].clone(),
        RowExpr::Lit(v) => v.clone(),
        RowExpr::Arith(a, op, b) => {
            let (x, y) = (eval_row(a, row)?, eval_row(b, row)?);
            if x.is_null() || y.is_null() {
                let dt = x.dtype().promote(y.dtype()).unwrap_or_else(|| x.dtype());
                return Ok(Value::Null(dt));
            }
            match (&x, &y) {
                (Value::I64(xi), Value::I64(yi)) if *op != ArithOp::Div => {
                    let r = match op {
                        ArithOp::Add => xi + yi,
                        ArithOp::Sub => xi - yi,
                        ArithOp::Mul => xi * yi,
                        ArithOp::Mod => xi % yi,
                        ArithOp::Div => unreachable!(),
                    };
                    Value::I64(r)
                }
                _ => {
                    let xf = x.as_f64().context("arith on non-numeric")?;
                    let yf = y.as_f64().context("arith on non-numeric")?;
                    Value::F64(match op {
                        ArithOp::Add => xf + yf,
                        ArithOp::Sub => xf - yf,
                        ArithOp::Mul => xf * yf,
                        ArithOp::Div => xf / yf,
                        ArithOp::Mod => xf % yf,
                    })
                }
            }
        }
        RowExpr::Cmp(a, op, b) => {
            let (x, y) = (eval_row(a, row)?, eval_row(b, row)?);
            if x.is_null() || y.is_null() {
                return Ok(Value::Null(crate::types::DType::Bool));
            }
            let r = match (&x, &y) {
                (Value::Str(xs), Value::Str(ys)) => match op {
                    CmpOp::Lt => xs < ys,
                    CmpOp::Le => xs <= ys,
                    CmpOp::Gt => xs > ys,
                    CmpOp::Ge => xs >= ys,
                    CmpOp::Eq => xs == ys,
                    CmpOp::Ne => xs != ys,
                },
                _ => {
                    let xf = x.as_f64().context("cmp on non-numeric")?;
                    let yf = y.as_f64().context("cmp on non-numeric")?;
                    match op {
                        CmpOp::Lt => xf < yf,
                        CmpOp::Le => xf <= yf,
                        CmpOp::Gt => xf > yf,
                        CmpOp::Ge => xf >= yf,
                        CmpOp::Eq => xf == yf,
                        CmpOp::Ne => xf != yf,
                    }
                }
            };
            Value::Bool(r)
        }
        RowExpr::And(a, b) => {
            // SQL three-valued logic: FALSE AND NULL = FALSE, TRUE AND NULL
            // = NULL (mirrors the columnar Kleene validity)
            let (x, y) = (eval_row(a, row)?, eval_row(b, row)?);
            if x.as_bool() == Some(false) || y.as_bool() == Some(false) {
                return Ok(Value::Bool(false));
            }
            if x.is_null() || y.is_null() {
                return Ok(Value::Null(crate::types::DType::Bool));
            }
            Value::Bool(x.as_bool().context("and lhs")? && y.as_bool().context("and rhs")?)
        }
        RowExpr::Or(a, b) => {
            // SQL three-valued logic: TRUE OR NULL = TRUE, FALSE OR NULL =
            // NULL (mirrors the columnar Kleene validity)
            let (x, y) = (eval_row(a, row)?, eval_row(b, row)?);
            if x.as_bool() == Some(true) || y.as_bool() == Some(true) {
                return Ok(Value::Bool(true));
            }
            if x.is_null() || y.is_null() {
                return Ok(Value::Null(crate::types::DType::Bool));
            }
            Value::Bool(x.as_bool().context("or lhs")? || y.as_bool().context("or rhs")?)
        }
        RowExpr::Not(a) => {
            let x = eval_row(a, row)?;
            if x.is_null() {
                return Ok(Value::Null(crate::types::DType::Bool));
            }
            Value::Bool(!x.as_bool().context("not")?)
        }
        RowExpr::Math(f, a) => {
            let v = eval_row(a, row)?;
            if v.is_null() {
                // Abs/Neg keep Int64, everything else widens to Float64 —
                // the columnar Math typing rule
                let dt = match (f, v.dtype()) {
                    (MathFn::Abs | MathFn::Neg, crate::types::DType::I64) => {
                        crate::types::DType::I64
                    }
                    _ => crate::types::DType::F64,
                };
                return Ok(Value::Null(dt));
            }
            let x = v.as_f64().context("math arg")?;
            let r = match f {
                MathFn::Log => x.ln(),
                MathFn::Exp => x.exp(),
                MathFn::Sqrt => x.sqrt(),
                MathFn::Sin => x.sin(),
                MathFn::Cos => x.cos(),
                MathFn::Abs => x.abs(),
                MathFn::Neg => -x,
            };
            // match the columnar Math output dtype for Abs/Neg over Int64
            match (f, &v) {
                (MathFn::Abs | MathFn::Neg, Value::I64(_)) => Value::I64(r as i64),
                _ => Value::F64(r),
            }
        }
        RowExpr::BoolToInt(a) => {
            let v = eval_row(a, row)?;
            if v.is_null() {
                return Ok(Value::Null(crate::types::DType::I64));
            }
            Value::I64(v.as_bool().context("bool_to_int")? as i64)
        }
        RowExpr::IsNull(a) => Value::Bool(eval_row(a, row)?.is_null()),
        RowExpr::FillNull(a, fill) => {
            let v = eval_row(a, row)?;
            match v {
                // coerce the fill literal to the operand's dtype, like the
                // columnar fill_null kernel
                Value::Null(dt) => match dt {
                    crate::types::DType::I64 => {
                        Value::I64(fill.as_i64().context("fill_null int")?)
                    }
                    crate::types::DType::F64 => {
                        Value::F64(fill.as_f64().context("fill_null float")?)
                    }
                    crate::types::DType::Bool => {
                        Value::Bool(fill.as_bool().context("fill_null bool")?)
                    }
                    crate::types::DType::Str => match fill {
                        Value::Str(s) => Value::Str(s.clone()),
                        other => anyhow::bail!("fill_null: cannot fill String with {other:?}"),
                    },
                },
                other => other,
            }
        }
        RowExpr::Udf(u, args) => {
            // per-row argument buffer allocation: the measured UDF overhead
            let mut argv = Vec::with_capacity(args.len());
            for a in args {
                let v = eval_row(a, row)?;
                if v.is_null() {
                    return Ok(Value::Null(crate::types::DType::F64));
                }
                argv.push(v.as_f64().context("udf arg")?);
            }
            Value::F64((u.func)(&argv))
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::expr::{col, lit, Udf};
    use crate::types::DType;

    fn schema() -> Schema {
        Schema::of(&[("id", DType::I64), ("x", DType::F64)])
    }

    #[test]
    fn arithmetic_and_compare() {
        let e = compile_row_expr(&col("id").add(lit(1i64)).lt(col("x")), &schema()).unwrap();
        let row: Row = vec![Value::I64(1), Value::F64(3.0)];
        assert_eq!(eval_row(&e, &row).unwrap(), Value::Bool(true));
        let row: Row = vec![Value::I64(5), Value::F64(3.0)];
        assert_eq!(eval_row(&e, &row).unwrap(), Value::Bool(false));
    }

    #[test]
    fn int_arith_stays_int() {
        let e = compile_row_expr(&col("id").rem(lit(3i64)), &schema()).unwrap();
        let row: Row = vec![Value::I64(7), Value::F64(0.0)];
        assert_eq!(eval_row(&e, &row).unwrap(), Value::I64(1));
    }

    #[test]
    fn udf_through_rows() {
        let u = Udf::new("plus2", |a| a[0] + 2.0);
        let e = compile_row_expr(&Expr::Udf(u, vec![col("x")]), &schema()).unwrap();
        let row: Row = vec![Value::I64(0), Value::F64(40.0)];
        assert_eq!(eval_row(&e, &row).unwrap(), Value::F64(42.0));
    }

    #[test]
    fn unknown_column_fails_compile() {
        assert!(compile_row_expr(&col("zzz"), &schema()).is_err());
    }
}
