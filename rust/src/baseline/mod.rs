//! Comparison engines (substrates for the paper's evaluation):
//!
//! * [`sparklike`] — a faithful architectural model of the Spark SQL
//!   execution the paper benchmarks against: a master/driver with a
//!   centrally scheduled task queue (the sequential bottleneck of §2.2),
//!   row-oriented partitions, fully serialized shuffles through a shuffle
//!   store, map-side combiners for aggregation, window functions executed
//!   on a *single* executor after a gather (the §5 "Spark SQL gathers all
//!   the data on a single executor" behaviour), and boxed per-row UDFs
//!   (the Fig. 9/10 overhead).
//! * [`serial`] — the Pandas/Julia stand-in: single-threaded, eager,
//!   vectorized columnar ops, plus a row-lambda `rolling_apply` mode that
//!   reproduces the Pandas `rolling().apply(lambda)` slow path.
//!
//! Neither engine shares operator code with the HiFrames executor, so the
//! engine-agreement tests are meaningful cross-checks.

pub mod rowexpr;
pub mod serial;
pub mod sparklike;

use crate::types::Value;

/// A row in the row-oriented baseline engine.
pub type Row = Vec<Value>;
