//! Serial engine — the Pandas / Julia DataFrames stand-in.
//!
//! Eager, single-threaded, columnar-vectorized (Pandas' C backend). The
//! split the paper highlights in §5 is preserved: built-in operations run
//! vectorized ([`filter`], [`aggregate`], [`sma`]), while user-lambda paths
//! ([`filter_udf_rows`], [`rolling_apply`]) walk rows through boxed
//! closures — reproducing the Pandas SMA-vs-WMA gap of Fig. 8b.

use crate::column::Column;
use crate::expr::{eval, AggExpr, Expr};
use crate::ops::aggregate::{local_hash_aggregate, AggSpec};
use crate::ops::stencil::stencil_serial;
use crate::table::Table;
use anyhow::{Context, Result};

/// Vectorized filter (`df[df[:id] .< 100, :]`).
pub fn filter(table: &Table, predicate: &Expr) -> Result<Table> {
    let mask = eval(predicate, table)?;
    Ok(table.filter(mask.as_bool()))
}

/// Row-lambda filter — the "any expression evaluating to Boolean" Pandas
/// path that is "not evaluated inside the optimized backend" (§5).
pub fn filter_udf_rows(table: &Table, f: &dyn Fn(&[f64]) -> bool, cols: &[&str]) -> Result<Table> {
    let inputs: Vec<Vec<f64>> = cols
        .iter()
        .map(|c| {
            table
                .column(c)
                .with_context(|| format!("no column {c}"))
                .map(|col| col.to_f64_vec())
        })
        .collect::<Result<_>>()?;
    let n = table.num_rows();
    let mut mask = Vec::with_capacity(n);
    for i in 0..n {
        // fresh argument buffer per row — the boxed-lambda cost
        let argv: Vec<f64> = inputs.iter().map(|c| c[i]).collect();
        mask.push(f(&argv));
    }
    Ok(table.filter(&mask))
}

/// Hash inner join (Pandas `merge`).
pub fn join(left: &Table, right: &Table, lk: &str, rk: &str) -> Result<Table> {
    let lkeys = left.column(lk).context("join: left key")?.as_i64();
    let rkeys = right.column(rk).context("join: right key")?.as_i64();
    let mut index: crate::fxhash::FxHashMap<i64, Vec<usize>> = Default::default();
    for (j, &k) in rkeys.iter().enumerate() {
        index.entry(k).or_default().push(j);
    }
    let mut li = Vec::new();
    let mut ri = Vec::new();
    for (i, &k) in lkeys.iter().enumerate() {
        if let Some(matches) = index.get(&k) {
            for &j in matches {
                li.push(i);
                ri.push(j);
            }
        }
    }
    let mut pairs: Vec<(&str, Column)> = Vec::new();
    for (n, _) in left.schema().fields() {
        pairs.push((n.as_str(), left.column(n).unwrap().take(&li)));
    }
    for (n, _) in right.schema().fields() {
        if n == rk {
            continue;
        }
        pairs.push((n.as_str(), right.column(n).unwrap().take(&ri)));
    }
    Table::from_pairs(pairs)
}

/// Group-by aggregation (Pandas `groupby().agg`).
pub fn aggregate(table: &Table, key: &str, aggs: &[AggExpr]) -> Result<Table> {
    let keys = table.column(key).context("aggregate: key")?.as_i64();
    let mut expr_cols = Vec::with_capacity(aggs.len());
    let mut specs = Vec::with_capacity(aggs.len());
    for a in aggs {
        let c = eval(&a.input, table)?;
        specs.push(AggSpec {
            func: a.func,
            input_dtype: c.dtype(),
        });
        expr_cols.push(c);
    }
    let (out_keys, out_cols) = local_hash_aggregate(keys, &expr_cols, &specs);
    let mut pairs: Vec<(&str, Column)> = vec![(key, Column::I64(out_keys))];
    for (a, c) in aggs.iter().zip(out_cols) {
        pairs.push((a.out.as_str(), c));
    }
    Table::from_pairs(pairs)
}

/// Vertical concat.
pub fn concat(a: &Table, b: &Table) -> Result<Table> {
    a.concat(b)
}

/// Vectorized cumulative sum.
pub fn cumsum(table: &Table, column: &str, out: &str) -> Result<Table> {
    let src = table.column(column).context("cumsum col")?;
    let new = match src {
        Column::I64(v) => {
            let mut acc = 0i64;
            Column::I64(
                v.iter()
                    .map(|&x| {
                        acc += x;
                        acc
                    })
                    .collect(),
            )
        }
        other => {
            let v = other.to_f64_vec();
            let mut acc = 0.0;
            Column::F64(
                v.iter()
                    .map(|&x| {
                        acc += x;
                        acc
                    })
                    .collect(),
            )
        }
    };
    with_new_column(table, out, new)
}

/// Vectorized SMA (`rolling(w, center=True).mean()` — the fast Pandas path).
pub fn sma(table: &Table, column: &str, out: &str, window: usize) -> Result<Table> {
    let xs = table.column(column).context("sma col")?.to_f64_vec();
    let w = crate::ops::stencil::sma_weights(window);
    with_new_column(table, out, Column::F64(stencil_serial(&xs, &w)))
}

/// Row-lambda rolling window (`rolling(w).apply(lambda)` — the slow path).
/// The lambda sees the raw window (edge windows are truncated); weights
/// semantics must be applied by the lambda itself, exactly like Pandas.
pub fn rolling_apply(
    table: &Table,
    column: &str,
    out: &str,
    window: usize,
    f: &dyn Fn(&[f64]) -> f64,
) -> Result<Table> {
    assert!(window % 2 == 1);
    let xs = table.column(column).context("rolling col")?.to_f64_vec();
    let r = window / 2;
    let n = xs.len();
    let mut vals = Vec::with_capacity(n);
    for i in 0..n {
        let lo = i.saturating_sub(r);
        let hi = (i + r + 1).min(n);
        // per-row window copy through a boxed closure: the measured cost
        let win: Vec<f64> = xs[lo..hi].to_vec();
        vals.push(f(&win));
    }
    with_new_column(table, out, Column::F64(vals))
}

/// Vectorized WMA with explicit weights (matches HiFrames stencil
/// semantics: truncated + renormalized edges).
pub fn wma(table: &Table, column: &str, out: &str, weights: &[f64]) -> Result<Table> {
    let xs = table.column(column).context("wma col")?.to_f64_vec();
    with_new_column(table, out, Column::F64(stencil_serial(&xs, weights)))
}

fn with_new_column(table: &Table, out: &str, col: Column) -> Result<Table> {
    let mut pairs: Vec<(&str, Column)> = Vec::new();
    for (n, _) in table.schema().fields() {
        if n != out {
            pairs.push((n.as_str(), table.column(n).unwrap().clone()));
        }
    }
    pairs.push((out, col));
    Table::from_pairs(pairs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::expr::{col, lit, AggFn};

    fn t() -> Table {
        Table::from_pairs(vec![
            ("id", Column::I64(vec![1, 2, 1, 3])),
            ("x", Column::F64(vec![0.5, 1.5, 2.5, 3.5])),
        ])
        .unwrap()
    }

    #[test]
    fn filter_both_paths_agree() {
        let a = filter(&t(), &col("x").gt(lit(1.0))).unwrap();
        let b = filter_udf_rows(&t(), &|v| v[0] > 1.0, &["x"]).unwrap();
        assert_eq!(a, b);
        assert_eq!(a.num_rows(), 3);
    }

    #[test]
    fn join_matches_expected() {
        let r = Table::from_pairs(vec![
            ("cid", Column::I64(vec![1, 3])),
            ("w", Column::I64(vec![10, 30])),
        ])
        .unwrap();
        let j = join(&t(), &r, "id", "cid").unwrap();
        assert_eq!(j.num_rows(), 3); // id 1 twice + id 3 once
        assert_eq!(j.schema().names(), vec!["id", "x", "w"]);
    }

    #[test]
    fn aggregate_matches() {
        let a = aggregate(
            &t(),
            "id",
            &[AggExpr::new("n", AggFn::Count, col("x"))],
        )
        .unwrap();
        let s = a.sorted_by("id").unwrap();
        assert_eq!(s.column("n").unwrap().as_i64(), &[2, 1, 1]);
    }

    #[test]
    fn cumsum_and_windows() {
        let c = cumsum(&t(), "x", "cs").unwrap();
        assert_eq!(c.column("cs").unwrap().as_f64(), &[0.5, 2.0, 4.5, 8.0]);
        let s = sma(&t(), "x", "m", 3).unwrap();
        assert!((s.column("m").unwrap().as_f64()[1] - 1.5).abs() < 1e-12);
        // rolling_apply with mean lambda == vectorized sma
        let ra = rolling_apply(&t(), "x", "m", 3, &|w| {
            w.iter().sum::<f64>() / w.len() as f64
        })
        .unwrap();
        for (a, b) in ra
            .column("m")
            .unwrap()
            .as_f64()
            .iter()
            .zip(s.column("m").unwrap().as_f64())
        {
            assert!((a - b).abs() < 1e-12);
        }
    }
}
